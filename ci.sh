#!/usr/bin/env bash
# CI gate: formatting, lints, build, and the tier-1 test suite.
#
# Usage: ./ci.sh [--quick]
#   --quick   skip the release build (debug test run only)
#
# Python-side kernel tests run separately (python/tests) and require jax;
# they are not part of the rust tier-1 gate.
set -euo pipefail
cd "$(dirname "$0")"

quick=0
[[ "${1:-}" == "--quick" ]] && quick=1

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (-D warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

if [[ $quick -eq 0 ]]; then
  echo "== cargo build --release =="
  cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== bench_wire smoke =="
CAESAR_BENCH_QUICK=1 cargo bench --bench bench_wire

echo "CI OK"

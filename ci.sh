#!/usr/bin/env bash
# CI gate: formatting, lints, build, and the tier-1 test suite.
#
# Usage: ./ci.sh [--quick]
#   --quick   skip the release build (debug test run only)
#
# Python-side kernel tests run separately (python/tests) and require jax;
# they are not part of the rust tier-1 gate.
set -euo pipefail
cd "$(dirname "$0")"

quick=0
[[ "${1:-}" == "--quick" ]] && quick=1

# --- BENCH_*.json schema check (no toolchain needed) ---
# Committed bench files are either written by the bench binaries
# (placeholder: false) or hand-authored placeholders (placeholder: true,
# see CHANGES.md conventions). Either way they must carry the writers'
# required keys, so placeholder files can't silently drift from the
# format rust/benches/bench_{engine,wire}.rs emit.
echo "== BENCH_*.json schema check =="
require_keys() {
  local f=$1; shift
  [[ -f "$f" ]] || { echo "schema check: $f missing"; exit 1; }
  grep -Eq '"placeholder": *(true|false)' "$f" \
    || { echo "schema check: $f lacks a boolean \"placeholder\" flag"; exit 1; }
  local k
  for k in "$@"; do
    grep -q "\"$k\"" "$f" \
      || { echo "schema check: $f missing required key \"$k\""; exit 1; }
  done
  echo "  $f ok"
}
# keep these lists in sync with the JSON writers in rust/benches/
require_keys BENCH_engine.json bench task trainer host_workers cases \
  devices participants seq_ms_per_round par_ms_per_round workers speedup \
  seq_alloc_bytes_per_round par_alloc_bytes_per_round \
  seq_encode_calls_per_round encode_cache encode_requests_per_round \
  encode_calls_per_round encode_reduction \
  pool trainer_builds builds_reduction \
  cross_round_cache cache_cross_round_hits \
  semi_async barrier_round_s_mean overlap_round_s_mean round_s_reduction \
  barrier_ms_per_round overlap_ms_per_round staleness_bound \
  selection_scale keys rank sort_ms_per_call radix_ms_per_call \
  select_speedup radix_warm_alloc_bytes_per_call knee_keys \
  tree_agg groups chunk fold_baseline_ms stream_ms tree_ms \
  stream_reduce_alloc_bytes tree_reduce_alloc_bytes \
  stream_peak_delta_bytes tree_peak_delta_bytes max_chunk_len
require_keys BENCH_wire.json bench n_params codec_cases recovery aggregation \
  recover_ms recover_into_ms recover_alloc_bytes_per_call \
  recover_into_alloc_bytes_per_call dense_ms sparse_ms speedup
require_keys BENCH_transport.json bench codec_cases tcp_roundtrip \
  n_params kind frame_bytes encode_ns encode_frames_per_s \
  encode_allocs_per_frame decode_ns decode_frames_per_s \
  decode_allocs_per_frame rtt_us \
  fleet_mux conns devices_per_conn frames_per_round \
  reactor_frames_per_s reactor_ms_per_round reactor_wakeups \
  sleep_poll_frames_per_s sleep_poll_ms_per_round sleep_poll_wakeups \
  wakeup_ratio
require_keys BENCH_journal.json bench append_cases recover \
  case frame_bytes append_ns appends_per_s mb_per_s \
  allocs_per_append alloc_bytes_per_append \
  image_bytes records scan_ns

echo "== cargo fmt --check =="
cargo fmt --all -- --check

echo "== cargo clippy (-D warnings) =="
cargo clippy --workspace --all-targets -- -D warnings

if [[ $quick -eq 0 ]]; then
  echo "== cargo build --release =="
  cargo build --release
fi

echo "== cargo test -q =="
cargo test -q

echo "== transport smoke (two processes over an ephemeral localhost port) =="
# the example runs an in-process baseline, then re-execs itself as a Tcp
# coordinator + a device-fleet process and ASSERTS the model digests are
# bit-identical — the transport parity invariant across real OS process
# and socket boundaries (tests/transport_parity.rs pins the same
# invariant in-process, including reconnect-with-rejoin)
cargo run --release --example transport_localhost

echo "== fleet transport smoke (2 fleet processes x 4 devices over one connection each) =="
# the multiplexed sibling: 8 devices carried by TWO fleet processes (one
# connection each, 4 sessions per connection) against a Tcp coordinator
# on an ephemeral port; the example ASSERTS the model digest equals the
# in-process baseline — connection packing is invisible to the math
cargo run --release --example transport_fleet

echo "== bench_wire smoke =="
# run from a temp dir: the bench writes BENCH_wire.json to its cwd, and
# quick-mode numbers must never clobber the committed (schema-checked)
# file at the repo root
smoke_dir=$(mktemp -d)
trap 'rm -rf "$smoke_dir"' EXIT
(
  cd "$smoke_dir"
  CAESAR_BENCH_QUICK=1 cargo bench \
    --manifest-path "$OLDPWD/Cargo.toml" --bench bench_wire
)

echo "== bench_engine smoke =="
# quick rounds at fleet scale; the bench ASSERTS the persistent-pool
# acceptance target (trainer builds O(workers) per run, >= R x fewer
# than the legacy per-round fan-out), so CI fails if the pool regresses
(
  cd "$smoke_dir"
  CAESAR_BENCH_QUICK=1 cargo bench \
    --manifest-path "$OLDPWD/Cargo.toml" --bench bench_engine
)

echo "== bench_compress smoke =="
# codec micro-benches, including the radix-vs-sort threshold-select case
# (writes nothing, but stay in the temp dir like the other smokes)
(
  cd "$smoke_dir"
  CAESAR_BENCH_QUICK=1 cargo bench \
    --manifest-path "$OLDPWD/Cargo.toml" --bench bench_compress
)

echo "== bench_transport smoke =="
# frame codec throughput + a live localhost Tcp echo session
(
  cd "$smoke_dir"
  CAESAR_BENCH_QUICK=1 cargo bench \
    --manifest-path "$OLDPWD/Cargo.toml" --bench bench_transport
)

echo "== journal smoke (kill-point resume + offline replay) =="
# a short journaled run is killed mid-run by the scripted fault injector
# (expected to exit non-zero), resumed to completion from the journal,
# and then cross-checked offline with `caesar replay` — the durable-rounds
# invariant end to end through the real CLI (tests/durability.rs pins the
# bit-identity sweep in-process)
journal="$smoke_dir/smoke.cjl"
run_flags="scheme=caesar task=har rounds=3 devices=6 alpha=0.5 n-train=240 \
  eval-every=2 seed=7 trainer=native compression-backend=native quiet"
if cargo run --release --bin caesar -- run $run_flags \
  journal="$journal" journal-every=2 journal-kill-after=9 \
  out="$smoke_dir/killed"; then
  echo "journal smoke: the armed kill point did not fire"; exit 1
fi
[[ -s "$journal" ]] || { echo "journal smoke: no journal written"; exit 1; }
cargo run --release --bin caesar -- run $run_flags \
  journal="$journal" journal-every=2 out="$smoke_dir/resumed"
cargo run --release --bin caesar -- replay journal="$journal"

echo "== pipelined journal smoke (semi-async rounds survive kill + replay) =="
# the same kill/resume/replay loop with the semi-async window open:
# round t+1 is in flight while round t's stragglers fold through the
# staleness buffer, and the journal grammar (EndRound fold_t) must
# resume and replay exactly like the barrier schedule
pipe_journal="$smoke_dir/smoke_pipe.cjl"
pipe_flags="$run_flags pipeline-depth=2 staleness-bound=1"
if cargo run --release --bin caesar -- run $pipe_flags \
  journal="$pipe_journal" journal-every=2 journal-kill-after=9 \
  out="$smoke_dir/pipe_killed"; then
  echo "pipelined journal smoke: the armed kill point did not fire"; exit 1
fi
[[ -s "$pipe_journal" ]] || { echo "pipelined journal smoke: no journal written"; exit 1; }
cargo run --release --bin caesar -- run $pipe_flags \
  journal="$pipe_journal" journal-every=2 out="$smoke_dir/pipe_resumed"
cargo run --release --bin caesar -- replay journal="$pipe_journal"

echo "== bench_journal smoke =="
# append throughput + recovery-scan rate, quick mode
(
  cd "$smoke_dir"
  CAESAR_BENCH_QUICK=1 cargo bench \
    --manifest-path "$OLDPWD/Cargo.toml" --bench bench_journal
)

echo "CI OK"

//! Quickstart: the smallest end-to-end Caesar run.
//!
//! Builds an 80-device simulated fleet, trains the HAR stand-in task for
//! 25 communication rounds with Caesar's low-deviation compression, and
//! prints accuracy / traffic / simulated time as it goes.
//!
//! Run with:  cargo run --release --example quickstart
//! (requires `make artifacts` first; pass `trainer=native` to skip)

use caesar_fl::config::ExperimentConfig;
use caesar_fl::coordinator::Server;
use caesar_fl::schemes;
use caesar_fl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();

    // 1. Start from the paper's §6.1 preset for HAR and shrink it so the
    //    example finishes in seconds. Any `key=value` CLI arg overrides.
    let mut cfg = ExperimentConfig::preset("har");
    cfg.rounds = 25;
    cfg.n_train = 4000;
    cfg.n_test = 1000;
    let cfg = cfg.apply_overrides(&args);

    // 2. Pick the scheme. `schemes::by_name` knows Caesar, the four
    //    baselines, the two ablations and the Fig. 1 preliminary schemes.
    let scheme = schemes::by_name("caesar").unwrap();

    // 3. The Server owns the fleet, the non-IID partition, the PJRT
    //    runtime (artifacts/*.hlo.txt) and the round loop.
    let mut server = Server::new(cfg, scheme)?;
    let result = server.run_cb(|r| {
        if !r.accuracy.is_nan() && r.t % 5 == 0 {
            println!(
                "round {:>3}  acc={:.3}  loss={:.3}  traffic={:.3} GB  sim-time={:.0} s  wait={:.1} s",
                r.t, r.accuracy, r.mean_loss, r.traffic_gb, r.sim_time_s, r.avg_wait_s
            );
        }
    })?;

    println!(
        "\ndone: final acc={:.4}, total traffic={:.3} GB, simulated wall-clock={:.0} s",
        result.final_metric(false),
        result.total_traffic_gb(),
        result.total_time_s()
    );
    Ok(())
}

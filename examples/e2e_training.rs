//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! Trains the CIFAR-10 stand-in with Caesar AND FedAvg head-to-head for
//! 150 communication rounds, with
//!   * Layer 1/2 — local SGD + eval executed from the AOT HLO artifacts
//!     through the PJRT CPU runtime (python never runs),
//!   * Layer 3 — the rust coordinator doing staleness-aware download
//!     compression, importance-ranked upload compression and Eq. 7–9
//!     batch regulation,
//! and logs the loss/accuracy curve plus the traffic ledger. The run is
//! recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run with:  cargo run --release --example e2e_training [key=value ...]

use caesar_fl::config::ExperimentConfig;
use caesar_fl::coordinator::{RunResult, Server};
use caesar_fl::schemes;
use caesar_fl::util::cli::Args;

fn run(scheme: &str, args: &Args) -> anyhow::Result<RunResult> {
    let mut cfg = ExperimentConfig::preset("cifar");
    cfg.rounds = 150;
    cfg.n_train = 10_000;
    cfg.n_test = 2_000;
    cfg.eval_every = 5;
    let cfg = cfg.apply_overrides(args);
    println!(
        "=== {scheme} | task=cifar devices={} rounds={} alpha={} p={} trainer={:?} ===",
        cfg.n_devices(),
        cfg.rounds,
        cfg.alpha,
        cfg.het_p,
        cfg.trainer
    );
    let t0 = std::time::Instant::now();
    let mut server = Server::new(cfg, schemes::by_name(scheme).unwrap())?;
    let result = server.run_cb(|r| {
        if !r.accuracy.is_nan() && r.t % 25 == 0 {
            println!(
                "  round {:>4}  acc={:.4}  loss={:.4}  traffic={:>7.2} GB  sim={:>8.0} s  wait={:.1} s",
                r.t, r.accuracy, r.mean_loss, r.traffic_gb, r.sim_time_s, r.avg_wait_s
            );
        }
    })?;
    println!(
        "  >> final acc={:.4}  traffic={:.2} GB  sim-time={:.0} s  (real {:.1} s)",
        result.final_metric(false),
        result.total_traffic_gb(),
        result.total_time_s(),
        t0.elapsed().as_secs_f64()
    );
    Ok(result)
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let caesar = run("caesar", &args)?;
    let fedavg = run("fedavg", &args)?;

    // headline comparison at the best accuracy both runs reach
    let target = caesar
        .best_metric(false)
        .min(fedavg.best_metric(false));
    let target = (target * 100.0).floor() / 100.0;
    println!("\n=== head-to-head at target accuracy {target:.2} ===");
    for r in [&caesar, &fedavg] {
        match r.time_traffic_at(target, false) {
            Some((time, gb)) => println!(
                "  {:<8} traffic {:>7.2} GB   sim-time {:>8.0} s   mean wait {:>5.1} s",
                r.scheme,
                gb,
                time,
                r.mean_wait_s()
            ),
            None => println!("  {:<8} did not reach {target:.2}", r.scheme),
        }
    }
    if let (Some((tc, gc)), Some((tf, gf))) = (
        caesar.time_traffic_at(target, false),
        fedavg.time_traffic_at(target, false),
    ) {
        println!(
            "  Caesar saves {:.1}% traffic and gives {:.2}x speedup over FedAvg",
            100.0 * (1.0 - gc / gf),
            tf / tc
        );
    }

    let dir = std::path::Path::new("results/e2e");
    caesar.save(dir, "e2e")?;
    fedavg.save(dir, "e2e")?;
    println!("\nper-round curves saved under {}", dir.display());
    Ok(())
}

//! Device-scale stress example (the Fig. 10 scenario as a library example).
//!
//! Scales the simulated Jetson fleet to 100 / 200 / 400 devices and runs
//! one Caesar round-trip at each scale, reporting orchestration overhead:
//! per-round planning + codec + aggregation cost as measured on the host,
//! next to the simulated round time. Demonstrates the coordinator is not
//! the bottleneck as the fleet grows.
//!
//! Run with:  cargo run --release --example device_scale

use caesar_fl::config::ExperimentConfig;
use caesar_fl::coordinator::Server;
use caesar_fl::fleet::FleetKind;
use caesar_fl::schemes;
use caesar_fl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let rounds = args.get_usize("rounds").unwrap_or(10);

    println!(
        "{:>8}  {:>14}  {:>12}  {:>12}  {:>10}",
        "devices", "host ms/round", "sim s/round", "traffic GB", "final acc"
    );
    for &n in &[100usize, 200, 400] {
        let mut cfg = ExperimentConfig::preset("cifar");
        cfg.fleet = FleetKind::JetsonScaled(n);
        cfg.rounds = rounds;
        cfg.n_train = 8000;
        cfg.n_test = 1000;
        cfg.eval_every = rounds; // eval once at the end
        let cfg = cfg.apply_overrides(&args);
        let mut srv = Server::new(cfg, schemes::by_name("caesar").unwrap())?;
        let t0 = std::time::Instant::now();
        let r = srv.run()?;
        let host_ms = t0.elapsed().as_secs_f64() * 1000.0 / rounds as f64;
        let sim_s = r.total_time_s() / rounds as f64;
        println!(
            "{:>8}  {:>14.1}  {:>12.1}  {:>12.3}  {:>10.4}",
            n,
            host_ms,
            sim_s,
            r.total_traffic_gb(),
            r.final_metric(false)
        );
    }
    println!("\n(host = real orchestration cost on this machine; sim = Eq. 7 testbed clock)");
    Ok(())
}

//! Two-process localhost transport demo + parity check.
//!
//! Run with:
//!   cargo run --release --example transport_localhost
//!
//! The parent process first computes the in-process baseline
//! (`Server::run`) for a small fixed-seed HAR run, then re-executes
//! itself twice — once as the Tcp coordinator (`coordinator` role), once
//! as the device fleet (`devices <addr>` role, one thread + connection
//! per device) — and checks that the model digest printed by the
//! networked coordinator is **bit-identical** to the baseline. This is
//! the transport parity invariant demonstrated across real OS process
//! and socket boundaries; `tests/transport_parity.rs` pins the same
//! invariant in-process.

use std::io::{BufRead, BufReader};
use std::process::{Command, Stdio};
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::schemes;
use caesar_fl::transport::{
    model_digest, CoordinatorService, DeviceClient, SessionEnd, TcpConn, TcpTransport,
};

const N_DEVICES: usize = 6;

/// The one config every role must agree on.
fn demo_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("har");
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    cfg.fleet = caesar_fl::fleet::FleetKind::JetsonScaled(N_DEVICES);
    cfg.rounds = 2;
    cfg.alpha = 0.5; // 3 participants per round
    cfg.n_train = 600;
    cfg.n_test = 200;
    cfg.tau = 2;
    cfg.batch = 8;
    cfg.eval_every = 1;
    cfg.seed = 7;
    cfg
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let result = match args.get(1).map(String::as_str) {
        None => orchestrate(),
        Some("coordinator") => role_coordinator(),
        Some("devices") => role_devices(args.get(2).cloned()),
        Some(other) => Err(anyhow!("unknown role {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Child role: Tcp coordinator on an ephemeral port.
fn role_coordinator() -> Result<()> {
    let scheme = schemes::by_name("caesar").unwrap();
    let server = Server::new(demo_cfg(), scheme)?;
    let transport = TcpTransport::bind("127.0.0.1:0").map_err(|e| anyhow!("bind: {e}"))?;
    let mut svc = CoordinatorService::new(server, transport);
    println!("listening on {}", svc.local_addr());
    svc.wait_for_devices(N_DEVICES, Duration::from_secs(30))?;
    svc.run()?;
    println!("model digest {:016x}", model_digest(svc.server().model()));
    Ok(())
}

/// Child role: the whole device fleet, one thread + connection each.
fn role_devices(addr: Option<String>) -> Result<()> {
    let addr = addr.ok_or_else(|| anyhow!("devices role needs the coordinator address"))?;
    let mut handles = Vec::new();
    for d in 0..N_DEVICES {
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Result<()> {
            let mut client = DeviceClient::new(demo_cfg(), d)?;
            match client.run_reconnecting(|| TcpConn::connect(addr.as_str()), 5)? {
                SessionEnd::Finished => Ok(()),
                SessionEnd::Disconnected => Err(anyhow!("device {d} lost the coordinator")),
            }
        }));
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("device thread panicked"))??;
    }
    Ok(())
}

/// Parent: baseline run, then the two children, then the digest check.
fn orchestrate() -> Result<()> {
    println!("[1/3] in-process baseline...");
    let scheme = schemes::by_name("caesar").unwrap();
    let mut baseline = Server::new(demo_cfg(), scheme)?;
    baseline.run()?;
    let want = model_digest(baseline.model());
    println!("      baseline digest {want:016x}");

    println!("[2/3] spawning coordinator + {N_DEVICES} devices over Tcp...");
    let me = std::env::current_exe().context("resolving current_exe")?;
    let mut coord = Command::new(&me)
        .arg("coordinator")
        .stdout(Stdio::piped())
        .spawn()
        .context("spawning coordinator process")?;
    let mut lines = BufReader::new(coord.stdout.take().unwrap()).lines();

    // rendezvous: the coordinator prints its resolved ephemeral address
    let mut addr = None;
    let mut digest_line = None;
    for line in &mut lines {
        let line = line?;
        println!("      [coordinator] {line}");
        if let Some(a) = line.strip_prefix("listening on ") {
            addr = Some(a.trim().to_string());
            break;
        }
    }
    let addr = addr.ok_or_else(|| anyhow!("coordinator never printed its address"))?;

    let devices = Command::new(&me)
        .arg("devices")
        .arg(&addr)
        .spawn()
        .context("spawning device process")?;

    // drain the rest of the coordinator's output, catching the digest
    for line in &mut lines {
        let line = line?;
        println!("      [coordinator] {line}");
        if let Some(d) = line.strip_prefix("model digest ") {
            digest_line = Some(d.trim().to_string());
        }
    }
    let coord_status = coord.wait()?;
    let dev_status = devices.wait_with_output()?;
    if !coord_status.success() || !dev_status.status.success() {
        return Err(anyhow!("a child process failed"));
    }
    let got = u64::from_str_radix(
        digest_line.as_deref().ok_or_else(|| anyhow!("coordinator never printed a digest"))?,
        16,
    )?;

    println!("[3/3] digest over Tcp {got:016x}, in-process {want:016x}");
    if got != want {
        return Err(anyhow!("PARITY VIOLATION: Tcp run diverged from the in-process run"));
    }
    println!("parity holds: the transport moved bytes without touching the math");
    Ok(())
}

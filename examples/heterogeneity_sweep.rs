//! Data-heterogeneity sweep (the Fig. 8 scenario as a library example).
//!
//! Runs Caesar and the strongest baseline (PyramidFL) across
//! heterogeneity levels p ∈ {0, 1, 5, 10} on the HAR stand-in under a
//! fixed traffic budget and reports the accuracy each reaches — showing
//! Caesar's robustness to non-IID data.
//!
//! Run with:  cargo run --release --example heterogeneity_sweep

use caesar_fl::config::ExperimentConfig;
use caesar_fl::coordinator::Server;
use caesar_fl::schemes;
use caesar_fl::util::cli::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let budget_gb = args.get_f64("budget").unwrap_or(10.0);
    let levels = [0.0, 1.0, 5.0, 10.0];

    println!("{:>4}  {:>10}  {:>10}", "p", "caesar", "pyramidfl");
    for &p in &levels {
        let mut row = vec![];
        for scheme in ["caesar", "pyramidfl"] {
            let mut cfg = ExperimentConfig::preset("har");
            cfg.rounds = 60;
            cfg.n_train = 4000;
            cfg.n_test = 1000;
            cfg.het_p = p;
            cfg.eval_every = 2;
            let cfg = cfg.apply_overrides(&args);
            let mut srv = Server::new(cfg, schemes::by_name(scheme).unwrap())?;
            let r = srv.run()?;
            // accuracy at the traffic budget (Fig. 8's protocol)
            let mut acc = 0.0;
            for rec in &r.records {
                if rec.traffic_gb > budget_gb {
                    break;
                }
                if !rec.accuracy.is_nan() {
                    acc = rec.accuracy;
                }
            }
            row.push(acc);
        }
        println!("{:>4}  {:>10.4}  {:>10.4}", p, row[0], row[1]);
    }
    println!("\n(accuracy at a {budget_gb} GB traffic budget; higher is better)");
    Ok(())
}

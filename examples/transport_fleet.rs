//! Multi-process fleet-multiplexed transport demo + parity check.
//!
//! Run with:
//!   cargo run --release --example transport_fleet
//!
//! The fleet sibling of `transport_localhost`: the parent computes the
//! in-process baseline (`Server::run`) for a small fixed-seed HAR run,
//! then re-executes itself as a Tcp coordinator plus TWO fleet processes
//! — each carrying FOUR device sessions over a single connection
//! (`DeviceFleet`) — and checks that the networked model digest is
//! **bit-identical** to the baseline. Eight devices, two sockets: the
//! coordinator demux-routes every frame by the device id it names, so
//! how sessions pack onto connections is invisible to the math.

use std::io::{BufRead, BufReader};
use std::process::{Command, Stdio};
use std::time::Duration;

use anyhow::{anyhow, Context, Result};

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::schemes;
use caesar_fl::transport::{
    model_digest, CoordinatorService, DeviceFleet, SessionEnd, TcpConn, TcpTransport,
};

const N_DEVICES: usize = 8;
/// Device sessions carried per fleet process (one connection each).
const PER_FLEET: usize = 4;

/// The one config every role must agree on.
fn demo_cfg() -> ExperimentConfig {
    let mut cfg = ExperimentConfig::preset("har");
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    cfg.fleet = caesar_fl::fleet::FleetKind::JetsonScaled(N_DEVICES);
    cfg.rounds = 2;
    cfg.alpha = 0.5; // 4 participants per round
    cfg.n_train = 600;
    cfg.n_test = 200;
    cfg.tau = 2;
    cfg.batch = 8;
    cfg.eval_every = 1;
    cfg.seed = 11;
    cfg
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let result = match args.get(1).map(String::as_str) {
        None => orchestrate(),
        Some("coordinator") => role_coordinator(),
        Some("fleet") => role_fleet(args.get(2).cloned(), args.get(3).cloned()),
        Some(other) => Err(anyhow!("unknown role {other}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Child role: Tcp coordinator on an ephemeral port.
fn role_coordinator() -> Result<()> {
    let scheme = schemes::by_name("caesar").unwrap();
    let server = Server::new(demo_cfg(), scheme)?;
    let transport = TcpTransport::bind("127.0.0.1:0").map_err(|e| anyhow!("bind: {e}"))?;
    let mut svc = CoordinatorService::new(server, transport);
    println!("listening on {}", svc.local_addr());
    svc.wait_for_devices(N_DEVICES, Duration::from_secs(30))?;
    svc.run()?;
    println!("reactor wakeups {}", svc.wakeups());
    println!("model digest {:016x}", model_digest(svc.server().model()));
    Ok(())
}

/// Child role: one fleet of [`PER_FLEET`] devices over ONE connection.
fn role_fleet(addr: Option<String>, range: Option<String>) -> Result<()> {
    let addr = addr.ok_or_else(|| anyhow!("fleet role needs the coordinator address"))?;
    let range = range.ok_or_else(|| anyhow!("fleet role needs a device range a-b"))?;
    let (a, b) = range.split_once('-').ok_or_else(|| anyhow!("bad range {range}"))?;
    let (a, b): (usize, usize) = (a.parse()?, b.parse()?);
    let mut fleet = DeviceFleet::new(demo_cfg(), a..=b)?;
    match fleet.run_reconnecting(|| TcpConn::connect(addr.as_str()), 5)? {
        SessionEnd::Finished => Ok(()),
        SessionEnd::Disconnected => {
            Err(anyhow!("fleet {range} lost the coordinator"))
        }
    }
}

/// Parent: baseline run, then the three children, then the digest check.
fn orchestrate() -> Result<()> {
    println!("[1/3] in-process baseline...");
    let scheme = schemes::by_name("caesar").unwrap();
    let mut baseline = Server::new(demo_cfg(), scheme)?;
    baseline.run()?;
    let want = model_digest(baseline.model());
    println!("      baseline digest {want:016x}");

    let n_fleets = N_DEVICES / PER_FLEET;
    println!(
        "[2/3] spawning coordinator + {n_fleets} fleet processes \
         ({PER_FLEET} devices over one connection each)..."
    );
    let me = std::env::current_exe().context("resolving current_exe")?;
    let mut coord = Command::new(&me)
        .arg("coordinator")
        .stdout(Stdio::piped())
        .spawn()
        .context("spawning coordinator process")?;
    let mut lines = BufReader::new(coord.stdout.take().unwrap()).lines();

    // rendezvous: the coordinator prints its resolved ephemeral address
    let mut addr = None;
    let mut digest_line = None;
    for line in &mut lines {
        let line = line?;
        println!("      [coordinator] {line}");
        if let Some(a) = line.strip_prefix("listening on ") {
            addr = Some(a.trim().to_string());
            break;
        }
    }
    let addr = addr.ok_or_else(|| anyhow!("coordinator never printed its address"))?;

    let mut fleets = Vec::new();
    for f in 0..n_fleets {
        let (a, b) = (f * PER_FLEET, f * PER_FLEET + PER_FLEET - 1);
        fleets.push(
            Command::new(&me)
                .arg("fleet")
                .arg(&addr)
                .arg(format!("{a}-{b}"))
                .spawn()
                .with_context(|| format!("spawning fleet process {a}-{b}"))?,
        );
    }

    // drain the rest of the coordinator's output, catching the digest
    for line in &mut lines {
        let line = line?;
        println!("      [coordinator] {line}");
        if let Some(d) = line.strip_prefix("model digest ") {
            digest_line = Some(d.trim().to_string());
        }
    }
    let coord_status = coord.wait()?;
    let mut children_ok = true;
    for f in fleets {
        children_ok &= f.wait_with_output()?.status.success();
    }
    if !coord_status.success() || !children_ok {
        return Err(anyhow!("a child process failed"));
    }
    let got = u64::from_str_radix(
        digest_line.as_deref().ok_or_else(|| anyhow!("coordinator never printed a digest"))?,
        16,
    )?;

    println!("[3/3] digest over fleet-multiplexed Tcp {got:016x}, in-process {want:016x}");
    if got != want {
        return Err(anyhow!("PARITY VIOLATION: the fleet run diverged from the in-process run"));
    }
    println!("parity holds: 8 devices on 2 sockets, bit-identical to 0 sockets");
    Ok(())
}

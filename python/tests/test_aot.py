"""AOT pipeline tests: HLO text is emitted, well-formed, and complete."""

import json
import os
import tempfile

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def lowered_dir():
    with tempfile.TemporaryDirectory() as d:
        # One spec is enough to validate the pipeline quickly.
        aot.lower_all(d, specs=["har"], buckets=[4], quiet=True)
        yield d


def test_manifest_written(lowered_dir):
    with open(os.path.join(lowered_dir, "manifest.json")) as f:
        m = json.load(f)
    assert m["chunk"] == model.CHUNK
    mods = m["modules"]
    for name in (
        "train_har_b4",
        "eval_har",
        "gradnorm_har",
        "compress_har",
        "recover_har",
        "topk_har",
        "quantize_har",
    ):
        assert name in mods, name
        assert os.path.exists(os.path.join(lowered_dir, mods[name]["file"]))


def test_hlo_text_format(lowered_dir):
    """HLO text (not proto) — the format xla_extension 0.5.1 can re-parse."""
    path = os.path.join(lowered_dir, "train_har_b4.hlo.txt")
    text = open(path).read()
    assert text.startswith("HloModule"), text[:40]
    assert "ENTRY" in text
    # lowered with return_tuple=True: the root is a tuple
    assert "tuple(" in text or "(f32[" in text


def test_manifest_shapes_match_spec(lowered_dir):
    with open(os.path.join(lowered_dir, "manifest.json")) as f:
        m = json.load(f)
    spec = model.SPECS["har"]
    train = m["modules"]["train_har_b4"]
    assert train["inputs"][0]["shape"] == [spec.n_params]
    assert train["inputs"][1]["shape"] == [model.CHUNK, 4, spec.d_in]
    assert train["inputs"][1]["dtype"] == "f32"
    assert train["inputs"][2]["dtype"] == "i32"
    comp = m["modules"]["compress_har"]
    assert comp["outputs"][0]["shape"] == [spec.n_params]
    assert m["modules"]["_spec_har"]["n_params"] == spec.n_params


def test_compress_artifact_contains_no_custom_call(lowered_dir):
    """interpret=True must lower Pallas to plain HLO (no Mosaic custom-call
    — the CPU PJRT plugin cannot execute those)."""
    for name in ("compress_har", "recover_har", "topk_har", "quantize_har"):
        text = open(os.path.join(lowered_dir, f"{name}.hlo.txt")).read()
        assert "mosaic" not in text.lower(), name

"""Pallas kernels vs the pure-jnp oracle (the core L1 correctness signal).

Hypothesis sweeps shapes, ratios and value distributions; fixed cases pin
the edge semantics (ratio 0/1, zeros, ties, single element).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels import caesar_compress as cc
from compile.kernels import caesar_recover as cr
from compile.kernels import topk as tk
from compile.kernels import quantize as qz


def _vec(rng, n, scale=1.0):
    return (rng.standard_normal(n) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# caesar_compress
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    ratio=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
)
def test_compress_matches_ref(n, ratio, seed, scale):
    rng = np.random.default_rng(seed)
    w = _vec(rng, n, scale)
    outs_k = cc.caesar_compress(w, ratio)
    outs_r = ref.caesar_compress(w, ratio)
    for a, b in zip(outs_k, outs_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=0)


def test_compress_ratio_zero_keeps_everything():
    rng = np.random.default_rng(0)
    w = _vec(rng, 777)
    kept, mask, sign, avg, mx = cc.caesar_compress(w, 0.0)
    np.testing.assert_array_equal(np.asarray(kept), w)
    assert float(np.sum(np.asarray(mask))) == 0.0
    assert float(avg) == 0.0 and float(mx) == 0.0


def test_compress_ratio_one_quantizes_everything():
    rng = np.random.default_rng(1)
    w = _vec(rng, 512)
    kept, mask, sign, avg, mx = cc.caesar_compress(w, 1.0)
    assert float(np.sum(np.asarray(mask))) == 512.0
    np.testing.assert_array_equal(np.asarray(kept), np.zeros_like(w))
    np.testing.assert_allclose(float(avg), np.mean(np.abs(w)), rtol=1e-5)
    np.testing.assert_allclose(float(mx), np.max(np.abs(w)), rtol=1e-6)


def test_compress_quantized_fraction_matches_ratio():
    rng = np.random.default_rng(2)
    w = _vec(rng, 10000)
    for ratio in (0.1, 0.35, 0.6, 0.9):
        _, mask, _, _, _ = cc.caesar_compress(w, ratio)
        frac = float(np.sum(np.asarray(mask))) / w.size
        assert abs(frac - ratio) < 2e-3, (ratio, frac)


def test_compress_quantizes_smallest_magnitudes():
    rng = np.random.default_rng(3)
    w = _vec(rng, 4096)
    _, mask, _, _, _ = cc.caesar_compress(w, 0.5)
    mask = np.asarray(mask).astype(bool)
    assert np.max(np.abs(w[mask])) <= np.min(np.abs(w[~mask])) + 1e-12


def test_compress_all_zero_vector():
    w = np.zeros(100, dtype=np.float32)
    kept, mask, sign, avg, mx = cc.caesar_compress(w, 0.5)
    # every |w| equals the threshold (0) -> all quantized by the inclusive rule
    assert float(np.sum(np.asarray(mask))) == 100.0
    assert float(avg) == 0.0 and float(mx) == 0.0


# ---------------------------------------------------------------------------
# caesar_recover
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    ratio=st.floats(min_value=0.0, max_value=1.0),
    drift=st.sampled_from([0.0, 0.1, 1.0, 10.0]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_recover_matches_ref(n, ratio, drift, seed):
    rng = np.random.default_rng(seed)
    w = _vec(rng, n)
    local = (w + drift * rng.standard_normal(n)).astype(np.float32)
    k, m, s, a, mx = ref.caesar_compress(w, ratio)
    out_k = cr.caesar_recover(k, m, s, a, mx, local)
    out_r = ref.caesar_recover(k, m, s, a, mx, local)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), rtol=1e-6)


def test_recover_identical_local_is_near_lossless():
    """If the local model equals the global model, recovery is exact except
    sign-flips cannot occur and magnitudes are within max_abs: zero error."""
    rng = np.random.default_rng(4)
    w = _vec(rng, 2048)
    k, m, s, a, mx = ref.caesar_compress(w, 0.5)
    out = np.asarray(cr.caesar_recover(k, m, s, a, mx, w))
    np.testing.assert_allclose(out, w, rtol=1e-6)


def test_recover_sign_flip_falls_back_to_avg():
    w = np.array([0.5, -0.5, 2.0], dtype=np.float32)
    k, m, s, a, mx = ref.caesar_compress(w, 2.0 / 3.0)
    # local has wrong signs at the two quantized slots
    local = np.array([-0.4, 0.4, 2.0], dtype=np.float32)
    out = np.asarray(cr.caesar_recover(k, m, s, a, mx, local))
    assert out[0] == pytest.approx(float(a))   # +avg
    assert out[1] == pytest.approx(-float(a))  # -avg
    assert out[2] == pytest.approx(2.0)


def test_recover_magnitude_overflow_falls_back_to_avg():
    w = np.array([0.5, -0.5, 2.0], dtype=np.float32)
    k, m, s, a, mx = ref.caesar_compress(w, 2.0 / 3.0)
    local = np.array([0.9, -0.5, 2.0], dtype=np.float32)  # 0.9 > max_abs=0.5
    out = np.asarray(cr.caesar_recover(k, m, s, a, mx, local))
    assert out[0] == pytest.approx(float(a))
    assert out[1] == pytest.approx(-0.5)


def test_recover_reduces_error_vs_naive_signs():
    """The paper's claim in miniature: recovery via the stale local model
    beats reconstructing quantized slots as sign*avg alone when the local
    model is reasonably fresh."""
    rng = np.random.default_rng(5)
    w = _vec(rng, 8192)
    local = (w + 0.05 * rng.standard_normal(8192)).astype(np.float32)
    k, m, s, a, mx = ref.caesar_compress(w, 0.5)
    rec = np.asarray(cr.caesar_recover(k, m, s, a, mx, local))
    naive = np.asarray(k) + np.asarray(s) * float(a) * np.asarray(m)
    err_rec = np.mean((rec - w) ** 2)
    err_naive = np.mean((naive - w) ** 2)
    assert err_rec < err_naive


# ---------------------------------------------------------------------------
# topk_sparsify
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    ratio=st.floats(min_value=0.0, max_value=1.0),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_topk_matches_ref(n, ratio, seed):
    rng = np.random.default_rng(seed)
    g = _vec(rng, n)
    np.testing.assert_allclose(
        np.asarray(tk.topk_sparsify(g, ratio)),
        np.asarray(ref.topk_sparsify(g, ratio)),
        rtol=1e-6,
    )


def test_topk_keeps_largest():
    rng = np.random.default_rng(6)
    g = _vec(rng, 4096)
    out = np.asarray(tk.topk_sparsify(g, 0.75))
    kept = out != 0
    n_kept = int(kept.sum())
    assert abs(n_kept - 1024) <= 2
    assert np.min(np.abs(g[kept])) >= np.max(np.abs(g[~kept]))
    np.testing.assert_array_equal(out[kept], g[kept])


def test_topk_ratio_edges():
    rng = np.random.default_rng(7)
    g = _vec(rng, 100)
    np.testing.assert_array_equal(np.asarray(tk.topk_sparsify(g, 0.0)), g)
    out = np.asarray(tk.topk_sparsify(g, 1.0))
    np.testing.assert_array_equal(out, np.zeros_like(g))


# ---------------------------------------------------------------------------
# quantize_stochastic
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(min_value=1, max_value=5000),
    levels=st.sampled_from([1.0, 3.0, 15.0, 255.0]),
    seed=st.integers(min_value=0, max_value=2**31),
)
def test_quantize_matches_ref(n, levels, seed):
    rng = np.random.default_rng(seed)
    x = _vec(rng, n)
    u = rng.random(n).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(qz.quantize_stochastic(x, levels, u)),
        np.asarray(ref.quantize_stochastic(x, levels, u)),
        rtol=1e-5,
        atol=1e-7,
    )


def test_quantize_is_unbiased_in_expectation():
    rng = np.random.default_rng(8)
    x = _vec(rng, 256)
    acc = np.zeros_like(x, dtype=np.float64)
    trials = 400
    for _ in range(trials):
        u = rng.random(256).astype(np.float32)
        acc += np.asarray(qz.quantize_stochastic(x, 4.0, u))
    mean = (acc / trials).astype(np.float32)
    # per-element stderr ~ bucket/2/sqrt(trials) ~ 0.02; 5-sigma bound over
    # 256 elements, plus a mean-bias check an order tighter.
    np.testing.assert_allclose(mean, x, atol=0.12)
    assert abs(float(np.mean(mean - x))) < 0.01


def test_quantize_error_bounded_by_bucket():
    rng = np.random.default_rng(9)
    x = _vec(rng, 1024)
    u = rng.random(1024).astype(np.float32)
    levels = 15.0
    q = np.asarray(qz.quantize_stochastic(x, levels, u))
    bucket = np.max(np.abs(x)) / levels
    assert np.max(np.abs(q - x)) <= bucket + 1e-6


def test_quantize_zero_vector():
    x = np.zeros(64, dtype=np.float32)
    u = np.full(64, 0.999, dtype=np.float32)
    q = np.asarray(qz.quantize_stochastic(x, 7.0, u))
    np.testing.assert_array_equal(q, x)

"""Layer-2 model tests: shapes, gradients, SGD semantics, convergence."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model


@pytest.fixture(params=list(model.SPECS))
def spec(request):
    return model.SPECS[request.param]


def _init(spec, rng):
    # He-style init, matching rust/src/nn init (same scheme, different seed ok)
    flat = np.zeros(spec.n_params, dtype=np.float32)
    for ow, ob, (a, b) in spec.slices():
        flat[ow:ob] = rng.standard_normal(a * b).astype(np.float32) * np.sqrt(2.0 / a)
    return jnp.asarray(flat)


def test_n_params_matches_slices(spec):
    last = spec.slices()[-1]
    assert last[1] + last[2][1] == spec.n_params


def test_apply_shapes(spec):
    rng = np.random.default_rng(0)
    flat = _init(spec, rng)
    x = jnp.asarray(rng.standard_normal((7, spec.d_in)).astype(np.float32))
    logits = model.apply(spec, flat, x)
    assert logits.shape == (7, spec.n_classes)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_matches_manual_softmax(spec):
    rng = np.random.default_rng(1)
    flat = _init(spec, rng)
    x = jnp.asarray(rng.standard_normal((5, spec.d_in)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, spec.n_classes, 5).astype(np.int32))
    logits = np.asarray(model.apply(spec, flat, x), dtype=np.float64)
    z = logits - logits.max(axis=1, keepdims=True)
    p = np.exp(z) / np.exp(z).sum(axis=1, keepdims=True)
    manual = -np.mean(np.log(p[np.arange(5), np.asarray(y)]))
    got = float(model.loss_fn(spec, flat, x, y))
    assert got == pytest.approx(manual, rel=1e-4)


def test_grad_matches_finite_difference():
    spec = model.SPECS["har"]
    rng = np.random.default_rng(2)
    flat = _init(spec, rng)
    x = jnp.asarray(rng.standard_normal((4, spec.d_in)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, spec.n_classes, 4).astype(np.int32))
    g = np.asarray(jax.grad(lambda f: model.loss_fn(spec, f, x, y))(flat))
    eps = 1e-3
    idx = rng.integers(0, spec.n_params, 10)
    for i in idx:
        fp = np.asarray(flat).copy()
        fm = fp.copy()
        fp[i] += eps
        fm[i] -= eps
        fd = (
            float(model.loss_fn(spec, jnp.asarray(fp), x, y))
            - float(model.loss_fn(spec, jnp.asarray(fm), x, y))
        ) / (2 * eps)
        assert g[i] == pytest.approx(fd, rel=0.05, abs=1e-4)


def test_train_chunk_equals_manual_loop(spec):
    rng = np.random.default_rng(3)
    flat = _init(spec, rng)
    C, B = model.CHUNK, 8
    xs = jnp.asarray(rng.standard_normal((C, B, spec.d_in)).astype(np.float32))
    ys = jnp.asarray(rng.integers(0, spec.n_classes, (C, B)).astype(np.int32))
    lr = jnp.float32(0.05)
    train = model.make_train_chunk(spec)
    out, _loss = train(flat, xs, ys, lr)

    f = flat
    grad_fn = jax.grad(lambda fl, x, y: model.loss_fn(spec, fl, x, y))
    for j in range(C):
        f = f - lr * grad_fn(f, xs[j], ys[j])
    np.testing.assert_allclose(np.asarray(out), np.asarray(f), rtol=2e-4, atol=1e-6)


def test_train_chunk_decreases_loss_on_repeated_batch():
    spec = model.SPECS["cifar"]
    rng = np.random.default_rng(4)
    flat = _init(spec, rng)
    B = 32
    x = rng.standard_normal((B, spec.d_in)).astype(np.float32)
    y = rng.integers(0, spec.n_classes, B).astype(np.int32)
    xs = jnp.asarray(np.broadcast_to(x, (model.CHUNK, B, spec.d_in)).copy())
    ys = jnp.asarray(np.broadcast_to(y, (model.CHUNK, B)).copy())
    train = jax.jit(model.make_train_chunk(spec))
    l0 = float(model.loss_fn(spec, flat, jnp.asarray(x), jnp.asarray(y)))
    f = flat
    for _ in range(8):
        f, _ = train(f, xs, ys, jnp.float32(0.1))
    l1 = float(model.loss_fn(spec, f, jnp.asarray(x), jnp.asarray(y)))
    assert l1 < l0 * 0.5


def test_eval_chunk_shape(spec):
    rng = np.random.default_rng(5)
    flat = _init(spec, rng)
    xs = jnp.asarray(
        rng.standard_normal((model.EVAL_CHUNK, spec.d_in)).astype(np.float32)
    )
    logits = model.make_eval_chunk(spec)(flat, xs)
    assert logits.shape == (model.EVAL_CHUNK, spec.n_classes)


def test_gradnorm_positive():
    spec = model.SPECS["speech"]
    rng = np.random.default_rng(6)
    flat = _init(spec, rng)
    x = jnp.asarray(rng.standard_normal((32, spec.d_in)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, spec.n_classes, 32).astype(np.int32))
    gn = float(model.make_grad_norm(spec)(flat, x, y))
    assert gn > 0.0 and np.isfinite(gn)


def test_oppo_spec_is_pure_logistic_regression():
    spec = model.SPECS["oppo"]
    assert len(spec.slices()) == 1  # no hidden layer
    rng = np.random.default_rng(7)
    flat = _init(spec, rng)
    x = jnp.asarray(rng.standard_normal((3, spec.d_in)).astype(np.float32))
    logits = np.asarray(model.apply(spec, flat, x))
    w = np.asarray(flat[: spec.d_in * 2]).reshape(spec.d_in, 2)
    b = np.asarray(flat[spec.d_in * 2 :])
    np.testing.assert_allclose(logits, np.asarray(x) @ w + b, rtol=1e-5)

"""AOT lowering: JAX/Pallas entrypoints -> HLO text artifacts + manifest.

Run once at build time (``make artifacts``); rust loads the HLO text via
``HloModuleProto::from_text_file`` and executes on the PJRT CPU client.

HLO *text* — NOT ``lowered.compile().serialize()`` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts per model spec (cifar/har/speech/oppo):
  train_{spec}_b{B}.hlo.txt   (flat, xs[C,B,d], ys[C,B] i32, lr) -> (flat', loss)
  eval_{spec}.hlo.txt         (flat, xs[E,d]) -> logits[E,H]
  gradnorm_{spec}.hlo.txt     (flat, xs[B,d], ys[B]) -> ||g||
  compress_{spec}.hlo.txt     (w, ratio) -> (kept, mask, sign, avg, max)
  recover_{spec}.hlo.txt      (kept, mask, sign, avg, max, local) -> w_hat
  topk_{spec}.hlo.txt         (g, ratio) -> g_sparse
  quantize_{spec}.hlo.txt     (x, levels, noise) -> x_quant
plus artifacts/manifest.json describing every input/output tensor.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import caesar_compress, caesar_recover, topk, quantize


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _spec_entry(shape, dtype="f32"):
    return {"shape": list(shape), "dtype": dtype}


def lower_all(out_dir, specs=None, buckets=None, quiet=False):
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"chunk": model.CHUNK, "eval_chunk": model.EVAL_CHUNK, "modules": {}}
    specs = specs or list(model.SPECS)
    buckets = buckets or model.BATCH_BUCKETS

    def emit(name, fn, arg_specs, outputs):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["modules"][name] = {
            "file": fname,
            "inputs": [
                _spec_entry(s.shape, "i32" if s.dtype == jnp.int32 else "f32")
                for s in arg_specs
            ],
            "outputs": outputs,
        }
        if not quiet:
            print(f"  {fname:36s} {len(text)//1024:6d} KiB")

    for sname in specs:
        spec = model.SPECS[sname]
        P, d, H = spec.n_params, spec.d_in, spec.n_classes
        C, E = model.CHUNK, model.EVAL_CHUNK
        if not quiet:
            print(f"[{sname}] dims={spec.dims} P={P}")

        train = model.make_train_chunk(spec)
        for b in buckets:
            emit(
                f"train_{sname}_b{b}",
                train,
                (
                    _sds((P,)),
                    _sds((C, b, d)),
                    _sds((C, b), jnp.int32),
                    _sds(()),
                ),
                [_spec_entry((P,)), _spec_entry(())],
            )

        emit(
            f"eval_{sname}",
            model.make_eval_chunk(spec),
            (_sds((P,)), _sds((E, d))),
            [_spec_entry((E, H))],
        )

        emit(
            f"gradnorm_{sname}",
            model.make_grad_norm(spec),
            (_sds((P,)), _sds((32, d)), _sds((32,), jnp.int32)),
            [_spec_entry(())],
        )

        emit(
            f"compress_{sname}",
            lambda w, r: caesar_compress.caesar_compress(w, r, interpret=True),
            (_sds((P,)), _sds(())),
            [
                _spec_entry((P,)),
                _spec_entry((P,)),
                _spec_entry((P,)),
                _spec_entry(()),
                _spec_entry(()),
            ],
        )
        emit(
            f"recover_{sname}",
            lambda k, m, s, a, x, l: caesar_recover.caesar_recover(
                k, m, s, a, x, l, interpret=True
            ),
            (_sds((P,)), _sds((P,)), _sds((P,)), _sds(()), _sds(()), _sds((P,))),
            [_spec_entry((P,))],
        )
        emit(
            f"topk_{sname}",
            lambda g, r: topk.topk_sparsify(g, r, interpret=True),
            (_sds((P,)), _sds(())),
            [_spec_entry((P,))],
        )
        emit(
            f"quantize_{sname}",
            lambda x, lv, u: quantize.quantize_stochastic(x, lv, u, interpret=True),
            (_sds((P,)), _sds(()), _sds((P,))),
            [_spec_entry((P,))],
        )
        manifest["modules"][f"_spec_{sname}"] = {
            "dims": spec.dims,
            "n_params": P,
            "d_in": d,
            "n_classes": H,
        }

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1, sort_keys=True)
    if not quiet:
        print(f"wrote manifest with {len(manifest['modules'])} entries")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--specs", default=None, help="comma-separated subset")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()
    specs = args.specs.split(",") if args.specs else None
    lower_all(args.out, specs=specs, quiet=args.quiet)


if __name__ == "__main__":
    main()

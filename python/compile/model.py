"""Layer-2 JAX model: the FL local-training compute graph.

Defines a generic flat-parameter MLP softmax classifier (covers all four of
the paper's workload stand-ins — see DESIGN.md §Substitutions: MLP-C/H/S and
the LR-O logistic model, which is the zero-hidden-layer case), its loss and
SGD update, and the jitted entrypoints that are AOT-lowered by ``aot.py``
into the HLO artifacts the rust runtime executes:

* ``train_chunk`` — CHUNK mini-batch SGD iterations via ``lax.scan`` (the
  rust coordinator calls it ceil(tau/CHUNK) times per device round; shape
  bucketing over batch size handles the paper's Eq. 9 adaptive batches)
* ``eval_chunk``  — logits for a test chunk (accuracy/AUC reduced in rust)
* the Layer-1 kernel entrypoints (compress/recover/topk/quantize) so the
  Pallas kernels lower into standalone HLO modules for the rust-side
  ``--compression-backend xla`` path and the parity tests.

Parameters live in ONE flat f32 vector — the natural layout for the paper's
vector-level compression codecs and for single-buffer interchange with rust.
"""

import jax
import jax.numpy as jnp

# Number of SGD iterations fused into one artifact call (see DESIGN.md:
# tau is 10 or 30 in the paper; PyramidFL varies tau per device, so the
# artifact granularity is a divisor of both).
CHUNK = 5


class MlpSpec:
    """Static description of one model configuration."""

    def __init__(self, name, dims):
        # dims = [d_in, hidden..., n_classes]
        self.name = name
        self.dims = list(dims)

    @property
    def d_in(self):
        return self.dims[0]

    @property
    def n_classes(self):
        return self.dims[-1]

    @property
    def n_params(self):
        p = 0
        for a, b in zip(self.dims[:-1], self.dims[1:]):
            p += a * b + b
        return p

    def slices(self):
        """(offset_w, offset_b, shape) triples for each layer."""
        out, off = [], 0
        for a, b in zip(self.dims[:-1], self.dims[1:]):
            out.append((off, off + a * b, (a, b)))
            off += a * b + b
        return out


def apply(spec, flat, x):
    """Forward pass: x f32[B, d_in] -> logits f32[B, n_classes]."""
    h = x
    layers = spec.slices()
    for li, (ow, ob, shape) in enumerate(layers):
        w = flat[ow:ob].reshape(shape)
        b = flat[ob : ob + shape[1]]
        h = h @ w + b
        if li + 1 < len(layers):
            h = jax.nn.relu(h)
    return h


def loss_fn(spec, flat, x, y):
    """Mean softmax cross-entropy over the batch (y int32 labels)."""
    logits = apply(spec, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None].astype(jnp.int32), axis=-1)
    return jnp.mean(nll)


def make_train_chunk(spec):
    """CHUNK SGD steps: (flat, xs[C,B,d], ys[C,B], lr) -> (flat', mean_loss)."""

    grad_fn = jax.value_and_grad(lambda f, x, y: loss_fn(spec, f, x, y))

    def train_chunk(flat, xs, ys, lr):
        def step(carry, batch):
            f = carry
            x, y = batch
            l, g = grad_fn(f, x, y)
            return f - lr * g, l

        flat2, losses = jax.lax.scan(step, flat, (xs, ys))
        return flat2, jnp.mean(losses)

    return train_chunk


def make_eval_chunk(spec):
    """Logits for a fixed-size test chunk: (flat, xs[B,d]) -> logits[B,H]."""

    def eval_chunk(flat, xs):
        return apply(spec, flat, xs)

    return eval_chunk


def make_grad_norm(spec):
    """Per-round gradient-norm probe (used by the PyramidFL baseline)."""

    grad_fn = jax.grad(lambda f, x, y: loss_fn(spec, f, x, y))

    def grad_norm(flat, x, y):
        g = grad_fn(flat, x, y)
        return jnp.sqrt(jnp.sum(g * g))

    return grad_norm


# ---------------------------------------------------------------------------
# The four workload stand-ins (class counts match the paper's datasets;
# sizes are CPU-tractable — see DESIGN.md §Substitutions).
# ---------------------------------------------------------------------------

SPECS = {
    "cifar": MlpSpec("cifar", [64, 128, 10]),    # CIFAR-10 / ResNet-18 stand-in
    "har": MlpSpec("har", [36, 64, 6]),          # HAR / CNN-H stand-in
    "speech": MlpSpec("speech", [40, 96, 35]),   # Google-Speech / CNN-S stand-in
    "oppo": MlpSpec("oppo", [128, 2]),           # OPPO-TS / LR stand-in (no hidden)
}

# Batch-size buckets AOT-compiled per spec (Eq. 9 batches round down into
# these; the simulated-time model uses the exact b_i).
BATCH_BUCKETS = [4, 8, 16, 32]

# Test-set evaluation chunk size.
EVAL_CHUNK = 256

"""Layer-1 Pallas kernel: QSGD-style stochastic uniform quantization.

The codec of the ProWD baseline (bandwidth-chosen bit-width).  ``noise`` is
a uniform[0,1) vector supplied by the caller (the rust coordinator's
deterministic PRNG) so the kernel itself is a pure function.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024


def _quant_kernel(x_ref, noise_ref, params_ref, out_ref):
    x = x_ref[...]
    u = noise_ref[...]
    norm = params_ref[0]
    levels = params_ref[1]
    safe = jnp.maximum(norm, 1e-30)
    scaled = jnp.abs(x) / safe * levels
    q = jnp.minimum(jnp.floor(scaled + u), levels)
    sign = jnp.where(x >= 0.0, 1.0, -1.0)
    out = sign * q / levels * safe
    out_ref[...] = jnp.where(norm > 0.0, out, jnp.zeros_like(x))


@functools.partial(jax.jit, static_argnames=("interpret",))
def quantize_stochastic(x, levels, noise, interpret=True):
    """Mirror of ``ref.quantize_stochastic`` (norm reduce in XLA)."""
    x = jnp.asarray(x, jnp.float32)
    noise = jnp.asarray(noise, jnp.float32)
    n = x.shape[0]
    block = min(BLOCK, n) if n > 0 else 1
    pad = (-n) % block
    xp = jnp.pad(x, (0, pad))
    up = jnp.pad(noise, (0, pad))
    norm = jnp.max(jnp.abs(x))
    params = jnp.stack([norm, jnp.asarray(levels, jnp.float32)])
    grid = (xp.shape[0] // block,)
    out = pl.pallas_call(
        _quant_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(xp.shape, jnp.float32),
        interpret=interpret,
    )(xp, up, params)
    return out[:n]

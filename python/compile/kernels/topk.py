"""Layer-1 Pallas kernel: Top-K gradient sparsification (paper §4.2).

Drops the ``ratio`` fraction of smallest-|g| elements.  The keep-threshold
comes from one XLA sort in the wrapper; the masking pass is the Pallas
kernel (streaming select, memory-bound optimal).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

BLOCK = 8 * 1024


def _mask_kernel(g_ref, thr_ref, out_ref):
    g = g_ref[...]
    thr = thr_ref[0]
    keep = jnp.abs(g) >= thr
    out_ref[...] = jnp.where(keep, g, 0.0)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _apply_threshold(g, thr, interpret=True):
    n = g.shape[0]
    block = min(BLOCK, n) if n > 0 else 1
    pad = (-n) % block
    gp = jnp.pad(g, (0, pad))
    grid = (gp.shape[0] // block,)
    out = pl.pallas_call(
        _mask_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(gp.shape, jnp.float32),
        interpret=interpret,
    )(gp, jnp.reshape(thr, (1,)).astype(jnp.float32))
    return out[:n]


def topk_sparsify(g, ratio, interpret=True):
    """Mirror of ``ref.topk_sparsify`` with the mask pass in Pallas."""
    g = jnp.asarray(g, jnp.float32)
    thr, drop = ref.keep_threshold(g, ratio)
    out = _apply_threshold(g, thr, interpret=interpret)
    return jnp.where(drop >= g.shape[0], jnp.zeros_like(out), out)

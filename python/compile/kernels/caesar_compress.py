"""Layer-1 Pallas kernel: Caesar threshold-split model compression.

The hot loop of the paper's §4.1 codec: given the parameter vector and the
quantization threshold (computed once per call from the target ratio via an
XLA sort in the Layer-2 wrapper), stream the vector and produce

  kept  — fp32 payload (0 at quantized positions)
  mask  — 1.0 at quantized positions (the 1-bit plane on the wire)
  sign  — transmitted sign (+1/-1) at quantized positions, else 0

The avg-abs / max-abs scalars of the quantized set are reduced by XLA on the
kernel's mask output (two fused reductions over one already-resident array).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the vector is tiled into
VMEM-sized 1-D blocks; the body is pure VPU select/sign work, one HBM read
and three writes per element — memory-bound optimal.  On CPU we run under
``interpret=True`` (Mosaic custom-calls cannot execute on the CPU plugin).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# 1-D block: 8 * 1024 f32 = 32 KiB per input block in VMEM; with 4 resident
# arrays (in + 3 outs) and double-buffering this stays far below the 16 MiB
# VMEM budget while keeping the grid short.
BLOCK = 8 * 1024


def _compress_kernel(w_ref, thr_ref, kept_ref, mask_ref, sign_ref):
    w = w_ref[...]
    thr = thr_ref[0]
    absw = jnp.abs(w)
    quant = absw <= thr
    maskf = quant.astype(jnp.float32)
    kept_ref[...] = jnp.where(quant, 0.0, w)
    mask_ref[...] = maskf
    sign_ref[...] = jnp.where(w >= 0.0, 1.0, -1.0) * maskf


@functools.partial(jax.jit, static_argnames=("interpret",))
def compress_split(w, thr, interpret=True):
    """Apply the threshold split to ``w`` (1-D f32) with scalar ``thr``."""
    n = w.shape[0]
    block = min(BLOCK, n) if n > 0 else 1
    pad = (-n) % block
    wp = jnp.pad(w, (0, pad))
    grid = (wp.shape[0] // block,)
    thr_arr = jnp.reshape(thr, (1,)).astype(jnp.float32)
    out_shape = [jax.ShapeDtypeStruct(wp.shape, jnp.float32)] * 3
    kept, mask, sign = pl.pallas_call(
        _compress_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,))] * 3,
        out_shape=out_shape,
        interpret=interpret,
    )(wp, thr_arr)
    # Padding is zero; zero <= thr would mark pads quantized — slice them off
    # before any reduction sees them.
    return kept[:n], mask[:n], sign[:n]


def caesar_compress(w, ratio, interpret=True):
    """Full Caesar model compression: threshold + Pallas split + stats.

    Mirrors :func:`ref.caesar_compress`; the threshold and the two scalar
    reductions run in plain XLA (sort + fused reduce), the per-element split
    runs in the Pallas kernel.
    """
    w = jnp.asarray(w, jnp.float32)
    thr = ref.quant_threshold(w, ratio)
    kept, mask, sign = compress_split(w, thr, interpret=interpret)
    absw = jnp.abs(w)
    cnt = jnp.sum(mask)
    avg_abs = jnp.where(cnt > 0, jnp.sum(absw * mask) / jnp.maximum(cnt, 1.0), 0.0)
    max_abs = jnp.max(absw * mask)
    return kept, mask, sign, avg_abs, max_abs

"""Layer-1 Pallas kernel: Caesar device-side model recovery.

Implements the paper's Figure-3 recovery: quantized (1-bit) positions are
approximated by the stale local model; positions whose local value has the
wrong sign or an out-of-range magnitude fall back to ``sign * avg_abs``.

Pure element-wise select work — one streaming pass, VPU-only on TPU,
``interpret=True`` on CPU.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 8 * 1024


def _recover_kernel(kept_ref, mask_ref, sign_ref, stats_ref, local_ref, out_ref):
    kept = kept_ref[...]
    mask = mask_ref[...]
    sign = sign_ref[...]
    local = local_ref[...]
    avg_abs = stats_ref[0]
    max_abs = stats_ref[1]
    local_sign = jnp.where(local >= 0.0, 1.0, -1.0)
    bad = (local_sign != sign) | (jnp.abs(local) > max_abs)
    approx = jnp.where(bad, sign * avg_abs, local)
    out_ref[...] = kept * (1.0 - mask) + approx * mask


@functools.partial(jax.jit, static_argnames=("interpret",))
def caesar_recover(kept, mask, sign, avg_abs, max_abs, local, interpret=True):
    """Recover the full-precision model (mirrors ``ref.caesar_recover``)."""
    kept = jnp.asarray(kept, jnp.float32)
    local = jnp.asarray(local, jnp.float32)
    n = kept.shape[0]
    block = min(BLOCK, n) if n > 0 else 1
    pad = (-n) % block
    args = [jnp.pad(jnp.asarray(a, jnp.float32), (0, pad)) for a in (kept, mask, sign)]
    stats = jnp.stack(
        [jnp.asarray(avg_abs, jnp.float32), jnp.asarray(max_abs, jnp.float32)]
    )
    localp = jnp.pad(local, (0, pad))
    grid = (args[0].shape[0] // block,)
    out = pl.pallas_call(
        _recover_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((block,), lambda i: (i,)),
            pl.BlockSpec((2,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct(args[0].shape, jnp.float32),
        interpret=interpret,
    )(args[0], args[1], args[2], stats, localp)
    return out[:n]

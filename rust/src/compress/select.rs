//! O(n) threshold selection: MSB-first radix select over |x| sort keys.
//!
//! Both threshold selections in the codebase — `topk::keep_threshold`
//! (upload sparsification, §4.2) and `caesar_model::quant_threshold`
//! (download split, §4.1) — reduce to the same primitive: *the |x| value
//! at a given ascending rank*. This module is that primitive's single
//! owner; the callers only differ in how they map `ratio` to a rank and
//! in which side of the threshold they act on.
//!
//! **The tie contract, stated once.** [`select_threshold`] returns the
//! value a full ascending sort of the `abs_sort_keys` u32 keys would
//! place at index `rank` — exactly what `select_nth_unstable` returned
//! before (property-pinned below, including NaN payloads, ±0 and
//! subnormals, which the sign-mask key transform orders totally). Equal
//! |x| values have identical keys, so *which* of several tied elements
//! lands on the rank is unobservable: the threshold is a value, and the
//! inclusive/exclusive handling of elements AT the threshold belongs to
//! the callers (`topk_encode` keeps `|g| >= thr`; `caesar_compress`
//! quantizes `|w| <= thr`).
//!
//! **Why radix.** `select_nth_unstable` is expected O(n) but
//! partition-based: data-dependent branches, O(n) writes per recursion
//! level, and adversarial inputs degrade it. The selector here is a
//! counting select over 8-bit digits, most-significant first:
//!
//! ```text
//!   pass 1: histogram the top byte (256 counters on the stack),
//!           walk the counters to find the bucket holding rank k,
//!           compact that bucket's keys to the front of the buffer;
//!   pass 2..4: recurse on the next byte within the shrunken bucket.
//! ```
//!
//! Each pass is a branch-free sequential sweep (one shift/mask and one
//! counter bump per key), at most 4 passes total, and passes 2..4 run
//! over ever-smaller survivor sets — for gradient-like data the top
//! byte (sign-cleared exponent + leading mantissa bit) already splits
//! ~256 ways, so the expected work is ~1.1 sweeps of n. Two early
//! exits: a bucket holding exactly one candidate IS the answer (fetched
//! with one filtered scan, no further passes), and the final byte pass
//! needs no compaction at all. No allocation: the histogram lives on
//! the stack and compaction is in place in the caller's (pooled) key
//! buffer.
//!
//! **Streaming fusion.** [`select_threshold`] does not run the key
//! transform and pass 1 as two sweeps of n: [`abs_keys_hist24`] builds
//! the keys AND the top-byte histogram in ONE pass over the floats, so
//! each model-sized cache line is pulled exactly once before the select
//! recurses into its (much smaller) bucket. The fused path is
//! property-pinned bit-identical to `abs_sort_keys` + fresh histogram.

use crate::util::pool;

/// Fused |x|-key transform + top-byte histogram: the streaming first
/// pass of [`select_threshold`]. Writes exactly what
/// [`super::abs_sort_keys`] writes (same 8-wide chunking, same scalar
/// tail) while counting `key >> 24` occupancy in the same sweep, so the
/// selector's pass 1 never re-reads the key buffer.
fn abs_keys_hist24(src: &[f32], dst: &mut Vec<u32>) -> [usize; 256] {
    const SIGN_OFF: u32 = 0x7fff_ffff;
    let mut hist = [0usize; 256];
    dst.clear();
    dst.reserve(src.len());
    let mut chunks = src.chunks_exact(8);
    for c in chunks.by_ref() {
        let keys: [u32; 8] = std::array::from_fn(|j| c[j].to_bits() & SIGN_OFF);
        for &k in &keys {
            hist[(k >> 24) as usize] += 1;
        }
        dst.extend_from_slice(&keys);
    }
    for x in chunks.remainder() {
        let k = x.to_bits() & SIGN_OFF;
        hist[(k >> 24) as usize] += 1;
        dst.push(k);
    }
    hist
}

/// The key at ascending rank `idx` among `keys[..]`, as a full sort
/// would place it. O(n) counting select, MSB-first over 8-bit digits;
/// the prefix of `keys` is permuted (it is scratch, like
/// `select_nth_unstable`'s reordering). Panics if `idx >= keys.len()`.
pub fn radix_select_kth(keys: &mut [u32], idx: usize) -> u32 {
    let mut hist = [0usize; 256];
    for &k in keys.iter() {
        hist[(k >> 24) as usize] += 1;
    }
    radix_select_with_hist24(keys, idx, hist)
}

/// [`radix_select_kth`] with the top-byte histogram already counted by a
/// producer that streamed the keys into place ([`abs_keys_hist24`]).
/// `hist24[b]` must equal the number of keys whose top byte is `b` —
/// debug-asserted against a recount.
fn radix_select_with_hist24(keys: &mut [u32], idx: usize, hist24: [usize; 256]) -> u32 {
    assert!(idx < keys.len(), "rank {idx} out of range ({} keys)", keys.len());
    debug_assert_eq!(hist24.iter().sum::<usize>(), keys.len(), "histogram miscounts the keys");
    let mut len = keys.len();
    let mut rank = idx;
    let mut prefix: u32 = 0;
    for shift in [24u32, 16, 8, 0] {
        let hist = if shift == 24 {
            hist24
        } else {
            let mut h = [0usize; 256];
            for &k in &keys[..len] {
                h[((k >> shift) & 0xff) as usize] += 1;
            }
            h
        };
        // find the digit bucket containing the rank
        let mut digit = 0usize;
        let mut below = 0usize;
        loop {
            let c = hist[digit];
            if below + c > rank {
                break;
            }
            below += c;
            digit += 1;
        }
        rank -= below;
        let digit = digit as u32;
        prefix |= digit << shift;
        if shift == 0 {
            // all 32 bits resolved: the key is the digit path itself
            return prefix;
        }
        if hist[digit as usize] == 1 {
            // the bucket holds exactly one candidate — it IS the rank-th
            // key; fetch it and skip the remaining passes
            return keys[..len]
                .iter()
                .copied()
                .find(|k| (k >> shift) & 0xff == digit)
                .expect("histogram counted a key the scan cannot find");
        }
        // compact the surviving bucket to the front, preserving order
        // (order within the bucket is irrelevant to the result; the
        // stable sweep just keeps the pass branch-predictable)
        let mut w = 0usize;
        for r in 0..len {
            let k = keys[r];
            if (k >> shift) & 0xff == digit {
                keys[w] = k;
                w += 1;
            }
        }
        len = w;
        debug_assert!(rank < len, "rank escaped its bucket");
    }
    unreachable!("the shift-0 pass always returns")
}

/// The |·| threshold at ascending rank `rank` of `g` — the single entry
/// point behind `topk::keep_threshold` and
/// `caesar_model::quant_threshold`. Streams the floats ONCE through the
/// fused [`abs_keys_hist24`] pass (8-wide branch-free key transform into
/// pooled per-thread scratch + the selector's first histogram, zero
/// model-sized allocation on the warm path) and radix selects in place.
/// Panics if `rank >= g.len()`; callers own their `ratio → rank`
/// clamping.
pub fn select_threshold(g: &[f32], rank: usize) -> f32 {
    let mut keys = pool::u32_buf();
    let hist24 = abs_keys_hist24(g, &mut keys);
    f32::from_bits(radix_select_with_hist24(&mut keys, rank, hist24))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen_vec_f32, Config};
    use crate::util::rng::Rng;

    /// The reference the radix path must match bit-for-bit.
    fn sort_select(keys: &[u32], idx: usize) -> u32 {
        let mut v = keys.to_vec();
        let (_, &mut k, _) = v.select_nth_unstable(idx);
        k
    }

    fn check_all_ranks(keys: &[u32]) {
        for idx in 0..keys.len() {
            let mut scratch = keys.to_vec();
            assert_eq!(
                radix_select_kth(&mut scratch, idx),
                sort_select(keys, idx),
                "rank {idx} of {keys:?}"
            );
        }
    }

    #[test]
    fn small_tails_every_rank() {
        // n < 8 exercises sub-chunk sizes end to end
        check_all_ranks(&[7]);
        check_all_ranks(&[3, 3]);
        check_all_ranks(&[5, 1, 4, 1, 5, 9, 2]);
        check_all_ranks(&[u32::MAX, 0, u32::MAX, 0, 1]);
    }

    #[test]
    fn all_equal_keys() {
        check_all_ranks(&[0x3f80_0000; 17]);
        check_all_ranks(&[0; 9]);
    }

    #[test]
    fn duplicates_straddling_the_rank() {
        // runs of duplicates positioned so the k-th element sits inside,
        // at the start of, and at the end of a tie run
        let mut keys = Vec::new();
        for v in [10u32, 10, 10, 20, 20, 20, 20, 30, 30] {
            keys.push(v << 20); // ties decided in the FIRST digit pass
            keys.push(v); // ties that survive to the LAST digit pass
        }
        check_all_ranks(&keys);
    }

    #[test]
    fn extreme_ranks_and_early_exit_buckets() {
        let mut rng = Rng::new(0x5E1E);
        // spread keys across distinct top bytes so hist[digit] == 1
        // triggers the unique-candidate early exit, plus a dense cluster
        // that forces full 4-pass resolution
        let mut keys: Vec<u32> = (0..64).map(|i| (i as u32) << 24 | rng.below(4096) as u32).collect();
        keys.extend([0x00AB_CD00u32; 40]);
        keys.push(0x00AB_CD01);
        check_all_ranks(&keys);
        // k = 0 and k = n-1 explicitly
        let mut s = keys.clone();
        assert_eq!(radix_select_kth(&mut s, 0), *keys.iter().min().unwrap());
        let mut s = keys.clone();
        assert_eq!(radix_select_kth(&mut s, keys.len() - 1), *keys.iter().max().unwrap());
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn rank_n_is_rejected() {
        radix_select_kth(&mut [1, 2, 3], 3);
    }

    #[test]
    fn adversarial_floats_through_the_key_transform() {
        // NaN (largest keys), infinities, ±0 (equal keys), subnormals —
        // the sign-mask transform totally orders all of them, and radix
        // must agree with sort-select on every rank
        let g = [
            0.0f32,
            -0.0,
            f32::NAN,
            -f32::NAN,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            1.0e-40, // subnormal
            -1.0e-44,
            1.5,
            -1.5,
            f32::MAX,
        ];
        let mut keys = Vec::new();
        super::super::abs_sort_keys(&g, &mut keys);
        check_all_ranks(&keys);
        // and the f32-facing entry agrees bit-for-bit
        for rank in 0..g.len() {
            let thr = select_threshold(&g, rank);
            assert_eq!(thr.to_bits(), sort_select(&keys, rank), "rank {rank}");
        }
    }

    #[test]
    fn prop_fused_first_pass_matches_transform_plus_recount() {
        forall(
            Config { cases: 64, seed: 0xF0_5ED },
            |rng, size| {
                // sizes straddling the 8-wide chunk boundary, with NaN /
                // ±0 / subnormal salting via the generator's full range
                let bound = (size * 3 + rng.below(9)).max(1);
                gen_vec_f32(rng, bound, 1.0)
            },
            |g| {
                let mut fused = Vec::new();
                let hist = abs_keys_hist24(g, &mut fused);
                let mut plain = Vec::new();
                super::super::abs_sort_keys(g, &mut plain);
                if fused != plain {
                    return Err(format!("fused keys diverged at n={}", g.len()));
                }
                let mut recount = [0usize; 256];
                for &k in &plain {
                    recount[(k >> 24) as usize] += 1;
                }
                if hist != recount {
                    return Err(format!("fused histogram diverged at n={}", g.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_radix_matches_select_nth_unstable() {
        forall(
            Config { cases: 96, seed: 0x5E1EC7 },
            |rng, size| {
                // sizes straddling the 8-wide key-transform chunks; a mix
                // of smooth gradients and quantized (tie-heavy) values
                // gen_vec_f32 picks a length in 1..=bound, so sizes
                // straddle the 8-wide key-transform chunks on their own
                let bound = (size * 3 + rng.below(9)).max(1);
                let mut g = gen_vec_f32(rng, bound, 1.0);
                if rng.below(2) == 0 {
                    for x in &mut g {
                        *x = (*x * 4.0).round() / 4.0; // heavy ties
                    }
                }
                let rank = rng.below(g.len());
                (g, rank)
            },
            |(g, rank)| {
                let mut keys = Vec::new();
                super::super::abs_sort_keys(g, &mut keys);
                let want = sort_select(&keys, *rank);
                let got = radix_select_kth(&mut keys.clone(), *rank);
                if got != want {
                    return Err(format!(
                        "rank {} of n={}: radix {got:#010x} != sort {want:#010x}",
                        rank,
                        g.len()
                    ));
                }
                if select_threshold(g, *rank).to_bits() != want {
                    return Err("select_threshold disagrees with raw radix".into());
                }
                Ok(())
            },
        );
    }
}

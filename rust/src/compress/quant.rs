//! QSGD-style stochastic uniform quantization — the codec behind the ProWD
//! baseline (bandwidth-chosen bit-width). Mirrors the L1 `quantize` kernel:
//! q(x) = sign(x) · ⌊|x|/norm·s + u⌋/s · norm with norm = max|x|.

/// Quantize `x` to `levels` buckets using the caller-supplied uniform[0,1)
/// `noise` (same-length). Deterministic given its inputs.
pub fn quantize_stochastic(x: &[f32], levels: u32, noise: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), noise.len());
    assert!(levels >= 1);
    let norm = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    if norm == 0.0 {
        return vec![0.0; x.len()];
    }
    let s = levels as f32;
    x.iter()
        .zip(noise)
        .map(|(&xi, &u)| {
            let scaled = xi.abs() / norm * s;
            let q = (scaled + u).floor().min(s);
            let sign = if xi >= 0.0 { 1.0 } else { -1.0 };
            sign * q / s * norm
        })
        .collect()
}

/// Map a bandwidth fraction (0 = worst, 1 = best observed) to a
/// quantization bit-width in [min_bits, max_bits] (ProWD's policy shape:
/// weaker links use fewer bits).
pub fn bits_for_bandwidth(frac: f64, min_bits: u32, max_bits: u32) -> u32 {
    let f = frac.clamp(0.0, 1.0);
    min_bits + ((max_bits - min_bits) as f64 * f).round() as u32
}

/// Levels for a given bit-width: with 1 sign bit + b value bits,
/// s = 2^b − 1 buckets.
pub fn levels_for_bits(bits: u32) -> u32 {
    (1u32 << bits.clamp(1, 16)) - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn unif(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.f32()).collect()
    }

    #[test]
    fn error_bounded_by_bucket() {
        let x = randn(2048, 0);
        let u = unif(2048, 1);
        let levels = 15;
        let q = quantize_stochastic(&x, levels, &u);
        let norm = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let bucket = norm / levels as f32;
        for (a, b) in x.iter().zip(&q) {
            assert!((a - b).abs() <= bucket + 1e-6);
        }
    }

    #[test]
    fn unbiased_in_expectation() {
        let x = randn(128, 2);
        let mut rng = Rng::new(3);
        let trials = 2000;
        let mut acc = vec![0.0f64; 128];
        for _ in 0..trials {
            let u: Vec<f32> = (0..128).map(|_| rng.f32()).collect();
            for (a, q) in acc.iter_mut().zip(quantize_stochastic(&x, 4, &u)) {
                *a += q as f64;
            }
        }
        let bias: f64 = acc
            .iter()
            .zip(&x)
            .map(|(a, &xi)| (a / trials as f64 - xi as f64).abs())
            .sum::<f64>()
            / 128.0;
        assert!(bias < 0.02, "bias={bias}");
    }

    #[test]
    fn more_levels_less_error() {
        let x = randn(4096, 4);
        let u = unif(4096, 5);
        let err = |levels: u32| -> f64 {
            quantize_stochastic(&x, levels, &u)
                .iter()
                .zip(&x)
                .map(|(q, &xi)| ((q - xi) as f64).powi(2))
                .sum()
        };
        assert!(err(255) < err(15));
        assert!(err(15) < err(3));
    }

    #[test]
    fn zero_vector() {
        let x = vec![0.0f32; 10];
        let u = unif(10, 6);
        assert_eq!(quantize_stochastic(&x, 7, &u), x);
    }

    #[test]
    fn preserves_signs() {
        let x = randn(1024, 7);
        let u = unif(1024, 8);
        for (q, &xi) in quantize_stochastic(&x, 15, &u).iter().zip(&x) {
            if *q != 0.0 {
                assert_eq!(q.signum(), xi.signum());
            }
        }
    }

    #[test]
    fn bandwidth_policy_monotone() {
        let lo = bits_for_bandwidth(0.0, 2, 8);
        let mid = bits_for_bandwidth(0.5, 2, 8);
        let hi = bits_for_bandwidth(1.0, 2, 8);
        assert_eq!(lo, 2);
        assert_eq!(hi, 8);
        assert!(lo <= mid && mid <= hi);
        assert_eq!(levels_for_bits(4), 15);
        assert_eq!(levels_for_bits(1), 1);
    }
}

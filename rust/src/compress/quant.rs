//! QSGD-style stochastic uniform quantization — the codec behind the ProWD
//! baseline (bandwidth-chosen bit-width). Mirrors the L1 `quantize` kernel:
//! q(x) = sign(x) · ⌊|x|/norm·s + u⌋/s · norm with norm = max|x|.
//!
//! The wire-facing form is integer *codes* (`quantize_codes` /
//! `dequantize_code`): one sign bit plus a bucket index per element, which
//! is exactly what `wire::Payload::Quant` serializes. The dense helpers
//! below are thin reconstructions over the codes and stay bit-identical to
//! the historical element-wise formula.
//!
//! **RNG contract** (the codec layer depends on this for reproducibility):
//! a quantize call consumes exactly `x.len()` uniform draws from the
//! device stream iff [`noise_needed`] holds — i.e. the input has a nonzero
//! norm AND the bucket count is below [`DETERMINISTIC_LEVELS`]. In every
//! other case (zero vector, or `bits >= 23`-style wide quantizers whose
//! buckets are finer than f32 resolution) the stream is left untouched and
//! the deterministic floor path is used.

/// Bucket count at/above which stochastic rounding is dropped: from
/// `levels_for_bits(23)` = 2^23−1 buckets up, the quantization step is at
/// or below the f32 mantissa resolution of the scaled input, so the codec
/// uses the deterministic floor and skips the per-element draws entirely
/// (the `bits >= 23` wide-width case).
pub const DETERMINISTIC_LEVELS: u32 = (1 << 23) - 1;

/// Whether the stochastic path (and therefore `x.len()` RNG draws) is
/// actually needed. See the module-level RNG contract.
pub fn noise_needed(norm: f32, levels: u32) -> bool {
    norm != 0.0 && levels < DETERMINISTIC_LEVELS
}

/// Levels for a given bit-width: with 1 sign bit + b value bits,
/// s = 2^b − 1 buckets. Capped at 24 value bits: every `2^b − 1` up to
/// `2^24 − 1` is exactly representable in f32 (no `.min(s)` rounding trap
/// past the mantissa), and finer buckets are below f32 resolution anyway —
/// widths ≥ 23 already take the deterministic path ([`noise_needed`]).
pub fn levels_for_bits(bits: u32) -> u32 {
    (1u32 << bits.clamp(1, 24)) - 1
}

/// Quantize to integer wire codes: returns `(norm, codes)` with
/// `code = (q << 1) | negative` and bucket `q ∈ [0, levels]`.
/// `noise = None` selects the deterministic floor path (u = 0).
pub fn quantize_codes(x: &[f32], levels: u32, noise: Option<&[f32]>) -> (f32, Vec<u32>) {
    if let Some(u) = noise {
        assert_eq!(x.len(), u.len());
    }
    assert!(levels >= 1);
    let norm = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    (norm, codes_for(x, levels, noise, norm))
}

/// The code map given a precomputed `norm` (single max-norm pass for
/// callers that already needed it for the RNG gate).
fn codes_for(x: &[f32], levels: u32, noise: Option<&[f32]>, norm: f32) -> Vec<u32> {
    let s = levels as f32;
    x.iter()
        .enumerate()
        .map(|(i, &xi)| {
            // sign(0) = +1, matching the historical `xi >= 0.0` test
            let neg = if xi >= 0.0 { 0u32 } else { 1 };
            let q = if norm == 0.0 {
                0
            } else {
                let u = noise.map_or(0.0, |u| u[i]);
                let scaled = xi.abs() / norm * s;
                (scaled + u).floor().min(s) as u32
            };
            (q << 1) | neg
        })
        .collect()
}

/// Build the `Quant` wire payload for `x` — the ONE place that owns the
/// RNG gate ([`noise_needed`]), the single max-norm pass, and the payload
/// assembly, shared by the native and XLA codec paths. The drawn noise is
/// returned alongside (the XLA kernel consumes it as an input literal);
/// `None` means the deterministic path ran and no draws were consumed.
/// The noise lives in pooled per-thread scratch
/// ([`crate::util::pool::F32Buf`]) — dropping it recycles the n-word
/// buffer instead of freeing it, so the native hot path's only surviving
/// allocation is the codes vector that becomes the payload.
pub fn quant_payload(
    x: &[f32],
    bits: u32,
    rng: &mut crate::util::rng::Rng,
) -> (crate::wire::Payload, Option<crate::util::pool::F32Buf>) {
    let levels = levels_for_bits(bits);
    let norm = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
    let noise: Option<crate::util::pool::F32Buf> = if noise_needed(norm, levels) {
        let mut buf = crate::util::pool::f32_buf();
        buf.extend((0..x.len()).map(|_| rng.f32()));
        Some(buf)
    } else {
        None
    };
    let codes = codes_for(x, levels, noise.as_ref().map(|b| &b[..]), norm);
    (crate::wire::Payload::Quant { bits: bits.max(1), levels, norm, codes }, noise)
}

/// Reconstruct the f32 value of one wire code — bit-identical to what the
/// dense quantizers produce for the same element (same expression, same
/// operation order).
#[inline]
pub fn dequantize_code(code: u32, levels: u32, norm: f32) -> f32 {
    if norm == 0.0 {
        return 0.0;
    }
    let sign = if code & 1 == 0 { 1.0f32 } else { -1.0 };
    let q = (code >> 1) as f32;
    sign * q / levels as f32 * norm
}

/// Quantize `x` to `levels` buckets using the caller-supplied uniform[0,1)
/// `noise` (same-length). Deterministic given its inputs.
pub fn quantize_stochastic(x: &[f32], levels: u32, noise: &[f32]) -> Vec<f32> {
    let (norm, codes) = quantize_codes(x, levels, Some(noise));
    codes.iter().map(|&c| dequantize_code(c, levels, norm)).collect()
}

/// Deterministic (u = 0) quantization — the wide-width / zero-norm path
/// where the stochastic draws are skipped (see [`noise_needed`]).
pub fn quantize_floor(x: &[f32], levels: u32) -> Vec<f32> {
    let (norm, codes) = quantize_codes(x, levels, None);
    codes.iter().map(|&c| dequantize_code(c, levels, norm)).collect()
}

/// Map a bandwidth fraction (0 = worst, 1 = best observed) to a
/// quantization bit-width in [min_bits, max_bits] (ProWD's policy shape:
/// weaker links use fewer bits).
pub fn bits_for_bandwidth(frac: f64, min_bits: u32, max_bits: u32) -> u32 {
    let f = frac.clamp(0.0, 1.0);
    min_bits + ((max_bits - min_bits) as f64 * f).round() as u32
}


#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn unif(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.f32()).collect()
    }

    #[test]
    fn error_bounded_by_bucket() {
        let x = randn(2048, 0);
        let u = unif(2048, 1);
        let levels = 15;
        let q = quantize_stochastic(&x, levels, &u);
        let norm = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        let bucket = norm / levels as f32;
        for (a, b) in x.iter().zip(&q) {
            assert!((a - b).abs() <= bucket + 1e-6);
        }
    }

    #[test]
    fn unbiased_in_expectation() {
        let x = randn(128, 2);
        let mut rng = Rng::new(3);
        let trials = 2000;
        let mut acc = vec![0.0f64; 128];
        for _ in 0..trials {
            let u: Vec<f32> = (0..128).map(|_| rng.f32()).collect();
            for (a, q) in acc.iter_mut().zip(quantize_stochastic(&x, 4, &u)) {
                *a += q as f64;
            }
        }
        let bias: f64 = acc
            .iter()
            .zip(&x)
            .map(|(a, &xi)| (a / trials as f64 - xi as f64).abs())
            .sum::<f64>()
            / 128.0;
        assert!(bias < 0.02, "bias={bias}");
    }

    #[test]
    fn more_levels_less_error() {
        let x = randn(4096, 4);
        let u = unif(4096, 5);
        let err = |levels: u32| -> f64 {
            quantize_stochastic(&x, levels, &u)
                .iter()
                .zip(&x)
                .map(|(q, &xi)| ((q - xi) as f64).powi(2))
                .sum()
        };
        assert!(err(255) < err(15));
        assert!(err(15) < err(3));
    }

    #[test]
    fn zero_vector() {
        let x = vec![0.0f32; 10];
        let u = unif(10, 6);
        assert_eq!(quantize_stochastic(&x, 7, &u), x);
    }

    #[test]
    fn preserves_signs() {
        let x = randn(1024, 7);
        let u = unif(1024, 8);
        for (q, &xi) in quantize_stochastic(&x, 15, &u).iter().zip(&x) {
            if *q != 0.0 {
                assert_eq!(q.signum(), xi.signum());
            }
        }
    }

    #[test]
    fn codes_reconstruct_bit_identically() {
        let x = randn(4096, 10);
        let u = unif(4096, 11);
        for levels in [1u32, 3, 15, 255, 65_535] {
            let dense = quantize_stochastic(&x, levels, &u);
            let (norm, codes) = quantize_codes(&x, levels, Some(&u));
            for (i, &c) in codes.iter().enumerate() {
                let v = dequantize_code(c, levels, norm);
                assert_eq!(v.to_bits(), dense[i].to_bits(), "levels={levels} elem {i}");
            }
        }
    }

    #[test]
    fn floor_path_is_deterministic_and_bounded() {
        let x = randn(1024, 12);
        let q = quantize_floor(&x, 15);
        assert_eq!(q, quantize_floor(&x, 15));
        let norm = x.iter().fold(0.0f32, |a, &b| a.max(b.abs()));
        for (a, b) in x.iter().zip(&q) {
            // floor always rounds toward zero: |q| <= |x|, within a bucket
            assert!(b.abs() <= a.abs() + 1e-6);
            assert!((a - b).abs() <= norm / 15.0 + 1e-6);
        }
    }

    #[test]
    fn noise_gate_matches_contract() {
        assert!(noise_needed(1.0, 15));
        assert!(!noise_needed(0.0, 15), "zero norm never draws");
        assert!(!noise_needed(1.0, DETERMINISTIC_LEVELS), "wide widths never draw");
        assert!(noise_needed(1.0, DETERMINISTIC_LEVELS - 1));
        // the wide-width arm is REACHABLE: bits >= 23 maps to levels at or
        // above the threshold (levels_for_bits caps at 24 value bits)
        assert!(!noise_needed(1.0, levels_for_bits(23)));
        assert!(!noise_needed(1.0, levels_for_bits(28)));
        assert!(noise_needed(1.0, levels_for_bits(22)));
        assert_eq!(levels_for_bits(28), (1 << 24) - 1);
    }

    #[test]
    fn wide_width_payload_consumes_no_rng() {
        let x = randn(64, 20);
        let mut rng = Rng::new(21);
        let before = rng.clone();
        let (payload, noise) = quant_payload(&x, 23, &mut rng);
        assert!(noise.is_none(), "bits=23 must take the deterministic path");
        let mut b = before;
        assert_eq!(rng.next_u64(), b.next_u64(), "rng advanced on wide-width quantize");
        // and the payload is the deterministic floor reconstruction
        if let crate::wire::Payload::Quant { levels, norm, codes, .. } = payload {
            let want = quantize_floor(&x, levels);
            for (i, &c) in codes.iter().enumerate() {
                assert_eq!(dequantize_code(c, levels, norm).to_bits(), want[i].to_bits());
            }
        } else {
            panic!("expected Quant payload");
        }
    }

    #[test]
    fn bandwidth_policy_monotone() {
        let lo = bits_for_bandwidth(0.0, 2, 8);
        let mid = bits_for_bandwidth(0.5, 2, 8);
        let hi = bits_for_bandwidth(1.0, 2, 8);
        assert_eq!(lo, 2);
        assert_eq!(hi, 8);
        assert!(lo <= mid && mid <= hi);
        assert_eq!(levels_for_bits(4), 15);
        assert_eq!(levels_for_bits(1), 1);
    }
}

//! Top-K gradient sparsification (paper §4.2's upload codec; also the
//! codec behind the FIC/CAC preliminary schemes and the FlexCom baseline).
//!
//! `ratio` is the *dropped* fraction: k = n − floor(ratio·n) largest-|g|
//! elements survive. Inclusive-tie semantics match the L1 kernel.
//!
//! The wire-facing form is [`topk_encode`], which produces a
//! `wire::Payload::TopK` (indices + values) in one pass; [`topk_sparsify`]
//! is its densified view kept for the kernel-parity pins and callers that
//! want an aggregation-ready dense vector.

use crate::wire::Payload;

/// Sparse result of a Top-K pass.
#[derive(Clone, Debug, PartialEq)]
pub struct SparseGrad {
    /// Dense vector with dropped entries zeroed (aggregation-ready).
    pub dense: Vec<f32>,
    /// Number of surviving (non-zero-masked) entries.
    pub kept: usize,
}

impl SparseGrad {
    /// Exact wire size in bits (values + positions; see traffic.rs).
    pub fn wire_bits(&self) -> usize {
        super::traffic::topk_grad_bits(self.dense.len(), self.kept)
    }
}

/// The |g| threshold at-or-above which elements are kept.
/// Returns (threshold, drop_count).
///
/// Delegates the rank lookup to [`super::select_threshold`] — the O(n)
/// radix select that owns the tie contract — at ascending rank
/// `drop.min(n - 1)`: the smallest surviving |g| when `drop < n`, the
/// global max when everything drops (then nothing can exceed it anyway,
/// and `topk_encode` short-circuits on `drop >= n`).
pub fn keep_threshold(g: &[f32], ratio: f64) -> (f32, usize) {
    let n = g.len();
    let drop = (ratio * n as f64).floor() as usize;
    if n == 0 {
        return (0.0, 0);
    }
    (super::select_threshold(g, drop.min(n - 1)), drop)
}

/// One-pass Top-K encode: runs the threshold selection once and emits the
/// sparse wire payload (ascending indices + kept values). The realized
/// threshold is returned alongside so callers never re-run the selection
/// (`CodecEngine::download` used to sort the tensor twice).
pub fn topk_encode(g: &[f32], ratio: f64) -> (Payload, f32) {
    let n = g.len();
    let (thr, drop) = keep_threshold(g, ratio);
    if drop >= n {
        return (Payload::TopK { n, indices: Vec::new(), values: Vec::new() }, thr);
    }
    // the kept count is at least n - drop (inclusive ties add more);
    // pre-sizing to it avoids the doubling-regrowth churn of Vec::new
    let mut indices = Vec::with_capacity(n - drop);
    let mut values = Vec::with_capacity(n - drop);
    for i in 0..n {
        if g[i].abs() >= thr {
            indices.push(i as u32);
            values.push(g[i]);
        }
    }
    (Payload::TopK { n, indices, values }, thr)
}

/// Drop the `ratio` fraction of smallest-|g| elements (densified view of
/// [`topk_encode`]; bit-identical to the historical eager implementation).
pub fn topk_sparsify(g: &[f32], ratio: f64) -> SparseGrad {
    let (payload, _) = topk_encode(g, ratio);
    let Payload::TopK { ref indices, .. } = payload else { unreachable!() };
    let kept = indices.len();
    SparseGrad { dense: payload.to_dense(), kept }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen_vec_f32, Config};
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn ratio_zero_keeps_all() {
        let g = randn(100, 0);
        let s = topk_sparsify(&g, 0.0);
        assert_eq!(s.dense, g);
        assert_eq!(s.kept, 100);
    }

    #[test]
    fn ratio_one_drops_all() {
        let g = randn(100, 1);
        let s = topk_sparsify(&g, 1.0);
        assert_eq!(s.dense, vec![0.0; 100]);
        assert_eq!(s.kept, 0);
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let g = randn(4096, 2);
        let s = topk_sparsify(&g, 0.75);
        assert!((s.kept as i64 - 1024).abs() <= 2);
        let min_kept = g
            .iter()
            .zip(&s.dense)
            .filter(|(_, &d)| d != 0.0)
            .map(|(x, _)| x.abs())
            .fold(f32::MAX, f32::min);
        let max_dropped = g
            .iter()
            .zip(&s.dense)
            .filter(|(_, &d)| d == 0.0)
            .map(|(x, _)| x.abs())
            .fold(0.0f32, f32::max);
        assert!(min_kept >= max_dropped);
    }

    #[test]
    fn kept_values_unchanged() {
        let g = randn(512, 3);
        let s = topk_sparsify(&g, 0.5);
        for i in 0..512 {
            assert!(s.dense[i] == 0.0 || s.dense[i] == g[i]);
        }
    }

    #[test]
    fn single_element_vector() {
        let s = topk_sparsify(&[3.0], 0.0);
        assert_eq!(s.dense, vec![3.0]);
        let s = topk_sparsify(&[3.0], 0.99);
        assert_eq!(s.dense, vec![3.0]); // floor(0.99*1)=0 dropped
        let s = topk_sparsify(&[3.0], 1.0);
        assert_eq!(s.dense, vec![0.0]);
    }

    #[test]
    fn prop_kept_count_matches_mask_and_bound() {
        forall(
            Config { cases: 64, seed: 0x70CC },
            |rng, size| {
                let g = gen_vec_f32(rng, size * 4, 1.0);
                let ratio = rng.f64();
                (g, ratio)
            },
            |(g, ratio)| {
                let s = topk_sparsify(g, *ratio);
                let nz = s.dense.iter().filter(|&&x| x != 0.0).count();
                // zeros in g can be "kept" but stay 0 in dense; kept >= nz
                if s.kept < nz {
                    return Err(format!("kept {} < nonzeros {}", s.kept, nz));
                }
                // inclusive ties at the threshold can only *keep more*
                // than the n - drop target, never fewer: the invariants
                // are kept >= n - drop whenever drop < n, and kept <= n.
                let drop = (ratio * g.len() as f64).floor() as usize;
                if s.kept > g.len() {
                    return Err(format!("kept {} > n {}", s.kept, g.len()));
                }
                if drop < g.len() && s.kept < g.len() - drop {
                    return Err(format!(
                        "kept {} < n - drop {}",
                        s.kept,
                        g.len() - drop
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_sparsified_error_monotone_in_ratio() {
        forall(
            Config { cases: 32, seed: 0x70CD },
            |rng, size| gen_vec_f32(rng, size * 8, 1.0),
            |g| {
                let mut prev = -1.0f64;
                for ratio in [0.0, 0.3, 0.6, 0.9] {
                    let s = topk_sparsify(g, ratio);
                    let err: f64 = g
                        .iter()
                        .zip(&s.dense)
                        .map(|(a, b)| ((a - b) as f64).powi(2))
                        .sum();
                    if err < prev - 1e-9 {
                        return Err(format!("err not monotone at ratio {ratio}"));
                    }
                    prev = err;
                }
                Ok(())
            },
        );
    }
}

//! Compression codecs (rust-native implementations).
//!
//! These mirror the Layer-1 Pallas kernels bit-for-bit in semantics (the
//! integration test `tests/compress_parity.rs` pins them against the AOT
//! HLO artifacts): the simulator needs them at arbitrary shape and scale,
//! and the traffic accounting needs the realized masks.
//!
//! * [`caesar_model`] — the paper's §4.1 download codec: threshold-split
//!   Top-K + 1-bit sign quantization with avg/max side info, and the
//!   local-model-assisted recovery with the two error corrections.
//! * [`topk`] — Top-K gradient sparsification (§4.2 upload codec, also the
//!   FIC/CAC/FlexCom baselines' codec).
//! * [`quant`] — QSGD-style stochastic uniform quantization (ProWD).
//! * [`traffic`] — legacy closed-form bit accounting, kept as the
//!   cross-check for the *measured* wire lengths (`crate::wire`), plus the
//!   paper-scale [`traffic::PayloadScale`] and [`traffic::TrafficMeter`].
//!
//! Codecs emit first-class [`crate::wire::Payload`]s ([`topk::topk_encode`],
//! [`quant::quantize_codes`], `CompressedModel` wrapped by
//! `Payload::CaesarSplit`); the dense helpers remain as bit-identical
//! views for the kernel-parity pins.

pub mod caesar_model;
pub mod quant;
pub mod select;
pub mod topk;
pub mod traffic;

pub use caesar_model::{caesar_compress, caesar_recover, CompressedModel};
pub use quant::{quantize_floor, quantize_stochastic};
pub use select::{radix_select_kth, select_threshold};
pub use topk::{topk_encode, topk_sparsify};

/// Branch-free |x| → sortable-u32 transform feeding the radix threshold
/// selection ([`select::select_threshold`], behind both
/// [`topk::keep_threshold`] and [`caesar_model::quant_threshold`]).
///
/// For non-negative IEEE-754 floats the bit pattern orders exactly like
/// the value, and clearing the sign bit IS |x| (for every input,
/// including ±0 and NaN payloads) — so each lane is a single integer AND:
/// no `abs` call, no float compare, no branches. The body is chunked
/// 8-wide through a fixed-size array so the autovectorizer emits one
/// SIMD load/and/store per chunk at million-parameter scale; a scalar
/// tail covers `len % 8`. Keys land in `dst` (cleared first — pass pooled
/// scratch). Property-pinned equal to the scalar `x.abs().to_bits()` path.
pub fn abs_sort_keys(src: &[f32], dst: &mut Vec<u32>) {
    const SIGN_OFF: u32 = 0x7fff_ffff;
    dst.clear();
    dst.reserve(src.len());
    let mut chunks = src.chunks_exact(8);
    for c in chunks.by_ref() {
        let keys: [u32; 8] = std::array::from_fn(|j| c[j].to_bits() & SIGN_OFF);
        dst.extend_from_slice(&keys);
    }
    for x in chunks.remainder() {
        dst.push(x.to_bits() & SIGN_OFF);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen_vec_f32, Config};

    #[test]
    fn prop_abs_sort_keys_matches_the_scalar_abs_path() {
        forall(
            Config { cases: 64, seed: 0xAB5 },
            |rng, size| {
                // sizes straddling the 8-wide chunk boundary
                let n = size * 4 + (rng.below(9));
                gen_vec_f32(rng, n, 1.0)
            },
            |g| {
                let mut keys = Vec::new();
                abs_sort_keys(g, &mut keys);
                let scalar: Vec<u32> = g.iter().map(|x| x.abs().to_bits()).collect();
                if keys != scalar {
                    return Err(format!("key transform diverged at n={}", g.len()));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn abs_sort_keys_edge_values_and_tail() {
        // > 8 elements so both the chunked body and the tail run; covers
        // signed zeros, subnormals, infinities and NaN
        let g = [
            0.0f32,
            -0.0,
            1.5,
            -1.5,
            f32::MIN_POSITIVE,
            -f32::MIN_POSITIVE,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::NAN,
            -3.25e-40, // subnormal
            7.0,
        ];
        let mut keys = Vec::new();
        abs_sort_keys(&g, &mut keys);
        assert_eq!(keys.len(), g.len());
        for (i, x) in g.iter().enumerate() {
            assert_eq!(keys[i], x.abs().to_bits(), "elem {i} ({x})");
        }
        // reuse clears previous contents and handles the empty slice
        abs_sort_keys(&[], &mut keys);
        assert!(keys.is_empty());
    }
}

//! Compression codecs (rust-native implementations).
//!
//! These mirror the Layer-1 Pallas kernels bit-for-bit in semantics (the
//! integration test `tests/compress_parity.rs` pins them against the AOT
//! HLO artifacts): the simulator needs them at arbitrary shape and scale,
//! and the traffic accounting needs the realized masks.
//!
//! * [`caesar_model`] — the paper's §4.1 download codec: threshold-split
//!   Top-K + 1-bit sign quantization with avg/max side info, and the
//!   local-model-assisted recovery with the two error corrections.
//! * [`topk`] — Top-K gradient sparsification (§4.2 upload codec, also the
//!   FIC/CAC/FlexCom baselines' codec).
//! * [`quant`] — QSGD-style stochastic uniform quantization (ProWD).
//! * [`traffic`] — legacy closed-form bit accounting, kept as the
//!   cross-check for the *measured* wire lengths (`crate::wire`), plus the
//!   paper-scale [`traffic::PayloadScale`] and [`traffic::TrafficMeter`].
//!
//! Codecs emit first-class [`crate::wire::Payload`]s ([`topk::topk_encode`],
//! [`quant::quantize_codes`], `CompressedModel` wrapped by
//! `Payload::CaesarSplit`); the dense helpers remain as bit-identical
//! views for the kernel-parity pins.

pub mod caesar_model;
pub mod quant;
pub mod topk;
pub mod traffic;

pub use caesar_model::{caesar_compress, caesar_recover, CompressedModel};
pub use quant::{quantize_floor, quantize_stochastic};
pub use topk::{topk_encode, topk_sparsify};

//! Caesar's global-model download codec (paper §4.1, Figure 3).
//!
//! Compression: the `ratio` fraction of parameters with the smallest
//! absolute values is reduced to a 1-bit sign; the remaining parameters
//! travel fp32. The average and maximum absolute value of the quantized
//! set travel as two fp32 scalars.
//!
//! Recovery: a quantized position is approximated by the receiver's stale
//! local parameter, unless the local value's sign contradicts the
//! transmitted sign bit or its magnitude exceeds the transmitted max-abs —
//! then `sign * avg_abs` is used.
//!
//! Semantics (threshold = k-th smallest |w| with k = floor(ratio·n),
//! inclusive ties, sign(0) = +1) match `python/compile/kernels/ref.py`
//! exactly; the parity integration test pins all three implementations.

use crate::util::bitio::{BitReader, BitWriter};

/// A compressed global model as produced by the PS for one device.
#[derive(Clone, Debug, PartialEq)]
pub struct CompressedModel {
    /// Full-precision payload; 0.0 at quantized positions.
    pub kept: Vec<f32>,
    /// True at 1-bit (quantized) positions.
    pub mask: Vec<bool>,
    /// Transmitted sign at quantized positions (+1 / -1), 0 elsewhere.
    pub sign: Vec<i8>,
    /// Mean |w| over the quantized set (0 if empty).
    pub avg_abs: f32,
    /// Max |w| over the quantized set (0 if empty).
    pub max_abs: f32,
}

impl CompressedModel {
    pub fn len(&self) -> usize {
        self.kept.len()
    }

    pub fn is_empty(&self) -> bool {
        self.kept.is_empty()
    }

    /// Number of quantized (1-bit) positions.
    pub fn n_quantized(&self) -> usize {
        self.mask.iter().filter(|&&m| m).count()
    }

    /// Exact wire size in bits (see `traffic::caesar_model_bits`).
    pub fn wire_bits(&self) -> usize {
        super::traffic::caesar_model_bits(self.len(), self.n_quantized())
    }

    /// Prior-free reconstruction: `sign·avg_abs` at quantized slots, kept
    /// values elsewhere — what a receiver WITHOUT a stale local model can
    /// compute. Receivers with one use [`caesar_recover`] instead.
    pub fn naive_reconstruction(&self) -> Vec<f32> {
        (0..self.len())
            .map(|i| {
                if self.mask[i] {
                    self.sign[i] as f32 * self.avg_abs
                } else {
                    self.kept[i]
                }
            })
            .collect()
    }

    /// Serialize to the wire format (bitmap + signs + fp32 payload +
    /// 2 scalars) into an in-progress writer. This IS the byte stream the
    /// simulator moves for a `wire::Payload::CaesarSplit` download.
    pub fn encode_into(&self, w: &mut BitWriter) {
        for &m in &self.mask {
            w.push_bit(m);
        }
        for (i, &m) in self.mask.iter().enumerate() {
            if m {
                w.push_bit(self.sign[i] > 0);
            } else {
                w.push_f32(self.kept[i]);
            }
        }
        w.push_f32(self.avg_abs);
        w.push_f32(self.max_abs);
    }

    /// [`encode_into`] to a fresh byte buffer.
    pub fn encode(&self) -> Vec<u8> {
        let mut w = BitWriter::new();
        self.encode_into(&mut w);
        w.into_bytes()
    }

    /// Inverse of [`encode_into`]; `n` is the parameter count.
    pub fn decode_from(r: &mut BitReader, n: usize) -> CompressedModel {
        let mask: Vec<bool> = (0..n).map(|_| r.read_bit()).collect();
        let mut kept = vec![0.0f32; n];
        let mut sign = vec![0i8; n];
        for i in 0..n {
            if mask[i] {
                sign[i] = if r.read_bit() { 1 } else { -1 };
            } else {
                kept[i] = r.read_f32();
            }
        }
        let avg_abs = r.read_f32();
        let max_abs = r.read_f32();
        CompressedModel { kept, mask, sign, avg_abs, max_abs }
    }

    /// Inverse of [`encode`]; `n` is the parameter count.
    pub fn decode(bytes: &[u8], n: usize) -> CompressedModel {
        Self::decode_from(&mut BitReader::new(bytes), n)
    }
}

/// The |w| threshold below-or-equal which elements are quantized
/// (k = floor(ratio·n) smallest; -1.0 when k == 0 so nothing matches).
pub fn quant_threshold(w: &[f32], ratio: f64) -> f32 {
    let n = w.len();
    let k = (ratio * n as f64).floor() as usize;
    if k == 0 || n == 0 {
        return -1.0;
    }
    // rank lookup via the O(n) radix select that owns the tie contract:
    // the k-th smallest |w| is the value at ascending rank k - 1
    super::select_threshold(w, k.min(n) - 1)
}

/// Compress `w` with quantized-fraction `ratio` (mirrors the L1 kernel).
pub fn caesar_compress(w: &[f32], ratio: f64) -> CompressedModel {
    let thr = quant_threshold(w, ratio);
    let n = w.len();
    let mut kept = vec![0.0f32; n];
    let mut mask = vec![false; n];
    let mut sign = vec![0i8; n];
    let mut sum_abs = 0.0f64;
    let mut max_abs = 0.0f32;
    let mut count = 0usize;
    for i in 0..n {
        let a = w[i].abs();
        if a <= thr {
            mask[i] = true;
            sign[i] = if w[i] >= 0.0 { 1 } else { -1 };
            sum_abs += a as f64;
            max_abs = max_abs.max(a);
            count += 1;
        } else {
            kept[i] = w[i];
        }
    }
    let avg_abs = if count > 0 { (sum_abs / count as f64) as f32 } else { 0.0 };
    CompressedModel { kept, mask, sign, avg_abs, max_abs }
}

/// Recover the full-precision model using the stale `local` model
/// (mirrors the L1 kernel, paper Figure 3).
pub fn caesar_recover(cm: &CompressedModel, local: &[f32]) -> Vec<f32> {
    assert_eq!(cm.len(), local.len());
    let mut out = Vec::with_capacity(cm.len());
    for i in 0..cm.len() {
        if !cm.mask[i] {
            out.push(cm.kept[i]);
            continue;
        }
        let l = local[i];
        let local_sign: i8 = if l >= 0.0 { 1 } else { -1 };
        let bad = local_sign != cm.sign[i] || l.abs() > cm.max_abs;
        out.push(if bad { cm.sign[i] as f32 * cm.avg_abs } else { l });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, gen_vec_f32, Config};
    use crate::util::rng::Rng;
    use crate::util::stats;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn ratio_zero_is_identity_payload() {
        let w = randn(257, 0);
        let cm = caesar_compress(&w, 0.0);
        assert_eq!(cm.n_quantized(), 0);
        assert_eq!(cm.kept, w);
        assert_eq!(cm.avg_abs, 0.0);
        // recovery with any local model is exact
        let local = randn(257, 1);
        assert_eq!(caesar_recover(&cm, &local), w);
    }

    #[test]
    fn ratio_one_quantizes_all() {
        let w = randn(100, 2);
        let cm = caesar_compress(&w, 1.0);
        assert_eq!(cm.n_quantized(), 100);
        let want_avg = w.iter().map(|x| x.abs() as f64).sum::<f64>() / 100.0;
        assert!((cm.avg_abs as f64 - want_avg).abs() < 1e-6);
    }

    #[test]
    fn quantizes_smallest_magnitudes() {
        let w = randn(4096, 3);
        let cm = caesar_compress(&w, 0.5);
        let q_max = w
            .iter()
            .zip(&cm.mask)
            .filter(|(_, &m)| m)
            .map(|(x, _)| x.abs())
            .fold(0.0f32, f32::max);
        let k_min = w
            .iter()
            .zip(&cm.mask)
            .filter(|(_, &m)| !m)
            .map(|(x, _)| x.abs())
            .fold(f32::MAX, f32::min);
        assert!(q_max <= k_min);
        assert_eq!(cm.max_abs, q_max);
    }

    #[test]
    fn quantized_fraction_tracks_ratio() {
        let w = randn(10_000, 4);
        for ratio in [0.1, 0.35, 0.6, 0.9] {
            let cm = caesar_compress(&w, ratio);
            let frac = cm.n_quantized() as f64 / 10_000.0;
            assert!((frac - ratio).abs() < 2e-3, "ratio={ratio} frac={frac}");
        }
    }

    #[test]
    fn recovery_with_fresh_local_is_exact() {
        let w = randn(2048, 5);
        let cm = caesar_compress(&w, 0.5);
        let out = caesar_recover(&cm, &w);
        assert_eq!(out, w);
    }

    #[test]
    fn recovery_error_grows_with_staleness() {
        // the Fig. 1c phenomenon: more local-model drift → worse recovery
        let w = randn(8192, 6);
        let mut rng = Rng::new(7);
        let cm = caesar_compress(&w, 0.5);
        let mut prev = -1.0f64;
        for drift in [0.0, 0.05, 0.2, 1.0] {
            let local: Vec<f32> = w
                .iter()
                .map(|&x| x + drift * rng.normal() as f32)
                .collect();
            let err = stats::mse(&caesar_recover(&cm, &local), &w);
            assert!(err >= prev, "drift={drift} err={err} prev={prev}");
            prev = err;
        }
    }

    #[test]
    fn recovery_error_grows_with_ratio() {
        let w = randn(8192, 8);
        let mut rng = Rng::new(9);
        let local: Vec<f32> = w
            .iter()
            .map(|&x| x + 0.3 * rng.normal() as f32)
            .collect();
        let mut prev = -1.0f64;
        for ratio in [0.0, 0.2, 0.5, 0.9] {
            let cm = caesar_compress(&w, ratio);
            let err = stats::mse(&caesar_recover(&cm, &local), &w);
            assert!(err >= prev, "ratio={ratio} err={err} prev={prev}");
            prev = err;
        }
    }

    #[test]
    fn sign_flip_and_overflow_corrections() {
        // figure-3 micro example
        let w = [0.5f32, -0.5, 2.0];
        let cm = caesar_compress(&w, 2.0 / 3.0);
        assert_eq!(cm.mask, vec![true, true, false]);
        // sign flips at both quantized slots
        let out = caesar_recover(&cm, &[-0.4, 0.4, 2.0]);
        assert_eq!(out[0], cm.avg_abs);
        assert_eq!(out[1], -cm.avg_abs);
        assert_eq!(out[2], 2.0);
        // magnitude overflow at slot 0
        let out = caesar_recover(&cm, &[0.9, -0.5, 2.0]);
        assert_eq!(out[0], cm.avg_abs);
        assert_eq!(out[1], -0.5);
    }

    #[test]
    fn recovery_beats_naive_sign_avg_reconstruction() {
        let w = randn(8192, 10);
        let mut rng = Rng::new(11);
        let local: Vec<f32> = w
            .iter()
            .map(|&x| x + 0.05 * rng.normal() as f32)
            .collect();
        let cm = caesar_compress(&w, 0.5);
        let rec = caesar_recover(&cm, &local);
        let naive: Vec<f32> = (0..w.len())
            .map(|i| {
                if cm.mask[i] {
                    cm.sign[i] as f32 * cm.avg_abs
                } else {
                    cm.kept[i]
                }
            })
            .collect();
        assert!(stats::mse(&rec, &w) < stats::mse(&naive, &w));
    }

    #[test]
    fn encode_decode_roundtrip_and_size() {
        let w = randn(1000, 12);
        let cm = caesar_compress(&w, 0.35);
        let bytes = cm.encode();
        assert_eq!(bytes.len(), cm.wire_bits().div_ceil(8));
        let back = CompressedModel::decode(&bytes, 1000);
        assert_eq!(back, cm);
    }

    #[test]
    fn zeros_vector_edge() {
        let w = vec![0.0f32; 64];
        let cm = caesar_compress(&w, 0.5);
        // |0| <= thr(=0) → all quantized, signs all +1
        assert_eq!(cm.n_quantized(), 64);
        assert!(cm.sign.iter().all(|&s| s == 1));
        let rec = caesar_recover(&cm, &vec![0.0f32; 64]);
        assert_eq!(rec, w);
    }

    #[test]
    fn prop_recovery_never_worse_than_sign_only_with_exact_local() {
        forall(
            Config { cases: 48, seed: 0xCAFE },
            |rng, size| {
                let w = gen_vec_f32(rng, size * 4, 1.0);
                let ratio = rng.f64();
                (w, ratio)
            },
            |(w, ratio)| {
                let cm = caesar_compress(w, *ratio);
                let rec = caesar_recover(&cm, w);
                if rec == *w {
                    Ok(())
                } else {
                    Err("recover(compress(w), local=w) != w".into())
                }
            },
        );
    }

    #[test]
    fn prop_kept_plus_quantized_partition() {
        forall(
            Config { cases: 48, seed: 0xBEEF },
            |rng, size| {
                let w = gen_vec_f32(rng, size * 4, 1.0);
                let ratio = rng.f64();
                (w, ratio)
            },
            |(w, ratio)| {
                let cm = caesar_compress(w, *ratio);
                for i in 0..w.len() {
                    let ok = if cm.mask[i] {
                        cm.kept[i] == 0.0 && cm.sign[i] != 0
                    } else {
                        cm.kept[i] == w[i] && cm.sign[i] == 0
                    };
                    if !ok {
                        return Err(format!("partition violated at {i}"));
                    }
                }
                let k = (ratio * w.len() as f64).floor() as usize;
                if cm.n_quantized() < k {
                    return Err(format!(
                        "quantized {} < floor(ratio*n) {}",
                        cm.n_quantized(),
                        k
                    ));
                }
                Ok(())
            },
        );
    }
}

//! Closed-form wire-size accounting — now the *cross-check*, not the
//! source of truth.
//!
//! Production traffic numbers are measured from actually serialized
//! payloads (`crate::wire`): `EncodedPayload::bits` is what the meter and
//! the transfer-time model consume. The per-codec formulas below survive
//! as debug-assert cross-checks inside `wire::Payload::encode` and as the
//! pinned equalities in `tests/wire_format.rs`, so serialization and
//! accounting can never silently drift apart again.
//!
//! The paper reports traffic as θ·Q (ignoring position metadata). The wire
//! formats carry the real metadata — position bitmaps / index lists, side
//! scalars — so traffic numbers are honest; DESIGN.md notes where this
//! differs from the paper's idealized accounting (it is a few percent).
//!
//! The simulated payload is scaled to the paper's model sizes (`q_scale`):
//! compression decisions are measured on the real (small) stand-in model
//! and the resulting bits-per-parameter is applied to the paper-scale
//! parameter count, reproducing the paper's GB-scale traffic and its
//! comm/comp balance. See DESIGN.md §Substitutions.

/// Uncompressed model/gradient size in bits for `n` fp32 parameters.
pub fn full_model_bits(n: usize) -> usize {
    n * 32
}

/// Caesar download codec: P-bit position bitmap + 1 bit per quantized
/// element + 32 bits per kept element + avg/max scalars.
pub fn caesar_model_bits(n: usize, n_quantized: usize) -> usize {
    assert!(n_quantized <= n);
    n + n_quantized + (n - n_quantized) * 32 + 64
}

/// Top-K upload codec: 32 bits per kept value + positions. Positions cost
/// min(P-bit bitmap, k·ceil(log2 P)) — the encoder picks the cheaper.
pub fn topk_grad_bits(n: usize, kept: usize) -> usize {
    let idx_bits = crate::util::bitio::bits_for(n) as usize;
    kept * 32 + (kept * idx_bits).min(n)
}

/// QSGD codec: 1 sign bit + `bits` bucket bits per element + fp32 norm.
pub fn quantized_bits(n: usize, bits: u32) -> usize {
    n * (1 + bits as usize) + 32
}

/// Paper-scale payload model: bits-per-parameter measured on the stand-in
/// model, applied to the paper's parameter count.
#[derive(Clone, Copy, Debug)]
pub struct PayloadScale {
    /// Parameter count of the stand-in (our real trained model).
    pub n_real: usize,
    /// Parameter count whose traffic we simulate (paper's model).
    pub n_paper: usize,
}

impl PayloadScale {
    pub fn identity(n: usize) -> PayloadScale {
        PayloadScale { n_real: n, n_paper: n }
    }

    /// Scale measured wire bits on the stand-in up to paper scale.
    pub fn scale_bits(&self, measured_bits: usize) -> f64 {
        measured_bits as f64 * self.n_paper as f64 / self.n_real as f64
    }

    /// Paper-scale uncompressed payload (Eq. 7's Q) in bits.
    pub fn q_bits(&self) -> f64 {
        (self.n_paper * 32) as f64
    }
}

/// Running totals for one experiment.
#[derive(Clone, Copy, Debug, Default)]
pub struct TrafficMeter {
    pub down_bits: f64,
    pub up_bits: f64,
}

impl TrafficMeter {
    pub fn add_down(&mut self, bits: f64) {
        self.down_bits += bits;
    }

    pub fn add_up(&mut self, bits: f64) {
        self.up_bits += bits;
    }

    pub fn total_bits(&self) -> f64 {
        self.down_bits + self.up_bits
    }

    pub fn total_gb(&self) -> f64 {
        self.total_bits() / 8.0 / 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn caesar_bits_edges() {
        // nothing quantized: bitmap + full payload + scalars
        assert_eq!(caesar_model_bits(100, 0), 100 + 3200 + 64);
        // all quantized: bitmap + sign bits + scalars
        assert_eq!(caesar_model_bits(100, 100), 100 + 100 + 64);
        // caesar at ratio>0 always beats full precision + bitmap overhead
        assert!(caesar_model_bits(1000, 350) < full_model_bits(1000));
    }

    #[test]
    fn caesar_saving_matches_ratio_roughly() {
        let n = 10_000;
        let bits = caesar_model_bits(n, 3500);
        let ideal = 0.65 * 32.0 * n as f64 + 0.35 * n as f64;
        let overhead = bits as f64 - ideal;
        assert!(overhead <= (n + 64) as f64); // bitmap + scalars only
    }

    #[test]
    fn topk_picks_cheaper_position_encoding() {
        let n = 10_000; // idx bits = 14
        // tiny k → index list cheaper than bitmap
        assert_eq!(topk_grad_bits(n, 10), 10 * 32 + 10 * 14);
        // huge k → bitmap cheaper
        assert_eq!(topk_grad_bits(n, 5000), 5000 * 32 + n);
    }

    #[test]
    fn quantized_bits_formula() {
        assert_eq!(quantized_bits(1000, 4), 5000 + 32);
    }

    #[test]
    fn payload_scaling() {
        let s = PayloadScale { n_real: 9_610, n_paper: 11_690_000 };
        let measured = full_model_bits(9_610);
        let scaled = s.scale_bits(measured);
        assert!((scaled - 11_690_000.0 * 32.0).abs() < 1.0);
        assert_eq!(s.q_bits(), 11_690_000.0 * 32.0);
        let id = PayloadScale::identity(100);
        assert_eq!(id.scale_bits(50.0 as usize as usize * 1), 50.0);
    }

    #[test]
    fn meter_accumulates() {
        let mut m = TrafficMeter::default();
        m.add_down(8e9);
        m.add_up(8e9);
        assert_eq!(m.total_gb(), 2.0);
    }
}

//! Typed messages between the coordinator state machine and its simulated
//! devices.
//!
//! Real FL coordinators (xaynet, FedScale) are message-driven: devices
//! rendezvous (`Join`), prove liveness (`Heartbeat`), receive a round plan
//! (`StartRound`), and either report an update (`EndRound`) or vanish
//! (`Dropout`). Here devices are simulated on worker threads, so the same
//! vocabulary flows over an in-process channel; the coordinator side of
//! the protocol (registry updates, aggregation, accounting) is identical
//! to what a networked transport would drive.

use crate::fleet::RoundCost;
use crate::schemes::DevicePlan;
use crate::wire::EncodedPayload;

use super::aggregate::AggregatorShard;

/// Coordinator → device: kick off one round of local work. Carries the
/// scheme's plan plus this round's modelled link/compute draws.
#[derive(Clone, Copy, Debug)]
pub struct StartRound {
    /// 1-based round number.
    pub t: usize,
    pub plan: DevicePlan,
    /// Download / upload bandwidth (bit/s) drawn for this round.
    pub beta_d: f64,
    pub beta_u: f64,
    /// Per-sample compute latency (s).
    pub mu: f64,
}

/// A completed device round, ready for coordinator-side application.
/// The update *gradient* is deliberately absent: it was already folded
/// into the worker's [`AggregatorShard`] so full per-device update
/// vectors are never all materialized at once.
#[derive(Clone, Debug)]
pub struct RoundUpdate {
    pub device: usize,
    /// Final local model `w_i^{t,τ}` (becomes the device's stale local).
    pub w_final: Vec<f32>,
    /// The exact serialized upload the device put on the wire. The
    /// coordinator shard already folded its decoded payload; traffic
    /// accounting derives from `upload.bits` (the measured length).
    /// Retaining the bytes (rather than just the length) keeps the
    /// message an honest transcript of the transport; it is at most the
    /// size of `w_final` above (compressed codecs: far smaller), so the
    /// per-round memory order is unchanged.
    pub upload: EncodedPayload,
    /// ‖g_i‖₂ — PyramidFL's ranking signal.
    pub grad_norm: f64,
    /// Mean local training loss over the τ iterations.
    pub loss: f64,
    /// Measured wire length (bits) of the download this device received,
    /// at stand-in scale; the Server scales it to paper size.
    pub down_wire_bits: usize,
    /// Simulated Eq. 7 cost of the device's round.
    pub cost: RoundCost,
}

/// Device → coordinator messages.
#[derive(Clone, Debug)]
pub enum DeviceMsg {
    /// Rendezvous: the device is online and schedulable.
    Join { device: usize },
    /// Liveness ping at simulated time `sim_t_s`.
    Heartbeat { device: usize, sim_t_s: f64 },
    /// The device finished its round.
    EndRound(Box<RoundUpdate>),
    /// The device vanished mid-round, `after_s` seconds in. Its download
    /// had already completed (`down_wire_bits` measured bits were spent);
    /// no update reaches aggregation.
    Dropout { device: usize, after_s: f64, down_wire_bits: usize },
}

/// Everything a worker thread sends back to the coordinator loop.
#[derive(Debug)]
pub enum Event {
    Device(DeviceMsg),
    /// A finished aggregation shard (one per device group).
    Shard(AggregatorShard),
    /// A worker-side failure, stringified so it crosses the channel.
    Error(String),
}

/// Record of a device that dropped out of the current round.
#[derive(Clone, Copy, Debug)]
pub struct DroppedDevice {
    pub device: usize,
    /// Simulated seconds into the round at which it vanished.
    pub after_s: f64,
    /// Download traffic it had already consumed (measured stand-in bits).
    pub down_wire_bits: usize,
}

/// A straggler's upload parked in the semi-async staleness buffer: it was
/// produced in `origin_t` but folds into `fold_t > origin_t`'s aggregate.
/// All other round-`origin_t` accounting (traffic, locals, tracker) was
/// applied when `origin_t` closed; only the gradient fold is deferred.
#[derive(Clone, Debug)]
pub struct LateUpload {
    /// Round the device trained in.
    pub origin_t: usize,
    /// Round whose aggregate absorbs the upload.
    pub fold_t: usize,
    pub device: usize,
    /// The serialized upload, refolded verbatim at `fold_t`.
    pub upload: EncodedPayload,
}

//! Participant registry with liveness tracking.
//!
//! The coordinator's view of every device it has ever heard from: current
//! status, last-seen simulated time, and cumulative participation /
//! dropout counters. Mirrors the bookkeeping a networked FL coordinator
//! keeps to decide who is schedulable and who timed out.

/// A device's status as seen by the coordinator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeviceStatus {
    /// Never joined (no message received yet).
    #[default]
    Offline,
    /// Joined and schedulable.
    Idle,
    /// Currently executing a round.
    Training,
    /// Vanished mid-round; back to schedulable once it re-joins.
    Dropped,
}

/// Registry over a fixed device-id space `0..n`.
#[derive(Clone, Debug)]
pub struct Registry {
    status: Vec<DeviceStatus>,
    /// Simulated time of the last message from each device.
    last_seen_s: Vec<f64>,
    /// Completed rounds per device.
    completions: Vec<u32>,
    /// Mid-round dropouts per device.
    dropouts: Vec<u32>,
    /// Expected heartbeat interval (s); liveness allows 2 missed beats.
    heartbeat_s: f64,
}

impl Registry {
    pub fn new(n_devices: usize, heartbeat_s: f64) -> Registry {
        Registry {
            status: vec![DeviceStatus::Offline; n_devices],
            last_seen_s: vec![f64::NEG_INFINITY; n_devices],
            completions: vec![0; n_devices],
            dropouts: vec![0; n_devices],
            heartbeat_s,
        }
    }

    pub fn len(&self) -> usize {
        self.status.len()
    }

    pub fn is_empty(&self) -> bool {
        self.status.is_empty()
    }

    pub fn status(&self, device: usize) -> DeviceStatus {
        self.status[device]
    }

    /// Handle a rendezvous (idempotent; also how a dropped device returns).
    pub fn join(&mut self, device: usize, now_s: f64) {
        if self.status[device] != DeviceStatus::Training {
            self.status[device] = DeviceStatus::Idle;
        }
        self.touch(device, now_s);
    }

    pub fn heartbeat(&mut self, device: usize, now_s: f64) {
        self.touch(device, now_s);
    }

    pub fn start_round(&mut self, device: usize, now_s: f64) {
        self.status[device] = DeviceStatus::Training;
        self.touch(device, now_s);
    }

    pub fn end_round(&mut self, device: usize, now_s: f64) {
        self.status[device] = DeviceStatus::Idle;
        self.completions[device] = self.completions[device].saturating_add(1);
        self.touch(device, now_s);
    }

    pub fn dropout(&mut self, device: usize, now_s: f64) {
        self.status[device] = DeviceStatus::Dropped;
        self.dropouts[device] = self.dropouts[device].saturating_add(1);
        self.touch(device, now_s);
    }

    fn touch(&mut self, device: usize, now_s: f64) {
        let t = &mut self.last_seen_s[device];
        *t = t.max(now_s);
    }

    /// A device is live at `now_s` if it has been heard from within two
    /// heartbeat intervals (and is not dropped/offline). With heartbeats
    /// disabled (`heartbeat_s <= 0`) there is no timeout: any joined,
    /// non-dropped device counts as live.
    pub fn live(&self, device: usize, now_s: f64) -> bool {
        match self.status[device] {
            DeviceStatus::Offline | DeviceStatus::Dropped => false,
            DeviceStatus::Idle | DeviceStatus::Training => {
                self.heartbeat_s <= 0.0
                    || now_s - self.last_seen_s[device] <= 2.0 * self.heartbeat_s
            }
        }
    }

    pub fn completions(&self, device: usize) -> u32 {
        self.completions[device]
    }

    pub fn dropouts(&self, device: usize) -> u32 {
        self.dropouts[device]
    }

    /// (offline, idle, training, dropped) population counts.
    pub fn census(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for s in &self.status {
            match s {
                DeviceStatus::Offline => c.0 += 1,
                DeviceStatus::Idle => c.1 += 1,
                DeviceStatus::Training => c.2 += 1,
                DeviceStatus::Dropped => c.3 += 1,
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_standby_training_idle() {
        let mut r = Registry::new(4, 10.0);
        assert_eq!(r.status(0), DeviceStatus::Offline);
        assert!(!r.live(0, 0.0));
        r.join(0, 0.0);
        assert_eq!(r.status(0), DeviceStatus::Idle);
        assert!(r.live(0, 5.0));
        r.start_round(0, 5.0);
        assert_eq!(r.status(0), DeviceStatus::Training);
        r.end_round(0, 42.0);
        assert_eq!(r.status(0), DeviceStatus::Idle);
        assert_eq!(r.completions(0), 1);
        assert_eq!(r.census(), (3, 1, 0, 0));
    }

    #[test]
    fn liveness_expires_after_two_heartbeats() {
        let mut r = Registry::new(1, 10.0);
        r.join(0, 100.0);
        assert!(r.live(0, 119.9));
        assert!(!r.live(0, 120.1));
        r.heartbeat(0, 115.0);
        assert!(r.live(0, 130.0));
    }

    #[test]
    fn disabled_heartbeats_mean_no_timeout() {
        let mut r = Registry::new(1, 0.0);
        r.join(0, 0.0);
        assert!(r.live(0, 1e12)); // joined + never dropped = live forever
        r.dropout(0, 5.0);
        assert!(!r.live(0, 6.0)); // dropped still means dead
    }

    #[test]
    fn dropout_and_rejoin() {
        let mut r = Registry::new(2, 10.0);
        r.join(1, 0.0);
        r.start_round(1, 0.0);
        r.dropout(1, 30.0);
        assert_eq!(r.status(1), DeviceStatus::Dropped);
        assert!(!r.live(1, 30.0));
        assert_eq!(r.dropouts(1), 1);
        assert_eq!(r.completions(1), 0);
        r.join(1, 60.0);
        assert_eq!(r.status(1), DeviceStatus::Idle);
        assert!(r.live(1, 60.0));
    }

    #[test]
    fn last_seen_is_monotone() {
        let mut r = Registry::new(1, 10.0);
        r.join(0, 50.0);
        r.heartbeat(0, 20.0); // stale message cannot rewind liveness
        assert!(r.live(0, 65.0));
    }
}

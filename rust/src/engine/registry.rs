//! Participant registry with liveness tracking.
//!
//! The coordinator's view of every device it has ever heard from: current
//! status, last-seen simulated time, and cumulative participation /
//! dropout counters. Mirrors the bookkeeping a networked FL coordinator
//! keeps to decide who is schedulable and who timed out.
//!
//! Device ids may now arrive **off the wire** (`transport::server`), so
//! every mutating entry point is total over `usize`: an out-of-range id
//! is rejected with `false` (the networked coordinator logs it and sends
//! a `Reject` frame) instead of indexing out of bounds. [`Registry::live`]
//! reports a timed-out device, and [`Registry::sweep_expired`] actively
//! transitions silent Idle/Training devices to Dropped — the eviction
//! hook a networked coordinator runs between rounds.

/// A device's status as seen by the coordinator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DeviceStatus {
    /// Never joined (no message received yet).
    #[default]
    Offline,
    /// Joined and schedulable.
    Idle,
    /// Currently executing a round.
    Training,
    /// Vanished mid-round; back to schedulable once it re-joins.
    Dropped,
}

/// Registry over a fixed device-id space `0..n`.
#[derive(Clone, Debug)]
pub struct Registry {
    status: Vec<DeviceStatus>,
    /// Simulated time of the last message from each device.
    last_seen_s: Vec<f64>,
    /// Completed rounds per device.
    completions: Vec<u32>,
    /// Mid-round dropouts per device.
    dropouts: Vec<u32>,
    /// Highest round each device was kicked off in (0 = never) — the
    /// fence the semi-async engine checks so a resolution for an older
    /// overlapped round can never be mistaken for the newest one.
    round_of: Vec<usize>,
    /// Transport binding: the opaque connection token each device's
    /// session currently rides (`None` = unbound). Many devices may
    /// share one token — a fleet multiplexes its whole device range
    /// over a single connection — so the relation lives per-device
    /// with reverse lookup by token, never per-socket.
    conn: Vec<Option<u64>>,
    /// Expected heartbeat interval (s); liveness allows 2 missed beats.
    heartbeat_s: f64,
}

impl Registry {
    pub fn new(n_devices: usize, heartbeat_s: f64) -> Registry {
        Registry {
            status: vec![DeviceStatus::Offline; n_devices],
            last_seen_s: vec![f64::NEG_INFINITY; n_devices],
            completions: vec![0; n_devices],
            dropouts: vec![0; n_devices],
            round_of: vec![0; n_devices],
            conn: vec![None; n_devices],
            heartbeat_s,
        }
    }

    pub fn len(&self) -> usize {
        self.status.len()
    }

    pub fn is_empty(&self) -> bool {
        self.status.is_empty()
    }

    /// Whether `device` is a valid id in this registry's space.
    pub fn contains(&self, device: usize) -> bool {
        device < self.status.len()
    }

    /// Status of `device` (Offline for out-of-range ids — unknown devices
    /// have simply never been heard from).
    pub fn status(&self, device: usize) -> DeviceStatus {
        self.status.get(device).copied().unwrap_or_default()
    }

    /// Handle a rendezvous (idempotent; also how a dropped device
    /// returns). Returns `false` — a rejection, not a crash — for an
    /// out-of-range id, which a networked coordinator receives straight
    /// off the wire.
    pub fn join(&mut self, device: usize, now_s: f64) -> bool {
        if !self.contains(device) {
            return false;
        }
        if self.status[device] != DeviceStatus::Training {
            self.status[device] = DeviceStatus::Idle;
        }
        self.touch(device, now_s);
        true
    }

    /// Liveness ping; `false` rejects an out-of-range id.
    pub fn heartbeat(&mut self, device: usize, now_s: f64) -> bool {
        if !self.contains(device) {
            return false;
        }
        self.touch(device, now_s);
        true
    }

    /// Mark a device as executing a round; `false` rejects an
    /// out-of-range id.
    pub fn start_round(&mut self, device: usize, now_s: f64) -> bool {
        if !self.contains(device) {
            return false;
        }
        self.status[device] = DeviceStatus::Training;
        self.touch(device, now_s);
        true
    }

    /// [`Registry::start_round`] plus the round fence: records that the
    /// newest round `device` was kicked off in is at least `t` (monotone,
    /// so an overlapped older round's kickoff cannot rewind it).
    pub fn start_round_in(&mut self, device: usize, now_s: f64, t: usize) -> bool {
        if !self.start_round(device, now_s) {
            return false;
        }
        let r = &mut self.round_of[device];
        *r = (*r).max(t);
        true
    }

    /// Highest round `device` was ever kicked off in (0 = never, including
    /// out-of-range ids).
    pub fn last_started(&self, device: usize) -> usize {
        self.round_of.get(device).copied().unwrap_or(0)
    }

    /// Record a completed round; `false` rejects an out-of-range id.
    pub fn end_round(&mut self, device: usize, now_s: f64) -> bool {
        if !self.contains(device) {
            return false;
        }
        self.status[device] = DeviceStatus::Idle;
        self.completions[device] = self.completions[device].saturating_add(1);
        self.touch(device, now_s);
        true
    }

    /// Record a mid-round dropout; `false` rejects an out-of-range id.
    pub fn dropout(&mut self, device: usize, now_s: f64) -> bool {
        if !self.contains(device) {
            return false;
        }
        self.status[device] = DeviceStatus::Dropped;
        self.dropouts[device] = self.dropouts[device].saturating_add(1);
        self.touch(device, now_s);
        true
    }

    fn touch(&mut self, device: usize, now_s: f64) {
        let t = &mut self.last_seen_s[device];
        *t = t.max(now_s);
    }

    /// A device is live at `now_s` if it has been heard from within two
    /// heartbeat intervals (and is not dropped/offline). With heartbeats
    /// disabled (`heartbeat_s <= 0`) there is no timeout: any joined,
    /// non-dropped device counts as live. Out-of-range ids are never live.
    pub fn live(&self, device: usize, now_s: f64) -> bool {
        match self.status(device) {
            DeviceStatus::Offline | DeviceStatus::Dropped => false,
            DeviceStatus::Idle | DeviceStatus::Training => {
                self.heartbeat_s <= 0.0
                    || now_s - self.last_seen_s[device] <= 2.0 * self.heartbeat_s
            }
        }
    }

    /// Evict every device that has gone silent: Idle/Training devices not
    /// heard from within two heartbeat intervals transition to Dropped
    /// (counted as a dropout) and their ids are returned, ascending. The
    /// boundary matches [`Registry::live`] exactly — a device last seen
    /// precisely `2·heartbeat_s` ago is still live and is NOT swept. With
    /// heartbeats disabled there is no timeout and nothing is ever swept.
    pub fn sweep_expired(&mut self, now_s: f64) -> Vec<usize> {
        let mut evicted = Vec::new();
        if self.heartbeat_s <= 0.0 {
            return evicted;
        }
        for d in 0..self.status.len() {
            let silent = now_s - self.last_seen_s[d] > 2.0 * self.heartbeat_s;
            if silent
                && matches!(self.status[d], DeviceStatus::Idle | DeviceStatus::Training)
            {
                self.status[d] = DeviceStatus::Dropped;
                self.dropouts[d] = self.dropouts[d].saturating_add(1);
                evicted.push(d);
            }
        }
        evicted
    }

    /// Bind `device`'s session to connection `token` (re-binding — a
    /// rejoin from a fresh connection — simply replaces the old
    /// binding). `false` rejects an out-of-range id.
    pub fn bind_conn(&mut self, device: usize, token: u64) -> bool {
        if !self.contains(device) {
            return false;
        }
        self.conn[device] = Some(token);
        true
    }

    /// The connection token `device` is currently bound to, if any.
    pub fn conn_of(&self, device: usize) -> Option<u64> {
        self.conn.get(device).copied().flatten()
    }

    /// Sever every binding to connection `token`, returning the devices
    /// that rode it, ascending. This is the fleet-death primitive: one
    /// poisoned or dead socket unbinds ALL devices multiplexed on it —
    /// the caller decides whether they wait for a rejoin (clean death)
    /// or convert to synthesized Dropouts (poisoned peer).
    pub fn unbind_conn(&mut self, token: u64) -> Vec<usize> {
        let mut severed = Vec::new();
        for (d, c) in self.conn.iter_mut().enumerate() {
            if *c == Some(token) {
                *c = None;
                severed.push(d);
            }
        }
        severed
    }

    /// How many devices currently hold a connection binding.
    pub fn bound_count(&self) -> usize {
        self.conn.iter().filter(|c| c.is_some()).count()
    }

    pub fn completions(&self, device: usize) -> u32 {
        self.completions[device]
    }

    pub fn dropouts(&self, device: usize) -> u32 {
        self.dropouts[device]
    }

    /// (offline, idle, training, dropped) population counts.
    pub fn census(&self) -> (usize, usize, usize, usize) {
        let mut c = (0, 0, 0, 0);
        for s in &self.status {
            match s {
                DeviceStatus::Offline => c.0 += 1,
                DeviceStatus::Idle => c.1 += 1,
                DeviceStatus::Training => c.2 += 1,
                DeviceStatus::Dropped => c.3 += 1,
            }
        }
        c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lifecycle_standby_training_idle() {
        let mut r = Registry::new(4, 10.0);
        assert_eq!(r.status(0), DeviceStatus::Offline);
        assert!(!r.live(0, 0.0));
        r.join(0, 0.0);
        assert_eq!(r.status(0), DeviceStatus::Idle);
        assert!(r.live(0, 5.0));
        r.start_round(0, 5.0);
        assert_eq!(r.status(0), DeviceStatus::Training);
        r.end_round(0, 42.0);
        assert_eq!(r.status(0), DeviceStatus::Idle);
        assert_eq!(r.completions(0), 1);
        assert_eq!(r.census(), (3, 1, 0, 0));
    }

    #[test]
    fn liveness_expires_after_two_heartbeats() {
        let mut r = Registry::new(1, 10.0);
        r.join(0, 100.0);
        assert!(r.live(0, 119.9));
        assert!(!r.live(0, 120.1));
        r.heartbeat(0, 115.0);
        assert!(r.live(0, 130.0));
    }

    #[test]
    fn disabled_heartbeats_mean_no_timeout() {
        let mut r = Registry::new(1, 0.0);
        r.join(0, 0.0);
        assert!(r.live(0, 1e12)); // joined + never dropped = live forever
        r.dropout(0, 5.0);
        assert!(!r.live(0, 6.0)); // dropped still means dead
    }

    #[test]
    fn dropout_and_rejoin() {
        let mut r = Registry::new(2, 10.0);
        r.join(1, 0.0);
        r.start_round(1, 0.0);
        r.dropout(1, 30.0);
        assert_eq!(r.status(1), DeviceStatus::Dropped);
        assert!(!r.live(1, 30.0));
        assert_eq!(r.dropouts(1), 1);
        assert_eq!(r.completions(1), 0);
        r.join(1, 60.0);
        assert_eq!(r.status(1), DeviceStatus::Idle);
        assert!(r.live(1, 60.0));
    }

    #[test]
    fn out_of_range_ids_are_rejected_not_panics() {
        // wire-originated ids: every entry point must reject, not index
        let mut r = Registry::new(3, 10.0);
        for bogus in [3usize, 100, usize::MAX] {
            assert!(!r.contains(bogus));
            assert!(!r.join(bogus, 0.0));
            assert!(!r.heartbeat(bogus, 0.0));
            assert!(!r.start_round(bogus, 0.0));
            assert!(!r.end_round(bogus, 0.0));
            assert!(!r.dropout(bogus, 0.0));
            assert_eq!(r.status(bogus), DeviceStatus::Offline);
            assert!(!r.live(bogus, 0.0));
        }
        // the rejections left the registry untouched
        assert_eq!(r.census(), (3, 0, 0, 0));
        // in-range ids still work and report acceptance
        assert!(r.join(2, 1.0));
        assert_eq!(r.census(), (2, 1, 0, 0));
    }

    #[test]
    fn sweep_expired_pins_the_two_heartbeat_boundary() {
        let mut r = Registry::new(3, 10.0);
        r.join(0, 100.0);
        r.join(1, 100.0);
        r.start_round(1, 100.0);
        // device 2 never joined: Offline devices are not sweepable
        // at exactly 2 heartbeats of silence the devices are still live
        assert!(r.sweep_expired(120.0).is_empty());
        assert!(r.live(0, 120.0) && r.live(1, 120.0));
        // just past the boundary both Idle and Training are evicted
        let evicted = r.sweep_expired(120.1);
        assert_eq!(evicted, vec![0, 1]);
        assert_eq!(r.status(0), DeviceStatus::Dropped);
        assert_eq!(r.status(1), DeviceStatus::Dropped);
        assert_eq!((r.dropouts(0), r.dropouts(1)), (1, 1));
        assert_eq!(r.status(2), DeviceStatus::Offline);
        // idempotent: already-dropped devices are not re-evicted
        assert!(r.sweep_expired(500.0).is_empty());
        // a swept device can rejoin and is schedulable again
        assert!(r.join(0, 130.0));
        assert_eq!(r.status(0), DeviceStatus::Idle);
    }

    #[test]
    fn sweep_respects_fresh_heartbeats_and_disabled_liveness() {
        let mut r = Registry::new(2, 10.0);
        r.join(0, 0.0);
        r.join(1, 0.0);
        r.heartbeat(1, 15.0); // device 1 kept beating
        let evicted = r.sweep_expired(21.0); // 0 silent 21s, 1 silent 6s
        assert_eq!(evicted, vec![0]);
        assert_eq!(r.status(1), DeviceStatus::Idle);
        // disabled heartbeats: nothing ever expires
        let mut off = Registry::new(2, 0.0);
        off.join(0, 0.0);
        assert!(off.sweep_expired(1e12).is_empty());
        assert_eq!(off.status(0), DeviceStatus::Idle);
    }

    #[test]
    fn round_fence_is_monotone_and_rejects_out_of_range() {
        let mut r = Registry::new(2, 10.0);
        assert_eq!(r.last_started(0), 0);
        r.join(0, 0.0);
        assert!(r.start_round_in(0, 0.0, 3));
        assert_eq!(r.status(0), DeviceStatus::Training);
        assert_eq!(r.last_started(0), 3);
        // an overlapped older round's kickoff cannot rewind the fence
        assert!(r.start_round_in(0, 1.0, 2));
        assert_eq!(r.last_started(0), 3);
        assert!(!r.start_round_in(9, 0.0, 1));
        assert_eq!(r.last_started(9), 0);
    }

    #[test]
    fn conn_bindings_are_many_to_one_and_sever_together() {
        let mut r = Registry::new(5, 10.0);
        assert_eq!(r.conn_of(0), None);
        assert_eq!(r.bound_count(), 0);
        // a fleet: devices 0,2,4 ride conn 7; device 1 rides conn 9
        assert!(r.bind_conn(0, 7));
        assert!(r.bind_conn(2, 7));
        assert!(r.bind_conn(4, 7));
        assert!(r.bind_conn(1, 9));
        assert!(!r.bind_conn(99, 7), "out-of-range ids are rejected");
        assert_eq!(r.conn_of(2), Some(7));
        assert_eq!(r.bound_count(), 4);
        // rejoin from a fresh conn replaces the binding
        assert!(r.bind_conn(2, 9));
        assert_eq!(r.conn_of(2), Some(9));
        // one socket death severs ALL devices multiplexed on it
        assert_eq!(r.unbind_conn(7), vec![0, 4]);
        assert_eq!(r.conn_of(0), None);
        assert_eq!(r.conn_of(4), None);
        assert_eq!(r.bound_count(), 2);
        // severing an unknown token is a no-op
        assert!(r.unbind_conn(7).is_empty());
        assert_eq!(r.unbind_conn(9), vec![1, 2]);
        assert_eq!(r.bound_count(), 0);
        // bindings never touched status/liveness bookkeeping
        assert_eq!(r.census(), (5, 0, 0, 0));
    }

    #[test]
    fn last_seen_is_monotone() {
        let mut r = Registry::new(1, 10.0);
        r.join(0, 50.0);
        r.heartbeat(0, 20.0); // stale message cannot rewind liveness
        assert!(r.live(0, 65.0));
    }
}

//! Streaming, sharded, *order-exact* aggregation.
//!
//! Floating-point addition is not associative, so a parallel sum is only
//! bit-identical to a sequential one if both evaluate the SAME reduction
//! tree. The engine therefore fixes a canonical tree up front, independent
//! of how many workers execute it:
//!
//! 1. participants are sorted by device id and chunked into groups of
//!    `agg_group` (a config constant — never derived from worker count);
//! 2. an [`AggregatorShard`] accumulates one group's weighted partial sum
//!    in sorted order, folding each device's update the moment it is
//!    produced (the update vector is then dropped — at most one update
//!    per worker is ever alive);
//! 3. the [`ShardReducer`] folds finished shards into the global sum in
//!    ascending group order, buffering the occasional shard that finishes
//!    early.
//!
//! Any worker count — including 1, the sequential driver — walks this
//! exact tree, which is what the `engine_parity` integration test pins.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::compress::quant;
use crate::wire::{CaesarSlot, EncodedPayload, Payload, PayloadView};

/// Weighted f64 partial sum over one group of devices. Devices must be
/// folded in the (sorted) order fixed at construction.
#[derive(Debug)]
pub struct AggregatorShard {
    group: usize,
    sum: Vec<f64>,
    /// Device ids this shard expects, ascending.
    expect: Vec<usize>,
    /// Position of the next expected device.
    cursor: usize,
    /// Devices actually folded (dropouts are skipped).
    folded: usize,
}

impl AggregatorShard {
    pub fn new(group: usize, n_params: usize, expect: Vec<usize>) -> AggregatorShard {
        debug_assert!(expect.windows(2).all(|w| w[0] < w[1]), "expect must be sorted");
        AggregatorShard { group, sum: vec![0.0; n_params], expect, cursor: 0, folded: 0 }
    }

    pub fn group(&self) -> usize {
        self.group
    }

    pub fn folded(&self) -> usize {
        self.folded
    }

    /// Order check shared by every fold/skip entry point: `device` must be
    /// the next expected id in the shard's canonical order.
    fn advance(&mut self, device: usize, what: &str) {
        assert_eq!(
            self.expect.get(self.cursor).copied(),
            Some(device),
            "shard {}: {what} {device} out of order",
            self.group
        );
        self.cursor += 1;
    }

    /// Fold one device's dense update with aggregation weight `weight`.
    /// Must be called in the shard's expected device order.
    pub fn fold(&mut self, device: usize, update: &[f32], weight: f64) {
        self.advance(device, "device");
        assert_eq!(update.len(), self.sum.len(), "update length mismatch");
        for (s, &x) in self.sum.iter_mut().zip(update) {
            *s += (x as f64) * weight;
        }
        self.folded += 1;
    }

    /// Fold one device's decoded wire payload without densifying it first.
    ///
    /// Top-K folds only its kept entries — O(kept) work and no O(n)
    /// scratch vector — which is bit-identical to the dense fold because
    /// every skipped entry is an exact `0.0` (adding `0.0 * weight` to an
    /// f64 partial sum is a no-op, so the canonical reduction tree is
    /// unchanged). Quant dequantizes streaming with no intermediate
    /// allocation; Dense matches [`AggregatorShard::fold`] exactly.
    pub fn fold_payload(&mut self, device: usize, payload: &Payload, weight: f64) {
        self.advance(device, "device");
        assert_eq!(payload.n(), self.sum.len(), "payload length mismatch");
        match payload {
            Payload::Dense(values) => {
                for (s, &x) in self.sum.iter_mut().zip(values) {
                    *s += (x as f64) * weight;
                }
            }
            Payload::TopK { indices, values, .. } => {
                for (&i, &v) in indices.iter().zip(values) {
                    self.sum[i as usize] += (v as f64) * weight;
                }
            }
            Payload::Quant { levels, norm, codes, .. } => {
                for (s, &c) in self.sum.iter_mut().zip(codes) {
                    *s += (quant::dequantize_code(c, *levels, *norm) as f64) * weight;
                }
            }
            // downloads-only codec; accepted for completeness via the
            // prior-free densification
            Payload::CaesarSplit(cm) => {
                for (s, &x) in self.sum.iter_mut().zip(&cm.naive_reconstruction()) {
                    *s += (x as f64) * weight;
                }
            }
        }
        self.folded += 1;
    }

    /// Fold one device's *serialized* upload straight off its bytes —
    /// [`AggregatorShard::fold_payload`] without ever materializing the
    /// decoded payload. Elements stream through a borrowed
    /// [`PayloadView`] in the same order the eager decode would produce
    /// them, so the f64 additions (and therefore the canonical reduction
    /// tree) are bit-identical; the per-device index/value vectors the
    /// decode used to allocate simply never exist.
    pub fn fold_encoded(&mut self, device: usize, enc: &EncodedPayload, weight: f64) {
        self.advance(device, "device");
        assert_eq!(enc.spec.n(), self.sum.len(), "payload length mismatch");
        match enc.view() {
            PayloadView::Dense(v) => v.for_each(|i, x| self.sum[i] += (x as f64) * weight),
            PayloadView::TopK(v) => v.for_each(|i, x| self.sum[i] += (x as f64) * weight),
            PayloadView::Quant(v) => v.for_each(|i, x| self.sum[i] += (x as f64) * weight),
            // downloads-only codec; accepted for completeness — streams
            // the same prior-free reconstruction fold_payload densifies
            PayloadView::CaesarSplit(v) => {
                let (avg_abs, _) = v.scalars();
                v.for_each(|i, slot| {
                    let x = match slot {
                        CaesarSlot::Kept(val) => val,
                        CaesarSlot::Sign(sign) => sign as f32 * avg_abs,
                    };
                    self.sum[i] += (x as f64) * weight;
                });
            }
        }
        self.folded += 1;
    }

    /// Skip the next expected device (it dropped out mid-round).
    pub fn mark_dropped(&mut self, device: usize) {
        self.advance(device, "dropout");
    }

    /// True once every expected device was folded or dropped.
    pub fn complete(&self) -> bool {
        self.cursor == self.expect.len()
    }
}

/// Folds [`AggregatorShard`]s into the global sum in ascending group
/// order, regardless of the (nondeterministic) order they finish in.
#[derive(Debug)]
pub struct ShardReducer {
    total: Vec<f64>,
    next_group: usize,
    n_groups: usize,
    pending: BTreeMap<usize, AggregatorShard>,
    folded_devices: usize,
}

impl ShardReducer {
    pub fn new(n_params: usize, n_groups: usize) -> ShardReducer {
        ShardReducer {
            total: vec![0.0; n_params],
            next_group: 0,
            n_groups,
            pending: BTreeMap::new(),
            folded_devices: 0,
        }
    }

    /// Accept a finished shard; folds immediately if it is the next group
    /// in canonical order, otherwise buffers it (bounded by the number of
    /// in-flight workers in practice).
    pub fn push(&mut self, shard: AggregatorShard) -> Result<()> {
        if !shard.complete() {
            return Err(anyhow!("group {} shard pushed incomplete", shard.group()));
        }
        if shard.group() >= self.n_groups {
            return Err(anyhow!("group {} out of range ({})", shard.group(), self.n_groups));
        }
        if shard.group() < self.next_group || self.pending.contains_key(&shard.group()) {
            return Err(anyhow!("group {} reduced twice", shard.group()));
        }
        self.pending.insert(shard.group(), shard);
        while let Some(s) = self.pending.remove(&self.next_group) {
            for (t, x) in self.total.iter_mut().zip(&s.sum) {
                *t += x;
            }
            self.folded_devices += s.folded;
            self.next_group += 1;
        }
        Ok(())
    }

    /// Finish: every group must have reduced. Returns the canonical sum
    /// and the number of device updates inside it.
    pub fn finish(self) -> Result<(Vec<f64>, usize)> {
        if self.next_group != self.n_groups {
            return Err(anyhow!(
                "aggregation incomplete: {}/{} groups reduced",
                self.next_group,
                self.n_groups
            ));
        }
        Ok((self.total, self.folded_devices))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard_of(group: usize, devices: &[usize], vals: &[f32]) -> AggregatorShard {
        let mut s = AggregatorShard::new(group, vals.len(), devices.to_vec());
        for &d in devices {
            let update: Vec<f32> = vals.iter().map(|&v| v + d as f32).collect();
            s.fold(d, &update, 1.0);
        }
        s
    }

    #[test]
    fn out_of_order_shards_reduce_to_in_order_total() {
        let mk = |order: &[usize]| {
            let mut r = ShardReducer::new(3, 3);
            for &g in order {
                let devices = [g * 2, g * 2 + 1];
                r.push(shard_of(g, &devices, &[0.5, -1.25, 3.0])).unwrap();
            }
            r.finish().unwrap()
        };
        let (a, na) = mk(&[0, 1, 2]);
        let (b, nb) = mk(&[2, 0, 1]);
        assert_eq!(na, 6);
        assert_eq!(nb, 6);
        // bit-exact equality, not approximate
        assert_eq!(a, b);
    }

    #[test]
    fn shard_enforces_fold_order() {
        let mut s = AggregatorShard::new(0, 2, vec![3, 9]);
        s.fold(3, &[1.0, 1.0], 1.0);
        s.fold(9, &[1.0, 1.0], 2.0);
        assert!(s.complete());
        assert_eq!(s.folded(), 2);
        assert_eq!(s.sum, vec![3.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn shard_panics_on_wrong_device() {
        let mut s = AggregatorShard::new(0, 1, vec![3, 9]);
        s.fold(9, &[1.0], 1.0);
    }

    #[test]
    fn dropouts_are_skipped_not_summed() {
        let mut s = AggregatorShard::new(0, 2, vec![1, 2, 5]);
        s.fold(1, &[1.0, 2.0], 1.0);
        s.mark_dropped(2);
        s.fold(5, &[10.0, 20.0], 1.0);
        assert!(s.complete());
        assert_eq!(s.folded(), 2);
        assert_eq!(s.sum, vec![11.0, 22.0]);
    }

    #[test]
    fn reducer_rejects_incomplete_and_duplicate() {
        let mut r = ShardReducer::new(1, 2);
        let s = AggregatorShard::new(0, 1, vec![0, 1]); // incomplete
        assert!(r.push(s).is_err());
        r.push(shard_of(0, &[0], &[1.0])).unwrap();
        assert!(r.push(shard_of(0, &[0], &[1.0])).is_err()); // duplicate
        let r2 = ShardReducer::new(1, 2);
        assert!(r2.finish().is_err()); // nothing reduced
    }

    #[test]
    fn weight_scales_contributions() {
        let mut s = AggregatorShard::new(0, 1, vec![0]);
        s.fold(0, &[2.0], 0.25);
        assert_eq!(s.sum, vec![0.5]);
    }

    #[test]
    fn sparse_payload_fold_is_bit_identical_to_dense_fold() {
        use crate::compress::{quant, topk};
        use crate::util::rng::Rng;
        let n = 512;
        let mut rng = Rng::new(0xF01D);
        let grads: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let expect: Vec<usize> = (0..6).collect();
        let mut dense_shard = AggregatorShard::new(0, n, expect.clone());
        let mut payload_shard = AggregatorShard::new(0, n, expect.clone());
        let mut encoded_shard = AggregatorShard::new(0, n, expect);
        for (d, g) in grads.iter().enumerate() {
            // alternate codecs to cover every fold_payload arm
            let payload = match d % 3 {
                0 => topk::topk_encode(g, 0.8).0,
                1 => Payload::Dense(g.clone()),
                _ => {
                    let levels = quant::levels_for_bits(4);
                    let noise: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                    let (norm, codes) = quant::quantize_codes(g, levels, Some(&noise));
                    Payload::Quant { bits: 4, levels, norm, codes }
                }
            };
            // the wire really is traversed: encode → bytes → decode
            let enc = payload.encode();
            let decoded = enc.decode();
            dense_shard.fold(d, &decoded.to_dense(), 0.7);
            payload_shard.fold_payload(d, &decoded, 0.7);
            encoded_shard.fold_encoded(d, &enc, 0.7);
        }
        assert!(dense_shard.complete() && payload_shard.complete() && encoded_shard.complete());
        for ((a, b), c) in dense_shard.sum.iter().zip(&payload_shard.sum).zip(&encoded_shard.sum)
        {
            assert_eq!(a.to_bits(), b.to_bits());
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }

    #[test]
    fn caesar_fold_encoded_matches_fold_payload() {
        use crate::compress::caesar_compress;
        use crate::util::rng::Rng;
        let n = 257;
        let mut rng = Rng::new(0xCAE);
        let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let enc = Payload::CaesarSplit(caesar_compress(&w, 0.4)).encode();
        let mut a = AggregatorShard::new(0, n, vec![0]);
        let mut b = AggregatorShard::new(0, n, vec![0]);
        a.fold_payload(0, &enc.decode(), 1.3);
        b.fold_encoded(0, &enc, 1.3);
        for (x, y) in a.sum.iter().zip(&b.sum) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn payload_fold_enforces_order_too() {
        let mut s = AggregatorShard::new(0, 2, vec![3, 9]);
        s.fold_payload(9, &Payload::Dense(vec![1.0, 2.0]), 1.0);
    }
}

//! Streaming, sharded, *order-exact* hierarchical aggregation.
//!
//! Floating-point addition is not associative, so a parallel sum is only
//! bit-identical to a sequential one if both evaluate the SAME reduction
//! tree. The engine therefore fixes a canonical tree up front, whose
//! shape depends on nothing but the group count — never on worker count
//! or arrival order:
//!
//! 1. participants are sorted by device id and chunked into groups of
//!    `agg_group` (a config constant — never derived from worker count);
//! 2. an [`AggregatorShard`] accumulates one group's weighted partial sum
//!    in sorted order, folding each device's update the moment it is
//!    produced (the update vector is then dropped — at most one update
//!    per worker is ever alive);
//! 3. group partial sums combine pairwise up a **fixed-shape binary
//!    tree**: level 0 is the groups in ascending order, and each level
//!    pairs positions `(2i, 2i+1)` — the lower position is always the
//!    LEFT addend — with a lone trailing node promoted unchanged. The
//!    shape (and therefore every node's value) is a pure function of
//!    `n_groups`, so *any* execution of the tree produces the same bits:
//!    the [`ShardReducer`] executes it streaming (combining the moment
//!    both children of a node exist, buffering at most O(log G) partial
//!    nodes), and [`reduce_shards_parallel`] executes it *climb-merge*
//!    over scoped threads — each worker carries its leaf upward,
//!    rendezvousing with the sibling's carrier at every pair, with NO
//!    barrier between tree levels. Bit-identical by construction —
//!    pinned in tests here and in `engine_parity`.
//!
//! Partial sums are [`ChunkedSum`]s: the model vector chunk-sharded into
//! fixed power-of-two runs (`EngineConfig::agg_chunk`), so no single
//! reduction buffer is model-sized and chunk storage recycles through
//! `util::pool`'s chunk free list. Chunking is bit-transparent — element
//! order and per-element arithmetic are untouched; only the backing
//! storage is split.
//!
//! NOTE (history): through PR 6 the canonical order was a left fold over
//! groups. The fixed tree replaces it as THE canonical order — for
//! `n_groups <= 3` the two are the same association, beyond that this is
//! a last-bit rounding change of exactly the kind the `agg_group` config
//! docs already reserve. Every engine path shares this one reducer, so
//! all cross-path parity pins (worker counts, transports, external
//! rounds) are unchanged.

use std::collections::BTreeMap;
use std::sync::Mutex;

use anyhow::{anyhow, Result};

use crate::compress::quant;
use crate::util::{pool, threadpool};
use crate::wire::{CaesarSlot, EncodedPayload, Payload, PayloadView};

/// An f64 accumulator over `n` elements, stored as fixed-size chunks so
/// no single allocation is model-sized. Logical element `i` lives at
/// `chunks[i >> shift][i & mask]` — the chunk length is a power of two,
/// so sparse folds stay one shift + one mask away from a flat vector.
///
/// Bit-transparent by construction: every operation touches the same
/// elements with the same f64 ops in the same order as its flat-vector
/// equivalent. Chunk storage is leased from `util::pool`'s chunk free
/// list and recycled on drop.
#[derive(Debug)]
pub struct ChunkedSum {
    chunks: Vec<Vec<f64>>,
    /// log2 of the chunk length.
    shift: u32,
    n: usize,
}

impl ChunkedSum {
    /// A zeroed sum over `n` elements in chunks of `chunk_len` (rounded
    /// up to a power of two; `0` means unchunked — one buffer, the
    /// pre-chunking layout).
    pub fn new(n: usize, chunk_len: usize) -> ChunkedSum {
        let chunk = if n == 0 || chunk_len == 0 || chunk_len >= n {
            n.next_power_of_two().max(1)
        } else {
            chunk_len.next_power_of_two()
        };
        let mut chunks = Vec::with_capacity(n.div_ceil(chunk));
        let mut remaining = n;
        while remaining > 0 {
            let len = remaining.min(chunk);
            chunks.push(pool::f64_chunk(len));
            remaining -= len;
        }
        ChunkedSum { chunks, shift: chunk.trailing_zeros(), n }
    }

    /// Logical element count.
    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Largest backing allocation, in elements — the bound the
    /// chunk-sharding acceptance criterion asserts on.
    pub fn max_chunk_len(&self) -> usize {
        self.chunks.iter().map(Vec::len).max().unwrap_or(0)
    }

    /// Sparse accumulate: `self[i] += v`.
    #[inline]
    pub fn add(&mut self, i: usize, v: f64) {
        let mask = (1usize << self.shift) - 1;
        self.chunks[i >> self.shift][i & mask] += v;
    }

    /// Dense accumulate: `self[i] += xs[i]` for all `i`, in ascending
    /// element order — the exact per-element op sequence of the flat
    /// `zip` fold it replaces.
    pub fn zip_add(&mut self, mut xs: impl Iterator<Item = f64>) {
        for c in &mut self.chunks {
            for s in c.iter_mut() {
                *s += xs.next().expect("zip_add iterator shorter than the sum");
            }
        }
        debug_assert!(xs.next().is_none(), "zip_add iterator longer than the sum");
    }

    /// Pairwise tree combine: `self[i] += other[i]`. Consumes `other`,
    /// whose chunks recycle to the pool. Both sides must share the chunk
    /// layout (the engine derives it from one config knob).
    pub fn merge(&mut self, other: ChunkedSum) {
        assert_eq!(self.n, other.n, "merge length mismatch");
        assert_eq!(self.shift, other.shift, "merge chunk-layout mismatch");
        for (a, b) in self.chunks.iter_mut().zip(&other.chunks) {
            for (x, &y) in a.iter_mut().zip(b) {
                *x += y;
            }
        }
    }

    /// Elements in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = f64> + '_ {
        self.chunks.iter().flat_map(|c| c.iter().copied())
    }

    pub fn to_vec(&self) -> Vec<f64> {
        self.iter().collect()
    }
}

impl Drop for ChunkedSum {
    fn drop(&mut self) {
        for c in self.chunks.drain(..) {
            pool::recycle_f64_chunk(c);
        }
    }
}

/// Weighted f64 partial sum over one group of devices. Devices must be
/// folded in the (sorted) order fixed at construction.
#[derive(Debug)]
pub struct AggregatorShard {
    group: usize,
    sum: ChunkedSum,
    /// Device ids this shard expects, ascending.
    expect: Vec<usize>,
    /// Position of the next expected device.
    cursor: usize,
    /// Devices actually folded (dropouts are skipped).
    folded: usize,
}

impl AggregatorShard {
    /// Unchunked shard (one model-sized buffer) — see
    /// [`AggregatorShard::with_chunk`] for the sharded layout.
    pub fn new(group: usize, n_params: usize, expect: Vec<usize>) -> AggregatorShard {
        Self::with_chunk(group, n_params, 0, expect)
    }

    /// Shard whose partial sum is chunk-sharded into `chunk_len`-element
    /// runs (`0` = unchunked). Chunking is bit-transparent; every shard
    /// and reducer of a round must share one `chunk_len`.
    pub fn with_chunk(
        group: usize,
        n_params: usize,
        chunk_len: usize,
        expect: Vec<usize>,
    ) -> AggregatorShard {
        debug_assert!(expect.windows(2).all(|w| w[0] < w[1]), "expect must be sorted");
        AggregatorShard {
            group,
            sum: ChunkedSum::new(n_params, chunk_len),
            expect,
            cursor: 0,
            folded: 0,
        }
    }

    pub fn group(&self) -> usize {
        self.group
    }

    pub fn folded(&self) -> usize {
        self.folded
    }

    /// Order check shared by every fold/skip entry point: `device` must be
    /// the next expected id in the shard's canonical order.
    fn advance(&mut self, device: usize, what: &str) {
        assert_eq!(
            self.expect.get(self.cursor).copied(),
            Some(device),
            "shard {}: {what} {device} out of order",
            self.group
        );
        self.cursor += 1;
    }

    /// Fold one device's dense update with aggregation weight `weight`.
    /// Must be called in the shard's expected device order.
    pub fn fold(&mut self, device: usize, update: &[f32], weight: f64) {
        self.advance(device, "device");
        assert_eq!(update.len(), self.sum.len(), "update length mismatch");
        self.sum.zip_add(update.iter().map(|&x| (x as f64) * weight));
        self.folded += 1;
    }

    /// Fold one device's decoded wire payload without densifying it first.
    ///
    /// Top-K folds only its kept entries — O(kept) work and no O(n)
    /// scratch vector — which is bit-identical to the dense fold because
    /// every skipped entry is an exact `0.0` (adding `0.0 * weight` to an
    /// f64 partial sum is a no-op, so the canonical reduction tree is
    /// unchanged). Quant dequantizes streaming with no intermediate
    /// allocation; Dense matches [`AggregatorShard::fold`] exactly.
    pub fn fold_payload(&mut self, device: usize, payload: &Payload, weight: f64) {
        self.advance(device, "device");
        assert_eq!(payload.n(), self.sum.len(), "payload length mismatch");
        match payload {
            Payload::Dense(values) => {
                self.sum.zip_add(values.iter().map(|&x| (x as f64) * weight));
            }
            Payload::TopK { indices, values, .. } => {
                for (&i, &v) in indices.iter().zip(values) {
                    self.sum.add(i as usize, (v as f64) * weight);
                }
            }
            Payload::Quant { levels, norm, codes, .. } => {
                self.sum.zip_add(
                    codes
                        .iter()
                        .map(|&c| (quant::dequantize_code(c, *levels, *norm) as f64) * weight),
                );
            }
            // downloads-only codec; accepted for completeness via the
            // prior-free densification
            Payload::CaesarSplit(cm) => {
                self.sum
                    .zip_add(cm.naive_reconstruction().iter().map(|&x| (x as f64) * weight));
            }
        }
        self.folded += 1;
    }

    /// Fold one device's *serialized* upload straight off its bytes —
    /// [`AggregatorShard::fold_payload`] without ever materializing the
    /// decoded payload. Elements stream through a borrowed
    /// [`PayloadView`] in the same order the eager decode would produce
    /// them, so the f64 additions (and therefore the canonical reduction
    /// tree) are bit-identical; the per-device index/value vectors the
    /// decode used to allocate simply never exist.
    pub fn fold_encoded(&mut self, device: usize, enc: &EncodedPayload, weight: f64) {
        self.advance(device, "device");
        assert_eq!(enc.spec.n(), self.sum.len(), "payload length mismatch");
        let sum = &mut self.sum;
        match enc.view() {
            PayloadView::Dense(v) => v.for_each(|i, x| sum.add(i, (x as f64) * weight)),
            PayloadView::TopK(v) => v.for_each(|i, x| sum.add(i, (x as f64) * weight)),
            PayloadView::Quant(v) => v.for_each(|i, x| sum.add(i, (x as f64) * weight)),
            // downloads-only codec; accepted for completeness — streams
            // the same prior-free reconstruction fold_payload densifies
            PayloadView::CaesarSplit(v) => {
                let (avg_abs, _) = v.scalars();
                v.for_each(|i, slot| {
                    let x = match slot {
                        CaesarSlot::Kept(val) => val,
                        CaesarSlot::Sign(sign) => sign as f32 * avg_abs,
                    };
                    sum.add(i, (x as f64) * weight);
                });
            }
        }
        self.folded += 1;
    }

    /// Skip the next expected device (it dropped out mid-round).
    pub fn mark_dropped(&mut self, device: usize) {
        self.advance(device, "dropout");
    }

    /// True once every expected device was folded or dropped.
    pub fn complete(&self) -> bool {
        self.cursor == self.expect.len()
    }
}

/// Width of tree level `level` (level 0 = the `n_groups` leaves).
fn level_width(n_groups: usize, level: u32) -> usize {
    // ceil(n_groups / 2^level); level never exceeds ~log2(n_groups) + 1
    // because the walk stops at width 1
    (n_groups + ((1usize << level) - 1)) >> level
}

/// Executes the canonical fixed-shape reduction tree *streaming*: shards
/// arrive in any order, each combine fires the moment both children of a
/// node exist, and at most O(log n_groups) partial nodes are buffered.
/// The tree shape — and therefore the output bits — depends only on
/// `n_groups`; [`reduce_shards_parallel`] executes the identical tree
/// with its pairwise combines fanned over threads.
#[derive(Debug)]
pub struct ShardReducer {
    n_params: usize,
    n_groups: usize,
    chunk_len: usize,
    /// Leaf groups accepted so far (duplicate/range detection).
    seen: Vec<bool>,
    n_seen: usize,
    /// Partial tree nodes waiting for their sibling, keyed by
    /// `(level, position)`.
    pending: BTreeMap<(u32, usize), ChunkedSum>,
    folded_devices: usize,
    /// High-water mark of simultaneously buffered nodes (diagnostics;
    /// O(log n_groups) by the streaming invariant).
    peak_pending: usize,
}

impl ShardReducer {
    /// Unchunked reducer — see [`ShardReducer::with_chunk`].
    pub fn new(n_params: usize, n_groups: usize) -> ShardReducer {
        Self::with_chunk(n_params, n_groups, 0)
    }

    /// Reducer over chunk-sharded partial sums; `chunk_len` must match
    /// the shards' (`0` = unchunked).
    pub fn with_chunk(n_params: usize, n_groups: usize, chunk_len: usize) -> ShardReducer {
        ShardReducer {
            n_params,
            n_groups,
            chunk_len,
            seen: vec![false; n_groups],
            n_seen: 0,
            pending: BTreeMap::new(),
            folded_devices: 0,
            peak_pending: 0,
        }
    }

    /// High-water mark of buffered partial nodes so far.
    pub fn peak_pending(&self) -> usize {
        self.peak_pending
    }

    /// Accept a finished shard: validate it, then bubble it up the fixed
    /// tree, combining with every sibling already present. Invariant:
    /// `pending` never holds two nodes that could combine — the arriving
    /// node's bubble path performs every combine its arrival enables —
    /// so once all leaves arrived, `pending` is exactly the root.
    pub fn push(&mut self, shard: AggregatorShard) -> Result<()> {
        if !shard.complete() {
            return Err(anyhow!("group {} shard pushed incomplete", shard.group()));
        }
        if shard.group() >= self.n_groups {
            return Err(anyhow!("group {} out of range ({})", shard.group(), self.n_groups));
        }
        if self.seen[shard.group()] {
            return Err(anyhow!("group {} reduced twice", shard.group()));
        }
        self.seen[shard.group()] = true;
        self.n_seen += 1;
        self.folded_devices += shard.folded;
        let AggregatorShard { group, sum, .. } = shard;

        let mut level = 0u32;
        let mut pos = group;
        let mut node = sum;
        loop {
            let width = level_width(self.n_groups, level);
            if width <= 1 {
                // node contains every leaf: it is the root
                debug_assert_eq!(pos, 0);
                self.pending.insert((level, 0), node);
                break;
            }
            let sib = pos ^ 1;
            if sib >= width {
                // lone trailing node: promote unchanged
                level += 1;
                pos >>= 1;
                continue;
            }
            match self.pending.remove(&(level, sib)) {
                Some(other) => {
                    // the LOWER position is always the left addend
                    let (mut left, right) =
                        if pos < sib { (node, other) } else { (other, node) };
                    left.merge(right);
                    node = left;
                    level += 1;
                    pos >>= 1;
                }
                None => {
                    self.pending.insert((level, pos), node);
                    break;
                }
            }
        }
        self.peak_pending = self.peak_pending.max(self.pending.len());
        Ok(())
    }

    /// Finish: every group must have reduced. Returns the canonical sum
    /// and the number of device updates inside it.
    pub fn finish(mut self) -> Result<(ChunkedSum, usize)> {
        if self.n_seen != self.n_groups {
            return Err(anyhow!(
                "aggregation incomplete: {}/{} groups reduced",
                self.n_seen,
                self.n_groups
            ));
        }
        if self.n_groups == 0 {
            return Ok((ChunkedSum::new(self.n_params, self.chunk_len), 0));
        }
        debug_assert_eq!(self.pending.len(), 1, "streaming tree left extra partial nodes");
        let (_, root) = self
            .pending
            .pop_first()
            .ok_or_else(|| anyhow!("reduction tree lost its root"))?;
        Ok((root, self.folded_devices))
    }
}

/// Execute the canonical reduction tree **climb-merge** over `n_workers`
/// scoped threads. Exactly the tree [`ShardReducer`] evaluates streaming
/// — level `l` pairs positions `(2i, 2i+1)`, lower position on the left,
/// lone trailing node promoted — but with NO barrier between levels:
/// each worker takes one leaf and climbs, and at every pair the two
/// carriers rendezvous through a take-once slot. The first to arrive
/// deposits its node and ends its climb; the second merges (lower
/// position always the left addend) and carries the parent upward. A
/// fast subtree therefore reaches its upper merges while slow subtrees
/// are still folding leaves — wall-clock is the deepest *path*, not the
/// sum of slowest-per-level. The race decides only WHICH thread performs
/// a merge, never the operand order, so the result is bit-identical to
/// the streaming reduction at ANY worker count (`n_workers <= 1` climbs
/// inline: leaf `g` deposits, leaf `g+1` merges, exactly the ascending
/// streaming order). Validation matches [`ShardReducer::push`]/`finish`:
/// shards must be complete and cover every group exactly once.
pub fn reduce_shards_parallel(
    n_params: usize,
    n_groups: usize,
    chunk_len: usize,
    mut shards: Vec<AggregatorShard>,
    n_workers: usize,
) -> Result<(ChunkedSum, usize)> {
    if shards.len() != n_groups {
        return Err(anyhow!(
            "aggregation incomplete: {}/{} groups reduced",
            shards.len(),
            n_groups
        ));
    }
    if n_groups == 0 {
        return Ok((ChunkedSum::new(n_params, chunk_len), 0));
    }
    shards.sort_by_key(AggregatorShard::group);
    let mut folded_devices = 0usize;
    let mut leaves: Vec<Mutex<Option<ChunkedSum>>> = Vec::with_capacity(n_groups);
    for (g, shard) in shards.into_iter().enumerate() {
        if !shard.complete() {
            return Err(anyhow!("group {} shard pushed incomplete", shard.group()));
        }
        if shard.group() >= n_groups {
            return Err(anyhow!("group {} out of range ({n_groups})", shard.group()));
        }
        if shard.group() != g {
            return Err(anyhow!("group {} reduced twice", shard.group()));
        }
        folded_devices += shard.folded;
        let AggregatorShard { sum, .. } = shard;
        leaves.push(Mutex::new(Some(sum)));
    }
    // one rendezvous slot per (level, pair); a lone trailing node never
    // touches a slot — it promotes unchanged, same as the streaming tree
    let mut slots: Vec<Vec<Mutex<Option<(usize, ChunkedSum)>>>> = Vec::new();
    for level in 0.. {
        let width = level_width(n_groups, level);
        if width <= 1 {
            break;
        }
        slots.push((0..width / 2).map(|_| Mutex::new(None)).collect());
    }
    let slots = &slots;
    let leaves = &leaves;
    let climbs = threadpool::scope_map(n_groups, n_workers, move |g| {
        let mut node = leaves[g].lock().unwrap().take().expect("leaf climbed twice");
        let mut pos = g;
        for (level, pairs) in slots.iter().enumerate() {
            let width = level_width(n_groups, level as u32);
            let sib = pos ^ 1;
            if sib >= width {
                // lone trailing node: promote unchanged
                pos >>= 1;
                continue;
            }
            let deposited = slots_take_or_deposit(&pairs[pos >> 1], pos, node);
            match deposited {
                None => return None, // sibling's carrier finishes the pair
                Some((other_pos, other, mine)) => {
                    // the LOWER position is always the left addend
                    let (mut left, right) =
                        if pos < other_pos { (mine, other) } else { (other, mine) };
                    left.merge(right);
                    node = left;
                    pos >>= 1;
                }
            }
        }
        Some(node)
    });
    let mut roots: Vec<ChunkedSum> = climbs.into_iter().flatten().collect();
    if roots.len() != 1 {
        return Err(anyhow!("reduction tree lost its root ({} climbs finished)", roots.len()));
    }
    Ok((roots.pop().expect("checked above"), folded_devices))
}

/// The pair rendezvous: atomically either deposit `(pos, node)` into an
/// empty slot (returning `None` — this climb ends) or take the sibling's
/// deposit out of a full one (returning it plus `node` back — the caller
/// merges and climbs on). The lock is held only for the swap, never for
/// the merge.
fn slots_take_or_deposit(
    slot: &Mutex<Option<(usize, ChunkedSum)>>,
    pos: usize,
    node: ChunkedSum,
) -> Option<(usize, ChunkedSum, ChunkedSum)> {
    let mut guard = slot.lock().unwrap();
    match guard.take() {
        None => {
            *guard = Some((pos, node));
            None
        }
        Some((other_pos, other)) => {
            debug_assert_eq!(other_pos ^ 1, pos, "rendezvous between non-siblings");
            Some((other_pos, other, node))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shard_of(group: usize, devices: &[usize], vals: &[f32]) -> AggregatorShard {
        shard_of_chunked(group, devices, vals, 0)
    }

    fn shard_of_chunked(
        group: usize,
        devices: &[usize],
        vals: &[f32],
        chunk: usize,
    ) -> AggregatorShard {
        let mut s = AggregatorShard::with_chunk(group, vals.len(), chunk, devices.to_vec());
        for &d in devices {
            let update: Vec<f32> = vals.iter().map(|&v| v + d as f32).collect();
            s.fold(d, &update, 1.0);
        }
        s
    }

    #[test]
    fn chunked_sum_is_bit_transparent() {
        use crate::util::rng::Rng;
        let n = 137; // prime: chunks never line up with the length
        let mut rng = Rng::new(0xC4 + 7);
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut flat = vec![0.0f64; n];
        for (i, (&x, &y)) in xs.iter().zip(&ys).enumerate() {
            flat[i] += x;
            flat[i] += y * 0.37;
        }
        for chunk in [0, 1, 4, 16, 64, 200] {
            let mut cs = ChunkedSum::new(n, chunk);
            assert_eq!(cs.len(), n);
            for (i, &x) in xs.iter().enumerate() {
                cs.add(i, x);
            }
            cs.zip_add(ys.iter().map(|&y| y * 0.37));
            let got = cs.to_vec();
            for (a, b) in got.iter().zip(&flat) {
                assert_eq!(a.to_bits(), b.to_bits(), "chunk={chunk}");
            }
            if chunk != 0 && chunk < n {
                assert!(
                    cs.max_chunk_len() <= chunk.next_power_of_two(),
                    "chunk={chunk} max={}",
                    cs.max_chunk_len()
                );
            }
        }
        // merge is the same elementwise add
        let mut a = ChunkedSum::new(n, 16);
        a.zip_add(xs.iter().copied());
        let mut b = ChunkedSum::new(n, 16);
        b.zip_add(ys.iter().map(|&y| y * 0.37));
        a.merge(b);
        for (g, w) in a.iter().zip(&flat) {
            assert_eq!(g.to_bits(), w.to_bits());
        }
        // empty sums are fine
        let e = ChunkedSum::new(0, 8);
        assert!(e.is_empty() && e.to_vec().is_empty());
    }

    #[test]
    fn out_of_order_shards_reduce_to_in_order_total() {
        let mk = |order: &[usize]| {
            let mut r = ShardReducer::new(3, 3);
            for &g in order {
                let devices = [g * 2, g * 2 + 1];
                r.push(shard_of(g, &devices, &[0.5, -1.25, 3.0])).unwrap();
            }
            let (total, n) = r.finish().unwrap();
            (total.to_vec(), n)
        };
        let (a, na) = mk(&[0, 1, 2]);
        let (b, nb) = mk(&[2, 0, 1]);
        assert_eq!(na, 6);
        assert_eq!(nb, 6);
        // bit-exact equality, not approximate
        assert_eq!(a, b);
    }

    #[test]
    fn tree_bits_are_pinned_by_group_count_alone() {
        // every arrival order, every chunking, and the parallel executor
        // at several worker counts must agree bit-for-bit
        let vals = [0.1f32, -2.7, 3.14159, 1e-6, -4.2e3];
        for n_groups in [1usize, 2, 3, 4, 5, 7, 8] {
            let build = |chunk: usize| -> Vec<AggregatorShard> {
                (0..n_groups)
                    .map(|g| shard_of_chunked(g, &[g * 3, g * 3 + 2], &vals, chunk))
                    .collect()
            };
            let stream = |order: &[usize], chunk: usize| {
                let mut shards: Vec<Option<AggregatorShard>> =
                    build(chunk).into_iter().map(Some).collect();
                let mut r = ShardReducer::with_chunk(vals.len(), n_groups, chunk);
                for &g in order {
                    r.push(shards[g].take().unwrap()).unwrap();
                }
                r.finish().unwrap().0.to_vec()
            };
            let asc: Vec<usize> = (0..n_groups).collect();
            let desc: Vec<usize> = (0..n_groups).rev().collect();
            let scrambled: Vec<usize> =
                (0..n_groups).map(|i| (i * 5 + 3) % n_groups).collect();
            let want = stream(&asc, 0);
            assert_eq!(stream(&desc, 0), want, "G={n_groups} desc");
            if scrambled.iter().collect::<std::collections::BTreeSet<_>>().len() == n_groups {
                assert_eq!(stream(&scrambled, 0), want, "G={n_groups} scrambled");
            }
            // chunking must not move a single bit
            let chunked = stream(&asc, 2);
            assert_eq!(
                chunked.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "G={n_groups} chunked"
            );
            // parallel pairwise execution of the same tree
            for workers in [1usize, 2, 3, 8] {
                for chunk in [0usize, 2] {
                    let (root, folded) = reduce_shards_parallel(
                        vals.len(),
                        n_groups,
                        chunk,
                        build(chunk),
                        workers,
                    )
                    .unwrap();
                    assert_eq!(folded, n_groups * 2);
                    assert_eq!(
                        root.to_vec(),
                        want,
                        "G={n_groups} workers={workers} chunk={chunk}"
                    );
                }
            }
        }
    }

    #[test]
    fn climb_merge_matches_streaming_on_deep_ragged_trees() {
        // 33 groups: a 6-level tree whose lone trailing node promotes
        // through every level — the shape where a climb-ordering bug
        // would first show. Race it at several worker counts against the
        // streaming reducer's bits.
        let n_groups = 33;
        let vals = [1.0e-3f32, -0.77, 42.5];
        let build = || -> Vec<AggregatorShard> {
            (0..n_groups).map(|g| shard_of(g, &[g], &vals)).collect()
        };
        let mut r = ShardReducer::new(vals.len(), n_groups);
        for s in build() {
            r.push(s).unwrap();
        }
        let (want, want_folded) = r.finish().unwrap();
        let want = want.to_vec();
        for workers in [1usize, 2, 4, 8] {
            let (root, folded) =
                reduce_shards_parallel(vals.len(), n_groups, 0, build(), workers).unwrap();
            assert_eq!(folded, want_folded, "workers={workers}");
            assert_eq!(
                root.to_vec().iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                want.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "workers={workers}"
            );
        }
    }

    #[test]
    fn small_trees_match_the_historical_left_fold() {
        // for n_groups <= 3 the fixed tree IS the old left fold:
        // ((g0+g1)+g2) — pin that the restructure kept those bits
        for n_groups in [1usize, 2, 3] {
            let shards: Vec<AggregatorShard> = (0..n_groups)
                .map(|g| shard_of(g, &[g], &[0.3f32, -7.25, 1e-3]))
                .collect();
            let mut fold = vec![0.0f64; 3];
            for s in &shards {
                for (t, x) in fold.iter_mut().zip(s.sum.iter()) {
                    *t += x;
                }
            }
            let mut r = ShardReducer::new(3, n_groups);
            for s in shards {
                r.push(s).unwrap();
            }
            let got = r.finish().unwrap().0.to_vec();
            assert_eq!(
                got.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                fold.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn streaming_buffers_at_most_log_groups() {
        let n_groups = 64;
        // worst friendly case: ascending arrival — pending tracks the
        // binary-carry pattern, peaking at popcount(63) = 6
        let mut r = ShardReducer::new(1, n_groups);
        for g in 0..n_groups {
            r.push(shard_of(g, &[g], &[1.0])).unwrap();
        }
        assert!(r.peak_pending() <= 7, "peak {}", r.peak_pending());
        let (total, folded) = r.finish().unwrap();
        assert_eq!(folded, n_groups);
        // 64 shards of (1.0 + g): sum = 64 + sum(0..64)
        assert_eq!(total.to_vec(), vec![64.0 + (63.0 * 64.0) / 2.0]);
    }

    #[test]
    fn shard_enforces_fold_order() {
        let mut s = AggregatorShard::new(0, 2, vec![3, 9]);
        s.fold(3, &[1.0, 1.0], 1.0);
        s.fold(9, &[1.0, 1.0], 2.0);
        assert!(s.complete());
        assert_eq!(s.folded(), 2);
        assert_eq!(s.sum.to_vec(), vec![3.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn shard_panics_on_wrong_device() {
        let mut s = AggregatorShard::new(0, 1, vec![3, 9]);
        s.fold(9, &[1.0], 1.0);
    }

    #[test]
    fn dropouts_are_skipped_not_summed() {
        let mut s = AggregatorShard::new(0, 2, vec![1, 2, 5]);
        s.fold(1, &[1.0, 2.0], 1.0);
        s.mark_dropped(2);
        s.fold(5, &[10.0, 20.0], 1.0);
        assert!(s.complete());
        assert_eq!(s.folded(), 2);
        assert_eq!(s.sum.to_vec(), vec![11.0, 22.0]);
    }

    #[test]
    fn reducer_rejects_incomplete_and_duplicate() {
        let mut r = ShardReducer::new(1, 2);
        let s = AggregatorShard::new(0, 1, vec![0, 1]); // incomplete
        assert!(r.push(s).is_err());
        r.push(shard_of(0, &[0], &[1.0])).unwrap();
        assert!(r.push(shard_of(0, &[0], &[1.0])).is_err()); // duplicate
        let r2 = ShardReducer::new(1, 2);
        assert!(r2.finish().is_err()); // nothing reduced

        // the parallel executor enforces the same contract
        assert!(reduce_shards_parallel(1, 2, 0, vec![shard_of(0, &[0], &[1.0])], 2).is_err());
        assert!(reduce_shards_parallel(
            1,
            2,
            0,
            vec![shard_of(0, &[0], &[1.0]), shard_of(0, &[0], &[1.0])],
            2
        )
        .is_err());
        assert!(reduce_shards_parallel(
            1,
            1,
            0,
            vec![AggregatorShard::new(0, 1, vec![0, 1])],
            2
        )
        .is_err());
    }

    #[test]
    fn weight_scales_contributions() {
        let mut s = AggregatorShard::new(0, 1, vec![0]);
        s.fold(0, &[2.0], 0.25);
        assert_eq!(s.sum.to_vec(), vec![0.5]);
    }

    #[test]
    fn sparse_payload_fold_is_bit_identical_to_dense_fold() {
        use crate::compress::{quant, topk};
        use crate::util::rng::Rng;
        let n = 512;
        let mut rng = Rng::new(0xF01D);
        let grads: Vec<Vec<f32>> = (0..6)
            .map(|_| (0..n).map(|_| rng.normal() as f32).collect())
            .collect();
        let expect: Vec<usize> = (0..6).collect();
        let mut dense_shard = AggregatorShard::new(0, n, expect.clone());
        // the chunked payload/encoded folds must match the flat dense fold
        let mut payload_shard = AggregatorShard::with_chunk(0, n, 64, expect.clone());
        let mut encoded_shard = AggregatorShard::with_chunk(0, n, 64, expect);
        for (d, g) in grads.iter().enumerate() {
            // alternate codecs to cover every fold_payload arm
            let payload = match d % 3 {
                0 => topk::topk_encode(g, 0.8).0,
                1 => Payload::Dense(g.clone()),
                _ => {
                    let levels = quant::levels_for_bits(4);
                    let noise: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
                    let (norm, codes) = quant::quantize_codes(g, levels, Some(&noise));
                    Payload::Quant { bits: 4, levels, norm, codes }
                }
            };
            // the wire really is traversed: encode → bytes → decode
            let enc = payload.encode();
            let decoded = enc.decode();
            dense_shard.fold(d, &decoded.to_dense(), 0.7);
            payload_shard.fold_payload(d, &decoded, 0.7);
            encoded_shard.fold_encoded(d, &enc, 0.7);
        }
        assert!(dense_shard.complete() && payload_shard.complete() && encoded_shard.complete());
        for ((a, b), c) in
            dense_shard.sum.iter().zip(payload_shard.sum.iter()).zip(encoded_shard.sum.iter())
        {
            assert_eq!(a.to_bits(), b.to_bits());
            assert_eq!(a.to_bits(), c.to_bits());
        }
    }

    #[test]
    fn caesar_fold_encoded_matches_fold_payload() {
        use crate::compress::caesar_compress;
        use crate::util::rng::Rng;
        let n = 257;
        let mut rng = Rng::new(0xCAE);
        let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let enc = Payload::CaesarSplit(caesar_compress(&w, 0.4)).encode();
        let mut a = AggregatorShard::new(0, n, vec![0]);
        let mut b = AggregatorShard::with_chunk(0, n, 32, vec![0]);
        a.fold_payload(0, &enc.decode(), 1.3);
        b.fold_encoded(0, &enc, 1.3);
        for (x, y) in a.sum.iter().zip(b.sum.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    #[should_panic(expected = "out of order")]
    fn payload_fold_enforces_order_too() {
        let mut s = AggregatorShard::new(0, 2, vec![3, 9]);
        s.fold_payload(9, &Payload::Dense(vec![1.0, 2.0]), 1.0);
    }
}

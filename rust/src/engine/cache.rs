//! PS-side download-encode cache with a **cross-round generation key**.
//!
//! The staleness-greedy of §4.1 clusters participants into a handful of
//! discrete download ratios (`cfg.clusters`, default 4), and baselines
//! like FedAvg serve the identical `Full` payload to everyone — yet the
//! seed engine ran `CodecEngine::encode_download` once **per
//! participant**, re-compressing and re-serializing the same global model
//! for every device that shared a codec. This cache deduplicates by the
//! *effective* codec (post [`effective_download`] resolution, so a
//! CaesarSplit download degraded to `Full` for a local-less receiver
//! shares `Full`'s entry): O(distinct codecs) encodes per round instead
//! of O(participants), with the one `EncodedPayload` shared across
//! devices via `Arc` — every receiver sees byte-identical wire bytes, so
//! engine parity is untouched.
//!
//! **Generation keying.** The cache now lives with the [`super::Engine`]
//! for the whole run, not one round: the logical key is
//! `(model_version, effective codec)`. [`DownloadCache::begin_round`]
//! compares the incoming model version with the entries' generation — a
//! new version invalidates everything (the bytes encode a model that no
//! longer exists), while an unchanged version *carries* the entries over,
//! so multi-round serving reuses encodes when the global model did not
//! move (rounds whose participants all dropped out, evaluation-style
//! re-serves, stragglers re-fetching). Hits on carried entries are
//! counted separately (`cross_round_hits`) and surfaced through
//! `EngineStats::cache_cross_round_hits`.
//!
//! **RNG discipline.** Only RNG-free codecs are cacheable (`Full`,
//! `TopK`, `CaesarSplit` — pure functions of the global model). `Quant`
//! draws its stochastic-rounding noise from the *device* stream
//! (`compress::quant`'s contract), so its payload is device-specific: it
//! bypasses the cache and encodes per device, exactly as before. For
//! cacheable codecs the device stream is never touched — neither on a
//! miss (the encode is fed a throwaway RNG; these codecs draw nothing)
//! nor on a hit — so per-device draw sequences are identical to the
//! uncached engine and bit-exact parity holds at every worker count.
//!
//! **Concurrency.** One cache is shared by all workers. Misses encode
//! *while holding the lock*: the first device to need a codec pays the
//! encode, racing devices block and then share the `Arc` — exactly one
//! encode per distinct codec per generation, which keeps the
//! `encode_calls` metric deterministic across worker counts (a benched
//! acceptance number, not just a nicety). Hits are a lock + `Arc::clone`.
//! `begin_round` takes `&mut self`: generations only turn over between
//! rounds, on the coordinator thread.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::Result;

use crate::coordinator::codec::effective_download;
use crate::coordinator::CodecEngine;
use crate::schemes::DownloadCodec;
use crate::util::rng::Rng;
use crate::wire::EncodedPayload;

/// Hashable identity of a cacheable (RNG-free) download codec. Ratios are
/// keyed by their exact f64 bit pattern — the staleness clustering emits
/// identical f64s for devices in the same cluster, which is precisely the
/// sharing this cache exploits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum CacheKey {
    Full,
    CaesarSplit(u64),
    TopK(u64),
}

fn cache_key(codec: DownloadCodec) -> Option<CacheKey> {
    match codec {
        DownloadCodec::Full => Some(CacheKey::Full),
        DownloadCodec::CaesarSplit { ratio } => Some(CacheKey::CaesarSplit(ratio.to_bits())),
        DownloadCodec::TopK { ratio } => Some(CacheKey::TopK(ratio.to_bits())),
        // device-specific stochastic noise: never shared
        DownloadCodec::Quant { .. } => None,
    }
}

struct Entry {
    enc: Arc<EncodedPayload>,
    /// True once the entry has survived a round boundary within its
    /// generation — hits on it are cross-round reuse.
    carried: bool,
}

/// Shares one encoded download per distinct codec per model generation.
pub struct DownloadCache {
    entries: Mutex<HashMap<CacheKey, Entry>>,
    /// Model version the current entries encode (None before the first
    /// `begin_round`; pre-round standalone use keys a single implicit
    /// generation).
    generation: Option<u64>,
    requests: AtomicUsize,
    encodes: AtomicUsize,
    cross_round_hits: AtomicUsize,
}

impl Default for DownloadCache {
    fn default() -> Self {
        Self::new()
    }
}

impl DownloadCache {
    pub fn new() -> DownloadCache {
        DownloadCache {
            entries: Mutex::new(HashMap::new()),
            generation: None,
            requests: AtomicUsize::new(0),
            encodes: AtomicUsize::new(0),
            cross_round_hits: AtomicUsize::new(0),
        }
    }

    /// Turn the generation over for a round serving `model_version`: a
    /// changed version invalidates every entry, an unchanged one carries
    /// them across the round boundary (subsequent hits count as
    /// cross-round reuse). Counters are cumulative and never reset.
    pub fn begin_round(&mut self, model_version: u64) {
        // The cache is run-lifetime now: a panic under the lock (an encode
        // dying mid-miss on a worker) must not kill every later round. The
        // map itself is coherent on that path — inserts happen only after
        // a successful encode — but start the generation clean anyway.
        let poisoned = self.entries.is_poisoned();
        let entries = self.entries.get_mut().unwrap_or_else(PoisonError::into_inner);
        if poisoned || self.generation != Some(model_version) {
            entries.clear();
            self.generation = Some(model_version);
        } else {
            for e in entries.values_mut() {
                e.carried = true;
            }
        }
    }

    /// The serialized download for `codec`, encoding at most once per
    /// distinct cacheable codec per generation. `codec` must already be
    /// the *effective* codec ([`effective_download`]); a debug assertion
    /// guards the `has_local` contract. `rng` is the device stream —
    /// consumed only by uncacheable codecs (Quant), untouched otherwise.
    pub fn get_or_encode(
        &self,
        engine: &CodecEngine,
        codec: DownloadCodec,
        w: &[f32],
        has_local: bool,
        rng: &mut Rng,
    ) -> Result<Arc<EncodedPayload>> {
        debug_assert_eq!(
            effective_download(codec, has_local),
            codec,
            "get_or_encode requires the effective codec"
        );
        self.requests.fetch_add(1, Ordering::Relaxed);
        let Some(key) = cache_key(codec) else {
            self.encodes.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::new(engine.encode_download(codec, w, rng)?));
        };
        // survive a poisoned lock (another worker's encode panicked): the
        // entries present are all post-successful-encode, so keep serving
        let mut entries = self.entries.lock().unwrap_or_else(PoisonError::into_inner);
        if let Some(hit) = entries.get(&key) {
            if hit.carried {
                self.cross_round_hits.fetch_add(1, Ordering::Relaxed);
            }
            return Ok(Arc::clone(&hit.enc));
        }
        self.encodes.fetch_add(1, Ordering::Relaxed);
        // cacheable codecs are RNG-free by the module contract: feed a
        // throwaway stream so hit/miss can never diverge device draws
        let enc = Arc::new(engine.encode_download(codec, w, &mut Rng::new(0))?);
        entries.insert(key, Entry { enc: Arc::clone(&enc), carried: false });
        Ok(enc)
    }

    /// Downloads served so far (cache hits + encodes), cumulative over
    /// the cache's lifetime.
    pub fn requests(&self) -> usize {
        self.requests.load(Ordering::Relaxed)
    }

    /// Actual `encode_download` executions (misses + uncacheable codecs),
    /// cumulative over the cache's lifetime.
    pub fn encodes(&self) -> usize {
        self.encodes.load(Ordering::Relaxed)
    }

    /// Hits served from an entry carried across a round boundary
    /// (unchanged model version), cumulative.
    pub fn cross_round_hits(&self) -> usize {
        self.cross_round_hits.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn shared_codec_encodes_once_and_shares_the_allocation() {
        let w = randn(512, 1);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        let codec = DownloadCodec::CaesarSplit { ratio: 0.4 };
        let mut rng = Rng::new(9);
        let a = cache.get_or_encode(&e, codec, &w, true, &mut rng).unwrap();
        let b = cache.get_or_encode(&e, codec, &w, true, &mut rng).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "devices sharing a codec must share bytes");
        assert_eq!(cache.requests(), 2);
        assert_eq!(cache.encodes(), 1);
        // same-round hits are NOT cross-round reuse
        assert_eq!(cache.cross_round_hits(), 0);
        // byte-identical by construction, still worth pinning
        assert_eq!(a.bytes, b.bytes);
    }

    #[test]
    fn distinct_ratios_are_distinct_entries() {
        let w = randn(256, 2);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        let mut rng = Rng::new(3);
        for &r in &[0.2, 0.4, 0.2] {
            cache
                .get_or_encode(&e, DownloadCodec::CaesarSplit { ratio: r }, &w, true, &mut rng)
                .unwrap();
        }
        cache.get_or_encode(&e, DownloadCodec::Full, &w, false, &mut rng).unwrap();
        assert_eq!(cache.requests(), 4);
        assert_eq!(cache.encodes(), 3, "0.2 / 0.4 / Full");
    }

    #[test]
    fn unchanged_model_version_carries_entries_across_rounds() {
        let w = randn(300, 7);
        let e = CodecEngine::native();
        let mut cache = DownloadCache::new();
        cache.begin_round(5);
        let a = cache
            .get_or_encode(&e, DownloadCodec::Full, &w, true, &mut Rng::new(1))
            .unwrap();
        // next round, same model version: the entry survives and the hit
        // is a cross-round hit on the very same Arc
        cache.begin_round(5);
        let b = cache
            .get_or_encode(&e, DownloadCodec::Full, &w, true, &mut Rng::new(2))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "carried entry must be the same allocation");
        assert_eq!(cache.encodes(), 1);
        assert_eq!(cache.cross_round_hits(), 1);
        // a second hit in the same later round also counts (the entry
        // stays carried for the rest of the generation)
        cache
            .get_or_encode(&e, DownloadCodec::Full, &w, true, &mut Rng::new(3))
            .unwrap();
        assert_eq!(cache.cross_round_hits(), 2);
    }

    #[test]
    fn model_version_change_invalidates_everything() {
        let w0 = randn(300, 8);
        let e = CodecEngine::native();
        let mut cache = DownloadCache::new();
        cache.begin_round(1);
        cache
            .get_or_encode(&e, DownloadCodec::Full, &w0, true, &mut Rng::new(1))
            .unwrap();
        // model moved: same codec must RE-encode the new model
        let w1 = randn(300, 9);
        cache.begin_round(2);
        let b = cache
            .get_or_encode(&e, DownloadCodec::Full, &w1, true, &mut Rng::new(2))
            .unwrap();
        assert_eq!(cache.encodes(), 2, "new generation re-encodes");
        assert_eq!(cache.cross_round_hits(), 0);
        // and the served bytes are the NEW model's
        let direct = e.encode_download(DownloadCodec::Full, &w1, &mut Rng::new(0)).unwrap();
        assert_eq!(b.bytes, direct.bytes);
    }

    #[test]
    fn cacheable_codecs_never_touch_the_device_stream() {
        let w = randn(128, 4);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        let mut rng = Rng::new(5);
        let before = rng.clone();
        for codec in [
            DownloadCodec::Full,
            DownloadCodec::TopK { ratio: 0.5 },
            DownloadCodec::CaesarSplit { ratio: 0.5 },
            DownloadCodec::Full, // hit
        ] {
            cache.get_or_encode(&e, codec, &w, true, &mut rng).unwrap();
        }
        let mut b = before;
        assert_eq!(rng.next_u64(), b.next_u64(), "device stream advanced");
    }

    #[test]
    fn quant_bypasses_the_cache_and_draws_per_device() {
        let w = randn(64, 6);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        let codec = DownloadCodec::Quant { bits: 4 };
        // two devices, two streams → two distinct noise draws
        let a = cache
            .get_or_encode(&e, codec, &w, true, &mut Rng::stream(7, 1, 0))
            .unwrap();
        let b = cache
            .get_or_encode(&e, codec, &w, true, &mut Rng::stream(7, 1, 1))
            .unwrap();
        assert_eq!(cache.encodes(), 2, "quant must encode per device");
        assert!(!Arc::ptr_eq(&a, &b));
        assert_ne!(a.bytes, b.bytes, "independent noise must differ");
        // and the payload matches a direct per-device encode
        let direct = e.encode_download(codec, &w, &mut Rng::stream(7, 1, 0)).unwrap();
        assert_eq!(a.bytes, direct.bytes);
    }

    #[test]
    fn cached_bytes_match_a_direct_encode() {
        let w = randn(777, 8);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        for codec in [
            DownloadCodec::Full,
            DownloadCodec::TopK { ratio: 0.3 },
            DownloadCodec::CaesarSplit { ratio: 0.6 },
        ] {
            let cached =
                cache.get_or_encode(&e, codec, &w, true, &mut Rng::new(1)).unwrap();
            let direct = e.encode_download(codec, &w, &mut Rng::new(2)).unwrap();
            assert_eq!(cached.bytes, direct.bytes, "{codec:?}");
            assert_eq!(cached.bits, direct.bits, "{codec:?}");
        }
    }
}

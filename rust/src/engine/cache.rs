//! PS-side download-encode cache with a **cross-round generation key**.
//!
//! The staleness-greedy of §4.1 clusters participants into a handful of
//! discrete download ratios (`cfg.clusters`, default 4), and baselines
//! like FedAvg serve the identical `Full` payload to everyone — yet the
//! seed engine ran `CodecEngine::encode_download` once **per
//! participant**, re-compressing and re-serializing the same global model
//! for every device that shared a codec. This cache deduplicates by the
//! *effective* codec (post [`effective_download`] resolution, so a
//! CaesarSplit download degraded to `Full` for a local-less receiver
//! shares `Full`'s entry): O(distinct codecs) encodes per round instead
//! of O(participants), with the one `EncodedPayload` shared across
//! devices via `Arc` — every receiver sees byte-identical wire bytes, so
//! engine parity is untouched.
//!
//! **Generation keying.** The cache now lives with the [`super::Engine`]
//! for the whole run, not one round: the logical key is
//! `(model_version, effective codec)`. [`DownloadCache::begin_round`]
//! compares the incoming model version with the entries' generation — a
//! new version invalidates everything (the bytes encode a model that no
//! longer exists), while an unchanged version *carries* the entries over,
//! so multi-round serving reuses encodes when the global model did not
//! move (rounds whose participants all dropped out, evaluation-style
//! re-serves, stragglers re-fetching). Hits on carried entries are
//! counted separately (`cross_round_hits`) and surfaced through
//! `EngineStats::cache_cross_round_hits`.
//!
//! **RNG discipline.** Only RNG-free codecs are cacheable (`Full`,
//! `TopK`, `CaesarSplit` — pure functions of the global model). `Quant`
//! draws its stochastic-rounding noise from the *device* stream
//! (`compress::quant`'s contract), so its payload is device-specific: it
//! bypasses the cache and encodes per device, exactly as before. For
//! cacheable codecs the device stream is never touched — neither on a
//! miss (the encode is fed a throwaway RNG; these codecs draw nothing)
//! nor on a hit — so per-device draw sequences are identical to the
//! uncached engine and bit-exact parity holds at every worker count.
//!
//! **Concurrency.** One cache is shared by all workers. Misses encode
//! *while holding the lock*: the first device to need a codec pays the
//! encode, racing devices block and then share the `Arc` — exactly one
//! encode per distinct codec per generation, which keeps the
//! `encode_calls` metric deterministic across worker counts (a benched
//! acceptance number, not just a nicety). Hits are a lock + `Arc::clone`.
//! `begin_round` takes `&mut self`: generations only turn over between
//! rounds, on the coordinator thread.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::Result;

use crate::coordinator::codec::effective_download;
use crate::coordinator::CodecEngine;
use crate::schemes::DownloadCodec;
use crate::util::rng::Rng;
use crate::wire::EncodedPayload;

/// Hashable identity of a cacheable (RNG-free) download codec. Ratios are
/// keyed by their exact f64 bit pattern — the staleness clustering emits
/// identical f64s for devices in the same cluster, which is precisely the
/// sharing this cache exploits.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
enum CacheKey {
    Full,
    CaesarSplit(u64),
    TopK(u64),
}

fn cache_key(codec: DownloadCodec) -> Option<CacheKey> {
    match codec {
        DownloadCodec::Full => Some(CacheKey::Full),
        DownloadCodec::CaesarSplit { ratio } => Some(CacheKey::CaesarSplit(ratio.to_bits())),
        DownloadCodec::TopK { ratio } => Some(CacheKey::TopK(ratio.to_bits())),
        // device-specific stochastic noise: never shared
        DownloadCodec::Quant { .. } => None,
    }
}

struct Entry {
    enc: Arc<EncodedPayload>,
    /// True once the entry has survived a round boundary within its
    /// generation — hits on it are cross-round reuse.
    carried: bool,
}

/// One model generation's worth of encoded downloads.
struct Gen {
    /// Model version these entries encode (None for the implicit
    /// pre-`begin_round` generation standalone use keys).
    version: Option<u64>,
    entries: HashMap<CacheKey, Entry>,
}

/// Shares one encoded download per distinct codec per model generation.
///
/// Holds up to `capacity` generations (the engine sizes it to
/// `pipeline_depth`): with semi-async rounds two model versions are live
/// at once — round t+1 opens against the post-t model while round t's
/// stragglers still re-fetch the pre-t model — and neither round's
/// encodes may evict the other's. Serving order is front-is-current:
/// [`DownloadCache::begin_round`] promotes (or creates) the generation
/// for the round being opened, and misses insert into the front
/// generation only. At `capacity == 1` this is exactly the single-
/// generation cache the barrier engine always had.
pub struct DownloadCache {
    gens: Mutex<VecDeque<Gen>>,
    /// Maximum live generations (≥ 1).
    capacity: usize,
    requests: AtomicUsize,
    encodes: AtomicUsize,
    cross_round_hits: AtomicUsize,
}

impl Default for DownloadCache {
    fn default() -> Self {
        Self::new()
    }
}

impl DownloadCache {
    pub fn new() -> DownloadCache {
        Self::with_capacity(1)
    }

    /// A cache holding up to `capacity` live model generations.
    pub fn with_capacity(capacity: usize) -> DownloadCache {
        DownloadCache {
            gens: Mutex::new(VecDeque::new()),
            capacity: capacity.max(1),
            requests: AtomicUsize::new(0),
            encodes: AtomicUsize::new(0),
            cross_round_hits: AtomicUsize::new(0),
        }
    }

    /// Live generations this cache can hold.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Turn the generations over for a round serving `model_version`: if a
    /// live generation already encodes it, promote it to the front and
    /// mark its entries carried (subsequent hits count as cross-round
    /// reuse); otherwise open a fresh front generation. Generations past
    /// `capacity` are evicted oldest-first. Counters are cumulative and
    /// never reset.
    pub fn begin_round(&mut self, model_version: u64) {
        // The cache is run-lifetime: a panic under the lock (an encode
        // dying mid-miss on a worker) must not kill every later round. The
        // maps themselves are coherent on that path — inserts happen only
        // after a successful encode — but start from clean anyway.
        let poisoned = self.gens.is_poisoned();
        let gens = self.gens.get_mut().unwrap_or_else(PoisonError::into_inner);
        if poisoned {
            gens.clear();
        }
        match gens.iter().position(|g| g.version == Some(model_version)) {
            Some(i) => {
                let mut g = gens.remove(i).unwrap();
                for e in g.entries.values_mut() {
                    e.carried = true;
                }
                gens.push_front(g);
            }
            None => {
                gens.push_front(Gen { version: Some(model_version), entries: HashMap::new() });
            }
        }
        gens.truncate(self.capacity);
    }

    /// The serialized download for `codec`, encoding at most once per
    /// distinct cacheable codec per generation. `codec` must already be
    /// the *effective* codec ([`effective_download`]); a debug assertion
    /// guards the `has_local` contract. `rng` is the device stream —
    /// consumed only by uncacheable codecs (Quant), untouched otherwise.
    pub fn get_or_encode(
        &self,
        engine: &CodecEngine,
        codec: DownloadCodec,
        w: &[f32],
        has_local: bool,
        rng: &mut Rng,
    ) -> Result<Arc<EncodedPayload>> {
        debug_assert_eq!(
            effective_download(codec, has_local),
            codec,
            "get_or_encode requires the effective codec"
        );
        self.requests.fetch_add(1, Ordering::Relaxed);
        let Some(key) = cache_key(codec) else {
            self.encodes.fetch_add(1, Ordering::Relaxed);
            return Ok(Arc::new(engine.encode_download(codec, w, rng)?));
        };
        // survive a poisoned lock (another worker's encode panicked): the
        // entries present are all post-successful-encode, so keep serving
        let mut gens = self.gens.lock().unwrap_or_else(PoisonError::into_inner);
        if gens.is_empty() {
            // pre-`begin_round` standalone use keys one implicit generation
            gens.push_front(Gen { version: None, entries: HashMap::new() });
        }
        let front = gens.front_mut().expect("front generation just ensured");
        if let Some(hit) = front.entries.get(&key) {
            if hit.carried {
                self.cross_round_hits.fetch_add(1, Ordering::Relaxed);
            }
            return Ok(Arc::clone(&hit.enc));
        }
        self.encodes.fetch_add(1, Ordering::Relaxed);
        // cacheable codecs are RNG-free by the module contract: feed a
        // throwaway stream so hit/miss can never diverge device draws
        let enc = Arc::new(engine.encode_download(codec, w, &mut Rng::new(0))?);
        front.entries.insert(key, Entry { enc: Arc::clone(&enc), carried: false });
        Ok(enc)
    }

    /// Downloads served so far (cache hits + encodes), cumulative over
    /// the cache's lifetime.
    pub fn requests(&self) -> usize {
        self.requests.load(Ordering::Relaxed)
    }

    /// Actual `encode_download` executions (misses + uncacheable codecs),
    /// cumulative over the cache's lifetime.
    pub fn encodes(&self) -> usize {
        self.encodes.load(Ordering::Relaxed)
    }

    /// Hits served from an entry carried across a round boundary
    /// (unchanged model version), cumulative.
    pub fn cross_round_hits(&self) -> usize {
        self.cross_round_hits.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn shared_codec_encodes_once_and_shares_the_allocation() {
        let w = randn(512, 1);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        let codec = DownloadCodec::CaesarSplit { ratio: 0.4 };
        let mut rng = Rng::new(9);
        let a = cache.get_or_encode(&e, codec, &w, true, &mut rng).unwrap();
        let b = cache.get_or_encode(&e, codec, &w, true, &mut rng).unwrap();
        assert!(Arc::ptr_eq(&a, &b), "devices sharing a codec must share bytes");
        assert_eq!(cache.requests(), 2);
        assert_eq!(cache.encodes(), 1);
        // same-round hits are NOT cross-round reuse
        assert_eq!(cache.cross_round_hits(), 0);
        // byte-identical by construction, still worth pinning
        assert_eq!(a.bytes, b.bytes);
    }

    #[test]
    fn distinct_ratios_are_distinct_entries() {
        let w = randn(256, 2);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        let mut rng = Rng::new(3);
        for &r in &[0.2, 0.4, 0.2] {
            cache
                .get_or_encode(&e, DownloadCodec::CaesarSplit { ratio: r }, &w, true, &mut rng)
                .unwrap();
        }
        cache.get_or_encode(&e, DownloadCodec::Full, &w, false, &mut rng).unwrap();
        assert_eq!(cache.requests(), 4);
        assert_eq!(cache.encodes(), 3, "0.2 / 0.4 / Full");
    }

    #[test]
    fn unchanged_model_version_carries_entries_across_rounds() {
        let w = randn(300, 7);
        let e = CodecEngine::native();
        let mut cache = DownloadCache::new();
        cache.begin_round(5);
        let a = cache
            .get_or_encode(&e, DownloadCodec::Full, &w, true, &mut Rng::new(1))
            .unwrap();
        // next round, same model version: the entry survives and the hit
        // is a cross-round hit on the very same Arc
        cache.begin_round(5);
        let b = cache
            .get_or_encode(&e, DownloadCodec::Full, &w, true, &mut Rng::new(2))
            .unwrap();
        assert!(Arc::ptr_eq(&a, &b), "carried entry must be the same allocation");
        assert_eq!(cache.encodes(), 1);
        assert_eq!(cache.cross_round_hits(), 1);
        // a second hit in the same later round also counts (the entry
        // stays carried for the rest of the generation)
        cache
            .get_or_encode(&e, DownloadCodec::Full, &w, true, &mut Rng::new(3))
            .unwrap();
        assert_eq!(cache.cross_round_hits(), 2);
    }

    #[test]
    fn model_version_change_invalidates_everything() {
        let w0 = randn(300, 8);
        let e = CodecEngine::native();
        let mut cache = DownloadCache::new();
        cache.begin_round(1);
        cache
            .get_or_encode(&e, DownloadCodec::Full, &w0, true, &mut Rng::new(1))
            .unwrap();
        // model moved: same codec must RE-encode the new model
        let w1 = randn(300, 9);
        cache.begin_round(2);
        let b = cache
            .get_or_encode(&e, DownloadCodec::Full, &w1, true, &mut Rng::new(2))
            .unwrap();
        assert_eq!(cache.encodes(), 2, "new generation re-encodes");
        assert_eq!(cache.cross_round_hits(), 0);
        // and the served bytes are the NEW model's
        let direct = e.encode_download(DownloadCodec::Full, &w1, &mut Rng::new(0)).unwrap();
        assert_eq!(b.bytes, direct.bytes);
    }

    #[test]
    fn two_live_generations_never_evict_each_other() {
        // the semi-async shape: rounds t and t+1 are open at once, serving
        // model versions v and v+1 — a depth-2 cache must keep BOTH warm
        // while the scheduler alternates begin_round between them
        let wv = randn(300, 10);
        let wv1 = randn(300, 11);
        let e = CodecEngine::native();
        let mut cache = DownloadCache::with_capacity(2);
        assert_eq!(cache.capacity(), 2);

        cache.begin_round(7);
        let a7 = cache
            .get_or_encode(&e, DownloadCodec::Full, &wv, true, &mut Rng::new(1))
            .unwrap();
        cache.begin_round(8);
        let a8 = cache
            .get_or_encode(&e, DownloadCodec::Full, &wv1, true, &mut Rng::new(2))
            .unwrap();
        assert_eq!(cache.encodes(), 2, "one encode per live generation");

        // promoting v=7 back to the front serves its ORIGINAL bytes (no
        // re-encode) and classifies the hit as cross-round reuse; v=8's
        // entry survives the promotion untouched
        cache.begin_round(7);
        let b7 = cache
            .get_or_encode(&e, DownloadCodec::Full, &wv, true, &mut Rng::new(3))
            .unwrap();
        assert!(Arc::ptr_eq(&a7, &b7), "generation 7 was evicted by generation 8");
        cache.begin_round(8);
        let b8 = cache
            .get_or_encode(&e, DownloadCodec::Full, &wv1, true, &mut Rng::new(4))
            .unwrap();
        assert!(Arc::ptr_eq(&a8, &b8), "generation 8 was evicted by the promotion");
        assert_eq!(cache.encodes(), 2, "ping-ponging live generations must not re-encode");
        assert_eq!(cache.cross_round_hits(), 2);

        // a THIRD version overflows capacity 2: the oldest (7) is evicted,
        // so returning to it re-encodes
        cache.begin_round(9);
        cache
            .get_or_encode(&e, DownloadCodec::Full, &randn(300, 12), true, &mut Rng::new(5))
            .unwrap();
        cache.begin_round(7);
        let c7 = cache
            .get_or_encode(&e, DownloadCodec::Full, &wv, true, &mut Rng::new(6))
            .unwrap();
        assert!(!Arc::ptr_eq(&a7, &c7), "evicted generation must not resurrect its Arc");
        assert_eq!(cache.encodes(), 4);
        // the re-encode still serves the right bytes
        assert_eq!(c7.bytes, a7.bytes);
    }

    #[test]
    fn capacity_one_matches_the_legacy_single_generation_counters() {
        // depth 1 must reproduce the barrier cache bit-for-bit, counters
        // included: alternating versions re-encodes every time
        let wv = randn(200, 13);
        let wv1 = randn(200, 14);
        let e = CodecEngine::native();
        let mut cache = DownloadCache::new();
        assert_eq!(cache.capacity(), 1);
        for (round, w) in [(1u64, &wv), (2, &wv1), (1, &wv), (2, &wv1)] {
            cache.begin_round(round);
            cache.get_or_encode(&e, DownloadCodec::Full, w, true, &mut Rng::new(round)).unwrap();
        }
        assert_eq!(cache.requests(), 4);
        assert_eq!(cache.encodes(), 4, "capacity 1 evicts on every version turn");
        assert_eq!(cache.cross_round_hits(), 0);
    }

    #[test]
    fn promotion_marks_entries_carried_per_generation() {
        // cross_round_hits is deterministic: hits in the generation that
        // FIRST encoded an entry never count; hits after the generation
        // survives a begin_round boundary always do — independent of the
        // other live generation's activity
        let wv = randn(150, 15);
        let wv1 = randn(150, 16);
        let e = CodecEngine::native();
        let mut cache = DownloadCache::with_capacity(2);
        cache.begin_round(1);
        cache.get_or_encode(&e, DownloadCodec::Full, &wv, true, &mut Rng::new(1)).unwrap();
        // same round (no boundary): a plain hit, not cross-round
        cache.get_or_encode(&e, DownloadCodec::Full, &wv, true, &mut Rng::new(2)).unwrap();
        assert_eq!(cache.cross_round_hits(), 0);
        // open the overlapping round on the next version — gen 1 is
        // untouched behind it
        cache.begin_round(2);
        cache.get_or_encode(&e, DownloadCodec::Full, &wv1, true, &mut Rng::new(3)).unwrap();
        assert_eq!(cache.cross_round_hits(), 0, "fresh generation's first miss");
        // promote gen 1 back: its entries are now carried
        cache.begin_round(1);
        cache.get_or_encode(&e, DownloadCodec::Full, &wv, true, &mut Rng::new(4)).unwrap();
        assert_eq!(cache.cross_round_hits(), 1);
        // and promoting gen 2 back marks ITS entry carried too
        cache.begin_round(2);
        cache.get_or_encode(&e, DownloadCodec::Full, &wv1, true, &mut Rng::new(5)).unwrap();
        assert_eq!(cache.cross_round_hits(), 2);
        assert_eq!(cache.encodes(), 2, "no eviction anywhere in the ping-pong");
    }

    #[test]
    fn cacheable_codecs_never_touch_the_device_stream() {
        let w = randn(128, 4);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        let mut rng = Rng::new(5);
        let before = rng.clone();
        for codec in [
            DownloadCodec::Full,
            DownloadCodec::TopK { ratio: 0.5 },
            DownloadCodec::CaesarSplit { ratio: 0.5 },
            DownloadCodec::Full, // hit
        ] {
            cache.get_or_encode(&e, codec, &w, true, &mut rng).unwrap();
        }
        let mut b = before;
        assert_eq!(rng.next_u64(), b.next_u64(), "device stream advanced");
    }

    #[test]
    fn quant_bypasses_the_cache_and_draws_per_device() {
        let w = randn(64, 6);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        let codec = DownloadCodec::Quant { bits: 4 };
        // two devices, two streams → two distinct noise draws
        let a = cache
            .get_or_encode(&e, codec, &w, true, &mut Rng::stream(7, 1, 0))
            .unwrap();
        let b = cache
            .get_or_encode(&e, codec, &w, true, &mut Rng::stream(7, 1, 1))
            .unwrap();
        assert_eq!(cache.encodes(), 2, "quant must encode per device");
        assert!(!Arc::ptr_eq(&a, &b));
        assert_ne!(a.bytes, b.bytes, "independent noise must differ");
        // and the payload matches a direct per-device encode
        let direct = e.encode_download(codec, &w, &mut Rng::stream(7, 1, 0)).unwrap();
        assert_eq!(a.bytes, direct.bytes);
    }

    #[test]
    fn cached_bytes_match_a_direct_encode() {
        let w = randn(777, 8);
        let e = CodecEngine::native();
        let cache = DownloadCache::new();
        for codec in [
            DownloadCodec::Full,
            DownloadCodec::TopK { ratio: 0.3 },
            DownloadCodec::CaesarSplit { ratio: 0.6 },
        ] {
            let cached =
                cache.get_or_encode(&e, codec, &w, true, &mut Rng::new(1)).unwrap();
            let direct = e.encode_download(codec, &w, &mut Rng::new(2)).unwrap();
            assert_eq!(cached.bytes, direct.bytes, "{codec:?}");
            assert_eq!(cached.bits, direct.bits, "{codec:?}");
        }
    }
}

//! Event-driven round engine: the coordinator as a state machine
//! (`Standby → Round(t) → Finished`) over typed device messages, with the
//! per-device work of a round (decode download → local SGD → encode
//! upload) executed in parallel across a **persistent worker pool** and
//! aggregated through streaming, order-exact shards.
//!
//! ```text
//!                 Join/Heartbeat
//!                   ┌───────┐
//!                   ▼       │
//!   ┌─────────┐  StartRound{plan}   ┌──────────┐   finish()   ┌──────────┐
//!   │ Standby ├────────────────────▶│ Round(t) ├─────────────▶│ Finished │
//!   └─────────┘                     └────┬─────┘              └──────────┘
//!        ▲      EndRound{update} /       │
//!        └────── Dropout drained ◀───────┘
//! ```
//!
//! One `execute_round` call performs a full `Standby → Round(t) → Standby`
//! cycle: participants join the [`Registry`], each receives a
//! [`StartRound`] message, device work runs through the caller's
//! [`ExecutorHandle`] — inline on this thread, or batched onto a
//! [`WorkerPool`] of long-lived trainer threads — and [`DeviceMsg`]s
//! stream back to the coordinator loop which maintains liveness and
//! reduces [`AggregatorShard`]s in canonical order.
//!
//! **Run-lifetime resources.** The executor is built once per run and
//! survives every round: each pool worker owns its [`WorkerCtx`] (trainer
//! + PJRT runtime for the XLA backend) built by `WorkerPool::new`'s
//! `setup(worker_idx)` on the thread that keeps it, and the thread-local
//! `util::pool` scratch warms up once per worker instead of once per
//! round. `EngineStats::trainer_builds` mirrors the executor's build
//! count and stays O(workers) per run — the pre-pool engine paid
//! O(workers·rounds). A worker that panics is retired and surfaces as an
//! [`Event::Error`] (the round fails, the next one runs on the
//! survivors); it never deadlocks the drain.
//!
//! **Determinism contract.** For a fixed seed the engine's output is
//! bit-identical for ANY worker count, because every source of
//! nondeterminism is pinned:
//! * per-device randomness comes from pure [`Rng::stream`] keys
//!   `(base, t, device)` — no shared generator is advanced;
//! * devices execute in sorted-device-id order within fixed-size groups
//!   (`EngineConfig::agg_group`), and group partial sums combine up a
//!   fixed-shape binary tree whose shape is a function of the group
//!   count alone ([`aggregate`]) — the same f64 reduction tree
//!   regardless of which thread runs what, when;
//! * coordinator-side application (traffic, locals, tracker) happens in
//!   sorted order after the round drains.
//!
//! The per-device hot path is reuse-dominated: the engine-owned
//! [`DownloadCache`] shares each distinct download encode across all
//! receivers — and, keyed by `(model_version, effective codec)`, across
//! *rounds* whenever the global model did not move (`Arc`'d bytes,
//! O(distinct codecs) encodes per model generation — RNG-drawing codecs
//! bypass it). Recovery and the gradient use pooled scratch
//! ([`crate::util::pool`]) written in place, and uploads fold into shards
//! straight off their serialized bytes. All three layers are
//! bit-transparent: the cached bytes are what a per-device encode would
//! have produced, and the in-place/streaming folds walk the exact same
//! element order as the eager decode.
//!
//! `tests/engine_parity.rs` pins this contract end-to-end.

pub mod aggregate;
pub mod cache;
pub mod message;
pub mod registry;

pub use aggregate::{reduce_shards_parallel, AggregatorShard, ChunkedSum, ShardReducer};
pub use cache::DownloadCache;
pub use message::{DeviceMsg, DroppedDevice, Event, LateUpload, RoundUpdate, StartRound};
pub use registry::{DeviceStatus, Registry};

use std::collections::BTreeSet;
use std::path::Path;

use anyhow::{anyhow, Result};

use crate::compress::traffic::PayloadScale;
use crate::config::{EngineConfig, ExperimentConfig, TrainerBackend};
use crate::coordinator::codec::effective_download;
use crate::coordinator::{CodecEngine, EvalOutcome, Trainer};
use crate::data::{Dataset, Partition};
use crate::fleet::RoundCost;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::threadpool::{self, WorkerPool};

/// Stream-key salt separating device "fate" draws (dropout lottery) from
/// device work draws, so enabling dropout never perturbs the randomness
/// of devices that complete. Shared with `transport::client`, which runs
/// the same lottery on the remote device.
pub(crate) const FATE_SALT: u64 = 0xD60_D60;

/// Upper bound on simulated heartbeats emitted per device per round.
const MAX_HEARTBEATS: usize = 1_000;

/// Coordinator state-machine phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Between rounds; devices may join, rounds may start.
    Standby,
    /// Executing round `t`.
    Round(usize),
    /// Terminal; no further rounds accepted.
    Finished,
}

/// Cumulative engine counters (diagnostics; surfaced by `caesar info`-style
/// tooling, tests and the benches' per-round metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub rounds: usize,
    pub messages: usize,
    pub heartbeats: usize,
    pub dropouts: usize,
    /// Downloads served (one per StartRound that reached encoding).
    pub download_requests: usize,
    /// Actual `encode_download` executions — with the generation-keyed
    /// [`DownloadCache`], O(distinct codecs) of `download_requests` per
    /// model version.
    pub download_encodes: usize,
    /// Download requests served from an encode carried across a round
    /// boundary (the global model did not change between rounds).
    pub cache_cross_round_hits: usize,
    /// Trainer constructions performed by the run's [`ExecutorHandle`] —
    /// O(workers) per RUN (pool setup builds them once), where the
    /// per-round scoped fan-out paid O(workers·rounds).
    pub trainer_builds: usize,
    /// The aggregation chunk length this engine runs with — the explicit
    /// `agg-chunk=` override, or the L2-autotuned default
    /// (`config::detect_agg_chunk`).
    pub agg_chunk: usize,
}

/// Read-only view of everything a device round needs from the server.
pub struct RoundEnv<'a> {
    /// 1-based round number.
    pub t: usize,
    /// Learning rate at this round.
    pub lr: f32,
    pub cfg: &'a ExperimentConfig,
    /// Current global model.
    pub global: &'a [f32],
    /// Monotone version of `global` — bumped by the driver whenever the
    /// model changes. Keys the cross-round [`DownloadCache`] generation:
    /// consecutive rounds at the same version reuse download encodes.
    pub model_version: u64,
    /// Per-device stale local models.
    pub locals: &'a [Option<Vec<f32>>],
    pub train_ds: &'a Dataset,
    pub partition: &'a Partition,
    pub scale: &'a PayloadScale,
    /// Base key of the pure per-(round, device) RNG streams.
    pub stream_base: u64,
    /// Simulated wall-clock at round start (registry timestamps).
    pub sim_now_s: f64,
}

/// The long-lived state a pool worker owns across rounds: its trainer
/// (and, for the XLA backend, the PJRT runtime inside it). Built once per
/// worker by `WorkerPool::new`'s setup, on the thread that keeps it —
/// PJRT runtimes are not `Send`, which is exactly why the pool constructs
/// and drops them in place. The borrow-based [`CodecEngine`] is rebuilt
/// per job from these owned parts (a few words, no allocation).
pub struct WorkerCtx {
    pub trainer: Trainer,
}

/// How rounds obtain trainers — the run-lifetime resource that replaced
/// the per-round `TrainerProvider` closures. Owned by the driver
/// (`coordinator::Server`) and reused across every round of a run.
pub enum ExecutorHandle {
    /// Execute rounds inline on the calling thread with this owned
    /// trainer (`engine.workers <= 1`, the parity baseline — also the
    /// pick when device counts are too small to amortize thread
    /// hand-off).
    Inline(Trainer),
    /// Execute rounds as job batches on a persistent pool of trainer
    /// threads; trainers, runtimes and thread-local scratch survive round
    /// boundaries.
    Pool(WorkerPool<WorkerCtx>),
}

impl ExecutorHandle {
    /// Build the executor for `cfg`: inline for `engine.workers <= 1`,
    /// otherwise a persistent pool of `threadpool::workers(engine.workers)`
    /// trainer threads. Trainers (and PJRT runtimes) are built once per
    /// worker for the whole run.
    pub fn build(cfg: &ExperimentConfig, artifact_dir: &Path) -> Result<ExecutorHandle> {
        let backend = cfg.trainer;
        let task = cfg.task.clone();
        let dir = artifact_dir.to_path_buf();
        let make = move || -> Result<Trainer> {
            match backend {
                TrainerBackend::Native => Ok(Trainer::native(&task)),
                TrainerBackend::Xla => Trainer::xla(&task, &dir),
            }
        };
        if cfg.engine.workers <= 1 {
            Ok(ExecutorHandle::Inline(make()?))
        } else {
            let n = threadpool::workers(cfg.engine.workers);
            let pool = WorkerPool::new(n, move |_wi| -> Result<WorkerCtx> {
                Ok(WorkerCtx { trainer: make()? })
            })?;
            Ok(ExecutorHandle::Pool(pool))
        }
    }

    /// Trainer constructions this executor has performed — 1 inline, or
    /// one per pool worker; flat in the number of rounds by construction
    /// (pinned by `tests/engine_parity.rs`).
    pub fn trainer_builds(&self) -> usize {
        match self {
            ExecutorHandle::Inline(_) => 1,
            ExecutorHandle::Pool(p) => p.builds(),
        }
    }

    /// Model size, from whichever trainer this executor owns (pool mode
    /// probes a worker — the coordinator thread holds no runtime).
    pub fn n_params(&self) -> Result<usize> {
        match self {
            ExecutorHandle::Inline(t) => Ok(t.n_params()),
            ExecutorHandle::Pool(p) => {
                let mut out = None;
                p.run_batch(1, |ctx, _| ctx.trainer.n_params(), |r| out = r.ok());
                out.ok_or_else(|| anyhow!("worker pool lost the n_params probe"))
            }
        }
    }

    /// `(target, alive)` worker census: how many worker threads the pool
    /// was built with vs how many survive (a panicked worker retires
    /// itself). Inline executors are their own, always-alive thread.
    pub fn worker_census(&self) -> (usize, usize) {
        match self {
            ExecutorHandle::Inline(_) => (1, 1),
            ExecutorHandle::Pool(p) => (p.workers(), p.alive()),
        }
    }

    /// Rebuild any dead pool workers on fresh threads via the pool's
    /// original setup closure (trainers and runtimes are reconstructed
    /// exactly as at run start). Returns how many were rebuilt; inline
    /// executors have nothing to heal.
    pub fn respawn_dead(&mut self) -> Result<usize> {
        match self {
            ExecutorHandle::Inline(_) => Ok(0),
            ExecutorHandle::Pool(p) => p.respawn_dead(),
        }
    }

    /// Evaluate `w` on this executor's trainer. Pool mode runs the
    /// evaluation as a one-item batch on a worker thread, against that
    /// worker's long-lived trainer.
    pub fn eval(&self, w: &[f32], test: &Dataset) -> Result<EvalOutcome> {
        match self {
            ExecutorHandle::Inline(t) => t.eval(w, test),
            ExecutorHandle::Pool(p) => {
                let mut out = None;
                p.run_batch(1, |ctx, _| ctx.trainer.eval(w, test), |r| out = r.ok());
                out.ok_or_else(|| anyhow!("worker pool lost the eval job"))?
            }
        }
    }
}

/// In-flight state of an **externally driven** round: the engine's event
/// loop generalized over a transport. Where [`Engine::execute_round`]
/// simulates devices on worker threads, an external round receives its
/// [`DeviceMsg`]s from the outside — decoded transport frames
/// (`transport::server::CoordinatorService`) or a test script — and the
/// engine replays the identical coordinator-side handling: registry
/// bookkeeping per message, then one canonical aggregation pass at
/// [`Engine::finish_external`] that walks the exact same sorted-group
/// f64 reduction tree as the in-process path. Same seed + same messages
/// ⇒ bit-identical [`RoundOutput`], whichever loop drove the round.
pub struct ExternalRound {
    /// 1-based round number (matches the engine's `Phase::Round`).
    t: usize,
    /// Simulated wall-clock at round start (registry timestamps).
    start_s: f64,
    n_params: usize,
    /// Expected participant ids, ascending — the canonical fold order.
    expected: Vec<usize>,
    /// Participants that have not yet resolved (EndRound or Dropout).
    pending: BTreeSet<usize>,
    updates: Vec<RoundUpdate>,
    dropped: Vec<DroppedDevice>,
}

impl ExternalRound {
    pub fn t(&self) -> usize {
        self.t
    }

    /// True once every expected participant resolved.
    pub fn drained(&self) -> bool {
        self.pending.is_empty()
    }

    /// Participants still unresolved, ascending.
    pub fn pending(&self) -> Vec<usize> {
        self.pending.iter().copied().collect()
    }

    /// Whether `device` is an expected participant that has not yet
    /// resolved. The demux server guards resolutions on this before
    /// calling [`Engine::external_msg`]: a duplicate EndRound/Dropout
    /// is a stale frame to refuse, not an error to propagate.
    pub fn is_pending(&self, device: usize) -> bool {
        self.pending.contains(&device)
    }
}

/// What one executed round hands back to the driver.
pub struct RoundOutput {
    /// Canonical f64 sum of the (weighted) device updates, chunk-sharded
    /// per `EngineConfig::agg_chunk` (iterate or `to_vec` it; chunking
    /// never changes the bits).
    pub agg: ChunkedSum,
    /// Completed device rounds, sorted by device id.
    pub updates: Vec<RoundUpdate>,
    /// Devices that vanished mid-round, sorted by device id.
    pub dropped: Vec<DroppedDevice>,
}

/// One device's fate in a finished round — borrowed view for consumers
/// (the round journal) that need the resolutions in fold order without
/// taking the updates apart.
pub enum Resolution<'a> {
    Update(&'a RoundUpdate),
    Dropped(&'a DroppedDevice),
}

impl RoundOutput {
    /// All per-device resolutions merged in canonical fold order
    /// (ascending device id — each planned device appears exactly once,
    /// as an update or a dropout).
    pub fn resolutions(&self) -> Vec<Resolution<'_>> {
        let mut out = Vec::with_capacity(self.updates.len() + self.dropped.len());
        let (mut i, mut j) = (0, 0);
        while i < self.updates.len() && j < self.dropped.len() {
            if self.updates[i].device < self.dropped[j].device {
                out.push(Resolution::Update(&self.updates[i]));
                i += 1;
            } else {
                out.push(Resolution::Dropped(&self.dropped[j]));
                j += 1;
            }
        }
        out.extend(self.updates[i..].iter().map(Resolution::Update));
        out.extend(self.dropped[j..].iter().map(Resolution::Dropped));
        out
    }
}

/// The event-driven coordinator engine.
pub struct Engine {
    cfg: EngineConfig,
    phase: Phase,
    registry: Registry,
    stats: EngineStats,
    /// Cross-round download-encode cache, generation-keyed by the model
    /// version; shared by the inline and pool paths. Sized to hold one
    /// generation per in-flight round (`pipeline_depth`).
    cache: DownloadCache,
    /// Externally driven rounds currently open, ascending — at most
    /// `pipeline_depth` at once (1 = the classic single-round barrier).
    open_external: BTreeSet<usize>,
}

impl Engine {
    pub fn new(cfg: EngineConfig, n_devices: usize) -> Engine {
        Engine {
            registry: Registry::new(n_devices, cfg.heartbeat_s),
            phase: Phase::Standby,
            stats: EngineStats { agg_chunk: cfg.agg_chunk, ..EngineStats::default() },
            cache: DownloadCache::with_capacity(cfg.pipeline_depth.max(1)),
            open_external: BTreeSet::new(),
            cfg,
        }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// Bind `device`'s session to transport connection `token` (see
    /// [`Registry::bind_conn`]). `false` if the id is out of range.
    pub fn bind_conn(&mut self, device: usize, token: u64) -> bool {
        self.registry.bind_conn(device, token)
    }

    /// Sever every device bound to connection `token`, returning them
    /// ascending — one socket death is a whole fleet's death.
    pub fn unbind_conn(&mut self, token: u64) -> Vec<usize> {
        self.registry.unbind_conn(token)
    }

    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    pub fn config(&self) -> EngineConfig {
        self.cfg
    }

    /// Transition to the terminal phase; later rounds are rejected.
    pub fn finish(&mut self) {
        // accounting-drift tripwire: cache counters are read after the
        // parallel section, and every encode serves exactly one request —
        // requests trailing encodes would mean the snapshot points drifted
        debug_assert!(
            self.stats.download_requests >= self.stats.download_encodes,
            "download accounting drift: {} requests < {} encodes",
            self.stats.download_requests,
            self.stats.download_encodes
        );
        self.phase = Phase::Finished;
    }

    /// Execute one full round: `Standby → Round(t) → Standby`.
    ///
    /// `items` are the coordinator→device [`StartRound`] messages, one per
    /// participant (any order — execution is canonicalized internally).
    /// `executor` is the run-lifetime trainer resource; pass the same
    /// handle every round so pool workers keep their state.
    pub fn execute_round(
        &mut self,
        env: &RoundEnv,
        items: &[StartRound],
        executor: &ExecutorHandle,
    ) -> Result<RoundOutput> {
        match self.phase {
            Phase::Standby => {}
            Phase::Round(r) => return Err(anyhow!("engine re-entered while in round {r}")),
            Phase::Finished => return Err(anyhow!("engine is finished; no further rounds")),
        }
        self.phase = Phase::Round(env.t);
        let out = self.round_inner(env, items, executor, true);
        self.phase = Phase::Standby;
        if out.is_ok() {
            self.stats.rounds += 1;
        }
        out.map(|(agg, updates, dropped)| RoundOutput {
            agg: agg.expect("folding round returns an aggregate"),
            updates,
            dropped,
        })
    }

    /// [`Engine::execute_round`] without the aggregation fold: device work
    /// runs (and the registry / cache / stats bookkeeping happens) exactly
    /// as in a folding round, but the uploads are handed back unfolded.
    /// The semi-async driver executes overlapped rounds through this and
    /// defers each round's fold to [`Engine::fold_round`] at close time,
    /// when it knows which stragglers park in the staleness buffer.
    /// Does NOT bump `stats.rounds` — the round counts when it closes.
    pub fn execute_round_unfolded(
        &mut self,
        env: &RoundEnv,
        items: &[StartRound],
        executor: &ExecutorHandle,
    ) -> Result<(Vec<RoundUpdate>, Vec<DroppedDevice>)> {
        match self.phase {
            Phase::Standby => {}
            Phase::Round(r) => return Err(anyhow!("engine re-entered while in round {r}")),
            Phase::Finished => return Err(anyhow!("engine is finished; no further rounds")),
        }
        self.phase = Phase::Round(env.t);
        let out = self.round_inner(env, items, executor, false);
        self.phase = Phase::Standby;
        out.map(|(_, updates, dropped)| (updates, dropped))
    }

    fn round_inner(
        &mut self,
        env: &RoundEnv,
        items: &[StartRound],
        executor: &ExecutorHandle,
        fold: bool,
    ) -> Result<(Option<ChunkedSum>, Vec<RoundUpdate>, Vec<DroppedDevice>)> {
        let n_params = env.global.len();

        // trainers are run-lifetime resources: mirror the executor's build
        // count (flat across rounds by construction — the parity tests pin
        // it at O(workers) per run)
        self.stats.trainer_builds = executor.trainer_builds();
        // turn the encode-cache generation over: a changed model version
        // invalidates, an unchanged one carries entries across the round
        self.cache.begin_round(env.model_version);

        // Canonical execution order: item indices sorted by device id.
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by_key(|&i| items[i].plan.device);

        // Split the engine into independent parts: the shared cache is
        // read by worker closures while stats/registry mutate on the
        // coordinator side of the drain.
        let Engine { cfg, registry, stats, cache, .. } = self;
        let cache: &DownloadCache = cache;

        // Rendezvous + kickoff bookkeeping (coordinator-side sends).
        for &i in &order {
            let d = items[i].plan.device;
            registry.join(d, env.sim_now_s);
            registry.start_round_in(d, env.sim_now_s, env.t);
            stats.messages += 2; // Join ack + StartRound
        }

        let group = cfg.agg_group.max(1);
        let groups: Vec<&[usize]> = order.chunks(group).collect();
        let n_groups = groups.len();
        let ecfg = *cfg;

        let mut reducer = fold.then(|| ShardReducer::with_chunk(n_params, n_groups, cfg.agg_chunk));
        let mut updates: Vec<RoundUpdate> = Vec::with_capacity(order.len());
        let mut dropped: Vec<DroppedDevice> = Vec::new();
        let mut worker_err: Option<anyhow::Error> = None;

        match executor {
            ExecutorHandle::Inline(trainer) => {
                let codec =
                    CodecEngine::new(env.cfg.compression, trainer.runtime(), &env.cfg.task)?;
                for (g, members) in groups.iter().enumerate() {
                    let events =
                        execute_group(env, items, &ecfg, g, members, trainer, &codec, cache, fold)?;
                    for ev in events {
                        apply_event(
                            stats,
                            registry,
                            ev,
                            env.sim_now_s,
                            &mut reducer,
                            &mut updates,
                            &mut dropped,
                        )?;
                    }
                }
            }
            ExecutorHandle::Pool(pool) => {
                let groups = &groups;
                pool.run_batch(
                    n_groups,
                    |ctx: &mut WorkerCtx, g: usize| -> Vec<Event> {
                        // the codec engine is a borrow of the worker's
                        // owned trainer/runtime — rebuilt per job for free
                        let codec = match CodecEngine::new(
                            env.cfg.compression,
                            ctx.trainer.runtime(),
                            &env.cfg.task,
                        ) {
                            Ok(c) => c,
                            Err(e) => return vec![Event::Error(format!("worker codec: {e:#}"))],
                        };
                        match execute_group(
                            env,
                            items,
                            &ecfg,
                            g,
                            groups[g],
                            &ctx.trainer,
                            &codec,
                            cache,
                            fold,
                        ) {
                            Ok(events) => events,
                            Err(e) => vec![Event::Error(format!("group {g}: {e:#}"))],
                        }
                    },
                    |res| {
                        let events = match res {
                            Ok(events) => events,
                            // the worker running this group panicked (it
                            // has been retired from the pool): surface as
                            // an error event, exactly like a worker-side
                            // failure — the drain itself never blocks
                            Err(lost) => vec![Event::Error(format!(
                                "worker died running group {}",
                                lost.item
                            ))],
                        };
                        for ev in events {
                            if let Err(e) = apply_event(
                                stats,
                                registry,
                                ev,
                                env.sim_now_s,
                                &mut reducer,
                                &mut updates,
                                &mut dropped,
                            ) {
                                if worker_err.is_none() {
                                    worker_err = Some(e);
                                }
                            }
                        }
                    },
                );
                if let Some(e) = worker_err {
                    return Err(e);
                }
            }
        }

        // Canonical application order for the driver.
        updates.sort_by_key(|u| u.device);
        dropped.sort_by_key(|d| d.device);

        // Mirror the cache's cumulative counters (deterministic at any
        // worker count: misses encode under the cache lock).
        stats.download_requests = cache.requests();
        stats.download_encodes = cache.encodes();
        stats.cache_cross_round_hits = cache.cross_round_hits();

        let Some(reducer) = reducer else {
            return Ok((None, updates, dropped));
        };
        let (agg, folded) = reducer.finish()?;
        if folded != updates.len() {
            return Err(anyhow!(
                "aggregation folded {folded} updates but {} EndRound messages arrived",
                updates.len()
            ));
        }
        Ok((Some(agg), updates, dropped))
    }

    /// Read access to the engine-owned download cache, so an external
    /// driver (`transport::server`) can share encodes exactly like the
    /// in-process round does.
    pub fn cache(&self) -> &DownloadCache {
        &self.cache
    }

    /// Evict silent devices between rounds (see
    /// [`Registry::sweep_expired`]); evictions count as dropouts.
    pub fn sweep_expired(&mut self, now_s: f64) -> Vec<usize> {
        let evicted = self.registry.sweep_expired(now_s);
        self.stats.dropouts += evicted.len();
        evicted
    }

    /// Open round `t` for **external** driving: the transport-facing twin
    /// of [`Engine::execute_round`]'s setup. Performs the same phase
    /// transition, cache-generation turnover and per-participant registry
    /// bookkeeping (Join + StartRound), then hands back an
    /// [`ExternalRound`] that accumulates wire-delivered [`DeviceMsg`]s
    /// via [`Engine::external_msg`] until every participant resolved.
    ///
    /// `devices` must be sorted ascending and unique — the caller sends
    /// StartRound frames in this order, and it becomes the canonical
    /// aggregation order at [`Engine::finish_external`].
    /// With `EngineConfig::pipeline_depth > 1` up to that many external
    /// rounds may be open at once (the semi-async window); at the default
    /// depth 1 a second open is rejected exactly as it always was.
    pub fn begin_external(
        &mut self,
        t: usize,
        model_version: u64,
        sim_now_s: f64,
        devices: &[usize],
        n_params: usize,
    ) -> Result<ExternalRound> {
        let depth = self.cfg.pipeline_depth.max(1);
        match self.phase {
            Phase::Standby => {}
            Phase::Round(r) if self.open_external.len() >= depth => {
                return Err(anyhow!("engine re-entered while in round {r}"));
            }
            Phase::Round(_) => {}
            Phase::Finished => return Err(anyhow!("engine is finished; no further rounds")),
        }
        if self.open_external.contains(&t) {
            return Err(anyhow!("round {t} is already open"));
        }
        for pair in devices.windows(2) {
            if pair[0] >= pair[1] {
                return Err(anyhow!(
                    "external round participants must be sorted and unique (saw {} then {})",
                    pair[0],
                    pair[1]
                ));
            }
        }
        if let Some(&d) = devices.iter().find(|&&d| !self.registry.contains(d)) {
            return Err(anyhow!(
                "participant id {d} out of range (registry holds {})",
                self.registry.len()
            ));
        }
        self.phase = Phase::Round(t);
        self.open_external.insert(t);
        self.cache.begin_round(model_version);
        for &d in devices {
            self.registry.join(d, sim_now_s);
            self.registry.start_round_in(d, sim_now_s, t);
            self.stats.messages += 2; // Join ack + StartRound
        }
        Ok(ExternalRound {
            t,
            start_s: sim_now_s,
            n_params,
            expected: devices.to_vec(),
            pending: devices.iter().copied().collect(),
            updates: Vec::with_capacity(devices.len()),
            dropped: Vec::new(),
        })
    }

    /// Feed one wire-delivered device message into an open external
    /// round. Mirrors [`apply_event`]'s coordinator-side handling, with
    /// the trust boundary moved here: a message from an unknown device,
    /// a participant that already resolved, or an update whose shapes
    /// disagree with the round's model is rejected with an error (the
    /// service answers with a Reject frame) and leaves the round intact.
    pub fn external_msg(&mut self, round: &mut ExternalRound, msg: DeviceMsg) -> Result<()> {
        self.stats.messages += 1;
        match msg {
            DeviceMsg::Join { device } => {
                if !self.registry.join(device, round.start_s) {
                    return Err(anyhow!("join from out-of-range device {device}"));
                }
            }
            DeviceMsg::Heartbeat { device, sim_t_s } => {
                self.stats.heartbeats += 1;
                if !self.registry.heartbeat(device, sim_t_s) {
                    return Err(anyhow!("heartbeat from out-of-range device {device}"));
                }
            }
            DeviceMsg::EndRound(update) => {
                let d = update.device;
                if !round.pending.contains(&d) {
                    return Err(anyhow!("EndRound from device {d} not pending in round {}", round.t));
                }
                // shape checks run before the slot is consumed: a rejected
                // update leaves the device pending, and the service decides
                // whether to retry or synthesize a Dropout for it
                if update.w_final.len() != round.n_params {
                    return Err(anyhow!(
                        "EndRound from device {d}: w_final has {} params, round expects {}",
                        update.w_final.len(),
                        round.n_params
                    ));
                }
                if update.upload.spec.n() != round.n_params {
                    return Err(anyhow!(
                        "EndRound from device {d}: upload covers {} params, round expects {}",
                        update.upload.spec.n(),
                        round.n_params
                    ));
                }
                round.pending.remove(&d);
                self.registry.end_round(d, round.start_s + update.cost.total());
                round.updates.push(*update);
            }
            DeviceMsg::Dropout { device, after_s, down_wire_bits } => {
                if !round.pending.remove(&device) {
                    return Err(anyhow!(
                        "Dropout from device {device} not pending in round {}",
                        round.t
                    ));
                }
                self.stats.dropouts += 1;
                self.registry.dropout(device, round.start_s + after_s);
                round.dropped.push(DroppedDevice { device, after_s, down_wire_bits });
            }
        }
        Ok(())
    }

    /// Close a drained external round: run the canonical aggregation pass
    /// and return the same [`RoundOutput`] the in-process path produces.
    /// The fold replays [`round_inner`]'s exact reduction tree — expected
    /// ids chunked into `agg_group`-sized [`AggregatorShard`]s walked in
    /// ascending order, shard sums combined up the fixed-shape binary
    /// tree — so a fixed seed gives bit-identical `agg` regardless of
    /// message arrival order. With `workers > 1` both the shard builds
    /// (stream-folding each group's serialized uploads) and the pairwise
    /// tree combines fan out over scoped threads; the tree shape is a
    /// function of the group count alone, so the bits match the serial
    /// walk at any worker count.
    pub fn finish_external(&mut self, round: ExternalRound) -> Result<RoundOutput> {
        if !self.open_external.contains(&round.t) {
            return Err(anyhow!("finish_external outside round {}", round.t));
        }
        if !round.drained() {
            return Err(anyhow!(
                "round {} still waiting on devices {:?}",
                round.t,
                round.pending()
            ));
        }
        let ExternalRound { t, n_params, expected, mut updates, mut dropped, .. } = round;
        updates.sort_by_key(|u| u.device);
        dropped.sort_by_key(|d| d.device);

        let group = self.cfg.agg_group.max(1);
        let chunk = self.cfg.agg_chunk;
        let groups: Vec<&[usize]> = expected.chunks(group).collect();
        let workers = threadpool::workers(self.cfg.workers.max(1));
        // updates are sorted by device and each expected id resolved
        // exactly once, so every group locates its updates independently
        // — the builds are embarrassingly parallel and deterministic
        let updates_ref: &[RoundUpdate] = &updates;
        let groups_ref: &[&[usize]] = &groups;
        let shards = threadpool::scope_map(groups.len(), workers, |g| {
            let members = groups_ref[g];
            let mut shard = AggregatorShard::with_chunk(g, n_params, chunk, members.to_vec());
            let mut next = updates_ref.partition_point(|u| u.device < members[0]);
            for &d in members {
                if next < updates_ref.len() && updates_ref[next].device == d {
                    shard.fold_encoded(d, &updates_ref[next].upload, 1.0);
                    next += 1;
                } else {
                    shard.mark_dropped(d);
                }
            }
            shard
        });

        self.stats.download_requests = self.cache.requests();
        self.stats.download_encodes = self.cache.encodes();
        self.stats.cache_cross_round_hits = self.cache.cross_round_hits();

        let (agg, folded) =
            aggregate::reduce_shards_parallel(n_params, groups.len(), chunk, shards, workers)?;
        if folded != updates.len() {
            return Err(anyhow!(
                "aggregation folded {folded} updates but {} EndRound messages arrived",
                updates.len()
            ));
        }
        self.close_open_round(t);
        Ok(RoundOutput { agg, updates, dropped })
    }

    /// Close a drained external round **without** folding: the semi-async
    /// service takes the raw resolutions in canonical order and defers
    /// the aggregation to [`Engine::fold_round`] at close time, exactly
    /// like the in-process pipelined driver. Counts the round, mirrors
    /// the cache counters, and retires the round from the open window.
    /// Returns `(expected participants, updates, dropped)`, each sorted
    /// by device id.
    pub fn take_external(
        &mut self,
        round: ExternalRound,
    ) -> Result<(Vec<usize>, Vec<RoundUpdate>, Vec<DroppedDevice>)> {
        if !self.open_external.contains(&round.t) {
            return Err(anyhow!("take_external outside round {}", round.t));
        }
        if !round.drained() {
            return Err(anyhow!(
                "round {} still waiting on devices {:?}",
                round.t,
                round.pending()
            ));
        }
        let ExternalRound { t, expected, mut updates, mut dropped, .. } = round;
        updates.sort_by_key(|u| u.device);
        dropped.sort_by_key(|d| d.device);
        self.stats.download_requests = self.cache.requests();
        self.stats.download_encodes = self.cache.encodes();
        self.stats.cache_cross_round_hits = self.cache.cross_round_hits();
        self.close_open_round(t);
        Ok((expected, updates, dropped))
    }

    /// Retire round `t` from the open window and restore the phase: back
    /// to the newest still-open round, or Standby once the window drains.
    fn close_open_round(&mut self, t: usize) {
        self.open_external.remove(&t);
        self.phase = match self.open_external.iter().next_back() {
            Some(&r) => Phase::Round(r),
            None => Phase::Standby,
        };
        self.stats.rounds += 1;
    }

    /// The deferred aggregation fold of one semi-async round: fold the
    /// round's own on-time uploads in the canonical grouped order, skip
    /// its stragglers (their uploads park in the staleness buffer), and
    /// absorb prior rounds' late uploads whose fold round is this one as
    /// a single trailing shard. The tree shape is a function of the
    /// planned group count alone (always `groups + 1` here), so lateness
    /// changes WHAT the shards hold — never the f64 fold order — and the
    /// result is bit-identical at any worker count.
    ///
    /// `devices` is the round's planned participant set (ascending),
    /// `updates` its resolutions sorted by device, `on_time[i]` whether
    /// `updates[i]` folds now, and `late_ins` the absorbed uploads in
    /// (origin round, device) order. Returns the aggregate and the number
    /// of uploads folded (`on-time + late_ins`).
    pub fn fold_round(
        &self,
        n_params: usize,
        devices: &[usize],
        updates: &[RoundUpdate],
        on_time: &[bool],
        late_ins: &[LateUpload],
    ) -> Result<(ChunkedSum, usize)> {
        if updates.len() != on_time.len() {
            return Err(anyhow!(
                "fold_round: {} updates but {} on-time flags",
                updates.len(),
                on_time.len()
            ));
        }
        let group = self.cfg.agg_group.max(1);
        let chunk = self.cfg.agg_chunk;
        let groups: Vec<&[usize]> = devices.chunks(group).collect();
        let n_groups = groups.len();
        let workers = threadpool::workers(self.cfg.workers.max(1));
        let groups_ref: &[&[usize]] = &groups;
        let shards = threadpool::scope_map(n_groups + 1, workers, |g| {
            if g == n_groups {
                // the staleness shard: late uploads fold under synthetic
                // ascending slot ids (device ids may repeat across origins)
                let mut shard = AggregatorShard::with_chunk(
                    g,
                    n_params,
                    chunk,
                    (0..late_ins.len()).collect(),
                );
                for (slot, late) in late_ins.iter().enumerate() {
                    shard.fold_encoded(slot, &late.upload, 1.0);
                }
                return shard;
            }
            let members = groups_ref[g];
            let mut shard = AggregatorShard::with_chunk(g, n_params, chunk, members.to_vec());
            let mut next = updates.partition_point(|u| u.device < members[0]);
            for &d in members {
                if next < updates.len() && updates[next].device == d {
                    if on_time[next] {
                        shard.fold_encoded(d, &updates[next].upload, 1.0);
                    } else {
                        shard.mark_dropped(d);
                    }
                    next += 1;
                } else {
                    shard.mark_dropped(d);
                }
            }
            shard
        });
        aggregate::reduce_shards_parallel(n_params, n_groups + 1, chunk, shards, workers)
    }
}

/// Coordinator-side handler for one drained event. Must be
/// order-insensitive across devices: events from different worker
/// threads interleave nondeterministically.
fn apply_event(
    stats: &mut EngineStats,
    registry: &mut Registry,
    ev: Event,
    round_start_s: f64,
    reducer: &mut Option<ShardReducer>,
    updates: &mut Vec<RoundUpdate>,
    dropped: &mut Vec<DroppedDevice>,
) -> Result<()> {
    stats.messages += 1;
    match ev {
        Event::Device(DeviceMsg::Join { device }) => {
            registry.join(device, round_start_s);
        }
        Event::Device(DeviceMsg::Heartbeat { device, sim_t_s }) => {
            stats.heartbeats += 1;
            registry.heartbeat(device, sim_t_s);
        }
        Event::Device(DeviceMsg::EndRound(update)) => {
            registry.end_round(update.device, round_start_s + update.cost.total());
            updates.push(*update);
        }
        Event::Device(DeviceMsg::Dropout { device, after_s, down_wire_bits }) => {
            stats.dropouts += 1;
            registry.dropout(device, round_start_s + after_s);
            dropped.push(DroppedDevice { device, after_s, down_wire_bits });
        }
        Event::Shard(shard) => match reducer {
            Some(r) => r.push(shard)?,
            // unfolded rounds never emit shards; reaching here is a bug
            None => return Err(anyhow!("shard event in an unfolded round")),
        },
        Event::Error(msg) => return Err(anyhow!("engine worker failed: {msg}")),
    }
    Ok(())
}

/// Execute one aggregation group of devices in canonical (sorted) order,
/// folding each update into the group's shard as soon as it is produced.
/// Returns the group's event batch, ending with the finished shard.
#[allow(clippy::too_many_arguments)]
fn execute_group(
    env: &RoundEnv,
    items: &[StartRound],
    ecfg: &EngineConfig,
    group: usize,
    members: &[usize],
    trainer: &Trainer,
    codec: &CodecEngine,
    cache: &DownloadCache,
    fold: bool,
) -> Result<Vec<Event>> {
    let mut shard = fold.then(|| {
        let expect: Vec<usize> = members.iter().map(|&i| items[i].plan.device).collect();
        AggregatorShard::with_chunk(group, env.global.len(), ecfg.agg_chunk, expect)
    });
    let mut events = Vec::new();
    for &i in members {
        run_device(env, &items[i], ecfg, trainer, codec, cache, &mut events, shard.as_mut())?;
    }
    if let Some(shard) = shard {
        events.push(Event::Shard(shard));
    }
    Ok(events)
}

/// Simulate one device's round: serialize + transfer the download, (maybe)
/// drop out, decode + recover, local SGD, serialize the upload and fold
/// it into `shard`. Every payload that "crosses the wire" here really is
/// encoded to bytes and read back off them — traffic and transfer time
/// derive from the measured encoded lengths.
///
/// Hot-path reuse (three layers, all bit-transparent):
/// * the download bytes come from the engine's shared [`DownloadCache`]
///   (one encode per distinct codec per model generation, `Arc`-shared —
///   including across rounds while the model is unchanged);
/// * recovery writes into a pooled model buffer
///   (`recover_download_into` over a lazy `wire::PayloadView`) and the
///   gradient reuses a pooled buffer too — the O(n) scratch of a device
///   step is leased from `util::pool`, not allocated;
/// * the upload folds into the shard straight off its serialized bytes
///   (`fold_encoded`), so the decoded payload is never materialized.
#[allow(clippy::too_many_arguments)]
fn run_device(
    env: &RoundEnv,
    item: &StartRound,
    ecfg: &EngineConfig,
    trainer: &Trainer,
    codec: &CodecEngine,
    cache: &DownloadCache,
    events: &mut Vec<Event>,
    mut shard: Option<&mut AggregatorShard>,
) -> Result<()> {
    debug_assert_eq!(item.t, env.t, "StartRound round number disagrees with RoundEnv");
    let plan = item.plan;
    let d = plan.device;
    let mut dev_rng = Rng::stream(env.stream_base, env.t as u64, d as u64);
    let local = env.locals[d].as_deref();

    // (1) PS-side download encode (§4.1): the serialized bytes are the
    // wire truth, shared across every device with the same effective codec
    let down_codec = effective_download(plan.download, local.is_some());
    let down_enc =
        cache.get_or_encode(codec, down_codec, env.global, local.is_some(), &mut dev_rng)?;
    let down_wire_bits = down_enc.bits;
    let down_bits = env.scale.scale_bits(down_wire_bits);

    // Dropout lottery on an independent stream: enabling it never changes
    // the work randomness of devices that survive.
    if ecfg.dropout_rate > 0.0 {
        let mut fate = Rng::stream(env.stream_base ^ FATE_SALT, env.t as u64, d as u64);
        if fate.f64() < ecfg.dropout_rate {
            // the device vanishes partway through local training: the
            // download completed, the upload never happens
            let download_s = down_bits / item.beta_d;
            let compute_s = (plan.tau * plan.batch) as f64 * item.mu;
            let after_s = download_s + fate.f64() * compute_s;
            emit_heartbeats(events, ecfg, d, env.sim_now_s, after_s);
            events.push(Event::Device(DeviceMsg::Dropout {
                device: d,
                after_s,
                down_wire_bits,
            }));
            if let Some(shard) = shard.as_mut() {
                shard.mark_dropped(d);
            }
            return Ok(());
        }
    }

    // (2) device-side decode + recovery into a pooled model buffer, then
    // local training (Eq. 2) from the recovered initial model
    let mut model = pool::f32_buf();
    codec.recover_download_into(&down_enc, local, &mut model)?;
    drop(down_enc);
    let data_shard = &env.partition.shards[d];
    let (w_final, loss) = trainer.train(
        &model,
        env.train_ds,
        data_shard,
        plan.tau,
        plan.batch,
        env.lr,
        &mut dev_rng,
    )?;

    // (3) g_i = w_i^{t,0} − w_i^{t,τ} = η·Σ∇ (paper §2.1), in pooled
    // scratch — it only lives until the upload is serialized
    let mut g = pool::f32_buf();
    g.extend(model.iter().zip(&w_final).map(|(a, b)| a - b));
    drop(model);
    let grad_norm = g.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();

    // (4) upload compression (§4.2): the device serializes, the
    // coordinator-side shard folds straight off the serialized bytes —
    // sparsely for Top-K (O(kept)), with no decoded intermediate — and
    // the dense update never leaves this worker
    let up_enc = codec.encode_upload(plan.upload, &g, &mut dev_rng)?;
    drop(g);
    if let Some(shard) = shard.as_mut() {
        shard.fold_encoded(d, &up_enc, 1.0);
    }

    // (5) simulated cost (Eq. 7) from the measured wire lengths +
    // liveness traffic
    let cost = RoundCost::from_wire(
        down_wire_bits,
        up_enc.bits,
        env.scale,
        item.beta_d,
        item.beta_u,
        plan.tau,
        plan.batch,
        item.mu,
    );
    emit_heartbeats(events, ecfg, d, env.sim_now_s, cost.total());
    events.push(Event::Device(DeviceMsg::EndRound(Box::new(RoundUpdate {
        device: d,
        w_final,
        upload: up_enc,
        grad_norm,
        loss,
        down_wire_bits,
        cost,
    }))));
    Ok(())
}

/// Simulated-time heartbeat schedule of a device round lasting
/// `duration_s` seconds from `start_s`: one ping per `heartbeat_s`,
/// capped at [`MAX_HEARTBEATS`]. The single source of truth shared by the
/// in-process engine and the remote `transport::client` — both sides must
/// emit identical liveness traffic for the transport parity invariant.
pub(crate) fn heartbeat_schedule(
    heartbeat_s: f64,
    start_s: f64,
    duration_s: f64,
) -> impl Iterator<Item = f64> {
    let n = if heartbeat_s <= 0.0 {
        0
    } else {
        ((duration_s / heartbeat_s) as usize).min(MAX_HEARTBEATS)
    };
    (1..=n).map(move |k| start_s + k as f64 * heartbeat_s)
}

/// Emit the periodic liveness pings a device would send over a round
/// lasting `duration_s` simulated seconds.
fn emit_heartbeats(
    events: &mut Vec<Event>,
    ecfg: &EngineConfig,
    device: usize,
    start_s: f64,
    duration_s: f64,
) {
    for sim_t_s in heartbeat_schedule(ecfg.heartbeat_s, start_s, duration_s) {
        events.push(Event::Device(DeviceMsg::Heartbeat { device, sim_t_s }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_transitions_and_terminal_state() {
        let mut e = Engine::new(EngineConfig::default(), 8);
        assert_eq!(e.phase(), Phase::Standby);
        e.finish();
        assert_eq!(e.phase(), Phase::Finished);
        // a finished engine rejects rounds
        let cfg = ExperimentConfig::preset("har");
        let scale = PayloadScale::identity(4);
        let ds = Dataset::generate(
            &crate::data::TaskSpec::by_name("har").unwrap(),
            64,
            &mut Rng::new(0),
        );
        let part = crate::data::partition(&ds, 8, 0.0, &mut Rng::new(1));
        let global = vec![0.0f32; 4];
        let locals: Vec<Option<Vec<f32>>> = vec![None; 8];
        let env = RoundEnv {
            t: 1,
            lr: 0.1,
            cfg: &cfg,
            global: &global,
            model_version: 0,
            locals: &locals,
            train_ds: &ds,
            partition: &part,
            scale: &scale,
            stream_base: 7,
            sim_now_s: 0.0,
        };
        let exec = ExecutorHandle::Inline(Trainer::native("har"));
        let err = e.execute_round(&env, &[], &exec).unwrap_err();
        assert!(format!("{err}").contains("finished"), "{err}");
    }

    #[test]
    fn empty_round_yields_empty_output() {
        let mut e = Engine::new(EngineConfig::default(), 4);
        let cfg = ExperimentConfig::preset("har");
        let scale = PayloadScale::identity(4);
        let ds = Dataset::generate(
            &crate::data::TaskSpec::by_name("har").unwrap(),
            64,
            &mut Rng::new(0),
        );
        let part = crate::data::partition(&ds, 4, 0.0, &mut Rng::new(1));
        let global = vec![0.0f32; 4];
        let locals: Vec<Option<Vec<f32>>> = vec![None; 4];
        let env = RoundEnv {
            t: 1,
            lr: 0.1,
            cfg: &cfg,
            global: &global,
            model_version: 0,
            locals: &locals,
            train_ds: &ds,
            partition: &part,
            scale: &scale,
            stream_base: 7,
            sim_now_s: 0.0,
        };
        let exec = ExecutorHandle::Inline(Trainer::native("har"));
        let out = e.execute_round(&env, &[], &exec).unwrap();
        assert!(out.updates.is_empty() && out.dropped.is_empty());
        assert_eq!(out.agg.to_vec(), vec![0.0f64; 4]);
        assert_eq!(e.phase(), Phase::Standby);
        assert_eq!(e.stats().rounds, 1);
        // inline executor: exactly one trainer for the whole run
        assert_eq!(e.stats().trainer_builds, 1);
    }

    fn end_round_msg(device: usize, g: &[f32]) -> DeviceMsg {
        DeviceMsg::EndRound(Box::new(RoundUpdate {
            device,
            w_final: vec![0.5; g.len()],
            upload: crate::wire::Payload::Dense(g.to_vec()).encode(),
            grad_norm: 0.0,
            loss: 0.0,
            down_wire_bits: 64,
            cost: RoundCost { download_s: 1.0, compute_s: 2.0, upload_s: 3.0 },
        }))
    }

    #[test]
    fn external_round_replays_the_canonical_fold() {
        let ecfg = EngineConfig { agg_group: 2, ..EngineConfig::default() };
        let mut e = Engine::new(ecfg, 4);
        let mut round = e.begin_external(1, 0, 10.0, &[0, 1, 2], 3).unwrap();
        assert_eq!(e.phase(), Phase::Round(1));
        assert_eq!(e.registry().status(0), DeviceStatus::Training);
        assert!(!round.drained());
        assert_eq!(round.pending(), vec![0, 1, 2]);

        // arrival order scrambled on purpose: 1 ends, 2 drops, 0 ends
        e.external_msg(&mut round, end_round_msg(1, &[10.0, 20.0, 30.0])).unwrap();
        e.external_msg(
            &mut round,
            DeviceMsg::Dropout { device: 2, after_s: 0.5, down_wire_bits: 64 },
        )
        .unwrap();
        e.external_msg(&mut round, end_round_msg(0, &[1.0, 2.0, 3.0])).unwrap();
        assert!(round.drained());

        let out = e.finish_external(round).unwrap();
        // canonical order restored regardless of arrival order
        assert_eq!(out.updates.iter().map(|u| u.device).collect::<Vec<_>>(), vec![0, 1]);
        assert_eq!(out.dropped.iter().map(|d| d.device).collect::<Vec<_>>(), vec![2]);
        assert_eq!(out.agg.to_vec(), vec![11.0, 22.0, 33.0]);
        assert_eq!(e.phase(), Phase::Standby);
        assert_eq!(e.stats().rounds, 1);
        assert_eq!(e.stats().dropouts, 1);
        assert_eq!(e.registry().status(1), DeviceStatus::Idle);
        assert_eq!(e.registry().status(2), DeviceStatus::Dropped);
    }

    #[test]
    fn external_round_rejects_bad_input_without_corrupting_state() {
        let mut e = Engine::new(EngineConfig::default(), 4);
        // participants must be sorted/unique and in range
        assert!(e.begin_external(1, 0, 0.0, &[1, 0], 3).is_err());
        assert!(e.begin_external(1, 0, 0.0, &[0, 0], 3).is_err());
        assert!(e.begin_external(1, 0, 0.0, &[0, 9], 3).is_err());
        assert_eq!(e.phase(), Phase::Standby);

        let mut round = e.begin_external(1, 0, 0.0, &[0, 1], 3).unwrap();
        // a second round cannot open while one is in flight
        assert!(e.begin_external(2, 0, 0.0, &[0], 3).is_err());
        // closing before the round drains is refused
        let err = format!("{}", e.external_msg(&mut round, end_round_msg(2, &[0.0; 3])).unwrap_err());
        assert!(err.contains("not pending"), "{err}");
        // shape mismatches are rejections, not panics — and the device
        // stays pending so the service can retry or synthesize a Dropout
        assert!(e.external_msg(&mut round, end_round_msg(0, &[0.0; 5])).is_err());
        assert_eq!(round.pending(), vec![0, 1]);
        // a round that has not drained refuses to close
        let undrained = ExternalRound {
            t: 1,
            start_s: 0.0,
            n_params: 3,
            expected: vec![0, 1],
            pending: BTreeSet::from([1]),
            updates: Vec::new(),
            dropped: Vec::new(),
        };
        assert!(e.finish_external(undrained).is_err());
        e.external_msg(&mut round, end_round_msg(0, &[1.0, 1.0, 1.0])).unwrap();
        e.external_msg(&mut round, end_round_msg(1, &[1.0, 1.0, 1.0])).unwrap();
        // duplicate resolution is a rejection
        assert!(e.external_msg(&mut round, end_round_msg(1, &[1.0, 1.0, 1.0])).is_err());
        assert!(round.drained());
        let out = e.finish_external(round).unwrap();
        assert_eq!(out.updates.len(), 2);
    }

    #[test]
    fn heartbeat_emission_counts() {
        let ecfg = EngineConfig { heartbeat_s: 10.0, ..EngineConfig::default() };
        let mut events = Vec::new();
        emit_heartbeats(&mut events, &ecfg, 3, 100.0, 35.0);
        assert_eq!(events.len(), 3);
        match &events[0] {
            Event::Device(DeviceMsg::Heartbeat { device, sim_t_s }) => {
                assert_eq!(*device, 3);
                assert_eq!(*sim_t_s, 110.0);
            }
            other => panic!("unexpected event {other:?}"),
        }
        // disabled heartbeats emit nothing
        let off = EngineConfig { heartbeat_s: 0.0, ..EngineConfig::default() };
        let mut none = Vec::new();
        emit_heartbeats(&mut none, &off, 0, 0.0, 1e9);
        assert!(none.is_empty());
    }
}

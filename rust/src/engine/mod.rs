//! Event-driven round engine: the coordinator as a state machine
//! (`Standby → Round(t) → Finished`) over typed device messages, with the
//! per-device work of a round (decode download → local SGD → encode
//! upload) executed in parallel across a **persistent worker pool** and
//! aggregated through streaming, order-exact shards.
//!
//! ```text
//!                 Join/Heartbeat
//!                   ┌───────┐
//!                   ▼       │
//!   ┌─────────┐  StartRound{plan}   ┌──────────┐   finish()   ┌──────────┐
//!   │ Standby ├────────────────────▶│ Round(t) ├─────────────▶│ Finished │
//!   └─────────┘                     └────┬─────┘              └──────────┘
//!        ▲      EndRound{update} /       │
//!        └────── Dropout drained ◀───────┘
//! ```
//!
//! One `execute_round` call performs a full `Standby → Round(t) → Standby`
//! cycle: participants join the [`Registry`], each receives a
//! [`StartRound`] message, device work runs through the caller's
//! [`ExecutorHandle`] — inline on this thread, or batched onto a
//! [`WorkerPool`] of long-lived trainer threads — and [`DeviceMsg`]s
//! stream back to the coordinator loop which maintains liveness and
//! reduces [`AggregatorShard`]s in canonical order.
//!
//! **Run-lifetime resources.** The executor is built once per run and
//! survives every round: each pool worker owns its [`WorkerCtx`] (trainer
//! + PJRT runtime for the XLA backend) built by `WorkerPool::new`'s
//! `setup(worker_idx)` on the thread that keeps it, and the thread-local
//! `util::pool` scratch warms up once per worker instead of once per
//! round. `EngineStats::trainer_builds` mirrors the executor's build
//! count and stays O(workers) per run — the pre-pool engine paid
//! O(workers·rounds). A worker that panics is retired and surfaces as an
//! [`Event::Error`] (the round fails, the next one runs on the
//! survivors); it never deadlocks the drain.
//!
//! **Determinism contract.** For a fixed seed the engine's output is
//! bit-identical for ANY worker count, because every source of
//! nondeterminism is pinned:
//! * per-device randomness comes from pure [`Rng::stream`] keys
//!   `(base, t, device)` — no shared generator is advanced;
//! * devices execute in sorted-device-id order within fixed-size groups
//!   (`EngineConfig::agg_group`), and group partial sums reduce in group
//!   order ([`aggregate`]) — the same f64 reduction tree regardless of
//!   which thread runs what, when;
//! * coordinator-side application (traffic, locals, tracker) happens in
//!   sorted order after the round drains.
//!
//! The per-device hot path is reuse-dominated: the engine-owned
//! [`DownloadCache`] shares each distinct download encode across all
//! receivers — and, keyed by `(model_version, effective codec)`, across
//! *rounds* whenever the global model did not move (`Arc`'d bytes,
//! O(distinct codecs) encodes per model generation — RNG-drawing codecs
//! bypass it). Recovery and the gradient use pooled scratch
//! ([`crate::util::pool`]) written in place, and uploads fold into shards
//! straight off their serialized bytes. All three layers are
//! bit-transparent: the cached bytes are what a per-device encode would
//! have produced, and the in-place/streaming folds walk the exact same
//! element order as the eager decode.
//!
//! `tests/engine_parity.rs` pins this contract end-to-end.

pub mod aggregate;
pub mod cache;
pub mod message;
pub mod registry;

pub use aggregate::{AggregatorShard, ShardReducer};
pub use cache::DownloadCache;
pub use message::{DeviceMsg, DroppedDevice, Event, RoundUpdate, StartRound};
pub use registry::{DeviceStatus, Registry};

use std::path::Path;

use anyhow::{anyhow, Result};

use crate::compress::traffic::PayloadScale;
use crate::config::{EngineConfig, ExperimentConfig, TrainerBackend};
use crate::coordinator::codec::effective_download;
use crate::coordinator::{CodecEngine, EvalOutcome, Trainer};
use crate::data::{Dataset, Partition};
use crate::fleet::RoundCost;
use crate::util::pool;
use crate::util::rng::Rng;
use crate::util::threadpool::{self, WorkerPool};

/// Stream-key salt separating device "fate" draws (dropout lottery) from
/// device work draws, so enabling dropout never perturbs the randomness
/// of devices that complete.
const FATE_SALT: u64 = 0xD60_D60;

/// Upper bound on simulated heartbeats emitted per device per round.
const MAX_HEARTBEATS: usize = 1_000;

/// Coordinator state-machine phase.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Between rounds; devices may join, rounds may start.
    Standby,
    /// Executing round `t`.
    Round(usize),
    /// Terminal; no further rounds accepted.
    Finished,
}

/// Cumulative engine counters (diagnostics; surfaced by `caesar info`-style
/// tooling, tests and the benches' per-round metrics).
#[derive(Clone, Copy, Debug, Default)]
pub struct EngineStats {
    pub rounds: usize,
    pub messages: usize,
    pub heartbeats: usize,
    pub dropouts: usize,
    /// Downloads served (one per StartRound that reached encoding).
    pub download_requests: usize,
    /// Actual `encode_download` executions — with the generation-keyed
    /// [`DownloadCache`], O(distinct codecs) of `download_requests` per
    /// model version.
    pub download_encodes: usize,
    /// Download requests served from an encode carried across a round
    /// boundary (the global model did not change between rounds).
    pub cache_cross_round_hits: usize,
    /// Trainer constructions performed by the run's [`ExecutorHandle`] —
    /// O(workers) per RUN (pool setup builds them once), where the
    /// per-round scoped fan-out paid O(workers·rounds).
    pub trainer_builds: usize,
}

/// Read-only view of everything a device round needs from the server.
pub struct RoundEnv<'a> {
    /// 1-based round number.
    pub t: usize,
    /// Learning rate at this round.
    pub lr: f32,
    pub cfg: &'a ExperimentConfig,
    /// Current global model.
    pub global: &'a [f32],
    /// Monotone version of `global` — bumped by the driver whenever the
    /// model changes. Keys the cross-round [`DownloadCache`] generation:
    /// consecutive rounds at the same version reuse download encodes.
    pub model_version: u64,
    /// Per-device stale local models.
    pub locals: &'a [Option<Vec<f32>>],
    pub train_ds: &'a Dataset,
    pub partition: &'a Partition,
    pub scale: &'a PayloadScale,
    /// Base key of the pure per-(round, device) RNG streams.
    pub stream_base: u64,
    /// Simulated wall-clock at round start (registry timestamps).
    pub sim_now_s: f64,
}

/// The long-lived state a pool worker owns across rounds: its trainer
/// (and, for the XLA backend, the PJRT runtime inside it). Built once per
/// worker by `WorkerPool::new`'s setup, on the thread that keeps it —
/// PJRT runtimes are not `Send`, which is exactly why the pool constructs
/// and drops them in place. The borrow-based [`CodecEngine`] is rebuilt
/// per job from these owned parts (a few words, no allocation).
pub struct WorkerCtx {
    pub trainer: Trainer,
}

/// How rounds obtain trainers — the run-lifetime resource that replaced
/// the per-round `TrainerProvider` closures. Owned by the driver
/// (`coordinator::Server`) and reused across every round of a run.
pub enum ExecutorHandle {
    /// Execute rounds inline on the calling thread with this owned
    /// trainer (`engine.workers <= 1`, the parity baseline — also the
    /// pick when device counts are too small to amortize thread
    /// hand-off).
    Inline(Trainer),
    /// Execute rounds as job batches on a persistent pool of trainer
    /// threads; trainers, runtimes and thread-local scratch survive round
    /// boundaries.
    Pool(WorkerPool<WorkerCtx>),
}

impl ExecutorHandle {
    /// Build the executor for `cfg`: inline for `engine.workers <= 1`,
    /// otherwise a persistent pool of `threadpool::workers(engine.workers)`
    /// trainer threads. Trainers (and PJRT runtimes) are built once per
    /// worker for the whole run.
    pub fn build(cfg: &ExperimentConfig, artifact_dir: &Path) -> Result<ExecutorHandle> {
        let backend = cfg.trainer;
        let task = cfg.task.clone();
        let dir = artifact_dir.to_path_buf();
        let make = move || -> Result<Trainer> {
            match backend {
                TrainerBackend::Native => Ok(Trainer::native(&task)),
                TrainerBackend::Xla => Trainer::xla(&task, &dir),
            }
        };
        if cfg.engine.workers <= 1 {
            Ok(ExecutorHandle::Inline(make()?))
        } else {
            let n = threadpool::workers(cfg.engine.workers);
            let pool = WorkerPool::new(n, move |_wi| -> Result<WorkerCtx> {
                Ok(WorkerCtx { trainer: make()? })
            })?;
            Ok(ExecutorHandle::Pool(pool))
        }
    }

    /// Trainer constructions this executor has performed — 1 inline, or
    /// one per pool worker; flat in the number of rounds by construction
    /// (pinned by `tests/engine_parity.rs`).
    pub fn trainer_builds(&self) -> usize {
        match self {
            ExecutorHandle::Inline(_) => 1,
            ExecutorHandle::Pool(p) => p.builds(),
        }
    }

    /// Model size, from whichever trainer this executor owns (pool mode
    /// probes a worker — the coordinator thread holds no runtime).
    pub fn n_params(&self) -> Result<usize> {
        match self {
            ExecutorHandle::Inline(t) => Ok(t.n_params()),
            ExecutorHandle::Pool(p) => {
                let mut out = None;
                p.run_batch(1, |ctx, _| ctx.trainer.n_params(), |r| out = r.ok());
                out.ok_or_else(|| anyhow!("worker pool lost the n_params probe"))
            }
        }
    }

    /// Evaluate `w` on this executor's trainer. Pool mode runs the
    /// evaluation as a one-item batch on a worker thread, against that
    /// worker's long-lived trainer.
    pub fn eval(&self, w: &[f32], test: &Dataset) -> Result<EvalOutcome> {
        match self {
            ExecutorHandle::Inline(t) => t.eval(w, test),
            ExecutorHandle::Pool(p) => {
                let mut out = None;
                p.run_batch(1, |ctx, _| ctx.trainer.eval(w, test), |r| out = r.ok());
                out.ok_or_else(|| anyhow!("worker pool lost the eval job"))?
            }
        }
    }
}

/// What one executed round hands back to the driver.
pub struct RoundOutput {
    /// Canonical f64 sum of the (weighted) device updates.
    pub agg: Vec<f64>,
    /// Completed device rounds, sorted by device id.
    pub updates: Vec<RoundUpdate>,
    /// Devices that vanished mid-round, sorted by device id.
    pub dropped: Vec<DroppedDevice>,
}

/// The event-driven coordinator engine.
pub struct Engine {
    cfg: EngineConfig,
    phase: Phase,
    registry: Registry,
    stats: EngineStats,
    /// Cross-round download-encode cache, generation-keyed by the model
    /// version; shared by the inline and pool paths.
    cache: DownloadCache,
}

impl Engine {
    pub fn new(cfg: EngineConfig, n_devices: usize) -> Engine {
        Engine {
            registry: Registry::new(n_devices, cfg.heartbeat_s),
            phase: Phase::Standby,
            stats: EngineStats::default(),
            cache: DownloadCache::new(),
            cfg,
        }
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn stats(&self) -> EngineStats {
        self.stats
    }

    pub fn config(&self) -> EngineConfig {
        self.cfg
    }

    /// Transition to the terminal phase; later rounds are rejected.
    pub fn finish(&mut self) {
        // accounting-drift tripwire: cache counters are read after the
        // parallel section, and every encode serves exactly one request —
        // requests trailing encodes would mean the snapshot points drifted
        debug_assert!(
            self.stats.download_requests >= self.stats.download_encodes,
            "download accounting drift: {} requests < {} encodes",
            self.stats.download_requests,
            self.stats.download_encodes
        );
        self.phase = Phase::Finished;
    }

    /// Execute one full round: `Standby → Round(t) → Standby`.
    ///
    /// `items` are the coordinator→device [`StartRound`] messages, one per
    /// participant (any order — execution is canonicalized internally).
    /// `executor` is the run-lifetime trainer resource; pass the same
    /// handle every round so pool workers keep their state.
    pub fn execute_round(
        &mut self,
        env: &RoundEnv,
        items: &[StartRound],
        executor: &ExecutorHandle,
    ) -> Result<RoundOutput> {
        match self.phase {
            Phase::Standby => {}
            Phase::Round(r) => return Err(anyhow!("engine re-entered while in round {r}")),
            Phase::Finished => return Err(anyhow!("engine is finished; no further rounds")),
        }
        self.phase = Phase::Round(env.t);
        let out = self.round_inner(env, items, executor);
        self.phase = Phase::Standby;
        if out.is_ok() {
            self.stats.rounds += 1;
        }
        out
    }

    fn round_inner(
        &mut self,
        env: &RoundEnv,
        items: &[StartRound],
        executor: &ExecutorHandle,
    ) -> Result<RoundOutput> {
        let n_params = env.global.len();

        // trainers are run-lifetime resources: mirror the executor's build
        // count (flat across rounds by construction — the parity tests pin
        // it at O(workers) per run)
        self.stats.trainer_builds = executor.trainer_builds();
        // turn the encode-cache generation over: a changed model version
        // invalidates, an unchanged one carries entries across the round
        self.cache.begin_round(env.model_version);

        // Canonical execution order: item indices sorted by device id.
        let mut order: Vec<usize> = (0..items.len()).collect();
        order.sort_by_key(|&i| items[i].plan.device);

        // Split the engine into independent parts: the shared cache is
        // read by worker closures while stats/registry mutate on the
        // coordinator side of the drain.
        let Engine { cfg, registry, stats, cache, .. } = self;
        let cache: &DownloadCache = cache;

        // Rendezvous + kickoff bookkeeping (coordinator-side sends).
        for &i in &order {
            let d = items[i].plan.device;
            registry.join(d, env.sim_now_s);
            registry.start_round(d, env.sim_now_s);
            stats.messages += 2; // Join ack + StartRound
        }

        let group = cfg.agg_group.max(1);
        let groups: Vec<&[usize]> = order.chunks(group).collect();
        let n_groups = groups.len();
        let ecfg = *cfg;

        let mut reducer = ShardReducer::new(n_params, n_groups);
        let mut updates: Vec<RoundUpdate> = Vec::with_capacity(order.len());
        let mut dropped: Vec<DroppedDevice> = Vec::new();
        let mut worker_err: Option<anyhow::Error> = None;

        match executor {
            ExecutorHandle::Inline(trainer) => {
                let codec =
                    CodecEngine::new(env.cfg.compression, trainer.runtime(), &env.cfg.task)?;
                for (g, members) in groups.iter().enumerate() {
                    let events =
                        execute_group(env, items, &ecfg, g, members, trainer, &codec, cache)?;
                    for ev in events {
                        apply_event(
                            stats,
                            registry,
                            ev,
                            env.sim_now_s,
                            &mut reducer,
                            &mut updates,
                            &mut dropped,
                        )?;
                    }
                }
            }
            ExecutorHandle::Pool(pool) => {
                let groups = &groups;
                pool.run_batch(
                    n_groups,
                    |ctx: &mut WorkerCtx, g: usize| -> Vec<Event> {
                        // the codec engine is a borrow of the worker's
                        // owned trainer/runtime — rebuilt per job for free
                        let codec = match CodecEngine::new(
                            env.cfg.compression,
                            ctx.trainer.runtime(),
                            &env.cfg.task,
                        ) {
                            Ok(c) => c,
                            Err(e) => return vec![Event::Error(format!("worker codec: {e:#}"))],
                        };
                        match execute_group(
                            env,
                            items,
                            &ecfg,
                            g,
                            groups[g],
                            &ctx.trainer,
                            &codec,
                            cache,
                        ) {
                            Ok(events) => events,
                            Err(e) => vec![Event::Error(format!("group {g}: {e:#}"))],
                        }
                    },
                    |res| {
                        let events = match res {
                            Ok(events) => events,
                            // the worker running this group panicked (it
                            // has been retired from the pool): surface as
                            // an error event, exactly like a worker-side
                            // failure — the drain itself never blocks
                            Err(lost) => vec![Event::Error(format!(
                                "worker died running group {}",
                                lost.item
                            ))],
                        };
                        for ev in events {
                            if let Err(e) = apply_event(
                                stats,
                                registry,
                                ev,
                                env.sim_now_s,
                                &mut reducer,
                                &mut updates,
                                &mut dropped,
                            ) {
                                if worker_err.is_none() {
                                    worker_err = Some(e);
                                }
                            }
                        }
                    },
                );
                if let Some(e) = worker_err {
                    return Err(e);
                }
            }
        }

        // Canonical application order for the driver.
        updates.sort_by_key(|u| u.device);
        dropped.sort_by_key(|d| d.device);

        // Mirror the cache's cumulative counters (deterministic at any
        // worker count: misses encode under the cache lock).
        stats.download_requests = cache.requests();
        stats.download_encodes = cache.encodes();
        stats.cache_cross_round_hits = cache.cross_round_hits();

        let (agg, folded) = reducer.finish()?;
        if folded != updates.len() {
            return Err(anyhow!(
                "aggregation folded {folded} updates but {} EndRound messages arrived",
                updates.len()
            ));
        }
        Ok(RoundOutput { agg, updates, dropped })
    }
}

/// Coordinator-side handler for one drained event. Must be
/// order-insensitive across devices: events from different worker
/// threads interleave nondeterministically.
fn apply_event(
    stats: &mut EngineStats,
    registry: &mut Registry,
    ev: Event,
    round_start_s: f64,
    reducer: &mut ShardReducer,
    updates: &mut Vec<RoundUpdate>,
    dropped: &mut Vec<DroppedDevice>,
) -> Result<()> {
    stats.messages += 1;
    match ev {
        Event::Device(DeviceMsg::Join { device }) => {
            registry.join(device, round_start_s);
        }
        Event::Device(DeviceMsg::Heartbeat { device, sim_t_s }) => {
            stats.heartbeats += 1;
            registry.heartbeat(device, sim_t_s);
        }
        Event::Device(DeviceMsg::EndRound(update)) => {
            registry.end_round(update.device, round_start_s + update.cost.total());
            updates.push(*update);
        }
        Event::Device(DeviceMsg::Dropout { device, after_s, down_wire_bits }) => {
            stats.dropouts += 1;
            registry.dropout(device, round_start_s + after_s);
            dropped.push(DroppedDevice { device, after_s, down_wire_bits });
        }
        Event::Shard(shard) => reducer.push(shard)?,
        Event::Error(msg) => return Err(anyhow!("engine worker failed: {msg}")),
    }
    Ok(())
}

/// Execute one aggregation group of devices in canonical (sorted) order,
/// folding each update into the group's shard as soon as it is produced.
/// Returns the group's event batch, ending with the finished shard.
#[allow(clippy::too_many_arguments)]
fn execute_group(
    env: &RoundEnv,
    items: &[StartRound],
    ecfg: &EngineConfig,
    group: usize,
    members: &[usize],
    trainer: &Trainer,
    codec: &CodecEngine,
    cache: &DownloadCache,
) -> Result<Vec<Event>> {
    let expect: Vec<usize> = members.iter().map(|&i| items[i].plan.device).collect();
    let mut shard = AggregatorShard::new(group, env.global.len(), expect);
    let mut events = Vec::new();
    for &i in members {
        run_device(env, &items[i], ecfg, trainer, codec, cache, &mut events, &mut shard)?;
    }
    events.push(Event::Shard(shard));
    Ok(events)
}

/// Simulate one device's round: serialize + transfer the download, (maybe)
/// drop out, decode + recover, local SGD, serialize the upload and fold
/// it into `shard`. Every payload that "crosses the wire" here really is
/// encoded to bytes and read back off them — traffic and transfer time
/// derive from the measured encoded lengths.
///
/// Hot-path reuse (three layers, all bit-transparent):
/// * the download bytes come from the engine's shared [`DownloadCache`]
///   (one encode per distinct codec per model generation, `Arc`-shared —
///   including across rounds while the model is unchanged);
/// * recovery writes into a pooled model buffer
///   (`recover_download_into` over a lazy `wire::PayloadView`) and the
///   gradient reuses a pooled buffer too — the O(n) scratch of a device
///   step is leased from `util::pool`, not allocated;
/// * the upload folds into the shard straight off its serialized bytes
///   (`fold_encoded`), so the decoded payload is never materialized.
#[allow(clippy::too_many_arguments)]
fn run_device(
    env: &RoundEnv,
    item: &StartRound,
    ecfg: &EngineConfig,
    trainer: &Trainer,
    codec: &CodecEngine,
    cache: &DownloadCache,
    events: &mut Vec<Event>,
    shard: &mut AggregatorShard,
) -> Result<()> {
    debug_assert_eq!(item.t, env.t, "StartRound round number disagrees with RoundEnv");
    let plan = item.plan;
    let d = plan.device;
    let mut dev_rng = Rng::stream(env.stream_base, env.t as u64, d as u64);
    let local = env.locals[d].as_deref();

    // (1) PS-side download encode (§4.1): the serialized bytes are the
    // wire truth, shared across every device with the same effective codec
    let down_codec = effective_download(plan.download, local.is_some());
    let down_enc =
        cache.get_or_encode(codec, down_codec, env.global, local.is_some(), &mut dev_rng)?;
    let down_wire_bits = down_enc.bits;
    let down_bits = env.scale.scale_bits(down_wire_bits);

    // Dropout lottery on an independent stream: enabling it never changes
    // the work randomness of devices that survive.
    if ecfg.dropout_rate > 0.0 {
        let mut fate = Rng::stream(env.stream_base ^ FATE_SALT, env.t as u64, d as u64);
        if fate.f64() < ecfg.dropout_rate {
            // the device vanishes partway through local training: the
            // download completed, the upload never happens
            let download_s = down_bits / item.beta_d;
            let compute_s = (plan.tau * plan.batch) as f64 * item.mu;
            let after_s = download_s + fate.f64() * compute_s;
            emit_heartbeats(events, ecfg, d, env.sim_now_s, after_s);
            events.push(Event::Device(DeviceMsg::Dropout {
                device: d,
                after_s,
                down_wire_bits,
            }));
            shard.mark_dropped(d);
            return Ok(());
        }
    }

    // (2) device-side decode + recovery into a pooled model buffer, then
    // local training (Eq. 2) from the recovered initial model
    let mut model = pool::f32_buf();
    codec.recover_download_into(&down_enc, local, &mut model)?;
    drop(down_enc);
    let data_shard = &env.partition.shards[d];
    let (w_final, loss) = trainer.train(
        &model,
        env.train_ds,
        data_shard,
        plan.tau,
        plan.batch,
        env.lr,
        &mut dev_rng,
    )?;

    // (3) g_i = w_i^{t,0} − w_i^{t,τ} = η·Σ∇ (paper §2.1), in pooled
    // scratch — it only lives until the upload is serialized
    let mut g = pool::f32_buf();
    g.extend(model.iter().zip(&w_final).map(|(a, b)| a - b));
    drop(model);
    let grad_norm = g.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();

    // (4) upload compression (§4.2): the device serializes, the
    // coordinator-side shard folds straight off the serialized bytes —
    // sparsely for Top-K (O(kept)), with no decoded intermediate — and
    // the dense update never leaves this worker
    let up_enc = codec.encode_upload(plan.upload, &g, &mut dev_rng)?;
    drop(g);
    shard.fold_encoded(d, &up_enc, 1.0);

    // (5) simulated cost (Eq. 7) from the measured wire lengths +
    // liveness traffic
    let cost = RoundCost::from_wire(
        down_wire_bits,
        up_enc.bits,
        env.scale,
        item.beta_d,
        item.beta_u,
        plan.tau,
        plan.batch,
        item.mu,
    );
    emit_heartbeats(events, ecfg, d, env.sim_now_s, cost.total());
    events.push(Event::Device(DeviceMsg::EndRound(Box::new(RoundUpdate {
        device: d,
        w_final,
        upload: up_enc,
        grad_norm,
        loss,
        down_wire_bits,
        cost,
    }))));
    Ok(())
}

/// Emit the periodic liveness pings a device would send over a round
/// lasting `duration_s` simulated seconds.
fn emit_heartbeats(
    events: &mut Vec<Event>,
    ecfg: &EngineConfig,
    device: usize,
    start_s: f64,
    duration_s: f64,
) {
    if ecfg.heartbeat_s <= 0.0 {
        return;
    }
    let n = ((duration_s / ecfg.heartbeat_s) as usize).min(MAX_HEARTBEATS);
    for k in 1..=n {
        events.push(Event::Device(DeviceMsg::Heartbeat {
            device,
            sim_t_s: start_s + k as f64 * ecfg.heartbeat_s,
        }));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phase_transitions_and_terminal_state() {
        let mut e = Engine::new(EngineConfig::default(), 8);
        assert_eq!(e.phase(), Phase::Standby);
        e.finish();
        assert_eq!(e.phase(), Phase::Finished);
        // a finished engine rejects rounds
        let cfg = ExperimentConfig::preset("har");
        let scale = PayloadScale::identity(4);
        let ds = Dataset::generate(
            &crate::data::TaskSpec::by_name("har").unwrap(),
            64,
            &mut Rng::new(0),
        );
        let part = crate::data::partition(&ds, 8, 0.0, &mut Rng::new(1));
        let global = vec![0.0f32; 4];
        let locals: Vec<Option<Vec<f32>>> = vec![None; 8];
        let env = RoundEnv {
            t: 1,
            lr: 0.1,
            cfg: &cfg,
            global: &global,
            model_version: 0,
            locals: &locals,
            train_ds: &ds,
            partition: &part,
            scale: &scale,
            stream_base: 7,
            sim_now_s: 0.0,
        };
        let exec = ExecutorHandle::Inline(Trainer::native("har"));
        let err = e.execute_round(&env, &[], &exec).unwrap_err();
        assert!(format!("{err}").contains("finished"), "{err}");
    }

    #[test]
    fn empty_round_yields_empty_output() {
        let mut e = Engine::new(EngineConfig::default(), 4);
        let cfg = ExperimentConfig::preset("har");
        let scale = PayloadScale::identity(4);
        let ds = Dataset::generate(
            &crate::data::TaskSpec::by_name("har").unwrap(),
            64,
            &mut Rng::new(0),
        );
        let part = crate::data::partition(&ds, 4, 0.0, &mut Rng::new(1));
        let global = vec![0.0f32; 4];
        let locals: Vec<Option<Vec<f32>>> = vec![None; 4];
        let env = RoundEnv {
            t: 1,
            lr: 0.1,
            cfg: &cfg,
            global: &global,
            model_version: 0,
            locals: &locals,
            train_ds: &ds,
            partition: &part,
            scale: &scale,
            stream_base: 7,
            sim_now_s: 0.0,
        };
        let exec = ExecutorHandle::Inline(Trainer::native("har"));
        let out = e.execute_round(&env, &[], &exec).unwrap();
        assert!(out.updates.is_empty() && out.dropped.is_empty());
        assert_eq!(out.agg, vec![0.0f64; 4]);
        assert_eq!(e.phase(), Phase::Standby);
        assert_eq!(e.stats().rounds, 1);
        // inline executor: exactly one trainer for the whole run
        assert_eq!(e.stats().trainer_builds, 1);
    }

    #[test]
    fn heartbeat_emission_counts() {
        let ecfg = EngineConfig { heartbeat_s: 10.0, ..EngineConfig::default() };
        let mut events = Vec::new();
        emit_heartbeats(&mut events, &ecfg, 3, 100.0, 35.0);
        assert_eq!(events.len(), 3);
        match &events[0] {
            Event::Device(DeviceMsg::Heartbeat { device, sim_t_s }) => {
                assert_eq!(*device, 3);
                assert_eq!(*sim_t_s, 110.0);
            }
            other => panic!("unexpected event {other:?}"),
        }
        // disabled heartbeats emit nothing
        let off = EngineConfig { heartbeat_s: 0.0, ..EngineConfig::default() };
        let mut none = Vec::new();
        emit_heartbeats(&mut none, &off, 0, 0.0, 1e9);
        assert!(none.is_empty());
    }
}

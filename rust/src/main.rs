//! `caesar` — leader entrypoint and CLI.
//!
//! Usage:
//!   caesar run scheme=<name> task=<cifar|har|speech|oppo> [key=value ...]
//!   caesar replay journal=<path>   # offline digest cross-check, no trainer
//!   caesar <fig1|fig1c|fig1d|fig5|fig8|fig9|fig10|table3|all> [overrides]
//!   caesar info            # artifact/runtime inventory
//!   caesar list            # schemes, tasks, experiments
//!
//! Common overrides: rounds= alpha= tau= batch= lr= p= theta-min= theta-max=
//! lambda= clusters= devices= seed= target= eval-every= n-train=
//! trainer=xla|native compression-backend=native|xla out=<dir> quiet
//! Engine knobs:     engine-workers= agg-group= dropout= heartbeat=
//!                   pipeline-depth= staleness-bound=   (semi-async rounds)
//! Durability:       journal=<path> journal-every=K journal-kill-after=N

use anyhow::Result;

use caesar_fl::config::ExperimentConfig;
use caesar_fl::coordinator::{RoundRecord, Server};
use caesar_fl::experiments;
use caesar_fl::journal::{self, KillSink};
use caesar_fl::runtime::Runtime;
use caesar_fl::schemes;
use caesar_fl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    match args.subcommand.as_deref() {
        Some("run") => cmd_run(args),
        Some("replay") => cmd_replay(args),
        Some("info") => cmd_info(),
        Some("list") | None => cmd_list(),
        Some(exp) => experiments::run_by_name(exp, args),
    }
}

fn cmd_run(args: &Args) -> Result<()> {
    let task = args.get_or("task", "cifar");
    let scheme_name = args.get_or("scheme", "caesar");
    let cfg = ExperimentConfig::preset(task).apply_overrides(args);
    let scheme = schemes::by_name(scheme_name)
        .ok_or_else(|| anyhow::anyhow!("unknown scheme {scheme_name} (try `caesar list`)"))?;
    let use_auc = task == "oppo";
    println!(
        "run: scheme={scheme_name} task={task} rounds={} devices={} alpha={} p={} trainer={:?}",
        cfg.rounds,
        cfg.n_devices(),
        cfg.alpha,
        cfg.het_p,
        cfg.trainer
    );
    let quiet = args.has_flag("quiet");
    let every = args.get_usize("print-every").unwrap_or(10);
    let mut progress = |r: &RoundRecord| {
        if !quiet && (r.t % every == 0 || r.t == 1) && !r.accuracy.is_nan() {
            println!(
                "  round {:>4}  acc={:.4}  auc={:.4}  loss={:.4}  time={:>8.1}s  traffic={:.3}GB  wait={:.2}s",
                r.t, r.accuracy, r.auc, r.mean_loss, r.sim_time_s, r.traffic_gb, r.avg_wait_s
            );
        }
    };
    let result = match args.get("journal") {
        Some(jpath) => {
            let snap_every = args.get_usize("journal-every").unwrap_or(10);
            let path = std::path::Path::new(jpath);
            let (mut srv, mut jw) = Server::journaled_open(cfg, scheme, path, snap_every)?;
            if jw.is_fresh() {
                println!("journal: fresh run -> {}", path.display());
            } else {
                println!(
                    "journal: resuming after round {} from {}",
                    jw.prior_rounds(),
                    path.display()
                );
            }
            if let Some(k) = args.get_usize("journal-kill-after") {
                // fault injection for the durability smoke: the k-th
                // append tears mid-frame and the process dies with an
                // error exit — a subsequent run with the same journal=
                // must resume and finish bit-identically
                println!("journal: fault injection armed, dying at append #{k}");
                jw.map_sink(|s| Box::new(KillSink::new(s, k, 3)));
            }
            srv.run_journaled_cb(&mut jw, &mut progress)?
        }
        None => {
            let mut srv = Server::new(cfg, scheme)?;
            srv.run_cb(&mut progress)?
        }
    };
    println!(
        "final: metric={:.4}  time={:.1}s(sim)  traffic={:.3}GB  mean-wait={:.2}s",
        result.final_metric(use_auc),
        result.total_time_s(),
        result.total_traffic_gb(),
        result.mean_wait_s()
    );
    if let Some((t, time, gb)) = result.reached_target {
        println!(
            "target {:.2} reached at round {t}: {:.1}s(sim), {:.3}GB",
            result.target, time, gb
        );
    } else {
        println!("target {:.2} not reached", result.target);
    }
    let dir = experiments::out_dir(args).join("run");
    result.save(&dir, "")?;
    println!("saved per-round CSV/JSON under {}", dir.display());
    Ok(())
}

/// Offline replay verification: re-derive the run from its journal alone
/// (no trainer, no fleet) and cross-check every recorded digest, traffic
/// bit-count and round record. Exits non-zero on any mismatch.
fn cmd_replay(args: &Args) -> Result<()> {
    let jpath = args
        .get("journal")
        .or_else(|| args.positional.first().map(|s| s.as_str()))
        .ok_or_else(|| anyhow::anyhow!("usage: caesar replay journal=<path>"))?;
    let path = std::path::Path::new(jpath);
    let (recovered, bytes) = journal::recover_file(path)?;
    if bytes.is_empty() {
        return Err(anyhow::anyhow!("journal {} is missing or empty", path.display()));
    }
    println!(
        "journal {}: {} records, {} valid bytes, {} torn bytes discarded",
        path.display(),
        recovered.records.len(),
        recovered.valid_len,
        recovered.discarded(bytes.len()),
    );
    let summary = journal::verify(&recovered.records)
        .map_err(|e| anyhow::anyhow!("replay verification FAILED: {e:#}"))?;
    println!(
        "replay OK: {} rounds, {} digests cross-checked, {} snapshots, {} late uploads{}",
        summary.rounds,
        summary.digests_checked,
        summary.snapshots,
        summary.late_uploads,
        if summary.partial_tail { " (journal ends mid-round)" } else { "" },
    );
    println!("  final model digest {:016x}", summary.final_model_digest);
    println!(
        "  traffic: {} bits down, {} bits up; sim time {:.1}s",
        summary.down_bits, summary.up_bits, summary.sim_time_s
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    let dir = Runtime::default_dir();
    println!("artifact dir: {}", dir.display());
    match Runtime::open(&dir) {
        Ok(rt) => {
            let m = rt.manifest();
            println!("train chunk={} eval_chunk={}", m.chunk, m.eval_chunk);
            let mut names: Vec<&str> = m.module_names().collect();
            names.sort();
            println!("{} modules:", names.len());
            for n in names {
                println!("  {n}");
            }
        }
        Err(e) => println!("runtime unavailable ({e}); native trainer still works"),
    }
    Ok(())
}

fn cmd_list() -> Result<()> {
    println!("schemes:      fedavg flexcom prowd pyramidfl caesar caesar-br caesar-dc");
    println!("              nocomp gm-fic gm-cac lg-fic lg-cac");
    println!("tasks:        cifar har speech oppo");
    println!("experiments:  fig1 fig1c fig1d fig5 (=fig6/fig7/table3) fig8 fig9 fig10 all");
    println!("extensions:   ablation-k ablation-lambda");
    println!("also:         run scheme=<s> task=<t> [key=value ...] | info");
    println!("              replay journal=<path>   (offline digest cross-check)");
    println!("engine knobs: engine-workers= agg-group= dropout= heartbeat=");
    println!("semi-async:   pipeline-depth= staleness-bound=  (1/0 = barrier)");
    println!("durability:   journal= journal-every= journal-kill-after=");
    Ok(())
}

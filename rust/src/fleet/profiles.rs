//! Device class profiles, derived from the paper's Tables 1–2.
//!
//! `base_mu_s` is the per-sample training latency (seconds) of the class in
//! its fastest mode for the reference model (the CIFAR stand-in; other
//! models scale it by their `model_cost`). Mode multipliers reproduce the
//! paper's configurable power modes (TX2: 4 modes, NX/AGX: 8 modes,
//! phones: normal + power-saving) and its observed ≈100× μ spread between
//! AGX mode-0 and TX2's slowest mode.

/// Hardware class of a simulated device.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DeviceClass {
    JetsonTX2,
    JetsonNX,
    JetsonAGX,
    PhoneA1,
    PhoneReno9,
    PhoneFindX6,
}

/// Static per-class profile.
#[derive(Clone, Debug)]
pub struct Profile {
    pub name: &'static str,
    /// Per-sample latency in the fastest mode, reference model (seconds).
    pub base_mu_s: f64,
    /// Slow-down factor per power mode (index 0 = fastest).
    pub mode_multipliers: &'static [f64],
}

const TX2_MODES: &[f64] = &[1.0, 2.0, 8.0, 25.0];
const NX_MODES: &[f64] = &[1.0, 1.4, 2.0, 2.8, 4.0, 5.6, 8.0, 11.0];
const AGX_MODES: &[f64] = &[1.0, 1.3, 1.8, 2.4, 3.2, 4.2, 5.6, 7.5];
const PHONE_MODES: &[f64] = &[1.0, 3.0];

impl DeviceClass {
    pub fn profile(&self) -> Profile {
        match self {
            // Jetson: AI perf 1.33 TFLOPs (TX2) vs 21 TOPs (NX) vs 32 TOPs
            // (AGX) → base μ ordering AGX < NX < TX2.
            DeviceClass::JetsonTX2 => Profile {
                name: "jetson-tx2",
                base_mu_s: 4.0e-3,
                mode_multipliers: TX2_MODES,
            },
            DeviceClass::JetsonNX => Profile {
                name: "jetson-nx",
                base_mu_s: 1.8e-3,
                mode_multipliers: NX_MODES,
            },
            DeviceClass::JetsonAGX => Profile {
                name: "jetson-agx",
                base_mu_s: 1.0e-3,
                mode_multipliers: AGX_MODES,
            },
            // Phones: 486 GFLOPs (A1) vs 844 (Reno9) vs 3482 (FindX6).
            DeviceClass::PhoneA1 => Profile {
                name: "oppo-a1",
                base_mu_s: 8.0e-3,
                mode_multipliers: PHONE_MODES,
            },
            DeviceClass::PhoneReno9 => Profile {
                name: "oppo-reno9",
                base_mu_s: 4.6e-3,
                mode_multipliers: PHONE_MODES,
            },
            DeviceClass::PhoneFindX6 => Profile {
                name: "oppo-findx6",
                base_mu_s: 1.1e-3,
                mode_multipliers: PHONE_MODES,
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mode_counts_match_paper() {
        assert_eq!(DeviceClass::JetsonTX2.profile().mode_multipliers.len(), 4);
        assert_eq!(DeviceClass::JetsonNX.profile().mode_multipliers.len(), 8);
        assert_eq!(DeviceClass::JetsonAGX.profile().mode_multipliers.len(), 8);
        assert_eq!(DeviceClass::PhoneA1.profile().mode_multipliers.len(), 2);
    }

    #[test]
    fn perf_ordering_matches_spec_tables() {
        let mu = |c: DeviceClass| c.profile().base_mu_s;
        assert!(mu(DeviceClass::JetsonAGX) < mu(DeviceClass::JetsonNX));
        assert!(mu(DeviceClass::JetsonNX) < mu(DeviceClass::JetsonTX2));
        assert!(mu(DeviceClass::PhoneFindX6) < mu(DeviceClass::PhoneReno9));
        assert!(mu(DeviceClass::PhoneReno9) < mu(DeviceClass::PhoneA1));
    }

    #[test]
    fn mode_multipliers_start_at_one_and_increase() {
        for c in [
            DeviceClass::JetsonTX2,
            DeviceClass::JetsonNX,
            DeviceClass::JetsonAGX,
            DeviceClass::PhoneA1,
        ] {
            let m = c.profile().mode_multipliers;
            assert_eq!(m[0], 1.0);
            for w in m.windows(2) {
                assert!(w[0] < w[1]);
            }
        }
    }
}

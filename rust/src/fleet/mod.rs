//! Device fleet simulator — the stand-in for the paper's two physical
//! prototypes (80 NVIDIA Jetson kits, 40 OPPO smartphones).
//!
//! The FL coordinator only consumes two quantities per device per round:
//! the per-sample training latency `μ_i^t` (heterogeneous and time-varying
//! power modes, §6.1 "up to 100× difference", re-rolled every 20 rounds)
//! and the download/upload bandwidths `β_{d,i}^t, β_{u,i}^t` (four WiFi
//! distance groups, per-round fluctuation within [1, 30] Mb/s). This module
//! reproduces exactly those distributions; see DESIGN.md §Substitutions.

pub mod network;
pub mod profiles;

pub use network::{BandwidthModel, NetworkGroup};
pub use profiles::{DeviceClass, Profile};

use crate::util::rng::Rng;

/// Rounds between power-mode re-rolls (paper §6.1: every 20 rounds).
pub const MODE_REROLL_ROUNDS: usize = 20;

/// One simulated device.
#[derive(Clone, Debug)]
pub struct Device {
    pub id: usize,
    pub class: DeviceClass,
    pub group: NetworkGroup,
    /// Current power-mode index into `class.profile().mode_multipliers`.
    pub mode: usize,
    rng: Rng,
}

impl Device {
    /// Per-sample compute latency (seconds) in the current mode, for a
    /// model with relative cost `model_cost` (1.0 = the CIFAR stand-in).
    pub fn mu(&self, model_cost: f64) -> f64 {
        let p = self.class.profile();
        p.base_mu_s * p.mode_multipliers[self.mode] * model_cost
    }

    /// Re-roll the power mode (uniform over the class's modes).
    pub fn reroll_mode(&mut self) {
        let n = self.class.profile().mode_multipliers.len();
        self.mode = self.rng.below(n);
    }

    /// Draw this round's (download, upload) bandwidth in bit/s.
    ///
    /// Takes the caller's per-(round, device) RNG stream rather than the
    /// device's own generator: bandwidth draws must be a pure function of
    /// `(seed, round, device)` so the round engine can evaluate them in
    /// any order (or in parallel) with bit-identical results. The device's
    /// internal RNG is reserved for fleet dynamics (power-mode re-rolls).
    pub fn draw_bandwidth(&self, model: &BandwidthModel, rng: &mut Rng) -> (f64, f64) {
        model.draw(self.group, rng)
    }
}

/// The whole fleet plus its shared dynamics.
pub struct Fleet {
    pub devices: Vec<Device>,
    pub bandwidth: BandwidthModel,
}

/// Fleet presets matching the paper's prototypes.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FleetKind {
    /// 30 TX2 + 40 NX + 10 AGX (image/HAR/speech experiments).
    Jetson80,
    /// 15 A1 + 15 Reno9 + 10 FindX6 (OPPO-TS experiments).
    Phone40,
    /// Fig. 10 scale-out: Jetson proportions replicated to `n` devices.
    JetsonScaled(usize),
}

impl Fleet {
    pub fn new(kind: FleetKind, seed: u64) -> Fleet {
        let mut rng = Rng::new(seed ^ 0xF1EE7);
        let classes: Vec<DeviceClass> = match kind {
            FleetKind::Jetson80 => Self::mix(
                &[
                    (DeviceClass::JetsonTX2, 30),
                    (DeviceClass::JetsonNX, 40),
                    (DeviceClass::JetsonAGX, 10),
                ],
            ),
            FleetKind::Phone40 => Self::mix(
                &[
                    (DeviceClass::PhoneA1, 15),
                    (DeviceClass::PhoneReno9, 15),
                    (DeviceClass::PhoneFindX6, 10),
                ],
            ),
            FleetKind::JetsonScaled(n) => {
                // keep 3:4:1 proportions
                let tx2 = n * 3 / 8;
                let agx = n / 8;
                let nx = n - tx2 - agx;
                Self::mix(&[
                    (DeviceClass::JetsonTX2, tx2),
                    (DeviceClass::JetsonNX, nx),
                    (DeviceClass::JetsonAGX, agx),
                ])
            }
        };
        let n = classes.len();
        let devices = classes
            .into_iter()
            .enumerate()
            .map(|(id, class)| {
                let mut drng = rng.fork(id as u64);
                let group = NetworkGroup::from_index(id * 4 / n);
                let mode = drng.below(class.profile().mode_multipliers.len());
                Device { id, class, group, mode, rng: drng }
            })
            .collect();
        Fleet { devices, bandwidth: BandwidthModel::default() }
    }

    fn mix(spec: &[(DeviceClass, usize)]) -> Vec<DeviceClass> {
        let mut v = vec![];
        for &(c, n) in spec {
            v.extend(std::iter::repeat(c).take(n));
        }
        v
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    /// Advance fleet dynamics to round `t` (mode re-roll every 20 rounds).
    pub fn on_round_start(&mut self, t: usize) {
        if t > 0 && t % MODE_REROLL_ROUNDS == 0 {
            for d in self.devices.iter_mut() {
                d.reroll_mode();
            }
        }
    }
}

/// Simulated per-round cost of one participant (Eq. 7).
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundCost {
    pub download_s: f64,
    pub compute_s: f64,
    pub upload_s: f64,
}

impl RoundCost {
    pub fn total(&self) -> f64 {
        self.download_s + self.compute_s + self.upload_s
    }

    /// Eq. 7: M_i = bits_down/β_d + τ·b·μ + bits_up/β_u.
    pub fn new(
        bits_down: f64,
        bits_up: f64,
        beta_down: f64,
        beta_up: f64,
        tau: usize,
        batch: usize,
        mu: f64,
    ) -> RoundCost {
        RoundCost {
            download_s: bits_down / beta_down,
            compute_s: tau as f64 * batch as f64 * mu,
            upload_s: bits_up / beta_up,
        }
    }

    /// Eq. 7 from *measured* wire lengths: the transfer terms derive from
    /// the actual encoded payload sizes (stand-in bits, scaled to paper
    /// size by `scale`) rather than from closed-form codec formulas.
    #[allow(clippy::too_many_arguments)]
    pub fn from_wire(
        down_wire_bits: usize,
        up_wire_bits: usize,
        scale: &crate::compress::traffic::PayloadScale,
        beta_down: f64,
        beta_up: f64,
        tau: usize,
        batch: usize,
        mu: f64,
    ) -> RoundCost {
        RoundCost::new(
            scale.scale_bits(down_wire_bits),
            scale.scale_bits(up_wire_bits),
            beta_down,
            beta_up,
            tau,
            batch,
            mu,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn jetson80_composition() {
        let f = Fleet::new(FleetKind::Jetson80, 0);
        assert_eq!(f.len(), 80);
        let tx2 = f.devices.iter().filter(|d| d.class == DeviceClass::JetsonTX2).count();
        let nx = f.devices.iter().filter(|d| d.class == DeviceClass::JetsonNX).count();
        let agx = f.devices.iter().filter(|d| d.class == DeviceClass::JetsonAGX).count();
        assert_eq!((tx2, nx, agx), (30, 40, 10));
    }

    #[test]
    fn phone40_composition() {
        let f = Fleet::new(FleetKind::Phone40, 0);
        assert_eq!(f.len(), 40);
    }

    #[test]
    fn scaled_fleet_has_requested_size() {
        for n in [100, 200, 300] {
            let f = Fleet::new(FleetKind::JetsonScaled(n), 1);
            assert_eq!(f.len(), n);
        }
    }

    #[test]
    fn network_groups_are_balanced() {
        let f = Fleet::new(FleetKind::Jetson80, 2);
        let mut counts = [0usize; 4];
        for d in &f.devices {
            counts[d.group as usize] += 1;
        }
        assert_eq!(counts, [20, 20, 20, 20]);
    }

    #[test]
    fn mu_spread_is_about_100x() {
        // paper: up to ~100× difference between fastest AGX mode and
        // slowest TX2 mode
        let f = Fleet::new(FleetKind::Jetson80, 3);
        let best = DeviceClass::JetsonAGX.profile();
        let worst = DeviceClass::JetsonTX2.profile();
        let min_mu = best.base_mu_s
            * best
                .mode_multipliers
                .iter()
                .fold(f64::MAX, |a, &b| a.min(b));
        let max_mu = worst.base_mu_s
            * worst
                .mode_multipliers
                .iter()
                .fold(f64::MIN, |a, &b| a.max(b));
        let spread = max_mu / min_mu;
        assert!(spread > 50.0 && spread < 200.0, "spread={spread}");
        drop(f);
    }

    #[test]
    fn mode_reroll_changes_modes() {
        let mut f = Fleet::new(FleetKind::Jetson80, 4);
        let before: Vec<usize> = f.devices.iter().map(|d| d.mode).collect();
        f.on_round_start(MODE_REROLL_ROUNDS);
        let after: Vec<usize> = f.devices.iter().map(|d| d.mode).collect();
        assert_ne!(before, after);
        // non-multiple rounds do not reroll
        let snapshot = after.clone();
        f.on_round_start(MODE_REROLL_ROUNDS + 1);
        let same: Vec<usize> = f.devices.iter().map(|d| d.mode).collect();
        assert_eq!(snapshot, same);
    }

    #[test]
    fn bandwidth_draws_are_order_independent() {
        // the same (base, round, device) stream yields the same draw no
        // matter how many other devices drew before it
        let f = Fleet::new(FleetKind::Jetson80, 5);
        let draw = |d: usize| {
            let mut rng = Rng::stream(0xBEEF, 9, d as u64);
            f.devices[d].draw_bandwidth(&f.bandwidth, &mut rng)
        };
        let forward: Vec<(f64, f64)> = (0..10).map(draw).collect();
        let backward: Vec<(f64, f64)> = (0..10).rev().map(draw).collect();
        for (i, b) in backward.into_iter().rev().enumerate() {
            assert_eq!(forward[i], b, "device {i}");
        }
    }

    #[test]
    fn round_cost_total_matches_eq7() {
        let c = RoundCost::new(1e6, 5e5, 1e6, 5e5, 30, 32, 0.001);
        assert!((c.download_s - 1.0).abs() < 1e-12);
        assert!((c.upload_s - 1.0).abs() < 1e-12);
        assert!((c.compute_s - 0.96).abs() < 1e-12);
        assert!((c.total() - 2.96).abs() < 1e-12);
    }

    #[test]
    fn round_cost_from_wire_scales_measured_bits() {
        use crate::compress::traffic::PayloadScale;
        let scale = PayloadScale { n_real: 1_000, n_paper: 2_000 };
        let c = RoundCost::from_wire(500_000, 250_000, &scale, 1e6, 5e5, 30, 32, 0.001);
        // 500k stand-in bits → 1M paper bits at 1 Mb/s = 1 s, same uplink
        assert!((c.download_s - 1.0).abs() < 1e-12);
        assert!((c.upload_s - 1.0).abs() < 1e-12);
        assert!((c.compute_s - 0.96).abs() < 1e-12);
    }
}

//! WiFi bandwidth model — the stand-in for the paper's four rooms at
//! 2 m / 8 m / 14 m / 20 m from the router with measured per-round
//! fluctuation inside [1, 30] Mb/s (§6.1 "Setting of System Heterogeneity").

use crate::util::rng::Rng;

/// Distance group (index 0 = closest to the router).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NetworkGroup {
    Near = 0,   // ~2 m
    Mid = 1,    // ~8 m
    Far = 2,    // ~14 m
    VeryFar = 3, // ~20 m
}

impl NetworkGroup {
    pub fn from_index(i: usize) -> NetworkGroup {
        match i {
            0 => NetworkGroup::Near,
            1 => NetworkGroup::Mid,
            2 => NetworkGroup::Far,
            _ => NetworkGroup::VeryFar,
        }
    }
}

/// Per-round bandwidth sampler.
#[derive(Clone, Debug)]
pub struct BandwidthModel {
    /// Mean downlink bandwidth per group, bit/s.
    pub mean_down_bps: [f64; 4],
    /// Uplink mean as a fraction of downlink (WiFi is roughly symmetric;
    /// contention skews uploads slightly down).
    pub up_fraction: f64,
    /// Lognormal sigma of the per-round fluctuation.
    pub sigma: f64,
    /// Hard clamp, bit/s (paper: [1, 30] Mb/s).
    pub min_bps: f64,
    pub max_bps: f64,
}

impl Default for BandwidthModel {
    fn default() -> Self {
        BandwidthModel {
            // Calibrated so FedAvg's round time on the CIFAR stand-in sits
            // near the paper's ~90 s/round with comm : compute ≈ 40 : 60
            // (the paper's own FedAvg waiting time of ~12 s rules out a
            // comm-starved testbed despite the quoted 1 Mb/s floor).
            mean_down_bps: [26e6, 21e6, 15e6, 9e6],
            up_fraction: 0.8,
            sigma: 0.35,
            min_bps: 1e6,
            max_bps: 30e6,
        }
    }
}

impl BandwidthModel {
    /// Draw (download, upload) bandwidth in bit/s for one round.
    pub fn draw(&self, group: NetworkGroup, rng: &mut Rng) -> (f64, f64) {
        let mean = self.mean_down_bps[group as usize];
        let down = rng
            .lognormal_mean(mean, self.sigma)
            .clamp(self.min_bps, self.max_bps);
        let up = rng
            .lognormal_mean(mean * self.up_fraction, self.sigma)
            .clamp(self.min_bps, self.max_bps);
        (down, up)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_within_clamp() {
        let m = BandwidthModel::default();
        let mut rng = Rng::new(0);
        for g in 0..4 {
            for _ in 0..1000 {
                let (d, u) = m.draw(NetworkGroup::from_index(g), &mut rng);
                assert!((1e6..=30e6).contains(&d));
                assert!((1e6..=30e6).contains(&u));
            }
        }
    }

    #[test]
    fn nearer_groups_are_faster_on_average() {
        let m = BandwidthModel::default();
        let mut rng = Rng::new(1);
        let avg = |g: usize, rng: &mut Rng| {
            (0..2000)
                .map(|_| m.draw(NetworkGroup::from_index(g), rng).0)
                .sum::<f64>()
                / 2000.0
        };
        let a = avg(0, &mut rng);
        let b = avg(1, &mut rng);
        let c = avg(2, &mut rng);
        let d = avg(3, &mut rng);
        assert!(a > b && b > c && c > d, "{a} {b} {c} {d}");
    }

    #[test]
    fn fluctuates_round_to_round() {
        let m = BandwidthModel::default();
        let mut rng = Rng::new(2);
        let draws: Vec<f64> = (0..50)
            .map(|_| m.draw(NetworkGroup::Mid, &mut rng).0)
            .collect();
        let distinct = draws
            .iter()
            .filter(|&&x| (x - draws[0]).abs() > 1.0)
            .count();
        assert!(distinct > 40);
    }
}

//! `caesar-coordinator` — run the FL coordinator behind a Tcp listener.
//!
//! Usage:
//!   caesar-coordinator [listen=127.0.0.1:0] [task=har] [scheme=caesar]
//!                      [expect=<n>] [rendezvous-timeout=60]
//!                      [round-timeout=120] [journal=<path>]
//!                      [journal-every=K] [pipeline-depth=D]
//!                      [staleness-bound=S] [key=value overrides] [quiet]
//!
//! With `pipeline-depth` > 1 (or `staleness-bound` > 0) the run is
//! semi-async: up to D rounds are open on the wire at once and a
//! straggler's update may fold into a round up to S past its origin.
//! Depth 1 / bound 0 reproduces the barrier schedule bit for bit.
//!
//! With `journal=`, every coordinator decision is event-sourced to an
//! append-only CRC-framed log; a coordinator killed mid-run resumes from
//! the last snapshot + journal tail when restarted with the same journal
//! path, config and scheme, and finishes bit-identically. Verify offline
//! with `caesar replay journal=<path>`.
//!
//! Binds `listen` (port 0 = OS-assigned; the resolved address is printed
//! as `listening on <addr>` — the line `caesar-device` users and the
//! two-process example wait for), waits for `expect` devices to Join
//! (default: the per-round participant count), then drives the full run
//! over the wire. Devices that die mid-round can reconnect and rejoin;
//! stragglers past `round-timeout` seconds become dropouts.
//!
//! The networked path is native-only: trainer and compression backends
//! are forced to `native` regardless of overrides (device processes own
//! no accelerator runtime), so a run here is bit-identical to
//! `caesar run trainer=native compression-backend=native` with the same
//! seed and overrides — compare the printed `model digest`.

use std::time::Duration;

use anyhow::{anyhow, Result};

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::coordinator::Server;
use caesar_fl::schemes;
use caesar_fl::transport::{model_digest, CoordinatorService, TcpTransport};
use caesar_fl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(args: &Args) -> Result<()> {
    let task = args.get_or("task", "har");
    let scheme_name = args.get_or("scheme", "caesar");
    let mut cfg = ExperimentConfig::preset(task).apply_overrides(args);
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    let scheme = schemes::by_name(scheme_name)
        .ok_or_else(|| anyhow!("unknown scheme {scheme_name} (try `caesar list`)"))?;
    let quiet = args.has_flag("quiet");

    let listen = args.get_or("listen", "127.0.0.1:0");
    // every device can be sampled in any round, so by default wait for
    // the whole fleet (a missing device would resolve as a dropout)
    let expect = args.get_usize("expect").unwrap_or_else(|| cfg.n_devices());
    let rendezvous = Duration::from_secs(args.get_u64("rendezvous-timeout").unwrap_or(60));
    let round_timeout = Duration::from_secs(args.get_u64("round-timeout").unwrap_or(120));

    let (server, mut journal) = match args.get("journal") {
        Some(jpath) => {
            let snap_every = args.get_usize("journal-every").unwrap_or(10);
            let path = std::path::Path::new(jpath);
            let (srv, jw) = Server::journaled_open(cfg, scheme, path, snap_every)?;
            if jw.is_fresh() {
                println!("journal: fresh run -> {}", path.display());
            } else {
                println!(
                    "journal: resuming after round {} from {}",
                    jw.prior_rounds(),
                    path.display()
                );
            }
            (srv, Some(jw))
        }
        None => (Server::new(cfg, scheme)?, None),
    };
    let transport =
        TcpTransport::bind(listen).map_err(|e| anyhow!("binding {listen}: {e}"))?;
    let mut svc = CoordinatorService::new(server, transport);
    svc.round_timeout = round_timeout;

    println!(
        "coordinator: scheme={scheme_name} task={task} rounds={} devices={} expect={expect}",
        svc.server().cfg.rounds,
        svc.server().cfg.n_devices(),
    );
    // machine-readable rendezvous line (parsed by the two-process example)
    println!("listening on {}", svc.local_addr());
    svc.wait_for_devices(expect, rendezvous)?;
    println!("{} devices joined; starting", svc.connected());

    let use_auc = task == "oppo";
    let mut progress = |r: &caesar_fl::coordinator::RoundRecord| {
        if !quiet && !r.accuracy.is_nan() {
            println!(
                "  round {:>4}  acc={:.4}  loss={:.4}  time={:>8.1}s  traffic={:.3}GB",
                r.t, r.accuracy, r.mean_loss, r.sim_time_s, r.traffic_gb
            );
        }
    };
    let result = match journal.as_mut() {
        Some(jw) => svc.run_journaled_cb(jw, &mut progress)?,
        None => svc.run_cb(&mut progress)?,
    };
    let server = svc.into_server();
    println!(
        "final: metric={:.4}  time={:.1}s(sim)  traffic={:.3}GB",
        result.final_metric(use_auc),
        result.total_time_s(),
        result.total_traffic_gb(),
    );
    // machine-readable parity line (compared across transports)
    println!("model digest {:016x}", model_digest(server.model()));
    Ok(())
}

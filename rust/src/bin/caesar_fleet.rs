//! `caesar-fleet` — run many FL device workers over ONE Tcp connection
//! against a `caesar-coordinator`.
//!
//! Usage:
//!   caesar-fleet connect=127.0.0.1:PORT devices=0-7
//!                [task=har] [max-redials=5] [key=value overrides] [quiet]
//!
//! The multiplexed sibling of `caesar-device`: where that binary opens
//! one socket per device id, this one runs the whole `devices=` range as
//! a [`DeviceFleet`] — a single framed connection carrying every
//! session, demux-routed by the device id each frame names. Launch M
//! processes with disjoint ranges to spread N devices across M sockets;
//! the coordinator's math is bit-identical either way. Config overrides
//! MUST match the coordinator's (both sides derive datasets, shards and
//! model shape from the shared config + seed).

use std::time::Duration;

use anyhow::{anyhow, Result};

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::transport::{DeviceFleet, SessionEnd, TcpConn};
use caesar_fl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `devices=a-b` (inclusive) or `device=n`; defaults to every device in
/// the fleet.
fn device_range(args: &Args, n: usize) -> Result<Vec<usize>> {
    if let Some(d) = args.get_usize("device") {
        return Ok(vec![d]);
    }
    match args.get("devices") {
        None => Ok((0..n).collect()),
        Some(spec) => {
            let (a, b) = spec
                .split_once('-')
                .ok_or_else(|| anyhow!("devices= expects a-b, got {spec}"))?;
            let a: usize = a.trim().parse().map_err(|_| anyhow!("bad range start {a}"))?;
            let b: usize = b.trim().parse().map_err(|_| anyhow!("bad range end {b}"))?;
            if a > b {
                return Err(anyhow!("empty device range {spec}"));
            }
            Ok((a..=b).collect())
        }
    }
}

fn run(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow!("connect=HOST:PORT is required"))?
        .to_string();
    let task = args.get_or("task", "har");
    let mut cfg = ExperimentConfig::preset(task).apply_overrides(args);
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    let devices = device_range(args, cfg.n_devices())?;
    let max_redials = args.get_usize("max-redials").unwrap_or(5);
    let quiet = args.has_flag("quiet");

    if !quiet {
        println!("fleet of devices {devices:?} connecting to {addr} on one connection");
    }
    let mut fleet = DeviceFleet::new(cfg, devices)?;
    let end = fleet.run_reconnecting(|| TcpConn::connect(addr.as_str()), max_redials)?;
    let stats = fleet.stats();
    match end {
        SessionEnd::Finished => {
            if !quiet {
                println!(
                    "fleet finished: {} rounds, {} dropouts, {} redeliveries",
                    stats.rounds, stats.dropouts, stats.redeliveries
                );
            }
        }
        SessionEnd::Disconnected => {
            eprintln!("fleet gave up after repeated disconnects");
            std::process::exit(2);
        }
    }
    // give the coordinator a beat to log its side before we exit
    std::thread::sleep(Duration::from_millis(50));
    Ok(())
}

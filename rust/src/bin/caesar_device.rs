//! `caesar-device` — run one or more FL device workers against a
//! `caesar-coordinator` over Tcp.
//!
//! Usage:
//!   caesar-device connect=127.0.0.1:PORT [devices=0-7 | device=3]
//!                 [task=har] [max-redials=5] [key=value overrides] [quiet]
//!
//! Config overrides MUST match the coordinator's (both sides derive the
//! datasets, shards and model shape from the shared config + seed; the
//! JoinAck handshake cross-checks the fleet size, catching most skew).
//! Each device id gets its own thread and its own Tcp connection; a
//! dropped connection is redialed with a re-Join, and the coordinator
//! re-sends the pending round kickoff.

use std::time::Duration;

use anyhow::{anyhow, Result};

use caesar_fl::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use caesar_fl::transport::{DeviceClient, SessionEnd, TcpConn};
use caesar_fl::util::cli::Args;

fn main() {
    let args = Args::from_env();
    if let Err(e) = run(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// `devices=a-b` (inclusive) or `device=n`; defaults to every device in
/// the fleet.
fn device_range(args: &Args, n: usize) -> Result<Vec<usize>> {
    if let Some(d) = args.get_usize("device") {
        return Ok(vec![d]);
    }
    match args.get("devices") {
        None => Ok((0..n).collect()),
        Some(spec) => {
            let (a, b) = spec
                .split_once('-')
                .ok_or_else(|| anyhow!("devices= expects a-b, got {spec}"))?;
            let a: usize = a.trim().parse().map_err(|_| anyhow!("bad range start {a}"))?;
            let b: usize = b.trim().parse().map_err(|_| anyhow!("bad range end {b}"))?;
            if a > b {
                return Err(anyhow!("empty device range {spec}"));
            }
            Ok((a..=b).collect())
        }
    }
}

fn run(args: &Args) -> Result<()> {
    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow!("connect=HOST:PORT is required"))?
        .to_string();
    let task = args.get_or("task", "har");
    let mut cfg = ExperimentConfig::preset(task).apply_overrides(args);
    cfg.trainer = TrainerBackend::Native;
    cfg.compression = CompressionBackend::Native;
    let devices = device_range(args, cfg.n_devices())?;
    let max_redials = args.get_usize("max-redials").unwrap_or(5);
    let quiet = args.has_flag("quiet");

    if !quiet {
        println!("devices {:?} connecting to {addr}", devices);
    }
    let mut handles = Vec::new();
    for d in devices {
        let cfg = cfg.clone();
        let addr = addr.clone();
        handles.push(std::thread::spawn(move || -> Result<(usize, SessionEnd)> {
            let mut client = DeviceClient::new(cfg, d)?;
            let end = client.run_reconnecting(
                || TcpConn::connect(addr.as_str()),
                max_redials,
            )?;
            Ok((d, end))
        }));
    }
    let mut failed = false;
    for h in handles {
        match h.join().map_err(|_| anyhow!("device thread panicked"))? {
            Ok((d, SessionEnd::Finished)) => {
                if !quiet {
                    println!("device {d}: finished");
                }
            }
            Ok((d, SessionEnd::Disconnected)) => {
                eprintln!("device {d}: gave up after repeated disconnects");
                failed = true;
            }
            Err(e) => {
                eprintln!("device error: {e:#}");
                failed = true;
            }
        }
    }
    if failed {
        std::process::exit(2);
    }
    // give the coordinator a beat to log its side before we exit
    std::thread::sleep(Duration::from_millis(50));
    Ok(())
}

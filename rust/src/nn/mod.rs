//! Native MLP training — the rust-side oracle for the Layer-2 JAX model.
//!
//! Mirrors `python/compile/model.py` exactly: same flat-parameter layout
//! (per layer: row-major W[a,b] then bias[b]), ReLU between hidden layers,
//! mean softmax cross-entropy, plain SGD. The integration test
//! `tests/runtime_parity.rs` pins this implementation against the AOT HLO
//! train step, and the coordinator can fall back to it when artifacts are
//! not built (`--trainer native`).

use crate::util::rng::Rng;
use crate::util::stats;

/// Static MLP architecture: `dims = [d_in, hidden..., n_classes]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MlpSpec {
    pub name: String,
    pub dims: Vec<usize>,
}

impl MlpSpec {
    pub fn new(name: &str, dims: &[usize]) -> MlpSpec {
        assert!(dims.len() >= 2);
        MlpSpec { name: name.to_string(), dims: dims.to_vec() }
    }

    /// The stand-in model for each dataset (must match model.py's SPECS).
    pub fn for_task(task: &str) -> MlpSpec {
        match task {
            "cifar" => MlpSpec::new("cifar", &[64, 128, 10]),
            "har" => MlpSpec::new("har", &[36, 64, 6]),
            "speech" => MlpSpec::new("speech", &[40, 96, 35]),
            "oppo" => MlpSpec::new("oppo", &[128, 2]),
            other => panic!("unknown task {other}"),
        }
    }

    pub fn d_in(&self) -> usize {
        self.dims[0]
    }

    pub fn n_classes(&self) -> usize {
        *self.dims.last().unwrap()
    }

    pub fn n_params(&self) -> usize {
        self.dims
            .windows(2)
            .map(|w| w[0] * w[1] + w[1])
            .sum()
    }

    /// (w_offset, b_offset, (a, b)) per layer — identical to model.py.
    pub fn slices(&self) -> Vec<(usize, usize, (usize, usize))> {
        let mut out = Vec::new();
        let mut off = 0;
        for w in self.dims.windows(2) {
            let (a, b) = (w[0], w[1]);
            out.push((off, off + a * b, (a, b)));
            off += a * b + b;
        }
        out
    }

    /// He-normal init (matches the python tests' convention; biases zero).
    pub fn init(&self, rng: &mut Rng) -> Vec<f32> {
        let mut flat = vec![0.0f32; self.n_params()];
        for (ow, ob, (a, b)) in self.slices() {
            let scale = (2.0 / a as f64).sqrt();
            for x in flat[ow..ob].iter_mut() {
                *x = (rng.normal() * scale) as f32;
            }
            let _ = ob + b; // biases stay zero
        }
        flat
    }
}

/// Forward pass: returns logits (n × H, row-major) and, for backward, the
/// post-ReLU activations of each layer (including the input).
fn forward_cached(
    spec: &MlpSpec,
    flat: &[f32],
    x: &[f32],
    n: usize,
) -> (Vec<f32>, Vec<Vec<f32>>) {
    let layers = spec.slices();
    let mut acts: Vec<Vec<f32>> = vec![x.to_vec()];
    let mut cur_dim = spec.d_in();
    for (li, &(ow, ob, (a, b))) in layers.iter().enumerate() {
        debug_assert_eq!(a, cur_dim);
        let w = &flat[ow..ob];
        let bias = &flat[ob..ob + b];
        let prev = acts.last().unwrap();
        let mut out = vec![0.0f32; n * b];
        matmul_add_bias(prev, w, bias, &mut out, n, a, b);
        if li + 1 < layers.len() {
            for v in out.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        acts.push(out);
        cur_dim = b;
    }
    let logits = acts.last().unwrap().clone();
    (logits, acts)
}

/// out[n,b] = x[n,a] @ w[a,b] + bias[b]
fn matmul_add_bias(x: &[f32], w: &[f32], bias: &[f32], out: &mut [f32], n: usize, a: usize, b: usize) {
    for i in 0..n {
        let xi = &x[i * a..(i + 1) * a];
        let oi = &mut out[i * b..(i + 1) * b];
        oi.copy_from_slice(bias);
        for (k, &xv) in xi.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wr = &w[k * b..(k + 1) * b];
            for j in 0..b {
                oi[j] += xv * wr[j];
            }
        }
    }
}

/// Logits for a batch (no caching).
pub fn apply(spec: &MlpSpec, flat: &[f32], x: &[f32], n: usize) -> Vec<f32> {
    forward_cached(spec, flat, x, n).0
}

/// Mean softmax cross-entropy.
pub fn loss(spec: &MlpSpec, flat: &[f32], x: &[f32], y: &[i32], n: usize) -> f64 {
    let logits = apply(spec, flat, x, n);
    let h = spec.n_classes();
    let mut total = 0.0f64;
    for i in 0..n {
        let row = &logits[i * h..(i + 1) * h];
        total -= log_softmax_at(row, y[i] as usize);
    }
    total / n as f64
}

fn log_softmax_at(row: &[f32], idx: usize) -> f64 {
    let m = row.iter().fold(f32::MIN, |a, &b| a.max(b)) as f64;
    let lse = m + row.iter().map(|&v| ((v as f64) - m).exp()).sum::<f64>().ln();
    row[idx] as f64 - lse
}

/// Gradient of the mean CE loss w.r.t. the flat parameters.
pub fn grad(spec: &MlpSpec, flat: &[f32], x: &[f32], y: &[i32], n: usize) -> Vec<f32> {
    let layers = spec.slices();
    let h = spec.n_classes();
    let (logits, acts) = forward_cached(spec, flat, x, n);
    // dL/dlogits = (softmax - onehot)/n
    let mut delta = vec![0.0f32; n * h];
    for i in 0..n {
        let row = &logits[i * h..(i + 1) * h];
        let m = row.iter().fold(f32::MIN, |a, &b| a.max(b));
        let exps: Vec<f64> = row.iter().map(|&v| ((v - m) as f64).exp()).collect();
        let sum: f64 = exps.iter().sum();
        for j in 0..h {
            let p = exps[j] / sum;
            delta[i * h + j] =
                ((p - if j == y[i] as usize { 1.0 } else { 0.0 }) / n as f64) as f32;
        }
    }
    let mut g = vec![0.0f32; flat.len()];
    // backprop through layers in reverse
    let mut delta_cur = delta;
    for (li, &(ow, ob, (a, b))) in layers.iter().enumerate().rev() {
        let prev = &acts[li];
        // dW[a,b] += prev^T @ delta ; db[b] += sum delta
        for i in 0..n {
            let di = &delta_cur[i * b..(i + 1) * b];
            let pi = &prev[i * a..(i + 1) * a];
            for (k, &pv) in pi.iter().enumerate() {
                if pv == 0.0 {
                    continue;
                }
                let gr = &mut g[ow + k * b..ow + (k + 1) * b];
                for j in 0..b {
                    gr[j] += pv * di[j];
                }
            }
            let gb = &mut g[ob..ob + b];
            for j in 0..b {
                gb[j] += di[j];
            }
        }
        if li == 0 {
            break;
        }
        // delta_prev = (delta @ W^T) * relu'(prev)
        let w = &flat[ow..ob];
        let mut delta_prev = vec![0.0f32; n * a];
        for i in 0..n {
            let di = &delta_cur[i * b..(i + 1) * b];
            let dp = &mut delta_prev[i * a..(i + 1) * a];
            for k in 0..a {
                let wr = &w[k * b..(k + 1) * b];
                let mut s = 0.0f32;
                for j in 0..b {
                    s += di[j] * wr[j];
                }
                // relu' on the cached post-activation
                dp[k] = if prev[i * a + k] > 0.0 { s } else { 0.0 };
            }
        }
        delta_cur = delta_prev;
    }
    g
}

/// One SGD step in place; returns the batch loss.
pub fn sgd_step(
    spec: &MlpSpec,
    flat: &mut [f32],
    x: &[f32],
    y: &[i32],
    n: usize,
    lr: f32,
) -> f64 {
    let l = loss(spec, flat, x, y, n);
    let g = grad(spec, flat, x, y, n);
    for (p, gi) in flat.iter_mut().zip(&g) {
        *p -= lr * gi;
    }
    l
}

/// Accuracy over a dataset slice (features row-major).
pub fn accuracy(spec: &MlpSpec, flat: &[f32], x: &[f32], y: &[u8], n: usize) -> f64 {
    let h = spec.n_classes();
    let logits = apply(spec, flat, x, n);
    let mut correct = 0usize;
    for i in 0..n {
        if stats::argmax(&logits[i * h..(i + 1) * h]) == Some(y[i] as usize) {
            correct += 1;
        }
    }
    correct as f64 / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_spec() -> MlpSpec {
        MlpSpec::new("toy", &[4, 8, 3])
    }

    fn toy_batch(spec: &MlpSpec, n: usize, seed: u64) -> (Vec<f32>, Vec<i32>) {
        let mut rng = Rng::new(seed);
        let x = (0..n * spec.d_in()).map(|_| rng.normal() as f32).collect();
        let y = (0..n)
            .map(|_| rng.below(spec.n_classes()) as i32)
            .collect();
        (x, y)
    }

    #[test]
    fn n_params_matches_python_specs() {
        assert_eq!(MlpSpec::for_task("cifar").n_params(), 64 * 128 + 128 + 128 * 10 + 10);
        assert_eq!(MlpSpec::for_task("har").n_params(), 36 * 64 + 64 + 64 * 6 + 6);
        assert_eq!(MlpSpec::for_task("speech").n_params(), 40 * 96 + 96 + 96 * 35 + 35);
        assert_eq!(MlpSpec::for_task("oppo").n_params(), 128 * 2 + 2);
    }

    #[test]
    fn grad_matches_finite_difference() {
        let spec = toy_spec();
        let mut rng = Rng::new(0);
        let flat = spec.init(&mut rng);
        let (x, y) = toy_batch(&spec, 6, 1);
        let g = grad(&spec, &flat, &x, &y, 6);
        let eps = 1e-3f32;
        let mut rng2 = Rng::new(2);
        for _ in 0..12 {
            let i = rng2.below(flat.len());
            let mut fp = flat.clone();
            let mut fm = flat.clone();
            fp[i] += eps;
            fm[i] -= eps;
            let fd = (loss(&spec, &fp, &x, &y, 6) - loss(&spec, &fm, &x, &y, 6))
                / (2.0 * eps as f64);
            let rel = (g[i] as f64 - fd).abs() / (fd.abs().max(1e-4));
            assert!(rel < 0.05, "param {i}: analytic {} vs fd {fd}", g[i]);
        }
    }

    #[test]
    fn sgd_decreases_loss_on_fixed_batch() {
        let spec = toy_spec();
        let mut rng = Rng::new(3);
        let mut flat = spec.init(&mut rng);
        let (x, y) = toy_batch(&spec, 16, 4);
        let l0 = loss(&spec, &flat, &x, &y, 16);
        for _ in 0..400 {
            sgd_step(&spec, &mut flat, &x, &y, 16, 0.2);
        }
        let l1 = loss(&spec, &flat, &x, &y, 16);
        assert!(l1 < l0 * 0.3, "l0={l0} l1={l1}");
    }

    #[test]
    fn accuracy_reaches_high_on_separable_toy_data() {
        // linearly separable blobs → near-perfect accuracy
        let spec = MlpSpec::new("sep", &[2, 16, 2]);
        let mut rng = Rng::new(5);
        let mut flat = spec.init(&mut rng);
        let n = 200;
        let mut x = Vec::with_capacity(n * 2);
        let mut y = Vec::with_capacity(n);
        for i in 0..n {
            let c = i % 2;
            let cx = if c == 0 { -2.0 } else { 2.0 };
            x.push(cx + 0.5 * rng.normal() as f32);
            x.push(cx + 0.5 * rng.normal() as f32);
            y.push(c as i32);
        }
        for _ in 0..100 {
            sgd_step(&spec, &mut flat, &x, &y, n, 0.2);
        }
        let yl: Vec<u8> = y.iter().map(|&v| v as u8).collect();
        let acc = accuracy(&spec, &flat, &x, &yl, n);
        assert!(acc > 0.95, "acc={acc}");
    }

    #[test]
    fn logistic_regression_path_no_hidden() {
        let spec = MlpSpec::for_task("oppo");
        let mut rng = Rng::new(6);
        let flat = spec.init(&mut rng);
        let (x, y) = toy_batch(&spec, 4, 7);
        let logits = apply(&spec, &flat, &x, 4);
        assert_eq!(logits.len(), 4 * 2);
        // manual check: logits = x @ W + b
        let (ow, ob, (a, b)) = spec.slices()[0];
        for j in 0..b {
            let mut want = flat[ob + j];
            for k in 0..a {
                want += x[k] * flat[ow + k * b + j];
            }
            assert!((want - logits[j]).abs() < 1e-4);
        }
        let _ = grad(&spec, &flat, &x, &y, 4); // exercises li==0 break path
    }

    #[test]
    fn init_is_deterministic_and_scaled() {
        let spec = MlpSpec::for_task("har");
        let a = spec.init(&mut Rng::new(9));
        let b = spec.init(&mut Rng::new(9));
        assert_eq!(a, b);
        // He scale: std of first-layer weights ≈ sqrt(2/36)
        let (ow, ob, _) = spec.slices()[0];
        let ws: Vec<f64> = a[ow..ob].iter().map(|&x| x as f64).collect();
        let std = stats::std_dev(&ws);
        let want = (2.0f64 / 36.0).sqrt();
        assert!((std - want).abs() / want < 0.1, "std={std} want={want}");
        // biases zero
        let (_, ob0, (_, b0)) = spec.slices()[0];
        assert!(a[ob0..ob0 + b0].iter().all(|&x| x == 0.0));
    }
}

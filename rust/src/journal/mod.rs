//! Append-only, CRC-framed round journal — event-sourcing for the
//! coordinator (ROADMAP "Durable runs").
//!
//! Every coordinator decision is appended as one framed [`Record`]:
//!
//! ```text
//!  offset  size  field
//!  ──────  ────  ─────────────────────────────────────────────
//!       0     4  length, u32 LE       (kind + body; 1 ..= 1 GiB)
//!       4     1  record kind          (RunHeader=1 … RoundClose=6)
//!       5   n−1  body                 (kind-specific, byte-aligned)
//!     4+n     4  CRC-32, u32 LE       (over length + kind + body)
//! ```
//!
//! The CRC covers the length field too, so a bit flip anywhere in a
//! frame — including one that redirects the length — is detected. A
//! journal on disk is therefore self-healing at the tail: [`recover`]
//! scans from the front and keeps the **longest valid prefix** of whole
//! records, discarding a torn or corrupted final record instead of ever
//! folding it ([`tests in `rust/tests/durability.rs`]). The scan is
//! total — garbage input yields a (possibly empty) prefix, never a
//! panic.
//!
//! Writing goes through the [`JournalSink`] trait so the fault-injection
//! harness ([`KillSink`]) can script a crash at the N-th append — torn
//! mid-record, exactly like a process killed inside `write(2)` — while
//! production uses [`FileSink`] (append + flush per record). The
//! durability guarantee is scoped to **process crashes**: every
//! acknowledged append has reached the kernel, so killing the process at
//! any point loses at most a torn tail. No fsync is issued, so an OS
//! crash or power loss can drop recently acknowledged records entirely —
//! recovery still yields a clean earlier prefix, never corruption.
//!
//! [`RunJournal`] is the run-level wrapper the coordinator drives: it
//! frames records, enforces the snapshot cadence, and — after a resume —
//! cross-checks every re-derived record byte-for-byte against the
//! retained journal tail, so "resume continues bit-identically" is a
//! *checked invariant* of the production path, not just a test
//! assertion. See `coordinator::Server::journaled_open` for the
//! open-or-resume entry point and `journal::replay` for the offline
//! verifier.

pub mod record;
pub mod replay;

use std::collections::VecDeque;
use std::fs::{File, OpenOptions};
use std::io::Write;
use std::path::Path;

use crate::coordinator::RoundRecord;
use crate::util::bitio::BitWriter;

pub use record::{
    Dropout, EndRound, ParamBlock, PlanEntry, Record, RoundClose, RoundOpen, RunHeader, Snapshot,
    JOURNAL_VERSION,
};
pub use replay::{verify, ReplaySummary};

/// Frame overhead: 4-byte length + 4-byte CRC around `kind + body`.
pub const FRAME_OVERHEAD: usize = 8;

/// Upper bound on one record's `kind + body` — 1 GiB comfortably holds a
/// snapshot (global + every retained local) at the stand-in scales this
/// repo trains, while bounding what a corrupt length field can make the
/// recovery scan skip.
pub const MAX_RECORD: usize = 1 << 30;

/// Typed journal failure. Codec errors terminate a [`recover`] scan (the
/// valid prefix ends there); `Io` / `Killed` / `Diverged` surface from
/// the write path.
#[derive(Debug)]
pub enum JournalError {
    Io(std::io::Error),
    /// Fewer bytes than one whole frame — a torn tail.
    Truncated { need: usize, have: usize },
    /// Frame CRC mismatch — a corrupted record.
    BadCrc,
    /// Declared record length of zero or above [`MAX_RECORD`].
    BadLength { len: usize },
    /// Journal written by a different format version.
    Version { got: u32, want: u32 },
    UnknownKind(u8),
    Malformed(&'static str),
    /// Scripted fault injection hit ([`KillSink`]).
    Killed { at_append: usize },
    /// A resumed run re-derived a record that differs from what the
    /// journal tail recorded — the determinism contract was broken.
    Diverged { expected_kind: u8, got_kind: u8 },
}

impl std::fmt::Display for JournalError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            JournalError::Io(e) => write!(f, "journal io: {e}"),
            JournalError::Truncated { need, have } => {
                write!(f, "torn journal record: need {need} more bytes, have {have}")
            }
            JournalError::BadCrc => write!(f, "journal record failed its CRC"),
            JournalError::BadLength { len } => {
                write!(f, "journal record length {len} outside 1..={MAX_RECORD}")
            }
            JournalError::Version { got, want } => {
                write!(f, "journal format version {got} (this build speaks {want})")
            }
            JournalError::UnknownKind(k) => write!(f, "unknown journal record kind {k}"),
            JournalError::Malformed(what) => write!(f, "malformed journal record: {what}"),
            JournalError::Killed { at_append } => {
                write!(f, "scripted kill point hit at append {at_append}")
            }
            JournalError::Diverged { expected_kind, got_kind } => write!(
                f,
                "resumed run diverged from the journal tail \
                 (expected record kind {expected_kind}, re-derived {got_kind})"
            ),
        }
    }
}

impl std::error::Error for JournalError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            JournalError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for JournalError {
    fn from(e: std::io::Error) -> Self {
        JournalError::Io(e)
    }
}

// ---------------------------------------------------------------------
// CRC-32 (IEEE 802.3, reflected, poly 0xEDB88320)
// ---------------------------------------------------------------------

const CRC_TABLE: [u32; 256] = {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
};

/// CRC-32 of `bytes` (the common IEEE variant: `crc32(b"123456789") ==
/// 0xCBF4_3926`). Table-driven, byte at a time — the journal append path
/// is dominated by the write syscall, not this.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

// ---------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------

/// Serialize one record to a complete frame (length + kind + body + CRC).
pub fn encode_record(rec: &Record) -> Vec<u8> {
    let mut body = BitWriter::new();
    record::encode_body(rec, &mut body);
    debug_assert_eq!(body.len_bits() % 8, 0, "record fields must stay byte-aligned");
    let body = body.into_bytes();
    let len = 1 + body.len();
    assert!(len <= MAX_RECORD, "outgoing journal record of {len} bytes");

    let mut framed = Vec::with_capacity(FRAME_OVERHEAD + len);
    framed.extend_from_slice(&(len as u32).to_le_bytes());
    framed.push(rec.kind());
    framed.extend_from_slice(&body);
    let crc = crc32(&framed);
    framed.extend_from_slice(&crc.to_le_bytes());
    framed
}

/// Decode one frame from the front of `buf`. Returns the record and the
/// total bytes consumed. Any failure is typed; none panics.
pub fn decode_record(buf: &[u8]) -> Result<(Record, usize), JournalError> {
    if buf.len() < 5 {
        return Err(JournalError::Truncated { need: 5 - buf.len(), have: buf.len() });
    }
    let len = u32::from_le_bytes([buf[0], buf[1], buf[2], buf[3]]) as usize;
    if len == 0 || len > MAX_RECORD {
        return Err(JournalError::BadLength { len });
    }
    let total = FRAME_OVERHEAD + len;
    if buf.len() < total {
        return Err(JournalError::Truncated { need: total - buf.len(), have: buf.len() });
    }
    let stored = u32::from_le_bytes([buf[total - 4], buf[total - 3], buf[total - 2], buf[total - 1]]);
    if crc32(&buf[..total - 4]) != stored {
        return Err(JournalError::BadCrc);
    }
    let rec = record::decode_body(buf[4], &buf[5..4 + len])?;
    Ok((rec, total))
}

/// The result of scanning a journal image: the longest valid prefix of
/// whole records, with per-record end offsets for truncate/slice math.
#[derive(Debug, Default)]
pub struct Recovered {
    pub records: Vec<Record>,
    /// Byte offset just past each record (`ends[i]` = end of record i).
    pub ends: Vec<usize>,
    /// Total valid bytes — everything past this is torn/corrupt tail.
    pub valid_len: usize,
    /// Why the scan stopped: `None` when every byte decoded cleanly,
    /// otherwise the error at the first invalid record. Lets callers
    /// tell a torn tail ([`JournalError::Truncated`]) apart from a file
    /// this build cannot read at all (version skew, CRC corruption).
    pub terminal: Option<JournalError>,
}

impl Recovered {
    /// Bytes discarded from a `total_len`-byte image.
    pub fn discarded(&self, total_len: usize) -> usize {
        total_len.saturating_sub(self.valid_len)
    }
}

/// Scan a journal image and keep the longest valid prefix. Total: any
/// input — truncated, bit-flipped, or plain garbage — yields a (possibly
/// empty) prefix; the scan never panics and never reads past `bytes`.
pub fn recover(bytes: &[u8]) -> Recovered {
    let mut out = Recovered::default();
    let mut pos = 0;
    while pos < bytes.len() {
        match decode_record(&bytes[pos..]) {
            Ok((rec, used)) => {
                pos += used;
                out.records.push(rec);
                out.ends.push(pos);
            }
            Err(e) => {
                out.terminal = Some(e);
                break;
            }
        }
    }
    out.valid_len = pos;
    out
}

/// Truncate a journal file to its valid prefix, discarding a torn tail
/// before reopening it for appends.
pub fn truncate_file(path: &Path, len: usize) -> std::io::Result<()> {
    let file = OpenOptions::new().write(true).open(path)?;
    file.set_len(len as u64)
}

/// [`recover`] over a file. A missing file recovers to the empty prefix.
pub fn recover_file(path: &Path) -> std::io::Result<(Recovered, Vec<u8>)> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
        Err(e) => return Err(e),
    };
    let rec = recover(&bytes);
    Ok((rec, bytes))
}

// ---------------------------------------------------------------------
// sinks
// ---------------------------------------------------------------------

/// Where framed records go. `append` takes one complete frame (from
/// [`encode_record`]); `write_raw` is the byte-level primitive the kill
/// harness uses to tear a record mid-write.
pub trait JournalSink {
    fn write_raw(&mut self, bytes: &[u8]) -> Result<(), JournalError>;

    fn append(&mut self, framed: &[u8]) -> Result<(), JournalError> {
        self.write_raw(framed)
    }
}

impl JournalSink for Box<dyn JournalSink> {
    fn write_raw(&mut self, bytes: &[u8]) -> Result<(), JournalError> {
        (**self).write_raw(bytes)
    }

    fn append(&mut self, framed: &[u8]) -> Result<(), JournalError> {
        (**self).append(framed)
    }
}

/// Append-mode file sink: one `write_all` + `flush` per record, so every
/// acknowledged append has left the **process** (reached the kernel)
/// before the next decision is made — a `kill -9` at any instant loses
/// at most the torn record in flight. This deliberately stops short of
/// `fsync`: an OS crash or power loss may drop acknowledged records that
/// were still in the page cache, in which case [`recover`] returns a
/// clean earlier prefix (never a corrupt state) and the resumed run
/// re-executes the lost rounds. Callers needing power-loss durability at
/// a milestone can [`FileSink::sync_data`] explicitly.
pub struct FileSink {
    file: File,
}

impl FileSink {
    /// Open `path` for appending (created if missing, existing bytes
    /// kept — the resume path truncates first).
    pub fn append_to(path: &Path) -> std::io::Result<FileSink> {
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        Ok(FileSink { file })
    }

    /// Create `path` fresh, discarding any previous contents.
    pub fn create(path: &Path) -> std::io::Result<FileSink> {
        let file = OpenOptions::new().create(true).write(true).truncate(true).open(path)?;
        Ok(FileSink { file })
    }

    /// Force everything appended so far to stable storage (`fdatasync`).
    /// Not called per-append — see the struct docs for the trade-off.
    pub fn sync_data(&self) -> std::io::Result<()> {
        self.file.sync_data()
    }
}

impl JournalSink for FileSink {
    fn write_raw(&mut self, bytes: &[u8]) -> Result<(), JournalError> {
        self.file.write_all(bytes)?;
        self.file.flush()?;
        Ok(())
    }
}

/// In-memory sink (tests, benches, the torn-tail fuzz harness).
#[derive(Default)]
pub struct VecSink {
    pub buf: Vec<u8>,
}

impl JournalSink for VecSink {
    fn write_raw(&mut self, bytes: &[u8]) -> Result<(), JournalError> {
        self.buf.extend_from_slice(bytes);
        Ok(())
    }
}

/// Kill-point fault injection: behaves like the wrapped sink until the
/// `kill_at`-th append (0-based), which writes only the first
/// `torn_bytes` bytes of its record and then fails with
/// [`JournalError::Killed`] — the observable effect of a process dying
/// inside `write(2)`. The driver is expected to drop all process-side
/// state and resume from the file, which is exactly what
/// `rust/tests/durability.rs` sweeps.
pub struct KillSink<S: JournalSink> {
    inner: S,
    kill_at: usize,
    torn_bytes: usize,
    appends: usize,
}

impl<S: JournalSink> KillSink<S> {
    pub fn new(inner: S, kill_at: usize, torn_bytes: usize) -> KillSink<S> {
        KillSink { inner, kill_at, torn_bytes, appends: 0 }
    }

    /// Appends acknowledged so far (for sweep sizing).
    pub fn appends(&self) -> usize {
        self.appends
    }

    pub fn into_inner(self) -> S {
        self.inner
    }
}

impl<S: JournalSink> JournalSink for KillSink<S> {
    fn write_raw(&mut self, bytes: &[u8]) -> Result<(), JournalError> {
        self.inner.write_raw(bytes)
    }

    fn append(&mut self, framed: &[u8]) -> Result<(), JournalError> {
        let i = self.appends;
        if i == self.kill_at {
            let cut = self.torn_bytes.min(framed.len());
            self.inner.write_raw(&framed[..cut])?;
            return Err(JournalError::Killed { at_append: i });
        }
        self.appends += 1;
        self.inner.append(framed)
    }
}

// ---------------------------------------------------------------------
// the run-level journal the coordinator drives
// ---------------------------------------------------------------------

/// State a resume hands to the continuing run: the records already
/// rebuilt from the journal prefix, and the retained tail the re-executed
/// rounds must reproduce byte-for-byte.
pub(crate) struct ResumeCarry {
    pub(crate) records: Vec<RoundRecord>,
    pub(crate) expected_tail: VecDeque<Vec<u8>>,
}

/// The journal of one run: frames and appends records, owns the snapshot
/// cadence, and (after a resume) verifies each re-derived record against
/// the retained tail before it is written — so a resumed run that
/// diverges from the original fails loudly at the first differing
/// record instead of silently forking history.
pub struct RunJournal {
    sink: Box<dyn JournalSink>,
    snapshot_every: usize,
    /// True until the RunHeader + initial snapshot have been written.
    fresh: bool,
    /// Framed bytes of the journal tail past the resume snapshot; each
    /// append pops and byte-compares until it drains.
    expected: VecDeque<Vec<u8>>,
    /// Per-round records rebuilt by resume (empty on a fresh run).
    prior_records: Vec<RoundRecord>,
}

impl RunJournal {
    /// A fresh journal: the next append must be the RunHeader.
    pub fn fresh(sink: Box<dyn JournalSink>, snapshot_every: usize) -> RunJournal {
        RunJournal {
            sink,
            snapshot_every: snapshot_every.max(1),
            fresh: true,
            expected: VecDeque::new(),
            prior_records: Vec::new(),
        }
    }

    pub(crate) fn resumed(
        sink: Box<dyn JournalSink>,
        snapshot_every: usize,
        carry: ResumeCarry,
    ) -> RunJournal {
        RunJournal {
            sink,
            snapshot_every: snapshot_every.max(1),
            fresh: false,
            expected: carry.expected_tail,
            prior_records: carry.records,
        }
    }

    /// Replace the sink (the fault-injection harness wraps it in a
    /// [`KillSink`] after construction).
    pub fn map_sink(&mut self, f: impl FnOnce(Box<dyn JournalSink>) -> Box<dyn JournalSink>) {
        // swap through a no-op sink so `f` can consume the real one
        let sink = std::mem::replace(&mut self.sink, Box::new(VecSink::default()));
        self.sink = f(sink);
    }

    /// Whether the run-header preamble still needs to be written.
    pub fn is_fresh(&self) -> bool {
        self.fresh
    }

    /// Rounds already rebuilt by resume — the continuing run starts at
    /// `prior_rounds() + 1`.
    pub fn prior_rounds(&self) -> usize {
        self.prior_records.len()
    }

    pub(crate) fn take_prior_records(&mut self) -> Vec<RoundRecord> {
        std::mem::take(&mut self.prior_records)
    }

    pub fn snapshot_every(&self) -> usize {
        self.snapshot_every
    }

    /// Whether a snapshot is due after closing round `t`.
    pub fn due_snapshot(&self, t: usize) -> bool {
        t % self.snapshot_every == 0
    }

    /// Frame and append one record; after a resume, first byte-compare it
    /// against the retained tail.
    pub fn append(&mut self, rec: &Record) -> Result<(), JournalError> {
        let framed = encode_record(rec);
        if let Some(want) = self.expected.pop_front() {
            if want != framed {
                // byte 4 of a frame is the record kind (see module docs)
                return Err(JournalError::Diverged {
                    expected_kind: want.get(4).copied().unwrap_or(0),
                    got_kind: framed[4],
                });
            }
            // the tail already holds these exact bytes — don't rewrite
            return Ok(());
        }
        self.fresh = false;
        self.sink.append(&framed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{Rng, RngState};

    #[test]
    fn crc32_matches_the_ieee_check_value() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // incremental sanity: crc depends on every byte
        assert_ne!(crc32(b"journal"), crc32(b"journam"));
    }

    fn sample_records(rng: &mut Rng, rounds: usize) -> Vec<Record> {
        let n_dev = 3;
        let n_params = 4;
        let mut cfg = crate::config::ExperimentConfig::preset("har");
        cfg.trainer = crate::config::TrainerBackend::Native;
        cfg.fleet = crate::fleet::FleetKind::JetsonScaled(n_dev);
        let mut out = vec![Record::RunHeader(RunHeader {
            version: JOURNAL_VERSION,
            scheme: "caesar".to_string(),
            snapshot_every: 2,
            cfg,
        })];
        let snap = |rng: &mut Rng, t: usize| {
            Record::Snapshot(Box::new(Snapshot {
                t,
                model_version: t as u64,
                sim_time_s: t as f64 * 3.5,
                rng: RngState { s: [rng.next_u64(); 4], spare_normal: None },
                down_bits: rng.f64() * 1e9,
                up_bits: rng.f64() * 1e9,
                model: ParamBlock::new((0..n_params).map(|i| i as f32).collect()),
                locals: (0..n_dev)
                    .map(|d| {
                        (d % 2 == 0).then(|| {
                            ParamBlock::new((0..n_params).map(|i| (d + i) as f32).collect())
                        })
                    })
                    .collect(),
                grad_norms: (0..n_dev).map(|d| d as f64).collect(),
                last_round: (0..n_dev).map(|d| d % (t + 1)).collect(),
            }))
        };
        out.push(snap(rng, 0));
        for t in 1..=rounds {
            out.push(Record::RoundOpen(RoundOpen {
                t,
                model_version: t as u64 - 1,
                sim_now_s: t as f64,
                lr: 0.1,
                stream_base: 0xBEEF,
                plans: (0..2)
                    .map(|d| PlanEntry {
                        device: d,
                        download: crate::schemes::DownloadCodec::CaesarSplit { ratio: 0.4 },
                        upload: crate::schemes::UploadCodec::TopK { ratio: 0.5 },
                        batch: 16,
                        tau: 5,
                        beta_d: 1e6,
                        beta_u: 5e5,
                        mu: 1e-4,
                    })
                    .collect(),
            }));
            out.push(Record::EndRound(EndRound {
                t,
                fold_t: t,
                device: 0,
                w_digest: rng.next_u64(),
                upload_bits: 1024,
                down_wire_bits: 2048,
                grad_norm: 1.5,
                loss: 0.7,
                download_s: 0.1,
                compute_s: 0.2,
                upload_s: 0.3,
            }));
            out.push(Record::Dropout(Dropout {
                t,
                device: 1,
                after_s: 0.15,
                down_wire_bits: 2048,
            }));
            out.push(Record::RoundClose(RoundClose {
                t,
                completers: 1,
                model_version: t as u64,
                model_digest: rng.next_u64(),
                down_bits: t as f64 * 4096.0,
                up_bits: t as f64 * 1024.0,
                rec: crate::coordinator::RoundRecord {
                    t,
                    sim_time_s: t as f64,
                    traffic_gb: t as f64 * 1e-3,
                    accuracy: if t % 2 == 0 { 0.5 } else { f64::NAN },
                    auc: f64::NAN,
                    mean_loss: 0.7,
                    round_s: 0.6,
                    avg_wait_s: 0.0,
                    participants: 2,
                },
            }));
            if t % 2 == 0 {
                out.push(snap(rng, t));
            }
        }
        out
    }

    #[test]
    fn records_round_trip_bit_exactly() {
        let mut rng = Rng::new(0x10A0);
        for rec in sample_records(&mut rng, 3) {
            let framed = encode_record(&rec);
            let (back, used) = decode_record(&framed).unwrap();
            assert_eq!(used, framed.len());
            // canonical codec: re-encoding the decode reproduces the bytes
            assert_eq!(encode_record(&back), framed, "{}", rec.kind_name());
        }
    }

    #[test]
    fn minimum_size_plan_entries_decode() {
        // Full/Full entries encode to 50 bytes — the smallest possible —
        // so a plan of them must pass the decoder's count pre-flight
        // (a 64-byte/entry estimate used to reject journals from schemes
        // like fedavg whose every entry is Full/Full)
        use crate::schemes::{DownloadCodec, UploadCodec};
        let codecs: [(DownloadCodec, UploadCodec); 3] = [
            (DownloadCodec::Full, UploadCodec::Full),
            (DownloadCodec::Quant { bits: 8 }, UploadCodec::Full),
            (DownloadCodec::Quant { bits: 8 }, UploadCodec::Quant { bits: 4 }),
        ];
        for (download, upload) in codecs {
            let rec = Record::RoundOpen(RoundOpen {
                t: 1,
                model_version: 0,
                sim_now_s: 0.0,
                lr: 0.1,
                stream_base: 0xBEEF,
                plans: (0..8)
                    .map(|d| PlanEntry {
                        device: d,
                        download,
                        upload,
                        batch: 16,
                        tau: 5,
                        beta_d: 1e6,
                        beta_u: 5e5,
                        mu: 1e-4,
                    })
                    .collect(),
            });
            let framed = encode_record(&rec);
            let (back, used) = decode_record(&framed)
                .unwrap_or_else(|e| panic!("{download:?}/{upload:?} plan rejected: {e}"));
            assert_eq!(used, framed.len());
            assert_eq!(encode_record(&back), framed);
        }
    }

    #[test]
    fn recover_keeps_the_whole_valid_stream() {
        let mut rng = Rng::new(0x10A1);
        let records = sample_records(&mut rng, 5);
        let mut bytes = Vec::new();
        for rec in &records {
            bytes.extend_from_slice(&encode_record(rec));
        }
        let got = recover(&bytes);
        assert_eq!(got.records.len(), records.len());
        assert_eq!(got.valid_len, bytes.len());
        assert_eq!(got.discarded(bytes.len()), 0);
        for (a, b) in got.records.iter().zip(&records) {
            assert_eq!(encode_record(a), encode_record(b));
        }
        // ends are strictly increasing and land on the total
        assert!(got.ends.windows(2).all(|w| w[0] < w[1]));
        assert_eq!(*got.ends.last().unwrap(), bytes.len());
    }

    #[test]
    fn recover_of_garbage_is_empty_not_a_panic() {
        for bytes in [
            &b""[..],
            &b"\x00"[..],
            &b"not a journal at all, just some text"[..],
            &[0xFF; 64][..],
        ] {
            let got = recover(bytes);
            assert!(got.records.is_empty());
            assert_eq!(got.valid_len, 0);
        }
    }

    #[test]
    fn zero_and_oversized_lengths_are_typed_errors() {
        let mut zero = vec![0u8; 16];
        assert!(matches!(decode_record(&zero), Err(JournalError::BadLength { len: 0 })));
        zero[0..4].copy_from_slice(&(MAX_RECORD as u32 + 1).to_le_bytes());
        assert!(matches!(decode_record(&zero), Err(JournalError::BadLength { .. })));
    }

    #[test]
    fn version_skew_is_a_typed_error() {
        let mut cfg = crate::config::ExperimentConfig::preset("har");
        cfg.trainer = crate::config::TrainerBackend::Native;
        let rec = Record::RunHeader(RunHeader {
            version: JOURNAL_VERSION,
            scheme: "fedavg".into(),
            snapshot_every: 10,
            cfg,
        });
        let mut framed = encode_record(&rec);
        // bump the version field (first 4 body bytes after len+kind) and
        // re-seal the CRC so only the version check can object
        framed[5] = JOURNAL_VERSION as u8 + 1;
        let n = framed.len();
        let crc = crc32(&framed[..n - 4]);
        framed[n - 4..].copy_from_slice(&crc.to_le_bytes());
        assert!(matches!(
            decode_record(&framed),
            Err(JournalError::Version { got, want: JOURNAL_VERSION })
                if got == JOURNAL_VERSION + 1
        ));
    }

    #[test]
    fn kill_sink_tears_the_scripted_append() {
        let mut rng = Rng::new(0x10A2);
        let records = sample_records(&mut rng, 1);
        let mut sink = KillSink::new(VecSink::default(), 2, 5);
        let mut wrote = Vec::new();
        let mut killed_at = None;
        for (i, rec) in records.iter().enumerate() {
            match sink.append(&encode_record(rec)) {
                Ok(()) => wrote.push(i),
                Err(JournalError::Killed { at_append }) => {
                    killed_at = Some(at_append);
                    break;
                }
                Err(e) => panic!("unexpected {e}"),
            }
        }
        assert_eq!(killed_at, Some(2));
        assert_eq!(wrote, vec![0, 1]);
        let buf = sink.into_inner().buf;
        // the torn 5 bytes are present but recovery discards them
        let whole: usize =
            records[..2].iter().map(|r| encode_record(r).len()).sum();
        assert_eq!(buf.len(), whole + 5);
        let got = recover(&buf);
        assert_eq!(got.records.len(), 2);
        assert_eq!(got.valid_len, whole);
    }

    #[test]
    fn run_journal_divergence_is_detected() {
        let mut rng = Rng::new(0x10A3);
        let records = sample_records(&mut rng, 1);
        let tail: VecDeque<Vec<u8>> =
            records[2..4].iter().map(encode_record).collect();
        let mut jw = RunJournal::resumed(
            Box::new(VecSink::default()),
            2,
            ResumeCarry { records: Vec::new(), expected_tail: tail },
        );
        // matching record: accepted, not rewritten
        jw.append(&records[2]).unwrap();
        // diverging record: typed failure
        match jw.append(&records[1]) {
            Err(JournalError::Diverged { .. }) => {}
            other => panic!("expected divergence, got {other:?}"),
        }
    }
}

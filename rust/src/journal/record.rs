//! Journal record vocabulary + body codec.
//!
//! Every coordinator decision is event-sourced as one [`Record`]:
//!
//! * [`RunHeader`] — first record of every journal: format version, the
//!   scheme name, the snapshot cadence, and the **full**
//!   [`ExperimentConfig`], so a resume rebuilds datasets, partition,
//!   importance table and model init from nothing but the file.
//! * [`Snapshot`] — the complete mutable server state after `t` rounds
//!   (model / locals as length+digest-prefixed f32 blocks, RNG state,
//!   traffic ledger, tracker, clock). Written at `t = 0` and then every
//!   `snapshot_every` rounds; resume restores the last complete one.
//! * [`RoundOpen`] — participant set with codec/ratio assignments (in
//!   canonical ascending-device order), the learning rate, the RNG
//!   stream base, and the pre-round `model_version`.
//! * [`EndRound`] / [`Dropout`] — per-device resolutions in fold order
//!   (ascending device id, exactly the order `Server::apply_round`
//!   consumes them). `EndRound` carries the `w_final` digest — enough
//!   for resume-time cross-checks without storing every local model
//!   every round.
//! * [`RoundClose`] — the traffic-ledger totals, the post-round model
//!   version + digest, and the full [`RoundRecord`] (accuracy / AUC /
//!   mean loss / timing) as raw f64 bit patterns.
//!
//! Bodies are encoded through the same [`BitWriter`] as the wire frame
//! codec (every field a whole number of bytes, little-endian) and decoded
//! by a total bounds-checked byte cursor — a corrupt body yields a typed
//! [`JournalError`], never a panic. Unlike `transport::frame`, f64 fields
//! are stored and returned as **raw bit patterns** with no finiteness
//! checks: NaN is a legal value here (an unevaluated round's accuracy),
//! and integrity is the CRC frame's job (`journal::encode_record`).

use crate::config::{CompressionBackend, EngineConfig, ExperimentConfig, TrainerBackend};
use crate::coordinator::RoundRecord;
use crate::fleet::FleetKind;
use crate::journal::JournalError;
use crate::schemes::{DownloadCodec, UploadCodec};
use crate::util::bitio::BitWriter;
use crate::util::rng::RngState;

/// Journal format version, bumped on ANY record-layout change.
/// v2: `EndRound` carries `fold_t` (the round a late upload folds into)
/// and the engine config tail gains `pipeline_depth` / `staleness_bound`.
pub const JOURNAL_VERSION: u32 = 2;

/// A length+digest-prefixed f32 parameter block (a model or a retained
/// local). The digest is `transport::model_digest` over the block — what
/// `journal replay` and resume cross-check against the recorded bytes.
#[derive(Clone, Debug)]
pub struct ParamBlock {
    pub digest: u64,
    pub w: Vec<f32>,
}

impl ParamBlock {
    pub fn new(w: Vec<f32>) -> ParamBlock {
        ParamBlock { digest: crate::transport::model_digest(&w), w }
    }

    /// Whether the stored digest matches the stored bytes.
    pub fn digest_ok(&self) -> bool {
        crate::transport::model_digest(&self.w) == self.digest
    }
}

/// First record of every journal (see module docs).
#[derive(Clone, Debug)]
pub struct RunHeader {
    pub version: u32,
    pub scheme: String,
    /// Snapshot cadence K: a [`Snapshot`] follows every K-th round close.
    pub snapshot_every: usize,
    pub cfg: ExperimentConfig,
}

/// Complete mutable server state after `t` rounds.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Rounds completed when this snapshot was taken (0 = initial state).
    pub t: usize,
    pub model_version: u64,
    pub sim_time_s: f64,
    /// Server RNG state (participant sampling consumes it every round).
    pub rng: RngState,
    /// Traffic-ledger totals, bit-exact f64s.
    pub down_bits: f64,
    pub up_bits: f64,
    pub model: ParamBlock,
    /// Per-device retained locals (None until first participation).
    pub locals: Vec<Option<ParamBlock>>,
    pub grad_norms: Vec<f64>,
    /// `ParticipationTracker` state: last participation round per device.
    pub last_round: Vec<usize>,
}

/// One planned participant: the scheme's codec/ratio assignment plus the
/// link/compute draws the plan was costed with.
#[derive(Clone, Copy, Debug)]
pub struct PlanEntry {
    pub device: usize,
    pub download: DownloadCodec,
    pub upload: UploadCodec,
    pub batch: usize,
    pub tau: usize,
    pub beta_d: f64,
    pub beta_u: f64,
    pub mu: f64,
}

/// Round `t` opened: participant set + assignments, canonical order.
#[derive(Clone, Debug)]
pub struct RoundOpen {
    pub t: usize,
    /// Pre-round model version (what the downloads were encoded from).
    pub model_version: u64,
    pub sim_now_s: f64,
    pub lr: f32,
    /// Base key of the pure per-(round, device) RNG streams.
    pub stream_base: u64,
    /// Ascending device id — the same canonical order resolutions fold in.
    pub plans: Vec<PlanEntry>,
}

/// A device completed round `t` (fold-order resolution).
#[derive(Clone, Copy, Debug)]
pub struct EndRound {
    pub t: usize,
    /// Round this upload folds into: `t` when on time, `> t` when the
    /// semi-async engine classified the device as a straggler and parked
    /// the upload in the staleness buffer. Always `t` at depth 1/bound 0.
    pub fold_t: usize,
    pub device: usize,
    /// `transport::model_digest` of the device's final local model.
    pub w_digest: u64,
    /// Measured wire bits of the serialized upload (stand-in scale).
    pub upload_bits: usize,
    /// Measured wire bits of the download it received (stand-in scale).
    pub down_wire_bits: usize,
    pub grad_norm: f64,
    pub loss: f64,
    pub download_s: f64,
    pub compute_s: f64,
    pub upload_s: f64,
}

/// A device vanished mid-round (fold-order resolution).
#[derive(Clone, Copy, Debug)]
pub struct Dropout {
    pub t: usize,
    pub device: usize,
    pub after_s: f64,
    pub down_wire_bits: usize,
}

/// Round `t` closed: ledger deltas applied, model aggregated, metrics
/// recorded.
#[derive(Clone, Copy, Debug)]
pub struct RoundClose {
    pub t: usize,
    /// Devices whose updates reached aggregation this round.
    pub completers: usize,
    /// Post-round model version (bumped iff `completers > 0`).
    pub model_version: u64,
    /// `transport::model_digest` of the post-round global model.
    pub model_digest: u64,
    /// Cumulative traffic-ledger totals after this round, bit-exact.
    pub down_bits: f64,
    pub up_bits: f64,
    /// The full per-round metrics record (f64s stored as raw bits; NaN
    /// accuracy means the round was not evaluated).
    pub rec: RoundRecord,
}

/// One journal record. See the module docs for the life cycle.
#[derive(Clone, Debug)]
pub enum Record {
    RunHeader(RunHeader),
    Snapshot(Box<Snapshot>),
    RoundOpen(RoundOpen),
    EndRound(EndRound),
    Dropout(Dropout),
    RoundClose(RoundClose),
}

impl Record {
    pub(crate) fn kind(&self) -> u8 {
        match self {
            Record::RunHeader(_) => 1,
            Record::Snapshot(_) => 2,
            Record::RoundOpen(_) => 3,
            Record::EndRound(_) => 4,
            Record::Dropout(_) => 5,
            Record::RoundClose(_) => 6,
        }
    }

    /// Human-readable kind tag for diagnostics.
    pub fn kind_name(&self) -> &'static str {
        match self {
            Record::RunHeader(_) => "run-header",
            Record::Snapshot(_) => "snapshot",
            Record::RoundOpen(_) => "round-open",
            Record::EndRound(_) => "end-round",
            Record::Dropout(_) => "dropout",
            Record::RoundClose(_) => "round-close",
        }
    }
}

// ---------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------

pub(crate) fn encode_body(rec: &Record, w: &mut BitWriter) {
    match rec {
        Record::RunHeader(h) => {
            w.push_bits(h.version as u64, 32);
            put_str(w, &h.scheme);
            put_u64(w, h.snapshot_every as u64);
            encode_cfg(&h.cfg, w);
        }
        Record::Snapshot(s) => {
            put_u64(w, s.t as u64);
            put_u64(w, s.model_version);
            put_f64(w, s.sim_time_s);
            encode_rng_state(&s.rng, w);
            put_f64(w, s.down_bits);
            put_f64(w, s.up_bits);
            encode_block(&s.model, w);
            put_u64(w, s.locals.len() as u64);
            for local in &s.locals {
                match local {
                    None => w.push_bits(0, 8),
                    Some(b) => {
                        w.push_bits(1, 8);
                        encode_block(b, w);
                    }
                }
            }
            put_u64(w, s.grad_norms.len() as u64);
            for &g in &s.grad_norms {
                put_f64(w, g);
            }
            put_u64(w, s.last_round.len() as u64);
            for &r in &s.last_round {
                put_u64(w, r as u64);
            }
        }
        Record::RoundOpen(o) => {
            put_u64(w, o.t as u64);
            put_u64(w, o.model_version);
            put_f64(w, o.sim_now_s);
            w.push_f32(o.lr);
            put_u64(w, o.stream_base);
            put_u64(w, o.plans.len() as u64);
            for p in &o.plans {
                encode_plan_entry(p, w);
            }
        }
        Record::EndRound(e) => {
            put_u64(w, e.t as u64);
            put_u64(w, e.fold_t as u64);
            put_u64(w, e.device as u64);
            put_u64(w, e.w_digest);
            put_u64(w, e.upload_bits as u64);
            put_u64(w, e.down_wire_bits as u64);
            put_f64(w, e.grad_norm);
            put_f64(w, e.loss);
            put_f64(w, e.download_s);
            put_f64(w, e.compute_s);
            put_f64(w, e.upload_s);
        }
        Record::Dropout(d) => {
            put_u64(w, d.t as u64);
            put_u64(w, d.device as u64);
            put_f64(w, d.after_s);
            put_u64(w, d.down_wire_bits as u64);
        }
        Record::RoundClose(c) => {
            put_u64(w, c.t as u64);
            put_u64(w, c.completers as u64);
            put_u64(w, c.model_version);
            put_u64(w, c.model_digest);
            put_f64(w, c.down_bits);
            put_f64(w, c.up_bits);
            put_u64(w, c.rec.t as u64);
            put_f64(w, c.rec.sim_time_s);
            put_f64(w, c.rec.traffic_gb);
            put_f64(w, c.rec.accuracy);
            put_f64(w, c.rec.auc);
            put_f64(w, c.rec.mean_loss);
            put_f64(w, c.rec.round_s);
            put_f64(w, c.rec.avg_wait_s);
            put_u64(w, c.rec.participants as u64);
        }
    }
}

fn encode_block(b: &ParamBlock, w: &mut BitWriter) {
    put_u64(w, b.w.len() as u64);
    put_u64(w, b.digest);
    for &x in &b.w {
        w.push_f32(x);
    }
}

fn encode_plan_entry(p: &PlanEntry, w: &mut BitWriter) {
    put_u64(w, p.device as u64);
    match p.download {
        DownloadCodec::Full => w.push_bits(0, 8),
        DownloadCodec::CaesarSplit { ratio } => {
            w.push_bits(1, 8);
            put_f64(w, ratio);
        }
        DownloadCodec::TopK { ratio } => {
            w.push_bits(2, 8);
            put_f64(w, ratio);
        }
        DownloadCodec::Quant { bits } => {
            w.push_bits(3, 8);
            w.push_bits(bits as u64, 32);
        }
    }
    match p.upload {
        UploadCodec::Full => w.push_bits(0, 8),
        UploadCodec::TopK { ratio } => {
            w.push_bits(1, 8);
            put_f64(w, ratio);
        }
        UploadCodec::Quant { bits } => {
            w.push_bits(2, 8);
            w.push_bits(bits as u64, 32);
        }
    }
    put_u64(w, p.batch as u64);
    put_u64(w, p.tau as u64);
    put_f64(w, p.beta_d);
    put_f64(w, p.beta_u);
    put_f64(w, p.mu);
}

fn encode_rng_state(st: &RngState, w: &mut BitWriter) {
    for &word in &st.s {
        put_u64(w, word);
    }
    match st.spare_normal {
        None => w.push_bits(0, 8),
        Some(x) => {
            w.push_bits(1, 8);
            put_f64(w, x);
        }
    }
}

fn encode_cfg(cfg: &ExperimentConfig, w: &mut BitWriter) {
    put_str(w, &cfg.task);
    match cfg.fleet {
        FleetKind::Jetson80 => w.push_bits(0, 8),
        FleetKind::Phone40 => w.push_bits(1, 8),
        FleetKind::JetsonScaled(n) => {
            w.push_bits(2, 8);
            put_u64(w, n as u64);
        }
    }
    put_u64(w, cfg.n_train as u64);
    put_u64(w, cfg.n_test as u64);
    put_u64(w, cfg.rounds as u64);
    put_f64(w, cfg.alpha);
    put_u64(w, cfg.tau as u64);
    put_u64(w, cfg.batch as u64);
    put_f64(w, cfg.lr);
    put_f64(w, cfg.lr_decay);
    put_f64(w, cfg.het_p);
    put_f64(w, cfg.theta_min);
    put_f64(w, cfg.theta_max);
    put_f64(w, cfg.lambda);
    put_u64(w, cfg.clusters as u64);
    put_u64(w, cfg.n_params_paper as u64);
    put_f64(w, cfg.model_cost);
    put_u64(w, cfg.eval_every as u64);
    put_f64(w, cfg.target_acc);
    put_u64(w, cfg.seed);
    w.push_bits(
        match cfg.trainer {
            TrainerBackend::Native => 0,
            TrainerBackend::Xla => 1,
        },
        8,
    );
    w.push_bits(
        match cfg.compression {
            CompressionBackend::Native => 0,
            CompressionBackend::Xla => 1,
        },
        8,
    );
    put_u64(w, cfg.engine.workers as u64);
    put_u64(w, cfg.engine.agg_group as u64);
    put_u64(w, cfg.engine.agg_chunk as u64);
    put_f64(w, cfg.engine.dropout_rate);
    put_f64(w, cfg.engine.heartbeat_s);
    put_u64(w, cfg.engine.pipeline_depth as u64);
    put_u64(w, cfg.engine.staleness_bound as u64);
}

fn put_u64(w: &mut BitWriter, v: u64) {
    w.push_bits(v, 64);
}

fn put_f64(w: &mut BitWriter, v: f64) {
    w.push_bits(v.to_bits(), 64);
}

fn put_str(w: &mut BitWriter, s: &str) {
    w.push_bits(s.len() as u64, 32);
    w.push_bytes(s.as_bytes());
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

pub(crate) fn decode_body(kind: u8, body: &[u8]) -> Result<Record, JournalError> {
    let mut r = Reader { buf: body, pos: 0 };
    let rec = match kind {
        1 => Record::RunHeader(RunHeader {
            version: {
                let v = r.u32()?;
                if v != JOURNAL_VERSION {
                    return Err(JournalError::Version { got: v, want: JOURNAL_VERSION });
                }
                v
            },
            scheme: r.string()?,
            snapshot_every: r.usize64()?,
            cfg: decode_cfg(&mut r)?,
        }),
        2 => {
            let t = r.usize64()?;
            let model_version = r.u64()?;
            let sim_time_s = r.f64raw()?;
            let rng = decode_rng_state(&mut r)?;
            let down_bits = r.f64raw()?;
            let up_bits = r.f64raw()?;
            let model = decode_block(&mut r)?;
            let n_locals = r.usize64()?;
            r.need_at_least(n_locals)?; // 1 flag byte per local, minimum
            let mut locals = Vec::with_capacity(n_locals);
            for _ in 0..n_locals {
                locals.push(match r.u8()? {
                    0 => None,
                    1 => Some(decode_block(&mut r)?),
                    _ => return Err(JournalError::Malformed("local-model flag")),
                });
            }
            let n_norms = r.usize64()?;
            r.need_at_least(n_norms.checked_mul(8).ok_or(OVERFLOW)?)?;
            let mut grad_norms = Vec::with_capacity(n_norms);
            for _ in 0..n_norms {
                grad_norms.push(r.f64raw()?);
            }
            let n_last = r.usize64()?;
            r.need_at_least(n_last.checked_mul(8).ok_or(OVERFLOW)?)?;
            let mut last_round = Vec::with_capacity(n_last);
            for _ in 0..n_last {
                last_round.push(r.usize64()?);
            }
            Record::Snapshot(Box::new(Snapshot {
                t,
                model_version,
                sim_time_s,
                rng,
                down_bits,
                up_bits,
                model,
                locals,
                grad_norms,
                last_round,
            }))
        }
        3 => {
            let t = r.round_no()?;
            let model_version = r.u64()?;
            let sim_now_s = r.f64raw()?;
            let lr = r.f32()?;
            let stream_base = r.u64()?;
            let n = r.usize64()?;
            r.need_at_least(n.checked_mul(MIN_PLAN_ENTRY_BYTES).ok_or(OVERFLOW)?)?;
            let mut plans = Vec::with_capacity(n);
            for _ in 0..n {
                plans.push(decode_plan_entry(&mut r)?);
            }
            Record::RoundOpen(RoundOpen { t, model_version, sim_now_s, lr, stream_base, plans })
        }
        4 => {
            let t = r.round_no()?;
            let fold_t = r.round_no()?;
            if fold_t < t {
                return Err(JournalError::Malformed("fold round precedes origin round"));
            }
            Record::EndRound(EndRound {
                t,
                fold_t,
                device: r.usize64()?,
                w_digest: r.u64()?,
                upload_bits: r.usize64()?,
                down_wire_bits: r.usize64()?,
                grad_norm: r.f64raw()?,
                loss: r.f64raw()?,
                download_s: r.f64raw()?,
                compute_s: r.f64raw()?,
                upload_s: r.f64raw()?,
            })
        }
        5 => Record::Dropout(Dropout {
            t: r.round_no()?,
            device: r.usize64()?,
            after_s: r.f64raw()?,
            down_wire_bits: r.usize64()?,
        }),
        6 => Record::RoundClose(RoundClose {
            t: r.round_no()?,
            completers: r.usize64()?,
            model_version: r.u64()?,
            model_digest: r.u64()?,
            down_bits: r.f64raw()?,
            up_bits: r.f64raw()?,
            rec: RoundRecord {
                t: r.usize64()?,
                sim_time_s: r.f64raw()?,
                traffic_gb: r.f64raw()?,
                accuracy: r.f64raw()?,
                auc: r.f64raw()?,
                mean_loss: r.f64raw()?,
                round_s: r.f64raw()?,
                avg_wait_s: r.f64raw()?,
                participants: r.usize64()?,
            },
        }),
        other => return Err(JournalError::UnknownKind(other)),
    };
    if r.pos != r.buf.len() {
        return Err(JournalError::Malformed("trailing bytes in record body"));
    }
    Ok(rec)
}

const OVERFLOW: JournalError = JournalError::Malformed("length overflow");

/// Smallest possible [`PlanEntry`] encoding, used to pre-flight the plan
/// count before `Vec::with_capacity`: device (8) + two codec tags with no
/// payload (Full/Full, 1+1) + batch (8) + tau (8) + three f64s (24).
const MIN_PLAN_ENTRY_BYTES: usize = 50;

fn decode_block(r: &mut Reader) -> Result<ParamBlock, JournalError> {
    let n = r.usize64()?;
    let digest = r.u64()?;
    r.need_at_least(n.checked_mul(4).ok_or(OVERFLOW)?)?;
    let mut w = Vec::with_capacity(n);
    for _ in 0..n {
        w.push(r.f32()?);
    }
    Ok(ParamBlock { digest, w })
}

fn decode_plan_entry(r: &mut Reader) -> Result<PlanEntry, JournalError> {
    let device = r.usize64()?;
    let download = match r.u8()? {
        0 => DownloadCodec::Full,
        1 => DownloadCodec::CaesarSplit { ratio: r.f64raw()? },
        2 => DownloadCodec::TopK { ratio: r.f64raw()? },
        3 => DownloadCodec::Quant { bits: r.u32()? },
        _ => return Err(JournalError::Malformed("unknown download codec")),
    };
    let upload = match r.u8()? {
        0 => UploadCodec::Full,
        1 => UploadCodec::TopK { ratio: r.f64raw()? },
        2 => UploadCodec::Quant { bits: r.u32()? },
        _ => return Err(JournalError::Malformed("unknown upload codec")),
    };
    Ok(PlanEntry {
        device,
        download,
        upload,
        batch: r.usize64()?,
        tau: r.usize64()?,
        beta_d: r.f64raw()?,
        beta_u: r.f64raw()?,
        mu: r.f64raw()?,
    })
}

fn decode_rng_state(r: &mut Reader) -> Result<RngState, JournalError> {
    let s = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
    let spare_normal = match r.u8()? {
        0 => None,
        1 => Some(r.f64raw()?),
        _ => return Err(JournalError::Malformed("rng spare-normal flag")),
    };
    Ok(RngState { s, spare_normal })
}

fn decode_cfg(r: &mut Reader) -> Result<ExperimentConfig, JournalError> {
    let task = r.string()?;
    let fleet = match r.u8()? {
        0 => FleetKind::Jetson80,
        1 => FleetKind::Phone40,
        2 => FleetKind::JetsonScaled(r.usize64()?),
        _ => return Err(JournalError::Malformed("unknown fleet kind")),
    };
    Ok(ExperimentConfig {
        task,
        fleet,
        n_train: r.usize64()?,
        n_test: r.usize64()?,
        rounds: r.usize64()?,
        alpha: r.f64raw()?,
        tau: r.usize64()?,
        batch: r.usize64()?,
        lr: r.f64raw()?,
        lr_decay: r.f64raw()?,
        het_p: r.f64raw()?,
        theta_min: r.f64raw()?,
        theta_max: r.f64raw()?,
        lambda: r.f64raw()?,
        clusters: r.usize64()?,
        n_params_paper: r.usize64()?,
        model_cost: r.f64raw()?,
        eval_every: r.usize64()?,
        target_acc: r.f64raw()?,
        seed: r.u64()?,
        trainer: match r.u8()? {
            0 => TrainerBackend::Native,
            1 => TrainerBackend::Xla,
            _ => return Err(JournalError::Malformed("unknown trainer backend")),
        },
        compression: match r.u8()? {
            0 => CompressionBackend::Native,
            1 => CompressionBackend::Xla,
            _ => return Err(JournalError::Malformed("unknown compression backend")),
        },
        engine: EngineConfig {
            workers: r.usize64()?,
            agg_group: r.usize64()?,
            agg_chunk: r.usize64()?,
            dropout_rate: r.f64raw()?,
            heartbeat_s: r.f64raw()?,
            pipeline_depth: r.usize64()?,
            staleness_bound: r.usize64()?,
        },
    })
}

/// Bounds-checked byte cursor over a record body — the journal-side
/// sibling of `transport::frame`'s `BodyReader`. Total: every read either
/// yields a value or a typed [`JournalError`].
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn need(&self, n: usize) -> Result<(), JournalError> {
        let have = self.buf.len() - self.pos;
        if n > have {
            return Err(JournalError::Malformed("record body too short"));
        }
        Ok(())
    }

    /// Pre-flight a declared element count before `Vec::with_capacity`:
    /// the remaining bytes must plausibly hold it, so a corrupt length
    /// can never drive an over-allocation.
    fn need_at_least(&self, n: usize) -> Result<(), JournalError> {
        self.need(n)
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], JournalError> {
        self.need(n)?;
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, JournalError> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, JournalError> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, JournalError> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32, JournalError> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// An f64 as its raw bit pattern — NaN and ∞ round-trip untouched.
    fn f64raw(&mut self) -> Result<f64, JournalError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn usize64(&mut self) -> Result<usize, JournalError> {
        usize::try_from(self.u64()?)
            .map_err(|_| JournalError::Malformed("length overflows usize"))
    }

    /// A 1-based round number.
    fn round_no(&mut self) -> Result<usize, JournalError> {
        let t = self.usize64()?;
        if t == 0 {
            return Err(JournalError::Malformed("round numbers are 1-based"));
        }
        Ok(t)
    }

    fn string(&mut self) -> Result<String, JournalError> {
        let n = self.u32()? as usize;
        let b = self.bytes(n)?;
        String::from_utf8(b.to_vec()).map_err(|_| JournalError::Malformed("non-utf8 string"))
    }
}

//! Offline journal verification — `caesar replay`.
//!
//! [`verify`] re-derives a run from nothing but its journal records and
//! cross-checks every re-derivable quantity **bit-exactly**, without
//! constructing a trainer, dataset, or accelerator runtime:
//!
//! * the traffic ledger, replayed through the same [`TrafficMeter`] /
//!   [`PayloadScale`] arithmetic `Server::apply_round` uses, in the same
//!   f64 accumulation order (all EndRounds of a round, then its
//!   Dropouts — the journal stores resolutions merged in fold order, so
//!   the replay makes two passes);
//! * barrier timing (`round_s` as the same `f64::max` fold, `avg_wait_s`,
//!   `sim_time_s`) and `mean_loss`;
//! * the evaluation cadence (`accuracy` is NaN exactly on unevaluated
//!   rounds) and the learning-rate schedule (`cfg.lr_at`);
//! * `model_version` bumps (iff a round had completers);
//! * every stored digest: [`ParamBlock`] self-consistency in snapshots,
//!   snapshot locals against the last `EndRound.w_digest` per device,
//!   and the model-digest *chain* — a round with no completers must
//!   carry the previous model digest forward, and every snapshot's model
//!   digest must equal the preceding `RoundClose.model_digest`.
//!
//! **Semi-async journals.** The verifier simulates the same deterministic
//! scheduler `Server::run_pipelined_cb` runs: a depth-capped window of
//! open rounds that never crosses a snapshot boundary, closed oldest
//! first (a barrier run is the depth-1 degenerate case — same grammar).
//! Each `EndRound` carries the round its upload folds into; replay
//! re-derives that fold round from the round's **own journaled costs**
//! via the cost-median lateness rule ([`crate::coordinator::
//! classify_lateness`]) and demands an exact match, then tracks the
//! staleness buffer so every close's `completers` (= on-time + absorbed
//! stragglers) and every timing/ledger formula checks bit-exactly.
//!
//! What replay deliberately cannot check: training itself (`w_digest` of
//! a fresh local, the aggregated model bits between snapshots) — those
//! are pinned by the resume path and `rust/tests/durability.rs`, which
//! do own trainers.
//!
//! A journal recovered from a crash is a valid *prefix*: a trailing
//! round that opened but never closed (or a due open or snapshot the
//! kill preempted) is reported via [`ReplaySummary::partial_tail`], not
//! as an error.

use anyhow::{anyhow, Result};

use crate::compress::traffic::{PayloadScale, TrafficMeter};
use crate::coordinator::{barrier_after, classify_lateness};
use crate::journal::record::{Record, RoundClose, RoundOpen, RunHeader, Snapshot};

/// What [`verify`] established about a journal.
#[derive(Clone, Copy, Debug)]
pub struct ReplaySummary {
    /// Complete rounds verified (open + resolutions + close).
    pub rounds: usize,
    /// Digest cross-checks performed (block self-checks, local-vs-
    /// EndRound matches, model-chain links).
    pub digests_checked: usize,
    /// Digest of the model as of the last verified point.
    pub final_model_digest: u64,
    /// Replayed traffic-ledger totals, bit-exact.
    pub down_bits: f64,
    pub up_bits: f64,
    pub sim_time_s: f64,
    /// Snapshots verified (including the initial one).
    pub snapshots: usize,
    /// Uploads classified late (parked in the staleness buffer at their
    /// origin round, folded at a later one). Always 0 for barrier runs.
    pub late_uploads: usize,
    /// True when the journal ends mid-round or before a due open or
    /// snapshot — the valid-prefix shape a crash leaves behind.
    pub partial_tail: bool,
}

/// Bit-exact f64 comparison: NaN == NaN, -0.0 != 0.0 — the journal
/// stores raw bit patterns and the replay must reproduce them exactly.
fn same_bits(a: f64, b: f64) -> bool {
    a.to_bits() == b.to_bits()
}

fn check(cond: bool, what: impl FnOnce() -> String) -> Result<()> {
    if cond {
        Ok(())
    } else {
        Err(anyhow!("replay: {}", what()))
    }
}

/// Verify a recovered record stream (see module docs). Errors name the
/// first inconsistency; a torn-but-valid prefix is not an error.
pub fn verify(records: &[Record]) -> Result<ReplaySummary> {
    let mut it = records.iter().peekable();

    let header: &RunHeader = match it.next() {
        Some(Record::RunHeader(h)) => h,
        Some(other) => {
            return Err(anyhow!("replay: journal starts with {}, not a run header", other.kind_name()))
        }
        None => return Err(anyhow!("replay: empty journal")),
    };
    let cfg = &header.cfg;
    check(header.snapshot_every >= 1, || {
        format!("snapshot cadence {} is not >= 1", header.snapshot_every)
    })?;
    // cfg invariants the replay arithmetic depends on: a CRC-valid but
    // crafted/corrupted header must yield a typed error, not a panic —
    // eval_every feeds a remainder below, and no live run can journal a
    // zero (the CLI clamps it and the coordinator's own eval cadence
    // would divide by it)
    check(cfg.eval_every >= 1, || {
        format!("config eval_every {} is not >= 1", cfg.eval_every)
    })?;

    let snap0: &Snapshot = match it.next() {
        Some(Record::Snapshot(s)) if s.t == 0 => s,
        Some(other) => {
            return Err(anyhow!(
                "replay: second record is {}, not the initial snapshot",
                other.kind_name()
            ))
        }
        None => return Err(anyhow!("replay: journal ends before the initial snapshot")),
    };

    let n_devices = cfg.n_devices();
    let n_real = snap0.model.w.len();
    let scale = PayloadScale { n_real, n_paper: cfg.n_params_paper };
    let participants = cfg.participants_per_round();

    let mut digests_checked = 0usize;
    let verify_snapshot_shape = |s: &Snapshot| -> Result<()> {
        check(s.model.digest_ok(), || format!("snapshot t={}: model digest mismatch", s.t))?;
        check(s.model.w.len() == n_real, || {
            format!("snapshot t={}: model has {} params, expected {n_real}", s.t, s.model.w.len())
        })?;
        check(
            s.locals.len() == n_devices
                && s.grad_norms.len() == n_devices
                && s.last_round.len() == n_devices,
            || format!("snapshot t={}: per-device vectors are not fleet-sized", s.t),
        )?;
        for (d, local) in s.locals.iter().enumerate() {
            if let Some(b) = local {
                check(b.digest_ok(), || format!("snapshot t={}: local {d} digest mismatch", s.t))?;
            }
        }
        Ok(())
    };
    verify_snapshot_shape(snap0)?;
    digests_checked += 1 + snap0.locals.iter().flatten().count();

    // --- replayed server state ---
    let mut traffic = TrafficMeter { down_bits: snap0.down_bits, up_bits: snap0.up_bits };
    let mut sim_time_s = snap0.sim_time_s;
    let mut model_version = snap0.model_version;
    let mut model_digest = snap0.model.digest;
    // per-device shadows of what snapshots must agree with
    let mut last_w_digest: Vec<Option<u64>> = snap0
        .locals
        .iter()
        .map(|l| l.as_ref().map(|b| b.digest))
        .collect();
    let mut grad_norms: Vec<f64> = snap0.grad_norms.clone();
    let mut last_round: Vec<usize> = snap0.last_round.clone();

    let depth = cfg.engine.pipeline_depth.max(1);
    let quiesce = header.snapshot_every;
    let total_rounds = cfg.rounds;

    let mut stream_base: Option<u64> = None;
    let mut rounds = 0usize;
    let mut snapshots = 1usize;
    let mut late_uploads = 0usize;
    let mut partial_tail = false;
    // fold rounds of parked stragglers (the replayed staleness buffer)
    let mut parked: Vec<usize> = Vec::new();
    // opened-but-unclosed rounds, oldest first (the front is round t)
    let mut window: std::collections::VecDeque<&RoundOpen> = std::collections::VecDeque::new();
    let mut next_open = 1usize;

    'rounds: loop {
        let t = rounds + 1;
        if t > total_rounds {
            if let Some(other) = it.next() {
                return Err(anyhow!(
                    "replay: journal continues past the configured {total_rounds} rounds with {}",
                    other.kind_name()
                ));
            }
            break;
        }

        // --- opens due before round t can close: the deterministic
        // window schedule — depth-capped, never past the next quiescence
        // barrier (a snapshot boundary) — exactly as the scheduler in
        // `Server::run_pipelined_cb` emits it (the barrier loop is its
        // depth-1 degenerate case). Each open is validated against the
        // replay state AT THIS POINT: overlapped rounds legitimately
        // open at the pre-close model version and clock ---
        while next_open <= barrier_after(t, quiesce, total_rounds) && window.len() < depth {
            let u = next_open;
            let open: &RoundOpen = match it.next() {
                None => {
                    partial_tail = true;
                    break 'rounds;
                }
                Some(Record::RoundOpen(o)) => o,
                Some(other) => {
                    return Err(anyhow!(
                        "replay: expected round {u} to open, found {}",
                        other.kind_name()
                    ))
                }
            };
            check(open.t == u, || {
                format!("round open out of sequence: got t={}, expected {u}", open.t)
            })?;
            check(open.model_version == model_version, || {
                format!(
                    "round {u} opened at model v{}, replay is at v{model_version}",
                    open.model_version
                )
            })?;
            check(same_bits(open.sim_now_s, sim_time_s), || {
                format!("round {u} opened at sim time {}, replay is at {sim_time_s}", open.sim_now_s)
            })?;
            check(open.lr.to_bits() == (cfg.lr_at(u - 1) as f32).to_bits(), || {
                format!("round {u} lr {} differs from the schedule's {}", open.lr, cfg.lr_at(u - 1))
            })?;
            match stream_base {
                None => stream_base = Some(open.stream_base),
                Some(base) => check(open.stream_base == base, || {
                    format!("round {u} changed the RNG stream base")
                })?,
            }
            check(open.plans.len() == participants, || {
                format!("round {u} planned {} devices, cfg says {participants}", open.plans.len())
            })?;
            check(
                open.plans.windows(2).all(|w| w[0].device < w[1].device)
                    && open.plans.iter().all(|p| p.device < n_devices),
                || format!("round {u} plan set is not strictly ascending in-range device ids"),
            )?;
            window.push_back(open);
            next_open += 1;
        }
        let open = window.pop_front().expect("the schedule opens round t before it closes");

        // --- resolutions in fold order, until the close ---
        let mut ends = Vec::new();
        let mut drops = Vec::new();
        let mut resolved: Vec<usize> = Vec::new();
        let close: &RoundClose = loop {
            match it.next() {
                None => {
                    partial_tail = true;
                    break 'rounds;
                }
                Some(Record::EndRound(e)) => {
                    check(e.t == t, || format!("round {t}: end-round tagged t={}", e.t))?;
                    resolved.push(e.device);
                    ends.push(e);
                }
                Some(Record::Dropout(d)) => {
                    check(d.t == t, || format!("round {t}: dropout tagged t={}", d.t))?;
                    resolved.push(d.device);
                    drops.push(d);
                }
                Some(Record::RoundClose(c)) => break c,
                Some(other) => {
                    return Err(anyhow!(
                        "replay: round {t} interrupted by {}",
                        other.kind_name()
                    ))
                }
            }
        };
        // every planned device resolves at its own round exactly once,
        // in ascending device order — late or not, an upload's EndRound
        // lives in its origin round's close group
        let planned: Vec<usize> = open.plans.iter().map(|p| p.device).collect();
        check(resolved == planned, || {
            format!("round {t}: resolutions {resolved:?} do not match the plan {planned:?}")
        })?;

        // --- re-derive each completer's fold round from the round's own
        // journaled costs (the cost-median lateness rule is a pure
        // function of them) and demand the journal agrees ---
        let costs_all: Vec<f64> =
            ends.iter().map(|e| e.download_s + e.compute_s + e.upload_s).collect();
        let s_eff = cfg
            .engine
            .staleness_bound
            .min(barrier_after(t, quiesce, total_rounds).saturating_sub(t));
        let fold_ts = classify_lateness(&costs_all, t, s_eff);
        for (e, &f) in ends.iter().zip(&fold_ts) {
            check(e.fold_t == f, || {
                format!(
                    "round {t}: device {} journaled fold round {} but the cost-median \
                     rule derives {f}",
                    e.device, e.fold_t
                )
            })?;
        }

        // --- replay the close, in its exact f64 order: every end's
        // down+up first (all land at the origin round), then every
        // dropout's down ---
        let n_ends = ends.len();
        let mut n_on_time = 0usize;
        let mut loss_sum = 0.0f64;
        let mut costs: Vec<f64> = Vec::with_capacity(n_ends);
        for (i, e) in ends.iter().enumerate() {
            traffic.add_down(scale.scale_bits(e.down_wire_bits));
            traffic.add_up(scale.scale_bits(e.upload_bits));
            grad_norms[e.device] = e.grad_norm;
            last_w_digest[e.device] = Some(e.w_digest);
            last_round[e.device] = t;
            loss_sum += e.loss;
            if fold_ts[i] == t {
                n_on_time += 1;
                costs.push(costs_all[i]);
            } else {
                parked.push(fold_ts[i]);
                late_uploads += 1;
            }
        }
        for d in &drops {
            traffic.add_down(scale.scale_bits(d.down_wire_bits));
        }
        // prior rounds' stragglers whose fold slot is this round
        let due = parked.iter().filter(|&&f| f <= t).count();
        parked.retain(|&f| f > t);
        let folded = n_on_time + due;
        if folded > 0 {
            model_version += 1;
            // the model moved: its digest is whatever the close claims,
            // chain-checked at the next snapshot
            model_digest = close.model_digest;
        } else {
            check(close.model_digest == model_digest, || {
                format!("round {t} folded nothing but the model digest changed")
            })?;
        }
        digests_checked += 1;
        // semi-async timing: only on-time completers and noticed
        // dropouts hold the round (identical to the barrier fold when
        // nothing is late)
        let round_s = costs
            .iter()
            .copied()
            .chain(drops.iter().map(|d| d.after_s))
            .fold(0.0f64, f64::max);
        let avg_wait_s = if n_on_time > 0 {
            costs.iter().map(|&c| round_s - c).sum::<f64>() / n_on_time as f64
        } else {
            0.0
        };
        sim_time_s += round_s;
        let mean_loss = if n_ends > 0 { loss_sum / n_ends as f64 } else { f64::NAN };

        check(close.t == t, || format!("round close tagged t={}, expected {t}", close.t))?;
        check(close.completers == folded, || {
            format!(
                "round {t} close claims {} folded uploads, replay counted {folded}",
                close.completers
            )
        })?;
        check(close.model_version == model_version, || {
            format!("round {t} close at model v{}, replay is at v{model_version}", close.model_version)
        })?;
        check(same_bits(close.down_bits, traffic.down_bits), || {
            format!("round {t}: downlink ledger diverged ({} vs replayed {})", close.down_bits, traffic.down_bits)
        })?;
        check(same_bits(close.up_bits, traffic.up_bits), || {
            format!("round {t}: uplink ledger diverged ({} vs replayed {})", close.up_bits, traffic.up_bits)
        })?;
        let rec = &close.rec;
        check(rec.t == t, || format!("round {t} metrics record tagged t={}", rec.t))?;
        check(same_bits(rec.sim_time_s, sim_time_s), || {
            format!("round {t}: sim time diverged ({} vs replayed {sim_time_s})", rec.sim_time_s)
        })?;
        check(same_bits(rec.traffic_gb, traffic.total_gb()), || {
            format!("round {t}: traffic_gb diverged ({} vs replayed {})", rec.traffic_gb, traffic.total_gb())
        })?;
        check(same_bits(rec.mean_loss, mean_loss), || {
            format!("round {t}: mean loss diverged ({} vs replayed {mean_loss})", rec.mean_loss)
        })?;
        check(same_bits(rec.round_s, round_s), || {
            format!("round {t}: round_s diverged ({} vs replayed {round_s})", rec.round_s)
        })?;
        check(same_bits(rec.avg_wait_s, avg_wait_s), || {
            format!("round {t}: avg_wait_s diverged ({} vs replayed {avg_wait_s})", rec.avg_wait_s)
        })?;
        check(rec.participants == participants, || {
            format!("round {t}: {} participants recorded, cfg says {participants}", rec.participants)
        })?;
        let evaluated = t % cfg.eval_every == 0 || t == cfg.rounds;
        check(evaluated != rec.accuracy.is_nan(), || {
            format!(
                "round {t}: accuracy {} contradicts the eval cadence (evaluated={evaluated})",
                rec.accuracy
            )
        })?;
        if !evaluated {
            check(rec.auc.is_nan(), || format!("round {t}: auc set on an unevaluated round"))?;
        }
        rounds = t;

        // --- due snapshot, unless the journal ends first ---
        if t % header.snapshot_every == 0 {
            match it.peek() {
                None => {
                    partial_tail = true;
                    break 'rounds;
                }
                Some(Record::Snapshot(s)) => {
                    it.next();
                    check(s.t == t, || format!("snapshot after round {t} tagged t={}", s.t))?;
                    verify_snapshot_shape(s)?;
                    digests_checked += 1 + s.locals.iter().flatten().count();
                    check(s.model.digest == model_digest, || {
                        format!(
                            "snapshot t={t}: model digest breaks the chain from the round close"
                        )
                    })?;
                    digests_checked += 1;
                    check(s.model_version == model_version, || {
                        format!("snapshot t={t}: model v{}, replay is at v{model_version}", s.model_version)
                    })?;
                    check(same_bits(s.sim_time_s, sim_time_s), || {
                        format!("snapshot t={t}: sim time diverged")
                    })?;
                    check(
                        same_bits(s.down_bits, traffic.down_bits)
                            && same_bits(s.up_bits, traffic.up_bits),
                        || format!("snapshot t={t}: traffic ledger diverged"),
                    )?;
                    for d in 0..n_devices {
                        let got = s.locals[d].as_ref().map(|b| b.digest);
                        check(got == last_w_digest[d], || {
                            format!(
                                "snapshot t={t}: local {d} digest {:?} contradicts the \
                                 end-round history {:?}",
                                got, last_w_digest[d]
                            )
                        })?;
                        if got.is_some() {
                            digests_checked += 1;
                        }
                        check(same_bits(s.grad_norms[d], grad_norms[d]), || {
                            format!("snapshot t={t}: grad norm of device {d} diverged")
                        })?;
                        check(s.last_round[d] == last_round[d], || {
                            format!("snapshot t={t}: participation round of device {d} diverged")
                        })?;
                    }
                    snapshots += 1;
                }
                Some(other) => {
                    return Err(anyhow!(
                        "replay: snapshot due after round {t}, found {}",
                        other.kind_name()
                    ))
                }
            }
        }
    }

    Ok(ReplaySummary {
        rounds,
        digests_checked,
        final_model_digest: model_digest,
        down_bits: traffic.down_bits,
        up_bits: traffic.up_bits,
        sim_time_s,
        snapshots,
        late_uploads,
        partial_tail,
    })
}

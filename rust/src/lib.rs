//! Caesar: low-deviation model/gradient compression for efficient
//! federated learning — a reproduction of Yan et al. (2024).
//!
//! Three-layer architecture (DESIGN.md §2): this rust crate is Layer 3
//! (coordinator, fleet simulator, schemes, experiments) plus the PJRT
//! runtime that executes the Layer-2 JAX / Layer-1 Pallas artifacts
//! AOT-lowered by `python/compile/aot.py` into `artifacts/*.hlo.txt`.
//!
//! Public API tour:
//! * [`coordinator::Server`] — the synchronous FL round loop. `Server::run`
//!   is the one-call driver: select → plan → execute → aggregate → record,
//!   round after round.
//! * [`engine`] — the event-driven round engine underneath `Server`: a
//!   coordinator state machine (`Standby → Round(t) → Finished`) exchanging
//!   typed messages (`Join`/`Heartbeat`/`StartRound`/`EndRound`/`Dropout`)
//!   with simulated devices, executing device work through a run-lifetime
//!   [`engine::ExecutorHandle`] — inline, or batched onto the persistent
//!   [`util::threadpool::WorkerPool`] whose long-lived threads each own
//!   their trainer (one PJRT runtime per worker, built once per RUN) —
//!   and aggregating through streaming, order-exact shards.
//!   `cfg.engine.workers` selects the parallelism; every worker count is
//!   bit-identical for a fixed seed, and a panicking worker surfaces as
//!   an error event, never a deadlock.
//! * [`schemes`] — Caesar and the paper's baselines behind one trait; the
//!   codec enums carry `encode_payload` constructors for the wire forms.
//! * [`compress`] — the §4.1/§4.2 codecs (native; pinned to the L1 kernels).
//! * [`wire`] — the serialized form of every compressed tensor: a
//!   [`wire::Payload`] (Dense / TopK / CaesarSplit / Quant) with bit-exact
//!   `encode`/`decode` over [`util::bitio`]. Downloads and uploads really
//!   cross the simulated wire as bytes; traffic and transfer time derive
//!   from the measured `EncodedPayload::bits`, with the legacy
//!   `compress::traffic` formulas demoted to debug-assert cross-checks.
//!   The hot path never materializes a decoded payload: a borrowed
//!   [`wire::PayloadView`] streams elements off the bytes — recovery
//!   writes in place (`CodecEngine::recover_download_into` into pooled
//!   [`util::pool`] buffers) and uploads fold sparsely straight from
//!   their serialization (`engine::AggregatorShard::fold_encoded`,
//!   O(kept) per device). PS-side download encodes are deduplicated by
//!   [`engine::DownloadCache`], generation-keyed on `(model version,
//!   effective codec)` — O(distinct codecs) per model generation, not
//!   O(participants), with reuse across rounds whenever the global model
//!   did not move.
//! * [`transport`] — the networked coordinator: a std-only binary frame
//!   codec ([`transport::frame`], magic + version + tag + length-prefixed
//!   body, total on untrusted input) under a [`transport::Transport`] /
//!   [`transport::Conn`] pair with two implementations — in-process
//!   [`transport::LoopbackHub`] (the default and parity baseline) and
//!   [`transport::TcpTransport`] (framed `std::net::TcpStream`,
//!   reconnect-with-rejoin). [`transport::CoordinatorService`] drives
//!   the `Server`+`Engine` pair from decoded frames on a
//!   readiness-driven serving loop — [`transport::Reactor`] parks in
//!   `poll(2)` over the listener and every live connection at once
//!   (waker keys on Loopback, a threaded-reader pump as the portable
//!   fallback), so the coordinator wakes per frame delivered, never on
//!   a sleep-poll timer. [`transport::DeviceClient`] runs the worker
//!   side of a round remotely, and [`transport::DeviceFleet`]
//!   multiplexes many device sessions over ONE connection — frames are
//!   routed by device id, not socket, and the registry binds each
//!   device to the connection its Join arrived on. Invariant: a
//!   fixed-seed Tcp localhost run — connection-per-device or
//!   fleet-multiplexed, barrier or pipelined — is bit-identical (final
//!   model, traffic ledger, round records) to the Loopback and
//!   in-process runs.
//! * [`journal`] — durable rounds: an append-only, CRC-framed record log
//!   event-sourcing every coordinator decision (round plans, per-device
//!   resolutions in fold order, traffic ledgers, periodic model
//!   snapshots). `Server::journaled_open` resumes a killed run from the
//!   last snapshot + journal tail and continues **bit-identically**;
//!   [`journal::verify`] re-derives the whole run offline — no trainers —
//!   cross-checking every recorded digest; torn tails are CRC-detected
//!   and discarded, never trusted.
//! * [`caesar`] — Eq. 3–9: staleness, importance, batch-size regulation.
//! * [`fleet`], [`data`] — the simulated testbed and non-IID datasets.
//! * [`runtime`] — PJRT CPU execution of the AOT artifacts.
//! * [`experiments`] — one runner per paper table/figure.
//!
//! When to use what: drive [`coordinator::Server::run`] (or `step`) for
//! experiments and anything that wants the paper's Algorithm 1 semantics —
//! it owns the fleet, clock and traffic ledger and already routes every
//! round through the engine. Reach for [`engine::Engine`] directly only
//! when building a new driver (custom selection loops, asynchronous
//! protocols, transport integration) that needs the state machine and
//! sharded aggregation without the Server's bookkeeping.

pub mod caesar;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod engine;
pub mod experiments;
pub mod fleet;
pub mod journal;
pub mod nn;
pub mod runtime;
pub mod schemes;
pub mod transport;
pub mod util;
pub mod wire;

pub mod bench;

pub use util::rng::Rng;

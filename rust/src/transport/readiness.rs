//! Readiness-driven serving: one wait-set over the listener and every
//! live connection.
//!
//! The pre-reactor coordinator drove each connection with its own short
//! sleep-poll (`recv_timeout(2ms)` per pending device, `5ms` accept
//! naps), so idle wall-clock cost scaled with elapsed-time ×
//! connections. The [`Reactor`] inverts that: the serving loop blocks
//! on *all* sources at once and wakes only when bytes or accepts are
//! actually ready, so wakeups scale with frames delivered.
//!
//! Three readiness mechanisms hide behind one [`RawSource`] enum:
//!
//! * **`Fd` (unix)** — real sockets wait in a single `poll(2)` call over
//!   the listener plus every connection fd. The syscall is declared by
//!   hand in [`sys`] (std already links libc on unix) so the crate stays
//!   dependency-free.
//! * **`Key`** — channel-backed sources (the Loopback transport, the
//!   threaded-reader fallback) signal a [`Waker`]: the sender pushes its
//!   key *after* making the data visible, the reactor drains queued keys
//!   or blocks on the condvar. Key `0` ([`ACCEPT_KEY`]) is reserved for
//!   "the accept queue has a pending connection".
//! * **`Unready`** — a source with no integration (e.g. a custom test
//!   `Conn`). The reactor degrades to bounded sweep slices for wait-sets
//!   containing one: every conn is reported sweepable each slice, which
//!   is correct (a non-ready conn's `try_recv` returns `None`
//!   harmlessly) just not cheap.
//!
//! Lost-wakeup safety: key posts are push-data-then-wake, so a key
//! consumed before its conn is registered is harmless as long as the
//! caller drains every *freshly accepted* conn once unconditionally —
//! the data the orphaned key announced is already visible to that
//! drain. Fd sources are level-triggered by `poll(2)` and the serving
//! loop drains until `WouldBlock`, which restores the invariant "no
//! complete frame is buffered when the reactor blocks".

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender, TryRecvError};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::frame::WireMsg;
use super::{Conn, TransportError};

/// Reserved waker key: "the listener's accept queue is non-empty".
pub const ACCEPT_KEY: u64 = 0;

/// Slice length for degraded (swept) wait-sets and for the identify
/// deadline scan — bounded so protocol deadlines still fire without
/// events, generous so degraded mode is not a busy loop.
const SWEEP_SLICE: Duration = Duration::from_millis(10);

/// Slice the threaded reader blocks per `recv_timeout` call, bounding
/// how long shutdown (`stop` flag) can lag.
const READER_SLICE: Duration = Duration::from_millis(20);

/// How a source presents itself to the reactor's wait-set.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RawSource {
    /// An OS file descriptor `poll(2)` can wait on.
    #[cfg(unix)]
    Fd(std::os::unix::io::RawFd),
    /// Signaled through the owning transport's [`Waker`] under this key.
    Key(u64),
    /// No readiness integration: forces the sweep fallback.
    Unready,
}

/// Cross-thread wake channel for non-fd sources: senders post the key
/// of the source that just became ready, the reactor drains keys or
/// blocks on the condvar. Posting while nobody waits is fine — keys
/// queue until the next wait.
pub struct Waker {
    keys: Mutex<VecDeque<u64>>,
    cv: Condvar,
}

impl Waker {
    pub fn new() -> Arc<Waker> {
        Arc::new(Waker { keys: Mutex::new(VecDeque::new()), cv: Condvar::new() })
    }

    /// Post `key` and wake a waiting reactor (callers must make the
    /// ready data visible *before* calling this).
    pub fn wake(&self, key: u64) {
        let mut q = self.keys.lock().expect("waker lock");
        q.push_back(key);
        self.cv.notify_one();
    }

    /// Drain all queued keys, blocking up to `timeout` if none are
    /// queued yet. Empty result ⇔ the deadline passed with no posts.
    fn drain(&self, timeout: Duration) -> Vec<u64> {
        let deadline = Instant::now() + timeout;
        let mut q = self.keys.lock().expect("waker lock");
        loop {
            if !q.is_empty() {
                return q.drain(..).collect();
            }
            let now = Instant::now();
            if now >= deadline {
                return Vec::new();
            }
            let (guard, _) = self
                .cv
                .wait_timeout(q, deadline - now)
                .expect("waker condvar");
            q = guard;
        }
    }
}

/// What one [`Reactor::wait`] observed.
#[derive(Debug, Default)]
pub struct Wake {
    /// The listener has (or may have) pending accepts to drain.
    pub accept: bool,
    /// Tokens whose connections have readable data (may repeat; the
    /// caller's drain-until-`None` makes duplicates harmless).
    pub ready: Vec<u64>,
    /// Degraded wait: *every* registered conn should be swept with a
    /// non-blocking receive (set when the wait-set held sources without
    /// readiness integration).
    pub sweep: bool,
}

/// One serving-side wait-set. Owns the waker non-fd sources signal and
/// the wakeup counter the benches compare against sleep-polling.
pub struct Reactor {
    waker: Arc<Waker>,
    wakeups: u64,
}

impl Reactor {
    /// `waker`: the transport's own wake channel if it has one (the
    /// Loopback hub), otherwise the reactor mints a private one for
    /// threaded-reader fallbacks.
    pub fn new(waker: Option<Arc<Waker>>) -> Reactor {
        Reactor { waker: waker.unwrap_or_else(Waker::new), wakeups: 0 }
    }

    /// The wake channel non-fd sources should signal.
    pub fn waker(&self) -> &Arc<Waker> {
        &self.waker
    }

    /// Times `wait` has returned — the "how often did the serving loop
    /// run" number. With precise readiness this scales with frames
    /// delivered + deadline expiries, not elapsed-time × connections.
    pub fn wakeups(&self) -> u64 {
        self.wakeups
    }

    /// Block until the listener or some conn is ready, or `timeout`
    /// elapses. `conns` pairs an opaque caller token with each conn's
    /// source; returned [`Wake::ready`] speaks in those tokens.
    pub fn wait(
        &mut self,
        listener: RawSource,
        conns: &[(u64, RawSource)],
        timeout: Duration,
    ) -> Result<Wake, TransportError> {
        self.wakeups += 1;
        let any_unready = matches!(listener, RawSource::Unready)
            || conns.iter().any(|(_, s)| matches!(s, RawSource::Unready));
        #[cfg(unix)]
        {
            let all_fd = matches!(listener, RawSource::Fd(_))
                && conns.iter().all(|(_, s)| matches!(s, RawSource::Fd(_)));
            if all_fd {
                return self.wait_fds(listener, conns, timeout);
            }
        }
        if any_unready {
            // Degraded: bounded slice on the waker condvar (no
            // thread::sleep — a key post still cuts the nap short),
            // then report everything sweepable.
            let _ = self.waker.drain(timeout.min(SWEEP_SLICE));
            return Ok(Wake { accept: true, ready: Vec::new(), sweep: true });
        }
        self.wait_keys(listener, conns, timeout)
    }

    /// Precise waker path: every source is `Key`-backed.
    fn wait_keys(
        &mut self,
        listener: RawSource,
        conns: &[(u64, RawSource)],
        timeout: Duration,
    ) -> Result<Wake, TransportError> {
        let mut wake = Wake::default();
        let keys = self.waker.drain(timeout);
        for key in keys {
            if key == ACCEPT_KEY || listener == RawSource::Key(key) {
                wake.accept = true;
                continue;
            }
            if let Some(&(token, _)) =
                conns.iter().find(|(_, s)| *s == RawSource::Key(key))
            {
                wake.ready.push(token);
            }
            // Unknown keys (a conn dropped since posting, or posted
            // before registration) are safely discarded: push-then-wake
            // ordering means the announced data is already visible to
            // the caller's fresh-conn drain.
        }
        Ok(wake)
    }

    /// Precise fd path: one `poll(2)` over listener + conns.
    #[cfg(unix)]
    fn wait_fds(
        &mut self,
        listener: RawSource,
        conns: &[(u64, RawSource)],
        timeout: Duration,
    ) -> Result<Wake, TransportError> {
        let RawSource::Fd(lfd) = listener else { unreachable!("checked by caller") };
        let mut fds = Vec::with_capacity(conns.len() + 1);
        fds.push(sys::PollFd { fd: lfd, events: sys::POLLIN, revents: 0 });
        for (_, s) in conns {
            let RawSource::Fd(fd) = *s else { unreachable!("checked by caller") };
            fds.push(sys::PollFd { fd, events: sys::POLLIN, revents: 0 });
        }
        let n = sys::poll_fds(&mut fds, timeout).map_err(TransportError::Io)?;
        let mut wake = Wake::default();
        if n == 0 {
            return Ok(wake);
        }
        wake.accept = fds[0].readable();
        for (i, pfd) in fds.iter().enumerate().skip(1) {
            if pfd.readable() {
                wake.ready.push(conns[i - 1].0);
            }
        }
        Ok(wake)
    }
}

/// Minimal vendored FFI shim over `poll(2)` — the one libc symbol the
/// readiness path needs, declared by hand so the crate keeps zero
/// external dependencies (std itself links libc on unix).
#[cfg(unix)]
pub(crate) mod sys {
    use std::os::unix::io::RawFd;
    use std::time::{Duration, Instant};

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;
    pub const POLLNVAL: i16 = 0x020;

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: RawFd,
        pub events: i16,
        pub revents: i16,
    }

    impl PollFd {
        /// Error/hangup conditions count as readable: the next read
        /// surfaces the actual close/error instead of us guessing here.
        pub fn readable(&self) -> bool {
            self.revents & (POLLIN | POLLERR | POLLHUP | POLLNVAL) != 0
        }
    }

    // `nfds_t` is `unsigned long` on linux, `u32` on macOS.
    #[cfg(target_os = "macos")]
    type NfdsT = u32;
    #[cfg(not(target_os = "macos"))]
    type NfdsT = std::os::raw::c_ulong;

    extern "C" {
        fn poll(
            fds: *mut PollFd,
            nfds: NfdsT,
            timeout: std::os::raw::c_int,
        ) -> std::os::raw::c_int;
    }

    /// `poll(2)` over `fds` with an EINTR-retrying deadline. Returns
    /// the number of entries with events set (0 ⇔ timeout).
    pub fn poll_fds(fds: &mut [PollFd], timeout: Duration) -> std::io::Result<usize> {
        let deadline = Instant::now() + timeout;
        loop {
            let left = deadline.saturating_duration_since(Instant::now());
            let mut ms = left.as_millis() as i64;
            // round a sub-ms remainder up so a short deadline still
            // blocks instead of degenerating into a spin of 0ms polls
            if ms == 0 && !left.is_zero() {
                ms = 1;
            }
            let ms = ms.min(i32::MAX as i64) as i32;
            let rc = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, ms) };
            if rc >= 0 {
                return Ok(rc as usize);
            }
            let err = std::io::Error::last_os_error();
            if err.kind() != std::io::ErrorKind::Interrupted {
                return Err(err);
            }
            if Instant::now() >= deadline {
                return Ok(0);
            }
        }
    }

    /// Block until `fd` is readable or `timeout` — the listener's
    /// accept wait and nothing else sleeps for it.
    pub fn wait_readable(fd: RawFd, timeout: Duration) -> std::io::Result<bool> {
        let mut fds = [PollFd { fd, events: POLLIN, revents: 0 }];
        Ok(poll_fds(&mut fds, timeout)? > 0)
    }

    /// Block until `fd` is writable or `timeout` — write-readiness for
    /// the nonblocking send path (replaces any fixed retry nap).
    pub fn wait_writable(fd: RawFd, timeout: Duration) -> std::io::Result<bool> {
        let mut fds = [PollFd { fd, events: POLLOUT, revents: 0 }];
        Ok(poll_fds(&mut fds, timeout)? > 0)
    }

    #[cfg(any(target_os = "linux", target_os = "android"))]
    const RLIMIT_NOFILE: std::os::raw::c_int = 7;
    #[cfg(not(any(target_os = "linux", target_os = "android")))]
    const RLIMIT_NOFILE: std::os::raw::c_int = 8;

    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }

    extern "C" {
        fn getrlimit(resource: std::os::raw::c_int, rlim: *mut RLimit) -> std::os::raw::c_int;
        fn setrlimit(resource: std::os::raw::c_int, rlim: *const RLimit) -> std::os::raw::c_int;
    }

    /// Best-effort raise of the soft fd limit toward the hard limit
    /// (capped at 65536 — some platforms refuse RLIM_INFINITY softs).
    /// Errors are swallowed: callers treat this as an optimization.
    pub fn raise_nofile_limit() {
        unsafe {
            let mut lim = RLimit { cur: 0, max: 0 };
            if getrlimit(RLIMIT_NOFILE, &mut lim) != 0 {
                return;
            }
            let want = lim.max.min(65_536);
            if lim.cur < want {
                let req = RLimit { cur: want, max: lim.max };
                let _ = setrlimit(RLIMIT_NOFILE, &req);
            }
        }
    }
}

/// Best-effort raise of the process fd limit (no-op off unix) — the
/// fan-out benches open thousands of sockets from one process, which
/// overruns common default soft limits.
pub fn raise_fd_limit() {
    #[cfg(unix)]
    sys::raise_nofile_limit();
}

/// Portable threaded-reader fallback: adapts any [`Conn`] without
/// readiness integration into a `Key` source. A dedicated thread owns
/// the receive side (sliced `recv_timeout`s), forwards each decoded
/// frame over a channel, and posts the key — so the reactor still
/// blocks on one wait-set and the serving loop stays sleep-free even
/// when the underlying conn can only sleep-poll. This is what keeps
/// the crate buildable (and the coordinator correct) on targets
/// without `poll(2)`.
pub struct ThreadedReader<C: Conn> {
    conn: Arc<Mutex<C>>,
    rx: Receiver<Result<WireMsg, TransportError>>,
    key: u64,
    /// Set after the reader forwarded a terminal error; later receives
    /// report `Closed` instead of blocking forever on a dead channel.
    dead: bool,
    stop: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
    peer: String,
}

impl<C: Conn> ThreadedReader<C> {
    pub fn new(conn: C, key: u64, waker: Arc<Waker>) -> ThreadedReader<C> {
        let peer = conn.peer();
        let conn = Arc::new(Mutex::new(conn));
        let stop = Arc::new(AtomicBool::new(false));
        let (tx, rx) = mpsc::channel();
        let handle = {
            let conn = Arc::clone(&conn);
            let stop = Arc::clone(&stop);
            std::thread::spawn(move || reader_loop(conn, tx, key, waker, stop))
        };
        ThreadedReader { conn, rx, key, dead: false, stop, handle: Some(handle), peer }
    }
}

fn reader_loop<C: Conn>(
    conn: Arc<Mutex<C>>,
    tx: Sender<Result<WireMsg, TransportError>>,
    key: u64,
    waker: Arc<Waker>,
    stop: Arc<AtomicBool>,
) {
    while !stop.load(Ordering::Relaxed) {
        let r = {
            let mut guard = conn.lock().expect("reader conn lock");
            guard.recv_timeout(READER_SLICE)
        };
        match r {
            Ok(None) => continue,
            Ok(Some(msg)) => {
                // push-then-wake: the frame is in the channel before
                // the key is posted (lost-wakeup safety)
                if tx.send(Ok(msg)).is_err() {
                    return; // owner dropped
                }
                waker.wake(key);
            }
            Err(e) => {
                let _ = tx.send(Err(e));
                waker.wake(key);
                return; // terminal: owner sees the error, drops us
            }
        }
    }
}

impl<C: Conn> Conn for ThreadedReader<C> {
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError> {
        // may wait out one reader slice for the lock — bounded by
        // READER_SLICE, not a protocol timeout
        self.conn.lock().expect("reader conn lock").send(msg)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        if self.dead {
            return Err(TransportError::Closed);
        }
        match self.rx.recv_timeout(timeout) {
            Ok(Ok(msg)) => Ok(Some(msg)),
            Ok(Err(e)) => {
                self.dead = true;
                Err(e)
            }
            Err(mpsc::RecvTimeoutError::Timeout) => Ok(None),
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                self.dead = true;
                Err(TransportError::Closed)
            }
        }
    }

    fn try_recv(&mut self) -> Result<Option<WireMsg>, TransportError> {
        if self.dead {
            return Err(TransportError::Closed);
        }
        match self.rx.try_recv() {
            Ok(Ok(msg)) => Ok(Some(msg)),
            Ok(Err(e)) => {
                self.dead = true;
                Err(e)
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                self.dead = true;
                Err(TransportError::Closed)
            }
        }
    }

    fn source(&self) -> RawSource {
        RawSource::Key(self.key)
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

impl<C: Conn> Drop for ThreadedReader<C> {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join(); // exits within one READER_SLICE
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::loopback::LoopbackHub;
    use crate::transport::Transport;

    #[test]
    fn waker_queues_keys_posted_before_the_wait() {
        let w = Waker::new();
        w.wake(3);
        w.wake(7);
        let keys = w.drain(Duration::from_millis(1));
        assert_eq!(keys, vec![3, 7]);
        // drained: next wait times out empty
        assert!(w.drain(Duration::from_millis(1)).is_empty());
    }

    #[test]
    fn waker_wakes_a_blocked_drain_from_another_thread() {
        let w = Waker::new();
        let w2 = Arc::clone(&w);
        let h = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            w2.wake(9);
        });
        let start = Instant::now();
        let keys = w.drain(Duration::from_secs(5));
        assert_eq!(keys, vec![9]);
        assert!(start.elapsed() < Duration::from_secs(4), "woke, not timed out");
        h.join().unwrap();
    }

    #[test]
    fn key_reactor_maps_keys_to_tokens_and_accept() {
        let hub = LoopbackHub::new();
        let mut r = Reactor::new(hub.waker());
        let conns = [(10u64, RawSource::Key(1)), (11u64, RawSource::Key(2))];
        r.waker().wake(ACCEPT_KEY);
        r.waker().wake(2);
        r.waker().wake(42); // unknown: discarded
        let wake = r
            .wait(RawSource::Key(ACCEPT_KEY), &conns, Duration::from_millis(50))
            .unwrap();
        assert!(wake.accept);
        assert_eq!(wake.ready, vec![11]);
        assert!(!wake.sweep);
        assert_eq!(r.wakeups(), 1);
    }

    #[test]
    fn unready_sources_degrade_to_sweep() {
        let mut r = Reactor::new(None);
        let conns = [(0u64, RawSource::Unready)];
        let wake = r
            .wait(RawSource::Key(ACCEPT_KEY), &conns, Duration::from_millis(5))
            .unwrap();
        assert!(wake.sweep, "unready sources must force a sweep");
        assert!(wake.accept);
    }

    #[cfg(unix)]
    #[test]
    fn fd_reactor_wakes_on_listener_and_conn_bytes() {
        use crate::transport::tcp::{TcpConn, TcpTransport};

        let mut lst = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = lst.socket_addr();
        let mut r = Reactor::new(None);
        let listener_src = lst.listener_source();

        // nothing pending: pure timeout, nothing ready
        let wake = r.wait(listener_src, &[], Duration::from_millis(5)).unwrap();
        assert!(!wake.accept && wake.ready.is_empty() && !wake.sweep);

        // a dial makes the listener readable
        let mut client = TcpConn::connect(addr).unwrap();
        let wake = r.wait(listener_src, &[], Duration::from_secs(5)).unwrap();
        assert!(wake.accept, "pending accept must wake the reactor");
        let mut sconn = lst.accept_timeout(Duration::from_secs(5)).unwrap().unwrap();

        // bytes on the conn wake its token
        let sources = [(77u64, sconn.source())];
        client.send(&WireMsg::Join { device: 1 }).unwrap();
        let wake = r.wait(listener_src, &sources, Duration::from_secs(5)).unwrap();
        assert!(wake.ready.contains(&77), "conn bytes must wake its token");
        match sconn.try_recv().unwrap() {
            Some(WireMsg::Join { device: 1 }) => {}
            other => panic!("{other:?}"),
        }
        // drained: the wait-set goes quiet again
        let wake = r.wait(listener_src, &sources, Duration::from_millis(5)).unwrap();
        assert!(wake.ready.is_empty());
    }

    #[test]
    fn threaded_reader_forwards_frames_and_wakes_its_key() {
        let hub = LoopbackHub::new();
        let dialer = hub.dialer();
        let mut hub = hub;
        let mut client = dialer.connect().unwrap();
        let server = hub.accept_timeout(Duration::from_millis(200)).unwrap().unwrap();

        let mut r = Reactor::new(None);
        let mut reader = ThreadedReader::new(server, 5, Arc::clone(r.waker()));
        assert_eq!(reader.source(), RawSource::Key(5));

        client.send(&WireMsg::Heartbeat { device: 2, sim_t_s: 1.5 }).unwrap();
        let sources = [(30u64, reader.source())];
        let wake = r
            .wait(RawSource::Key(ACCEPT_KEY), &sources, Duration::from_secs(5))
            .unwrap();
        assert!(wake.ready.contains(&30));
        match reader.try_recv().unwrap() {
            Some(WireMsg::Heartbeat { device: 2, .. }) => {}
            other => panic!("{other:?}"),
        }
        // peer death surfaces as an error on the next receive
        drop(client);
        let mut saw_err = false;
        for _ in 0..100 {
            match reader.recv_timeout(Duration::from_millis(20)) {
                Ok(Some(_)) => {}
                Ok(None) => continue,
                Err(_) => {
                    saw_err = true;
                    break;
                }
            }
        }
        assert!(saw_err, "reader must forward the peer's death");
    }
}

//! Device fleet: many [`DeviceClient`] sessions multiplexed over ONE
//! connection.
//!
//! A process simulating hundreds of devices does not need hundreds of
//! sockets: every frame in the protocol names its device (see
//! [`WireMsg::device`]), so a single framed connection can carry any
//! number of sessions, and the coordinator's demux routes by the frame,
//! not the socket. [`DeviceFleet`] is the client half of that contract:
//! it Joins every device it holds over the shared connection (ascending,
//! so rendezvous counts are deterministic), then runs a scheduler loop
//! that **interleaves kickoff handling** — incoming frames drain into a
//! queue between every kickoff execution, so a device deep in τ local
//! steps never blocks its fleet-mates' JoinAcks, rejects or newly
//! arrived kickoffs from being picked up (their heartbeat/EndRound
//! frames still serialize on the shared socket, which is the point:
//! byte order on one connection is deterministic given the kickoff
//! execution order, and the coordinator's canonical fold makes even
//! *that* order bit-irrelevant).
//!
//! Fate sharing: one connection is one failure domain. If the socket
//! dies, every session on it disconnects together — and on the
//! coordinator side, every device bound to it is severed together
//! (`Registry::unbind_conn`). [`DeviceFleet::run_reconnecting`] redials
//! the whole fleet as a unit; each device's redelivery cache answers the
//! duplicate kickoffs that follow the rejoin.

use std::collections::{BTreeMap, VecDeque};
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Result};

use crate::config::ExperimentConfig;
use crate::coordinator::NetworkedStart;

use super::client::{redial_backoff_ms, ClientStats, DeviceClient, SessionEnd, Step};
use super::frame::WireMsg;
use super::{Conn, TransportError};

/// Receive slice while the scheduler has no queued kickoff to execute.
const RECV_SLICE: Duration = Duration::from_millis(100);

/// Many device sessions over one connection.
pub struct DeviceFleet {
    clients: BTreeMap<usize, DeviceClient>,
    /// Experiment seed, for deterministic redial jitter.
    seed: u64,
    /// Silence budget before a session reports
    /// [`SessionEnd::Disconnected`] (the whole fleet disconnects as a
    /// unit — one socket is one failure domain).
    pub idle_timeout: Duration,
}

impl DeviceFleet {
    /// Build one [`DeviceClient`] per id in `devices`. Each client
    /// rebuilds the data world locally from `cfg.seed`, exactly as a
    /// standalone client would — multiplexing changes the socket count,
    /// never the math.
    pub fn new(cfg: ExperimentConfig, devices: impl IntoIterator<Item = usize>) -> Result<DeviceFleet> {
        let seed = cfg.seed;
        let mut clients = BTreeMap::new();
        for d in devices {
            ensure!(
                clients.insert(d, DeviceClient::new(cfg.clone(), d)?).is_none(),
                "device {d} listed twice in the fleet"
            );
        }
        ensure!(!clients.is_empty(), "a device fleet needs at least one device");
        Ok(DeviceFleet { clients, seed, idle_timeout: Duration::from_secs(600) })
    }

    /// The device ids this fleet holds, ascending.
    pub fn devices(&self) -> Vec<usize> {
        self.clients.keys().copied().collect()
    }

    /// One member session, if `device` is in the fleet.
    pub fn client(&self, device: usize) -> Option<&DeviceClient> {
        self.clients.get(&device)
    }

    /// Summed session counters across the fleet.
    pub fn stats(&self) -> ClientStats {
        let mut sum = ClientStats::default();
        for c in self.clients.values() {
            sum.rounds += c.stats.rounds;
            sum.dropouts += c.stats.dropouts;
            sum.heartbeats += c.stats.heartbeats;
            sum.redeliveries += c.stats.redeliveries;
            sum.stale_rejects += c.stats.stale_rejects;
        }
        sum
    }

    /// Run one session over `conn`: Join every device, then serve
    /// kickoffs until the coordinator finishes or the connection dies.
    /// Same error contract as [`DeviceClient::run`]: transport failures
    /// are `Ok(Disconnected)` (retryable), protocol violations are
    /// `Err` (fatal).
    pub fn run<C: Conn>(&mut self, conn: &mut C) -> Result<SessionEnd> {
        // Join storm, ascending: the coordinator binds each id to this
        // connection as the frames arrive
        for d in self.clients.keys() {
            if conn.send(&WireMsg::Join { device: *d }).is_err() {
                return Ok(SessionEnd::Disconnected);
            }
        }
        let mut kickoffs: VecDeque<(usize, Box<NetworkedStart>)> = VecDeque::new();
        let mut last_activity = Instant::now();
        loop {
            // drain everything the connection has buffered before (and
            // between) kickoff executions — cheap frames are handled
            // inline, kickoffs queue up behind the one being trained
            loop {
                match conn.try_recv() {
                    Ok(Some(msg)) => {
                        last_activity = Instant::now();
                        match self.dispatch(conn, msg, &mut kickoffs)? {
                            Step::Continue => {}
                            Step::Finished => return Ok(SessionEnd::Finished),
                            Step::Disconnected => return Ok(SessionEnd::Disconnected),
                        }
                    }
                    Ok(None) => break,
                    Err(TransportError::Closed) | Err(TransportError::Io(_)) => {
                        return Ok(SessionEnd::Disconnected)
                    }
                    Err(e) => return Err(anyhow!("fleet: {e}")),
                }
            }
            if let Some((d, start)) = kickoffs.pop_front() {
                let client = self.clients.get_mut(&d).expect("queued kickoffs name members");
                match client.serve_kickoff(conn, start)? {
                    Step::Continue => {}
                    Step::Finished => return Ok(SessionEnd::Finished),
                    Step::Disconnected => return Ok(SessionEnd::Disconnected),
                }
                last_activity = Instant::now();
                continue; // re-drain before executing the next kickoff
            }
            // nothing queued and nothing buffered: block for a slice
            match conn.recv_timeout(RECV_SLICE) {
                Ok(Some(msg)) => {
                    last_activity = Instant::now();
                    match self.dispatch(conn, msg, &mut kickoffs)? {
                        Step::Continue => {}
                        Step::Finished => return Ok(SessionEnd::Finished),
                        Step::Disconnected => return Ok(SessionEnd::Disconnected),
                    }
                }
                Ok(None) => {
                    if last_activity.elapsed() >= self.idle_timeout {
                        return Ok(SessionEnd::Disconnected);
                    }
                }
                Err(TransportError::Closed) | Err(TransportError::Io(_)) => {
                    return Ok(SessionEnd::Disconnected)
                }
                Err(e) => return Err(anyhow!("fleet: {e}")),
            }
        }
    }

    /// Route one coordinator frame to the session it names. Kickoffs
    /// queue (executed by the scheduler loop, interleaved with drains);
    /// everything else is handled inline by the member's own protocol
    /// handler.
    fn dispatch<C: Conn>(
        &mut self,
        conn: &mut C,
        msg: WireMsg,
        kickoffs: &mut VecDeque<(usize, Box<NetworkedStart>)>,
    ) -> Result<Step> {
        if matches!(msg, WireMsg::Finish) {
            // Finish is fleet-wide: one frame ends every session on the
            // connection
            return Ok(Step::Finished);
        }
        let d = msg
            .device()
            .ok_or_else(|| anyhow!("fleet: coordinator frame names no device: {msg:?}"))?;
        if !self.clients.contains_key(&d) {
            return Err(anyhow!(
                "fleet: coordinator sent a frame for device {d}, which this fleet does \
                 not hold (members: {:?})",
                self.devices()
            ));
        }
        if let WireMsg::StartRound(start) = msg {
            kickoffs.push_back((d, start));
            return Ok(Step::Continue);
        }
        self.clients.get_mut(&d).expect("membership checked above").on_msg(conn, msg)
    }

    /// [`run`](DeviceFleet::run) with reconnect-with-rejoin, the fleet
    /// analogue of [`DeviceClient::run_reconnecting`]: when a session
    /// disconnects, dial a fresh connection and re-Join every member
    /// (the coordinator re-binds them all and re-sends pending
    /// kickoffs; redelivery caches answer the duplicates). Gives up
    /// after `max_redials` **consecutive** fruitless attempts; any
    /// member's protocol progress resets the budget. Backoff jitter is
    /// keyed on the fleet's lowest device id, so co-located fleets
    /// dropped by one fault do not redial in lockstep.
    pub fn run_reconnecting<C: Conn>(
        &mut self,
        mut dial: impl FnMut() -> Result<C, TransportError>,
        max_redials: usize,
    ) -> Result<SessionEnd> {
        let lead = *self.clients.keys().next().expect("fleets are non-empty");
        let mut redials = 0;
        loop {
            let before = self.stats();
            if let Ok(mut conn) = dial() {
                if self.run(&mut conn)? == SessionEnd::Finished {
                    return Ok(SessionEnd::Finished);
                }
            }
            let after = self.stats();
            let progressed = after.rounds > before.rounds
                || after.dropouts > before.dropouts
                || after.redeliveries > before.redeliveries;
            redials = if progressed { 0 } else { redials + 1 };
            if redials > max_redials {
                return Ok(SessionEnd::Disconnected);
            }
            std::thread::sleep(Duration::from_millis(redial_backoff_ms(
                self.seed,
                lead,
                redials.max(1),
            )));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompressionBackend, TrainerBackend};
    use crate::fleet::FleetKind;

    fn tiny_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset("har");
        cfg.trainer = TrainerBackend::Native;
        cfg.compression = CompressionBackend::Native;
        cfg.fleet = FleetKind::JetsonScaled(4);
        cfg.n_train = 240;
        cfg.n_test = 80;
        cfg
    }

    #[test]
    fn fleet_membership_is_validated_and_ascending() {
        let fleet = DeviceFleet::new(tiny_cfg(), [2, 0, 3]).unwrap();
        assert_eq!(fleet.devices(), vec![0, 2, 3]);
        assert!(fleet.client(2).is_some());
        assert!(fleet.client(1).is_none());

        assert!(DeviceFleet::new(tiny_cfg(), []).is_err(), "empty fleets are refused");
        assert!(DeviceFleet::new(tiny_cfg(), [1, 1]).is_err(), "duplicate ids are refused");
        assert!(DeviceFleet::new(tiny_cfg(), [99]).is_err(), "out-of-range ids are refused");
    }

    #[test]
    fn stats_sum_across_members() {
        let mut fleet = DeviceFleet::new(tiny_cfg(), [0, 1]).unwrap();
        fleet.clients.get_mut(&0).unwrap().stats.rounds = 3;
        fleet.clients.get_mut(&1).unwrap().stats.rounds = 2;
        fleet.clients.get_mut(&1).unwrap().stats.stale_rejects = 1;
        let s = fleet.stats();
        assert_eq!((s.rounds, s.stale_rejects), (5, 1));
    }
}

//! In-process transport: mpsc channels carrying **encoded frames**.
//!
//! The default transport, and the parity baseline. Each direction of a
//! connection is a channel of `Vec<u8>` frame buffers: `send` runs the
//! real [`frame::encode_frame`] and `recv_timeout` the real
//! [`frame::decode_frame`], so every byte-level invariant of the codec
//! is exercised on every message — the only thing Loopback skips is the
//! socket. A Tcp run that diverges from a Loopback run therefore
//! isolates the fault to stream handling, not message encoding.
//!
//! Readiness integration is waker-keyed (see [`super::readiness`]):
//! the hub owns a [`Waker`]; dialing posts [`ACCEPT_KEY`] after queuing
//! the server half, and every client→server send posts the server
//! half's key after queuing the frame (push-then-wake). The serving
//! reactor therefore blocks on the hub's waker exactly like it blocks
//! on `poll(2)` for sockets — the Loopback path exercises the same
//! zero-sleep serving loop the Tcp path does.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::Duration;

use super::frame::{self, WireMsg};
use super::readiness::{RawSource, Waker, ACCEPT_KEY};
use super::{Conn, Transport, TransportError};

/// Coordinator-side listener: a queue of freshly dialed connections.
pub struct LoopbackHub {
    accept_rx: Receiver<LoopbackConn>,
    /// Kept so [`LoopbackHub::dialer`] can mint connectors after
    /// construction; also keeps the accept channel open for the hub's
    /// lifetime (accept reports timeout, not closure, while devices may
    /// still dial).
    accept_tx: Sender<LoopbackConn>,
    /// The wake channel the serving reactor blocks on; dialers and
    /// client halves signal it.
    waker: Arc<Waker>,
    /// Key mint for server halves (key 0 is [`ACCEPT_KEY`]).
    next_key: Arc<AtomicU64>,
}

impl LoopbackHub {
    pub fn new() -> LoopbackHub {
        let (accept_tx, accept_rx) = mpsc::channel();
        LoopbackHub {
            accept_rx,
            accept_tx,
            waker: Waker::new(),
            next_key: Arc::new(AtomicU64::new(1)),
        }
    }

    /// A cloneable, `Send` handle devices use to dial this hub.
    pub fn dialer(&self) -> LoopbackDialer {
        LoopbackDialer {
            accept_tx: self.accept_tx.clone(),
            waker: Arc::clone(&self.waker),
            next_key: Arc::clone(&self.next_key),
        }
    }
}

impl Default for LoopbackHub {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for LoopbackHub {
    type Conn = LoopbackConn;

    fn accept_timeout(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<LoopbackConn>, TransportError> {
        match self.accept_rx.recv_timeout(timeout) {
            Ok(conn) => Ok(Some(conn)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            // unreachable while we hold accept_tx, but total anyway
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed),
        }
    }

    fn listener_source(&self) -> RawSource {
        RawSource::Key(ACCEPT_KEY)
    }

    fn waker(&self) -> Option<Arc<Waker>> {
        Some(Arc::clone(&self.waker))
    }

    fn local_addr(&self) -> String {
        "loopback".into()
    }
}

/// Device-side connector to a [`LoopbackHub`].
#[derive(Clone)]
pub struct LoopbackDialer {
    accept_tx: Sender<LoopbackConn>,
    waker: Arc<Waker>,
    next_key: Arc<AtomicU64>,
}

impl LoopbackDialer {
    /// Open a connection pair and hand the server half to the hub's
    /// accept queue (then wake the reactor's accept token).
    pub fn connect(&self) -> Result<LoopbackConn, TransportError> {
        let (c2s_tx, c2s_rx) = mpsc::channel::<Vec<u8>>();
        let (s2c_tx, s2c_rx) = mpsc::channel::<Vec<u8>>();
        let key = self.next_key.fetch_add(1, Ordering::Relaxed);
        let server_half = LoopbackConn {
            tx: s2c_tx,
            rx: c2s_rx,
            key,
            notify: None,
            peer: "loopback-device".into(),
        };
        let client_half = LoopbackConn {
            tx: c2s_tx,
            rx: s2c_rx,
            key: 0,
            notify: Some((Arc::clone(&self.waker), key)),
            peer: "loopback-coordinator".into(),
        };
        self.accept_tx.send(server_half).map_err(|_| TransportError::Closed)?;
        self.waker.wake(ACCEPT_KEY);
        Ok(client_half)
    }
}

/// One half of an in-process connection.
pub struct LoopbackConn {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    /// Reactor key of this half when it is the *server* half; `0` on
    /// the client half (which is never in a serving wait-set).
    key: u64,
    /// Client half only: wake `(waker, server_key)` after each send so
    /// the serving reactor sees the frame without polling.
    notify: Option<(Arc<Waker>, u64)>,
    peer: String,
}

impl LoopbackConn {
    fn decode(buf: Vec<u8>) -> Result<Option<WireMsg>, TransportError> {
        let (msg, used) = frame::decode_frame(&buf)?;
        if used != buf.len() {
            return Err(TransportError::Frame(frame::FrameError::TrailingBytes {
                extra: buf.len() - used,
            }));
        }
        Ok(Some(msg))
    }
}

impl Conn for LoopbackConn {
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError> {
        self.tx.send(frame::encode_frame(msg)).map_err(|_| TransportError::Closed)?;
        // push-then-wake: the frame is visible before the key posts
        if let Some((waker, key)) = &self.notify {
            waker.wake(*key);
        }
        Ok(())
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        match self.rx.recv_timeout(timeout) {
            Ok(buf) => Self::decode(buf),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed),
        }
    }

    fn try_recv(&mut self) -> Result<Option<WireMsg>, TransportError> {
        match self.rx.try_recv() {
            Ok(buf) => Self::decode(buf),
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => Err(TransportError::Closed),
        }
    }

    fn source(&self) -> RawSource {
        if self.key == 0 {
            RawSource::Unready // client half: never in a serving wait-set
        } else {
            RawSource::Key(self.key)
        }
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dial_accept_and_exchange_frames() {
        let mut hub = LoopbackHub::new();
        let dialer = hub.dialer();
        let mut client = dialer.connect().unwrap();
        let mut server = hub
            .accept_timeout(Duration::from_millis(100))
            .unwrap()
            .expect("dialed connection must be acceptable");

        client.send(&WireMsg::Join { device: 7 }).unwrap();
        match server.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(WireMsg::Join { device: 7 }) => {}
            other => panic!("{other:?}"),
        }
        server.send(&WireMsg::JoinAck { device: 7, n_devices: 8 }).unwrap();
        match client.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(WireMsg::JoinAck { device: 7, n_devices: 8 }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn timeout_and_hangup_are_distinguished() {
        let mut hub = LoopbackHub::new();
        assert!(hub.accept_timeout(Duration::from_millis(5)).unwrap().is_none());

        let dialer = hub.dialer();
        let client = dialer.connect().unwrap();
        let mut server = hub.accept_timeout(Duration::from_millis(100)).unwrap().unwrap();
        // no traffic yet: timeout, not error
        assert!(server.recv_timeout(Duration::from_millis(5)).unwrap().is_none());
        // peer drops: Closed
        drop(client);
        match server.recv_timeout(Duration::from_millis(5)) {
            Err(TransportError::Closed) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn dials_and_sends_post_wake_keys() {
        let mut hub = LoopbackHub::new();
        let waker = Transport::waker(&hub).expect("loopback is waker-backed");
        let dialer = hub.dialer();
        let mut client = client_of(&dialer);
        let server = hub.accept_timeout(Duration::from_millis(100)).unwrap().unwrap();
        let server_key = match server.source() {
            RawSource::Key(k) => k,
            other => panic!("server half must be a key source, got {other:?}"),
        };
        assert_ne!(server_key, ACCEPT_KEY);
        assert_eq!(client.source(), RawSource::Unready, "client half stays out of wait-sets");

        // the dial posted ACCEPT_KEY; a send posts the server key
        client.send(&WireMsg::Join { device: 1 }).unwrap();
        let mut reactor = super::super::readiness::Reactor::new(Some(waker));
        let sources = [(99u64, server.source())];
        let wake = reactor
            .wait(RawSource::Key(ACCEPT_KEY), &sources, Duration::from_millis(200))
            .unwrap();
        assert!(wake.accept, "dial must post the accept key");
        assert!(wake.ready.contains(&99), "send must post the conn key");
    }

    fn client_of(dialer: &LoopbackDialer) -> LoopbackConn {
        dialer.connect().unwrap()
    }
}

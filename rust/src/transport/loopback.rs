//! In-process transport: mpsc channels carrying **encoded frames**.
//!
//! The default transport, and the parity baseline. Each direction of a
//! connection is a channel of `Vec<u8>` frame buffers: `send` runs the
//! real [`frame::encode_frame`] and `recv_timeout` the real
//! [`frame::decode_frame`], so every byte-level invariant of the codec
//! is exercised on every message — the only thing Loopback skips is the
//! socket. A Tcp run that diverges from a Loopback run therefore
//! isolates the fault to stream handling, not message encoding.

use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

use super::frame::{self, WireMsg};
use super::{Conn, Transport, TransportError};

/// Coordinator-side listener: a queue of freshly dialed connections.
pub struct LoopbackHub {
    accept_rx: Receiver<LoopbackConn>,
    /// Kept so [`LoopbackHub::dialer`] can mint connectors after
    /// construction; also keeps the accept channel open for the hub's
    /// lifetime (accept reports timeout, not closure, while devices may
    /// still dial).
    accept_tx: Sender<LoopbackConn>,
}

impl LoopbackHub {
    pub fn new() -> LoopbackHub {
        let (accept_tx, accept_rx) = mpsc::channel();
        LoopbackHub { accept_rx, accept_tx }
    }

    /// A cloneable, `Send` handle devices use to dial this hub.
    pub fn dialer(&self) -> LoopbackDialer {
        LoopbackDialer { accept_tx: self.accept_tx.clone() }
    }
}

impl Default for LoopbackHub {
    fn default() -> Self {
        Self::new()
    }
}

impl Transport for LoopbackHub {
    type Conn = LoopbackConn;

    fn accept_timeout(
        &mut self,
        timeout: Duration,
    ) -> Result<Option<LoopbackConn>, TransportError> {
        match self.accept_rx.recv_timeout(timeout) {
            Ok(conn) => Ok(Some(conn)),
            Err(RecvTimeoutError::Timeout) => Ok(None),
            // unreachable while we hold accept_tx, but total anyway
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed),
        }
    }

    fn local_addr(&self) -> String {
        "loopback".into()
    }
}

/// Device-side connector to a [`LoopbackHub`].
#[derive(Clone)]
pub struct LoopbackDialer {
    accept_tx: Sender<LoopbackConn>,
}

impl LoopbackDialer {
    /// Open a connection pair and hand the server half to the hub's
    /// accept queue.
    pub fn connect(&self) -> Result<LoopbackConn, TransportError> {
        let (c2s_tx, c2s_rx) = mpsc::channel::<Vec<u8>>();
        let (s2c_tx, s2c_rx) = mpsc::channel::<Vec<u8>>();
        let server_half =
            LoopbackConn { tx: s2c_tx, rx: c2s_rx, peer: "loopback-device".into() };
        let client_half =
            LoopbackConn { tx: c2s_tx, rx: s2c_rx, peer: "loopback-coordinator".into() };
        self.accept_tx.send(server_half).map_err(|_| TransportError::Closed)?;
        Ok(client_half)
    }
}

/// One half of an in-process connection.
pub struct LoopbackConn {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
    peer: String,
}

impl Conn for LoopbackConn {
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError> {
        self.tx.send(frame::encode_frame(msg)).map_err(|_| TransportError::Closed)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        match self.rx.recv_timeout(timeout) {
            Ok(buf) => {
                let (msg, used) = frame::decode_frame(&buf)?;
                if used != buf.len() {
                    return Err(TransportError::Frame(frame::FrameError::TrailingBytes {
                        extra: buf.len() - used,
                    }));
                }
                Ok(Some(msg))
            }
            Err(RecvTimeoutError::Timeout) => Ok(None),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed),
        }
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dial_accept_and_exchange_frames() {
        let mut hub = LoopbackHub::new();
        let dialer = hub.dialer();
        let mut client = dialer.connect().unwrap();
        let mut server = hub
            .accept_timeout(Duration::from_millis(100))
            .unwrap()
            .expect("dialed connection must be acceptable");

        client.send(&WireMsg::Join { device: 7 }).unwrap();
        match server.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(WireMsg::Join { device: 7 }) => {}
            other => panic!("{other:?}"),
        }
        server.send(&WireMsg::JoinAck { device: 7, n_devices: 8 }).unwrap();
        match client.recv_timeout(Duration::from_millis(100)).unwrap() {
            Some(WireMsg::JoinAck { device: 7, n_devices: 8 }) => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn timeout_and_hangup_are_distinguished() {
        let mut hub = LoopbackHub::new();
        assert!(hub.accept_timeout(Duration::from_millis(5)).unwrap().is_none());

        let dialer = hub.dialer();
        let client = dialer.connect().unwrap();
        let mut server = hub.accept_timeout(Duration::from_millis(100)).unwrap().unwrap();
        // no traffic yet: timeout, not error
        assert!(server.recv_timeout(Duration::from_millis(5)).unwrap().is_none());
        // peer drops: Closed
        drop(client);
        match server.recv_timeout(Duration::from_millis(5)) {
            Err(TransportError::Closed) => {}
            other => panic!("{other:?}"),
        }
    }
}

//! Binary frame codec for the networked coordinator protocol.
//!
//! Every message that crosses a transport (Tcp socket or in-process
//! Loopback channel) is one **frame**:
//!
//! ```text
//!  offset  size  field
//!  ──────  ────  ─────────────────────────────────────────────
//!       0     4  magic  "CAES"
//!       4     2  protocol version, u16 LE   (currently 2)
//!       6     1  message tag                (Join=1 … Reject=8)
//!       7     1  flags                      (0; reserved)
//!       8     4  body length, u32 LE        (≤ 64 MiB)
//!      12     n  body (tag-specific layout, every field byte-aligned)
//! ```
//!
//! Encoding goes through the same [`BitWriter`] as the wire payload
//! format — every frame field is a whole number of bytes, so an embedded
//! [`EncodedPayload`] splices in as a straight byte copy
//! ([`BitWriter::push_bytes`]) and the payload bytes on the socket are
//! *identical* to the bytes the simulated path accounts for.
//!
//! Decoding is the trust boundary: frames arrive from the network, so
//! [`decode_frame`] is total — truncated, malformed, oversized or
//! version-skewed input returns a typed [`FrameError`], never panics,
//! and never allocates more than the received byte count. Embedded
//! payloads are deep-validated (exact bit-length per codec, ascending
//! Top-K indices, bitmap popcounts, zero tail padding) so a decoded
//! frame is safe to hand to the engine's unchecked hot paths.
//!
//! Version rules: the `u16` version is bumped on ANY layout change; a
//! decoder rejects every version but its own ([`FrameError::Version`])
//! and the peer is expected to disconnect — there is no negotiation.

use std::sync::Arc;

use crate::coordinator::NetworkedStart;
use crate::engine::message::{RoundUpdate, StartRound};
use crate::fleet::RoundCost;
use crate::schemes::{DevicePlan, DownloadCodec, UploadCodec};
use crate::util::bitio::{bits_for, BitReader, BitWriter};
use crate::util::rng::RngState;
use crate::wire::payload::{index_list_is_cheaper, position_bits};
use crate::wire::{EncodedPayload, PayloadSpec};

/// Frame magic: ASCII "CAES".
pub const MAGIC: [u8; 4] = *b"CAES";
/// Protocol version this build speaks (see module docs for the rules).
/// v2: EndRound/Dropout carry their round number; StartRound carries the
/// coordinator's retained-local digest for recovery-prior agreement.
pub const VERSION: u16 = 2;
/// Fixed header size in bytes.
pub const HEADER_LEN: usize = 12;
/// Upper bound on a frame body — 64 MiB comfortably fits a full fp32
/// model at the stand-in scales this repo trains, while bounding what a
/// malicious length field can make the reader buffer.
pub const MAX_BODY: usize = 64 * 1024 * 1024;

/// Reject reason codes carried by [`WireMsg::Reject`].
pub mod reject {
    /// Device id outside the registry's space.
    pub const UNKNOWN_DEVICE: u16 = 1;
    /// Message arrived in a phase that cannot accept it.
    pub const BAD_STATE: u16 = 2;
    /// Frame decoded but its contents failed engine-side validation.
    pub const BAD_UPDATE: u16 = 3;
    /// A resolution (EndRound/Dropout) for a round that is no longer
    /// open — e.g. a buffered straggler frame from a round whose deadline
    /// already converted the device to a Dropout. Informational: the
    /// coordinator keeps the connection and the client keeps serving.
    pub const STALE_ROUND: u16 = 4;
}

/// Every message of the coordinator protocol, as carried by one frame.
#[derive(Clone, Debug)]
pub enum WireMsg {
    /// Device → coordinator rendezvous.
    Join { device: usize },
    /// Coordinator → device: join accepted; echoes the registry size so
    /// the device can sanity-check its config matches the server's.
    JoinAck { device: usize, n_devices: usize },
    /// Device → coordinator liveness ping at simulated time `sim_t_s`.
    Heartbeat { device: usize, sim_t_s: f64 },
    /// Coordinator → device round kickoff (plan + context + download).
    StartRound(Box<NetworkedStart>),
    /// Device → coordinator completed round `t`. The round number lets
    /// the coordinator refuse resolutions that were buffered past their
    /// round's close instead of folding them into the wrong aggregate.
    EndRound { t: usize, update: Box<RoundUpdate> },
    /// Device → coordinator mid-round dropout notice for round `t`.
    Dropout { t: usize, device: usize, after_s: f64, down_wire_bits: usize },
    /// Coordinator → device: the run is over, disconnect.
    Finish,
    /// Coordinator → device: message refused (see [`reject`] codes).
    Reject { device: usize, code: u16 },
}

impl WireMsg {
    /// The device a frame concerns, when it names one — the demux key
    /// for connection multiplexing. Every device-relevant message has
    /// carried its device id since protocol v1, which is what lets a
    /// fleet interleave many sessions on one connection with **no**
    /// frame-format change: both sides route by this id, never by which
    /// socket a frame arrived on. `Finish` is a broadcast (one per
    /// connection, however many devices ride it) and names no device.
    pub fn device(&self) -> Option<usize> {
        match self {
            WireMsg::Join { device }
            | WireMsg::JoinAck { device, .. }
            | WireMsg::Heartbeat { device, .. }
            | WireMsg::Dropout { device, .. }
            | WireMsg::Reject { device, .. } => Some(*device),
            WireMsg::StartRound(s) => Some(s.item.plan.device),
            WireMsg::EndRound { update, .. } => Some(update.device),
            WireMsg::Finish => None,
        }
    }

    fn tag(&self) -> u8 {
        match self {
            WireMsg::Join { .. } => 1,
            WireMsg::JoinAck { .. } => 2,
            WireMsg::Heartbeat { .. } => 3,
            WireMsg::StartRound(_) => 4,
            WireMsg::EndRound { .. } => 5,
            WireMsg::Dropout { .. } => 6,
            WireMsg::Finish => 7,
            WireMsg::Reject { .. } => 8,
        }
    }
}

/// Typed decode failure. `Truncated` is retryable (more bytes may
/// arrive); everything else is a protocol violation.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FrameError {
    /// Not enough bytes yet: `need` more than the `have` available.
    Truncated { need: usize, have: usize },
    BadMagic([u8; 4]),
    Version { got: u16, want: u16 },
    UnknownTag(u8),
    Oversized { len: usize, max: usize },
    Malformed(&'static str),
    /// The body decoded cleanly but `extra` bytes were left over.
    TrailingBytes { extra: usize },
}

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FrameError::Truncated { need, have } => {
                write!(f, "truncated frame: need {need} more bytes, have {have}")
            }
            FrameError::BadMagic(m) => write!(f, "bad frame magic {m:02x?}"),
            FrameError::Version { got, want } => {
                write!(f, "protocol version {got} (this build speaks {want})")
            }
            FrameError::UnknownTag(t) => write!(f, "unknown message tag {t}"),
            FrameError::Oversized { len, max } => {
                write!(f, "frame body of {len} bytes exceeds the {max}-byte cap")
            }
            FrameError::Malformed(what) => write!(f, "malformed frame: {what}"),
            FrameError::TrailingBytes { extra } => {
                write!(f, "frame body has {extra} undecoded trailing bytes")
            }
        }
    }
}

impl std::error::Error for FrameError {}

/// Whether a decode failure means "wait for more bytes" rather than
/// "protocol violation" — the framing loop in `transport::tcp` keeps
/// reading on the former and drops the connection on the latter.
impl FrameError {
    pub fn is_incomplete(&self) -> bool {
        matches!(self, FrameError::Truncated { .. })
    }
}

// ---------------------------------------------------------------------
// encoding
// ---------------------------------------------------------------------

/// Serialize one message to a complete frame (header + body).
pub fn encode_frame(msg: &WireMsg) -> Vec<u8> {
    let mut body = BitWriter::new();
    encode_body(msg, &mut body);
    debug_assert_eq!(body.len_bits() % 8, 0, "frame fields must stay byte-aligned");
    let body = body.into_bytes();
    assert!(body.len() <= MAX_BODY, "outgoing frame body of {} bytes", body.len());

    let mut w = BitWriter::new();
    w.push_bytes(&MAGIC);
    w.push_bits(VERSION as u64, 16);
    w.push_bits(msg.tag() as u64, 8);
    w.push_bits(0, 8); // flags
    w.push_bits(body.len() as u64, 32);
    w.push_bytes(&body);
    w.into_bytes()
}

fn encode_body(msg: &WireMsg, w: &mut BitWriter) {
    match msg {
        WireMsg::Join { device } => put_u64(w, *device as u64),
        WireMsg::JoinAck { device, n_devices } => {
            put_u64(w, *device as u64);
            put_u64(w, *n_devices as u64);
        }
        WireMsg::Heartbeat { device, sim_t_s } => {
            put_u64(w, *device as u64);
            put_f64(w, *sim_t_s);
        }
        WireMsg::StartRound(s) => encode_start(s, w),
        WireMsg::EndRound { t, update } => {
            put_u64(w, *t as u64);
            encode_update(update, w);
        }
        WireMsg::Dropout { t, device, after_s, down_wire_bits } => {
            put_u64(w, *t as u64);
            put_u64(w, *device as u64);
            put_f64(w, *after_s);
            put_u64(w, *down_wire_bits as u64);
        }
        WireMsg::Finish => {}
        WireMsg::Reject { device, code } => {
            put_u64(w, *device as u64);
            w.push_bits(*code as u64, 16);
        }
    }
}

fn encode_start(s: &NetworkedStart, w: &mut BitWriter) {
    put_u64(w, s.item.t as u64);
    encode_plan(&s.item.plan, w);
    put_f64(w, s.item.beta_d);
    put_f64(w, s.item.beta_u);
    put_f64(w, s.item.mu);
    w.push_f32(s.lr);
    encode_rng_state(&s.rng, w);
    put_u64(w, s.stream_base);
    put_f64(w, s.dropout_rate);
    put_f64(w, s.heartbeat_s);
    put_f64(w, s.sim_now_s);
    match s.prior_digest {
        None => w.push_bits(0, 8),
        Some(dig) => {
            w.push_bits(1, 8);
            put_u64(w, dig);
        }
    }
    encode_payload(&s.download, w);
}

fn encode_update(u: &RoundUpdate, w: &mut BitWriter) {
    put_u64(w, u.device as u64);
    put_u64(w, u.w_final.len() as u64);
    for &x in &u.w_final {
        w.push_f32(x);
    }
    encode_payload(&u.upload, w);
    put_f64(w, u.grad_norm);
    put_f64(w, u.loss);
    put_u64(w, u.down_wire_bits as u64);
    put_f64(w, u.cost.download_s);
    put_f64(w, u.cost.compute_s);
    put_f64(w, u.cost.upload_s);
}

fn encode_plan(p: &DevicePlan, w: &mut BitWriter) {
    put_u64(w, p.device as u64);
    match p.download {
        DownloadCodec::Full => w.push_bits(0, 8),
        DownloadCodec::CaesarSplit { ratio } => {
            w.push_bits(1, 8);
            put_f64(w, ratio);
        }
        DownloadCodec::TopK { ratio } => {
            w.push_bits(2, 8);
            put_f64(w, ratio);
        }
        DownloadCodec::Quant { bits } => {
            w.push_bits(3, 8);
            w.push_bits(bits as u64, 32);
        }
    }
    match p.upload {
        UploadCodec::Full => w.push_bits(0, 8),
        UploadCodec::TopK { ratio } => {
            w.push_bits(1, 8);
            put_f64(w, ratio);
        }
        UploadCodec::Quant { bits } => {
            w.push_bits(2, 8);
            w.push_bits(bits as u64, 32);
        }
    }
    put_u64(w, p.batch as u64);
    put_u64(w, p.tau as u64);
}

fn encode_rng_state(st: &RngState, w: &mut BitWriter) {
    for &word in &st.s {
        put_u64(w, word);
    }
    match st.spare_normal {
        None => w.push_bits(0, 8),
        Some(x) => {
            w.push_bits(1, 8);
            put_f64(w, x);
        }
    }
}

fn encode_payload(p: &EncodedPayload, w: &mut BitWriter) {
    match p.spec {
        PayloadSpec::Dense { n } => {
            w.push_bits(0, 8);
            put_u64(w, n as u64);
        }
        PayloadSpec::TopK { n, kept } => {
            w.push_bits(1, 8);
            put_u64(w, n as u64);
            put_u64(w, kept as u64);
        }
        PayloadSpec::CaesarSplit { n } => {
            w.push_bits(2, 8);
            put_u64(w, n as u64);
        }
        PayloadSpec::Quant { n, bits, levels } => {
            w.push_bits(3, 8);
            put_u64(w, n as u64);
            w.push_bits(bits as u64, 32);
            w.push_bits(levels as u64, 32);
        }
    }
    put_u64(w, p.bits as u64);
    put_u64(w, p.bytes.len() as u64);
    w.push_bytes(&p.bytes);
}

fn put_u64(w: &mut BitWriter, v: u64) {
    w.push_bits(v, 64);
}

fn put_f64(w: &mut BitWriter, v: f64) {
    w.push_bits(v.to_bits(), 64);
}

// ---------------------------------------------------------------------
// decoding
// ---------------------------------------------------------------------

/// Decode one frame from the front of `buf`. On success returns the
/// message and the total bytes consumed (header + body). A
/// [`FrameError::Truncated`] means the caller should read more bytes and
/// retry; every other error is a protocol violation.
pub fn decode_frame(buf: &[u8]) -> Result<(WireMsg, usize), FrameError> {
    if buf.len() < HEADER_LEN {
        return Err(FrameError::Truncated { need: HEADER_LEN - buf.len(), have: buf.len() });
    }
    if buf[0..4] != MAGIC {
        return Err(FrameError::BadMagic([buf[0], buf[1], buf[2], buf[3]]));
    }
    let version = u16::from_le_bytes([buf[4], buf[5]]);
    if version != VERSION {
        return Err(FrameError::Version { got: version, want: VERSION });
    }
    let tag = buf[6];
    if buf[7] != 0 {
        return Err(FrameError::Malformed("nonzero flags"));
    }
    let body_len = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
    if body_len > MAX_BODY {
        return Err(FrameError::Oversized { len: body_len, max: MAX_BODY });
    }
    let total = HEADER_LEN + body_len;
    if buf.len() < total {
        return Err(FrameError::Truncated { need: total - buf.len(), have: buf.len() });
    }
    let mut r = BodyReader { buf: &buf[HEADER_LEN..total], pos: 0 };
    let msg = decode_body(tag, &mut r)?;
    if r.pos != r.buf.len() {
        return Err(FrameError::TrailingBytes { extra: r.buf.len() - r.pos });
    }
    Ok((msg, total))
}

/// Exact size of the frame starting at `buf`, if the header is complete —
/// lets a stream reader size its buffer before the body arrives.
pub fn frame_len(buf: &[u8]) -> Result<usize, FrameError> {
    if buf.len() < HEADER_LEN {
        return Err(FrameError::Truncated { need: HEADER_LEN - buf.len(), have: buf.len() });
    }
    let body_len = u32::from_le_bytes([buf[8], buf[9], buf[10], buf[11]]) as usize;
    if body_len > MAX_BODY {
        return Err(FrameError::Oversized { len: body_len, max: MAX_BODY });
    }
    Ok(HEADER_LEN + body_len)
}

fn decode_body(tag: u8, r: &mut BodyReader) -> Result<WireMsg, FrameError> {
    match tag {
        1 => Ok(WireMsg::Join { device: r.usize64()? }),
        2 => Ok(WireMsg::JoinAck { device: r.usize64()?, n_devices: r.usize64()? }),
        3 => Ok(WireMsg::Heartbeat { device: r.usize64()?, sim_t_s: r.finite_f64()? }),
        4 => Ok(WireMsg::StartRound(Box::new(decode_start(r)?))),
        5 => Ok(WireMsg::EndRound { t: round_no(r)?, update: Box::new(decode_update(r)?) }),
        6 => Ok(WireMsg::Dropout {
            t: round_no(r)?,
            device: r.usize64()?,
            after_s: r.finite_f64()?,
            down_wire_bits: r.usize64()?,
        }),
        7 => Ok(WireMsg::Finish),
        8 => Ok(WireMsg::Reject { device: r.usize64()?, code: r.u16()? }),
        other => Err(FrameError::UnknownTag(other)),
    }
}

/// A 1-based round number.
fn round_no(r: &mut BodyReader) -> Result<usize, FrameError> {
    let t = r.usize64()?;
    if t == 0 {
        return Err(FrameError::Malformed("round numbers are 1-based"));
    }
    Ok(t)
}

fn decode_start(r: &mut BodyReader) -> Result<NetworkedStart, FrameError> {
    let t = round_no(r)?;
    let plan = decode_plan(r)?;
    let beta_d = r.finite_f64()?;
    let beta_u = r.finite_f64()?;
    let mu = r.finite_f64()?;
    if beta_d <= 0.0 || beta_u <= 0.0 || mu < 0.0 {
        return Err(FrameError::Malformed("non-positive link bandwidth"));
    }
    let lr = r.f32()?;
    let rng = decode_rng_state(r)?;
    let stream_base = r.u64()?;
    let dropout_rate = r.finite_f64()?;
    if !(0.0..=1.0).contains(&dropout_rate) {
        return Err(FrameError::Malformed("dropout rate outside [0, 1]"));
    }
    let heartbeat_s = r.finite_f64()?;
    if heartbeat_s < 0.0 {
        return Err(FrameError::Malformed("negative heartbeat interval"));
    }
    let sim_now_s = r.finite_f64()?;
    let prior_digest = match r.u8()? {
        0 => None,
        1 => Some(r.u64()?),
        _ => return Err(FrameError::Malformed("prior-digest flag")),
    };
    let download = Arc::new(decode_payload(r)?);
    Ok(NetworkedStart {
        item: StartRound { t, plan, beta_d, beta_u, mu },
        lr,
        rng,
        stream_base,
        dropout_rate,
        heartbeat_s,
        sim_now_s,
        prior_digest,
        download,
    })
}

fn decode_update(r: &mut BodyReader) -> Result<RoundUpdate, FrameError> {
    let device = r.usize64()?;
    let n = r.usize64()?;
    // length-check before allocating: the params must actually be present
    r.need(n.checked_mul(4).ok_or(FrameError::Malformed("w_final length overflow"))?)?;
    let mut w_final = Vec::with_capacity(n);
    for _ in 0..n {
        w_final.push(r.f32()?);
    }
    let upload = decode_payload(r)?;
    if upload.spec.n() != n {
        return Err(FrameError::Malformed("upload payload disagrees with w_final length"));
    }
    let grad_norm = r.finite_f64()?;
    let loss = r.finite_f64()?;
    let down_wire_bits = r.usize64()?;
    let cost = RoundCost {
        download_s: r.finite_f64()?,
        compute_s: r.finite_f64()?,
        upload_s: r.finite_f64()?,
    };
    if cost.download_s < 0.0 || cost.compute_s < 0.0 || cost.upload_s < 0.0 {
        return Err(FrameError::Malformed("negative round cost"));
    }
    Ok(RoundUpdate { device, w_final, upload, grad_norm, loss, down_wire_bits, cost })
}

fn decode_plan(r: &mut BodyReader) -> Result<DevicePlan, FrameError> {
    let device = r.usize64()?;
    let download = match r.u8()? {
        0 => DownloadCodec::Full,
        1 => DownloadCodec::CaesarSplit { ratio: r.unit_f64()? },
        2 => DownloadCodec::TopK { ratio: r.unit_f64()? },
        3 => DownloadCodec::Quant { bits: r.quant_bits()? },
        _ => return Err(FrameError::Malformed("unknown download codec")),
    };
    let upload = match r.u8()? {
        0 => UploadCodec::Full,
        1 => UploadCodec::TopK { ratio: r.unit_f64()? },
        2 => UploadCodec::Quant { bits: r.quant_bits()? },
        _ => return Err(FrameError::Malformed("unknown upload codec")),
    };
    let batch = r.usize64()?;
    let tau = r.usize64()?;
    if batch == 0 || tau == 0 {
        return Err(FrameError::Malformed("zero batch or tau"));
    }
    Ok(DevicePlan { device, download, upload, batch, tau })
}

fn decode_rng_state(r: &mut BodyReader) -> Result<RngState, FrameError> {
    let s = [r.u64()?, r.u64()?, r.u64()?, r.u64()?];
    let spare_normal = match r.u8()? {
        0 => None,
        1 => Some(r.finite_f64()?),
        _ => return Err(FrameError::Malformed("rng spare-normal flag")),
    };
    Ok(RngState { s, spare_normal })
}

/// Decode + deep-validate an embedded payload. Everything downstream
/// (shard folds, lazy `PayloadView` cursors, recovery) indexes these
/// bytes unchecked, so this is where wire-originated payloads earn
/// trust: the bit length must match the codec's exact closed form, the
/// structural sections (Top-K positions, split bitmaps, quant buckets)
/// must be internally consistent, and the padding bits of the final
/// byte must be zero (canonical encoding — also what byte-level parity
/// with the loopback path requires).
fn decode_payload(r: &mut BodyReader) -> Result<EncodedPayload, FrameError> {
    let spec = match r.u8()? {
        0 => PayloadSpec::Dense { n: r.usize64()? },
        1 => PayloadSpec::TopK { n: r.usize64()?, kept: r.usize64()? },
        2 => PayloadSpec::CaesarSplit { n: r.usize64()? },
        3 => {
            let n = r.usize64()?;
            let bits = r.quant_bits()?;
            let levels = r.u32()?;
            // levels = 0 would make dequantization divide by zero and
            // fold NaN into the global model
            if levels == 0 {
                return Err(FrameError::Malformed("quant levels must be at least 1"));
            }
            if (levels as u64) >= (1u64 << bits) {
                return Err(FrameError::Malformed("quant levels exceed the bit width"));
            }
            PayloadSpec::Quant { n, bits, levels }
        }
        _ => return Err(FrameError::Malformed("unknown payload spec")),
    };
    let bits = r.usize64()?;
    let n_bytes = r.usize64()?;
    if n_bytes != bits.div_ceil(8) {
        return Err(FrameError::Malformed("payload byte count disagrees with bit length"));
    }
    let bytes = r.bytes(n_bytes)?.to_vec();
    // canonical padding: a BitWriter leaves unused high bits of the tail
    // byte zero, and every honest encoder goes through one
    if bits % 8 != 0 {
        let tail = bytes[n_bytes - 1];
        if tail >> (bits % 8) != 0 {
            return Err(FrameError::Malformed("nonzero payload padding bits"));
        }
    }
    validate_payload(&spec, bits, &bytes)?;
    Ok(EncodedPayload { spec, bits, bytes })
}

/// Structural validation of payload bytes against their spec (see
/// [`decode_payload`]). Reads at most `bits` bits, which the caller has
/// verified fit in `bytes`.
fn validate_payload(spec: &PayloadSpec, bits: usize, bytes: &[u8]) -> Result<(), FrameError> {
    match *spec {
        PayloadSpec::Dense { n } => {
            if bits != n.checked_mul(32).ok_or(FrameError::Malformed("payload size overflow"))? {
                return Err(FrameError::Malformed("dense payload bit length"));
            }
            let mut rd = BitReader::new(bytes);
            for _ in 0..n {
                finite_f32(rd.read_bits(32), "non-finite dense value")?;
            }
        }
        PayloadSpec::TopK { n, kept } => {
            if kept > n {
                return Err(FrameError::Malformed("top-k kept exceeds n"));
            }
            let expect = kept
                .checked_mul(32)
                .and_then(|v| v.checked_add(position_bits(n, kept)))
                .ok_or(FrameError::Malformed("payload size overflow"))?;
            if bits != expect {
                return Err(FrameError::Malformed("top-k payload bit length"));
            }
            let mut rd = BitReader::new(bytes);
            if index_list_is_cheaper(n, kept) {
                let idx_bits = bits_for(n);
                let mut prev: Option<u64> = None;
                for _ in 0..kept {
                    let i = rd.read_bits(idx_bits);
                    if i as usize >= n || prev.is_some_and(|p| p >= i) {
                        return Err(FrameError::Malformed("top-k indices not ascending"));
                    }
                    prev = Some(i);
                }
            } else {
                let mut ones = 0usize;
                for _ in 0..n {
                    ones += rd.read_bit() as usize;
                }
                if ones != kept {
                    return Err(FrameError::Malformed("top-k bitmap popcount"));
                }
            }
            for _ in 0..kept {
                finite_f32(rd.read_bits(32), "non-finite top-k value")?;
            }
        }
        PayloadSpec::CaesarSplit { n } => {
            // layout: n-bit mask, then per-position sign bit (quantized)
            // or f32 (kept), then 2 scalars — so for popcount q,
            // bits = n + q + (n−q)·32 + 64. Solve for q and verify.
            let full = n
                .checked_mul(33)
                .and_then(|v| v.checked_add(64))
                .ok_or(FrameError::Malformed("payload size overflow"))?;
            if bits > full || bits < full.saturating_sub(n * 31) {
                return Err(FrameError::Malformed("split payload bit length"));
            }
            if (full - bits) % 31 != 0 {
                return Err(FrameError::Malformed("split payload bit length"));
            }
            let q = (full - bits) / 31;
            let mut rd = BitReader::new(bytes);
            let mut ones = 0usize;
            for _ in 0..n {
                ones += rd.read_bit() as usize;
            }
            if ones != q {
                return Err(FrameError::Malformed("split bitmap popcount"));
            }
            // `rd` now sits at the mixed sign/value section; a second
            // cursor re-walks the mask in lockstep to tell them apart
            let mut mask_rd = BitReader::new(bytes);
            for _ in 0..n {
                if mask_rd.read_bit() {
                    let _sign = rd.read_bit();
                } else {
                    finite_f32(rd.read_bits(32), "non-finite split value")?;
                }
            }
            finite_f32(rd.read_bits(32), "non-finite split avg_abs")?;
            finite_f32(rd.read_bits(32), "non-finite split max_abs")?;
        }
        PayloadSpec::Quant { n, bits: qbits, levels } => {
            let expect = n
                .checked_mul(1 + qbits as usize)
                .and_then(|v| v.checked_add(32))
                .ok_or(FrameError::Malformed("payload size overflow"))?;
            if bits != expect {
                return Err(FrameError::Malformed("quant payload bit length"));
            }
            let mut rd = BitReader::new(bytes);
            finite_f32(rd.read_bits(32), "non-finite quant norm")?;
            for _ in 0..n {
                let _sign = rd.read_bit();
                if rd.read_bits(qbits) > levels as u64 {
                    return Err(FrameError::Malformed("quant bucket exceeds levels"));
                }
            }
        }
    }
    Ok(())
}

/// Embedded payload f32 finiteness: wire-originated values feed straight
/// into recovery and aggregation arithmetic, where a NaN/∞ would poison
/// the global model as silently as a non-finite f64 poisons the clock.
fn finite_f32(raw: u64, what: &'static str) -> Result<(), FrameError> {
    if !f32::from_bits(raw as u32).is_finite() {
        return Err(FrameError::Malformed(what));
    }
    Ok(())
}

/// Bounds-checked byte cursor over an untrusted frame body. The bit-level
/// [`BitReader`] indexes unchecked (it is a hot-path tool for bytes that
/// already earned trust); this reader is its total counterpart for the
/// trust boundary.
struct BodyReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BodyReader<'a> {
    fn need(&self, n: usize) -> Result<(), FrameError> {
        let have = self.buf.len() - self.pos;
        if n > have {
            return Err(FrameError::Truncated { need: n - have, have });
        }
        Ok(())
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        self.need(n)?;
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.bytes(1)?[0])
    }

    fn u16(&mut self) -> Result<u16, FrameError> {
        let b = self.bytes(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        let b = self.bytes(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        let b = self.bytes(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    fn f32(&mut self) -> Result<f32, FrameError> {
        Ok(f32::from_bits(self.u32()?))
    }

    /// A u64 that must fit this platform's `usize`.
    fn usize64(&mut self) -> Result<usize, FrameError> {
        usize::try_from(self.u64()?).map_err(|_| FrameError::Malformed("length overflows usize"))
    }

    /// An f64 that must be finite (NaN/∞ would poison simulated time,
    /// costs and rates downstream).
    fn finite_f64(&mut self) -> Result<f64, FrameError> {
        let v = f64::from_bits(self.u64()?);
        if !v.is_finite() {
            return Err(FrameError::Malformed("non-finite f64"));
        }
        Ok(v)
    }

    /// A finite f64 in `[0, 1]` (codec ratios).
    fn unit_f64(&mut self) -> Result<f64, FrameError> {
        let v = self.finite_f64()?;
        if !(0.0..=1.0).contains(&v) {
            return Err(FrameError::Malformed("ratio outside [0, 1]"));
        }
        Ok(v)
    }

    /// A quantizer bit width in `1..=32`.
    fn quant_bits(&mut self) -> Result<u32, FrameError> {
        let b = self.u32()?;
        if !(1..=32).contains(&b) {
            return Err(FrameError::Malformed("quant bits outside 1..=32"));
        }
        Ok(b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Config};
    use crate::util::rng::Rng;
    use crate::wire::Payload;

    fn sample_update(rng: &mut Rng, n: usize) -> RoundUpdate {
        let g: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let upload = match rng.below(3) {
            0 => Payload::Dense(g.clone()).encode(),
            1 => crate::compress::topk::topk_encode(&g, 0.5).0.encode(),
            _ => crate::compress::quant::quant_payload(&g, 4, rng).0.encode(),
        };
        RoundUpdate {
            device: rng.below(64),
            w_final: (0..n).map(|_| rng.normal() as f32).collect(),
            upload,
            grad_norm: rng.f64(),
            loss: rng.f64(),
            down_wire_bits: rng.below(1 << 20),
            cost: RoundCost {
                download_s: rng.f64(),
                compute_s: rng.f64(),
                upload_s: rng.f64(),
            },
        }
    }

    fn sample_start(rng: &mut Rng, n: usize) -> NetworkedStart {
        let w: Vec<f32> = (0..n).map(|_| rng.normal() as f32).collect();
        let download = Arc::new(Payload::Dense(w).encode());
        NetworkedStart {
            item: StartRound {
                t: 1 + rng.below(100),
                plan: DevicePlan {
                    device: rng.below(64),
                    download: DownloadCodec::CaesarSplit { ratio: rng.f64() },
                    upload: UploadCodec::TopK { ratio: rng.f64() },
                    batch: 1 + rng.below(64),
                    tau: 1 + rng.below(16),
                },
                beta_d: 1.0 + rng.f64() * 1e6,
                beta_u: 1.0 + rng.f64() * 1e6,
                mu: rng.f64(),
            },
            lr: rng.f64() as f32,
            rng: Rng::new(rng.next_u64()).state(),
            stream_base: rng.next_u64(),
            dropout_rate: rng.f64() * 0.5,
            heartbeat_s: rng.f64() * 30.0,
            sim_now_s: rng.f64() * 1e4,
            prior_digest: if rng.below(2) == 0 { None } else { Some(rng.next_u64()) },
            download,
        }
    }

    fn sample_msg(rng: &mut Rng, size: usize) -> WireMsg {
        let n = 1 + rng.below(size.max(1));
        match rng.below(8) {
            0 => WireMsg::Join { device: rng.below(1000) },
            1 => WireMsg::JoinAck { device: rng.below(1000), n_devices: 1 + rng.below(1000) },
            2 => WireMsg::Heartbeat { device: rng.below(1000), sim_t_s: rng.f64() * 1e5 },
            3 => WireMsg::StartRound(Box::new(sample_start(rng, n))),
            4 => WireMsg::EndRound {
                t: 1 + rng.below(100),
                update: Box::new(sample_update(rng, n)),
            },
            5 => WireMsg::Dropout {
                t: 1 + rng.below(100),
                device: rng.below(1000),
                after_s: rng.f64() * 100.0,
                down_wire_bits: rng.below(1 << 24),
            },
            6 => WireMsg::Finish,
            _ => WireMsg::Reject { device: rng.below(1000), code: rng.below(4) as u16 },
        }
    }

    /// Structural equality for round-trip checks (floats by bit pattern —
    /// the transport must be bit-transparent, not approximately equal).
    fn assert_same(a: &WireMsg, b: &WireMsg) {
        match (a, b) {
            (WireMsg::Join { device: x }, WireMsg::Join { device: y }) => assert_eq!(x, y),
            (
                WireMsg::JoinAck { device: x, n_devices: nx },
                WireMsg::JoinAck { device: y, n_devices: ny },
            ) => assert_eq!((x, nx), (y, ny)),
            (
                WireMsg::Heartbeat { device: x, sim_t_s: tx },
                WireMsg::Heartbeat { device: y, sim_t_s: ty },
            ) => {
                assert_eq!(x, y);
                assert_eq!(tx.to_bits(), ty.to_bits());
            }
            (WireMsg::StartRound(x), WireMsg::StartRound(y)) => {
                assert_eq!(format!("{x:?}"), format!("{y:?}"));
                assert_eq!(x.download.bytes, y.download.bytes);
                assert_eq!(x.rng, y.rng);
            }
            (
                WireMsg::EndRound { t: tx, update: x },
                WireMsg::EndRound { t: ty, update: y },
            ) => {
                assert_eq!(tx, ty);
                assert_eq!(x.device, y.device);
                let xb: Vec<u32> = x.w_final.iter().map(|v| v.to_bits()).collect();
                let yb: Vec<u32> = y.w_final.iter().map(|v| v.to_bits()).collect();
                assert_eq!(xb, yb);
                assert_eq!(x.upload.bytes, y.upload.bytes);
                assert_eq!(x.upload.bits, y.upload.bits);
                assert_eq!(x.grad_norm.to_bits(), y.grad_norm.to_bits());
                assert_eq!(x.down_wire_bits, y.down_wire_bits);
                assert_eq!(x.cost.total().to_bits(), y.cost.total().to_bits());
            }
            (
                WireMsg::Dropout { t: tx, device: x, after_s: ax, down_wire_bits: bx },
                WireMsg::Dropout { t: ty, device: y, after_s: ay, down_wire_bits: by },
            ) => {
                assert_eq!((tx, x, bx), (ty, y, by));
                assert_eq!(ax.to_bits(), ay.to_bits());
            }
            (WireMsg::Finish, WireMsg::Finish) => {}
            (
                WireMsg::Reject { device: x, code: cx },
                WireMsg::Reject { device: y, code: cy },
            ) => assert_eq!((x, cx), (y, cy)),
            (a, b) => panic!("variant mismatch: {a:?} vs {b:?}"),
        }
    }

    #[test]
    fn every_variant_round_trips() {
        forall(
            Config { cases: 96, seed: 0xF4A3E },
            |rng, size| sample_msg(rng, size),
            |msg| {
                let frame = encode_frame(msg);
                let (back, used) = decode_frame(&frame).map_err(|e| format!("{e}"))?;
                if used != frame.len() {
                    return Err(format!("consumed {used} of {}", frame.len()));
                }
                assert_same(msg, &back);
                // a second frame appended: the first decode stops exactly
                // at the boundary
                let mut two = frame.clone();
                two.extend_from_slice(&encode_frame(&WireMsg::Finish));
                let (_, used2) = decode_frame(&two).map_err(|e| format!("{e}"))?;
                if used2 != frame.len() {
                    return Err("decode overran the frame boundary".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn every_truncation_errs_and_never_panics() {
        forall(
            Config { cases: 48, seed: 0x7A11 },
            |rng, size| {
                let frame = encode_frame(&sample_msg(rng, size));
                let cut = rng.below(frame.len());
                (frame, cut)
            },
            |(frame, cut)| match decode_frame(&frame[..*cut]) {
                Ok(_) => Err(format!("decoded from {cut} of {} bytes", frame.len())),
                Err(e) if e.is_incomplete() => Ok(()),
                // a truncation can also surface as a structural error
                // (e.g. the cut lands inside a length field); it must
                // still be an Err, never a panic
                Err(_) => Ok(()),
            },
        );
    }

    #[test]
    fn every_single_byte_mutation_errs_or_decodes_without_panic() {
        forall(
            Config { cases: 48, seed: 0xBADF00D },
            |rng, size| {
                let frame = encode_frame(&sample_msg(rng, size));
                let at = rng.below(frame.len());
                let flip = 1u8 << rng.below(8);
                (frame, at, flip)
            },
            |(frame, at, flip)| {
                let mut bad = frame.clone();
                bad[*at] ^= flip;
                // decoding must be total: Ok (the flip hit a benign float
                // payload byte) or a typed Err — the panic is the bug
                let _ = decode_frame(&bad);
                Ok(())
            },
        );
    }

    #[test]
    fn version_mismatch_is_a_typed_error() {
        let mut frame = encode_frame(&WireMsg::Finish);
        frame[4] = VERSION as u8 + 1; // future version, LE low byte
        match decode_frame(&frame) {
            Err(FrameError::Version { got, want: VERSION }) if got == VERSION + 1 => {}
            other => panic!("expected version error, got {other:?}"),
        }
    }

    #[test]
    fn bad_magic_oversize_and_trailing_are_typed_errors() {
        let good = encode_frame(&WireMsg::Join { device: 3 });
        let mut bad = good.clone();
        bad[0] = b'X';
        assert!(matches!(decode_frame(&bad), Err(FrameError::BadMagic(_))));

        let mut oversized = good.clone();
        oversized[8..12].copy_from_slice(&(MAX_BODY as u32 + 1).to_le_bytes());
        assert!(matches!(decode_frame(&oversized), Err(FrameError::Oversized { .. })));

        // grow the declared body without growing the content the decoder
        // consumes: trailing bytes must be flagged
        let mut padded = good.clone();
        let body_len = u32::from_le_bytes([good[8], good[9], good[10], good[11]]);
        padded[8..12].copy_from_slice(&(body_len + 3).to_le_bytes());
        padded.extend_from_slice(&[0, 0, 0]);
        assert!(matches!(decode_frame(&padded), Err(FrameError::TrailingBytes { extra: 3 })));

        assert!(matches!(decode_frame(&[]), Err(FrameError::Truncated { .. })));
    }

    #[test]
    fn payload_validation_rejects_structural_lies() {
        let g = [1.0f32, -2.0, 3.0, -4.0, 5.0, -6.0, 7.0, -8.0];
        let honest = crate::compress::topk::topk_encode(&g, 0.5).0.encode();
        let mut upd = RoundUpdate {
            device: 0,
            w_final: vec![0.0; honest.spec.n()],
            upload: honest,
            grad_norm: 1.0,
            loss: 1.0,
            down_wire_bits: 10,
            cost: RoundCost { download_s: 1.0, compute_s: 1.0, upload_s: 1.0 },
        };
        // lie about the bit length: byte/bit disagreement is caught
        upd.upload.bits += 8;
        upd.upload.bytes.push(0);
        let frame = encode_frame(&WireMsg::EndRound { t: 1, update: Box::new(upd) });
        match decode_frame(&frame) {
            Err(FrameError::Malformed(_)) => {}
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn quant_levels_zero_and_non_finite_values_are_rejected() {
        // a hostile Quant spec with levels=0 would dequantize to 0/0=NaN
        let honest = crate::compress::quant::quant_payload(
            &[1.0f32, -2.0, 3.0, -4.0],
            3,
            &mut Rng::new(5),
        )
        .0
        .encode();
        let mut upd = RoundUpdate {
            device: 0,
            w_final: vec![0.0; honest.spec.n()],
            upload: honest,
            grad_norm: 1.0,
            loss: 1.0,
            down_wire_bits: 10,
            cost: RoundCost { download_s: 1.0, compute_s: 1.0, upload_s: 1.0 },
        };
        if let PayloadSpec::Quant { levels, .. } = &mut upd.upload.spec {
            *levels = 0;
        } else {
            panic!("expected a quant payload");
        }
        let frame = encode_frame(&WireMsg::EndRound { t: 1, update: Box::new(upd) });
        match decode_frame(&frame) {
            Err(FrameError::Malformed("quant levels must be at least 1")) => {}
            other => panic!("expected malformed, got {other:?}"),
        }

        // a dense payload smuggling a NaN value is refused at the frame
        // boundary instead of poisoning downstream arithmetic
        let poisoned = Payload::Dense(vec![1.0f32, f32::NAN, 3.0]).encode();
        let upd = RoundUpdate {
            device: 0,
            w_final: vec![0.0; 3],
            upload: poisoned,
            grad_norm: 1.0,
            loss: 1.0,
            down_wire_bits: 10,
            cost: RoundCost { download_s: 1.0, compute_s: 1.0, upload_s: 1.0 },
        };
        let frame = encode_frame(&WireMsg::EndRound { t: 1, update: Box::new(upd) });
        match decode_frame(&frame) {
            Err(FrameError::Malformed("non-finite dense value")) => {}
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn zero_round_resolutions_are_rejected() {
        let frame = encode_frame(&WireMsg::Dropout {
            t: 0,
            device: 1,
            after_s: 0.5,
            down_wire_bits: 64,
        });
        match decode_frame(&frame) {
            Err(FrameError::Malformed("round numbers are 1-based")) => {}
            other => panic!("expected malformed, got {other:?}"),
        }
    }

    #[test]
    fn quant_and_split_payloads_round_trip_through_frames() {
        let mut rng = Rng::new(42);
        let w: Vec<f32> = (0..257).map(|_| rng.normal() as f32).collect();
        for payload in [
            crate::compress::quant::quant_payload(&w, 3, &mut rng).0,
            crate::schemes::DownloadCodec::CaesarSplit { ratio: 0.7 }
                .encode_payload(&w, &mut rng),
        ] {
            let enc = payload.encode();
            let start = NetworkedStart {
                item: StartRound {
                    t: 1,
                    plan: DevicePlan {
                        device: 0,
                        download: DownloadCodec::Full,
                        upload: UploadCodec::Full,
                        batch: 8,
                        tau: 2,
                    },
                    beta_d: 1e6,
                    beta_u: 1e6,
                    mu: 1e-4,
                },
                lr: 0.1,
                rng: Rng::new(7).state(),
                stream_base: 99,
                dropout_rate: 0.0,
                heartbeat_s: 10.0,
                sim_now_s: 0.0,
                prior_digest: Some(0xDEAD_BEEF),
                download: Arc::new(enc.clone()),
            };
            let frame = encode_frame(&WireMsg::StartRound(Box::new(start)));
            let (msg, _) = decode_frame(&frame).unwrap();
            match msg {
                WireMsg::StartRound(s) => {
                    assert_eq!(s.download.bytes, enc.bytes);
                    assert_eq!(s.download.bits, enc.bits);
                    assert_eq!(s.download.spec, enc.spec);
                }
                other => panic!("{other:?}"),
            }
        }
    }
}

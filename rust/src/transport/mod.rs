//! Networked coordinator: the engine behind a real transport.
//!
//! The simulator's coordinator protocol (`engine::message`) was always
//! message-shaped; this subsystem moves those messages across an actual
//! byte boundary. It is std-only — no async runtime, no serde — and
//! splits into:
//!
//! * [`frame`] — the binary frame codec (magic + version + tag +
//!   length-prefixed body), total on untrusted input;
//! * the [`Transport`]/[`Conn`] traits with two implementations:
//!   [`loopback::LoopbackHub`] (in-process mpsc channels of *encoded
//!   frames* — the codec is genuinely exercised without a socket) and
//!   [`tcp::TcpTransport`] (framed `std::net::TcpStream`, timeouts,
//!   connection-per-device accept loop, reconnect-with-rejoin);
//! * [`readiness`] — the serving-side reactor: one wait-set over the
//!   listener plus every live connection (`poll(2)` via a vendored FFI
//!   shim on unix, waker keys for channels, a threaded-reader fallback
//!   for anything else), so the coordinator wakes on bytes, never on a
//!   timer;
//! * [`server::CoordinatorService`] — drives `coordinator::Server` +
//!   `engine::Engine` from decoded frames, demux-routing every frame by
//!   the device id it carries (never by which socket it arrived on);
//!   [`client::DeviceClient`] — the worker-side round (recover download
//!   → train → encode upload) run remotely; [`fleet::DeviceFleet`] —
//!   many device sessions multiplexed over ONE connection.
//!
//! The headline invariant, pinned by `tests/transport_parity.rs`: a
//! fixed-seed run over Tcp on localhost produces **bit-identical** final
//! models and traffic ledgers to the same run over Loopback and to the
//! in-process `Server::run` path. Transport moves bytes; it never
//! touches math.

pub mod client;
pub mod fleet;
pub mod frame;
pub mod loopback;
pub mod readiness;
pub mod server;
pub mod tcp;

pub use client::{ClientStats, DeviceClient, SessionEnd};
pub use fleet::DeviceFleet;
pub use frame::{decode_frame, encode_frame, FrameError, WireMsg};
pub use loopback::{LoopbackConn, LoopbackDialer, LoopbackHub};
pub use readiness::{RawSource, Reactor, ThreadedReader, Wake, Waker};
pub use server::CoordinatorService;
pub use tcp::{TcpConn, TcpTransport};

use std::sync::Arc;
use std::time::Duration;

/// Transport-layer failure.
#[derive(Debug)]
pub enum TransportError {
    /// Socket-level I/O failure.
    Io(std::io::Error),
    /// The peer sent bytes that are not a valid frame.
    Frame(FrameError),
    /// The peer hung up (clean close or channel disconnect).
    Closed,
    /// The listener's accept call itself failed (fd exhaustion, a dying
    /// interface) — a *coordinator-side* fault, typed apart from
    /// [`TransportError::Io`] so drivers don't mistake it for one bad
    /// peer connection and busy-poll past it.
    Accept(std::io::Error),
}

impl std::fmt::Display for TransportError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TransportError::Io(e) => write!(f, "transport i/o: {e}"),
            TransportError::Frame(e) => write!(f, "transport framing: {e}"),
            TransportError::Closed => write!(f, "peer closed the connection"),
            TransportError::Accept(e) => write!(f, "listener accept: {e}"),
        }
    }
}

impl std::error::Error for TransportError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            TransportError::Io(e) => Some(e),
            TransportError::Frame(e) => Some(e),
            TransportError::Closed => None,
            TransportError::Accept(e) => Some(e),
        }
    }
}

impl From<std::io::Error> for TransportError {
    fn from(e: std::io::Error) -> Self {
        TransportError::Io(e)
    }
}

impl From<FrameError> for TransportError {
    fn from(e: FrameError) -> Self {
        TransportError::Frame(e)
    }
}

/// One framed, bidirectional connection to a peer.
///
/// The readiness hooks (`source`, `try_recv`) have conservative
/// defaults so simple test doubles keep compiling: a defaulted conn
/// reports [`RawSource::Unready`] and the reactor degrades to bounded
/// sweeps for it. Real transports override both — `try_recv` in
/// particular must actually pull newly arrived bytes (a zero-timeout
/// `recv_timeout` on a socket would not), or a level-triggered wait
/// would spin on a conn it can never drain.
pub trait Conn: Send + 'static {
    /// Serialize and send one message (blocking, with the transport's
    /// write timeout).
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError>;

    /// Receive the next complete frame, waiting at most `timeout`.
    /// `Ok(None)` means the timeout elapsed with no complete frame (any
    /// partial bytes stay buffered for the next call).
    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<WireMsg>, TransportError>;

    /// Non-blocking receive: `Ok(None)` when no complete frame is
    /// available *right now*. The default is a short sliced receive —
    /// correct but slow; readiness-integrated conns override.
    fn try_recv(&mut self) -> Result<Option<WireMsg>, TransportError> {
        self.recv_timeout(Duration::from_millis(1))
    }

    /// How the reactor can wait on this conn (see [`readiness`]).
    fn source(&self) -> RawSource {
        RawSource::Unready
    }

    /// Human-readable peer address (diagnostics).
    fn peer(&self) -> String;
}

/// A listener producing [`Conn`]s — how the coordinator accepts devices.
pub trait Transport {
    type Conn: Conn;

    /// Accept one pending connection, waiting at most `timeout`;
    /// `Ok(None)` on timeout.
    fn accept_timeout(&mut self, timeout: Duration)
        -> Result<Option<Self::Conn>, TransportError>;

    /// How the reactor can wait on the accept queue itself.
    fn listener_source(&self) -> RawSource {
        RawSource::Unready
    }

    /// The wake channel this transport's conns signal, if readiness is
    /// channel-based (the Loopback hub). Fd-based transports return
    /// `None` and the reactor mints its own waker for any
    /// threaded-reader fallbacks.
    fn waker(&self) -> Option<Arc<Waker>> {
        None
    }

    /// The address devices should dial (diagnostics / test plumbing).
    fn local_addr(&self) -> String;
}

/// Order-sensitive FNV-1a digest over a model's exact f32 bit patterns —
/// the fingerprint the parity tests and the two-process example compare
/// across transports. Bit-identical models ⇔ equal digests.
pub fn model_digest(w: &[f32]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &x in w {
        for b in x.to_bits().to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_digest_separates_bit_patterns() {
        let a = vec![1.0f32, 2.0, 3.0];
        let mut b = a.clone();
        assert_eq!(model_digest(&a), model_digest(&b));
        b[2] = 3.0000002; // one ulp-ish nudge
        assert_ne!(model_digest(&a), model_digest(&b));
        // 0.0 and -0.0 differ in bits, so they must differ in digest
        assert_ne!(model_digest(&[0.0]), model_digest(&[-0.0]));
        // order matters
        assert_ne!(model_digest(&[1.0, 2.0]), model_digest(&[2.0, 1.0]));
    }
}

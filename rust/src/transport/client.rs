//! Device client: the worker side of a round, run remotely.
//!
//! A [`DeviceClient`] owns one device's view of the experiment — the
//! shared config, its data shard (rebuilt locally from the seed via
//! `coordinator::build_data`, so training examples never cross the
//! wire) and its retained local model — and executes kickoff frames
//! exactly as `engine::run_device` would in-process:
//!
//! 1. resume the device RNG stream from the kickoff's [`RngState`]
//!    (the PS-side download encode already consumed its draws),
//! 2. run the dropout lottery on the independent fate stream,
//! 3. recover the download against the retained local model the kickoff's
//!    prior digest selects (see `pick_prior` — under semi-async
//!    pipelining the coordinator's view can lag several rounds behind,
//!    so the client keeps a short digest-matched history ring), train τ
//!    local steps, encode the upload,
//! 4. send heartbeats on the shared simulated-time schedule, then the
//!    EndRound (or Dropout) frame.
//!
//! Every input to the math arrives bit-exact over the wire, so the
//! update frames are bit-identical to the in-process path — the
//! transport parity invariant.
//!
//! Redelivery: the client caches the resolution frames of its last few
//! rounds. A duplicate StartRound for an already-completed round (the
//! coordinator re-sends kickoffs on rejoin — it cannot know whether the
//! EndRound made it out before the connection died, and under pipelining
//! several rounds can be open at once) is answered by resending the
//! cached frame, never by re-training: the local model has already
//! advanced, so a second training pass would diverge.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use anyhow::{anyhow, ensure, Context, Result};

use crate::compress::traffic::PayloadScale;
use crate::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
use crate::coordinator::{self, CodecEngine, NetworkedStart, Trainer};
use crate::data::{Dataset, Partition};
use crate::engine::{self, RoundUpdate};
use crate::fleet::RoundCost;
use crate::util::pool;
use crate::util::rng::Rng;

use super::frame::{reject, WireMsg};
use super::{model_digest, Conn, TransportError};

/// Receive slice while waiting for the next frame.
const RECV_SLICE: Duration = Duration::from_millis(100);

/// Stream-key salt for redial-backoff jitter draws.
const REDIAL_SALT: u64 = 0x12ED;
/// First-attempt redial delay (doubles per consecutive fruitless attempt).
const REDIAL_BASE_MS: u64 = 20;
/// Backoff ceiling — a fleet of patient clients, not a thundering herd.
const REDIAL_CAP_MS: u64 = 2000;

/// Redial delay before consecutive fruitless attempt `attempt` (1-based):
/// capped exponential backoff plus deterministic jitter in
/// `[0, nominal/2]`, drawn from the device's own `Rng::stream` so two
/// clients dropped by the same fault never redial in lockstep — and so
/// tests of the delay sequence stay reproducible. A session that makes
/// protocol progress restarts the sequence at attempt 1.
pub(crate) fn redial_backoff_ms(seed: u64, device: usize, attempt: usize) -> u64 {
    let attempt = attempt.max(1);
    // 20 << 7 already clears the cap; clamping the shift avoids overflow
    let nominal = (REDIAL_BASE_MS << (attempt - 1).min(7) as u32).min(REDIAL_CAP_MS);
    let jitter = Rng::stream(seed ^ REDIAL_SALT, device as u64, attempt as u64)
        .below(nominal as usize / 2 + 1) as u64;
    nominal + jitter
}

/// Counters for one client session (diagnostics; not part of parity).
#[derive(Clone, Copy, Debug, Default)]
pub struct ClientStats {
    /// Rounds completed with an EndRound.
    pub rounds: usize,
    /// Rounds resolved by losing the dropout lottery.
    pub dropouts: usize,
    /// Heartbeat frames sent.
    pub heartbeats: usize,
    /// Duplicate kickoffs answered from the redelivery cache.
    pub redeliveries: usize,
    /// Resolutions the coordinator refused as stale (a buffered frame
    /// from a round whose deadline had already converted this device to
    /// a Dropout). Harmless — the refusal is informational.
    pub stale_rejects: usize,
}

/// How many post-training models (and resolution frames) the client
/// retains for digest-matched recovery and redelivery. The coordinator's
/// `locals[d]` can trail this client by one round per refused EndRound
/// *plus* one per round of pipeline overlap, so the ring comfortably
/// covers `pipeline_depth ≤ 3` with a refusal on top; a deeper mismatch
/// is genuine divergence and fails loudly in `pick_prior`.
const HISTORY_DEPTH: usize = 4;

/// How a client session over one connection ended.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SessionEnd {
    /// The coordinator broadcast Finish: the run is over.
    Finished,
    /// The connection died or went silent past the idle budget; the
    /// device state is intact and [`DeviceClient::run_reconnecting`]
    /// may dial again and re-Join.
    Disconnected,
}

/// What one handled frame means for the session serving this device —
/// the per-message unit [`DeviceClient::run`] and the fleet scheduler
/// ([`super::fleet::DeviceFleet`]) both loop over.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Step {
    /// Keep serving.
    Continue,
    /// The coordinator broadcast Finish.
    Finished,
    /// The connection died mid-send; state is intact, redial-able.
    Disconnected,
}

/// One device's stateful worker loop.
pub struct DeviceClient {
    cfg: ExperimentConfig,
    device: usize,
    trainer: Trainer,
    train_ds: Dataset,
    partition: Partition,
    /// Retained post-training models, newest first — the reference set
    /// for CaesarSplit download recovery. An entry is pushed when a
    /// round's EndRound goes out; the coordinator's `locals[d]` advances
    /// only when an EndRound is *accepted* and, under semi-async
    /// pipelining, rounds are *opened* against pre-close locals — so the
    /// sides can disagree by several rounds. Each kickoff therefore
    /// declares the digest of the prior the PS encoded against, and the
    /// client recovers against whichever retained model matches (see
    /// `pick_prior`). Capped at [`HISTORY_DEPTH`]; matched entries are
    /// never removed (the same prior can serve several open rounds).
    history: VecDeque<Vec<f32>>,
    /// Redelivery cache: `(round, resolution frame)` for the last
    /// [`HISTORY_DEPTH`] rounds this device resolved, newest first.
    resolutions: VecDeque<(usize, WireMsg)>,
    /// Highest round this device has resolved (0 before any round).
    last_round: usize,
    pub stats: ClientStats,
    /// Silence budget before a session reports [`SessionEnd::Disconnected`].
    /// Idle is normal (non-participants wait out whole rounds), so this
    /// defaults generously; transport-level errors disconnect immediately.
    pub idle_timeout: Duration,
}

impl DeviceClient {
    /// Build the device's local world from the shared config. Data and
    /// model-shape are derived from `cfg.seed` exactly as the
    /// coordinator derives them, which is what keeps the wire free of
    /// training data.
    pub fn new(cfg: ExperimentConfig, device: usize) -> Result<DeviceClient> {
        ensure!(
            device < cfg.n_devices(),
            "device id {device} out of range for a {} device fleet",
            cfg.n_devices()
        );
        ensure!(
            cfg.trainer == TrainerBackend::Native && cfg.compression == CompressionBackend::Native,
            "the device client is native-only (no accelerator runtime on the worker side)"
        );
        let (train_ds, _test_ds, partition, _rng) =
            coordinator::build_data(&cfg).context("building the device-side data world")?;
        let trainer = Trainer::native(&cfg.task);
        Ok(DeviceClient {
            cfg,
            device,
            trainer,
            train_ds,
            partition,
            history: VecDeque::new(),
            resolutions: VecDeque::new(),
            last_round: 0,
            stats: ClientStats::default(),
            idle_timeout: Duration::from_secs(600),
        })
    }

    pub fn device(&self) -> usize {
        self.device
    }

    /// The newest retained local model, if any round has completed.
    pub fn local(&self) -> Option<&[f32]> {
        self.history.front().map(Vec::as_slice)
    }

    /// Run one session over `conn`: Join, then serve kickoffs until the
    /// coordinator finishes or the connection dies. Transport failures
    /// return `Ok(Disconnected)` (retryable — state is intact); protocol
    /// rejections and engine-level errors are `Err` (fatal).
    pub fn run<C: Conn>(&mut self, conn: &mut C) -> Result<SessionEnd> {
        if conn.send(&WireMsg::Join { device: self.device }).is_err() {
            return Ok(SessionEnd::Disconnected);
        }
        let mut last_activity = Instant::now();
        loop {
            let msg = match conn.recv_timeout(RECV_SLICE) {
                Ok(Some(m)) => {
                    last_activity = Instant::now();
                    m
                }
                Ok(None) => {
                    if last_activity.elapsed() >= self.idle_timeout {
                        return Ok(SessionEnd::Disconnected);
                    }
                    continue;
                }
                Err(TransportError::Closed) | Err(TransportError::Io(_)) => {
                    return Ok(SessionEnd::Disconnected)
                }
                // framing (the peer speaks garbage) and anything else
                // the transport grows are fatal, not retryable
                Err(e) => return Err(anyhow!("device {}: {e}", self.device)),
            };
            match self.on_msg(conn, msg)? {
                Step::Continue => {}
                Step::Finished => return Ok(SessionEnd::Finished),
                Step::Disconnected => return Ok(SessionEnd::Disconnected),
            }
        }
    }

    /// Handle one coordinator frame addressed to this device. The unit
    /// both [`run`](DeviceClient::run) and the fleet scheduler loop
    /// over: `run` owns the receive, the fleet owns the demux, this owns
    /// the protocol.
    pub(crate) fn on_msg<C: Conn>(&mut self, conn: &mut C, msg: WireMsg) -> Result<Step> {
        match msg {
            WireMsg::JoinAck { device, n_devices } => {
                ensure!(
                    device == self.device,
                    "joined as device {} but was acked as {device}",
                    self.device
                );
                ensure!(
                    n_devices == self.cfg.n_devices(),
                    "config skew: coordinator runs {n_devices} devices, this client \
                     was configured for {}",
                    self.cfg.n_devices()
                );
                Ok(Step::Continue)
            }
            WireMsg::StartRound(start) => self.serve_kickoff(conn, start),
            WireMsg::Finish => Ok(Step::Finished),
            WireMsg::Reject { code: reject::STALE_ROUND, .. } => {
                // a resolution of ours was buffered past its round's
                // close and refused — informational, keep serving
                self.stats.stale_rejects += 1;
                Ok(Step::Continue)
            }
            WireMsg::Reject { code, .. } => {
                Err(anyhow!("coordinator rejected device {} (code {code})", self.device))
            }
            other => Err(anyhow!(
                "device {}: unexpected frame from coordinator: {other:?}",
                self.device
            )),
        }
    }

    /// Serve one kickoff frame: answer duplicates from the redelivery
    /// cache, drop stale stragglers, train fresh rounds.
    pub(crate) fn serve_kickoff<C: Conn>(
        &mut self,
        conn: &mut C,
        start: Box<NetworkedStart>,
    ) -> Result<Step> {
        let t = start.item.t;
        let cached =
            self.resolutions.iter().find(|(rt, _)| *rt == t).map(|(_, frame)| frame.clone());
        if let Some(cached) = cached {
            // duplicate kickoff after a rejoin: answer from the cache,
            // never re-train (see module docs)
            self.stats.redeliveries += 1;
            if conn.send(&cached).is_err() {
                return Ok(Step::Disconnected);
            }
        } else if t <= self.last_round {
            // stale straggler frame beyond the redelivery cache: the
            // coordinator has long since closed that round
        } else if self.handle_start(conn, *start)?.is_none() {
            return Ok(Step::Disconnected);
        }
        Ok(Step::Continue)
    }

    /// [`run`] with reconnect-with-rejoin: when a session disconnects,
    /// dial a fresh connection and Join again (the coordinator replaces
    /// the dead connection and re-sends any pending kickoff). Gives up
    /// after `max_redials` **consecutive** fruitless attempts — any
    /// session that makes protocol progress (a completed round, a
    /// dropout resolution, a redelivery) resets the budget, so a long
    /// run survives occasional transient disconnects indefinitely.
    pub fn run_reconnecting<C: Conn>(
        &mut self,
        mut dial: impl FnMut() -> Result<C, TransportError>,
        max_redials: usize,
    ) -> Result<SessionEnd> {
        let mut redials = 0;
        loop {
            let before = self.stats;
            if let Ok(mut conn) = dial() {
                if self.run(&mut conn)? == SessionEnd::Finished {
                    return Ok(SessionEnd::Finished);
                }
            }
            let progressed = self.stats.rounds > before.rounds
                || self.stats.dropouts > before.dropouts
                || self.stats.redeliveries > before.redeliveries;
            redials = if progressed { 0 } else { redials + 1 };
            if redials > max_redials {
                return Ok(SessionEnd::Disconnected);
            }
            // capped exponential backoff with deterministic per-device
            // jitter; a progressing session restarts at the base delay
            std::thread::sleep(Duration::from_millis(redial_backoff_ms(
                self.cfg.seed,
                self.device,
                redials.max(1),
            )));
        }
    }

    /// Execute one kickoff: the remote mirror of `engine::run_device`
    /// from the post-download-encode point. Returns `Ok(None)` if the
    /// connection died mid-send (retryable), `Ok(Some(()))` on success.
    fn handle_start<C: Conn>(
        &mut self,
        conn: &mut C,
        start: NetworkedStart,
    ) -> Result<Option<()>> {
        let item = &start.item;
        let t = item.t;
        let d = self.device;
        ensure!(
            item.plan.device == d,
            "kickoff for device {} delivered to device {d}",
            item.plan.device
        );
        let scale =
            PayloadScale { n_real: self.trainer.n_params(), n_paper: self.cfg.n_params_paper };
        let down_wire_bits = start.download.bits;
        let down_bits = scale.scale_bits(down_wire_bits);

        // dropout lottery on the independent fate stream — same draw,
        // same outcome, as the in-process simulation of this device
        if start.dropout_rate > 0.0 {
            let mut fate =
                Rng::stream(start.stream_base ^ engine::FATE_SALT, t as u64, d as u64);
            if fate.f64() < start.dropout_rate {
                let download_s = down_bits / item.beta_d;
                let compute_s = (item.plan.tau * item.plan.batch) as f64 * item.mu;
                let after_s = download_s + fate.f64() * compute_s;
                if self.heartbeats(conn, start.heartbeat_s, start.sim_now_s, after_s).is_none() {
                    return Ok(None);
                }
                let resolution = WireMsg::Dropout { t, device: d, after_s, down_wire_bits };
                if conn.send(&resolution).is_err() {
                    return Ok(None);
                }
                // the local model does NOT advance on a dropout
                self.remember_resolution(t, resolution);
                self.stats.dropouts += 1;
                return Ok(Some(()));
            }
        }

        // recover against the prior the PS actually encoded for — the
        // kickoff's digest tells us which of our retained models that is
        let pick = self.pick_prior(start.prior_digest)?;
        // resume the device stream where the PS-side encode left it
        let mut dev_rng = Rng::from_state(start.rng);
        let codec = CodecEngine::native();
        let mut model = pool::f32_buf();
        let prior = pick.map(|i| self.history[i].as_slice());
        codec.recover_download_into(&start.download, prior, &mut model)?;
        let shard = &self.partition.shards[d];
        let (w_final, loss) = self.trainer.train(
            &model,
            &self.train_ds,
            shard,
            item.plan.tau,
            item.plan.batch,
            start.lr,
            &mut dev_rng,
        )?;

        let mut g = pool::f32_buf();
        g.extend(model.iter().zip(&w_final).map(|(a, b)| a - b));
        drop(model);
        let grad_norm = g.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>().sqrt();
        let up_enc = codec.encode_upload(item.plan.upload, &g, &mut dev_rng)?;
        drop(g);

        let cost = RoundCost::from_wire(
            down_wire_bits,
            up_enc.bits,
            &scale,
            item.beta_d,
            item.beta_u,
            item.plan.tau,
            item.plan.batch,
            item.mu,
        );
        if self.heartbeats(conn, start.heartbeat_s, start.sim_now_s, cost.total()).is_none() {
            return Ok(None);
        }
        let resolution = WireMsg::EndRound {
            t,
            update: Box::new(RoundUpdate {
                device: d,
                w_final: w_final.clone(),
                upload: up_enc,
                grad_norm,
                loss,
                down_wire_bits,
                cost,
            }),
        };
        if conn.send(&resolution).is_err() {
            return Ok(None);
        }
        // the ring keeps the priors recent rounds trained from — exactly
        // what the coordinator still holds for any EndRound it refuses
        // or any pipelined round it opened before this one closed
        self.history.push_front(w_final);
        self.history.truncate(HISTORY_DEPTH);
        self.remember_resolution(t, resolution);
        self.stats.rounds += 1;
        Ok(Some(()))
    }

    /// Match a kickoff's declared prior digest against the retained
    /// history ring, returning the matching entry's index (newest = 0)
    /// or `None` for a priorless recovery. The coordinator encodes
    /// downloads against its `locals[d]` — normally this client's newest
    /// model, but older when the coordinator refused an EndRound or
    /// opened a pipelined round before an earlier one closed. A digest
    /// matching nothing in the ring is genuine divergence (say, a client
    /// restart losing the retained models) and fails loudly here:
    /// training from a mismatched prior would break bit parity silently.
    fn pick_prior(&self, declared: Option<u64>) -> Result<Option<usize>> {
        let Some(dig) = declared else { return Ok(None) };
        if let Some(i) = self.history.iter().position(|l| model_digest(l) == dig) {
            return Ok(Some(i));
        }
        Err(anyhow!(
            "device {}: the coordinator's recovery prior (digest {dig:#018x}) matches \
             none of the {} retained local models — the sides have diverged (was this \
             client restarted mid-run?)",
            self.device,
            self.history.len()
        ))
    }

    /// Record a round's resolution frame in the redelivery ring and
    /// advance the high-water round marker.
    fn remember_resolution(&mut self, t: usize, frame: WireMsg) {
        self.last_round = self.last_round.max(t);
        self.resolutions.push_front((t, frame));
        self.resolutions.truncate(HISTORY_DEPTH);
    }

    /// Send the simulated-time heartbeat schedule (shared with the
    /// in-process engine via `engine::heartbeat_schedule`). `None` if
    /// the connection died mid-stream.
    fn heartbeats<C: Conn>(
        &mut self,
        conn: &mut C,
        heartbeat_s: f64,
        start_s: f64,
        duration_s: f64,
    ) -> Option<()> {
        let d = self.device;
        for sim_t_s in engine::heartbeat_schedule(heartbeat_s, start_s, duration_s) {
            if conn.send(&WireMsg::Heartbeat { device: d, sim_t_s }).is_err() {
                return None;
            }
            self.stats.heartbeats += 1;
        }
        Some(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::message::StartRound;
    use crate::fleet::FleetKind;
    use crate::schemes::{DevicePlan, DownloadCodec, UploadCodec};
    use crate::wire::Payload;
    use std::collections::VecDeque;
    use std::sync::Arc;

    fn tiny_client() -> DeviceClient {
        let mut cfg = ExperimentConfig::preset("har");
        cfg.trainer = TrainerBackend::Native;
        cfg.compression = CompressionBackend::Native;
        cfg.fleet = FleetKind::JetsonScaled(4);
        cfg.n_train = 240;
        cfg.n_test = 80;
        DeviceClient::new(cfg, 0).unwrap()
    }

    #[test]
    fn pick_prior_matches_any_ring_entry_and_fails_on_divergence() {
        let mut client = tiny_client();
        let models: Vec<Vec<f32>> =
            (0..HISTORY_DEPTH as i32).map(|i| vec![i as f32, 2.0, 3.0]).collect();
        for m in &models {
            client.history.push_front(m.clone());
        }
        // newest first: models[3] is at index 0
        assert_eq!(client.pick_prior(None).unwrap(), None);
        for (i, m) in models.iter().rev().enumerate() {
            assert_eq!(client.pick_prior(Some(model_digest(m))).unwrap(), Some(i));
        }
        let err = client.pick_prior(Some(0xBAD)).unwrap_err();
        assert!(format!("{err}").contains("diverged"), "{err}");

        // a model pushed out of the capped ring no longer matches
        client.history.push_front(vec![9.0f32, 9.0, 9.0]);
        client.history.truncate(HISTORY_DEPTH);
        assert!(client.pick_prior(Some(model_digest(&models[0]))).is_err());

        // a fresh client (no retained models) must refuse any Some digest
        client.history.clear();
        assert!(client.pick_prior(Some(model_digest(&models[0]))).is_err());
        assert_eq!(client.pick_prior(None).unwrap(), None);
    }

    #[test]
    fn redelivery_ring_covers_several_rounds_and_is_capped() {
        let mut client = tiny_client();
        for t in 1..=HISTORY_DEPTH + 2 {
            client.remember_resolution(
                t,
                WireMsg::Dropout { t, device: 0, after_s: t as f64, down_wire_bits: 64 },
            );
        }
        assert_eq!(client.last_round, HISTORY_DEPTH + 2);
        assert_eq!(client.resolutions.len(), HISTORY_DEPTH);
        // the newest HISTORY_DEPTH rounds are answerable, older ones gone
        for t in 3..=HISTORY_DEPTH + 2 {
            assert!(client.resolutions.iter().any(|(rt, _)| *rt == t), "round {t} evicted");
        }
        assert!(!client.resolutions.iter().any(|(rt, _)| *rt == 1));
    }

    /// A [`Conn`] that replays a scripted receive sequence and accepts
    /// every send; once the script runs dry it reports Closed.
    struct ScriptedConn {
        script: VecDeque<Result<Option<WireMsg>, TransportError>>,
    }

    impl Conn for ScriptedConn {
        fn send(&mut self, _msg: &WireMsg) -> Result<(), TransportError> {
            Ok(())
        }
        fn recv_timeout(
            &mut self,
            _timeout: Duration,
        ) -> Result<Option<WireMsg>, TransportError> {
            match self.script.pop_front() {
                Some(r) => r,
                None => Err(TransportError::Closed),
            }
        }
        fn peer(&self) -> String {
            "scripted".into()
        }
    }

    fn duplicate_kickoff(t: usize) -> WireMsg {
        WireMsg::StartRound(Box::new(NetworkedStart {
            item: StartRound {
                t,
                plan: DevicePlan {
                    device: 0,
                    download: DownloadCodec::Full,
                    upload: UploadCodec::Full,
                    batch: 8,
                    tau: 1,
                },
                beta_d: 1e6,
                beta_u: 1e6,
                mu: 1e-4,
            },
            lr: 0.1,
            rng: Rng::new(1).state(),
            stream_base: 0,
            dropout_rate: 0.0,
            heartbeat_s: 0.0,
            sim_now_s: 0.0,
            prior_digest: None,
            download: Arc::new(Payload::Dense(vec![0.0f32; 4]).encode()),
        }))
    }

    #[test]
    fn redial_backoff_is_deterministic_bounded_and_capped() {
        for attempt in 1..=12 {
            let nominal = (REDIAL_BASE_MS << (attempt as u32 - 1).min(7)).min(REDIAL_CAP_MS);
            let a = redial_backoff_ms(0xCAE5, 3, attempt);
            // deterministic: the same (seed, device, attempt) always
            // draws the same jitter
            assert_eq!(a, redial_backoff_ms(0xCAE5, 3, attempt));
            // jitter bounded in [nominal, 3·nominal/2]
            assert!(a >= nominal && a <= nominal + nominal / 2, "attempt {attempt}: {a}");
        }
        // the exponential growth is capped
        assert!(redial_backoff_ms(1, 0, 40) <= REDIAL_CAP_MS + REDIAL_CAP_MS / 2);
        // different devices de-sync even at the same attempt (for this
        // seed; the jitter range at attempt 7 is wide enough to check)
        let spread: std::collections::BTreeSet<u64> =
            (0..16).map(|d| redial_backoff_ms(7, d, 7)).collect();
        assert!(spread.len() > 1, "all devices drew identical jitter");
    }

    #[test]
    fn redial_backoff_restarts_from_base_after_progress_reset() {
        // run_reconnecting passes redials.max(1): after a progress reset
        // (redials = 0) the next fruitless attempt is attempt 1 again
        let late = redial_backoff_ms(2, 5, 5);
        let reset = redial_backoff_ms(2, 5, 1);
        assert!(reset >= REDIAL_BASE_MS && reset <= REDIAL_BASE_MS + REDIAL_BASE_MS / 2);
        assert!(late > reset, "attempt 5 ({late}ms) should dwarf attempt 1 ({reset}ms)");
    }

    #[test]
    fn redial_budget_bounds_consecutive_fruitless_attempts() {
        let mut client = tiny_client();
        let mut dials = 0usize;
        let end = client
            .run_reconnecting(
                || {
                    dials += 1;
                    Ok(ScriptedConn { script: VecDeque::new() })
                },
                3,
            )
            .unwrap();
        assert_eq!(end, SessionEnd::Disconnected);
        // the initial attempt plus max_redials fruitless redials
        assert_eq!(dials, 4);
    }

    #[test]
    fn sessions_that_progress_reset_the_redial_budget() {
        let mut client = tiny_client();
        // pretend round 1 already resolved so a duplicate kickoff is
        // answered from the redelivery cache (= protocol progress)
        client.remember_resolution(
            1,
            WireMsg::Dropout { t: 1, device: 0, after_s: 0.5, down_wire_bits: 64 },
        );
        let n = client.cfg.n_devices();

        let mut dials = 0usize;
        let end = client
            .run_reconnecting(
                || {
                    dials += 1;
                    let mut script: VecDeque<Result<Option<WireMsg>, TransportError>> =
                        VecDeque::new();
                    script.push_back(Ok(Some(WireMsg::JoinAck { device: 0, n_devices: n })));
                    if dials <= 6 {
                        // a redelivery, then the connection dies: with a
                        // budget of 1 consecutive failure, only the
                        // progress reset keeps 6 of these alive
                        script.push_back(Ok(Some(duplicate_kickoff(1))));
                    } else {
                        script.push_back(Ok(Some(WireMsg::Finish)));
                    }
                    Ok(ScriptedConn { script })
                },
                1,
            )
            .unwrap();
        assert_eq!(end, SessionEnd::Finished);
        assert_eq!(dials, 7);
        assert_eq!(client.stats.redeliveries, 6);
    }
}

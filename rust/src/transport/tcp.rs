//! Tcp transport: framed `std::net::TcpStream`, std-only.
//!
//! The coordinator binds a non-blocking listener and — on unix — waits
//! for accepts and bytes with `poll(2)` (see [`super::readiness`]):
//! there is no sleep-poll anywhere in the unix serving path. Each
//! connection may carry one device session (connection-per-device) or a
//! whole fleet's worth (frames are device-tagged; the server routes by
//! id, not socket). Streams run with `TCP_NODELAY` (frames are
//! latency-sensitive and already batched) and bounded read/write
//! timeouts, and the receive path keeps an incremental buffer: a frame
//! may arrive split across arbitrarily many reads, and partial bytes
//! survive timeouts intact — [`frame::decode_frame`]'s `Truncated`
//! error is the "keep reading" signal, any other decode error poisons
//! the connection.
//!
//! A conn toggles between blocking mode (client-side `recv_timeout`
//! slices) and non-blocking mode (server-side reactor `try_recv`); the
//! mode is cached so the fcntl only runs on transitions. In
//! non-blocking mode `send` handles partial writes itself, waiting on
//! *write-readiness* (`poll(2)` `POLLOUT`) within the write deadline —
//! never a fixed-length nap.

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::time::{Duration, Instant};

use super::frame::{self, WireMsg};
use super::readiness::RawSource;
use super::{Conn, Transport, TransportError};

#[cfg(unix)]
use super::readiness::sys;
#[cfg(unix)]
use std::os::unix::io::AsRawFd;

/// Granularity of the non-blocking accept poll — **non-unix fallback
/// only**; the unix path blocks in `poll(2)` until the listener is
/// actually readable.
#[cfg(not(unix))]
const ACCEPT_POLL: Duration = Duration::from_millis(5);
/// Cap on a single blocking read's timeout, so `recv_timeout` can honor
/// deadlines shorter or longer than any one socket wait.
const READ_SLICE: Duration = Duration::from_millis(100);
/// Write timeout: a peer that cannot drain a frame in this long is dead.
const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Coordinator-side listener.
pub struct TcpTransport {
    listener: TcpListener,
    addr: SocketAddr,
}

impl TcpTransport {
    /// Bind and start listening. `addr` may be `"127.0.0.1:0"` to let
    /// the OS pick an ephemeral port (see [`TcpTransport::local_addr`]).
    pub fn bind<A: ToSocketAddrs>(addr: A) -> Result<TcpTransport, TransportError> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        Ok(TcpTransport { listener, addr })
    }

    /// The bound socket address (resolves ephemeral ports).
    pub fn socket_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Transport for TcpTransport {
    type Conn = TcpConn;

    fn accept_timeout(&mut self, timeout: Duration) -> Result<Option<TcpConn>, TransportError> {
        let deadline = Instant::now() + timeout;
        loop {
            match self.listener.accept() {
                Ok((stream, peer)) => return Ok(Some(TcpConn::from_stream(stream, peer)?)),
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    let now = Instant::now();
                    if now >= deadline {
                        return Ok(None);
                    }
                    // wait for accept-readiness, not a timer
                    #[cfg(unix)]
                    sys::wait_readable(self.listener.as_raw_fd(), deadline - now)?;
                    #[cfg(not(unix))]
                    std::thread::sleep(ACCEPT_POLL.min(deadline - now));
                }
                // a non-WouldBlock accept failure is the listener itself
                // breaking (fd exhaustion, interface death) — surface it
                // typed instead of busy-polling past it like a timeout
                Err(e) => return Err(TransportError::Accept(e)),
            }
        }
    }

    fn listener_source(&self) -> RawSource {
        #[cfg(unix)]
        {
            RawSource::Fd(self.listener.as_raw_fd())
        }
        #[cfg(not(unix))]
        {
            RawSource::Unready
        }
    }

    fn local_addr(&self) -> String {
        self.addr.to_string()
    }
}

/// One framed Tcp connection (either side).
pub struct TcpConn {
    stream: TcpStream,
    /// Bytes received but not yet decoded — a frame boundary rarely
    /// coincides with a read boundary.
    rbuf: Vec<u8>,
    /// Cached O_NONBLOCK state so mode flips cost a syscall only on
    /// actual transitions (reactor `try_recv` ↔ blocking `recv_timeout`).
    nonblocking: bool,
    peer: String,
}

impl TcpConn {
    /// Dial a coordinator.
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<TcpConn, TransportError> {
        let stream = TcpStream::connect(addr)?;
        let peer = stream.peer_addr()?;
        Self::from_stream(stream, peer)
    }

    fn from_stream(stream: TcpStream, peer: SocketAddr) -> Result<TcpConn, TransportError> {
        stream.set_nodelay(true)?;
        stream.set_write_timeout(Some(WRITE_TIMEOUT))?;
        Ok(TcpConn { stream, rbuf: Vec::new(), nonblocking: false, peer: peer.to_string() })
    }

    fn set_mode(&mut self, nonblocking: bool) -> Result<(), TransportError> {
        if self.nonblocking != nonblocking {
            self.stream.set_nonblocking(nonblocking)?;
            self.nonblocking = nonblocking;
        }
        Ok(())
    }

    /// Decode one frame out of `rbuf` if a complete one is buffered.
    fn decode_buffered(&mut self) -> Result<Option<WireMsg>, TransportError> {
        match frame::decode_frame(&self.rbuf) {
            Ok((msg, used)) => {
                self.rbuf.drain(..used);
                Ok(Some(msg))
            }
            Err(e) if e.is_incomplete() => Ok(None),
            Err(e) => Err(TransportError::Frame(e)),
        }
    }

    /// Write the whole buffer within [`WRITE_TIMEOUT`], handling the
    /// partial writes a non-blocking stream produces by waiting on
    /// write-readiness (unix) or a bounded growing backoff (elsewhere)
    /// — never a fixed-length nap.
    fn write_deadline(&mut self, bytes: &[u8]) -> Result<(), TransportError> {
        let deadline = Instant::now() + WRITE_TIMEOUT;
        let mut off = 0;
        #[cfg(not(unix))]
        let mut backoff = Duration::from_micros(50);
        while off < bytes.len() {
            match self.stream.write(&bytes[off..]) {
                Ok(0) => {
                    return Err(TransportError::Io(std::io::Error::new(
                        ErrorKind::WriteZero,
                        "socket accepted zero bytes",
                    )))
                }
                Ok(k) => off += k,
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut =>
                {
                    let now = Instant::now();
                    if now >= deadline {
                        return Err(TransportError::Io(std::io::Error::new(
                            ErrorKind::TimedOut,
                            "peer cannot drain a frame within the write timeout",
                        )));
                    }
                    #[cfg(unix)]
                    sys::wait_writable(self.stream.as_raw_fd(), deadline - now)?;
                    #[cfg(not(unix))]
                    {
                        std::thread::sleep(backoff.min(deadline - now));
                        backoff = (backoff * 2).min(Duration::from_millis(5));
                    }
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(TransportError::Io(e)),
            }
        }
        Ok(())
    }
}

impl Conn for TcpConn {
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError> {
        let bytes = frame::encode_frame(msg);
        self.write_deadline(&bytes)
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        self.set_mode(false)?;
        let deadline = Instant::now() + timeout;
        loop {
            // a complete frame may already be buffered
            if let Some(msg) = self.decode_buffered()? {
                return Ok(Some(msg));
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(None); // partial bytes stay in rbuf
            }
            let slice = (deadline - now).min(READ_SLICE).max(Duration::from_millis(1));
            self.stream.set_read_timeout(Some(slice))?;
            let mut tmp = [0u8; 64 * 1024];
            match self.stream.read(&mut tmp) {
                Ok(0) => return Err(TransportError::Closed),
                Ok(k) => self.rbuf.extend_from_slice(&tmp[..k]),
                Err(e)
                    if e.kind() == ErrorKind::WouldBlock
                        || e.kind() == ErrorKind::TimedOut
                        || e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(TransportError::Io(e)),
            }
        }
    }

    fn try_recv(&mut self) -> Result<Option<WireMsg>, TransportError> {
        loop {
            if let Some(msg) = self.decode_buffered()? {
                return Ok(Some(msg));
            }
            // genuinely non-blocking: pull whatever the kernel has,
            // return None the moment it has nothing
            self.set_mode(true)?;
            let mut tmp = [0u8; 64 * 1024];
            match self.stream.read(&mut tmp) {
                Ok(0) => return Err(TransportError::Closed),
                Ok(k) => self.rbuf.extend_from_slice(&tmp[..k]),
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(None),
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(TransportError::Io(e)),
            }
        }
    }

    fn source(&self) -> RawSource {
        #[cfg(unix)]
        {
            RawSource::Fd(self.stream.as_raw_fd())
        }
        #[cfg(not(unix))]
        {
            RawSource::Unready
        }
    }

    fn peer(&self) -> String {
        self.peer.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ephemeral_bind_dial_and_roundtrip() {
        let mut lst = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = lst.socket_addr();
        let handle = std::thread::spawn(move || {
            let mut c = TcpConn::connect(addr).unwrap();
            c.send(&WireMsg::Join { device: 5 }).unwrap();
            match c.recv_timeout(Duration::from_secs(5)).unwrap() {
                Some(WireMsg::JoinAck { device: 5, n_devices: 9 }) => {}
                other => panic!("{other:?}"),
            }
        });
        let mut sconn = lst
            .accept_timeout(Duration::from_secs(5))
            .unwrap()
            .expect("client should connect");
        match sconn.recv_timeout(Duration::from_secs(5)).unwrap() {
            Some(WireMsg::Join { device: 5 }) => {}
            other => panic!("{other:?}"),
        }
        sconn.send(&WireMsg::JoinAck { device: 5, n_devices: 9 }).unwrap();
        handle.join().unwrap();
    }

    #[test]
    fn split_writes_reassemble_into_one_frame() {
        let mut lst = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = lst.socket_addr();
        let frame_bytes = frame::encode_frame(&WireMsg::Heartbeat { device: 2, sim_t_s: 4.5 });
        let handle = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            // dribble the frame a few bytes at a time across the socket
            for chunk in frame_bytes.chunks(3) {
                s.write_all(chunk).unwrap();
                s.flush().unwrap();
                std::thread::sleep(Duration::from_millis(2));
            }
        });
        let mut sconn = lst.accept_timeout(Duration::from_secs(5)).unwrap().unwrap();
        // short timeouts in between must preserve the partial bytes
        let mut got = None;
        for _ in 0..500 {
            if let Some(m) = sconn.recv_timeout(Duration::from_millis(10)).unwrap() {
                got = Some(m);
                break;
            }
        }
        match got {
            Some(WireMsg::Heartbeat { device: 2, sim_t_s }) => assert_eq!(sim_t_s, 4.5),
            other => panic!("{other:?}"),
        }
        handle.join().unwrap();
    }

    #[test]
    fn try_recv_pulls_fresh_bytes_and_mode_flips_are_reversible() {
        let mut lst = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = lst.socket_addr();
        let handle = std::thread::spawn(move || {
            let mut c = TcpConn::connect(addr).unwrap();
            c.send(&WireMsg::Join { device: 3 }).unwrap();
            std::thread::sleep(Duration::from_millis(30));
            c.send(&WireMsg::Heartbeat { device: 3, sim_t_s: 1.0 }).unwrap();
        });
        let mut sconn = lst.accept_timeout(Duration::from_secs(5)).unwrap().unwrap();
        // the Join arrives eventually; try_recv must find it without blocking
        let mut got_join = false;
        for _ in 0..500 {
            match sconn.try_recv().unwrap() {
                Some(WireMsg::Join { device: 3 }) => {
                    got_join = true;
                    break;
                }
                Some(other) => panic!("{other:?}"),
                None => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        assert!(got_join);
        // back to a blocking receive on the same conn for the heartbeat
        let mut got_hb = false;
        for _ in 0..100 {
            if let Some(WireMsg::Heartbeat { device: 3, .. }) =
                sconn.recv_timeout(Duration::from_millis(50)).unwrap()
            {
                got_hb = true;
                break;
            }
        }
        assert!(got_hb, "mode flip back to blocking must still deliver frames");
        handle.join().unwrap();
    }

    #[test]
    fn garbage_bytes_poison_the_connection_without_panic() {
        let mut lst = TcpTransport::bind("127.0.0.1:0").unwrap();
        let addr = lst.socket_addr();
        let handle = std::thread::spawn(move || {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GETS / HTTP/1.1\r\n\r\n").unwrap();
        });
        let mut sconn = lst.accept_timeout(Duration::from_secs(5)).unwrap().unwrap();
        let mut saw_err = false;
        for _ in 0..200 {
            match sconn.recv_timeout(Duration::from_millis(10)) {
                Ok(Some(m)) => panic!("decoded {m:?} from garbage"),
                Ok(None) => {}
                Err(TransportError::Frame(_)) => {
                    saw_err = true;
                    break;
                }
                Err(_) => {
                    saw_err = true;
                    break;
                }
            }
        }
        assert!(saw_err, "garbage should surface as a framing error");
        handle.join().unwrap();
    }
}

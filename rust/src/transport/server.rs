//! Coordinator service: `coordinator::Server` + `engine::Engine` driven
//! by decoded transport frames.
//!
//! Generic over [`Transport`], so the same service runs the Loopback
//! parity baseline and real Tcp sessions. Per round it asks the server
//! for a networked kickoff (`begin_networked_round` — plans, encoded
//! downloads and per-device RNG resume states), sends one StartRound
//! frame per participant, then serves the wait-set until all
//! participants resolve. The canonical aggregation in
//! `Engine::finish_external` and the shared `Server::apply_round` make
//! the result bit-identical to the in-process `Server::run` path — the
//! invariant `tests/transport_parity.rs` pins across Loopback, Tcp and
//! fleet-multiplexed Tcp.
//!
//! **Readiness, not polling.** The serving loop blocks in one
//! [`Reactor`] wait over the listener plus every live connection
//! (`poll(2)` on unix, waker keys for Loopback, threaded readers for
//! anything else — see [`super::readiness`]) and wakes only when bytes
//! or accepts are ready. There is no per-connection receive poll and no
//! `thread::sleep` anywhere in this serving path: wakeups scale with
//! frames delivered, not elapsed-time × connections (the reactor's
//! wakeup counter, surfaced by `bench_transport`'s `fleet_mux` case,
//! records exactly this).
//!
//! **Demux routing.** Sessions are keyed by the device id each frame
//! carries, never by the socket it arrived on: one connection may carry
//! a single `DeviceClient` or a whole [`super::fleet::DeviceFleet`]'s
//! device range. The connection table ([`Slots`]) holds anonymous
//! transport endpoints; the registry holds the device→connection
//! binding (`Registry::bind_conn`, many-to-one), established per device
//! by its Join frame. A frame naming a device not bound to its
//! connection is a protocol violation.
//!
//! **Fault handling — death vs poison.** A connection that dies cleanly
//! (reset, close) mid-round keeps ALL its devices pending — each may
//! reconnect, re-Join and receive its kickoffs again
//! (*reconnect-with-rejoin*), and stragglers still pending at the
//! wall-clock round deadline convert to protocol `Dropout`s. A
//! connection that turns hostile (framing garbage, frames for devices
//! it never identified, messages only a coordinator may send) is
//! *poisoned*: it is cut immediately and every device multiplexed on it
//! converts to a synthesized Dropout in every open round right away —
//! the peer holding their sessions has proven it cannot be spoken to,
//! so waiting out the deadline would only stall the fleet. Either way
//! the synthesized message bits are identical (`after_s = 0`, the
//! round's booked download bill), so timing never leaks into simulated
//! state. A resolution frame whose round number matches no open round —
//! or a duplicate for a device that already resolved — is refused with
//! [`reject::STALE_ROUND`] and never reaches the engine.
//!
//! With `pipeline-depth` > 1 (or `staleness-bound` > 0) the service
//! runs the semi-async schedule: up to D rounds are open at once (their
//! kickoffs all on the wire) and resolution frames route to whichever
//! open round they are tagged with. The barrier schedule is the same
//! loop over a one-round window; only the close differs
//! (`finish_external` vs `Server::close_pipelined`).
//!
//! The registry's liveness sweep (`Engine::sweep_expired`) is exposed as
//! [`CoordinatorService::sweep_expired`] but NOT run automatically:
//! under the synchronous barrier, devices only heartbeat while executing
//! a kickoff, so simulated-time silence is the *normal* state of a
//! healthy connected non-participant — a blanket sweep would mark such
//! devices Dropped and inflate dropout diagnostics. In-round stragglers
//! are already evicted by the deadline conversion above; the explicit
//! hook is for future asynchronous drivers whose devices heartbeat
//! continuously.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::{self, RoundOutcome, RoundRecord, RunResult, Server};
use crate::engine::{DeviceMsg, ExternalRound, StartRound};
use crate::journal::RunJournal;

use super::frame::{reject, WireMsg};
use super::readiness::{RawSource, Reactor, ThreadedReader};
use super::{Conn, Transport, TransportError};

/// How long a freshly accepted connection gets to identify at least one
/// device with a Join frame before being dropped.
const IDENTIFY_TIMEOUT: Duration = Duration::from_secs(2);

/// Waker-key base for threaded-reader fallbacks, far above any key a
/// transport mints for its own conns (Loopback starts at 1).
const PUMP_KEY_BASE: u64 = 1 << 32;

/// A networked FL coordinator session over one [`Transport`].
pub struct CoordinatorService<T: Transport> {
    server: Server,
    transport: T,
    /// Anonymous live connections, token-indexed. Which devices ride
    /// each connection lives in the registry (`bind_conn`), because the
    /// relation is many-to-one under fleet multiplexing.
    conns: Slots<T::Conn>,
    /// The one wait-set the serving loop blocks on.
    reactor: Reactor,
    /// Key mint for threaded-reader-wrapped conns.
    next_pump_key: u64,
    /// Wall-clock budget per round before stragglers become Dropouts.
    pub round_timeout: Duration,
}

/// What one reactor pump observed, in arrival order.
enum Event {
    /// A device identified itself (fresh-conn Join or in-band re-Join):
    /// its binding is updated; open rounds re-kick it if pending.
    Joined(usize),
    /// A frame from an identified device (Heartbeat/EndRound/Dropout).
    Frame(usize, WireMsg),
    /// A connection died cleanly with these devices bound: they stay
    /// pending (rejoin-with-redelivery or the deadline resolves them).
    ConnDied(Vec<usize>),
    /// A connection was poisoned (garbage frames, protocol violations)
    /// with these devices bound: ALL of them convert to synthesized
    /// Dropouts in every open round, immediately.
    ConnPoisoned(Vec<usize>),
}

impl<T: Transport> CoordinatorService<T> {
    pub fn new(server: Server, transport: T) -> CoordinatorService<T> {
        let reactor = Reactor::new(transport.waker());
        CoordinatorService {
            server,
            transport,
            conns: Slots::new(),
            reactor,
            next_pump_key: PUMP_KEY_BASE,
            round_timeout: Duration::from_secs(120),
        }
    }

    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Hand the server back (post-run inspection: model, traffic, stats).
    pub fn into_server(self) -> Server {
        self.server
    }

    /// The transport's listen address (resolves ephemeral Tcp ports).
    pub fn local_addr(&self) -> String {
        self.transport.local_addr()
    }

    /// Number of identified device sessions (NOT connections — a fleet
    /// binds many devices to one connection).
    pub fn connected(&self) -> usize {
        self.server.engine().registry().bound_count()
    }

    /// Times the serving reactor has woken — with precise readiness
    /// this scales with frames delivered plus deadline expiries, not
    /// with elapsed-time × connections.
    pub fn wakeups(&self) -> u64 {
        self.reactor.wakeups()
    }

    /// Accept + identify connections until `expect` devices are bound
    /// or `timeout` elapses (error). Call before [`run`]: the first
    /// round kicks off immediately. Rendezvous-phase Joins only bind
    /// transport routes — the engine first hears of a device when a
    /// round selects it, so the census never counts connected-but-
    /// unselected devices as joined.
    pub fn wait_for_devices(&mut self, expect: usize, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        let mut events = Vec::new();
        while self.connected() < expect {
            let now = Instant::now();
            if now >= deadline {
                return Err(anyhow!(
                    "{} of {expect} devices connected before the rendezvous timeout",
                    self.connected()
                ));
            }
            events.clear();
            self.pump(deadline - now, &mut events)?;
            // Joined events already bound their routes in `on_frame`;
            // any other pre-round frame is dropped here.
        }
        Ok(())
    }

    /// Execute the full run: rounds 1..=cfg.rounds over the transport,
    /// evaluation/records identical to `Server::run_cb`, then a Finish
    /// broadcast so devices disconnect cleanly.
    pub fn run_cb(&mut self, mut cb: impl FnMut(&RoundRecord)) -> Result<RunResult> {
        if self.server.pipelined() {
            return self.run_pipelined(None, cb);
        }
        let rounds = self.server.cfg.rounds;
        let mut records = Vec::with_capacity(rounds);
        let mut reached: Option<(usize, f64, f64)> = None;
        for t in 1..=rounds {
            let (outcome, _) = self.round_networked(t, None)?;
            let rec = self.server.observe_round(t, &outcome, &mut reached)?;
            cb(&rec);
            records.push(rec);
        }
        self.broadcast_finish();
        Ok(self.server.finish_run(records, reached))
    }

    /// [`run_cb`] without a progress observer.
    pub fn run(&mut self) -> Result<RunResult> {
        self.run_cb(|_| {})
    }

    /// [`run_cb`] with every coordinator decision event-sourced through
    /// `jw` — the networked twin of `Server::run_journaled_cb`. Records
    /// are written in canonical order (round open sorted by device,
    /// resolutions in fold order), so a networked run's journal is
    /// byte-identical to the in-process loop's for the same seed and
    /// arrival outcome — and a journal written here resumes on either
    /// path.
    pub fn run_journaled_cb(
        &mut self,
        jw: &mut RunJournal,
        mut cb: impl FnMut(&RoundRecord),
    ) -> Result<RunResult> {
        if jw.is_fresh() {
            jw.append(&self.server.record_header(jw.snapshot_every()))?;
            jw.append(&self.server.journal_snapshot(0))?;
        }
        if self.server.pipelined() {
            return self.run_pipelined(Some(jw), cb);
        }
        let mut records = jw.take_prior_records();
        let mut reached = self.server.recompute_reached(&records);
        let rounds = self.server.cfg.rounds;
        for t in records.len() + 1..=rounds {
            let (outcome, completers) = self.round_networked(t, Some(jw))?;
            let rec = self.server.observe_round(t, &outcome, &mut reached)?;
            jw.append(&self.server.record_close(t, completers, &rec))?;
            if jw.due_snapshot(t) {
                jw.append(&self.server.journal_snapshot(t))?;
            }
            cb(&rec);
            records.push(rec);
        }
        self.broadcast_finish();
        Ok(self.server.finish_run(records, reached))
    }

    /// Evict devices whose last simulated-time heartbeat is stale (see
    /// the module docs for why this is NOT called automatically: under
    /// the synchronous barrier only kickoff-executing devices heartbeat,
    /// so a blanket sweep would misclassify healthy idle devices).
    /// Returns the evicted device ids.
    pub fn sweep_expired(&mut self) -> Vec<usize> {
        let now_s = self.server.sim_time_s();
        self.server.engine_mut().sweep_expired(now_s)
    }

    /// One Finish frame per *connection* — a fleet's devices all hear
    /// it through their shared socket.
    fn broadcast_finish(&mut self) {
        for slot in self.conns.iter_mut() {
            let _ = slot.conn.send(&WireMsg::Finish);
        }
    }

    // -----------------------------------------------------------------
    // the reactor pump: accept + drain, demux into events
    // -----------------------------------------------------------------

    /// One serving cycle: block on the wait-set (at most `wait`), drain
    /// the accept queue and every readable connection, and append the
    /// decoded per-device events in arrival order. Join frames are
    /// handled here (JoinAck + route binding); everything else is
    /// returned for the round loops to route.
    fn pump(&mut self, wait: Duration, events: &mut Vec<Event>) -> Result<()> {
        // cap the block while unidentified conns exist so their
        // identify deadline fires without needing an event
        let wait =
            if self.conns.unidentified > 0 { wait.min(IDENTIFY_TIMEOUT) } else { wait };
        let listener = self.transport.listener_source();
        let sources = self.conns.sources();
        let wake = self
            .reactor
            .wait(listener, &sources, wait)
            .map_err(|e| anyhow!("reactor wait: {e}"))?;
        let mut fresh = Vec::new();
        if wake.accept || wake.sweep {
            while let Some(conn) = self
                .transport
                .accept_timeout(Duration::ZERO)
                .map_err(|e| anyhow!("accept: {e}"))?
            {
                fresh.push(self.add_conn(conn));
            }
        }
        // Freshly accepted conns are drained once unconditionally: a
        // frame (and its wake key) may have raced ahead of the conn's
        // registration in the wait-set, and the key for data already
        // visible now may have just been discarded as unknown.
        for token in fresh {
            self.drain_conn(token, events)?;
        }
        let tokens = if wake.sweep { self.conns.tokens() } else { wake.ready };
        for token in tokens {
            self.drain_conn(token, events)?;
        }
        self.expire_unidentified();
        Ok(())
    }

    /// Register an accepted connection, wrapping readiness-less conns
    /// in the threaded-reader fallback so the wait-set stays precise.
    fn add_conn(&mut self, conn: T::Conn) -> u64 {
        let served = if conn.source() == RawSource::Unready {
            let key = self.next_pump_key;
            self.next_pump_key += 1;
            Served::Pumped(ThreadedReader::new(conn, key, Arc::clone(self.reactor.waker())))
        } else {
            Served::Direct(conn)
        };
        self.conns.add(served)
    }

    /// Pull every complete frame the connection has buffered right now.
    fn drain_conn(&mut self, token: u64, events: &mut Vec<Event>) -> Result<()> {
        loop {
            let Some(slot) = self.conns.get_mut(token) else { return Ok(()) };
            match slot.conn.try_recv() {
                Ok(None) => return Ok(()),
                Ok(Some(msg)) => self.on_frame(token, msg, events)?,
                Err(TransportError::Frame(_)) => {
                    // garbage on the wire: the peer is poisoned
                    let devices = self.drop_conn(token);
                    if !devices.is_empty() {
                        events.push(Event::ConnPoisoned(devices));
                    }
                    return Ok(());
                }
                Err(_) => {
                    // clean death: devices stay pending for a rejoin
                    let devices = self.drop_conn(token);
                    if !devices.is_empty() {
                        events.push(Event::ConnDied(devices));
                    }
                    return Ok(());
                }
            }
        }
    }

    /// Demux one decoded frame from connection `token`.
    fn on_frame(&mut self, token: u64, msg: WireMsg, events: &mut Vec<Event>) -> Result<()> {
        match msg {
            WireMsg::Join { device } => {
                if !self.server.engine().registry().contains(device) {
                    // refuse the id but KEEP the connection: a fleet's
                    // other (valid) devices may ride the same socket
                    if let Some(slot) = self.conns.get_mut(token) {
                        let _ = slot
                            .conn
                            .send(&WireMsg::Reject { device, code: reject::UNKNOWN_DEVICE });
                    }
                    return Ok(());
                }
                let n = self.server.cfg.n_devices();
                let acked = match self.conns.get_mut(token) {
                    Some(slot) => {
                        slot.conn.send(&WireMsg::JoinAck { device, n_devices: n }).is_ok()
                    }
                    None => false,
                };
                if !acked {
                    let devices = self.drop_conn(token);
                    if !devices.is_empty() {
                        events.push(Event::ConnDied(devices));
                    }
                    return Ok(());
                }
                // binding replaces any previous route (rejoin from a
                // fresh connection)
                self.server.engine_mut().bind_conn(device, token);
                self.conns.mark_identified(token);
                events.push(Event::Joined(device));
            }
            WireMsg::Heartbeat { .. } | WireMsg::EndRound { .. } | WireMsg::Dropout { .. } => {
                let d = msg.device().expect("heartbeat/endround/dropout name a device");
                if self.server.engine().registry().conn_of(d) != Some(token) {
                    // a frame for a device this connection never
                    // identified: protocol violation, poison the conn
                    if let Some(slot) = self.conns.get_mut(token) {
                        let _ = slot
                            .conn
                            .send(&WireMsg::Reject { device: d, code: reject::BAD_STATE });
                    }
                    let devices = self.drop_conn(token);
                    if !devices.is_empty() {
                        events.push(Event::ConnPoisoned(devices));
                    }
                    return Ok(());
                }
                events.push(Event::Frame(d, msg));
            }
            other => {
                // JoinAck / StartRound / Reject / Finish: only a
                // coordinator sends these — the peer is poisoned
                let d = other.device().unwrap_or(0);
                if let Some(slot) = self.conns.get_mut(token) {
                    let _ =
                        slot.conn.send(&WireMsg::Reject { device: d, code: reject::BAD_STATE });
                }
                let devices = self.drop_conn(token);
                if !devices.is_empty() {
                    events.push(Event::ConnPoisoned(devices));
                }
            }
        }
        Ok(())
    }

    /// Remove a connection and sever every device bound to it (returned
    /// ascending) — one socket's death is a whole fleet's death.
    fn drop_conn(&mut self, token: u64) -> Vec<usize> {
        self.conns.remove(token);
        self.server.engine_mut().unbind_conn(token)
    }

    /// Drop unidentified connections older than [`IDENTIFY_TIMEOUT`].
    fn expire_unidentified(&mut self) {
        if self.conns.unidentified == 0 {
            return;
        }
        for token in self.conns.tokens() {
            if let Some(slot) = self.conns.get(token) {
                if !slot.identified && slot.accepted_at.elapsed() > IDENTIFY_TIMEOUT {
                    self.drop_conn(token); // nothing bound: nothing severed
                }
            }
        }
    }

    /// Send `msg` to the connection `d`'s session rides. `false` if the
    /// device is unbound or the send failed (the connection is dropped;
    /// `d` and any fleet-mates stay pending for rejoin or deadline).
    fn send_to_device(&mut self, d: usize, msg: &WireMsg) -> bool {
        let Some(token) = self.server.engine().registry().conn_of(d) else {
            return false;
        };
        let Some(slot) = self.conns.get_mut(token) else {
            // stale binding (should not happen — drops unbind eagerly)
            self.server.engine_mut().unbind_conn(token);
            return false;
        };
        if slot.conn.send(msg).is_ok() {
            return true;
        }
        self.drop_conn(token);
        false
    }

    // -----------------------------------------------------------------
    // round driving: one loop for barrier and pipelined schedules
    // -----------------------------------------------------------------

    /// One barrier round: a one-round window through the shared serving
    /// loop, then the canonical `finish_external` aggregation and
    /// application. With a journal, the round-open record goes out
    /// before any kickoff frame and the fold-order resolutions after
    /// the round drains (both before `apply_round` mutates the server).
    /// Returns the outcome and the completer count.
    fn round_networked(
        &mut self,
        t: usize,
        mut jw: Option<&mut RunJournal>,
    ) -> Result<(RoundOutcome, usize)> {
        let nr = self.open_networked(t, jw.as_deref_mut())?;
        let mut window = vec![nr];
        self.drain_front_round(&mut window)?;
        let nr = window.pop().expect("the barrier window holds exactly one round");
        let out = self.server.engine_mut().finish_external(nr.round)?;
        let completers = out.updates.len();
        if let Some(jw) = jw.as_deref_mut() {
            for r in self.server.resolution_records(t, &out) {
                jw.append(&r)?;
            }
        }
        Ok((self.server.apply_round(t, out), completers))
    }

    /// Serve the wait-set until the window's FRONT round drains: block
    /// on readiness, route events, convert front stragglers to Dropouts
    /// at the wall-clock deadline. Younger open rounds resolve devices
    /// as their frames arrive; they get a fresh deadline once they
    /// reach the front.
    fn drain_front_round(&mut self, window: &mut Vec<NetRound>) -> Result<()> {
        let deadline = Instant::now() + self.round_timeout;
        let mut events: Vec<Event> = Vec::new();
        while !window[0].round.drained() {
            let now = Instant::now();
            if now >= deadline {
                // stragglers become dropouts so the round can close;
                // the engine books their already-spent download traffic
                let nr = &mut window[0];
                for d in nr.round.pending() {
                    let bits = nr.down_bits.get(&d).copied().unwrap_or(0);
                    self.server.engine_mut().external_msg(
                        &mut nr.round,
                        DeviceMsg::Dropout { device: d, after_s: 0.0, down_wire_bits: bits },
                    )?;
                }
                continue; // loop re-checks drained()
            }
            events.clear();
            self.pump(deadline - now, &mut events)?;
            for ev in events.drain(..) {
                self.route_event(window, ev)?;
            }
        }
        Ok(())
    }

    /// Apply one pump event against the open window.
    fn route_event(&mut self, window: &mut [NetRound], ev: Event) -> Result<()> {
        match ev {
            Event::Joined(d) => {
                // (re)join mid-run: registry join + re-kick every open
                // round the device is still pending in, in round order
                let _ = self
                    .server
                    .engine_mut()
                    .external_msg(&mut window[0].round, DeviceMsg::Join { device: d });
                for nr in window.iter() {
                    if nr.round.is_pending(d) {
                        if let Some(msg) = nr.outbox.get(&d) {
                            self.send_to_device(d, msg);
                        }
                    }
                }
            }
            Event::Frame(d, msg) => self.route_frame(window, d, msg)?,
            Event::ConnDied(_) => {
                // devices stay pending: rejoin-with-redelivery may
                // still resolve them, else the deadline will
            }
            Event::ConnPoisoned(devices) => {
                // the peer holding these sessions cannot be spoken to:
                // convert ALL its devices in every open round now (same
                // message bits the deadline conversion would write)
                for d in devices {
                    for nr in window.iter_mut() {
                        if nr.round.is_pending(d) {
                            let bits = nr.down_bits.get(&d).copied().unwrap_or(0);
                            self.server.engine_mut().external_msg(
                                &mut nr.round,
                                DeviceMsg::Dropout {
                                    device: d,
                                    after_s: 0.0,
                                    down_wire_bits: bits,
                                },
                            )?;
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Dispatch one identified device frame against the open window:
    /// resolutions go to the round they are tagged with, heartbeats to
    /// the front; anything matching no open round — or duplicating a
    /// device that already resolved — is refused without touching the
    /// engine.
    fn route_frame(&mut self, window: &mut [NetRound], d: usize, msg: WireMsg) -> Result<()> {
        match msg {
            WireMsg::Heartbeat { device, sim_t_s } => {
                let _ = self
                    .server
                    .engine_mut()
                    .external_msg(&mut window[0].round, DeviceMsg::Heartbeat { device, sim_t_s });
            }
            WireMsg::EndRound { t: ft, update } => {
                match window.iter_mut().find(|nr| nr.round.t() == ft) {
                    Some(nr) if nr.round.is_pending(d) => {
                        if self
                            .server
                            .engine_mut()
                            .external_msg(&mut nr.round, DeviceMsg::EndRound(update))
                            .is_err()
                        {
                            // decoded fine but failed engine validation:
                            // refuse it and count the device out of that
                            // round (its download traffic is spent)
                            let bits = nr.down_bits.get(&d).copied().unwrap_or(0);
                            self.server.engine_mut().external_msg(
                                &mut nr.round,
                                DeviceMsg::Dropout {
                                    device: d,
                                    after_s: 0.0,
                                    down_wire_bits: bits,
                                },
                            )?;
                            self.send_to_device(
                                d,
                                &WireMsg::Reject { device: d, code: reject::BAD_UPDATE },
                            );
                        }
                    }
                    _ => {
                        // closed round, or a duplicate for a still-open
                        // one (a redelivery racing its original):
                        // refuse, keep the connection
                        self.send_to_device(
                            d,
                            &WireMsg::Reject { device: d, code: reject::STALE_ROUND },
                        );
                    }
                }
            }
            WireMsg::Dropout { t: ft, device, after_s, down_wire_bits } => {
                match window.iter_mut().find(|nr| nr.round.t() == ft) {
                    Some(nr) if nr.round.is_pending(d) => {
                        self.server.engine_mut().external_msg(
                            &mut nr.round,
                            DeviceMsg::Dropout { device, after_s, down_wire_bits },
                        )?;
                    }
                    _ => {
                        self.send_to_device(
                            d,
                            &WireMsg::Reject { device: d, code: reject::STALE_ROUND },
                        );
                    }
                }
            }
            // on_frame only forwards the three variants above; stay
            // total anyway
            _ => {}
        }
        Ok(())
    }

    // -----------------------------------------------------------------
    // semi-async pipelined rounds over the transport
    // -----------------------------------------------------------------

    /// The networked semi-async run loop — the transport twin of
    /// `Server::run_pipelined_cb`, sharing its schedule (`barrier_after`
    /// window bounds) and its close (`Server::close_pipelined`), so the
    /// two write byte-identical journals and bit-identical state for the
    /// same seed and arrival outcome. While the oldest open round
    /// drains, later rounds' kickoffs are already on the wire.
    fn run_pipelined(
        &mut self,
        mut jw: Option<&mut RunJournal>,
        mut cb: impl FnMut(&RoundRecord),
    ) -> Result<RunResult> {
        let quiesce = jw.as_ref().map(|j| j.snapshot_every()).unwrap_or(0);
        let mut records = match jw.as_mut() {
            Some(j) => j.take_prior_records(),
            None => Vec::with_capacity(self.server.cfg.rounds),
        };
        let mut reached = self.server.recompute_reached(&records);
        let depth = self.server.cfg.engine.pipeline_depth.max(1);
        let rounds = self.server.cfg.rounds;
        let mut window: Vec<NetRound> = Vec::with_capacity(depth);
        let mut next_open = records.len() + 1;
        for t in records.len() + 1..=rounds {
            while next_open <= coordinator::barrier_after(t, quiesce, rounds)
                && window.len() < depth
            {
                let nr = self.open_networked(next_open, jw.as_deref_mut())?;
                window.push(nr);
                next_open += 1;
            }
            let pend = self.drain_front(&mut window)?;
            debug_assert_eq!(pend.t, t);
            let (outcome, folded) =
                self.server.close_pipelined(pend, quiesce, jw.as_deref_mut())?;
            let rec = self.server.observe_round(t, &outcome, &mut reached)?;
            if let Some(j) = jw.as_mut() {
                j.append(&self.server.record_close(t, folded, &rec))?;
                if j.due_snapshot(t) {
                    j.append(&self.server.journal_snapshot(t))?;
                }
            }
            cb(&rec);
            records.push(rec);
        }
        self.broadcast_finish();
        Ok(self.server.finish_run(records, reached))
    }

    /// Open round `u` behind the still-draining window front: plan +
    /// journal the RoundOpen + put every kickoff frame on the wire
    /// (routed per device — fleet-multiplexed devices share a socket).
    fn open_networked(&mut self, u: usize, jw: Option<&mut RunJournal>) -> Result<NetRound> {
        let (round, starts) = self.server.begin_networked_round(u)?;
        if let Some(jw) = jw {
            let items: Vec<StartRound> = starts.iter().map(|s| s.item).collect();
            let lr = self.server.cfg.lr_at(u - 1) as f32;
            jw.append(&self.server.record_open(u, &items, lr))?;
        }
        let mut down_bits: BTreeMap<usize, usize> = BTreeMap::new();
        let mut outbox: BTreeMap<usize, WireMsg> = BTreeMap::new();
        for s in starts {
            let d = s.item.plan.device;
            down_bits.insert(d, s.download.bits);
            outbox.insert(d, WireMsg::StartRound(Box::new(s)));
        }
        for (d, msg) in &outbox {
            // unbound / dead connections: the deadline (or a rejoin
            // re-kick) handles the device
            self.send_to_device(*d, msg);
        }
        Ok(NetRound { round, outbox, down_bits })
    }

    /// Serve until the window's oldest round drains, then take it out
    /// of the engine as a [`coordinator::PendingRound`] for the shared
    /// close.
    fn drain_front(&mut self, window: &mut Vec<NetRound>) -> Result<coordinator::PendingRound> {
        self.drain_front_round(window)?;
        let nr = window.remove(0);
        let t = nr.round.t();
        let (devices, updates, dropped) = self.server.engine_mut().take_external(nr.round)?;
        Ok(coordinator::PendingRound { t, devices, updates, dropped })
    }
}

/// One open round of the networked window: the engine-side external
/// round plus the outbox (for rejoin re-kicks) and the per-device
/// download bill (for synthesized dropouts).
struct NetRound {
    round: ExternalRound,
    outbox: BTreeMap<usize, WireMsg>,
    down_bits: BTreeMap<usize, usize>,
}

/// A served connection: direct when the conn integrates with the
/// reactor, wrapped in the threaded-reader fallback when it does not.
enum Served<C: Conn> {
    Direct(C),
    Pumped(ThreadedReader<C>),
}

impl<C: Conn> Conn for Served<C> {
    fn send(&mut self, msg: &WireMsg) -> Result<(), TransportError> {
        match self {
            Served::Direct(c) => c.send(msg),
            Served::Pumped(r) => r.send(msg),
        }
    }

    fn recv_timeout(&mut self, timeout: Duration) -> Result<Option<WireMsg>, TransportError> {
        match self {
            Served::Direct(c) => c.recv_timeout(timeout),
            Served::Pumped(r) => r.recv_timeout(timeout),
        }
    }

    fn try_recv(&mut self) -> Result<Option<WireMsg>, TransportError> {
        match self {
            Served::Direct(c) => c.try_recv(),
            Served::Pumped(r) => r.try_recv(),
        }
    }

    fn source(&self) -> RawSource {
        match self {
            Served::Direct(c) => c.source(),
            Served::Pumped(r) => r.source(),
        }
    }

    fn peer(&self) -> String {
        match self {
            Served::Direct(c) => c.peer(),
            Served::Pumped(r) => r.peer(),
        }
    }
}

/// The serving-side connection table: slot-indexed anonymous endpoints
/// (tokens are slot indices; freed slots are reused). Device routing
/// lives in the registry, not here — see the module docs.
struct Slots<C: Conn> {
    slots: Vec<Option<Slot<C>>>,
    /// Count of connections still awaiting their first Join; the
    /// identify-deadline scan runs only while nonzero.
    unidentified: usize,
}

struct Slot<C: Conn> {
    conn: Served<C>,
    /// Whether any device ever identified on this connection.
    identified: bool,
    /// Accept time, for the identify deadline on device-less conns.
    accepted_at: Instant,
}

impl<C: Conn> Slots<C> {
    fn new() -> Slots<C> {
        Slots { slots: Vec::new(), unidentified: 0 }
    }

    fn add(&mut self, conn: Served<C>) -> u64 {
        let slot = Slot { conn, identified: false, accepted_at: Instant::now() };
        self.unidentified += 1;
        for (i, s) in self.slots.iter_mut().enumerate() {
            if s.is_none() {
                *s = Some(slot);
                return i as u64;
            }
        }
        self.slots.push(Some(slot));
        (self.slots.len() - 1) as u64
    }

    fn get(&self, token: u64) -> Option<&Slot<C>> {
        self.slots.get(token as usize).and_then(|s| s.as_ref())
    }

    fn get_mut(&mut self, token: u64) -> Option<&mut Slot<C>> {
        self.slots.get_mut(token as usize).and_then(|s| s.as_mut())
    }

    fn remove(&mut self, token: u64) -> Option<Slot<C>> {
        let taken = self.slots.get_mut(token as usize).and_then(|s| s.take());
        if let Some(slot) = &taken {
            if !slot.identified {
                self.unidentified -= 1;
            }
        }
        taken
    }

    fn mark_identified(&mut self, token: u64) {
        if let Some(slot) = self.slots.get_mut(token as usize).and_then(|s| s.as_mut()) {
            if !slot.identified {
                slot.identified = true;
                self.unidentified -= 1;
            }
        }
    }

    /// `(token, source)` pairs for the reactor wait-set.
    fn sources(&self) -> Vec<(u64, RawSource)> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|slot| (i as u64, slot.conn.source())))
            .collect()
    }

    fn tokens(&self) -> Vec<u64> {
        self.slots
            .iter()
            .enumerate()
            .filter_map(|(i, s)| s.as_ref().map(|_| i as u64))
            .collect()
    }

    fn iter_mut(&mut self) -> impl Iterator<Item = &mut Slot<C>> {
        self.slots.iter_mut().flatten()
    }
}

//! Coordinator service: `coordinator::Server` + `engine::Engine` driven
//! by decoded transport frames.
//!
//! Generic over [`Transport`], so the same service runs the Loopback
//! parity baseline and real Tcp sessions. Per round it asks the server
//! for a networked kickoff (`begin_networked_round` — plans, encoded
//! downloads and per-device RNG resume states), sends one StartRound
//! frame per participant, then polls the per-device connections feeding
//! every arriving frame into the engine's external round until all
//! participants resolve. The canonical aggregation in
//! `Engine::finish_external` and the shared `Server::apply_round` make
//! the result bit-identical to the in-process `Server::run` path — the
//! invariant `tests/transport_parity.rs` pins across Loopback and Tcp.
//!
//! Fault handling: a connection that drops mid-round keeps its device
//! pending — the device may reconnect and re-Join (the service re-sends
//! its StartRound, *reconnect-with-rejoin*). Devices still pending at
//! the wall-clock round deadline are converted to protocol `Dropout`s
//! (their download traffic is already spent) so one dead device cannot
//! wedge the run. A resolution frame whose round number is not the open
//! round (a straggler's EndRound buffered past the deadline conversion)
//! is refused with [`reject::STALE_ROUND`] and never reaches the engine.
//!
//! With `pipeline-depth` > 1 (or `staleness-bound` > 0) the service runs
//! the semi-async schedule instead: up to D rounds are open at once
//! (their kickoffs all on the wire), resolution frames route to
//! whichever open round they are tagged with, and only frames matching
//! NO open round are refused stale — see [`CoordinatorService::run_cb`]
//! routing to the pipelined loop and `Server::close_pipelined` for the
//! shared close.
//!
//! The registry's liveness sweep (`Engine::sweep_expired`) is exposed as
//! [`CoordinatorService::sweep_expired`] but NOT run automatically:
//! under the synchronous barrier, devices only heartbeat while executing
//! a kickoff, so simulated-time silence is the *normal* state of a
//! healthy connected non-participant — a blanket sweep would mark such
//! devices Dropped and inflate dropout diagnostics. In-round stragglers
//! are already evicted by the deadline conversion above; the explicit
//! hook is for future asynchronous drivers whose devices heartbeat
//! continuously.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::coordinator::{self, RoundOutcome, RoundRecord, RunResult, Server};
use crate::engine::{DeviceMsg, ExternalRound, StartRound};
use crate::journal::RunJournal;

use super::frame::{reject, WireMsg};
use super::{Conn, Transport};

/// Per-connection receive poll during a round.
const POLL: Duration = Duration::from_millis(2);
/// Accept-queue poll during a round (rejoins) and device wait.
const ACCEPT_SLICE: Duration = Duration::from_millis(2);
/// How long a freshly accepted connection gets to identify itself with
/// a Join frame before being dropped.
const IDENTIFY_TIMEOUT: Duration = Duration::from_secs(2);

/// A networked FL coordinator session over one [`Transport`].
pub struct CoordinatorService<T: Transport> {
    server: Server,
    transport: T,
    /// Connection-per-device: the latest identified connection wins
    /// (a re-Join from a reconnecting device replaces the dead one).
    conns: BTreeMap<usize, T::Conn>,
    /// Wall-clock budget per round before stragglers become Dropouts.
    pub round_timeout: Duration,
}

impl<T: Transport> CoordinatorService<T> {
    pub fn new(server: Server, transport: T) -> CoordinatorService<T> {
        CoordinatorService {
            server,
            transport,
            conns: BTreeMap::new(),
            round_timeout: Duration::from_secs(120),
        }
    }

    pub fn server(&self) -> &Server {
        &self.server
    }

    /// Hand the server back (post-run inspection: model, traffic, stats).
    pub fn into_server(self) -> Server {
        self.server
    }

    /// The transport's listen address (resolves ephemeral Tcp ports).
    pub fn local_addr(&self) -> String {
        self.transport.local_addr()
    }

    /// Number of identified device connections.
    pub fn connected(&self) -> usize {
        self.conns.len()
    }

    /// Accept + identify connections until `expect` devices are
    /// connected or `timeout` elapses (error). Call before [`run`]: the
    /// first round kicks off immediately.
    pub fn wait_for_devices(&mut self, expect: usize, timeout: Duration) -> Result<()> {
        let deadline = Instant::now() + timeout;
        while self.conns.len() < expect {
            if Instant::now() >= deadline {
                return Err(anyhow!(
                    "{} of {expect} devices connected before the rendezvous timeout",
                    self.conns.len()
                ));
            }
            self.accept_and_identify()?;
        }
        Ok(())
    }

    /// Accept at most one pending connection and run the Join handshake.
    /// Returns the identified device id, if any. Unknown device ids get
    /// a Reject frame and are dropped; a known id replaces any previous
    /// connection for that device (rejoin).
    fn accept_and_identify(&mut self) -> Result<Option<usize>> {
        let Some(mut conn) = self.transport.accept_timeout(ACCEPT_SLICE).map_err(|e| anyhow!("{e}"))?
        else {
            return Ok(None);
        };
        // the first frame on a connection must be Join
        let deadline = Instant::now() + IDENTIFY_TIMEOUT;
        loop {
            match conn.recv_timeout(POLL) {
                Ok(Some(WireMsg::Join { device })) => {
                    let n = self.server.cfg.n_devices();
                    if !self.server.engine().registry().contains(device) {
                        let _ = conn.send(&WireMsg::Reject {
                            device,
                            code: reject::UNKNOWN_DEVICE,
                        });
                        return Ok(None);
                    }
                    conn.send(&WireMsg::JoinAck { device, n_devices: n })
                        .map_err(|e| anyhow!("join ack to device {device}: {e}"))?;
                    self.conns.insert(device, conn);
                    return Ok(Some(device));
                }
                Ok(Some(_)) | Err(_) => return Ok(None), // not our protocol: drop
                Ok(None) => {
                    if Instant::now() >= deadline {
                        return Ok(None); // never identified: drop
                    }
                }
            }
        }
    }

    /// Execute the full run: rounds 1..=cfg.rounds over the transport,
    /// evaluation/records identical to `Server::run_cb`, then a Finish
    /// broadcast so devices disconnect cleanly.
    pub fn run_cb(&mut self, mut cb: impl FnMut(&RoundRecord)) -> Result<RunResult> {
        if self.server.pipelined() {
            return self.run_pipelined(None, cb);
        }
        let rounds = self.server.cfg.rounds;
        let mut records = Vec::with_capacity(rounds);
        let mut reached: Option<(usize, f64, f64)> = None;
        for t in 1..=rounds {
            let (outcome, _) = self.round_networked(t, None)?;
            let rec = self.server.observe_round(t, &outcome, &mut reached)?;
            cb(&rec);
            records.push(rec);
        }
        for conn in self.conns.values_mut() {
            let _ = conn.send(&WireMsg::Finish);
        }
        Ok(self.server.finish_run(records, reached))
    }

    /// [`run_cb`] without a progress observer.
    pub fn run(&mut self) -> Result<RunResult> {
        self.run_cb(|_| {})
    }

    /// [`run_cb`] with every coordinator decision event-sourced through
    /// `jw` — the networked twin of `Server::run_journaled_cb`. Records
    /// are written in canonical order (round open sorted by device,
    /// resolutions in fold order), so a networked run's journal is
    /// byte-identical to the in-process loop's for the same seed and
    /// arrival outcome — and a journal written here resumes on either
    /// path.
    pub fn run_journaled_cb(
        &mut self,
        jw: &mut RunJournal,
        mut cb: impl FnMut(&RoundRecord),
    ) -> Result<RunResult> {
        if jw.is_fresh() {
            jw.append(&self.server.record_header(jw.snapshot_every()))?;
            jw.append(&self.server.journal_snapshot(0))?;
        }
        if self.server.pipelined() {
            return self.run_pipelined(Some(jw), cb);
        }
        let mut records = jw.take_prior_records();
        let mut reached = self.server.recompute_reached(&records);
        let rounds = self.server.cfg.rounds;
        for t in records.len() + 1..=rounds {
            let (outcome, completers) = self.round_networked(t, Some(jw))?;
            let rec = self.server.observe_round(t, &outcome, &mut reached)?;
            jw.append(&self.server.record_close(t, completers, &rec))?;
            if jw.due_snapshot(t) {
                jw.append(&self.server.journal_snapshot(t))?;
            }
            cb(&rec);
            records.push(rec);
        }
        for conn in self.conns.values_mut() {
            let _ = conn.send(&WireMsg::Finish);
        }
        Ok(self.server.finish_run(records, reached))
    }

    /// Evict devices whose last simulated-time heartbeat is stale (see
    /// the module docs for why this is NOT called automatically: under
    /// the synchronous barrier only kickoff-executing devices heartbeat,
    /// so a blanket sweep would misclassify healthy idle devices).
    /// Returns the evicted device ids.
    pub fn sweep_expired(&mut self) -> Vec<usize> {
        let now_s = self.server.sim_time_s();
        self.server.engine_mut().sweep_expired(now_s)
    }

    /// One networked round: kickoff frames out, device frames in until
    /// the external round drains, canonical aggregation, application.
    /// With a journal, the round-open record goes out before any kickoff
    /// frame and the fold-order resolutions after the round drains (both
    /// before `apply_round` mutates the server). Returns the outcome and
    /// the completer count (what the close record needs).
    fn round_networked(
        &mut self,
        t: usize,
        mut jw: Option<&mut RunJournal>,
    ) -> Result<(RoundOutcome, usize)> {
        let (mut round, starts) = self.server.begin_networked_round(t)?;
        if let Some(jw) = jw.as_deref_mut() {
            let items: Vec<StartRound> = starts.iter().map(|s| s.item).collect();
            let lr = self.server.cfg.lr_at(t - 1) as f32;
            jw.append(&self.server.record_open(t, &items, lr))?;
        }
        let mut down_bits: BTreeMap<usize, usize> = BTreeMap::new();
        let mut outbox: BTreeMap<usize, WireMsg> = BTreeMap::new();
        for s in starts {
            let d = s.item.plan.device;
            down_bits.insert(d, s.download.bits);
            outbox.insert(d, WireMsg::StartRound(Box::new(s)));
        }
        for (d, msg) in &outbox {
            match self.conns.get_mut(d) {
                Some(conn) => {
                    if conn.send(msg).is_err() {
                        // dead connection: drop it, the device may rejoin
                        self.conns.remove(d);
                    }
                }
                None => {} // never connected / currently gone: deadline handles it
            }
        }

        let deadline = Instant::now() + self.round_timeout;
        while !round.drained() {
            // rejoins and late arrivals: a reconnecting pending device
            // gets its kickoff frame again
            if let Some(d) = self.accept_and_identify()? {
                if round.pending().contains(&d) {
                    if let (Some(msg), Some(conn)) = (outbox.get(&d), self.conns.get_mut(&d)) {
                        let _ = conn.send(msg);
                    }
                }
            }

            for d in round.pending() {
                let msg = match self.conns.get_mut(&d) {
                    None => continue,
                    Some(conn) => match conn.recv_timeout(POLL) {
                        Ok(None) => continue,
                        Ok(Some(m)) => m,
                        Err(_) => {
                            self.conns.remove(&d);
                            continue;
                        }
                    },
                };
                match msg {
                    WireMsg::Heartbeat { device, sim_t_s } if device == d => {
                        let _ = self
                            .server
                            .engine_mut()
                            .external_msg(&mut round, DeviceMsg::Heartbeat { device, sim_t_s });
                    }
                    WireMsg::Join { device } if device == d => {
                        // in-band rejoin on a surviving connection
                        let _ = self
                            .server
                            .engine_mut()
                            .external_msg(&mut round, DeviceMsg::Join { device });
                        if let (Some(m), Some(conn)) = (outbox.get(&d), self.conns.get_mut(&d)) {
                            let _ = conn.send(m);
                        }
                    }
                    WireMsg::EndRound { t: ft, update } if update.device == d => {
                        if ft != t {
                            // a resolution for a round that already closed
                            // (e.g. buffered past the deadline conversion):
                            // refuse it, keep the connection — the device's
                            // *current*-round resolution may still arrive
                            if let Some(conn) = self.conns.get_mut(&d) {
                                let _ = conn
                                    .send(&WireMsg::Reject { device: d, code: reject::STALE_ROUND });
                            }
                        } else if self
                            .server
                            .engine_mut()
                            .external_msg(&mut round, DeviceMsg::EndRound(update))
                            .is_err()
                        {
                            // decoded fine but failed engine validation:
                            // refuse it and count the device out (its
                            // download traffic is already spent)
                            if let Some(conn) = self.conns.get_mut(&d) {
                                let _ = conn
                                    .send(&WireMsg::Reject { device: d, code: reject::BAD_UPDATE });
                            }
                            self.server.engine_mut().external_msg(
                                &mut round,
                                DeviceMsg::Dropout {
                                    device: d,
                                    after_s: 0.0,
                                    down_wire_bits: down_bits.get(&d).copied().unwrap_or(0),
                                },
                            )?;
                        }
                    }
                    WireMsg::Dropout { t: ft, device, after_s, down_wire_bits }
                        if device == d =>
                    {
                        if ft != t {
                            if let Some(conn) = self.conns.get_mut(&d) {
                                let _ = conn
                                    .send(&WireMsg::Reject { device: d, code: reject::STALE_ROUND });
                            }
                        } else {
                            self.server.engine_mut().external_msg(
                                &mut round,
                                DeviceMsg::Dropout { device, after_s, down_wire_bits },
                            )?;
                        }
                    }
                    _other => {
                        // a frame this side of the protocol never expects:
                        // refuse and cut the connection
                        if let Some(conn) = self.conns.get_mut(&d) {
                            let _ =
                                conn.send(&WireMsg::Reject { device: d, code: reject::BAD_STATE });
                        }
                        self.conns.remove(&d);
                    }
                }
            }

            if !round.drained() && Instant::now() >= deadline {
                // stragglers become dropouts so the round can close; the
                // engine books their already-spent download traffic
                for d in round.pending() {
                    self.server.engine_mut().external_msg(
                        &mut round,
                        DeviceMsg::Dropout {
                            device: d,
                            after_s: 0.0,
                            down_wire_bits: down_bits.get(&d).copied().unwrap_or(0),
                        },
                    )?;
                }
            }
        }

        let out = self.server.engine_mut().finish_external(round)?;
        let completers = out.updates.len();
        if let Some(jw) = jw.as_deref_mut() {
            for r in self.server.resolution_records(t, &out) {
                jw.append(&r)?;
            }
        }
        Ok((self.server.apply_round(t, out), completers))
    }

    // -----------------------------------------------------------------
    // semi-async pipelined rounds over the transport
    // -----------------------------------------------------------------

    /// The networked semi-async run loop — the transport twin of
    /// `Server::run_pipelined_cb`, sharing its schedule (`barrier_after`
    /// window bounds) and its close (`Server::close_pipelined`), so the
    /// two write byte-identical journals and bit-identical state for the
    /// same seed and arrival outcome. While the oldest open round
    /// drains, later rounds' kickoffs are already on the wire; a
    /// resolution frame is routed to whichever open round it is tagged
    /// with, and only frames matching NO open round are refused as
    /// [`reject::STALE_ROUND`].
    fn run_pipelined(
        &mut self,
        mut jw: Option<&mut RunJournal>,
        mut cb: impl FnMut(&RoundRecord),
    ) -> Result<RunResult> {
        let quiesce = jw.as_ref().map(|j| j.snapshot_every()).unwrap_or(0);
        let mut records = match jw.as_mut() {
            Some(j) => j.take_prior_records(),
            None => Vec::with_capacity(self.server.cfg.rounds),
        };
        let mut reached = self.server.recompute_reached(&records);
        let depth = self.server.cfg.engine.pipeline_depth.max(1);
        let rounds = self.server.cfg.rounds;
        let mut window: Vec<NetRound> = Vec::with_capacity(depth);
        let mut next_open = records.len() + 1;
        for t in records.len() + 1..=rounds {
            while next_open <= coordinator::barrier_after(t, quiesce, rounds)
                && window.len() < depth
            {
                let nr = self.open_networked(next_open, jw.as_deref_mut())?;
                window.push(nr);
                next_open += 1;
            }
            let pend = self.drain_front(&mut window)?;
            debug_assert_eq!(pend.t, t);
            let (outcome, folded) = self.server.close_pipelined(pend, quiesce, jw.as_deref_mut())?;
            let rec = self.server.observe_round(t, &outcome, &mut reached)?;
            if let Some(j) = jw.as_mut() {
                j.append(&self.server.record_close(t, folded, &rec))?;
                if j.due_snapshot(t) {
                    j.append(&self.server.journal_snapshot(t))?;
                }
            }
            cb(&rec);
            records.push(rec);
        }
        for conn in self.conns.values_mut() {
            let _ = conn.send(&WireMsg::Finish);
        }
        Ok(self.server.finish_run(records, reached))
    }

    /// Open round `u` behind the still-draining window front: plan +
    /// journal the RoundOpen + put every kickoff frame on the wire. The
    /// engine tracks up to `pipeline_depth` concurrently open external
    /// rounds; devices selected in overlapping rounds see their kickoffs
    /// in round order on the same connection.
    fn open_networked(&mut self, u: usize, jw: Option<&mut RunJournal>) -> Result<NetRound> {
        let (round, starts) = self.server.begin_networked_round(u)?;
        if let Some(jw) = jw {
            let items: Vec<StartRound> = starts.iter().map(|s| s.item).collect();
            let lr = self.server.cfg.lr_at(u - 1) as f32;
            jw.append(&self.server.record_open(u, &items, lr))?;
        }
        let mut down_bits: BTreeMap<usize, usize> = BTreeMap::new();
        let mut outbox: BTreeMap<usize, WireMsg> = BTreeMap::new();
        for s in starts {
            let d = s.item.plan.device;
            down_bits.insert(d, s.download.bits);
            outbox.insert(d, WireMsg::StartRound(Box::new(s)));
        }
        for (d, msg) in &outbox {
            match self.conns.get_mut(d) {
                Some(conn) => {
                    if conn.send(msg).is_err() {
                        self.conns.remove(d);
                    }
                }
                None => {} // never connected / currently gone: deadline handles it
            }
        }
        Ok(NetRound { round, outbox, down_bits })
    }

    /// Poll until the window's oldest round drains, then take it out of
    /// the engine as a [`coordinator::PendingRound`] for the shared
    /// close. Frames tagged for younger open rounds are fed to those
    /// rounds as they arrive (their devices resolve early); the
    /// wall-clock deadline converts only the FRONT round's stragglers
    /// into dropouts — younger rounds get a fresh deadline once they
    /// reach the front.
    fn drain_front(&mut self, window: &mut Vec<NetRound>) -> Result<coordinator::PendingRound> {
        let deadline = Instant::now() + self.round_timeout;
        while !window[0].round.drained() {
            // rejoins: a reconnecting device gets the kickoff of every
            // open round it is still pending in, in round order
            if let Some(d) = self.accept_and_identify()? {
                for nr in window.iter_mut() {
                    if nr.round.pending().contains(&d) {
                        if let (Some(msg), Some(conn)) = (nr.outbox.get(&d), self.conns.get_mut(&d))
                        {
                            let _ = conn.send(msg);
                        }
                    }
                }
            }

            for d in window[0].round.pending() {
                let msg = match self.conns.get_mut(&d) {
                    None => continue,
                    Some(conn) => match conn.recv_timeout(POLL) {
                        Ok(None) => continue,
                        Ok(Some(m)) => m,
                        Err(_) => {
                            self.conns.remove(&d);
                            continue;
                        }
                    },
                };
                self.route_frame(window, d, msg)?;
            }

            if !window[0].round.drained() && Instant::now() >= deadline {
                // front-round stragglers become dropouts so the round
                // can close; their download traffic is already spent
                let nr = &mut window[0];
                for d in nr.round.pending() {
                    let bits = nr.down_bits.get(&d).copied().unwrap_or(0);
                    self.server.engine_mut().external_msg(
                        &mut nr.round,
                        DeviceMsg::Dropout { device: d, after_s: 0.0, down_wire_bits: bits },
                    )?;
                }
            }
        }
        let nr = window.remove(0);
        let t = nr.round.t();
        let (devices, updates, dropped) = self.server.engine_mut().take_external(nr.round)?;
        Ok(coordinator::PendingRound { t, devices, updates, dropped })
    }

    /// Dispatch one decoded frame from device `d` against the open
    /// window: resolutions go to the round they are tagged with,
    /// heartbeats and in-band rejoins to the front, anything matching no
    /// open round is refused without touching the engine.
    fn route_frame(&mut self, window: &mut [NetRound], d: usize, msg: WireMsg) -> Result<()> {
        match msg {
            WireMsg::Heartbeat { device, sim_t_s } if device == d => {
                let _ = self
                    .server
                    .engine_mut()
                    .external_msg(&mut window[0].round, DeviceMsg::Heartbeat { device, sim_t_s });
            }
            WireMsg::Join { device } if device == d => {
                // in-band rejoin on a surviving connection: re-kick every
                // open round the device is still pending in
                let _ = self
                    .server
                    .engine_mut()
                    .external_msg(&mut window[0].round, DeviceMsg::Join { device });
                for nr in window.iter_mut() {
                    if nr.round.pending().contains(&d) {
                        if let (Some(m), Some(conn)) = (nr.outbox.get(&d), self.conns.get_mut(&d)) {
                            let _ = conn.send(m);
                        }
                    }
                }
            }
            WireMsg::EndRound { t: ft, update } if update.device == d => {
                match window.iter_mut().find(|nr| nr.round.t() == ft) {
                    None => {
                        // a resolution for a round that already closed:
                        // refuse it, keep the connection
                        if let Some(conn) = self.conns.get_mut(&d) {
                            let _ = conn
                                .send(&WireMsg::Reject { device: d, code: reject::STALE_ROUND });
                        }
                    }
                    Some(nr) => {
                        if self
                            .server
                            .engine_mut()
                            .external_msg(&mut nr.round, DeviceMsg::EndRound(update))
                            .is_err()
                        {
                            // decoded fine but failed engine validation:
                            // refuse it and count the device out of that
                            // round (its download traffic is spent)
                            if let Some(conn) = self.conns.get_mut(&d) {
                                let _ = conn
                                    .send(&WireMsg::Reject { device: d, code: reject::BAD_UPDATE });
                            }
                            let bits = nr.down_bits.get(&d).copied().unwrap_or(0);
                            self.server.engine_mut().external_msg(
                                &mut nr.round,
                                DeviceMsg::Dropout { device: d, after_s: 0.0, down_wire_bits: bits },
                            )?;
                        }
                    }
                }
            }
            WireMsg::Dropout { t: ft, device, after_s, down_wire_bits } if device == d => {
                match window.iter_mut().find(|nr| nr.round.t() == ft) {
                    None => {
                        if let Some(conn) = self.conns.get_mut(&d) {
                            let _ = conn
                                .send(&WireMsg::Reject { device: d, code: reject::STALE_ROUND });
                        }
                    }
                    Some(nr) => {
                        self.server.engine_mut().external_msg(
                            &mut nr.round,
                            DeviceMsg::Dropout { device, after_s, down_wire_bits },
                        )?;
                    }
                }
            }
            _other => {
                // a frame this side of the protocol never expects:
                // refuse and cut the connection
                if let Some(conn) = self.conns.get_mut(&d) {
                    let _ = conn.send(&WireMsg::Reject { device: d, code: reject::BAD_STATE });
                }
                self.conns.remove(&d);
            }
        }
        Ok(())
    }
}

/// One open round of the networked window: the engine-side external
/// round plus the outbox (for rejoin re-kicks) and the per-device
/// download bill (for synthesized dropouts).
struct NetRound {
    round: ExternalRound,
    outbox: BTreeMap<usize, WireMsg>,
    down_bits: BTreeMap<usize, usize>,
}

//! Codec execution engine — applies a [`DownloadCodec`]/[`UploadCodec`]
//! through either the rust-native implementations in `compress/` or the
//! AOT-lowered L1 Pallas kernels via the PJRT runtime.
//!
//! Both backends produce the same numerics (pinned by
//! `tests/compress_parity.rs`); the native backend works at any shape and
//! is the default, the XLA backend proves the three-layer path end to end.

use anyhow::{anyhow, Result};

use crate::compress::caesar_model::CompressedModel;
use crate::compress::{self, quant, traffic};
use crate::config::CompressionBackend;
use crate::runtime::{lit_f32, lit_scalar, to_scalar_f32, to_vec_f32, Runtime};
use crate::schemes::{DownloadCodec, UploadCodec};
use crate::util::rng::Rng;

/// One device's view of a compressed download after recovery, plus the
/// exact wire size that was transferred.
pub struct Recovered {
    pub model: Vec<f32>,
    pub wire_bits: usize,
}

/// A compressed upload ready for aggregation (dense, dropped = 0).
pub struct Uploaded {
    pub grad: Vec<f32>,
    pub wire_bits: usize,
}

/// Stateless codec executor bound to a backend.
pub struct CodecEngine<'a> {
    backend: CompressionBackend,
    rt: Option<&'a Runtime>,
    task: &'a str,
}

impl<'a> CodecEngine<'a> {
    pub fn native() -> CodecEngine<'static> {
        CodecEngine { backend: CompressionBackend::Native, rt: None, task: "" }
    }

    pub fn new(
        backend: CompressionBackend,
        rt: Option<&'a Runtime>,
        task: &'a str,
    ) -> Result<CodecEngine<'a>> {
        if backend == CompressionBackend::Xla && rt.is_none() {
            return Err(anyhow!("XLA compression backend requires a runtime"));
        }
        Ok(CodecEngine { backend, rt, task })
    }

    fn xla(&self) -> &Runtime {
        self.rt.expect("xla backend without runtime")
    }

    /// Compress the global model `w` for one device, transfer it, and
    /// recover on-device using the stale `local` model (if any).
    pub fn download(
        &self,
        codec: DownloadCodec,
        w: &[f32],
        local: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Result<Recovered> {
        let n = w.len();
        match codec {
            DownloadCodec::Full => Ok(Recovered {
                model: w.to_vec(),
                wire_bits: traffic::full_model_bits(n),
            }),
            DownloadCodec::CaesarSplit { ratio } => {
                let Some(local) = local else {
                    // no local model → the scheme should have sent Full;
                    // degrade gracefully to full precision
                    return self.download(DownloadCodec::Full, w, None, rng);
                };
                match self.backend {
                    CompressionBackend::Native => {
                        let cm = compress::caesar_compress(w, ratio);
                        let wire_bits = cm.wire_bits();
                        Ok(Recovered { model: compress::caesar_recover(&cm, local), wire_bits })
                    }
                    CompressionBackend::Xla => {
                        let rt = self.xla();
                        let out = rt.exec(
                            &format!("compress_{}", self.task),
                            &[lit_f32(w, &[n as i64])?, lit_scalar(ratio as f32)],
                        )?;
                        let (kept, mask, sign) =
                            (to_vec_f32(&out[0])?, to_vec_f32(&out[1])?, to_vec_f32(&out[2])?);
                        let (avg, max) = (to_scalar_f32(&out[3])?, to_scalar_f32(&out[4])?);
                        let n_quant = mask.iter().filter(|&&m| m > 0.5).count();
                        let wire_bits = traffic::caesar_model_bits(n, n_quant);
                        let rec = rt.exec(
                            &format!("recover_{}", self.task),
                            &[
                                lit_f32(&kept, &[n as i64])?,
                                lit_f32(&mask, &[n as i64])?,
                                lit_f32(&sign, &[n as i64])?,
                                lit_scalar(avg),
                                lit_scalar(max),
                                lit_f32(local, &[n as i64])?,
                            ],
                        )?;
                        Ok(Recovered { model: to_vec_f32(&rec[0])?, wire_bits })
                    }
                }
            }
            DownloadCodec::TopK { ratio } => {
                // GM-FIC / GM-CAC / Caesar-BR download: the (1-ratio)
                // largest-|w| parameters travel; dropped positions are
                // filled from the stale local model (zeros if none).
                let (dense, kept) = self.topk_dense(w, ratio)?;
                let thr = compress::topk::keep_threshold(w, ratio).0;
                let model: Vec<f32> = (0..n)
                    .map(|i| {
                        if w[i].abs() >= thr {
                            dense[i]
                        } else {
                            local.map_or(0.0, |l| l[i])
                        }
                    })
                    .collect();
                Ok(Recovered { model, wire_bits: traffic::topk_grad_bits(n, kept) })
            }
            DownloadCodec::Quant { bits } => {
                let q = self.quantize(w, bits, rng)?;
                Ok(Recovered { model: q, wire_bits: traffic::quantized_bits(n, bits) })
            }
        }
    }

    /// Compress a local gradient for upload. Output is dense
    /// (aggregation-ready) with the exact wire size accounted.
    pub fn upload(&self, codec: UploadCodec, g: &[f32], rng: &mut Rng) -> Result<Uploaded> {
        let n = g.len();
        match codec {
            UploadCodec::Full => Ok(Uploaded {
                grad: g.to_vec(),
                wire_bits: traffic::full_model_bits(n),
            }),
            UploadCodec::TopK { ratio } => {
                let (dense, kept) = self.topk_dense(g, ratio)?;
                Ok(Uploaded { grad: dense, wire_bits: traffic::topk_grad_bits(n, kept) })
            }
            UploadCodec::Quant { bits } => {
                let q = self.quantize(g, bits, rng)?;
                Ok(Uploaded { grad: q, wire_bits: traffic::quantized_bits(n, bits) })
            }
        }
    }

    /// Top-K through the configured backend; returns (dense, kept-count).
    fn topk_dense(&self, x: &[f32], ratio: f64) -> Result<(Vec<f32>, usize)> {
        match self.backend {
            CompressionBackend::Native => {
                let s = compress::topk_sparsify(x, ratio);
                Ok((s.dense, s.kept))
            }
            CompressionBackend::Xla => {
                let n = x.len();
                let out = self.xla().exec(
                    &format!("topk_{}", self.task),
                    &[lit_f32(x, &[n as i64])?, lit_scalar(ratio as f32)],
                )?;
                let dense = to_vec_f32(&out[0])?;
                let kept = n - ((ratio * n as f64).floor() as usize).min(n);
                Ok((dense, kept))
            }
        }
    }

    fn quantize(&self, x: &[f32], bits: u32, rng: &mut Rng) -> Result<Vec<f32>> {
        let n = x.len();
        let noise: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let levels = quant::levels_for_bits(bits);
        match self.backend {
            CompressionBackend::Native => Ok(quant::quantize_stochastic(x, levels, &noise)),
            CompressionBackend::Xla => {
                let out = self.xla().exec(
                    &format!("quantize_{}", self.task),
                    &[
                        lit_f32(x, &[n as i64])?,
                        lit_scalar(levels as f32),
                        lit_f32(&noise, &[n as i64])?,
                    ],
                )?;
                Ok(to_vec_f32(&out[0])?)
            }
        }
    }
}

/// Expose the caesar codec's intermediate form for diagnostics (Fig. 1c).
pub fn caesar_compressed(w: &[f32], ratio: f64) -> CompressedModel {
    compress::caesar_compress(w, ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn full_download_is_identity() {
        let w = randn(512, 0);
        let e = CodecEngine::native();
        let r = e.download(DownloadCodec::Full, &w, None, &mut Rng::new(1)).unwrap();
        assert_eq!(r.model, w);
        assert_eq!(r.wire_bits, 512 * 32);
    }

    #[test]
    fn caesar_download_recovers_with_fresh_local() {
        let w = randn(1024, 2);
        let e = CodecEngine::native();
        let r = e
            .download(DownloadCodec::CaesarSplit { ratio: 0.5 }, &w, Some(&w), &mut Rng::new(1))
            .unwrap();
        assert_eq!(r.model, w);
        assert!(r.wire_bits < 1024 * 32);
    }

    #[test]
    fn caesar_download_without_local_degrades_to_full() {
        let w = randn(256, 3);
        let e = CodecEngine::native();
        let r = e
            .download(DownloadCodec::CaesarSplit { ratio: 0.5 }, &w, None, &mut Rng::new(1))
            .unwrap();
        assert_eq!(r.model, w);
        assert_eq!(r.wire_bits, 256 * 32);
    }

    #[test]
    fn topk_download_fills_dropped_from_local() {
        let w = randn(512, 4);
        let local = randn(512, 5);
        let e = CodecEngine::native();
        let r = e
            .download(DownloadCodec::TopK { ratio: 0.5 }, &w, Some(&local), &mut Rng::new(1))
            .unwrap();
        let thr = compress::topk::keep_threshold(&w, 0.5).0;
        for i in 0..512 {
            if w[i].abs() >= thr {
                assert_eq!(r.model[i], w[i]);
            } else {
                assert_eq!(r.model[i], local[i]);
            }
        }
    }

    #[test]
    fn topk_download_without_local_zero_fills() {
        let w = randn(512, 6);
        let e = CodecEngine::native();
        let r = e
            .download(DownloadCodec::TopK { ratio: 0.9 }, &w, None, &mut Rng::new(1))
            .unwrap();
        let zeros = r.model.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros >= 450, "zeros={zeros}");
    }

    #[test]
    fn quant_download_error_shrinks_with_bits() {
        let w = randn(4096, 7);
        let e = CodecEngine::native();
        let mut prev = f64::MAX;
        for bits in [2u32, 4, 8] {
            let r = e
                .download(DownloadCodec::Quant { bits }, &w, None, &mut Rng::new(9))
                .unwrap();
            let err = stats::mse(&r.model, &w);
            assert!(err < prev, "bits={bits} err={err}");
            prev = err;
        }
    }

    #[test]
    fn upload_topk_bits_smaller_than_full() {
        let g = randn(2048, 8);
        let e = CodecEngine::native();
        let f = e.upload(UploadCodec::Full, &g, &mut Rng::new(1)).unwrap();
        let s = e.upload(UploadCodec::TopK { ratio: 0.6 }, &g, &mut Rng::new(1)).unwrap();
        assert!(s.wire_bits < f.wire_bits);
        let nz = s.grad.iter().filter(|&&x| x != 0.0).count();
        assert!((nz as f64) < 0.5 * 2048.0);
    }

    #[test]
    fn upload_quant_preserves_sign() {
        let g = randn(1024, 9);
        let e = CodecEngine::native();
        let u = e.upload(UploadCodec::Quant { bits: 4 }, &g, &mut Rng::new(2)).unwrap();
        for (a, b) in g.iter().zip(&u.grad) {
            assert!(*b == 0.0 || a.signum() == b.signum());
        }
    }

    #[test]
    fn xla_engine_requires_runtime() {
        assert!(CodecEngine::new(CompressionBackend::Xla, None, "cifar").is_err());
    }
}

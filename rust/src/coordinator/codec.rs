//! Codec execution engine — applies a [`DownloadCodec`]/[`UploadCodec`]
//! through either the rust-native implementations in `compress/` or the
//! AOT-lowered L1 Pallas kernels via the PJRT runtime, producing and
//! consuming *serialized* [`wire::EncodedPayload`]s.
//!
//! The split API mirrors the real protocol: `encode_download` runs on the
//! PS, the returned bytes are what crosses the wire, and
//! `recover_download` runs on the device over the decoded payload;
//! `encode_upload` runs on the device and the coordinator folds the
//! decoded payload into its aggregation shard. Every reported wire size is
//! the *measured* serialized length (`EncodedPayload::bits`) — the legacy
//! `compress::traffic` formulas are debug-assert cross-checks inside
//! `wire::Payload::encode`.
//!
//! Both backends produce the same numerics (pinned by
//! `tests/compress_parity.rs`); the native backend works at any shape and
//! is the default, the XLA backend proves the three-layer path end to end.

use anyhow::{anyhow, Result};

use crate::compress::caesar_model::CompressedModel;
use crate::compress::{self, quant};
use crate::config::CompressionBackend;
use crate::runtime::{lit_f32, lit_scalar, to_scalar_f32, to_vec_f32, Runtime};
use crate::schemes::{DownloadCodec, UploadCodec};
use crate::util::rng::Rng;
use crate::wire::{EncodedPayload, Payload, PayloadView};

/// One device's view of a compressed download after recovery, plus the
/// measured wire size that was transferred.
pub struct Recovered {
    pub model: Vec<f32>,
    pub wire_bits: usize,
}

/// A compressed upload decoded back to dense (aggregation-ready) form,
/// plus the measured wire size.
pub struct Uploaded {
    pub grad: Vec<f32>,
    pub wire_bits: usize,
}

/// `CaesarSplit` needs a stale local model on the receiver; schemes send
/// `Full` to first-time participants. Degrade gracefully if one slips by.
pub fn effective_download(codec: DownloadCodec, has_local: bool) -> DownloadCodec {
    match codec {
        DownloadCodec::CaesarSplit { .. } if !has_local => DownloadCodec::Full,
        c => c,
    }
}

/// Stateless codec executor bound to a backend.
pub struct CodecEngine<'a> {
    backend: CompressionBackend,
    rt: Option<&'a Runtime>,
    task: &'a str,
}

impl<'a> CodecEngine<'a> {
    pub fn native() -> CodecEngine<'static> {
        CodecEngine { backend: CompressionBackend::Native, rt: None, task: "" }
    }

    pub fn new(
        backend: CompressionBackend,
        rt: Option<&'a Runtime>,
        task: &'a str,
    ) -> Result<CodecEngine<'a>> {
        if backend == CompressionBackend::Xla && rt.is_none() {
            return Err(anyhow!("XLA compression backend requires a runtime"));
        }
        Ok(CodecEngine { backend, rt, task })
    }

    fn xla(&self) -> &Runtime {
        self.rt.expect("xla backend without runtime")
    }

    /// PS-side: compress + serialize the global model for one device. The
    /// returned bytes are the wire truth; `bits` is their measured length.
    ///
    /// Callers that may serve a receiver WITHOUT a stale local model must
    /// resolve [`effective_download`] first (CaesarSplit degrades to Full
    /// there) — [`CodecEngine::download`] and the round engine both do.
    /// Encoding CaesarSplit for a local-less receiver is not an error, but
    /// recovery can only produce the naive sign·avg reconstruction.
    pub fn encode_download(
        &self,
        codec: DownloadCodec,
        w: &[f32],
        rng: &mut Rng,
    ) -> Result<EncodedPayload> {
        let payload = match self.backend {
            CompressionBackend::Native => codec.encode_payload(w, rng),
            CompressionBackend::Xla => match codec {
                DownloadCodec::Full => Payload::Dense(w.to_vec()),
                DownloadCodec::CaesarSplit { ratio } => self.caesar_payload_xla(w, ratio)?,
                DownloadCodec::TopK { ratio } => self.topk_payload_xla(w, ratio)?,
                DownloadCodec::Quant { bits } => self.quant_payload_xla(w, bits, rng)?,
            },
        };
        Ok(payload.encode())
    }

    /// Device-side: decode the received bytes and reconstruct the dense
    /// model, consulting the stale `local` model for the codecs that need
    /// it (`CaesarSplit` recovery, `TopK` hole-filling).
    pub fn recover_download(
        &self,
        enc: &EncodedPayload,
        local: Option<&[f32]>,
    ) -> Result<Vec<f32>> {
        match enc.decode() {
            Payload::CaesarSplit(cm) => match local {
                Some(l) => match self.backend {
                    CompressionBackend::Native => Ok(compress::caesar_recover(&cm, l)),
                    CompressionBackend::Xla => self.recover_xla(&cm, l),
                },
                // no prior: the receiver can only build the naive
                // sign·avg reconstruction
                None => Ok(cm.naive_reconstruction()),
            },
            Payload::TopK { n, indices, values } => {
                let mut model: Vec<f32> = match local {
                    Some(l) => {
                        debug_assert_eq!(l.len(), n);
                        l.to_vec()
                    }
                    None => vec![0.0; n],
                };
                for (i, v) in indices.into_iter().zip(values) {
                    model[i as usize] = v;
                }
                Ok(model)
            }
            // Dense moves its vector out; Quant dequantizes
            other => Ok(other.into_dense()),
        }
    }

    /// [`CodecEngine::recover_download`] writing into a caller-owned
    /// buffer — the round engine's form. Decodes lazily through
    /// [`PayloadView`] (no intermediate index/value/`CompressedModel`
    /// vectors) and reuses `out`'s capacity, so a worker that processes
    /// many devices recovers every download into the same allocation.
    /// Bit-identical to `recover_download` for every codec and local-model
    /// state (pinned by `tests/wire_format.rs`).
    pub fn recover_download_into(
        &self,
        enc: &EncodedPayload,
        local: Option<&[f32]>,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        match enc.view() {
            PayloadView::CaesarSplit(v) => match local {
                Some(l) => match self.backend {
                    CompressionBackend::Native => v.recover_into(l, out),
                    // the XLA kernel consumes the materialized model; the
                    // zero-copy path is native-only
                    CompressionBackend::Xla => {
                        let Payload::CaesarSplit(cm) = enc.decode() else {
                            unreachable!("CaesarSplit spec decoded to another variant")
                        };
                        let rec = self.recover_xla(&cm, l)?;
                        out.clear();
                        out.extend_from_slice(&rec);
                    }
                },
                None => v.naive_into(out),
            },
            PayloadView::TopK(v) => {
                out.clear();
                match local {
                    Some(l) => {
                        debug_assert_eq!(l.len(), v.n());
                        out.extend_from_slice(l);
                    }
                    None => out.resize(v.n(), 0.0),
                }
                v.for_each(|i, val| out[i] = val);
            }
            PayloadView::Dense(v) => v.read_into(out),
            PayloadView::Quant(v) => v.read_into(out),
        }
        Ok(())
    }

    /// Composition used by sequential drivers, tools and tests: encode,
    /// "transfer", decode + recover. `wire_bits` is the measured length.
    pub fn download(
        &self,
        codec: DownloadCodec,
        w: &[f32],
        local: Option<&[f32]>,
        rng: &mut Rng,
    ) -> Result<Recovered> {
        let enc = self.encode_download(effective_download(codec, local.is_some()), w, rng)?;
        let model = self.recover_download(&enc, local)?;
        Ok(Recovered { model, wire_bits: enc.bits })
    }

    /// Device-side: compress + serialize the local gradient for upload.
    pub fn encode_upload(
        &self,
        codec: UploadCodec,
        g: &[f32],
        rng: &mut Rng,
    ) -> Result<EncodedPayload> {
        let payload = match self.backend {
            CompressionBackend::Native => codec.encode_payload(g, rng),
            CompressionBackend::Xla => match codec {
                UploadCodec::Full => Payload::Dense(g.to_vec()),
                UploadCodec::TopK { ratio } => self.topk_payload_xla(g, ratio)?,
                UploadCodec::Quant { bits } => self.quant_payload_xla(g, bits, rng)?,
            },
        };
        Ok(payload.encode())
    }

    /// Composition for tools and tests: encode then decode back to a
    /// dense, aggregation-ready gradient (the engine's hot path folds the
    /// decoded payload sparsely instead — `AggregatorShard::fold_payload`).
    pub fn upload(&self, codec: UploadCodec, g: &[f32], rng: &mut Rng) -> Result<Uploaded> {
        let enc = self.encode_upload(codec, g, rng)?;
        Ok(Uploaded { grad: enc.decode().into_dense(), wire_bits: enc.bits })
    }

    /// Caesar compress through the L1 kernel, canonicalized to the wire
    /// invariants (kept = 0 and sign ∈ {±1} at quantized slots, sign = 0
    /// elsewhere).
    fn caesar_payload_xla(&self, w: &[f32], ratio: f64) -> Result<Payload> {
        let n = w.len();
        let out = self.xla().exec(
            &format!("compress_{}", self.task),
            &[lit_f32(w, &[n as i64])?, lit_scalar(ratio as f32)],
        )?;
        let (kept_raw, mask_raw, sign_raw) =
            (to_vec_f32(&out[0])?, to_vec_f32(&out[1])?, to_vec_f32(&out[2])?);
        let (avg_abs, max_abs) = (to_scalar_f32(&out[3])?, to_scalar_f32(&out[4])?);
        let mask: Vec<bool> = mask_raw.iter().map(|&m| m > 0.5).collect();
        let mut kept = vec![0.0f32; n];
        let mut sign = vec![0i8; n];
        for i in 0..n {
            if mask[i] {
                sign[i] = if sign_raw[i] >= 0.0 { 1 } else { -1 };
            } else {
                kept[i] = kept_raw[i];
            }
        }
        Ok(Payload::CaesarSplit(CompressedModel { kept, mask, sign, avg_abs, max_abs }))
    }

    /// Caesar recovery through the L1 kernel.
    fn recover_xla(&self, cm: &CompressedModel, local: &[f32]) -> Result<Vec<f32>> {
        let n = cm.len();
        let mask_f: Vec<f32> = cm.mask.iter().map(|&m| if m { 1.0 } else { 0.0 }).collect();
        let sign_f: Vec<f32> = cm.sign.iter().map(|&s| s as f32).collect();
        let rec = self.xla().exec(
            &format!("recover_{}", self.task),
            &[
                lit_f32(&cm.kept, &[n as i64])?,
                lit_f32(&mask_f, &[n as i64])?,
                lit_f32(&sign_f, &[n as i64])?,
                lit_scalar(cm.avg_abs),
                lit_scalar(cm.max_abs),
                lit_f32(local, &[n as i64])?,
            ],
        )?;
        to_vec_f32(&rec[0])
    }

    /// Top-K through the L1 kernel: the kernel produces the dense masked
    /// vector; ONE native selection ([`compress::topk::topk_encode`],
    /// parity-pinned to the kernel — the single owner of the
    /// inclusive-tie semantics) realizes the index set, and the wire
    /// values are the kernel's outputs at those indices.
    fn topk_payload_xla(&self, x: &[f32], ratio: f64) -> Result<Payload> {
        let n = x.len();
        let out = self.xla().exec(
            &format!("topk_{}", self.task),
            &[lit_f32(x, &[n as i64])?, lit_scalar(ratio as f32)],
        )?;
        let dense = to_vec_f32(&out[0])?;
        let (payload, _) = compress::topk::topk_encode(x, ratio);
        let Payload::TopK { indices, mut values, .. } = payload else {
            unreachable!("topk_encode produced a non-TopK payload")
        };
        // overwrite the exact-size values buffer with the kernel's outputs
        for (v, &i) in values.iter_mut().zip(&indices) {
            *v = dense[i as usize];
        }
        Ok(Payload::TopK { n, indices, values })
    }

    /// Quantization for the XLA backend. The wire payload (codes, norm,
    /// noise draws) comes from the single shared constructor
    /// `quant::quant_payload` — one RNG contract for both backends. Debug
    /// builds additionally run the L1 kernel over the same inputs and
    /// cross-check it against the wire codes (the parity pin); release
    /// builds skip the kernel exec entirely — its output is never the
    /// returned value, the wire is.
    fn quant_payload_xla(&self, x: &[f32], bits: u32, rng: &mut Rng) -> Result<Payload> {
        let (payload, noise) = quant::quant_payload(x, bits, rng);
        if cfg!(debug_assertions) {
            let n = x.len();
            let levels = quant::levels_for_bits(bits);
            let zeros;
            let noise: &[f32] = match &noise {
                Some(buf) => &buf[..],
                None => {
                    zeros = vec![0.0f32; n];
                    &zeros
                }
            };
            let out = self.xla().exec(
                &format!("quantize_{}", self.task),
                &[
                    lit_f32(x, &[n as i64])?,
                    lit_scalar(levels as f32),
                    lit_f32(noise, &[n as i64])?,
                ],
            )?;
            let kernel = to_vec_f32(&out[0])?;
            if let Payload::Quant { levels, norm, codes, .. } = &payload {
                for (i, &k) in kernel.iter().enumerate() {
                    let v = quant::dequantize_code(codes[i], *levels, *norm);
                    debug_assert!(
                        (k - v).abs() <= 1e-5 * (1.0 + k.abs()),
                        "quantize kernel drift at {i}: kernel {k} vs wire {v}"
                    );
                }
            }
        }
        Ok(payload)
    }
}

/// Expose the caesar codec's intermediate form for diagnostics (Fig. 1c).
pub fn caesar_compressed(w: &[f32], ratio: f64) -> CompressedModel {
    compress::caesar_compress(w, ratio)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::traffic;
    use crate::util::stats;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn full_download_is_identity() {
        let w = randn(512, 0);
        let e = CodecEngine::native();
        let r = e.download(DownloadCodec::Full, &w, None, &mut Rng::new(1)).unwrap();
        assert_eq!(r.model, w);
        assert_eq!(r.wire_bits, 512 * 32);
    }

    #[test]
    fn caesar_download_recovers_with_fresh_local() {
        let w = randn(1024, 2);
        let e = CodecEngine::native();
        let r = e
            .download(DownloadCodec::CaesarSplit { ratio: 0.5 }, &w, Some(&w), &mut Rng::new(1))
            .unwrap();
        assert_eq!(r.model, w);
        assert!(r.wire_bits < 1024 * 32);
    }

    #[test]
    fn caesar_download_without_local_degrades_to_full() {
        let w = randn(256, 3);
        let e = CodecEngine::native();
        let r = e
            .download(DownloadCodec::CaesarSplit { ratio: 0.5 }, &w, None, &mut Rng::new(1))
            .unwrap();
        assert_eq!(r.model, w);
        assert_eq!(r.wire_bits, 256 * 32);
    }

    #[test]
    fn topk_download_fills_dropped_from_local() {
        let w = randn(512, 4);
        let local = randn(512, 5);
        let e = CodecEngine::native();
        let r = e
            .download(DownloadCodec::TopK { ratio: 0.5 }, &w, Some(&local), &mut Rng::new(1))
            .unwrap();
        let thr = compress::topk::keep_threshold(&w, 0.5).0;
        for i in 0..512 {
            if w[i].abs() >= thr {
                assert_eq!(r.model[i], w[i]);
            } else {
                assert_eq!(r.model[i], local[i]);
            }
        }
    }

    #[test]
    fn topk_download_without_local_zero_fills() {
        let w = randn(512, 6);
        let e = CodecEngine::native();
        let r = e
            .download(DownloadCodec::TopK { ratio: 0.9 }, &w, None, &mut Rng::new(1))
            .unwrap();
        let zeros = r.model.iter().filter(|&&x| x == 0.0).count();
        assert!(zeros >= 450, "zeros={zeros}");
    }

    #[test]
    fn quant_download_error_shrinks_with_bits() {
        let w = randn(4096, 7);
        let e = CodecEngine::native();
        let mut prev = f64::MAX;
        for bits in [2u32, 4, 8] {
            let r = e
                .download(DownloadCodec::Quant { bits }, &w, None, &mut Rng::new(9))
                .unwrap();
            let err = stats::mse(&r.model, &w);
            assert!(err < prev, "bits={bits} err={err}");
            prev = err;
        }
    }

    #[test]
    fn upload_topk_bits_smaller_than_full() {
        let g = randn(2048, 8);
        let e = CodecEngine::native();
        let f = e.upload(UploadCodec::Full, &g, &mut Rng::new(1)).unwrap();
        let s = e.upload(UploadCodec::TopK { ratio: 0.6 }, &g, &mut Rng::new(1)).unwrap();
        assert!(s.wire_bits < f.wire_bits);
        let nz = s.grad.iter().filter(|&&x| x != 0.0).count();
        assert!((nz as f64) < 0.5 * 2048.0);
    }

    #[test]
    fn upload_quant_preserves_sign() {
        let g = randn(1024, 9);
        let e = CodecEngine::native();
        let u = e.upload(UploadCodec::Quant { bits: 4 }, &g, &mut Rng::new(2)).unwrap();
        for (a, b) in g.iter().zip(&u.grad) {
            assert!(*b == 0.0 || a.signum() == b.signum());
        }
    }

    #[test]
    fn wire_bits_are_measured_and_match_legacy_formulas() {
        let e = CodecEngine::native();
        let w = randn(777, 10); // odd size: exercises padding paths
        let r = e
            .download(DownloadCodec::CaesarSplit { ratio: 0.35 }, &w, Some(&w), &mut Rng::new(3))
            .unwrap();
        let cm = compress::caesar_compress(&w, 0.35);
        assert_eq!(r.wire_bits, traffic::caesar_model_bits(777, cm.n_quantized()));
        let g = randn(777, 11);
        let u = e.upload(UploadCodec::TopK { ratio: 0.8 }, &g, &mut Rng::new(4)).unwrap();
        let kept = u.grad.iter().filter(|&&x| x != 0.0).count();
        assert_eq!(u.wire_bits, traffic::topk_grad_bits(777, kept));
        let q = e.upload(UploadCodec::Quant { bits: 6 }, &g, &mut Rng::new(5)).unwrap();
        assert_eq!(q.wire_bits, traffic::quantized_bits(777, 6));
    }

    #[test]
    fn split_encode_recover_matches_composed_download() {
        let e = CodecEngine::native();
        let w = randn(600, 12);
        let local = randn(600, 13);
        for codec in [
            DownloadCodec::Full,
            DownloadCodec::CaesarSplit { ratio: 0.4 },
            DownloadCodec::TopK { ratio: 0.7 },
            DownloadCodec::Quant { bits: 5 },
        ] {
            let composed =
                e.download(codec, &w, Some(&local), &mut Rng::new(21)).unwrap();
            let enc = e.encode_download(codec, &w, &mut Rng::new(21)).unwrap();
            assert_eq!(enc.bits, composed.wire_bits, "{codec:?}");
            let model = e.recover_download(&enc, Some(&local)).unwrap();
            for i in 0..600 {
                assert_eq!(
                    model[i].to_bits(),
                    composed.model[i].to_bits(),
                    "{codec:?} elem {i}"
                );
            }
        }
    }

    #[test]
    fn quant_zero_vector_consumes_no_rng() {
        // the documented RNG contract: no draws on the deterministic path
        let e = CodecEngine::native();
        let zeros = vec![0.0f32; 128];
        let mut rng = Rng::new(7);
        let before = rng.clone();
        let u = e.upload(UploadCodec::Quant { bits: 4 }, &zeros, &mut rng).unwrap();
        assert_eq!(u.grad, zeros);
        let mut b = before;
        assert_eq!(rng.next_u64(), b.next_u64(), "rng advanced on zero-norm quantize");
    }

    #[test]
    fn xla_engine_requires_runtime() {
        assert!(CodecEngine::new(CompressionBackend::Xla, None, "cifar").is_err());
    }
}

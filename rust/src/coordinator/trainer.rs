//! Local-training execution backends.
//!
//! [`Trainer::Xla`] is the production three-layer path: it executes the
//! AOT train/eval HLO artifacts through the PJRT runtime (python never
//! runs). [`Trainer::Native`] is the rust oracle from `nn/` — used by
//! tests and as an artifact-free fallback; the two are pinned against each
//! other in `tests/runtime_parity.rs`.

use anyhow::{anyhow, Context, Result};

use crate::data::{Dataset, Shard};
use crate::nn::{self, MlpSpec};
use crate::runtime::{lit_f32, lit_i32, lit_scalar, to_scalar_f32, to_vec_f32, Runtime};
use crate::util::rng::Rng;
use crate::util::stats;

/// Outcome of evaluating a model on the test set.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalOutcome {
    pub accuracy: f64,
    /// AUC for binary tasks (0.5 when not binary / degenerate).
    pub auc: f64,
    pub mean_loss: f64,
}

pub enum Trainer {
    Native {
        spec: MlpSpec,
    },
    Xla {
        rt: Runtime,
        task: String,
        buckets: Vec<usize>,
        chunk: usize,
        eval_chunk: usize,
        d: usize,
        n_classes: usize,
    },
}

impl Trainer {
    pub fn native(task: &str) -> Trainer {
        Trainer::Native { spec: MlpSpec::for_task(task) }
    }

    /// Open the XLA trainer from an artifact directory.
    pub fn xla(task: &str, artifact_dir: &std::path::Path) -> Result<Trainer> {
        let rt = Runtime::open(artifact_dir)?;
        let m = rt.manifest();
        let spec = m
            .task(task)
            .ok_or_else(|| anyhow!("task {task} not in manifest"))?;
        let buckets = m.train_buckets(task);
        if buckets.is_empty() {
            return Err(anyhow!("no train buckets for {task}"));
        }
        Ok(Trainer::Xla {
            task: task.to_string(),
            buckets,
            chunk: m.chunk,
            eval_chunk: m.eval_chunk,
            d: spec.d_in,
            n_classes: spec.n_classes,
            rt,
        })
    }

    pub fn n_params(&self) -> usize {
        match self {
            Trainer::Native { spec } => spec.n_params(),
            Trainer::Xla { rt, task, .. } => rt.manifest().task(task).unwrap().n_params,
        }
    }

    pub fn init_model(&self, rng: &mut Rng) -> Vec<f32> {
        match self {
            Trainer::Native { spec } => spec.init(rng),
            Trainer::Xla { task, .. } => MlpSpec::for_task(task).init(rng),
        }
    }

    /// Runtime access for the `--compression-backend xla` path.
    pub fn runtime(&self) -> Option<&Runtime> {
        match self {
            Trainer::Xla { rt, .. } => Some(rt),
            Trainer::Native { .. } => None,
        }
    }

    /// The batch bucket the XLA path will actually execute for `batch`
    /// (largest bucket ≤ batch, or the smallest available).
    pub fn effective_batch(&self, batch: usize) -> usize {
        match self {
            Trainer::Native { .. } => batch,
            Trainer::Xla { buckets, .. } => buckets
                .iter()
                .rev()
                .find(|&&b| b <= batch)
                .copied()
                .unwrap_or(buckets[0]),
        }
    }

    /// Run `tau` local SGD iterations from `w0` on the device's shard.
    /// Batches are sampled with replacement by `rng`. Returns the final
    /// model and the mean training loss.
    pub fn train(
        &self,
        w0: &[f32],
        ds: &Dataset,
        shard: &Shard,
        tau: usize,
        batch: usize,
        lr: f32,
        rng: &mut Rng,
    ) -> Result<(Vec<f32>, f64)> {
        assert!(!shard.is_empty(), "device shard is empty");
        match self {
            Trainer::Native { spec } => {
                let mut w = w0.to_vec();
                let mut losses = 0.0;
                for _ in 0..tau {
                    let pos: Vec<usize> =
                        (0..batch).map(|_| rng.below(shard.len())).collect();
                    let (xs, ys) = shard.gather(ds, &pos);
                    losses += nn::sgd_step(spec, &mut w, &xs, &ys, batch, lr);
                }
                Ok((w, losses / tau as f64))
            }
            Trainer::Xla { rt, task, chunk, d, .. } => {
                let b = self.effective_batch(batch);
                let module = format!("train_{task}_b{b}");
                let n_chunks = tau.div_ceil(*chunk);
                let mut w = w0.to_vec();
                let mut losses = 0.0;
                for _ in 0..n_chunks {
                    let pos: Vec<usize> = (0..*chunk * b)
                        .map(|_| rng.below(shard.len()))
                        .collect();
                    let (xs, ys) = shard.gather(ds, &pos);
                    let out = rt
                        .exec(
                            &module,
                            &[
                                lit_f32(&w, &[w.len() as i64])?,
                                lit_f32(&xs, &[*chunk as i64, b as i64, *d as i64])?,
                                lit_i32(&ys, &[*chunk as i64, b as i64])?,
                                lit_scalar(lr),
                            ],
                        )
                        .with_context(|| format!("train chunk {module}"))?;
                    w = to_vec_f32(&out[0])?;
                    losses += to_scalar_f32(&out[1])? as f64;
                }
                Ok((w, losses / n_chunks as f64))
            }
        }
    }

    /// Evaluate on the whole test set (accuracy, AUC for binary tasks).
    pub fn eval(&self, w: &[f32], test: &Dataset) -> Result<EvalOutcome> {
        let n = test.len();
        let h = test.n_classes;
        let logits: Vec<f32> = match self {
            Trainer::Native { spec } => nn::apply(spec, w, &test.features, n),
            Trainer::Xla { rt, task, eval_chunk, d, .. } => {
                let module = format!("eval_{task}");
                let e = *eval_chunk;
                let mut all = Vec::with_capacity(n * h);
                let mut i = 0;
                while i < n {
                    let take = (n - i).min(e);
                    // pad the last chunk by repeating the first rows
                    let mut xs = Vec::with_capacity(e * d);
                    xs.extend_from_slice(&test.features[i * d..(i + take) * d]);
                    while xs.len() < e * d {
                        xs.extend_from_slice(&test.features[..*d]);
                    }
                    let out = rt.exec(
                        &module,
                        &[
                            lit_f32(w, &[w.len() as i64])?,
                            lit_f32(&xs, &[e as i64, *d as i64])?,
                        ],
                    )?;
                    let chunk_logits = to_vec_f32(&out[0])?;
                    all.extend_from_slice(&chunk_logits[..take * h]);
                    i += take;
                }
                all
            }
        };
        Ok(score_logits(&logits, test))
    }
}

/// Accuracy / AUC / mean CE loss from raw logits.
pub fn score_logits(logits: &[f32], test: &Dataset) -> EvalOutcome {
    let n = test.len();
    let h = test.n_classes;
    assert_eq!(logits.len(), n * h);
    let mut correct = 0usize;
    let mut loss = 0.0f64;
    let mut scores = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for i in 0..n {
        let row = &logits[i * h..(i + 1) * h];
        let y = test.labels[i] as usize;
        if stats::argmax(row) == Some(y) {
            correct += 1;
        }
        // CE via log-sum-exp
        let m = row.iter().fold(f32::MIN, |a, &b| a.max(b)) as f64;
        let lse = m + row.iter().map(|&v| (v as f64 - m).exp()).sum::<f64>().ln();
        loss += lse - row[y] as f64;
        if h == 2 {
            scores.push(row[1] - row[0]);
            labels.push(test.labels[i]);
        }
    }
    EvalOutcome {
        accuracy: correct as f64 / n.max(1) as f64,
        auc: if h == 2 { stats::auc(&scores, &labels) } else { 0.5 },
        mean_loss: loss / n.max(1) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{Shard, TaskSpec};

    fn setup(task: &str, n: usize) -> (Trainer, Dataset, Shard) {
        let spec = TaskSpec::by_name(task).unwrap();
        let ds = Dataset::generate(&spec, n, &mut Rng::new(5));
        let shard = Shard { indices: (0..n).collect() };
        (Trainer::native(task), ds, shard)
    }

    #[test]
    fn native_training_learns() {
        let (tr, ds, shard) = setup("har", 600);
        let mut rng = Rng::new(0);
        let mut w = tr.init_model(&mut rng);
        let e0 = tr.eval(&w, &ds).unwrap();
        for _ in 0..20 {
            let (w2, _) = tr.train(&w, &ds, &shard, 10, 16, 0.05, &mut rng).unwrap();
            w = w2;
        }
        let e1 = tr.eval(&w, &ds).unwrap();
        assert!(
            e1.accuracy > e0.accuracy + 0.2,
            "acc {} -> {}",
            e0.accuracy,
            e1.accuracy
        );
        assert!(e1.mean_loss < e0.mean_loss);
    }

    #[test]
    fn eval_outcome_auc_for_binary() {
        let (tr, ds, shard) = setup("oppo", 800);
        let mut rng = Rng::new(1);
        let mut w = tr.init_model(&mut rng);
        for _ in 0..30 {
            let (w2, _) = tr.train(&w, &ds, &shard, 10, 32, 0.1, &mut rng).unwrap();
            w = w2;
        }
        let e = tr.eval(&w, &ds).unwrap();
        assert!(e.auc > 0.6, "auc={}", e.auc);
    }

    #[test]
    fn effective_batch_is_identity_for_native() {
        let (tr, _, _) = setup("cifar", 10);
        assert_eq!(tr.effective_batch(17), 17);
    }

    #[test]
    fn score_logits_counts_correctly() {
        let spec = TaskSpec::har_like();
        let mut ds = Dataset::generate(&spec, 4, &mut Rng::new(2));
        ds.labels = vec![0, 1, 2, 3];
        // logits that put all mass on the true label for first 3 samples
        let h = ds.n_classes;
        let mut logits = vec![0.0f32; 4 * h];
        for i in 0..3 {
            logits[i * h + ds.labels[i] as usize] = 10.0;
        }
        logits[3 * h + ((ds.labels[3] as usize + 1) % h)] = 10.0; // wrong
        let out = score_logits(&logits, &ds);
        assert!((out.accuracy - 0.75).abs() < 1e-12);
    }
}

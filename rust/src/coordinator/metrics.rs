//! Per-round metrics, run results, and CSV/JSON export.

use std::io::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use crate::util::json::{self, Json};

/// One communication round's record.
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundRecord {
    /// 1-based round index.
    pub t: usize,
    /// Cumulative simulated wall-clock (s).
    pub sim_time_s: f64,
    /// Cumulative traffic (GB, paper-scale payloads).
    pub traffic_gb: f64,
    /// Test accuracy (NaN when not evaluated this round).
    pub accuracy: f64,
    /// Test AUC for binary tasks.
    pub auc: f64,
    pub mean_loss: f64,
    /// This round's duration (max over participants).
    pub round_s: f64,
    /// Mean idle waiting across participants this round.
    pub avg_wait_s: f64,
    pub participants: usize,
}

/// Result of one full FL run.
#[derive(Clone, Debug)]
pub struct RunResult {
    pub scheme: String,
    pub task: String,
    pub seed: u64,
    pub records: Vec<RoundRecord>,
    /// (round, sim_time_s, traffic_gb) at first reaching the target metric.
    pub reached_target: Option<(usize, f64, f64)>,
    pub target: f64,
}

impl RunResult {
    /// Last evaluated accuracy (or AUC for binary tasks if `use_auc`).
    pub fn final_metric(&self, use_auc: bool) -> f64 {
        self.records
            .iter()
            .rev()
            .find(|r| !r.accuracy.is_nan())
            .map(|r| if use_auc { r.auc } else { r.accuracy })
            .unwrap_or(0.0)
    }

    /// Best (max) metric over the run.
    pub fn best_metric(&self, use_auc: bool) -> f64 {
        self.records
            .iter()
            .filter(|r| !r.accuracy.is_nan())
            .map(|r| if use_auc { r.auc } else { r.accuracy })
            .fold(0.0, f64::max)
    }

    /// Mean per-round waiting time across the run.
    pub fn mean_wait_s(&self) -> f64 {
        let xs: Vec<f64> = self.records.iter().map(|r| r.avg_wait_s).collect();
        crate::util::stats::mean(&xs)
    }

    pub fn total_time_s(&self) -> f64 {
        self.records.last().map(|r| r.sim_time_s).unwrap_or(0.0)
    }

    pub fn total_traffic_gb(&self) -> f64 {
        self.records.last().map(|r| r.traffic_gb).unwrap_or(0.0)
    }

    /// First round whose *evaluated* metric reaches `target`; returns the
    /// cumulative (time, traffic) there.
    pub fn time_traffic_at(&self, target: f64, use_auc: bool) -> Option<(f64, f64)> {
        self.records
            .iter()
            .find(|r| {
                !r.accuracy.is_nan()
                    && (if use_auc { r.auc } else { r.accuracy }) >= target
            })
            .map(|r| (r.sim_time_s, r.traffic_gb))
    }

    /// CSV with one row per round.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "round,sim_time_s,traffic_gb,accuracy,auc,mean_loss,round_s,avg_wait_s,participants\n",
        );
        for r in &self.records {
            out.push_str(&format!(
                "{},{:.3},{:.6},{},{},{:.5},{:.3},{:.3},{}\n",
                r.t,
                r.sim_time_s,
                r.traffic_gb,
                if r.accuracy.is_nan() { String::new() } else { format!("{:.4}", r.accuracy) },
                if r.accuracy.is_nan() { String::new() } else { format!("{:.4}", r.auc) },
                r.mean_loss,
                r.round_s,
                r.avg_wait_s,
                r.participants
            ));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("scheme", json::s(&self.scheme))
            .set("task", json::s(&self.task))
            .set("seed", json::num(self.seed as f64))
            .set("target", json::num(self.target))
            .set("final_accuracy", json::num(self.final_metric(false)))
            .set("final_auc", json::num(self.final_metric(true)))
            .set("total_time_s", json::num(self.total_time_s()))
            .set("total_traffic_gb", json::num(self.total_traffic_gb()))
            .set("mean_wait_s", json::num(self.mean_wait_s()));
        if let Some((t, time, gb)) = self.reached_target {
            let mut r = Json::obj();
            r.set("round", json::num(t as f64))
                .set("time_s", json::num(time))
                .set("traffic_gb", json::num(gb));
            j.set("reached_target", r);
        }
        let rounds: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("t", json::num(r.t as f64))
                    .set("time", json::num(r.sim_time_s))
                    .set("gb", json::num(r.traffic_gb))
                    .set("acc", if r.accuracy.is_nan() { Json::Null } else { json::num(r.accuracy) })
                    .set("wait", json::num(r.avg_wait_s));
                o
            })
            .collect();
        j.set("rounds", Json::Arr(rounds));
        j
    }

    /// Write `<dir>/<scheme>_<task>[_suffix].{csv,json}`.
    pub fn save(&self, dir: &Path, suffix: &str) -> Result<()> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("mkdir {}", dir.display()))?;
        let stem = if suffix.is_empty() {
            format!("{}_{}", self.scheme, self.task)
        } else {
            format!("{}_{}_{}", self.scheme, self.task, suffix)
        };
        let mut f = std::fs::File::create(dir.join(format!("{stem}.csv")))?;
        f.write_all(self.to_csv().as_bytes())?;
        let mut f = std::fs::File::create(dir.join(format!("{stem}.json")))?;
        f.write_all(self.to_json().to_string().as_bytes())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(t: usize, acc: f64, time: f64, gb: f64) -> RoundRecord {
        RoundRecord {
            t,
            sim_time_s: time,
            traffic_gb: gb,
            accuracy: acc,
            auc: acc,
            mean_loss: 1.0,
            round_s: 10.0,
            avg_wait_s: 2.0,
            participants: 8,
        }
    }

    fn run() -> RunResult {
        RunResult {
            scheme: "caesar".into(),
            task: "cifar".into(),
            seed: 1,
            records: vec![
                rec(1, 0.3, 10.0, 1.0),
                rec(2, f64::NAN, 20.0, 2.0),
                rec(3, 0.7, 30.0, 3.0),
                rec(4, 0.8, 40.0, 4.0),
            ],
            reached_target: Some((4, 40.0, 4.0)),
            target: 0.8,
        }
    }

    #[test]
    fn final_metric_skips_unevaluated() {
        let r = run();
        assert_eq!(r.final_metric(false), 0.8);
        assert_eq!(r.best_metric(false), 0.8);
    }

    #[test]
    fn time_traffic_at_target() {
        let r = run();
        assert_eq!(r.time_traffic_at(0.7, false), Some((30.0, 3.0)));
        assert_eq!(r.time_traffic_at(0.9, false), None);
    }

    #[test]
    fn csv_has_header_and_rows() {
        let c = run().to_csv();
        assert!(c.starts_with("round,"));
        assert_eq!(c.lines().count(), 5);
        // NaN accuracy renders as empty field
        let row2: Vec<&str> = c.lines().nth(2).unwrap().split(',').collect();
        assert_eq!(row2[3], "");
    }

    #[test]
    fn json_roundtrips() {
        let j = run().to_json();
        let parsed = crate::util::json::Json::parse(&j.to_string()).unwrap();
        assert_eq!(parsed.get("scheme").unwrap().as_str(), Some("caesar"));
        assert_eq!(
            parsed
                .get("reached_target")
                .unwrap()
                .get("round")
                .unwrap()
                .as_usize(),
            Some(4)
        );
        assert_eq!(parsed.get("rounds").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn save_writes_files() {
        let dir = std::env::temp_dir().join("caesar_metrics_test");
        let _ = std::fs::remove_dir_all(&dir);
        run().save(&dir, "p5").unwrap();
        assert!(dir.join("caesar_cifar_p5.csv").exists());
        assert!(dir.join("caesar_cifar_p5.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}

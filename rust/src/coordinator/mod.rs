//! Layer-3 coordinator: the synchronous FL round loop (paper Algorithm 1).
//!
//! The [`Server`] owns the global model, the simulated device fleet, the
//! non-IID data partition, the participation tracker, the traffic meter
//! and the simulated clock. Each round it (1) selects participants,
//! (2) asks the configured [`Scheme`] for a per-device plan (codec +
//! batch + τ), (3) hands the plans to the event-driven [`crate::engine`]
//! as `StartRound` messages — which executes downloads, local training and
//! uploads (in parallel when `cfg.engine.workers > 1`) and streams the
//! updates back through sharded order-exact aggregation — then (4) applies
//! the round output to the global model and (5) records metrics. Training
//! runs REAL SGD (native or AOT HLO via PJRT); time and traffic are
//! simulated at paper scale per DESIGN.md §Substitutions.
//!
//! The engine is configuration-transparent: with the default
//! `engine.workers = 1` the round executes sequentially on this thread,
//! and any other worker count produces bit-identical results
//! (`tests/engine_parity.rs`).
//!
//! Trainers are **run-lifetime** resources: the Server owns an
//! [`engine::ExecutorHandle`] built once at construction — an inline
//! trainer for `workers <= 1`, or a persistent worker pool whose threads
//! each own a trainer (and PJRT runtime) for the whole run. Rounds no
//! longer rebuild factories or closures; evaluation routes through the
//! same executor.

pub mod codec;
pub mod metrics;
pub mod trainer;

pub use codec::CodecEngine;
pub use metrics::{RoundRecord, RunResult};
pub use trainer::{EvalOutcome, Trainer};

use std::sync::Arc;

use anyhow::{anyhow, Context, Result};

use crate::caesar::{ImportanceTable, ParticipationTracker};
use crate::compress::traffic::{PayloadScale, TrafficMeter};
use crate::config::{CompressionBackend, ExperimentConfig};
use crate::coordinator::codec::effective_download;
use crate::data::{self, Dataset, Partition, TaskSpec};
use crate::engine::{self, Engine, ExecutorHandle, ExternalRound, LateUpload, StartRound};
use crate::fleet::Fleet;
use crate::journal::{self, record as jrec, RunJournal};
use crate::nn::MlpSpec;
use crate::schemes::{RoundCtx, Scheme};
use crate::runtime::Runtime;
use crate::util::rng::{Rng, RngState};
use crate::wire::EncodedPayload;

/// Stream-key salt for per-(round, device) link-bandwidth draws.
const LINK_SALT: u64 = 0x11C4;

/// Regenerate the run's data artifacts from a config, replaying the exact
/// server-side fork order (`0xDA7A` train → `0x7E57` test → `0xD1FF`
/// partition). The single source of truth shared by
/// [`Server::with_artifacts`] and `transport::client::DeviceClient` — a
/// remote device rebuilds bit-identical datasets and shard assignment
/// from nothing but the config, so payload frames never carry data. The
/// returned [`Rng`] has consumed exactly those three forks; the server
/// continues it for model init and stream keys.
pub(crate) fn build_data(
    cfg: &ExperimentConfig,
) -> Result<(Dataset, Dataset, Partition, Rng)> {
    let mut rng = Rng::new(cfg.seed);
    let spec =
        TaskSpec::by_name(&cfg.task).with_context(|| format!("unknown task {}", cfg.task))?;
    let train_ds = Dataset::generate(&spec, cfg.n_train, &mut rng.fork(0xDA7A));
    let test_ds = Dataset::generate(&spec, cfg.n_test, &mut rng.fork(0x7E57));
    let partition = data::partition(&train_ds, cfg.n_devices(), cfg.het_p, &mut rng.fork(0xD1FF));
    Ok((train_ds, test_ds, partition, rng))
}

/// The federated-learning server (PS) plus the simulated testbed.
pub struct Server {
    pub cfg: ExperimentConfig,
    scheme: Box<dyn Scheme>,
    fleet: Fleet,
    train_ds: Dataset,
    test_ds: Dataset,
    partition: Partition,
    importance: ImportanceTable,
    tracker: ParticipationTracker,
    /// Run-lifetime trainer resource: an inline trainer or a persistent
    /// worker pool, reused by every round AND by evaluation.
    executor: ExecutorHandle,
    scale: PayloadScale,
    /// Current global model (flat parameter vector).
    pub global: Vec<f32>,
    /// Monotone version of `global`: bumped whenever a round actually
    /// moves the model. Keys the engine's cross-round download-encode
    /// cache — consecutive rounds at the same version reuse encodes.
    model_version: u64,
    /// Per-device stale local models (None until first participation).
    locals: Vec<Option<Vec<f32>>>,
    /// Last observed ||g_i|| per device (PyramidFL's ranking signal).
    grad_norms: Vec<f64>,
    traffic: TrafficMeter,
    sim_time_s: f64,
    rng: Rng,
    /// Base key of the pure per-(round, device) RNG streams.
    stream_base: u64,
    /// The event-driven round engine (state machine + encode cache).
    engine: Engine,
    /// Semi-async staleness buffer: stragglers' uploads parked at their
    /// origin round's close, waiting for their fold round. Kept in
    /// (origin round, device) order — closes are sequential and each
    /// close appends in device order, so no re-sort is ever needed.
    late_buffer: Vec<LateUpload>,
    /// Consecutive completed rounds during which the worker pool ran
    /// short-handed (a worker panicked and retired). Drives the
    /// self-healing respawn in [`Server::maintain_workers`].
    short_rounds: usize,
}

/// Everything measured in one executed round.
pub(crate) struct RoundOutcome {
    pub(crate) round_s: f64,
    pub(crate) avg_wait_s: f64,
    pub(crate) mean_loss: f64,
}

/// Everything a remote device needs to execute one round — the
/// coordinator→device kickoff in networked mode, carried by a
/// `transport::frame` StartRound frame. Bundles the in-process
/// [`StartRound`] item with the run context the simulated path reads out
/// of [`engine::RoundEnv`] (which a remote device cannot see): the
/// learning rate, the dropout/heartbeat knobs, the simulated clock, the
/// RNG stream key — and, crucially, the device stream's exact
/// [`RngState`] *after* the PS-side download encode, so the remote draw
/// sequence continues bit-identically to the loopback engine's.
#[derive(Clone, Debug)]
pub struct NetworkedStart {
    pub item: StartRound,
    pub lr: f32,
    /// Device stream state after the PS-side download encode consumed its
    /// draws (RNG-drawing download codecs); the device resumes from here.
    pub rng: RngState,
    /// Base key of the per-(round, device) streams (fate + link salts).
    pub stream_base: u64,
    pub dropout_rate: f64,
    pub heartbeat_s: f64,
    /// Simulated wall-clock at round start.
    pub sim_now_s: f64,
    /// `transport::model_digest` of the coordinator's retained local
    /// model for this device (`None` if it has none). The recovery prior
    /// the PS *encoded against* — the device must recover against the
    /// model with this exact digest (or none), otherwise the sides have
    /// diverged (e.g. the coordinator synthesized a Dropout after the
    /// device advanced) and the device must resync instead of silently
    /// training from a mismatched prior.
    pub prior_digest: Option<u64>,
    /// The encoded download payload — the same `Arc`'d bytes every
    /// co-participant with this effective codec receives.
    pub download: Arc<EncodedPayload>,
}

impl Server {
    /// Build a server from a config and scheme, reading AOT artifacts from
    /// [`Runtime::default_dir`] when the XLA trainer is configured.
    pub fn new(cfg: ExperimentConfig, scheme: Box<dyn Scheme>) -> Result<Server> {
        Self::with_artifacts(cfg, scheme, &Runtime::default_dir())
    }

    /// Build a server with an explicit artifact directory.
    pub fn with_artifacts(
        cfg: ExperimentConfig,
        scheme: Box<dyn Scheme>,
        artifact_dir: &std::path::Path,
    ) -> Result<Server> {
        let (train_ds, test_ds, partition, mut rng) = build_data(&cfg)?;
        let n = cfg.n_devices();

        // Static importance table (Eq. 4–5), computed once before training
        // exactly as §4.2 prescribes.
        let volumes: Vec<usize> = partition.shards.iter().map(|s| s.len()).collect();
        let kls: Vec<f64> = partition
            .shards
            .iter()
            .map(|s| s.kl_from_uniform(&train_ds))
            .collect();
        let importance = ImportanceTable::build(&volumes, &kls, cfg.lambda);

        // Run-lifetime executor: the inline trainer, or a persistent pool
        // whose workers each build their trainer once, on their own thread.
        let executor = ExecutorHandle::build(&cfg, artifact_dir)
            .with_context(|| format!("open artifacts at {}", artifact_dir.display()))?;
        let scale = PayloadScale { n_real: executor.n_params()?, n_paper: cfg.n_params_paper };
        // Init is spec-level (both trainer backends defer to MlpSpec), so
        // the coordinator thread never needs a trainer of its own.
        let global = MlpSpec::for_task(&cfg.task).init(&mut rng.fork(0x1417));
        let fleet = Fleet::new(cfg.fleet, cfg.seed);
        let stream_base = rng.fork(0x57EA).next_u64();
        let engine = Engine::new(cfg.engine, n);

        Ok(Server {
            tracker: ParticipationTracker::new(n),
            locals: vec![None; n],
            grad_norms: vec![0.0; n],
            traffic: TrafficMeter::default(),
            sim_time_s: 0.0,
            model_version: 0,
            late_buffer: Vec::new(),
            short_rounds: 0,
            scheme,
            fleet,
            train_ds,
            test_ds,
            partition,
            importance,
            executor,
            scale,
            global,
            stream_base,
            engine,
            cfg,
            rng,
        })
    }

    pub fn scheme_name(&self) -> &'static str {
        self.scheme.name()
    }

    /// Whether the target metric for this task is AUC (binary tasks).
    pub fn uses_auc(&self) -> bool {
        self.test_ds.n_classes == 2
    }

    /// Per-device sample volumes (diagnostics / Fig. 1d).
    pub fn volumes(&self) -> Vec<usize> {
        self.partition.shards.iter().map(|s| s.len()).collect()
    }

    pub fn importance_table(&self) -> &ImportanceTable {
        &self.importance
    }

    /// The event-driven round engine (phase, registry, message stats).
    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    /// Mutable engine access for the networked driver
    /// (`transport::server` feeds decoded frames into an external round).
    pub(crate) fn engine_mut(&mut self) -> &mut Engine {
        &mut self.engine
    }

    /// Cumulative traffic ledger (down/up bits, measured off the wire).
    pub fn traffic(&self) -> &TrafficMeter {
        &self.traffic
    }

    /// Simulated wall-clock, seconds since the run started.
    pub fn sim_time_s(&self) -> f64 {
        self.sim_time_s
    }

    /// Monotone global-model version (bumped when a round moves the model).
    pub fn model_version(&self) -> u64 {
        self.model_version
    }

    /// The current global model (what `transport::model_digest` should
    /// fingerprint for cross-transport parity checks).
    pub fn model(&self) -> &[f32] {
        &self.global
    }

    /// Participation tracker (staleness bookkeeping) — read access for
    /// diagnostics and tests.
    pub fn tracker(&self) -> &ParticipationTracker {
        &self.tracker
    }

    /// Evaluate the current global model on the held-out test set (pool
    /// mode runs this as a one-item batch on a worker's trainer).
    pub fn evaluate(&self) -> Result<EvalOutcome> {
        self.executor.eval(&self.global, &self.test_ds)
    }

    /// Execute rounds 1..=cfg.rounds, recording metrics every round and
    /// evaluating every `cfg.eval_every` rounds. `cb` observes each record
    /// as it is produced (progress printing).
    pub fn run_cb(&mut self, mut cb: impl FnMut(&RoundRecord)) -> Result<RunResult> {
        if self.pipelined() {
            return self.run_pipelined_cb(None, cb);
        }
        let mut records = Vec::with_capacity(self.cfg.rounds);
        let mut reached: Option<(usize, f64, f64)> = None;
        for t in 1..=self.cfg.rounds {
            let out = self.round(t)?;
            let rec = self.observe_round(t, &out, &mut reached)?;
            self.maintain_workers();
            cb(&rec);
            records.push(rec);
        }
        Ok(self.finish_run(records, reached))
    }

    /// Evaluate + record one applied round: the metrics block shared by
    /// the in-process loop and `transport::server::CoordinatorService`.
    pub(crate) fn observe_round(
        &mut self,
        t: usize,
        out: &RoundOutcome,
        reached: &mut Option<(usize, f64, f64)>,
    ) -> Result<RoundRecord> {
        let evaluated = t % self.cfg.eval_every == 0 || t == self.cfg.rounds;
        let (acc, auc) = if evaluated {
            let e = self.evaluate()?;
            (e.accuracy, e.auc)
        } else {
            (f64::NAN, f64::NAN)
        };
        let rec = RoundRecord {
            t,
            sim_time_s: self.sim_time_s,
            traffic_gb: self.traffic.total_gb(),
            accuracy: acc,
            auc,
            mean_loss: out.mean_loss,
            round_s: out.round_s,
            avg_wait_s: out.avg_wait_s,
            participants: self.cfg.participants_per_round(),
        };
        if reached.is_none() && evaluated {
            let metric = if self.uses_auc() { auc } else { acc };
            if metric >= self.cfg.target_acc {
                *reached = Some((t, self.sim_time_s, self.traffic.total_gb()));
            }
        }
        Ok(rec)
    }

    /// Assemble the final [`RunResult`] from per-round records.
    pub(crate) fn finish_run(
        &self,
        records: Vec<RoundRecord>,
        reached: Option<(usize, f64, f64)>,
    ) -> RunResult {
        RunResult {
            scheme: self.scheme.name().to_string(),
            task: self.cfg.task.clone(),
            seed: self.cfg.seed,
            records,
            reached_target: reached,
            target: self.cfg.target_acc,
        }
    }

    /// [`run_cb`] without a progress observer.
    pub fn run(&mut self) -> Result<RunResult> {
        self.run_cb(|_| {})
    }

    /// One communication round (1-based `t`). Public for step-by-step
    /// drivers (examples, benches).
    pub fn step(&mut self, t: usize) -> Result<()> {
        self.round(t).map(|_| ())
    }

    fn round(&mut self, t: usize) -> Result<RoundOutcome> {
        let (items, lr) = self.plan_round(t);
        let env = engine::RoundEnv {
            t,
            lr,
            cfg: &self.cfg,
            global: &self.global,
            model_version: self.model_version,
            locals: &self.locals,
            train_ds: &self.train_ds,
            partition: &self.partition,
            scale: &self.scale,
            stream_base: self.stream_base,
            sim_now_s: self.sim_time_s,
        };
        // the same run-lifetime executor every round: pool workers keep
        // their trainers, runtimes and thread-local scratch warm
        let out = self.engine.execute_round(&env, &items, &self.executor)?;
        Ok(self.apply_round(t, out))
    }

    /// Rounds 1..t-1 planning side: participant selection, link draws and
    /// the scheme's per-device plans, emitted as [`StartRound`] items.
    /// Consumes this round's draws from the server RNG — call exactly
    /// once per round, whichever loop (in-process or networked) executes
    /// it.
    pub(crate) fn plan_round(&mut self, t: usize) -> (Vec<StartRound>, f32) {
        assert!(t >= 1, "rounds are 1-based (Eq. 3 divides by t)");
        self.fleet.on_round_start(t);
        let cfg = self.cfg.clone();
        let k = cfg.participants_per_round();
        let participants = self.rng.sample_indices(self.fleet.len(), k);

        // --- gather the planning context ---
        let staleness: Vec<usize> =
            participants.iter().map(|&d| self.tracker.staleness(d, t)).collect();
        let never: Vec<bool> =
            participants.iter().map(|&d| self.tracker.never_participated(d)).collect();
        let mut beta_d = Vec::with_capacity(k);
        let mut beta_u = Vec::with_capacity(k);
        let mut mu = Vec::with_capacity(k);
        {
            let Fleet { devices, bandwidth } = &self.fleet;
            for &d in &participants {
                // pure per-(round, device) stream: draws are independent of
                // participant iteration order (prerequisite for parallelism)
                let mut link_rng =
                    Rng::stream(self.stream_base ^ LINK_SALT, t as u64, d as u64);
                let (bd, bu) = devices[d].draw_bandwidth(bandwidth, &mut link_rng);
                beta_d.push(bd);
                beta_u.push(bu);
                mu.push(devices[d].mu(cfg.model_cost));
            }
        }
        let plans = {
            let ctx = RoundCtx {
                t,
                participants: &participants,
                staleness: &staleness,
                never: &never,
                beta_d: &beta_d,
                beta_u: &beta_u,
                mu: &mu,
                q_bits: self.scale.q_bits(),
                importance: &self.importance,
                grad_norms: &self.grad_norms,
                cfg: &cfg,
            };
            self.scheme.plan_round(&ctx)
        };
        assert_eq!(plans.len(), k, "scheme must plan every participant");

        // --- hand the round to the engine as StartRound messages ---
        let lr = cfg.lr_at(t - 1) as f32;
        let items: Vec<StartRound> = plans
            .iter()
            .enumerate()
            .map(|(i, &plan)| StartRound { t, plan, beta_d: beta_d[i], beta_u: beta_u[i], mu: mu[i] })
            .collect();
        (items, lr)
    }

    /// Apply a drained round's output to the server state — traffic,
    /// locals, tracker, global aggregation, simulated clock — in
    /// canonical (device-id) order. The single application path shared by
    /// the in-process loop and the networked coordinator: a
    /// [`engine::RoundOutput`] is applied identically whether its updates
    /// arrived from worker threads or off a socket.
    pub(crate) fn apply_round(&mut self, t: usize, out: engine::RoundOutput) -> RoundOutcome {
        let engine::RoundOutput { agg, updates, dropped } = out;

        // --- apply the round output in canonical (device-id) order ---
        // traffic is derived from the measured wire lengths of the actual
        // serialized payloads (scaled to paper size), not from formulas
        let completers = updates.len();
        let mut costs: Vec<f64> = Vec::with_capacity(completers);
        let mut loss_sum = 0.0f64;
        for u in updates {
            self.traffic.add_down(self.scale.scale_bits(u.down_wire_bits));
            self.traffic.add_up(self.scale.scale_bits(u.upload.bits));
            self.grad_norms[u.device] = u.grad_norm;
            self.locals[u.device] = Some(u.w_final);
            self.tracker.record(u.device, t);
            loss_sum += u.loss;
            costs.push(u.cost.total());
        }
        for d in &dropped {
            // a dropped device consumed its download before vanishing; it
            // contributes no update and its staleness keeps growing
            self.traffic.add_down(self.scale.scale_bits(d.down_wire_bits));
        }

        // --- global aggregation: w ← w − mean(ḡ) over completers (§2.1) ---
        if completers > 0 {
            let inv = 1.0 / completers as f64;
            // agg is chunk-sharded; iteration yields ascending elements,
            // bit-identical to the flat vector it replaced
            for (w, a) in self.global.iter_mut().zip(agg.iter()) {
                *w -= (a * inv) as f32;
            }
            // the model moved: downloads encoded for the old version are
            // stale, so the engine's cross-round cache must turn over
            self.model_version += 1;
        }

        // --- synchronous barrier timing (dropouts hold the barrier until
        // the PS notices them vanish) ---
        let round_s = costs
            .iter()
            .copied()
            .chain(dropped.iter().map(|d| d.after_s))
            .fold(0.0f64, f64::max);
        let avg_wait_s = if completers > 0 {
            costs.iter().map(|&c| round_s - c).sum::<f64>() / completers as f64
        } else {
            0.0
        };
        self.sim_time_s += round_s;
        let mean_loss = if completers > 0 { loss_sum / completers as f64 } else { f64::NAN };
        RoundOutcome { round_s, avg_wait_s, mean_loss }
    }

    /// Open round `t` for **networked** execution: plan exactly as the
    /// in-process loop would, encode each participant's download through
    /// the engine's shared cache, and return the engine's
    /// [`ExternalRound`] plus one [`NetworkedStart`] per participant
    /// (ascending device id — the canonical order the frames go out in).
    ///
    /// RNG alignment is the subtle part: the simulated path draws the
    /// PS-side download encode from the *device's* stream before handing
    /// the stream to training, so each kickoff captures the post-encode
    /// [`RngState`] for the remote device to resume from. Everything else
    /// a device needs is derivable from the shared config.
    pub(crate) fn begin_networked_round(
        &mut self,
        t: usize,
    ) -> Result<(ExternalRound, Vec<NetworkedStart>)> {
        if self.cfg.compression != CompressionBackend::Native {
            return Err(anyhow!(
                "networked rounds require the native compression backend \
                 (the coordinator thread owns no accelerator runtime)"
            ));
        }
        let (mut items, lr) = self.plan_round(t);
        // canonical (ascending device) order for kickoff + aggregation
        items.sort_by_key(|it| it.plan.device);
        let devices: Vec<usize> = items.iter().map(|it| it.plan.device).collect();
        let n_params = self.global.len();
        let round = self.engine.begin_external(
            t,
            self.model_version,
            self.sim_time_s,
            &devices,
            n_params,
        )?;
        let codec = CodecEngine::native();
        let ecfg = self.engine.config();
        let mut starts = Vec::with_capacity(items.len());
        for item in items {
            let d = item.plan.device;
            let has_local = self.locals[d].is_some();
            let down_codec = effective_download(item.plan.download, has_local);
            // same stream, same draw order as `engine::run_device`: the
            // PS-side encode consumes the device stream's first draws
            let mut dev_rng = Rng::stream(self.stream_base, t as u64, d as u64);
            let download = self.engine.cache().get_or_encode(
                &codec,
                down_codec,
                &self.global,
                has_local,
                &mut dev_rng,
            )?;
            starts.push(NetworkedStart {
                item,
                lr,
                rng: dev_rng.state(),
                stream_base: self.stream_base,
                dropout_rate: ecfg.dropout_rate,
                heartbeat_s: ecfg.heartbeat_s,
                sim_now_s: self.sim_time_s,
                prior_digest: self.locals[d].as_deref().map(crate::transport::model_digest),
                download,
            });
        }
        Ok((round, starts))
    }
}

// ---------------------------------------------------------------------
// durable rounds: the journaled run loop + crash resume
// ---------------------------------------------------------------------

impl Server {
    /// Open a journaled run: resume from `path` when it holds a valid
    /// run-header + snapshot prefix for this exact config and scheme.
    /// Starts fresh only over a missing/empty file or a torn crash-at-
    /// birth prefix that never reached its initial snapshot; a non-empty
    /// file this build cannot decode (version skew, corruption, foreign
    /// bytes) is an error, never silently truncated. Artifacts come from
    /// [`Runtime::default_dir`].
    pub fn journaled_open(
        cfg: ExperimentConfig,
        scheme: Box<dyn Scheme>,
        path: &std::path::Path,
        snapshot_every: usize,
    ) -> Result<(Server, RunJournal)> {
        Self::journaled_open_with(cfg, scheme, path, snapshot_every, &Runtime::default_dir())
    }

    /// [`journaled_open`] with an explicit artifact directory.
    ///
    /// Resume is **verify-then-truncate**: recover the longest valid
    /// record prefix, drop only the torn bytes past it, restore the last
    /// complete snapshot, and retain the records after that snapshot as
    /// an *expected tail* — the resumed run re-executes those rounds and
    /// [`RunJournal::append`] byte-compares each re-derived record
    /// against the tail, so any divergence from the original run fails
    /// loudly instead of forking history. A journal written by a
    /// different config or scheme is an error, never silently clobbered.
    pub fn journaled_open_with(
        cfg: ExperimentConfig,
        scheme: Box<dyn Scheme>,
        path: &std::path::Path,
        snapshot_every: usize,
        artifact_dir: &std::path::Path,
    ) -> Result<(Server, RunJournal)> {
        let (recovered, bytes) = journal::recover_file(path)
            .with_context(|| format!("recover journal {}", path.display()))?;

        // resumable = a complete RunHeader followed by at least the
        // initial snapshot survived; anything less (missing file, empty
        // file, a run killed before snapshot 0 landed) starts fresh
        let header = match recovered.records.first() {
            Some(jrec::Record::RunHeader(h)) => Some(h),
            _ => None,
        };
        let snap_idx = recovered
            .records
            .iter()
            .rposition(|r| matches!(r, jrec::Record::Snapshot(_)));
        let (header, snap_idx) = match (header, snap_idx) {
            (Some(h), Some(i)) => (h, i),
            _ => {
                // Starting fresh truncates `path`, so it is only allowed
                // over nothing (no file / empty file) or over the shape a
                // crash-at-birth leaves behind: a valid prefix — possibly
                // just a torn first write — that never reached snapshot 0.
                // A non-empty file whose records stop for any reason other
                // than truncation (format-version skew, a CRC failure, a
                // foreign file) is an error, never silently clobbered.
                let torn_only = matches!(
                    recovered.terminal,
                    None | Some(journal::JournalError::Truncated { .. })
                );
                let header_shaped = recovered.records.is_empty()
                    || matches!(recovered.records.first(), Some(jrec::Record::RunHeader(_)));
                if !bytes.is_empty() && !(torn_only && header_shaped) {
                    let why = match &recovered.terminal {
                        Some(e) => e.to_string(),
                        None => "it does not begin with a run header".to_string(),
                    };
                    return Err(anyhow!(
                        "journal {} exists but cannot be read by this build ({why}); \
                         refusing to overwrite it",
                        path.display()
                    ));
                }
                let srv = Server::with_artifacts(cfg, scheme, artifact_dir)?;
                let sink = journal::FileSink::create(path)
                    .with_context(|| format!("create journal {}", path.display()))?;
                return Ok((srv, RunJournal::fresh(Box::new(sink), snapshot_every.max(1))));
            }
        };

        // the journal's identity must match what the caller is opening —
        // scheme first (better message), then the full config, compared
        // through the canonical record encoding (ExperimentConfig has no
        // PartialEq, and the encoding is the format's source of truth)
        if header.scheme != scheme.name() {
            return Err(anyhow!(
                "journal {} was written by scheme '{}', refusing to resume as '{}'",
                path.display(),
                header.scheme,
                scheme.name()
            ));
        }
        let candidate = jrec::Record::RunHeader(jrec::RunHeader {
            version: jrec::JOURNAL_VERSION,
            scheme: scheme.name().to_string(),
            snapshot_every: header.snapshot_every,
            cfg: cfg.clone(),
        });
        if journal::encode_record(&candidate) != bytes[..recovered.ends[0]] {
            return Err(anyhow!(
                "journal {} was written under a different experiment config, \
                 refusing to resume",
                path.display()
            ));
        }
        // the journal's snapshot cadence governs where snapshots sit in
        // the byte stream, so a resume adopts it regardless of the flag
        let snapshot_every = header.snapshot_every.max(1);

        let snap = match &recovered.records[snap_idx] {
            jrec::Record::Snapshot(s) => s,
            _ => unreachable!("rposition matched a snapshot"),
        };

        // per-round records for rounds 1..=snap.t, in close order
        let prior: Vec<RoundRecord> = recovered.records[..snap_idx]
            .iter()
            .filter_map(|r| match r {
                jrec::Record::RoundClose(c) => Some(c.rec),
                _ => None,
            })
            .collect();
        if prior.len() != snap.t {
            return Err(anyhow!(
                "journal {} is inconsistent: snapshot at t={} but {} round closes precede it",
                path.display(),
                snap.t,
                prior.len()
            ));
        }

        // records past the snapshot stay on disk and become the expected
        // tail: the exact original frame bytes, sliced per record
        let expected_tail: std::collections::VecDeque<Vec<u8>> = (snap_idx + 1
            ..recovered.records.len())
            .map(|j| bytes[recovered.ends[j - 1]..recovered.ends[j]].to_vec())
            .collect();

        let mut srv = Server::with_artifacts(cfg, scheme, artifact_dir)?;
        // the fleet's only per-round mutation is the periodic mode
        // reroll; replaying the call sequence reproduces its state
        for t in 1..=snap.t {
            srv.fleet.on_round_start(t);
        }
        srv.restore_snapshot(snap)?;

        // drop only the torn bytes; the valid prefix (snapshot + tail
        // records included) stays, so the finished file is byte-identical
        // to an uninterrupted run's
        if bytes.len() > recovered.valid_len {
            journal::truncate_file(path, recovered.valid_len)
                .with_context(|| format!("truncate torn tail of {}", path.display()))?;
        }
        let sink = journal::FileSink::append_to(path)
            .with_context(|| format!("reopen journal {}", path.display()))?;
        let carry = journal::ResumeCarry { records: prior, expected_tail };
        Ok((srv, RunJournal::resumed(Box::new(sink), snapshot_every, carry)))
    }

    /// [`run_cb`] with every coordinator decision event-sourced through
    /// `jw`. On a fresh journal this writes the run header + initial
    /// snapshot first; on a resumed one it continues at
    /// `jw.prior_rounds() + 1`, re-verifying the retained tail as it
    /// goes. The returned [`RunResult`] covers the whole run either way.
    pub fn run_journaled_cb(
        &mut self,
        jw: &mut RunJournal,
        mut cb: impl FnMut(&RoundRecord),
    ) -> Result<RunResult> {
        if jw.is_fresh() {
            jw.append(&self.record_header(jw.snapshot_every()))?;
            jw.append(&self.journal_snapshot(0))?;
        }
        if self.pipelined() {
            return self.run_pipelined_cb(Some(jw), cb);
        }
        let mut records = jw.take_prior_records();
        let mut reached = self.recompute_reached(&records);
        for t in records.len() + 1..=self.cfg.rounds {
            let (items, lr) = self.plan_round(t);
            jw.append(&self.record_open(t, &items, lr))?;
            let env = engine::RoundEnv {
                t,
                lr,
                cfg: &self.cfg,
                global: &self.global,
                model_version: self.model_version,
                locals: &self.locals,
                train_ds: &self.train_ds,
                partition: &self.partition,
                scale: &self.scale,
                stream_base: self.stream_base,
                sim_now_s: self.sim_time_s,
            };
            let out = self.engine.execute_round(&env, &items, &self.executor)?;
            let completers = out.updates.len();
            for r in self.resolution_records(t, &out) {
                jw.append(&r)?;
            }
            let outcome = self.apply_round(t, out);
            let rec = self.observe_round(t, &outcome, &mut reached)?;
            jw.append(&self.record_close(t, completers, &rec))?;
            if jw.due_snapshot(t) {
                jw.append(&self.journal_snapshot(t))?;
            }
            self.maintain_workers();
            cb(&rec);
            records.push(rec);
        }
        Ok(self.finish_run(records, reached))
    }

    /// [`run_journaled_cb`] without a progress observer.
    pub fn run_journaled(&mut self, jw: &mut RunJournal) -> Result<RunResult> {
        self.run_journaled_cb(jw, |_| {})
    }

    /// Re-derive the reached-target marker from journaled per-round
    /// records, exactly as `observe_round` would have set it live: the
    /// first evaluated round (non-NaN accuracy) whose metric crossed
    /// `cfg.target_acc`.
    pub(crate) fn recompute_reached(&self, records: &[RoundRecord]) -> Option<(usize, f64, f64)> {
        let uses_auc = self.uses_auc();
        for rec in records {
            if !rec.accuracy.is_nan() {
                let metric = if uses_auc { rec.auc } else { rec.accuracy };
                if metric >= self.cfg.target_acc {
                    return Some((rec.t, rec.sim_time_s, rec.traffic_gb));
                }
            }
        }
        None
    }

    /// The journal's first record: format version, scheme, cadence, and
    /// the full config (what resume and `replay` rebuild the run from).
    pub(crate) fn record_header(&self, snapshot_every: usize) -> jrec::Record {
        jrec::Record::RunHeader(jrec::RunHeader {
            version: jrec::JOURNAL_VERSION,
            scheme: self.scheme.name().to_string(),
            snapshot_every,
            cfg: self.cfg.clone(),
        })
    }

    /// Round `t` opened: the participant plans in **canonical ascending
    /// device order** — `plan_round` emits sampled order but the
    /// networked path sorts before kickoff, and execution is
    /// order-insensitive, so canonicalizing here makes the in-process
    /// and networked loops write byte-identical journals.
    pub(crate) fn record_open(&self, t: usize, items: &[StartRound], lr: f32) -> jrec::Record {
        let mut plans: Vec<jrec::PlanEntry> = items
            .iter()
            .map(|it| jrec::PlanEntry {
                device: it.plan.device,
                download: it.plan.download,
                upload: it.plan.upload,
                batch: it.plan.batch,
                tau: it.plan.tau,
                beta_d: it.beta_d,
                beta_u: it.beta_u,
                mu: it.mu,
            })
            .collect();
        plans.sort_by_key(|p| p.device);
        jrec::Record::RoundOpen(jrec::RoundOpen {
            t,
            model_version: self.model_version,
            sim_now_s: self.sim_time_s,
            lr,
            stream_base: self.stream_base,
            plans,
        })
    }

    /// Per-device resolutions in fold order (ascending device id), built
    /// from the drained round output *before* [`Self::apply_round`]
    /// consumes it. The barrier path: every upload folds at its own
    /// round, so `fold_t == t` throughout.
    pub(crate) fn resolution_records(&self, t: usize, out: &engine::RoundOutput) -> Vec<jrec::Record> {
        let fold_ts = vec![t; out.updates.len()];
        self.resolution_records_with(t, &out.updates, &out.dropped, &fold_ts)
    }

    /// [`Self::resolution_records`] with an explicit fold round per
    /// update (`fold_ts` is parallel to `updates`): the semi-async close
    /// journals each straggler's EndRound in its **origin** round's close
    /// group, carrying the round its upload will fold into. `updates`
    /// and `dropped` must already be device-ascending; the merge emits
    /// one record per resolution in that canonical order.
    fn resolution_records_with(
        &self,
        t: usize,
        updates: &[engine::RoundUpdate],
        dropped: &[engine::DroppedDevice],
        fold_ts: &[usize],
    ) -> Vec<jrec::Record> {
        debug_assert_eq!(updates.len(), fold_ts.len());
        let mut recs = Vec::with_capacity(updates.len() + dropped.len());
        let (mut ui, mut di) = (0usize, 0usize);
        while ui < updates.len() || di < dropped.len() {
            let end_first = match (updates.get(ui), dropped.get(di)) {
                (Some(u), Some(d)) => u.device < d.device,
                (Some(_), None) => true,
                _ => false,
            };
            if end_first {
                let u = &updates[ui];
                recs.push(jrec::Record::EndRound(jrec::EndRound {
                    t,
                    fold_t: fold_ts[ui],
                    device: u.device,
                    w_digest: crate::transport::model_digest(&u.w_final),
                    upload_bits: u.upload.bits,
                    down_wire_bits: u.down_wire_bits,
                    grad_norm: u.grad_norm,
                    loss: u.loss,
                    download_s: u.cost.download_s,
                    compute_s: u.cost.compute_s,
                    upload_s: u.cost.upload_s,
                }));
                ui += 1;
            } else {
                let d = &dropped[di];
                recs.push(jrec::Record::Dropout(jrec::Dropout {
                    t,
                    device: d.device,
                    after_s: d.after_s,
                    down_wire_bits: d.down_wire_bits,
                }));
                di += 1;
            }
        }
        recs
    }

    /// Round `t` closed: post-apply model version + digest, cumulative
    /// ledger totals, and the full metrics record.
    pub(crate) fn record_close(&self, t: usize, completers: usize, rec: &RoundRecord) -> jrec::Record {
        jrec::Record::RoundClose(jrec::RoundClose {
            t,
            completers,
            model_version: self.model_version,
            model_digest: crate::transport::model_digest(&self.global),
            down_bits: self.traffic.down_bits,
            up_bits: self.traffic.up_bits,
            rec: *rec,
        })
    }

    /// The complete mutable server state after `t` rounds, as a journal
    /// snapshot record.
    pub(crate) fn journal_snapshot(&self, t: usize) -> jrec::Record {
        jrec::Record::Snapshot(Box::new(jrec::Snapshot {
            t,
            model_version: self.model_version,
            sim_time_s: self.sim_time_s,
            rng: self.rng.state(),
            down_bits: self.traffic.down_bits,
            up_bits: self.traffic.up_bits,
            model: jrec::ParamBlock::new(self.global.clone()),
            locals: self
                .locals
                .iter()
                .map(|l| l.as_ref().map(|w| jrec::ParamBlock::new(w.clone())))
                .collect(),
            grad_norms: self.grad_norms.clone(),
            last_round: self.tracker.last_rounds().to_vec(),
        }))
    }

    /// Restore the mutable server state from a journal snapshot,
    /// verifying every stored digest against its bytes first.
    pub(crate) fn restore_snapshot(&mut self, s: &jrec::Snapshot) -> Result<()> {
        let n = self.cfg.n_devices();
        if !s.model.digest_ok() {
            return Err(anyhow!("journal snapshot t={}: model digest mismatch", s.t));
        }
        if s.model.w.len() != self.global.len() {
            return Err(anyhow!(
                "journal snapshot t={}: model has {} params, this run has {}",
                s.t,
                s.model.w.len(),
                self.global.len()
            ));
        }
        if s.locals.len() != n || s.grad_norms.len() != n || s.last_round.len() != n {
            return Err(anyhow!(
                "journal snapshot t={}: per-device state is not sized for {n} devices",
                s.t
            ));
        }
        for (d, local) in s.locals.iter().enumerate() {
            if let Some(b) = local {
                if !b.digest_ok() {
                    return Err(anyhow!(
                        "journal snapshot t={}: local model of device {d} fails its digest",
                        s.t
                    ));
                }
            }
        }
        self.global = s.model.w.clone();
        self.model_version = s.model_version;
        self.sim_time_s = s.sim_time_s;
        self.rng = Rng::from_state(s.rng);
        self.traffic = TrafficMeter { down_bits: s.down_bits, up_bits: s.up_bits };
        self.locals = s.locals.iter().map(|l| l.as_ref().map(|b| b.w.clone())).collect();
        self.grad_norms = s.grad_norms.clone();
        self.tracker = ParticipationTracker::from_rounds(s.last_round.clone());
        Ok(())
    }
}

// ---------------------------------------------------------------------
// semi-async pipelined rounds: straggler-overlapped aggregation
// ---------------------------------------------------------------------

/// One opened-but-unclosed pipelined round. Time is simulated, so the
/// download/train/upload phase ran **eagerly at open** (against the
/// global model as of the open — round t+1 trains on the pre-close-t
/// model, the semi-async staleness the paper's baseline tolerates);
/// the resolutions wait here for their close slot, where lateness is
/// classified and the deferred fold happens.
pub(crate) struct PendingRound {
    pub(crate) t: usize,
    /// Planned participants, ascending (the canonical fold order).
    pub(crate) devices: Vec<usize>,
    /// Resolutions sorted by device id.
    pub(crate) updates: Vec<engine::RoundUpdate>,
    pub(crate) dropped: Vec<engine::DroppedDevice>,
}

/// The last round the scheduler may hold open while round `t` is the
/// oldest unclosed one: the end of the run, or — on journaled runs —
/// the next snapshot boundary (`quiesce` = the snapshot cadence, 0 for
/// no journal). Snapshots only land on fully-drained state (empty
/// window, empty staleness buffer), so no round and no parked upload
/// may straddle one; that keeps the snapshot format unchanged and
/// resume trivially correct.
pub(crate) fn barrier_after(t: usize, quiesce: usize, rounds: usize) -> usize {
    if quiesce == 0 { rounds } else { (t.div_ceil(quiesce) * quiesce).min(rounds) }
}

/// Classify round `t`'s completers as on-time or late, returning each
/// one's fold round (`== t` when on time). `costs` are the completers'
/// total simulated costs in device order. A pure function of the
/// round's own journaled EndRound costs, so `caesar replay` re-derives
/// every fold round bit-exactly from the journal alone: sort the costs,
/// take the median, call anything beyond 2× the median late, and park
/// it `ceil(cost/deadline) − 1` rounds ahead, capped by the effective
/// staleness budget `s_eff` (0 disables lateness entirely — the
/// barrier). The median rule guarantees at least half the completers
/// stay on time, so a round's clock never collapses to zero.
pub(crate) fn classify_lateness(costs: &[f64], t: usize, s_eff: usize) -> Vec<usize> {
    if costs.is_empty() || s_eff == 0 {
        return vec![t; costs.len()];
    }
    let mut cs = costs.to_vec();
    cs.sort_by(f64::total_cmp);
    let deadline = 2.0 * cs[(cs.len() - 1) / 2];
    costs
        .iter()
        .map(|&c| {
            if deadline <= 0.0 || c <= deadline {
                return t;
            }
            let lag = ((c / deadline).ceil() as usize).saturating_sub(1).clamp(1, s_eff);
            t + lag
        })
        .collect()
}

impl Server {
    /// Whether the semi-async scheduler drives this run: any pipeline
    /// depth beyond 1, or any staleness tolerance. Depth 1 / bound 0
    /// routes through the untouched barrier loops, bit-for-bit.
    pub(crate) fn pipelined(&self) -> bool {
        self.cfg.engine.pipeline_depth > 1 || self.cfg.engine.staleness_bound > 0
    }

    /// Open round `u` for pipelined execution: plan (consuming the
    /// server RNG in open order), journal the RoundOpen, and execute the
    /// simulated round eagerly against the current global model. The
    /// output is NOT applied — it parks as a [`PendingRound`] until its
    /// close slot.
    fn open_pipelined(&mut self, u: usize, jw: Option<&mut RunJournal>) -> Result<PendingRound> {
        let (mut items, lr) = self.plan_round(u);
        if let Some(jw) = jw {
            jw.append(&self.record_open(u, &items, lr))?;
        }
        // canonical (ascending device) order, as the networked path kicks off
        items.sort_by_key(|it| it.plan.device);
        let devices: Vec<usize> = items.iter().map(|it| it.plan.device).collect();
        let env = engine::RoundEnv {
            t: u,
            lr,
            cfg: &self.cfg,
            global: &self.global,
            model_version: self.model_version,
            locals: &self.locals,
            train_ds: &self.train_ds,
            partition: &self.partition,
            scale: &self.scale,
            stream_base: self.stream_base,
            sim_now_s: self.sim_time_s,
        };
        let (mut updates, mut dropped) =
            self.engine.execute_round_unfolded(&env, &items, &self.executor)?;
        updates.sort_by_key(|up| up.device);
        dropped.sort_by_key(|d| d.device);
        Ok(PendingRound { t: u, devices, updates, dropped })
    }

    /// Close round `t`: classify lateness from the round's own costs,
    /// journal the device-ascending resolutions (each EndRound carrying
    /// its fold round), fold the on-time uploads plus any prior rounds'
    /// stragglers due this round, and apply everything at the origin
    /// round in canonical device order. The single close path shared by
    /// the in-process scheduler and the networked coordinator — both
    /// write byte-identical journals. Returns the round outcome and the
    /// number of uploads folded (what RoundClose records as
    /// `completers`).
    pub(crate) fn close_pipelined(
        &mut self,
        pend: PendingRound,
        quiesce: usize,
        jw: Option<&mut RunJournal>,
    ) -> Result<(RoundOutcome, usize)> {
        let PendingRound { t, devices, updates, dropped } = pend;
        let s_eff = self
            .cfg
            .engine
            .staleness_bound
            .min(barrier_after(t, quiesce, self.cfg.rounds) - t);
        let costs_all: Vec<f64> = updates.iter().map(|u| u.cost.total()).collect();
        let fold_ts = classify_lateness(&costs_all, t, s_eff);
        let on_time: Vec<bool> = fold_ts.iter().map(|&f| f == t).collect();

        if let Some(jw) = jw {
            for r in self.resolution_records_with(t, &updates, &dropped, &fold_ts) {
                jw.append(&r)?;
            }
        }

        // prior rounds' parked stragglers whose fold slot arrived; the
        // partition preserves the buffer's (origin, device) order
        let parked = std::mem::take(&mut self.late_buffer);
        let (late_ins, parked): (Vec<_>, Vec<_>) =
            parked.into_iter().partition(|l| l.fold_t <= t);
        self.late_buffer = parked;

        let (agg, folded) =
            self.engine.fold_round(self.global.len(), &devices, &updates, &on_time, &late_ins)?;

        // --- apply in canonical device order: everything except the
        // gradient fold lands at the origin round, late or not ---
        let n_ends = updates.len();
        let mut n_on_time = 0usize;
        let mut costs: Vec<f64> = Vec::with_capacity(n_ends);
        let mut loss_sum = 0.0f64;
        for (i, u) in updates.into_iter().enumerate() {
            self.traffic.add_down(self.scale.scale_bits(u.down_wire_bits));
            self.traffic.add_up(self.scale.scale_bits(u.upload.bits));
            self.grad_norms[u.device] = u.grad_norm;
            self.locals[u.device] = Some(u.w_final);
            self.tracker.record(u.device, t);
            loss_sum += u.loss;
            if on_time[i] {
                n_on_time += 1;
                costs.push(u.cost.total());
            } else {
                self.late_buffer.push(LateUpload {
                    origin_t: t,
                    fold_t: fold_ts[i],
                    device: u.device,
                    upload: u.upload,
                });
            }
        }
        for d in &dropped {
            self.traffic.add_down(self.scale.scale_bits(d.down_wire_bits));
        }

        // --- global aggregation: the mean over everything folded THIS
        // round (on-time completers + absorbed stragglers) ---
        if folded > 0 {
            let inv = 1.0 / folded as f64;
            for (w, a) in self.global.iter_mut().zip(agg.iter()) {
                *w -= (a * inv) as f32;
            }
            self.model_version += 1;
        }

        // --- semi-async timing: the barrier waits only for on-time
        // completers and noticed dropouts; stragglers no longer hold the
        // round open (THE wall-clock lever of this scheduler) ---
        let round_s = costs
            .iter()
            .copied()
            .chain(dropped.iter().map(|d| d.after_s))
            .fold(0.0f64, f64::max);
        let avg_wait_s = if n_on_time > 0 {
            costs.iter().map(|&c| round_s - c).sum::<f64>() / n_on_time as f64
        } else {
            0.0
        };
        self.sim_time_s += round_s;
        let mean_loss = if n_ends > 0 { loss_sum / n_ends as f64 } else { f64::NAN };
        Ok((RoundOutcome { round_s, avg_wait_s, mean_loss }, folded))
    }

    /// The semi-async run loop: a depth-bounded window of open rounds,
    /// closed oldest-first. While round `t` drains, rounds up to
    /// `barrier_after(t)` open behind it (plan → journal RoundOpen →
    /// eager execute); every close folds its on-time uploads plus the
    /// staleness buffer's due entries. With a journal, opens never cross
    /// a snapshot boundary, so every snapshot lands on fully-quiescent
    /// state and resume restarts the scheduler cold at `snap.t + 1`.
    fn run_pipelined_cb(
        &mut self,
        mut jw: Option<&mut RunJournal>,
        mut cb: impl FnMut(&RoundRecord),
    ) -> Result<RunResult> {
        let quiesce = jw.as_ref().map(|j| j.snapshot_every()).unwrap_or(0);
        let mut records = match jw.as_mut() {
            Some(j) => j.take_prior_records(),
            None => Vec::with_capacity(self.cfg.rounds),
        };
        let mut reached = self.recompute_reached(&records);
        let depth = self.cfg.engine.pipeline_depth.max(1);
        let rounds = self.cfg.rounds;
        let mut window: std::collections::VecDeque<PendingRound> =
            std::collections::VecDeque::with_capacity(depth);
        let mut next_open = records.len() + 1;
        for t in records.len() + 1..=rounds {
            while next_open <= barrier_after(t, quiesce, rounds) && window.len() < depth {
                let pend = self.open_pipelined(next_open, jw.as_deref_mut())?;
                window.push_back(pend);
                next_open += 1;
            }
            let pend = window.pop_front().expect("the window always holds round t");
            debug_assert_eq!(pend.t, t);
            let (outcome, folded) = self.close_pipelined(pend, quiesce, jw.as_deref_mut())?;
            let rec = self.observe_round(t, &outcome, &mut reached)?;
            if let Some(j) = jw.as_mut() {
                j.append(&self.record_close(t, folded, &rec))?;
                if j.due_snapshot(t) {
                    debug_assert!(
                        window.is_empty() && self.late_buffer.is_empty(),
                        "snapshots only land on quiescent state"
                    );
                    j.append(&self.journal_snapshot(t))?;
                }
            }
            self.maintain_workers();
            cb(&rec);
            records.push(rec);
        }
        Ok(self.finish_run(records, reached))
    }

    /// Self-healing worker pool: a panicked worker retires mid-round
    /// (the round still completes on the survivors — results are
    /// worker-count-invariant, so nothing shifts); after two consecutive
    /// short-handed completed rounds the pool rebuilds the missing
    /// threads through the same setup closure that built them at run
    /// start. Called after every applied round; failed rounds never
    /// reach it.
    pub(crate) fn maintain_workers(&mut self) {
        let (target, alive) = self.executor.worker_census();
        if alive >= target {
            self.short_rounds = 0;
            return;
        }
        self.short_rounds += 1;
        if self.short_rounds >= 2 {
            match self.executor.respawn_dead() {
                Ok(_) => self.short_rounds = 0,
                // a failed rebuild leaves the pool as it was; retry at
                // the next round boundary
                Err(_) => {}
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CompressionBackend, ExperimentConfig, TrainerBackend};
    use crate::schemes;

    fn tiny_cfg(task: &str, scheme_rounds: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset(task);
        cfg.trainer = TrainerBackend::Native;
        cfg.compression = CompressionBackend::Native;
        cfg.rounds = scheme_rounds;
        cfg.n_train = 1200;
        cfg.n_test = 400;
        cfg.tau = 5;
        cfg.alpha = 0.3; // more data per round so tiny runs visibly learn
        cfg.lr = 0.1;
        cfg.eval_every = 1;
        cfg
    }

    fn run_scheme(task: &str, scheme: &str, rounds: usize) -> RunResult {
        let cfg = tiny_cfg(task, rounds);
        let mut srv = Server::new(cfg, schemes::by_name(scheme).unwrap()).unwrap();
        srv.run().unwrap()
    }

    #[test]
    fn fedavg_learns_on_tiny_run() {
        let r = run_scheme("har", "fedavg", 30);
        assert_eq!(r.records.len(), 30);
        let first = r.records.first().unwrap().accuracy;
        let last = r.final_metric(false);
        assert!(last > first + 0.15, "acc {first} -> {last}");
        // time and traffic are strictly increasing
        assert!(r.total_time_s() > 0.0 && r.total_traffic_gb() > 0.0);
        for w in r.records.windows(2) {
            assert!(w[1].sim_time_s > w[0].sim_time_s);
            assert!(w[1].traffic_gb > w[0].traffic_gb);
        }
    }

    #[test]
    fn caesar_uses_less_traffic_than_fedavg() {
        let a = run_scheme("har", "fedavg", 8);
        let b = run_scheme("har", "caesar", 8);
        assert!(
            b.total_traffic_gb() < 0.9 * a.total_traffic_gb(),
            "caesar {} vs fedavg {}",
            b.total_traffic_gb(),
            a.total_traffic_gb()
        );
    }

    #[test]
    fn all_schemes_complete_a_round() {
        for s in [
            "fedavg",
            "flexcom",
            "prowd",
            "pyramidfl",
            "caesar",
            "caesar-br",
            "caesar-dc",
            "nocomp",
            "gm-fic",
            "gm-cac",
            "lg-fic",
            "lg-cac",
        ] {
            let cfg = tiny_cfg("har", 2);
            let mut srv = Server::new(cfg, schemes::by_name(s).unwrap()).unwrap();
            let r = srv.run().unwrap();
            assert_eq!(r.records.len(), 2, "{s}");
            assert!(r.records[1].round_s > 0.0, "{s}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_scheme("har", "caesar", 4);
        let b = run_scheme("har", "caesar", 4);
        assert_eq!(a.final_metric(false), b.final_metric(false));
        assert_eq!(a.total_traffic_gb(), b.total_traffic_gb());
    }

    #[test]
    fn seeds_change_outcomes() {
        let mut cfg = tiny_cfg("har", 4);
        cfg.seed = 1;
        let mut s1 = Server::new(cfg.clone(), schemes::by_name("caesar").unwrap()).unwrap();
        let r1 = s1.run().unwrap();
        cfg.seed = 2;
        let mut s2 = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
        let r2 = s2.run().unwrap();
        assert_ne!(r1.total_traffic_gb(), r2.total_traffic_gb());
    }

    #[test]
    fn reached_target_recorded() {
        let mut cfg = tiny_cfg("har", 30);
        cfg.target_acc = 0.30; // low bar the tiny run will cross
        let mut srv = Server::new(cfg, schemes::by_name("fedavg").unwrap()).unwrap();
        let r = srv.run().unwrap();
        let (t, time, gb) = r.reached_target.expect("target should be reached");
        assert!(t >= 1 && time > 0.0 && gb > 0.0);
    }

    #[test]
    fn waiting_time_lower_for_caesar_than_fedavg() {
        // batch regulation (Eq. 7–9) should cut the synchronous-barrier
        // idle time — the Fig. 7 phenomenon, already visible on tiny runs
        let a = run_scheme("cifar", "fedavg", 6);
        let b = run_scheme("cifar", "caesar", 6);
        assert!(
            b.mean_wait_s() < a.mean_wait_s(),
            "caesar wait {} vs fedavg {}",
            b.mean_wait_s(),
            a.mean_wait_s()
        );
    }

    #[test]
    fn oppo_uses_auc() {
        let cfg = tiny_cfg("oppo", 2);
        let srv = Server::new(cfg, schemes::by_name("caesar").unwrap()).unwrap();
        assert!(srv.uses_auc());
    }
}

//! Micro-benchmark harness (offline build: no criterion).
//!
//! `cargo bench` targets are plain binaries (`harness = false`) that call
//! [`Bench::run`]: warm-up, timed iterations with adaptive count, and a
//! report line with mean / p50 / p99 and optional per-element throughput.

use std::time::{Duration, Instant};

use crate::util::stats;

/// One benchmark group printer.
pub struct Bench {
    name: String,
    min_iters: usize,
    max_iters: usize,
    target: Duration,
    warmup: Duration,
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p99_ns: f64,
}

impl Bench {
    pub fn new(name: &str) -> Bench {
        println!("\n== bench: {name} ==");
        Bench {
            name: name.to_string(),
            min_iters: 10,
            max_iters: 100_000,
            target: Duration::from_millis(700),
            warmup: Duration::from_millis(150),
        }
    }

    /// Quick mode for CI-ish runs (CAESAR_BENCH_QUICK=1).
    pub fn quick(mut self) -> Bench {
        if std::env::var("CAESAR_BENCH_QUICK").is_ok() {
            self.target = Duration::from_millis(120);
            self.warmup = Duration::from_millis(30);
            self.max_iters = 2_000;
        }
        self
    }

    /// Run one case; `elems` (if > 0) adds ns/elem + throughput columns.
    pub fn case<F: FnMut()>(&self, case_name: &str, elems: usize, mut f: F) -> BenchResult {
        // warm-up
        let start = Instant::now();
        while start.elapsed() < self.warmup {
            f();
        }
        // calibrate: single run
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().max(Duration::from_nanos(50));
        let iters = ((self.target.as_nanos() / once.as_nanos()).max(1) as usize)
            .clamp(self.min_iters, self.max_iters);
        let mut samples = Vec::with_capacity(iters);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_nanos() as f64);
        }
        let mean = stats::mean(&samples);
        let p50 = stats::percentile(&samples, 50.0);
        let p99 = stats::percentile(&samples, 99.0);
        let mut line = format!(
            "  {case_name:40} {iters:>7} it  mean {:>12}  p50 {:>12}  p99 {:>12}",
            fmt_ns(mean),
            fmt_ns(p50),
            fmt_ns(p99)
        );
        if elems > 0 {
            let ns_per = mean / elems as f64;
            let melems = elems as f64 / mean * 1e3; // elems/ns → Melem/s
            line.push_str(&format!("  {ns_per:>8.2} ns/elem  {melems:>9.1} Melem/s"));
        }
        println!("{line}");
        BenchResult {
            name: format!("{}/{case_name}", self.name),
            iters,
            mean_ns: mean,
            p50_ns: p50,
            p99_ns: p99,
        }
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        std::env::set_var("CAESAR_BENCH_QUICK", "1");
        let b = Bench::new("selftest").quick();
        let mut acc = 0u64;
        let r = b.case("noop-ish", 100, || {
            acc = acc.wrapping_add(std::hint::black_box(1));
        });
        assert!(r.iters >= 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.p99_ns >= r.p50_ns * 0.5);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5_000.0).contains("µs"));
        assert!(fmt_ns(5_000_000.0).contains("ms"));
        assert!(fmt_ns(5e9).contains(" s"));
    }
}

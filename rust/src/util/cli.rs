//! Tiny CLI parser (offline build: no `clap`).
//!
//! Grammar: `caesar <subcommand> [--flag] [--key value] [key=value ...]`.
//! `--key value` and `key=value` are equivalent; the experiment configs
//! consume them as overrides.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub opts: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|n| !n.starts_with("--") && !n.contains('='))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if let Some((k, v)) = a.split_once('=') {
                out.opts.insert(k.to_string(), v.to_string());
            } else if out.subcommand.is_none() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(|v| v.parse().ok())
    }

    pub fn get_u64(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(|v| v.parse().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(|x| x.to_string()))
    }

    #[test]
    fn subcommand_and_kv() {
        let a = parse("fig5 dataset=cifar rounds=250 --out results --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("fig5"));
        assert_eq!(a.get("dataset"), Some("cifar"));
        assert_eq!(a.get_usize("rounds"), Some(250));
        assert_eq!(a.get("out"), Some("results"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn double_dash_equals() {
        let a = parse("run --seed=7 --alpha 0.1");
        assert_eq!(a.get_u64("seed"), Some(7));
        assert_eq!(a.get_f64("alpha"), Some(0.1));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("run --dry-run");
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.subcommand.as_deref(), Some("run"));
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse("bench compress recover");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.positional, vec!["compress", "recover"]);
    }

    #[test]
    fn kv_value_with_equals_not_consumed_as_option_value() {
        // `--out x=y` : x=y looks like kv, so --out becomes a flag and x=y an opt
        let a = parse("run --out x=y");
        assert!(a.has_flag("out"));
        assert_eq!(a.get("x"), Some("y"));
    }
}

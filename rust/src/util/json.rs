//! Minimal JSON: a writer for metrics/results output and a parser for the
//! artifact manifest. (The offline build has no `serde`.)

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. BTreeMap keeps object output deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, val: Json) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), val);
        } else {
            panic!("Json::set on non-object");
        }
        self
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn keys(&self) -> Vec<&str> {
        match self {
            Json::Obj(m) => m.keys().map(|s| s.as_str()).collect(),
            _ => vec![],
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{}", x);
                    }
                } else {
                    out.push_str("null");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document (recursive descent; enough for manifest.json).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}
pub fn s(x: &str) -> Json {
    Json::Str(x.to_string())
}
pub fn arr(v: Vec<Json>) -> Json {
    Json::Arr(v)
}
pub fn arr_f64(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x)).collect())
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        return Err("unexpected end".into());
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => parse_lit(b, pos, "true", Json::Bool(true)),
        b'f' => parse_lit(b, pos, "false", Json::Bool(false)),
        b'n' => parse_lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn parse_lit(b: &[u8], pos: &mut usize, lit: &str, v: Json) -> Result<Json, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("bad literal at {}", *pos))
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    std::str::from_utf8(&b[start..*pos])
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .map(Json::Num)
        .ok_or_else(|| format!("bad number at {start}"))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    debug_assert_eq!(b[*pos], b'"');
    *pos += 1;
    let mut out = String::new();
    while *pos < b.len() {
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                if *pos >= b.len() {
                    return Err("bad escape".into());
                }
                match b[*pos] {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'n' => out.push('\n'),
                    b't' => out.push('\t'),
                    b'r' => out.push('\r'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'u' => {
                        if *pos + 4 >= b.len() {
                            return Err("bad \\u".into());
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])
                            .map_err(|_| "bad \\u")?;
                        let code =
                            u32::from_str_radix(hex, 16).map_err(|_| "bad \\u hex")?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *pos += 4;
                    }
                    c => return Err(format!("bad escape \\{}", c as char)),
                }
                *pos += 1;
            }
            _ => {
                // copy the full UTF-8 sequence
                let s = &b[*pos..];
                let ch_len = match s[0] {
                    0x00..=0x7f => 1,
                    0xc0..=0xdf => 2,
                    0xe0..=0xef => 3,
                    _ => 4,
                };
                out.push_str(
                    std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| "bad utf8")?,
                );
                *pos += ch_len;
            }
        }
    }
    Err("unterminated string".into())
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '['
    let mut v = Vec::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b']' {
        *pos += 1;
        return Ok(Json::Arr(v));
    }
    loop {
        v.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(v));
            }
            _ => return Err(format!("expected , or ] at {}", *pos)),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json, String> {
    *pos += 1; // '{'
    let mut m = BTreeMap::new();
    skip_ws(b, pos);
    if *pos < b.len() && b[*pos] == b'}' {
        *pos += 1;
        return Ok(Json::Obj(m));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            return Err(format!("expected : at {}", *pos));
        }
        *pos += 1;
        m.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(m));
            }
            _ => return Err(format!("expected , or }} at {}", *pos)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let mut j = Json::obj();
        j.set("name", s("caesar"))
            .set("rounds", num(250.0))
            .set("accs", arr_f64(&[0.1, 0.5]))
            .set("flag", Json::Bool(true));
        let text = j.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": {"b": [1, 2.5, "x"], "c": null}}"#).unwrap();
        let b = j.get("a").unwrap().get("b").unwrap().as_arr().unwrap();
        assert_eq!(b[0].as_f64(), Some(1.0));
        assert_eq!(b[1].as_f64(), Some(2.5));
        assert_eq!(b[2].as_str(), Some("x"));
        assert_eq!(j.get("a").unwrap().get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let j = Json::parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn string_escaping_on_write() {
        let j = s("line\n\"quoted\"");
        let t = j.to_string();
        assert_eq!(t, r#""line\n\"quoted\"""#);
        assert_eq!(Json::parse(&t).unwrap(), j);
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn integers_written_without_decimal() {
        assert_eq!(num(250.0).to_string(), "250");
        assert_eq!(num(0.5).to_string(), "0.5");
    }

    #[test]
    fn parses_real_manifest_shape() {
        let text = r#"{"chunk": 5, "modules": {"train_har_b4": {"file": "t.hlo.txt",
            "inputs": [{"dtype": "f32", "shape": [2758]}]}}}"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("chunk").unwrap().as_usize(), Some(5));
        let inp = j
            .get("modules")
            .unwrap()
            .get("train_har_b4")
            .unwrap()
            .get("inputs")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(
            inp[0].get("shape").unwrap().as_arr().unwrap()[0].as_usize(),
            Some(2758)
        );
    }
}

//! Thread-local scratch-buffer pool for the round hot path.
//!
//! The per-device codec work allocates several model-sized vectors per
//! call (`keep_threshold`'s |g| key buffer, the quantizer's noise draws,
//! the recovered download model, the local gradient). At fleet scale that
//! is O(participants) short-lived n-word allocations per round. This pool
//! recycles them: [`f32_buf`] / [`u32_buf`] lease a cleared `Vec` whose
//! capacity survives from the previous lease on the same thread, and the
//! RAII guard returns it on drop — so a worker thread allocates each
//! scratch shape once per round instead of once per device.
//!
//! Design notes:
//! * **Thread-local, lock-free.** Each thread owns its free lists; leases
//!   never contend. Engine workers are the *persistent* pool threads of
//!   `util::threadpool::WorkerPool` — they live for the whole run, so a
//!   worker's free lists (like its trainer) survive round boundaries and
//!   reuse amortizes across every device it ever executes; the sequential
//!   (inline) path reuses across rounds on the coordinator thread.
//! * **Bounded.** At most [`MAX_POOLED`] buffers are retained per type;
//!   extra returns are simply dropped, so the pool can never hoard more
//!   than a few model-sized vectors per thread.
//! * **A lease is just a `Vec`.** The guards deref to `Vec<T>`, start
//!   empty (`len == 0`, capacity recycled), and may be grown, shrunk or
//!   `mem::take`n freely — a stolen (taken) buffer is replaced by an
//!   empty one, which is what gets recycled.
//!
//! Buffers that *escape* into long-lived values (wire payloads, the
//! updates a round returns) are intentionally NOT pooled — pooling only
//! pays for scratch whose lifetime ends with the device step.

use std::cell::RefCell;
use std::ops::{Deref, DerefMut};

/// Free-list depth per element type, per thread. The round loop needs at
/// most a handful of simultaneous leases (model + gradient + codec
/// scratch), so a small constant suffices.
pub const MAX_POOLED: usize = 8;

thread_local! {
    static F32_POOL: RefCell<Vec<Vec<f32>>> = RefCell::new(Vec::new());
    static U32_POOL: RefCell<Vec<Vec<u32>>> = RefCell::new(Vec::new());
    /// (leases, reuses) — diagnostics for tests and benches.
    static STATS: RefCell<(u64, u64)> = const { RefCell::new((0, 0)) };
}

/// Leased `Vec<f32>` scratch; returns to this thread's pool on drop.
pub struct F32Buf {
    buf: Vec<f32>,
}

/// Leased `Vec<u32>` scratch; returns to this thread's pool on drop.
pub struct U32Buf {
    buf: Vec<u32>,
}

macro_rules! impl_buf {
    ($name:ident, $elem:ty, $pool:ident, $lease:ident) => {
        /// Lease a cleared buffer from this thread's pool (empty, with
        /// whatever capacity its previous life left behind).
        pub fn $lease() -> $name {
            let reused = $pool.with(|p| p.borrow_mut().pop());
            STATS.with(|s| {
                let mut s = s.borrow_mut();
                s.0 += 1;
                if reused.is_some() {
                    s.1 += 1;
                }
            });
            $name { buf: reused.unwrap_or_default() }
        }

        impl Deref for $name {
            type Target = Vec<$elem>;
            fn deref(&self) -> &Vec<$elem> {
                &self.buf
            }
        }

        impl DerefMut for $name {
            fn deref_mut(&mut self) -> &mut Vec<$elem> {
                &mut self.buf
            }
        }

        impl Drop for $name {
            fn drop(&mut self) {
                let mut v = std::mem::take(&mut self.buf);
                if v.capacity() == 0 {
                    return; // nothing worth recycling (or it was stolen)
                }
                v.clear();
                $pool.with(|p| {
                    let mut p = p.borrow_mut();
                    if p.len() < MAX_POOLED {
                        p.push(v);
                    }
                });
            }
        }
    };
}

impl_buf!(F32Buf, f32, F32_POOL, f32_buf);
impl_buf!(U32Buf, u32, U32_POOL, u32_buf);

/// Free-list depth for f64 aggregation chunks — its own (deeper) cap:
/// where scratch buffers come a handful per thread, a chunk-sharded
/// reduction holds O(model / chunk) chunks per live partial sum, and
/// recycling across rounds only pays if a round's worth of chunks fits.
pub const MAX_POOLED_CHUNKS: usize = 256;

thread_local! {
    static F64_CHUNK_POOL: RefCell<Vec<Vec<f64>>> = const { RefCell::new(Vec::new()) };
}

/// Take a zeroed f64 chunk of exactly `len` elements, recycling capacity
/// from this thread's chunk pool when available. Unlike the RAII buffer
/// leases above, chunks are plain `Vec`s handed back explicitly via
/// [`recycle_f64_chunk`] (the aggregation types do it in their `Drop`):
/// a chunk lives inside long-lived sums that cross thread boundaries, so
/// a thread-pinned guard would recycle into the wrong pool. A chunk
/// dropped on a different thread than it was taken from simply lands in
/// *that* thread's free list — still bounded, still reused by whatever
/// reduction that thread runs next.
pub fn f64_chunk(len: usize) -> Vec<f64> {
    let mut v = F64_CHUNK_POOL.with(|p| p.borrow_mut().pop()).unwrap_or_default();
    v.clear();
    v.resize(len, 0.0);
    v
}

/// Return a chunk's capacity to this thread's pool (bounded by
/// [`MAX_POOLED_CHUNKS`]; zero-capacity vectors are not worth keeping).
pub fn recycle_f64_chunk(v: Vec<f64>) {
    if v.capacity() == 0 {
        return;
    }
    F64_CHUNK_POOL.with(|p| {
        let mut p = p.borrow_mut();
        if p.len() < MAX_POOLED_CHUNKS {
            p.push(v);
        }
    });
}

/// (leases, reuses) served on this thread so far. A reuse is a lease that
/// recycled capacity instead of starting from a fresh allocation.
pub fn stats() -> (u64, u64) {
    STATS.with(|s| *s.borrow())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lease_is_empty_and_capacity_survives() {
        // drain whatever earlier tests on this thread left behind
        let drained: Vec<F32Buf> = (0..MAX_POOLED).map(|_| f32_buf()).collect();
        drop(drained);
        {
            let mut a = f32_buf();
            a.resize(4096, 1.5);
        } // drop returns it
        let b = f32_buf();
        assert!(b.is_empty(), "leases must start cleared");
        assert!(b.capacity() >= 4096, "capacity must be recycled");
    }

    #[test]
    fn reuse_is_counted() {
        {
            let mut w = u32_buf();
            w.push(7);
        }
        let (l0, r0) = stats();
        let x = u32_buf();
        let (l1, r1) = stats();
        assert_eq!(l1, l0 + 1);
        assert_eq!(r1, r0 + 1, "second lease must be a reuse");
        drop(x);
    }

    #[test]
    fn pool_depth_is_bounded() {
        let many: Vec<F32Buf> = (0..3 * MAX_POOLED)
            .map(|_| {
                let mut b = f32_buf();
                b.reserve(16);
                b
            })
            .collect();
        drop(many); // only MAX_POOLED of these may be retained
        let held = F32_POOL.with(|p| p.borrow().len());
        assert!(held <= MAX_POOLED, "held={held}");
    }

    #[test]
    fn stolen_buffer_is_replaced_not_recycled_twice() {
        let mut b = f32_buf();
        b.resize(64, 0.0);
        let stolen = std::mem::take(&mut *b);
        assert_eq!(stolen.len(), 64);
        drop(b); // inner vec is now empty: nothing pushed back
        // no panic / no double-free; the stolen vec is still intact
        assert_eq!(stolen.len(), 64);
    }

    #[test]
    fn f64_chunks_recycle_zeroed_with_capacity() {
        let mut c = f64_chunk(128);
        assert_eq!(c.len(), 128);
        c[5] = 3.0;
        recycle_f64_chunk(c);
        // one test = one thread = one deterministic LIFO free list
        let c2 = f64_chunk(64);
        assert_eq!(c2.len(), 64);
        assert!(c2.capacity() >= 128, "capacity must be recycled");
        assert!(c2.iter().all(|&x| x == 0.0), "chunks must come back zeroed");
    }

    #[test]
    fn chunk_pool_depth_is_bounded() {
        for _ in 0..MAX_POOLED_CHUNKS + 16 {
            recycle_f64_chunk(vec![0.0; 4]);
        }
        let held = F64_CHUNK_POOL.with(|p| p.borrow().len());
        assert!(held <= MAX_POOLED_CHUNKS, "held={held}");
    }

    #[test]
    fn separate_element_types_do_not_mix() {
        {
            let mut f = f32_buf();
            f.resize(100, 0.0);
            let mut u = u32_buf();
            u.resize(200, 0);
        }
        let f = f32_buf();
        let u = u32_buf();
        assert!(f.capacity() >= 100);
        assert!(u.capacity() >= 200);
    }
}

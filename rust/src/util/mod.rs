//! Self-built substrates (offline environment: no rand / serde / clap /
//! criterion / proptest — see DESIGN.md §8).

pub mod alloc_count;
pub mod bitio;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod threadpool;

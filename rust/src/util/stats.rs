//! Small statistics helpers used across the simulator and benches.

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population variance; 0.0 for fewer than 2 elements.
pub fn variance(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64
}

pub fn std_dev(xs: &[f64]) -> f64 {
    variance(xs).sqrt()
}

/// Percentile via linear interpolation on the sorted copy; p in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Mean squared error between two equal-length vectors.
pub fn mse(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    if a.is_empty() {
        return 0.0;
    }
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let d = (*x - *y) as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64
}

/// MSE normalized by the mean square of the reference (`b`).
pub fn normalized_mse(a: &[f32], b: &[f32]) -> f64 {
    let denom = b.iter().map(|y| (*y as f64) * (*y as f64)).sum::<f64>() / b.len().max(1) as f64;
    if denom == 0.0 {
        return 0.0;
    }
    mse(a, b) / denom
}

/// Area under the ROC curve via the Mann–Whitney U statistic.
///
/// `scores[i]` is the model score for sample i, `labels[i]` is 0/1.
/// Ties contribute 1/2. Returns 0.5 when one class is absent.
pub fn auc(scores: &[f32], labels: &[u8]) -> f64 {
    assert_eq!(scores.len(), labels.len());
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&i, &j| scores[i].partial_cmp(&scores[j]).unwrap());
    // ranks with tie-averaging
    let n = scores.len();
    let mut rank = vec![0.0f64; n];
    let mut i = 0;
    while i < n {
        let mut j = i;
        while j + 1 < n && scores[idx[j + 1]] == scores[idx[i]] {
            j += 1;
        }
        let avg = (i + j) as f64 / 2.0 + 1.0; // 1-based average rank
        for k in i..=j {
            rank[idx[k]] = avg;
        }
        i = j + 1;
    }
    let n_pos = labels.iter().filter(|&&l| l == 1).count() as f64;
    let n_neg = n as f64 - n_pos;
    if n_pos == 0.0 || n_neg == 0.0 {
        return 0.5;
    }
    let rank_sum_pos: f64 = labels
        .iter()
        .enumerate()
        .filter(|(_, &l)| l == 1)
        .map(|(i, _)| rank[i])
        .sum();
    (rank_sum_pos - n_pos * (n_pos + 1.0) / 2.0) / (n_pos * n_neg)
}

/// KL divergence KL(p || q) over discrete distributions (natural log).
/// Zero-probability entries in `p` contribute 0; `q` entries are floored.
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    assert_eq!(p.len(), q.len());
    p.iter()
        .zip(q)
        .filter(|(&pi, _)| pi > 0.0)
        .map(|(&pi, &qi)| pi * (pi / qi.max(1e-12)).ln())
        .sum()
}

/// Argmax index (first on ties); None for empty.
pub fn argmax(xs: &[f32]) -> Option<usize> {
    if xs.is_empty() {
        return None;
    }
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    Some(best)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_variance_basic() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((mean(&xs) - 2.5).abs() < 1e-12);
        assert!((variance(&xs) - 1.25).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(variance(&[5.0]), 0.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [10.0, 20.0, 30.0, 40.0];
        assert_eq!(percentile(&xs, 0.0), 10.0);
        assert_eq!(percentile(&xs, 100.0), 40.0);
        assert!((percentile(&xs, 50.0) - 25.0).abs() < 1e-12);
    }

    #[test]
    fn mse_and_normalized() {
        let a = [1.0f32, 2.0, 3.0];
        let b = [1.0f32, 2.0, 5.0];
        assert!((mse(&a, &b) - 4.0 / 3.0).abs() < 1e-9);
        assert_eq!(mse(&a, &a), 0.0);
        assert!(normalized_mse(&a, &b) > 0.0);
    }

    #[test]
    fn auc_perfect_and_random() {
        let scores = [0.1f32, 0.2, 0.8, 0.9];
        let labels = [0u8, 0, 1, 1];
        assert!((auc(&scores, &labels) - 1.0).abs() < 1e-12);
        let inv = [1u8, 1, 0, 0];
        assert!((auc(&scores, &inv) - 0.0).abs() < 1e-12);
        // one class absent
        assert_eq!(auc(&scores, &[0, 0, 0, 0]), 0.5);
    }

    #[test]
    fn auc_handles_ties() {
        let scores = [0.5f32, 0.5, 0.5, 0.5];
        let labels = [0u8, 1, 0, 1];
        assert!((auc(&scores, &labels) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn kl_zero_for_identical() {
        let p = [0.25, 0.25, 0.25, 0.25];
        assert!(kl_divergence(&p, &p).abs() < 1e-12);
    }

    #[test]
    fn kl_positive_for_skewed() {
        let p = [0.7, 0.1, 0.1, 0.1];
        let q = [0.25, 0.25, 0.25, 0.25];
        let d = kl_divergence(&p, &q);
        assert!(d > 0.0);
        // hand computation
        let expect = 0.7 * (0.7f64 / 0.25).ln() + 3.0 * (0.1 * (0.1f64 / 0.25).ln());
        assert!((d - expect).abs() < 1e-12);
    }

    #[test]
    fn kl_ignores_zero_p() {
        let p = [1.0, 0.0];
        let q = [0.5, 0.5];
        assert!((kl_divergence(&p, &q) - (2.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn argmax_first_on_ties() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), Some(1));
        assert_eq!(argmax(&[]), None);
    }
}

//! Allocation-counting global allocator for the bench binaries.
//!
//! `bytes allocated per round` is a first-class perf metric alongside
//! ms/round: the round hot path is supposed to be reuse-dominated (encode
//! cache, pooled scratch, in-place recovery), and a regression that
//! reintroduces per-device model-sized allocations shows up here long
//! before it shows up in wall-clock noise.
//!
//! Usage (bench binaries only — a process has exactly one global
//! allocator, so the library itself never installs it):
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: caesar_fl::util::alloc_count::CountingAlloc = CountingAlloc;
//!
//! let before = alloc_count::snapshot();
//! // ... measured work ...
//! let d = alloc_count::snapshot().since(&before);
//! println!("{} bytes in {} allocations", d.bytes, d.count);
//! ```
//!
//! Counters are process-wide relaxed atomics: cheap enough to leave on,
//! exact for single-threaded sections, and a faithful total across
//! threads (ordering between threads is irrelevant for sums). Two
//! metrics are kept:
//!
//! * **traffic** — fresh requests only (`alloc`, `alloc_zeroed`, and the
//!   growth portion of `realloc`); frees are not subtracted. Read via
//!   [`snapshot`]/[`AllocSnapshot::since`].
//! * **residency** — [`live_bytes`] tracks outstanding bytes
//!   (allocations minus frees) and [`peak_bytes`] its high-water mark
//!   since the last [`reset_peak`]. This is what the `tree_agg` bench
//!   uses to assert that a chunk-sharded reduction never holds a
//!   model-sized buffer. The watermark is exact for single-threaded
//!   sections; concurrent sections make it a faithful upper bound on
//!   any instant's total.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static BYTES: AtomicU64 = AtomicU64::new(0);
static COUNT: AtomicU64 = AtomicU64::new(0);
static LIVE: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

#[inline]
fn on_alloc(size: usize) {
    BYTES.fetch_add(size as u64, Ordering::Relaxed);
    COUNT.fetch_add(1, Ordering::Relaxed);
    let live = LIVE.fetch_add(size as u64, Ordering::Relaxed) + size as u64;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

/// A [`System`]-backed allocator that counts allocation traffic and
/// tracks the live-bytes watermark.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        on_alloc(layout.size());
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        on_alloc(layout.size());
        System.alloc_zeroed(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size() as u64, Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let grown = new_size.saturating_sub(layout.size());
        if grown > 0 {
            BYTES.fetch_add(grown as u64, Ordering::Relaxed);
            COUNT.fetch_add(1, Ordering::Relaxed);
            let live = LIVE.fetch_add(grown as u64, Ordering::Relaxed) + grown as u64;
            PEAK.fetch_max(live, Ordering::Relaxed);
        } else {
            LIVE.fetch_sub((layout.size() - new_size) as u64, Ordering::Relaxed);
        }
        System.realloc(ptr, layout, new_size)
    }
}

/// Cumulative allocation counters at one instant.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct AllocSnapshot {
    pub bytes: u64,
    pub count: u64,
}

impl AllocSnapshot {
    /// Traffic between `earlier` and `self`.
    pub fn since(&self, earlier: &AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            bytes: self.bytes.wrapping_sub(earlier.bytes),
            count: self.count.wrapping_sub(earlier.count),
        }
    }
}

/// Read the current cumulative counters. Zeros (forever) unless the
/// process installed [`CountingAlloc`] as its `#[global_allocator]`.
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        bytes: BYTES.load(Ordering::Relaxed),
        count: COUNT.load(Ordering::Relaxed),
    }
}

/// Outstanding heap bytes right now (allocations minus frees). Zero
/// unless [`CountingAlloc`] is installed.
pub fn live_bytes() -> u64 {
    LIVE.load(Ordering::Relaxed)
}

/// High-water mark of [`live_bytes`] since the last [`reset_peak`].
pub fn peak_bytes() -> u64 {
    PEAK.load(Ordering::Relaxed)
}

/// Re-arm the watermark at the current live level, so the next
/// [`peak_bytes`] read reports the peak of the section that follows.
/// Call from a quiescent point (benches bracket single-threaded
/// sections); a racing allocation merely lands in one section or the
/// other.
pub fn reset_peak() {
    PEAK.store(LIVE.load(Ordering::Relaxed), Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    // The test binary does NOT install CountingAlloc (the lib must not
    // claim the global allocator), so only the pure accounting is
    // testable here; the bench binaries exercise the hot path.

    #[test]
    fn snapshot_delta_arithmetic() {
        let a = AllocSnapshot { bytes: 100, count: 3 };
        let b = AllocSnapshot { bytes: 175, count: 9 };
        let d = b.since(&a);
        assert_eq!(d.bytes, 75);
        assert_eq!(d.count, 6);
    }

    #[test]
    fn uninstalled_residency_is_zero() {
        assert_eq!(live_bytes(), 0);
        reset_peak();
        assert_eq!(peak_bytes(), 0);
    }

    #[test]
    fn uninstalled_counters_are_stable() {
        let a = snapshot();
        let _v: Vec<u64> = (0..1000).collect();
        let b = snapshot();
        assert_eq!(a, b, "lib tests must not have the counting allocator installed");
    }
}

//! Scoped worker pool for per-device round work (offline build: no tokio /
//! rayon). `scope_map` fans a closure over items on N std threads and
//! returns the results in input order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: min(available_parallelism, cap).
pub fn workers(cap: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(cap.max(1))
}

/// Apply `f` to each item index in parallel over `n_workers` scoped threads;
/// results are collected in input order. `f` must be Sync (called from many
/// threads) and the per-item outputs are written into a pre-sized Vec.
pub fn scope_map<T, F>(n_items: usize, n_workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n_items == 0 {
        return Vec::new();
    }
    let n_workers = n_workers.clamp(1, n_items);
    if n_workers == 1 {
        return (0..n_items).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<T>>> = (0..n_items).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                let v = f(i);
                *out[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker missed item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let out = scope_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicU64::new(0);
        let out = scope_map(1000, 8, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(scope_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(scope_map(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn workers_capped() {
        assert!(workers(4) >= 1 && workers(4) <= 4);
        assert_eq!(workers(0), 1.min(workers(1)));
    }
}

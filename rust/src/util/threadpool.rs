//! Worker threading for the round engine (offline build: no tokio /
//! rayon). Two tools live here:
//!
//! * [`scope_map`] — fan a closure over items on N scoped std threads and
//!   collect the results in input order (the experiments runner's tool);
//! * [`WorkerPool`] — N **long-lived** worker threads, each owning
//!   per-thread state built once via `setup(worker_idx)` *on the thread
//!   that keeps it* (this is where non-`Send` resources — a PJRT runtime,
//!   a trainer — live), fed per-round job batches over channels with
//!   completion-order streaming back to the caller. This replaced the
//!   per-round `scope_stream` scoped fan-out: worker state now survives
//!   round boundaries, so per-round fixed costs (runtime opens, trainer
//!   builds, thread-local `util::pool` scratch warm-up) are paid once per
//!   run instead of once per round.
//!
//! **`WorkerPool` lifecycle.** `new` spawns the workers and blocks until
//! every `setup` reports (any failure tears the pool down and returns the
//! first error). Each [`WorkerPool::run_batch`] broadcasts one batch; the
//! workers race down a shared item counter and stream outputs back.
//! `shutdown` (also on drop) delivers a stop command and joins every
//! thread — worker states drop on their own threads, as non-`Send` state
//! must.
//!
//! **Panic isolation.** A job panic retires exactly the worker that ran
//! it: the dying worker reports the item it was holding, hands its batch
//! slot back, and later batches skip it. The caller always observes
//! exactly `n_items` resolutions per batch — `Ok(output)` or
//! [`WorkerLost`] — never a deadlock, even if every worker dies.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Result};

/// Number of worker threads to use: `min(available_parallelism, cap)`,
/// never less than one. The cap is clamped up via `cap.max(1)`, so
/// callers may pass an unvalidated knob: `workers(0) == 1` by contract.
pub fn workers(cap: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(cap.max(1))
}

/// Apply `f` to each item index in parallel over `n_workers` scoped threads;
/// results are collected in input order. `f` must be Sync (called from many
/// threads) and the per-item outputs are written into a pre-sized Vec.
pub fn scope_map<T, F>(n_items: usize, n_workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n_items == 0 {
        return Vec::new();
    }
    let n_workers = n_workers.clamp(1, n_items);
    if n_workers == 1 {
        return (0..n_items).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<T>>> = (0..n_items).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                let v = f(i);
                *out[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker missed item"))
        .collect()
}

/// A batch item that produced no output: the worker running it panicked
/// (and was retired from the pool), or no live worker remained to claim
/// it. The item index identifies which job was lost.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerLost {
    pub item: usize,
}

/// Lifetime-erased per-batch callbacks. The `'static` is a lie told by a
/// transmute in [`WorkerPool::run_batch`]: the references point into that
/// call's stack frame, and the batch protocol guarantees every worker has
/// left the batch (decremented `active`) before `run_batch` returns — so
/// the referents outlive every call. `W` appears only in argument
/// position; each worker invokes the hooks against its own state.
struct BatchHooks<W: 'static> {
    /// Run item `i` against the worker's state and deliver its output.
    run: &'static (dyn Fn(&mut W, usize) + Sync),
    /// Report that the worker holding item `i` is dying without output.
    lost: &'static (dyn Fn(usize) + Sync),
    /// Report that the last participant has left the batch.
    done: &'static (dyn Fn() + Sync),
}

/// One broadcast unit of work: workers race down `next` claiming items.
struct Batch<W: 'static> {
    next: AtomicUsize,
    n_items: usize,
    /// Participants still inside the batch, plus one hold for the caller
    /// while it broadcasts. Whoever decrements it to zero owes `done` —
    /// if that is the caller's own release, no worker ever will.
    active: AtomicUsize,
    hooks: BatchHooks<W>,
}

enum Cmd<W: 'static> {
    Batch(Arc<Batch<W>>),
    Shutdown,
}

struct WorkerLink<W: 'static> {
    tx: Sender<Cmd<W>>,
    /// Cleared by the worker itself as it dies (panic or setup failure),
    /// strictly before it leaves its final batch — so a caller that saw
    /// that batch finish also sees the flag.
    alive: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

/// Persistent worker pool: N long-lived threads, each owning non-`Send`
/// state `W` built once at construction and reused across every batch
/// until shutdown. See the module docs for the lifecycle and the panic
/// contract.
pub struct WorkerPool<W: 'static> {
    links: Vec<WorkerLink<W>>,
    builds: usize,
    /// The construction-time setup, retained so [`WorkerPool::respawn_dead`]
    /// can rebuild a retired worker's state on a fresh thread exactly as
    /// at pool birth.
    setup: Arc<dyn Fn(usize) -> Result<W> + Send + Sync>,
}

impl<W: 'static> WorkerPool<W> {
    /// Spawn `n_workers` (min 1) long-lived threads, each building its own
    /// state once via `setup(worker_idx)` on the thread that will own it.
    /// Blocks until every worker reports in; if any `setup` fails (or
    /// panics) the started workers are shut down and the first error is
    /// returned.
    pub fn new<S>(n_workers: usize, setup: S) -> Result<WorkerPool<W>>
    where
        S: Fn(usize) -> Result<W> + Send + Sync + 'static,
    {
        let n_workers = n_workers.max(1);
        let setup: Arc<dyn Fn(usize) -> Result<W> + Send + Sync> = Arc::new(setup);
        let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
        let mut links = Vec::with_capacity(n_workers);
        for wi in 0..n_workers {
            let (tx, rx) = std::sync::mpsc::channel::<Cmd<W>>();
            let alive = Arc::new(AtomicBool::new(true));
            let handle = {
                let setup = Arc::clone(&setup);
                let alive = Arc::clone(&alive);
                let ready = ready_tx.clone();
                std::thread::spawn(move || worker_main(wi, rx, setup, alive, ready))
            };
            links.push(WorkerLink { tx, alive, handle: Some(handle) });
        }
        drop(ready_tx);
        let mut first_err = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    first_err.get_or_insert(e);
                }
                // a worker panicked inside setup without reporting; its
                // ready sender died with it
                Err(_) => {
                    first_err.get_or_insert_with(|| anyhow!("a worker panicked during setup"));
                    break;
                }
            }
        }
        let mut pool = WorkerPool { links, builds: n_workers, setup };
        if let Some(e) = first_err {
            pool.shutdown();
            return Err(e.context("worker pool setup"));
        }
        Ok(pool)
    }

    /// Threads this pool was built with (live or retired).
    pub fn workers(&self) -> usize {
        self.links.len()
    }

    /// Workers still accepting batches.
    pub fn alive(&self) -> usize {
        self.links.iter().filter(|l| l.alive.load(Ordering::Acquire)).count()
    }

    /// Worker states built over the pool's lifetime — exactly the worker
    /// count: setup runs once per thread, never per batch.
    pub fn builds(&self) -> usize {
        self.builds
    }

    /// Fan `n_items` jobs over the live workers and stream every item's
    /// outcome to `sink` in **completion order**. Blocks until all items
    /// are resolved: `Ok(output)` for completed jobs, `Err(WorkerLost)`
    /// for jobs whose worker panicked or that no live worker remained to
    /// claim — exactly `n_items` sink calls either way, never a hang.
    ///
    /// `f` runs on worker threads against their long-lived state; `sink`
    /// runs on the calling thread. At most ~`workers` outputs are in
    /// flight at once (bounded channel back-pressure).
    pub fn run_batch<T, F>(
        &self,
        n_items: usize,
        f: F,
        mut sink: impl FnMut(Result<T, WorkerLost>),
    ) where
        T: Send,
        F: Fn(&mut W, usize) -> T + Sync,
    {
        if n_items == 0 {
            return;
        }
        enum Msg<T> {
            Out(usize, T),
            Lost(usize),
            Done,
        }
        /// Unwind guard: if `sink` panics mid-drain, keep receiving until
        /// the batch's `Done` so no worker can still hold the stack hooks
        /// when the caller's frame unwinds.
        struct DrainToDone<'a, T> {
            rx: &'a Receiver<Msg<T>>,
            seen_done: Cell<bool>,
        }
        impl<T> Drop for DrainToDone<'_, T> {
            fn drop(&mut self) {
                while !self.seen_done.get() {
                    match self.rx.recv() {
                        Ok(Msg::Done) | Err(_) => self.seen_done.set(true),
                        Ok(_) => {}
                    }
                }
            }
        }

        let (tx, rx) = std::sync::mpsc::sync_channel::<Msg<T>>(self.links.len() + 1);
        let run = |state: &mut W, i: usize| {
            let _ = tx.send(Msg::Out(i, f(state, i)));
        };
        let lost = |i: usize| {
            let _ = tx.send(Msg::Lost(i));
        };
        let done = || {
            let _ = tx.send(Msg::Done);
        };
        // SAFETY (lifetime erasure): the hooks point into this stack
        // frame. They are invoked only by workers that are *inside* the
        // batch (`active` slot held), and this function does not return —
        // even on unwind, via `DrainToDone` — until every participant has
        // left the batch, so the referents outlive every call.
        #[allow(clippy::useless_transmute)]
        let hooks = unsafe {
            BatchHooks {
                run: std::mem::transmute::<
                    &(dyn Fn(&mut W, usize) + Sync),
                    &'static (dyn Fn(&mut W, usize) + Sync),
                >(&run),
                lost: std::mem::transmute::<
                    &(dyn Fn(usize) + Sync),
                    &'static (dyn Fn(usize) + Sync),
                >(&lost),
                done: std::mem::transmute::<&(dyn Fn() + Sync), &'static (dyn Fn() + Sync)>(
                    &done,
                ),
            }
        };
        let batch = Arc::new(Batch {
            next: AtomicUsize::new(0),
            n_items,
            active: AtomicUsize::new(1), // the caller's broadcast hold
            hooks,
        });
        for link in &self.links {
            if !link.alive.load(Ordering::Acquire) {
                continue;
            }
            // take the slot BEFORE sending so a fast worker can never
            // drive `active` to zero while the broadcast is in progress
            batch.active.fetch_add(1, Ordering::AcqRel);
            if link.tx.send(Cmd::Batch(Arc::clone(&batch))).is_err() {
                // died between batches with a stale alive flag
                batch.active.fetch_sub(1, Ordering::AcqRel);
            }
        }
        // release the caller's hold; if it is the last one out, nothing
        // was delivered (or every recipient already finished, with all
        // its messages queued) and no `done` will ever arrive
        let no_done = batch.active.fetch_sub(1, Ordering::AcqRel) == 1;

        let mut resolved = vec![false; n_items];
        if no_done {
            while let Ok(msg) = rx.try_recv() {
                match msg {
                    Msg::Out(i, v) => {
                        resolved[i] = true;
                        sink(Ok(v));
                    }
                    Msg::Lost(i) => {
                        resolved[i] = true;
                        sink(Err(WorkerLost { item: i }));
                    }
                    Msg::Done => {}
                }
            }
        } else {
            let drain = DrainToDone { rx: &rx, seen_done: Cell::new(false) };
            loop {
                match drain.rx.recv() {
                    Ok(Msg::Out(i, v)) => {
                        resolved[i] = true;
                        sink(Ok(v));
                    }
                    Ok(Msg::Lost(i)) => {
                        resolved[i] = true;
                        sink(Err(WorkerLost { item: i }));
                    }
                    Ok(Msg::Done) | Err(_) => {
                        drain.seen_done.set(true);
                        break;
                    }
                }
            }
        }
        // items no live worker ever claimed (mass worker death)
        for (i, &r) in resolved.iter().enumerate() {
            if !r {
                sink(Err(WorkerLost { item: i }));
            }
        }
    }

    /// Rebuild every retired worker on a fresh thread via the pool's
    /// original `setup` closure (same worker index, so index-dependent
    /// state is reconstructed identically). Blocks until each
    /// replacement reports in. Returns how many workers were rebuilt; on
    /// a setup failure the slot stays dead (the pool keeps running on
    /// the survivors) and the error is returned for a later retry.
    pub fn respawn_dead(&mut self) -> Result<usize> {
        let mut rebuilt = 0;
        for wi in 0..self.links.len() {
            if self.links[wi].alive.load(Ordering::Acquire) {
                continue;
            }
            // join the dead thread first: its state must be fully gone
            // before a replacement claims the slot
            if let Some(h) = self.links[wi].handle.take() {
                let _ = h.join();
            }
            let (tx, rx) = std::sync::mpsc::channel::<Cmd<W>>();
            let alive = Arc::new(AtomicBool::new(true));
            let (ready_tx, ready_rx) = std::sync::mpsc::channel::<Result<()>>();
            let handle = {
                let setup = Arc::clone(&self.setup);
                let alive = Arc::clone(&alive);
                std::thread::spawn(move || worker_main(wi, rx, setup, alive, ready_tx))
            };
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    let _ = handle.join();
                    return Err(e.context(format!("respawn worker {wi}")));
                }
                Err(_) => {
                    let _ = handle.join();
                    return Err(anyhow!("replacement worker {wi} panicked during setup"));
                }
            }
            self.links[wi] = WorkerLink { tx, alive, handle: Some(handle) };
            self.builds += 1;
            rebuilt += 1;
        }
        Ok(rebuilt)
    }

    /// Stop every worker and join its thread. Idempotent; also runs on
    /// drop. Worker states are dropped on their own threads (they may be
    /// non-`Send`).
    pub fn shutdown(&mut self) {
        for link in &self.links {
            let _ = link.tx.send(Cmd::Shutdown);
        }
        for link in &mut self.links {
            if let Some(h) = link.handle.take() {
                let _ = h.join();
            }
        }
    }
}

impl<W: 'static> Drop for WorkerPool<W> {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_main<W: 'static>(
    wi: usize,
    rx: Receiver<Cmd<W>>,
    setup: Arc<dyn Fn(usize) -> Result<W> + Send + Sync>,
    alive: Arc<AtomicBool>,
    ready: std::sync::mpsc::Sender<Result<()>>,
) {
    let mut state = match setup(wi) {
        Ok(s) => {
            let _ = ready.send(Ok(()));
            s
        }
        Err(e) => {
            alive.store(false, Ordering::Release);
            let _ = ready.send(Err(e.context(format!("worker {wi} setup"))));
            return;
        }
    };
    drop(ready);
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Batch(batch) => run_worker_batch(&batch, &mut state, &alive),
            Cmd::Shutdown => break,
        }
    }
    alive.store(false, Ordering::Release);
    // `state` drops here, on the thread that built it
}

/// Guard ensuring this worker's batch bookkeeping happens on every exit
/// path, including unwinding out of a panicked job: clear the alive flag,
/// report the held item as lost, hand back the batch slot (firing `done`
/// if this was the last participant out).
struct LeaveGuard<'a, W: 'static> {
    batch: &'a Batch<W>,
    alive: &'a AtomicBool,
    claimed: Cell<Option<usize>>,
}

impl<W: 'static> Drop for LeaveGuard<'_, W> {
    fn drop(&mut self) {
        if std::thread::panicking() {
            // dying: later batches must not count on this worker. The
            // store precedes the `active` RMW below, so any thread that
            // observes this batch finished also observes the flag.
            self.alive.store(false, Ordering::Release);
            if let Some(i) = self.claimed.take() {
                // still inside the batch (slot not yet returned), so the
                // erased hook is live per BatchHooks' contract
                (self.batch.hooks.lost)(i);
            }
        }
        if self.batch.active.fetch_sub(1, Ordering::AcqRel) == 1 {
            // last participant out: the final use of the hooks
            (self.batch.hooks.done)();
        }
    }
}

fn run_worker_batch<W: 'static>(batch: &Batch<W>, state: &mut W, alive: &AtomicBool) {
    let leave = LeaveGuard { batch, alive, claimed: Cell::new(None) };
    loop {
        let i = batch.next.fetch_add(1, Ordering::Relaxed);
        if i >= batch.n_items {
            break;
        }
        leave.claimed.set(Some(i));
        // hooks are live while the LeaveGuard holds our batch slot
        (batch.hooks.run)(state, i);
        leave.claimed.set(None);
    }
    drop(leave);
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let out = scope_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicU64::new(0);
        let out = scope_map(1000, 8, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(scope_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(scope_map(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn workers_capped() {
        assert!(workers(4) >= 1 && workers(4) <= 4);
        // the cap.max(1) contract: a zero cap clamps UP to exactly one
        // worker regardless of host parallelism
        assert_eq!(workers(0), 1);
        assert_eq!(workers(1), 1);
    }

    #[test]
    fn pool_covers_every_item_with_setup_once_per_worker() {
        let setups = Arc::new(AtomicU64::new(0));
        let s = Arc::clone(&setups);
        let pool = WorkerPool::new(4, move |wi| {
            s.fetch_add(1, Ordering::Relaxed);
            Ok(wi)
        })
        .unwrap();
        for _ in 0..3 {
            let mut got: Vec<usize> = Vec::new();
            pool.run_batch(200, |_state, i| i * 2, |r| got.push(r.unwrap()));
            got.sort_unstable();
            assert_eq!(got, (0..200).map(|i| i * 2).collect::<Vec<_>>());
        }
        // setup ran once per WORKER for the pool's whole life — three
        // batches did not rebuild anything
        assert_eq!(setups.load(Ordering::Relaxed), 4);
        assert_eq!(pool.builds(), 4);
        assert_eq!(pool.alive(), 4);
    }

    #[test]
    fn worker_state_persists_across_batches() {
        // single worker: its counter must carry over between batches
        let pool = WorkerPool::new(1, |_| Ok(0usize)).unwrap();
        let mut seen: Vec<usize> = Vec::new();
        for _ in 0..2 {
            pool.run_batch(
                5,
                |count, _i| {
                    *count += 1;
                    *count
                },
                |r| seen.push(r.unwrap()),
            );
        }
        seen.sort_unstable();
        assert_eq!(seen, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn empty_batch_returns_immediately() {
        let pool = WorkerPool::new(2, |_| Ok(())).unwrap();
        pool.run_batch(0, |_, i| i, |_r: Result<usize, WorkerLost>| panic!("no items"));
    }

    #[test]
    fn setup_failure_tears_the_pool_down() {
        let err = WorkerPool::new(3, |wi| {
            if wi == 1 {
                Err(anyhow!("no runtime"))
            } else {
                Ok(wi)
            }
        })
        .map(|_| ())
        .unwrap_err();
        assert!(format!("{err:#}").contains("no runtime"), "{err:#}");
    }

    #[test]
    fn panicking_job_is_reported_lost_without_hanging() {
        let pool = WorkerPool::new(3, |_| Ok(())).unwrap();
        let (mut oks, mut lost) = (Vec::new(), Vec::new());
        pool.run_batch(
            50,
            |_state, i| {
                if i == 7 {
                    panic!("boom");
                }
                i
            },
            |r| match r {
                Ok(v) => oks.push(v),
                Err(l) => lost.push(l.item),
            },
        );
        assert_eq!(lost, vec![7]);
        oks.sort_unstable();
        let expect: Vec<usize> = (0..50).filter(|&i| i != 7).collect();
        assert_eq!(oks, expect);
        // exactly the worker that ran item 7 was retired
        assert_eq!(pool.alive(), 2);
        // the pool still executes later batches on the survivors
        let mut got = Vec::new();
        pool.run_batch(20, |_s, i| i, |r| got.push(r.unwrap()));
        got.sort_unstable();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
    }

    #[test]
    fn respawn_rebuilds_dead_workers_via_the_original_setup() {
        let setups = Arc::new(AtomicU64::new(0));
        let s = Arc::clone(&setups);
        let mut pool = WorkerPool::new(3, move |_wi| {
            s.fetch_add(1, Ordering::Relaxed);
            Ok(())
        })
        .unwrap();
        pool.run_batch(
            10,
            |_s, i| {
                if i == 0 {
                    panic!("boom");
                }
            },
            |_r| {},
        );
        assert_eq!(pool.alive(), 2);
        // the replacement runs the same setup, on a fresh thread, in the
        // same worker slot
        assert_eq!(pool.respawn_dead().unwrap(), 1);
        assert_eq!(pool.alive(), 3);
        assert_eq!(pool.builds(), 4);
        assert_eq!(setups.load(Ordering::Relaxed), 4);
        // the healed pool covers whole batches again
        let mut got = Vec::new();
        pool.run_batch(20, |_s, i| i, |r| got.push(r.unwrap()));
        got.sort_unstable();
        assert_eq!(got, (0..20).collect::<Vec<_>>());
        // a fully-alive pool is a no-op
        assert_eq!(pool.respawn_dead().unwrap(), 0);
    }

    #[test]
    fn all_workers_dead_resolves_every_item_as_lost() {
        let pool = WorkerPool::new(1, |_| Ok(())).unwrap();
        let mut first = Vec::new();
        pool.run_batch(
            3,
            |_s, _i| -> usize { panic!("die immediately") },
            |r| first.push(r),
        );
        assert_eq!(first.len(), 3, "every item resolved");
        assert!(first.iter().all(|r| r.is_err()));
        assert_eq!(pool.alive(), 0);
        // with nobody left, a batch still resolves (all lost) instead of
        // hanging
        let mut second = Vec::new();
        pool.run_batch(4, |_s, i| i, |r| second.push(r));
        assert_eq!(second.len(), 4);
        assert!(second.iter().all(|r| r.is_err()));
    }

    #[test]
    fn drop_joins_threads_and_drops_worker_state_on_them() {
        struct Held(Arc<AtomicU64>);
        impl Drop for Held {
            fn drop(&mut self) {
                self.0.fetch_add(1, Ordering::Relaxed);
            }
        }
        let drops = Arc::new(AtomicU64::new(0));
        let d = Arc::clone(&drops);
        let pool = WorkerPool::new(3, move |_| Ok(Held(Arc::clone(&d)))).unwrap();
        pool.run_batch(10, |_s, i| i, |_r| {});
        assert_eq!(drops.load(Ordering::Relaxed), 0, "state lives between batches");
        drop(pool); // shutdown: joins every thread
        assert_eq!(drops.load(Ordering::Relaxed), 3, "every worker state dropped");
    }

    #[test]
    fn shutdown_is_idempotent() {
        let mut pool = WorkerPool::new(2, |_| Ok(())).unwrap();
        pool.shutdown();
        pool.shutdown();
        // a shut-down pool resolves batches as lost rather than hanging
        let mut got = Vec::new();
        pool.run_batch(2, |_s, i| i, |r| got.push(r));
        assert_eq!(got.len(), 2);
        assert!(got.iter().all(|r| r.is_err()));
    }

    #[test]
    fn outputs_stream_in_completion_order_with_bounded_inflight() {
        // one worker ⇒ completion order == input order, and the bounded
        // result channel cannot reorder or drop anything
        let pool = WorkerPool::new(1, |_| Ok(())).unwrap();
        let mut got = Vec::new();
        pool.run_batch(64, |_s, i| i, |r| got.push(r.unwrap()));
        assert_eq!(got, (0..64).collect::<Vec<_>>());
    }
}

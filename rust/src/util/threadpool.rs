//! Scoped worker pool for per-device round work (offline build: no tokio /
//! rayon). `scope_map` fans a closure over items on N std threads and
//! returns the results in input order.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Number of worker threads to use: min(available_parallelism, cap).
pub fn workers(cap: usize) -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(cap.max(1))
}

/// Apply `f` to each item index in parallel over `n_workers` scoped threads;
/// results are collected in input order. `f` must be Sync (called from many
/// threads) and the per-item outputs are written into a pre-sized Vec.
pub fn scope_map<T, F>(n_items: usize, n_workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if n_items == 0 {
        return Vec::new();
    }
    let n_workers = n_workers.clamp(1, n_items);
    if n_workers == 1 {
        return (0..n_items).map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let out: Vec<Mutex<Option<T>>> = (0..n_items).map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..n_workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n_items {
                    break;
                }
                let v = f(i);
                *out[i].lock().unwrap() = Some(v);
            });
        }
    });
    out.into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker missed item"))
        .collect()
}

/// Fan work items over `n_workers` scoped threads like [`scope_map`], with
/// two differences the round engine needs:
///
/// 1. each worker builds per-thread state once via `setup(worker_idx)` —
///    this is where non-`Sync` resources (a PJRT runtime, a trainer) are
///    constructed on the thread that will own them;
/// 2. outputs stream back to `sink` on the calling thread as they
///    complete (completion order, NOT input order) instead of being
///    collected, so at most ~`n_workers` outputs are in flight at once.
///
/// With `n_workers == 1` everything runs inline on the calling thread in
/// input order — the degenerate case parallel callers compare against.
pub fn scope_stream<T, W, S, F>(
    n_items: usize,
    n_workers: usize,
    setup: S,
    f: F,
    mut sink: impl FnMut(T),
) where
    T: Send,
    S: Fn(usize) -> W + Sync,
    F: Fn(&mut W, usize) -> T + Sync,
{
    if n_items == 0 {
        return;
    }
    let n_workers = n_workers.clamp(1, n_items);
    if n_workers == 1 {
        let mut state = setup(0);
        for i in 0..n_items {
            sink(f(&mut state, i));
        }
        return;
    }
    let next = AtomicUsize::new(0);
    // Bounded channel: a worker that races ahead of the sink blocks after
    // n_workers undelivered outputs, enforcing the in-flight bound above
    // (there is no reverse edge, so blocked senders cannot deadlock).
    let (tx, rx) = std::sync::mpsc::sync_channel::<T>(n_workers);
    std::thread::scope(|scope| {
        for wi in 0..n_workers {
            let tx = tx.clone();
            let (next, setup, f) = (&next, &setup, &f);
            scope.spawn(move || {
                let mut state = setup(wi);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n_items {
                        break;
                    }
                    if tx.send(f(&mut state, i)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);
        for t in rx.iter() {
            sink(t);
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn maps_in_order() {
        let out = scope_map(100, 4, |i| i * i);
        assert_eq!(out, (0..100).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn runs_every_item_exactly_once() {
        let counter = AtomicU64::new(0);
        let out = scope_map(1000, 8, |i| {
            counter.fetch_add(1, Ordering::Relaxed);
            i
        });
        assert_eq!(counter.load(Ordering::Relaxed), 1000);
        assert_eq!(out.len(), 1000);
    }

    #[test]
    fn single_worker_and_empty() {
        assert_eq!(scope_map(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
        assert_eq!(scope_map(0, 4, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn scope_stream_covers_every_item_with_worker_state() {
        let setups = AtomicU64::new(0);
        let mut got: Vec<usize> = Vec::new();
        scope_stream(
            200,
            4,
            |wi| {
                setups.fetch_add(1, Ordering::Relaxed);
                wi // worker state = worker index
            },
            |_state, i| i * 2,
            |v| got.push(v),
        );
        // every item exactly once (order is completion order)
        got.sort_unstable();
        assert_eq!(got, (0..200).map(|i| i * 2).collect::<Vec<_>>());
        // setup ran once per worker, not once per item
        assert!(setups.load(Ordering::Relaxed) <= 4);
    }

    #[test]
    fn scope_stream_single_worker_is_in_order() {
        let mut got = Vec::new();
        scope_stream(5, 1, |_| (), |_, i| i, |v| got.push(v));
        assert_eq!(got, vec![0, 1, 2, 3, 4]);
        let mut none = Vec::new();
        scope_stream(0, 4, |_| (), |_, i| i, |v: usize| none.push(v));
        assert!(none.is_empty());
    }

    #[test]
    fn workers_capped() {
        assert!(workers(4) >= 1 && workers(4) <= 4);
        assert_eq!(workers(0), 1.min(workers(1)));
    }
}

//! Miniature property-testing harness (offline build: no `proptest`).
//!
//! `forall(seed, cases, gen, check)` draws `cases` random inputs from `gen`
//! and asserts `check`; on failure it performs a simple halving shrink via
//! the generator's size parameter and reports the smallest failing case's
//! seed so the failure replays exactly.

use crate::util::rng::Rng;

/// Configuration for a property run.
pub struct Config {
    pub cases: usize,
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config { cases: 64, seed: 0xCAE5A5 }
    }
}

/// Run `check` on `cases` inputs drawn by `gen(rng, size)`, with `size`
/// ramping from small to large (so early failures are small). On failure,
/// retries smaller sizes with the same case-seed to shrink, then panics
/// with a replayable report.
pub fn forall<T, G, C>(cfg: Config, mut gen: G, mut check: C)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng, usize) -> T,
    C: FnMut(&T) -> Result<(), String>,
{
    let mut master = Rng::new(cfg.seed);
    for case in 0..cfg.cases {
        let case_seed = master.next_u64();
        // size ramp: 1 .. ~2^10, roughly exponential over the run
        let size = 1usize << (1 + (case * 10 / cfg.cases.max(1))).min(12);
        let mut rng = Rng::new(case_seed);
        let input = gen(&mut rng, size);
        if let Err(msg) = check(&input) {
            // shrink: halve size with same seed while it still fails
            let mut best: (usize, String, String) = (size, msg, format!("{input:?}"));
            let mut s = size / 2;
            while s >= 1 {
                let mut rng = Rng::new(case_seed);
                let smaller = gen(&mut rng, s);
                match check(&smaller) {
                    Err(m) => {
                        best = (s, m, format!("{smaller:?}"));
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property failed (case {case}, case_seed {case_seed:#x}, size {}):\n  {}\n  input: {}",
                best.0, best.1, best.2
            );
        }
    }
}

/// Convenience: generate a f32 vector of length ~size with the given scale.
pub fn gen_vec_f32(rng: &mut Rng, size: usize, scale: f32) -> Vec<f32> {
    let n = 1 + rng.below(size.max(1));
    (0..n).map(|_| rng.normal() as f32 * scale).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(
            Config::default(),
            |rng, size| gen_vec_f32(rng, size, 1.0),
            |v| {
                if v.iter().all(|x| x.is_finite()) {
                    Ok(())
                } else {
                    Err("non-finite".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_report() {
        forall(
            Config { cases: 32, seed: 1 },
            |rng, size| gen_vec_f32(rng, size, 1.0),
            |v| {
                if v.len() < 4 {
                    Ok(())
                } else {
                    Err(format!("len {} >= 4", v.len()))
                }
            },
        );
    }

    #[test]
    fn replays_deterministically() {
        let mut lens1 = Vec::new();
        forall(
            Config { cases: 16, seed: 9 },
            |rng, size| gen_vec_f32(rng, size, 1.0),
            |v| {
                lens1.push(v.len());
                Ok(())
            },
        );
        let mut lens2 = Vec::new();
        forall(
            Config { cases: 16, seed: 9 },
            |rng, size| gen_vec_f32(rng, size, 1.0),
            |v| {
                lens2.push(v.len());
                Ok(())
            },
        );
        assert_eq!(lens1, lens2);
    }
}

//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so we implement the
//! generators we need: SplitMix64 for seeding and xoshiro256** as the
//! workhorse, plus the distributions the simulator uses (uniform, normal
//! via Box–Muller, gamma via Marsaglia–Tsang, Dirichlet, categorical,
//! Fisher–Yates shuffling and reservoir-free subset sampling).
//!
//! Everything is deterministic given the seed: every experiment in
//! EXPERIMENTS.md records its seed and replays exactly.

/// SplitMix64 — used to expand a single `u64` seed into the xoshiro state.
#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG (Blackman–Vigna), period 2^256 − 1.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// Cached second normal deviate from Box–Muller.
    spare_normal: Option<f64>,
}

/// A generator's full state, capturable mid-stream and serializable.
///
/// This is what lets a *remote* device continue a per-(round, device)
/// stream bit-exactly after the parameter server has already consumed an
/// unknown number of draws from it (the PS-side download encode draws
/// stochastic-rounding noise for `Quant`): the PS captures
/// [`Rng::state`] post-encode, ships it in the `StartRound` frame, and
/// the device resumes via [`Rng::from_state`]. The cached Box–Muller
/// deviate is part of the state — dropping it would skew every normal
/// draw after an odd number of them.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub spare_normal: Option<f64>,
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent child generator (e.g. one per device) without
    /// correlation with the parent stream.
    pub fn fork(&mut self, tag: u64) -> Rng {
        // Mix the tag through SplitMix so adjacent tags diverge fully.
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive a generator purely from `(base, a, b)` — typically a
    /// per-(round, device) stream. Unlike [`Rng::fork`], no generator state
    /// is consumed, so the result is independent of when or in what order
    /// streams are derived. This is the property the parallel round engine
    /// relies on for bit-exact parity with sequential execution: device
    /// `d`'s randomness at round `t` is a function of `(base, t, d)` only.
    pub fn stream(base: u64, a: u64, b: u64) -> Rng {
        let mut sm = base;
        let x = splitmix64(&mut sm);
        sm = x ^ a.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let y = splitmix64(&mut sm);
        sm = y ^ b.wrapping_mul(0xBF58_476D_1CE4_E5B9);
        Rng::new(splitmix64(&mut sm))
    }

    /// Snapshot the full generator state (see [`RngState`]).
    pub fn state(&self) -> RngState {
        RngState { s: self.s, spare_normal: self.spare_normal }
    }

    /// Resume a generator from a [`Rng::state`] snapshot: the restored
    /// generator produces exactly the sequence the snapshotted one would
    /// have produced next.
    pub fn from_state(st: RngState) -> Rng {
        Rng { s: st.s, spare_normal: st.spare_normal }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 high bits → [0,1) with full double precision.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n). Uses Lemire's multiply-shift rejection.
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo < n {
                let t = n.wrapping_neg() % n;
                if lo < t {
                    continue;
                }
            }
            return hi as usize;
        }
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Lognormal parameterized by the *target* mean and the sigma of the
    /// underlying normal (used by the bandwidth fluctuation model).
    pub fn lognormal_mean(&mut self, mean: f64, sigma: f64) -> f64 {
        // E[exp(N(mu, sigma^2))] = exp(mu + sigma^2/2) = mean
        let mu = mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.normal()).exp()
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang; shape > 0.
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = loop {
                let u = self.f64();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v;
            }
        }
    }

    /// Dirichlet(alpha) sample: `alpha[i] > 0`, returns a probability vector.
    pub fn dirichlet(&mut self, alpha: &[f64]) -> Vec<f64> {
        let mut g: Vec<f64> = alpha.iter().map(|&a| self.gamma(a).max(1e-300)).collect();
        let sum: f64 = g.iter().sum();
        for x in &mut g {
            *x /= sum;
        }
        g
    }

    /// Symmetric Dirichlet(alpha/k, ..., alpha/k)? No — Dir(conc * prior).
    pub fn dirichlet_sym(&mut self, conc: f64, k: usize) -> Vec<f64> {
        self.dirichlet(&vec![conc; k])
    }

    /// Sample an index from an (unnormalized) weight vector.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x < 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Uniform sample of `k` distinct indices from [0, n) (partial shuffle).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_indices: k={k} > n={n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range_usize(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn determinism() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn fork_diverges_from_parent() {
        let mut a = Rng::new(7);
        let mut c = a.fork(0);
        let mut d = a.fork(1);
        let eq = (0..100).filter(|_| c.next_u64() == d.next_u64()).count();
        assert_eq!(eq, 0);
    }

    #[test]
    fn stream_is_pure_and_order_independent() {
        // same key → same sequence, regardless of anything else drawn
        let mut a = Rng::stream(42, 3, 7);
        let mut b = Rng::stream(42, 3, 7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // distinct keys (any coordinate) diverge
        let mut base = Rng::stream(42, 3, 7);
        for (bs, t, d) in [(43, 3, 7), (42, 4, 7), (42, 3, 8)] {
            let mut other = Rng::stream(bs, t, d);
            let same = (0..100).filter(|_| base.next_u64() == other.next_u64()).count();
            assert_eq!(same, 0, "{bs}/{t}/{d}");
            base = Rng::stream(42, 3, 7);
        }
    }

    #[test]
    fn state_roundtrip_resumes_exactly() {
        let mut a = Rng::stream(0xCAE5A2, 3, 7);
        // consume an odd number of normal draws so the Box–Muller spare
        // is populated — the part of the state a naive [u64; 4] copy loses
        for _ in 0..5 {
            a.normal();
        }
        a.next_u64();
        let mut b = Rng::from_state(a.state());
        for _ in 0..50 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // and the normal stream continues identically too
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
    }

    #[test]
    fn state_captures_the_spare_normal() {
        let mut a = Rng::new(11);
        a.normal(); // leaves a cached spare
        let st = a.state();
        assert!(st.spare_normal.is_some());
        let mut b = Rng::from_state(st);
        assert_eq!(a.normal().to_bits(), b.normal().to_bits());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let x = r.below(7);
            assert!(x < 7);
            seen[x] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(7);
        for &shape in &[0.3, 1.0, 2.5, 10.0] {
            let n = 50_000;
            let mean = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!(
                (mean - shape).abs() / shape < 0.08,
                "shape={shape} mean={mean}"
            );
        }
    }

    #[test]
    fn dirichlet_sums_to_one_and_is_positive() {
        let mut r = Rng::new(8);
        for &c in &[0.05, 0.5, 5.0] {
            let v = r.dirichlet_sym(c, 10);
            assert_eq!(v.len(), 10);
            assert!((v.iter().sum::<f64>() - 1.0).abs() < 1e-9);
            assert!(v.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_concentration_controls_skew() {
        // Low concentration → one class dominates; high → near-uniform.
        let mut r = Rng::new(9);
        let n = 500;
        let max_low: f64 = (0..n)
            .map(|_| {
                r.dirichlet_sym(0.1, 10)
                    .into_iter()
                    .fold(f64::MIN, f64::max)
            })
            .sum::<f64>()
            / n as f64;
        let max_high: f64 = (0..n)
            .map(|_| {
                r.dirichlet_sym(100.0, 10)
                    .into_iter()
                    .fold(f64::MIN, f64::max)
            })
            .sum::<f64>()
            / n as f64;
        assert!(max_low > 0.6, "max_low={max_low}");
        assert!(max_high < 0.25, "max_high={max_high}");
    }

    #[test]
    fn categorical_respects_weights() {
        let mut r = Rng::new(10);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[r.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(11);
        for _ in 0..100 {
            let s = r.sample_indices(50, 10);
            assert_eq!(s.len(), 10);
            let mut t = s.clone();
            t.sort_unstable();
            t.dedup();
            assert_eq!(t.len(), 10);
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(12);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut ys = xs.clone();
        ys.sort_unstable();
        assert_eq!(ys, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn lognormal_mean_targets_mean() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let m = (0..n).map(|_| r.lognormal_mean(10.0, 0.5)).sum::<f64>() / n as f64;
        assert!((m - 10.0).abs() < 0.3, "m={m}");
    }
}

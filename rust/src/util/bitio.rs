//! Bit-level packing used by the wire-format traffic accounting and the
//! (optional) actual serialization of compressed payloads.

/// Append-only bit writer (LSB-first within each byte).
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    nbits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_bit(&mut self, bit: bool) {
        let byte = self.nbits / 8;
        if byte == self.buf.len() {
            self.buf.push(0);
        }
        if bit {
            self.buf[byte] |= 1 << (self.nbits % 8);
        }
        self.nbits += 1;
    }

    /// Write the low `width` bits of `value`.
    pub fn push_bits(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        for i in 0..width {
            self.push_bit((value >> i) & 1 == 1);
        }
    }

    pub fn push_f32(&mut self, x: f32) {
        self.push_bits(x.to_bits() as u64, 32);
    }

    pub fn len_bits(&self) -> usize {
        self.nbits
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential bit reader matching [`BitWriter`]'s layout.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    pub fn read_bit(&mut self) -> bool {
        let b = (self.buf[self.pos / 8] >> (self.pos % 8)) & 1 == 1;
        self.pos += 1;
        b
    }

    pub fn read_bits(&mut self, width: u32) -> u64 {
        let mut v = 0u64;
        for i in 0..width {
            if self.read_bit() {
                v |= 1 << i;
            }
        }
        v
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    pub fn remaining_bits(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

/// Bits needed to store values in [0, n) (0 for n <= 1).
pub fn bits_for(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrip() {
        let mut w = BitWriter::new();
        w.push_bit(true);
        w.push_bit(false);
        w.push_bits(0b1011, 4);
        w.push_f32(3.5);
        w.push_bits(u64::MAX, 64);
        let bits = w.len_bits();
        assert_eq!(bits, 1 + 1 + 4 + 32 + 64);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        assert!(!r.read_bit());
        assert_eq!(r.read_bits(4), 0b1011);
        assert_eq!(r.read_f32(), 3.5);
        assert_eq!(r.read_bits(64), u64::MAX);
    }

    #[test]
    fn bits_for_ranges() {
        assert_eq!(bits_for(0), 0);
        assert_eq!(bits_for(1), 0);
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(256), 8);
        assert_eq!(bits_for(257), 9);
        assert_eq!(bits_for(9610), 14);
    }

    #[test]
    fn f32_special_values_roundtrip() {
        for x in [0.0f32, -0.0, f32::INFINITY, f32::MIN_POSITIVE, -1e-38] {
            let mut w = BitWriter::new();
            w.push_f32(x);
            let b = w.into_bytes();
            let got = BitReader::new(&b).read_f32();
            assert_eq!(got.to_bits(), x.to_bits());
        }
    }
}

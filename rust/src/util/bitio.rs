//! Bit-level packing: the serialization substrate of the `wire` payload
//! format. Every compressed tensor that "crosses the wire" in the
//! simulator is actually packed through these types, so they are on the
//! per-device round hot path — `push_bits`/`read_bits` move whole bytes
//! at a time instead of looping bit-by-bit.

/// Append-only bit writer (LSB-first within each byte).
#[derive(Default)]
pub struct BitWriter {
    /// Invariant: `buf.len() == nbits.div_ceil(8)` — the tail byte exists
    /// as soon as any of its bits do, with unused high bits zero.
    buf: Vec<u8>,
    nbits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_bit(&mut self, bit: bool) {
        let byte = self.nbits / 8;
        if byte == self.buf.len() {
            self.buf.push(0);
        }
        if bit {
            self.buf[byte] |= 1 << (self.nbits % 8);
        }
        self.nbits += 1;
    }

    /// Write the low `width` bits of `value` (byte-at-a-time).
    pub fn push_bits(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        if width == 0 {
            return;
        }
        let mut v = if width == 64 { value } else { value & ((1u64 << width) - 1) };
        let mut remaining = width as usize;
        // top up the partial tail byte first
        let used = self.nbits % 8;
        if used != 0 {
            let take = remaining.min(8 - used); // <= 7
            let mask = (1u8 << take) - 1;
            let last = self.buf.len() - 1;
            self.buf[last] |= ((v as u8) & mask) << used;
            v >>= take;
            remaining -= take;
            self.nbits += take;
        }
        while remaining >= 8 {
            self.buf.push(v as u8);
            v >>= 8;
            remaining -= 8;
            self.nbits += 8;
        }
        if remaining > 0 {
            self.buf.push((v as u8) & ((1u8 << remaining) - 1));
            self.nbits += remaining;
        }
    }

    pub fn push_f32(&mut self, x: f32) {
        self.push_bits(x.to_bits() as u64, 32);
    }

    /// Append a raw byte slice. Requires the writer to be byte-aligned
    /// (the transport frame codec keeps every field a multiple of 8 bits
    /// precisely so payload bytes splice in as a straight copy).
    pub fn push_bytes(&mut self, bytes: &[u8]) {
        assert_eq!(self.nbits % 8, 0, "push_bytes on an unaligned writer");
        self.buf.extend_from_slice(bytes);
        self.nbits += bytes.len() * 8;
    }

    pub fn len_bits(&self) -> usize {
        self.nbits
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential bit reader matching [`BitWriter`]'s layout.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    /// A reader positioned at an absolute bit offset — how
    /// `wire::PayloadView` opens several cursors into one byte stream
    /// (e.g. Top-K positions and values as paired lazy streams).
    pub fn at_bit(buf: &'a [u8], bit: usize) -> Self {
        debug_assert!(bit <= buf.len() * 8, "offset {bit} past {} bits", buf.len() * 8);
        BitReader { buf, pos: bit }
    }

    /// Current absolute bit position.
    pub fn bit_pos(&self) -> usize {
        self.pos
    }

    pub fn read_bit(&mut self) -> bool {
        let b = (self.buf[self.pos / 8] >> (self.pos % 8)) & 1 == 1;
        self.pos += 1;
        b
    }

    /// Read `width` bits (byte-at-a-time, inverse of `push_bits`).
    pub fn read_bits(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 64);
        let mut v = 0u64;
        let mut got = 0u32;
        let mut remaining = width;
        // drain the partial head byte first
        let used = (self.pos % 8) as u32;
        if remaining > 0 && used != 0 {
            let take = remaining.min(8 - used); // <= 7
            let mask = (1u8 << take) - 1;
            v |= ((self.buf[self.pos / 8] >> used) & mask) as u64;
            got += take;
            self.pos += take as usize;
            remaining -= take;
        }
        while remaining >= 8 {
            v |= (self.buf[self.pos / 8] as u64) << got;
            got += 8;
            self.pos += 8;
            remaining -= 8;
        }
        if remaining > 0 {
            let mask = (1u8 << remaining) - 1;
            v |= ((self.buf[self.pos / 8] & mask) as u64) << got;
            self.pos += remaining as usize;
        }
        v
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    /// Append `count` f32s to `out` — the bulk decode path. When the
    /// cursor is byte-aligned (every `Dense` payload, whose values start
    /// at bit 0) this reads whole little-endian words straight off the
    /// byte slice instead of shifting bit-by-bit; the unaligned fallback
    /// is bit-identical ([`BitWriter::push_bits`] emits LSB-first, i.e.
    /// little-endian byte order at aligned positions).
    pub fn read_f32s_into(&mut self, out: &mut Vec<f32>, count: usize) {
        out.reserve(count);
        if self.pos % 8 == 0 {
            let start = self.pos / 8;
            let words = self.buf[start..start + 4 * count].chunks_exact(4);
            out.extend(
                words.map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
            );
            self.pos += 32 * count;
        } else {
            for _ in 0..count {
                out.push(self.read_f32());
            }
        }
    }

    pub fn remaining_bits(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

/// Bits needed to store values in [0, n) (0 for n <= 1).
pub fn bits_for(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrip() {
        let mut w = BitWriter::new();
        w.push_bit(true);
        w.push_bit(false);
        w.push_bits(0b1011, 4);
        w.push_f32(3.5);
        w.push_bits(u64::MAX, 64);
        let bits = w.len_bits();
        assert_eq!(bits, 1 + 1 + 4 + 32 + 64);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        assert!(!r.read_bit());
        assert_eq!(r.read_bits(4), 0b1011);
        assert_eq!(r.read_f32(), 3.5);
        assert_eq!(r.read_bits(64), u64::MAX);
    }

    #[test]
    fn bits_for_ranges() {
        assert_eq!(bits_for(0), 0);
        assert_eq!(bits_for(1), 0);
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(256), 8);
        assert_eq!(bits_for(257), 9);
        assert_eq!(bits_for(9610), 14);
    }

    #[test]
    fn f32_special_values_roundtrip() {
        for x in [0.0f32, -0.0, f32::INFINITY, f32::MIN_POSITIVE, -1e-38] {
            let mut w = BitWriter::new();
            w.push_f32(x);
            let b = w.into_bytes();
            let got = BitReader::new(&b).read_f32();
            assert_eq!(got.to_bits(), x.to_bits());
        }
    }

    #[test]
    fn every_width_roundtrips_at_every_alignment() {
        // write k bits / read k bits identity for all widths 1..=64,
        // starting from every possible bit offset within a byte
        let v = 0xDEAD_BEEF_CAFE_F00Du64;
        for prefix in 0..8usize {
            for width in 1..=64u32 {
                let mut w = BitWriter::new();
                for i in 0..prefix {
                    w.push_bit(i % 2 == 0);
                }
                w.push_bits(v, width);
                w.push_bits(0b101, 3);
                assert_eq!(w.len_bits(), prefix + width as usize + 3);
                let bytes = w.into_bytes();
                let mut r = BitReader::new(&bytes);
                for i in 0..prefix {
                    assert_eq!(r.read_bit(), i % 2 == 0, "prefix bit {i}");
                }
                let want = if width == 64 { v } else { v & ((1u64 << width) - 1) };
                assert_eq!(r.read_bits(width), want, "prefix={prefix} width={width}");
                assert_eq!(r.read_bits(3), 0b101, "prefix={prefix} width={width} tail");
            }
        }
    }

    #[test]
    fn push_bytes_splices_aligned_runs() {
        let mut w = BitWriter::new();
        w.push_bits(0xAB, 8);
        w.push_bytes(&[1, 2, 3]);
        w.push_f32(2.5);
        assert_eq!(w.len_bits(), 8 + 24 + 32);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(8), 0xAB);
        assert_eq!(r.read_bits(8), 1);
        assert_eq!(r.read_bits(8), 2);
        assert_eq!(r.read_bits(8), 3);
        assert_eq!(r.read_f32(), 2.5);
    }

    #[test]
    #[should_panic(expected = "unaligned")]
    fn push_bytes_rejects_unaligned_writer() {
        let mut w = BitWriter::new();
        w.push_bit(true);
        w.push_bytes(&[0]);
    }

    #[test]
    fn at_bit_matches_sequential_cursor() {
        let mut w = BitWriter::new();
        w.push_bits(0b1101, 4);
        w.push_f32(-7.25);
        w.push_bits(0x3F, 6);
        let bytes = w.into_bytes();
        let mut r = BitReader::at_bit(&bytes, 4);
        assert_eq!(r.bit_pos(), 4);
        assert_eq!(r.read_f32(), -7.25);
        assert_eq!(r.read_bits(6), 0x3F);
        assert_eq!(r.bit_pos(), 4 + 32 + 6);
    }

    #[test]
    fn bulk_f32_read_matches_scalar_at_every_alignment() {
        let xs: Vec<f32> = (0..37).map(|i| (i as f32) * 1.7 - 11.0).collect();
        for prefix in 0..8usize {
            let mut w = BitWriter::new();
            for i in 0..prefix {
                w.push_bit(i % 2 == 1);
            }
            for &x in &xs {
                w.push_f32(x);
            }
            let bytes = w.into_bytes();
            // scalar reference
            let mut r1 = BitReader::at_bit(&bytes, prefix);
            let want: Vec<f32> = (0..xs.len()).map(|_| r1.read_f32()).collect();
            // bulk path (aligned fast path iff prefix == 0)
            let mut r2 = BitReader::at_bit(&bytes, prefix);
            let mut got = Vec::new();
            r2.read_f32s_into(&mut got, xs.len());
            assert_eq!(r2.bit_pos(), prefix + 32 * xs.len(), "prefix={prefix}");
            for (i, (a, b)) in want.iter().zip(&got).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "prefix={prefix} elem {i}");
            }
            for (a, b) in xs.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn bulk_f32_read_appends_without_clearing() {
        let mut w = BitWriter::new();
        w.push_f32(1.0);
        w.push_f32(2.0);
        let bytes = w.into_bytes();
        let mut out = vec![9.0f32];
        BitReader::new(&bytes).read_f32s_into(&mut out, 2);
        assert_eq!(out, vec![9.0, 1.0, 2.0]);
    }

    #[test]
    fn prop_mixed_width_sequences_roundtrip() {
        use crate::util::prop::{forall, Config};
        forall(
            Config { cases: 96, seed: 0xB170 },
            |rng, size| {
                let n = 1 + rng.below(size * 4);
                (0..n)
                    .map(|_| (rng.next_u64(), 1 + rng.below(64) as u32))
                    .collect::<Vec<(u64, u32)>>()
            },
            |items| {
                let mut w = BitWriter::new();
                for &(v, width) in items {
                    w.push_bits(v, width);
                }
                let total: usize = items.iter().map(|&(_, wd)| wd as usize).sum();
                if w.len_bits() != total {
                    return Err(format!("len_bits {} != {total}", w.len_bits()));
                }
                let bytes = w.into_bytes();
                if bytes.len() != total.div_ceil(8) {
                    return Err(format!("byte len {} != ceil({total}/8)", bytes.len()));
                }
                let mut r = BitReader::new(&bytes);
                for (i, &(v, width)) in items.iter().enumerate() {
                    let want = if width == 64 { v } else { v & ((1u64 << width) - 1) };
                    let got = r.read_bits(width);
                    if got != want {
                        return Err(format!("item {i} width {width}: {got:#x} != {want:#x}"));
                    }
                }
                if r.remaining_bits() >= 8 {
                    return Err(format!("{} bits left over", r.remaining_bits()));
                }
                Ok(())
            },
        );
    }
}

//! Bit-level packing: the serialization substrate of the `wire` payload
//! format. Every compressed tensor that "crosses the wire" in the
//! simulator is actually packed through these types, so they are on the
//! per-device round hot path — `push_bits`/`read_bits` move whole bytes
//! at a time instead of looping bit-by-bit.

/// Append-only bit writer (LSB-first within each byte).
#[derive(Default)]
pub struct BitWriter {
    /// Invariant: `buf.len() == nbits.div_ceil(8)` — the tail byte exists
    /// as soon as any of its bits do, with unused high bits zero.
    buf: Vec<u8>,
    nbits: usize,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push_bit(&mut self, bit: bool) {
        let byte = self.nbits / 8;
        if byte == self.buf.len() {
            self.buf.push(0);
        }
        if bit {
            self.buf[byte] |= 1 << (self.nbits % 8);
        }
        self.nbits += 1;
    }

    /// Write the low `width` bits of `value` (byte-at-a-time).
    pub fn push_bits(&mut self, value: u64, width: u32) {
        debug_assert!(width <= 64);
        if width == 0 {
            return;
        }
        let mut v = if width == 64 { value } else { value & ((1u64 << width) - 1) };
        let mut remaining = width as usize;
        // top up the partial tail byte first
        let used = self.nbits % 8;
        if used != 0 {
            let take = remaining.min(8 - used); // <= 7
            let mask = (1u8 << take) - 1;
            let last = self.buf.len() - 1;
            self.buf[last] |= ((v as u8) & mask) << used;
            v >>= take;
            remaining -= take;
            self.nbits += take;
        }
        while remaining >= 8 {
            self.buf.push(v as u8);
            v >>= 8;
            remaining -= 8;
            self.nbits += 8;
        }
        if remaining > 0 {
            self.buf.push((v as u8) & ((1u8 << remaining) - 1));
            self.nbits += remaining;
        }
    }

    pub fn push_f32(&mut self, x: f32) {
        self.push_bits(x.to_bits() as u64, 32);
    }

    pub fn len_bits(&self) -> usize {
        self.nbits
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Sequential bit reader matching [`BitWriter`]'s layout.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        BitReader { buf, pos: 0 }
    }

    pub fn read_bit(&mut self) -> bool {
        let b = (self.buf[self.pos / 8] >> (self.pos % 8)) & 1 == 1;
        self.pos += 1;
        b
    }

    /// Read `width` bits (byte-at-a-time, inverse of `push_bits`).
    pub fn read_bits(&mut self, width: u32) -> u64 {
        debug_assert!(width <= 64);
        let mut v = 0u64;
        let mut got = 0u32;
        let mut remaining = width;
        // drain the partial head byte first
        let used = (self.pos % 8) as u32;
        if remaining > 0 && used != 0 {
            let take = remaining.min(8 - used); // <= 7
            let mask = (1u8 << take) - 1;
            v |= ((self.buf[self.pos / 8] >> used) & mask) as u64;
            got += take;
            self.pos += take as usize;
            remaining -= take;
        }
        while remaining >= 8 {
            v |= (self.buf[self.pos / 8] as u64) << got;
            got += 8;
            self.pos += 8;
            remaining -= 8;
        }
        if remaining > 0 {
            let mask = (1u8 << remaining) - 1;
            v |= ((self.buf[self.pos / 8] & mask) as u64) << got;
            self.pos += remaining as usize;
        }
        v
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    pub fn remaining_bits(&self) -> usize {
        self.buf.len() * 8 - self.pos
    }
}

/// Bits needed to store values in [0, n) (0 for n <= 1).
pub fn bits_for(n: usize) -> u32 {
    if n <= 1 {
        0
    } else {
        usize::BITS - (n - 1).leading_zeros()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bit_roundtrip() {
        let mut w = BitWriter::new();
        w.push_bit(true);
        w.push_bit(false);
        w.push_bits(0b1011, 4);
        w.push_f32(3.5);
        w.push_bits(u64::MAX, 64);
        let bits = w.len_bits();
        assert_eq!(bits, 1 + 1 + 4 + 32 + 64);
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        assert!(!r.read_bit());
        assert_eq!(r.read_bits(4), 0b1011);
        assert_eq!(r.read_f32(), 3.5);
        assert_eq!(r.read_bits(64), u64::MAX);
    }

    #[test]
    fn bits_for_ranges() {
        assert_eq!(bits_for(0), 0);
        assert_eq!(bits_for(1), 0);
        assert_eq!(bits_for(2), 1);
        assert_eq!(bits_for(3), 2);
        assert_eq!(bits_for(256), 8);
        assert_eq!(bits_for(257), 9);
        assert_eq!(bits_for(9610), 14);
    }

    #[test]
    fn f32_special_values_roundtrip() {
        for x in [0.0f32, -0.0, f32::INFINITY, f32::MIN_POSITIVE, -1e-38] {
            let mut w = BitWriter::new();
            w.push_f32(x);
            let b = w.into_bytes();
            let got = BitReader::new(&b).read_f32();
            assert_eq!(got.to_bits(), x.to_bits());
        }
    }

    #[test]
    fn every_width_roundtrips_at_every_alignment() {
        // write k bits / read k bits identity for all widths 1..=64,
        // starting from every possible bit offset within a byte
        let v = 0xDEAD_BEEF_CAFE_F00Du64;
        for prefix in 0..8usize {
            for width in 1..=64u32 {
                let mut w = BitWriter::new();
                for i in 0..prefix {
                    w.push_bit(i % 2 == 0);
                }
                w.push_bits(v, width);
                w.push_bits(0b101, 3);
                assert_eq!(w.len_bits(), prefix + width as usize + 3);
                let bytes = w.into_bytes();
                let mut r = BitReader::new(&bytes);
                for i in 0..prefix {
                    assert_eq!(r.read_bit(), i % 2 == 0, "prefix bit {i}");
                }
                let want = if width == 64 { v } else { v & ((1u64 << width) - 1) };
                assert_eq!(r.read_bits(width), want, "prefix={prefix} width={width}");
                assert_eq!(r.read_bits(3), 0b101, "prefix={prefix} width={width} tail");
            }
        }
    }

    #[test]
    fn prop_mixed_width_sequences_roundtrip() {
        use crate::util::prop::{forall, Config};
        forall(
            Config { cases: 96, seed: 0xB170 },
            |rng, size| {
                let n = 1 + rng.below(size * 4);
                (0..n)
                    .map(|_| (rng.next_u64(), 1 + rng.below(64) as u32))
                    .collect::<Vec<(u64, u32)>>()
            },
            |items| {
                let mut w = BitWriter::new();
                for &(v, width) in items {
                    w.push_bits(v, width);
                }
                let total: usize = items.iter().map(|&(_, wd)| wd as usize).sum();
                if w.len_bits() != total {
                    return Err(format!("len_bits {} != {total}", w.len_bits()));
                }
                let bytes = w.into_bytes();
                if bytes.len() != total.div_ceil(8) {
                    return Err(format!("byte len {} != ceil({total}/8)", bytes.len()));
                }
                let mut r = BitReader::new(&bytes);
                for (i, &(v, width)) in items.iter().enumerate() {
                    let want = if width == 64 { v } else { v & ((1u64 << width) - 1) };
                    let got = r.read_bits(width);
                    if got != want {
                        return Err(format!("item {i} width {width}: {got:#x} != {want:#x}"));
                    }
                }
                if r.remaining_bits() >= 8 {
                    return Err(format!("{} bits left over", r.remaining_bits()));
                }
                Ok(())
            },
        );
    }
}

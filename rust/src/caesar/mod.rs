//! Caesar's three decision components (paper §4): staleness-aware download
//! ratios (Eq. 3 + the K-cluster optimization), data-importance-driven
//! upload ratios (Eq. 4–6), and the greedy batch-size regulation (Eq. 7–9).

pub mod batchsize;
pub mod importance;
pub mod staleness;

pub use batchsize::{optimize_batches, BatchPlanInput};
pub use importance::{importance, upload_ratio, ImportanceTable};
pub use staleness::{cluster_download_ratios, download_ratio, ParticipationTracker};

//! Greedy batch-size regulation (paper §4.3, Eq. 7–9).
//!
//! The PS estimates each participant's round cost M_i (Eq. 7) from its
//! nominal compression ratios, bandwidths and per-sample latency μ_i,
//! picks the device that would finish fastest *at b_max* (Eq. 8), gives it
//! b_max, and sizes every other device's batch so its round time matches
//! (Eq. 9, floored, clamped to [1, b_max]).

/// Per-participant inputs to the batch planner.
#[derive(Clone, Copy, Debug)]
pub struct BatchPlanInput {
    /// Estimated download time θ_d·Q/β_d (seconds).
    pub download_s: f64,
    /// Estimated upload time θ_u·Q/β_u (seconds).
    pub upload_s: f64,
    /// Per-sample compute latency μ_i (seconds).
    pub mu: f64,
}

/// Eq. 8 + Eq. 9. Returns (batch sizes, index of the pace-setting device).
pub fn optimize_batches(
    inputs: &[BatchPlanInput],
    tau: usize,
    b_max: usize,
) -> (Vec<usize>, usize) {
    assert!(!inputs.is_empty() && tau > 0 && b_max >= 1);
    // Eq. 8: fastest device at full batch
    let cost_at_bmax =
        |inp: &BatchPlanInput| inp.download_s + inp.upload_s + tau as f64 * b_max as f64 * inp.mu;
    let leader = inputs
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| cost_at_bmax(a).partial_cmp(&cost_at_bmax(b)).unwrap())
        .map(|(i, _)| i)
        .unwrap();
    let m_l = cost_at_bmax(&inputs[leader]);
    // Eq. 9 for everyone else
    let batches = inputs
        .iter()
        .enumerate()
        .map(|(i, inp)| {
            if i == leader {
                return b_max;
            }
            let budget = m_l - inp.download_s - inp.upload_s;
            // small epsilon guards float noise at exact-integer budgets
            let b = (budget / (tau as f64 * inp.mu) + 1e-9).floor();
            (b as i64).clamp(1, b_max as i64) as usize
        })
        .collect();
    (batches, leader)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inp(dl: f64, ul: f64, mu: f64) -> BatchPlanInput {
        BatchPlanInput { download_s: dl, upload_s: ul, mu }
    }

    #[test]
    fn leader_gets_bmax() {
        let inputs = vec![inp(1.0, 1.0, 0.001), inp(5.0, 5.0, 0.01)];
        let (batches, leader) = optimize_batches(&inputs, 30, 32);
        assert_eq!(leader, 0);
        assert_eq!(batches[0], 32);
        assert!(batches[1] < 32);
    }

    #[test]
    fn eq9_hand_computed() {
        // leader: dl+ul=2, mu=0.001, tau=10, bmax=32 → M_l = 2 + 0.32 = 2.32
        // other: dl+ul=1.32, mu=0.01 → b = floor((2.32-1.32)/(10*0.01)) = 10
        let inputs = vec![inp(1.0, 1.0, 0.001), inp(0.66, 0.66, 0.01)];
        let (batches, leader) = optimize_batches(&inputs, 10, 32);
        assert_eq!(leader, 0);
        assert_eq!(batches[1], 10);
    }

    #[test]
    fn slow_device_floors_at_one() {
        let inputs = vec![inp(0.1, 0.1, 0.0001), inp(100.0, 100.0, 10.0)];
        let (batches, _) = optimize_batches(&inputs, 30, 32);
        assert_eq!(batches[1], 1);
    }

    #[test]
    fn round_times_equalized_within_one_sample() {
        let inputs = vec![
            inp(1.0, 0.5, 0.002),
            inp(2.0, 1.0, 0.004),
            inp(0.5, 0.2, 0.001),
            inp(3.0, 2.0, 0.0005),
        ];
        let tau = 20;
        let (batches, leader) = optimize_batches(&inputs, tau, 32);
        let m_l = inputs[leader].download_s
            + inputs[leader].upload_s
            + tau as f64 * batches[leader] as f64 * inputs[leader].mu;
        for (i, b) in batches.iter().enumerate() {
            let m = inputs[i].download_s
                + inputs[i].upload_s
                + tau as f64 * *b as f64 * inputs[i].mu;
            // no device exceeds the leader unless clamped at b=1
            if *b > 1 {
                assert!(
                    m <= m_l + 1e-9,
                    "device {i}: m={m} > leader {m_l}"
                );
                // and within one sample's compute of the leader if not at cap
                if *b < 32 {
                    assert!(m + tau as f64 * inputs[i].mu > m_l - 1e-9);
                }
            }
        }
    }

    #[test]
    fn identical_devices_all_get_bmax() {
        let inputs = vec![inp(1.0, 1.0, 0.001); 5];
        let (batches, _) = optimize_batches(&inputs, 30, 16);
        assert!(batches.iter().all(|&b| b == 16));
    }

    #[test]
    fn single_device() {
        let (batches, leader) = optimize_batches(&[inp(1.0, 1.0, 0.01)], 10, 8);
        assert_eq!(batches, vec![8]);
        assert_eq!(leader, 0);
    }
}

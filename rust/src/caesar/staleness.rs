//! Local-model staleness tracking and the staleness-aware download
//! compression ratio (paper §4.1).
//!
//! Eq. 3: θ_d,i^t = (1 − δ_i^t / t) · θ_d^max, where δ_i^t = t − r_i is the
//! number of rounds since device i's last participation (δ = t, i.e. θ = 0
//! full precision, for devices that never participated).
//!
//! The K-cluster optimization groups participants by staleness (1-D
//! k-means) and compresses once per cluster at the cluster's mean
//! staleness, trading PS compute for ratio precision.

/// Tracks each device's last participation round.
#[derive(Clone, Debug)]
pub struct ParticipationTracker {
    /// last_round[i] = Some(r) if device i last participated in round r
    /// (with r counted from 1 as in the paper: r_i = 0 means "never").
    last_round: Vec<usize>,
}

impl ParticipationTracker {
    pub fn new(n_devices: usize) -> Self {
        ParticipationTracker { last_round: vec![0; n_devices] }
    }

    /// Staleness δ_i^t at round t (1-based rounds; t >= 1).
    pub fn staleness(&self, device: usize, t: usize) -> usize {
        debug_assert!(t >= 1);
        t - self.last_round[device]
    }

    /// True if the device has never participated (no local model exists).
    pub fn never_participated(&self, device: usize) -> bool {
        self.last_round[device] == 0
    }

    /// Record participation in round t.
    pub fn record(&mut self, device: usize, t: usize) {
        self.last_round[device] = t;
    }

    /// The raw per-device last-participation rounds (0 = never) — what
    /// the round journal snapshots.
    pub fn last_rounds(&self) -> &[usize] {
        &self.last_round
    }

    /// Rebuild a tracker from journaled state (crash resume).
    pub fn from_rounds(last_round: Vec<usize>) -> Self {
        ParticipationTracker { last_round }
    }

    pub fn len(&self) -> usize {
        self.last_round.len()
    }

    pub fn is_empty(&self) -> bool {
        self.last_round.is_empty()
    }
}

/// Eq. 3: download compression ratio from staleness.
pub fn download_ratio(staleness: usize, t: usize, theta_d_max: f64) -> f64 {
    debug_assert!(t >= 1 && staleness <= t);
    (1.0 - staleness as f64 / t as f64) * theta_d_max
}

/// 1-D k-means over staleness values; returns per-participant download
/// ratios computed at their cluster's mean staleness (paper §4.1's
/// cluster-based solution). `k` is clamped to the number of participants.
pub fn cluster_download_ratios(
    stalenesses: &[usize],
    t: usize,
    theta_d_max: f64,
    k: usize,
) -> (Vec<f64>, usize) {
    let n = stalenesses.len();
    if n == 0 {
        return (vec![], 0);
    }
    let k = k.clamp(1, n);
    // init centers at quantiles of the sorted values
    let mut sorted: Vec<f64> = stalenesses.iter().map(|&s| s as f64).collect();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut centers: Vec<f64> = if k == 1 {
        vec![sorted.iter().sum::<f64>() / n as f64]
    } else {
        // spread the initial centers over the full sorted range so K = n
        // recovers the exact per-device ratios (Eq. 3)
        (0..k).map(|j| sorted[(j * (n - 1)) / (k - 1)]).collect()
    };
    centers.dedup();
    let k = centers.len();

    let mut assign = vec![0usize; n];
    for _ in 0..32 {
        // assign
        let mut changed = false;
        for (i, &s) in stalenesses.iter().enumerate() {
            let mut best = (f64::MAX, 0usize);
            for (j, &c) in centers.iter().enumerate() {
                let d = (s as f64 - c).abs();
                if d < best.0 {
                    best = (d, j);
                }
            }
            if assign[i] != best.1 {
                assign[i] = best.1;
                changed = true;
            }
        }
        // update
        for (j, c) in centers.iter_mut().enumerate() {
            let members: Vec<f64> = stalenesses
                .iter()
                .enumerate()
                .filter(|(i, _)| assign[*i] == j)
                .map(|(_, &s)| s as f64)
                .collect();
            if !members.is_empty() {
                *c = members.iter().sum::<f64>() / members.len() as f64;
            }
        }
        if !changed {
            break;
        }
    }
    let ratios = assign
        .iter()
        .map(|&j| {
            let mean_staleness = centers[j].min(t as f64);
            (1.0 - mean_staleness / t as f64) * theta_d_max
        })
        .collect();
    (ratios, k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staleness_counts_missed_rounds() {
        let mut tr = ParticipationTracker::new(3);
        assert!(tr.never_participated(0));
        assert_eq!(tr.staleness(0, 5), 5); // never participated → δ = t
        tr.record(0, 3);
        assert_eq!(tr.staleness(0, 5), 2);
        assert!(!tr.never_participated(0));
        tr.record(0, 5);
        assert_eq!(tr.staleness(0, 5), 0);
    }

    #[test]
    fn eq3_fresh_gets_max_ratio() {
        // δ=0 → full θ_max; δ=t (never) → 0 (full precision download)
        assert_eq!(download_ratio(0, 10, 0.6), 0.6);
        assert_eq!(download_ratio(10, 10, 0.6), 0.0);
        let mid = download_ratio(5, 10, 0.6);
        assert!((mid - 0.3).abs() < 1e-12);
    }

    #[test]
    fn eq3_monotone_in_staleness() {
        let mut prev = f64::MAX;
        for s in 0..=20 {
            let r = download_ratio(s, 20, 0.6);
            assert!(r <= prev);
            prev = r;
        }
    }

    #[test]
    fn cluster_ratios_group_similar_staleness() {
        let st = vec![1, 1, 2, 2, 50, 50, 51, 49];
        let (ratios, k) = cluster_download_ratios(&st, 100, 0.6, 2);
        assert_eq!(k, 2);
        // devices 0-3 share a ratio; devices 4-7 share a (smaller) ratio
        assert_eq!(ratios[0], ratios[1]);
        assert_eq!(ratios[4], ratios[5]);
        assert!(ratios[0] > ratios[4]);
    }

    #[test]
    fn cluster_k1_uses_global_mean() {
        let st = vec![0, 10, 20];
        let (ratios, k) = cluster_download_ratios(&st, 20, 0.6, 1);
        assert_eq!(k, 1);
        let want = (1.0 - 10.0 / 20.0) * 0.6;
        for r in ratios {
            assert!((r - want).abs() < 1e-9);
        }
    }

    #[test]
    fn cluster_k_equal_n_recovers_exact_eq3() {
        let st = vec![0, 5, 10, 15, 20];
        let (ratios, _) = cluster_download_ratios(&st, 20, 0.6, 5);
        for (i, &s) in st.iter().enumerate() {
            let want = download_ratio(s, 20, 0.6);
            assert!((ratios[i] - want).abs() < 1e-9, "{i}");
        }
    }

    #[test]
    fn cluster_handles_empty_and_single() {
        let (r, k) = cluster_download_ratios(&[], 10, 0.6, 3);
        assert!(r.is_empty());
        assert_eq!(k, 0);
        let (r, k) = cluster_download_ratios(&[4], 10, 0.6, 3);
        assert_eq!(r.len(), 1);
        assert_eq!(k, 1);
    }

    #[test]
    fn ratios_within_bounds() {
        let st: Vec<usize> = (0..50).map(|i| i % 25).collect();
        let (ratios, _) = cluster_download_ratios(&st, 25, 0.6, 4);
        for r in ratios {
            assert!((0.0..=0.6).contains(&r));
        }
    }
}

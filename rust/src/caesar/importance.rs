//! Gradient importance from local data properties and the rank-based
//! upload compression ratio (paper §4.2, Eq. 4–6).
//!
//! C_i = λ·A_i/A_max + (1−λ)·e^{−D_i}   (Eq. 5)
//! D_i = KL(Φ_i ‖ uniform)              (Eq. 4)
//! θ_u,i = θ_min + (θ_max−θ_min)/|N| · Rank(C_i)   (Eq. 6)
//!
//! Rank 0 = most important device → θ_min (least compression). The table
//! is computed once before training (importance is a static data property)
//! — exactly the paper's workflow.

/// Eq. 5 with the paper's default λ = 0.5.
pub const DEFAULT_LAMBDA: f64 = 0.5;

/// Importance of one device from its sample volume and KL gap.
pub fn importance(volume: usize, a_max: usize, kl_gap: f64, lambda: f64) -> f64 {
    let vol_term = volume as f64 / a_max.max(1) as f64;
    lambda * vol_term + (1.0 - lambda) * (-kl_gap).exp()
}

/// Eq. 6: upload ratio from a device's importance rank (0-based,
/// descending importance) among `n` devices.
pub fn upload_ratio(rank: usize, n: usize, theta_min: f64, theta_max: f64) -> f64 {
    debug_assert!(rank < n.max(1));
    theta_min + (theta_max - theta_min) / n.max(1) as f64 * rank as f64
}

/// Precomputed per-device importance and ranks.
#[derive(Clone, Debug)]
pub struct ImportanceTable {
    /// C_i per device.
    pub scores: Vec<f64>,
    /// rank[i] = 0-based position of device i in descending-score order.
    pub ranks: Vec<usize>,
}

impl ImportanceTable {
    /// Build from per-device (volume, KL-gap) pairs.
    pub fn build(volumes: &[usize], kl_gaps: &[f64], lambda: f64) -> ImportanceTable {
        assert_eq!(volumes.len(), kl_gaps.len());
        let a_max = volumes.iter().copied().max().unwrap_or(1);
        let scores: Vec<f64> = volumes
            .iter()
            .zip(kl_gaps)
            .map(|(&v, &d)| importance(v, a_max, d, lambda))
            .collect();
        let mut order: Vec<usize> = (0..scores.len()).collect();
        order.sort_by(|&a, &b| {
            scores[b]
                .partial_cmp(&scores[a])
                .unwrap()
                .then(a.cmp(&b)) // deterministic tie-break by id
        });
        let mut ranks = vec![0usize; scores.len()];
        for (pos, &dev) in order.iter().enumerate() {
            ranks[dev] = pos;
        }
        ImportanceTable { scores, ranks }
    }

    /// Eq. 6 for device `i`.
    pub fn upload_ratio(&self, i: usize, theta_min: f64, theta_max: f64) -> f64 {
        upload_ratio(self.ranks[i], self.ranks.len(), theta_min, theta_max)
    }

    pub fn len(&self) -> usize {
        self.scores.len()
    }

    pub fn is_empty(&self) -> bool {
        self.scores.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn importance_increases_with_volume() {
        let a = importance(100, 1000, 0.5, 0.5);
        let b = importance(900, 1000, 0.5, 0.5);
        assert!(b > a);
    }

    #[test]
    fn importance_decreases_with_kl_gap() {
        let a = importance(500, 1000, 0.0, 0.5);
        let b = importance(500, 1000, 2.0, 0.5);
        assert!(a > b);
    }

    #[test]
    fn lambda_extremes_isolate_terms() {
        // λ=1: only volume matters
        assert_eq!(
            importance(300, 1000, 9.9, 1.0),
            importance(300, 1000, 0.0, 1.0)
        );
        // λ=0: only distribution matters
        assert_eq!(
            importance(1, 1000, 0.7, 0.0),
            importance(999, 1000, 0.7, 0.0)
        );
    }

    #[test]
    fn eq5_hand_computed() {
        // C = 0.5 * 200/400 + 0.5 * e^{-ln 2} = 0.25 + 0.25 = 0.5
        let c = importance(200, 400, (2.0f64).ln(), 0.5);
        assert!((c - 0.5).abs() < 1e-12);
    }

    #[test]
    fn rank_zero_gets_theta_min() {
        assert_eq!(upload_ratio(0, 10, 0.1, 0.6), 0.1);
        let last = upload_ratio(9, 10, 0.1, 0.6);
        assert!(last < 0.6 && last > 0.5); // θ_min + 9/10·span
    }

    #[test]
    fn table_ranks_descending_importance() {
        // device 1 has the best data (big volume, uniform) → rank 0
        let volumes = [100, 1000, 400];
        let kls = [2.0, 0.0, 0.5];
        let t = ImportanceTable::build(&volumes, &kls, 0.5);
        assert_eq!(t.ranks[1], 0);
        assert!(t.scores[1] > t.scores[2] && t.scores[2] > t.scores[0]);
        assert_eq!(t.ranks[0], 2);
        // most important device gets the smallest upload ratio
        let r1 = t.upload_ratio(1, 0.1, 0.6);
        let r0 = t.upload_ratio(0, 0.1, 0.6);
        assert!(r1 < r0);
        assert_eq!(r1, 0.1);
    }

    #[test]
    fn ranks_are_a_permutation() {
        let volumes: Vec<usize> = (0..50).map(|i| (i * 37) % 500 + 1).collect();
        let kls: Vec<f64> = (0..50).map(|i| (i as f64 * 0.13) % 2.0).collect();
        let t = ImportanceTable::build(&volumes, &kls, 0.5);
        let mut sorted = t.ranks.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn tie_break_is_deterministic() {
        let t = ImportanceTable::build(&[100, 100], &[0.5, 0.5], 0.5);
        assert_eq!(t.ranks, vec![0, 1]);
    }

    #[test]
    fn ratios_stay_in_bounds() {
        let volumes: Vec<usize> = (1..=30).collect();
        let kls = vec![0.3; 30];
        let t = ImportanceTable::build(&volumes, &kls, 0.5);
        for i in 0..30 {
            let r = t.upload_ratio(i, 0.1, 0.6);
            assert!((0.1..=0.6).contains(&r));
        }
    }
}

//! Borrowed, lazily-decoded views over serialized payload bytes.
//!
//! [`Payload::decode_from`] materializes owned vectors (indices, values,
//! a full `CompressedModel`) before anyone consumes them — fine for
//! transcripts and tests, wasteful on the round hot path where the
//! decoded elements are immediately folded into an existing buffer
//! (download recovery writes into a reused per-worker model vector,
//! upload aggregation adds into an f64 shard). A [`PayloadView`] borrows
//! the `EncodedPayload`'s byte slice and streams elements straight out of
//! it: no intermediate `Vec` is ever built.
//!
//! Laziness is possible because every variant's layout is
//! cursor-computable from the out-of-band [`PayloadSpec`] plus the
//! measured bit length: Top-K's value stream starts exactly
//! `position_bits(n, kept)` bits in (so positions and values advance as
//! two paired [`BitReader`]s), CaesarSplit's two trailing scalars sit at
//! `bits − 64`, Dense and Quant are pure element streams. Dense reads use
//! the byte-aligned bulk-f32 fast path in [`crate::util::bitio`].
//!
//! Every view method is pinned bit-identical to the eager
//! `decode()`-then-densify path by `wire::view` unit tests and
//! `tests/wire_format.rs`.

use crate::compress::quant;
use crate::util::bitio::{bits_for, BitReader};

use super::payload::{index_list_is_cheaper, position_bits, EncodedPayload, PayloadSpec};

/// A borrowed decode cursor over one serialized payload.
pub enum PayloadView<'a> {
    Dense(DenseView<'a>),
    TopK(TopKView<'a>),
    CaesarSplit(CaesarSplitView<'a>),
    Quant(QuantView<'a>),
}

impl EncodedPayload {
    /// Open a lazy view over this payload's bytes.
    pub fn view(&self) -> PayloadView<'_> {
        match self.spec {
            PayloadSpec::Dense { n } => {
                PayloadView::Dense(DenseView { bytes: &self.bytes, n })
            }
            PayloadSpec::TopK { n, kept } => {
                PayloadView::TopK(TopKView { bytes: &self.bytes, n, kept })
            }
            PayloadSpec::CaesarSplit { n } => PayloadView::CaesarSplit(CaesarSplitView {
                bytes: &self.bytes,
                n,
                total_bits: self.bits,
            }),
            PayloadSpec::Quant { n, bits, levels } => {
                PayloadView::Quant(QuantView { bytes: &self.bytes, n, bits, levels })
            }
        }
    }
}

/// `n` little-endian f32 words starting at bit 0.
pub struct DenseView<'a> {
    bytes: &'a [u8],
    n: usize,
}

impl DenseView<'_> {
    pub fn n(&self) -> usize {
        self.n
    }

    /// Replace `out` with the decoded vector (bulk aligned reads).
    pub fn read_into(&self, out: &mut Vec<f32>) {
        out.clear();
        BitReader::new(self.bytes).read_f32s_into(out, self.n);
    }

    /// Stream `(index, value)` in order. Dense payloads start at bit 0,
    /// so this walks whole bytes — no bit shifting.
    pub fn for_each(&self, mut f: impl FnMut(usize, f32)) {
        for (i, c) in self.bytes.chunks_exact(4).take(self.n).enumerate() {
            f(i, f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
    }
}

/// Positions (bitmap or index list) then `kept` f32 values; streamed as
/// two paired cursors so neither an index nor a value vector is built.
pub struct TopKView<'a> {
    bytes: &'a [u8],
    n: usize,
    kept: usize,
}

impl TopKView<'_> {
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn kept(&self) -> usize {
        self.kept
    }

    /// Stream `(index, value)` pairs in ascending-index order — the
    /// decode context (`index_list_is_cheaper`) is re-derived from
    /// `(n, kept)` exactly as [`super::Payload::decode_from`] does.
    pub fn for_each(&self, mut f: impl FnMut(usize, f32)) {
        let mut vals = BitReader::at_bit(self.bytes, position_bits(self.n, self.kept));
        if index_list_is_cheaper(self.n, self.kept) {
            let idx_bits = bits_for(self.n);
            let mut idx = BitReader::new(self.bytes);
            for _ in 0..self.kept {
                f(idx.read_bits(idx_bits) as usize, vals.read_f32());
            }
        } else {
            let mut bitmap = BitReader::new(self.bytes);
            for pos in 0..self.n {
                if bitmap.read_bit() {
                    f(pos, vals.read_f32());
                }
            }
        }
    }
}

/// What one CaesarSplit position holds on the wire.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CaesarSlot {
    /// Full-precision parameter (mask bit 0).
    Kept(f32),
    /// 1-bit quantized parameter: the transmitted sign (+1 / −1).
    Sign(i8),
}

/// n-bit mask, interleaved sign-bit/f32 stream, then avg/max scalars at
/// the tail (located via the payload's measured bit length).
pub struct CaesarSplitView<'a> {
    bytes: &'a [u8],
    n: usize,
    total_bits: usize,
}

impl CaesarSplitView<'_> {
    pub fn n(&self) -> usize {
        self.n
    }

    /// The `(avg_abs, max_abs)` side info from the stream's tail.
    pub fn scalars(&self) -> (f32, f32) {
        let mut r = BitReader::at_bit(self.bytes, self.total_bits - 64);
        (r.read_f32(), r.read_f32())
    }

    /// Stream every position's slot in order: the mask cursor and the
    /// per-position payload cursor advance together.
    pub fn for_each(&self, mut f: impl FnMut(usize, CaesarSlot)) {
        let mut mask = BitReader::new(self.bytes);
        let mut data = BitReader::at_bit(self.bytes, self.n);
        for i in 0..self.n {
            if mask.read_bit() {
                f(i, CaesarSlot::Sign(if data.read_bit() { 1 } else { -1 }));
            } else {
                f(i, CaesarSlot::Kept(data.read_f32()));
            }
        }
    }

    /// §4.1 recovery straight into `out` — bit-identical to
    /// [`crate::compress::caesar_recover`] over the decoded model, with
    /// no intermediate `CompressedModel`.
    pub fn recover_into(&self, local: &[f32], out: &mut Vec<f32>) {
        assert_eq!(self.n, local.len(), "local model length mismatch");
        let (avg_abs, max_abs) = self.scalars();
        out.clear();
        out.reserve(self.n);
        self.for_each(|i, slot| match slot {
            CaesarSlot::Kept(v) => out.push(v),
            CaesarSlot::Sign(sign) => {
                let l = local[i];
                let local_sign: i8 = if l >= 0.0 { 1 } else { -1 };
                let bad = local_sign != sign || l.abs() > max_abs;
                out.push(if bad { sign as f32 * avg_abs } else { l });
            }
        });
    }

    /// Prior-free reconstruction (`sign·avg_abs` at quantized slots) into
    /// `out` — bit-identical to `CompressedModel::naive_reconstruction`.
    pub fn naive_into(&self, out: &mut Vec<f32>) {
        let (avg_abs, _) = self.scalars();
        out.clear();
        out.reserve(self.n);
        self.for_each(|_, slot| match slot {
            CaesarSlot::Kept(v) => out.push(v),
            CaesarSlot::Sign(sign) => out.push(sign as f32 * avg_abs),
        });
    }
}

/// f32 norm then `n` × (sign bit + `bits`-wide bucket code).
pub struct QuantView<'a> {
    bytes: &'a [u8],
    n: usize,
    bits: u32,
    levels: u32,
}

impl QuantView<'_> {
    pub fn n(&self) -> usize {
        self.n
    }

    pub fn norm(&self) -> f32 {
        BitReader::new(self.bytes).read_f32()
    }

    /// Stream `(index, dequantized value)` in order — the same
    /// [`quant::dequantize_code`] expression as the dense reconstruction.
    pub fn for_each(&self, mut f: impl FnMut(usize, f32)) {
        let mut r = BitReader::new(self.bytes);
        let norm = r.read_f32();
        for i in 0..self.n {
            let neg = r.read_bit() as u32;
            let q = r.read_bits(self.bits) as u32;
            f(i, quant::dequantize_code((q << 1) | neg, self.levels, norm));
        }
    }

    /// Replace `out` with the dequantized vector.
    pub fn read_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.n);
        self.for_each(|_, v| out.push(v));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{caesar_compress, caesar_recover, topk};
    use crate::util::rng::Rng;
    use crate::wire::Payload;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn dense_view_matches_decode() {
        let x = randn(777, 0);
        let enc = Payload::Dense(x.clone()).encode();
        let PayloadView::Dense(v) = enc.view() else { panic!("wrong view") };
        assert_eq!(v.n(), 777);
        let mut out = vec![f32::NAN; 3]; // dirty buffer: read_into must clear
        v.read_into(&mut out);
        assert_bits_eq(&out, &x, "dense read_into");
        let mut streamed = Vec::new();
        v.for_each(|i, val| {
            assert_eq!(i, streamed.len());
            streamed.push(val);
        });
        assert_bits_eq(&streamed, &x, "dense for_each");
    }

    #[test]
    fn topk_view_matches_decode_both_position_encodings() {
        let g = randn(4096, 1);
        for ratio in [0.99, 0.2, 0.0, 1.0] {
            let (p, _) = topk::topk_encode(&g, ratio);
            let enc = p.encode();
            let Payload::TopK { indices, values, .. } = enc.decode() else { panic!() };
            let PayloadView::TopK(v) = enc.view() else { panic!("wrong view") };
            assert_eq!(v.kept(), indices.len(), "ratio={ratio}");
            let mut got_i = Vec::new();
            let mut got_v = Vec::new();
            v.for_each(|i, val| {
                got_i.push(i as u32);
                got_v.push(val);
            });
            assert_eq!(got_i, indices, "ratio={ratio}");
            assert_bits_eq(&got_v, &values, &format!("ratio={ratio}"));
        }
    }

    #[test]
    fn caesar_view_recovers_bit_identically() {
        let w = randn(1000, 2);
        let local = randn(1000, 3);
        for ratio in [0.0, 0.35, 0.6, 1.0] {
            let cm = caesar_compress(&w, ratio);
            let enc = Payload::CaesarSplit(cm.clone()).encode();
            let PayloadView::CaesarSplit(v) = enc.view() else { panic!("wrong view") };
            let (avg, max) = v.scalars();
            assert_eq!(avg.to_bits(), cm.avg_abs.to_bits(), "ratio={ratio}");
            assert_eq!(max.to_bits(), cm.max_abs.to_bits(), "ratio={ratio}");
            let mut rec = vec![1.0f32]; // dirty
            v.recover_into(&local, &mut rec);
            assert_bits_eq(&rec, &caesar_recover(&cm, &local), &format!("ratio={ratio}"));
            let mut naive = Vec::new();
            v.naive_into(&mut naive);
            assert_bits_eq(&naive, &cm.naive_reconstruction(), &format!("ratio={ratio}"));
        }
    }

    #[test]
    fn quant_view_matches_decoded_dense() {
        let x = randn(2048, 4);
        let noise: Vec<f32> = {
            let mut rng = Rng::new(5);
            (0..2048).map(|_| rng.f32()).collect()
        };
        for bits in [1u32, 4, 12, 28] {
            let levels = quant::levels_for_bits(bits);
            let (norm, codes) = quant::quantize_codes(&x, levels, Some(&noise));
            let enc = Payload::Quant { bits, levels, norm, codes }.encode();
            let PayloadView::Quant(v) = enc.view() else { panic!("wrong view") };
            assert_eq!(v.norm().to_bits(), norm.to_bits(), "bits={bits}");
            let mut out = Vec::new();
            v.read_into(&mut out);
            assert_bits_eq(&out, &enc.decode().to_dense(), &format!("bits={bits}"));
        }
    }

    #[test]
    fn zero_length_payloads_stream_nothing() {
        let enc = Payload::TopK { n: 64, indices: vec![], values: vec![] }.encode();
        let PayloadView::TopK(v) = enc.view() else { panic!() };
        v.for_each(|_, _| panic!("empty top-k must stream nothing"));
        let enc = Payload::Dense(Vec::new()).encode();
        let PayloadView::Dense(v) = enc.view() else { panic!() };
        let mut out = vec![5.0f32];
        v.read_into(&mut out);
        assert!(out.is_empty());
    }
}

//! First-class wire format for compressed FL payloads.
//!
//! Historically the codecs densified immediately to `Vec<f32>` and wire
//! cost was a *parallel* hand-maintained formula in `compress::traffic`
//! that could silently drift from what a codec actually emits. This module
//! makes the serialized form the source of truth: every compressed tensor
//! that crosses the simulated wire is a [`Payload`] with a bit-exact
//! `encode`/`decode` built on [`crate::util::bitio`], and traffic /
//! transfer-time accounting derives from the *measured* encoded length
//! ([`Payload::len_bits`] / [`EncodedPayload::bits`]). The legacy
//! closed-form formulas survive only as cross-checks ([`legacy_bits`],
//! debug-asserted on every encode and pinned by tests).
//!
//! Bit layout of each variant (LSB-first within each byte; see README
//! §Wire format):
//!
//! | variant       | layout                                                          |
//! |---------------|-----------------------------------------------------------------|
//! | `Dense`       | n × f32                                                         |
//! | `TopK`        | positions (n-bit bitmap OR k × ⌈log₂n⌉ index list, whichever is |
//! |               | cheaper) then k × f32 values in ascending-index order           |
//! | `CaesarSplit` | n-bit quantized bitmap, then per position: sign bit (quantized) |
//! |               | or f32 (kept), then avg_abs + max_abs as 2 × f32                |
//! | `Quant`       | f32 norm, then n × (sign bit + `bits`-wide bucket code)         |
//!
//! Decoding needs the out-of-band [`PayloadSpec`] (codec kind, element
//! count, Top-K kept count, quantizer width). A real transport would spend
//! a few header bytes on this; the legacy accounting never charged for it
//! and the measured lengths stay pinned to those formulas, so the spec
//! rides alongside the bytes in [`EncodedPayload`] instead.
//!
//! The hot path never materializes a decoded [`Payload`] at all: a
//! borrowed [`PayloadView`] ([`EncodedPayload::view`]) streams elements
//! lazily from the byte slice — download recovery writes into a reused
//! model buffer (`CodecEngine::recover_download_into`) and upload
//! aggregation folds straight off the bytes
//! (`AggregatorShard::fold_encoded`), both pinned bit-identical to the
//! eager decode path.

pub mod payload;
pub mod view;

pub use payload::{legacy_bits, EncodedPayload, Payload, PayloadSpec};
pub use view::{CaesarSlot, CaesarSplitView, DenseView, PayloadView, QuantView, TopKView};

//! The [`Payload`] enum and its bit-exact serialization.

use crate::compress::caesar_model::CompressedModel;
use crate::compress::{quant, traffic};
use crate::util::bitio::{bits_for, BitReader, BitWriter};

/// A compressed tensor in its wire form — what a codec actually emits.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Uncompressed fp32 vector.
    Dense(Vec<f32>),
    /// Top-K sparsification: the surviving entries of an `n`-vector, as
    /// ascending `indices` with their fp32 `values`.
    TopK { n: usize, indices: Vec<u32>, values: Vec<f32> },
    /// Caesar's §4.1 download codec: threshold-split Top-K + 1-bit signs
    /// with avg/max side info.
    CaesarSplit(CompressedModel),
    /// QSGD-style quantization: `levels` buckets, one `bits`-wide code +
    /// sign bit per element, and the fp32 max-norm. `code = (q << 1) | neg`
    /// (see `quant::quantize_codes`).
    Quant { bits: u32, levels: u32, norm: f32, codes: Vec<u32> },
}

/// Out-of-band decode context: what a transport header would carry. Not
/// charged to traffic (the legacy accounting never charged it either).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PayloadSpec {
    Dense { n: usize },
    TopK { n: usize, kept: usize },
    CaesarSplit { n: usize },
    Quant { n: usize, bits: u32, levels: u32 },
}

impl PayloadSpec {
    /// Dense element count of the described tensor.
    pub fn n(&self) -> usize {
        match *self {
            PayloadSpec::Dense { n }
            | PayloadSpec::TopK { n, .. }
            | PayloadSpec::CaesarSplit { n }
            | PayloadSpec::Quant { n, .. } => n,
        }
    }
}

/// A serialized payload: the bytes that cross the wire plus the measured
/// bit length (`bytes` are padded to the next byte boundary) and the
/// out-of-band decode spec.
#[derive(Clone, Debug, PartialEq)]
pub struct EncodedPayload {
    pub spec: PayloadSpec,
    pub bytes: Vec<u8>,
    /// Exact serialized length in bits — the wire truth that traffic and
    /// transfer-time accounting derive from.
    pub bits: usize,
}

impl EncodedPayload {
    pub fn decode(&self) -> Payload {
        Payload::decode_from(&mut BitReader::new(&self.bytes), &self.spec)
    }

    pub fn len_bytes(&self) -> usize {
        self.bytes.len()
    }
}

/// Top-K position encoding: an index list costs `kept·⌈log₂n⌉` bits, a
/// bitmap costs `n`; the encoder picks the cheaper (ties → index list) and
/// the decoder (and `wire::view`) re-derive the choice from `(n, kept)`.
pub(crate) fn index_list_is_cheaper(n: usize, kept: usize) -> bool {
    kept * bits_for(n) as usize <= n
}

/// Bit length of the Top-K position section — where the value stream
/// starts (`wire::view` opens its paired value cursor here).
pub(crate) fn position_bits(n: usize, kept: usize) -> usize {
    (kept * bits_for(n) as usize).min(n)
}

impl Payload {
    /// Dense element count.
    pub fn n(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len(),
            Payload::TopK { n, .. } => *n,
            Payload::CaesarSplit(cm) => cm.len(),
            Payload::Quant { codes, .. } => codes.len(),
        }
    }

    /// The out-of-band decode context for this payload.
    pub fn spec(&self) -> PayloadSpec {
        match self {
            Payload::Dense(v) => PayloadSpec::Dense { n: v.len() },
            Payload::TopK { n, indices, .. } => {
                PayloadSpec::TopK { n: *n, kept: indices.len() }
            }
            Payload::CaesarSplit(cm) => PayloadSpec::CaesarSplit { n: cm.len() },
            Payload::Quant { bits, levels, codes, .. } => {
                PayloadSpec::Quant { n: codes.len(), bits: *bits, levels: *levels }
            }
        }
    }

    /// Exact serialized size in bits, computed from the layout (no
    /// encoding pass). `encode` debug-asserts this against both the real
    /// writer output and the legacy `traffic` closed forms.
    pub fn len_bits(&self) -> usize {
        match self {
            Payload::Dense(v) => v.len() * 32,
            Payload::TopK { n, indices, values } => {
                debug_assert_eq!(indices.len(), values.len());
                values.len() * 32 + position_bits(*n, indices.len())
            }
            Payload::CaesarSplit(cm) => {
                let q = cm.n_quantized();
                cm.len() + q + (cm.len() - q) * 32 + 64
            }
            Payload::Quant { bits, codes, .. } => codes.len() * (1 + *bits as usize) + 32,
        }
    }

    /// Serialize into an in-progress writer.
    pub fn encode_into(&self, w: &mut BitWriter) {
        match self {
            Payload::Dense(v) => {
                for &x in v {
                    w.push_f32(x);
                }
            }
            Payload::TopK { n, indices, values } => {
                debug_assert!(
                    indices.windows(2).all(|p| p[0] < p[1]),
                    "TopK indices must be ascending"
                );
                debug_assert!(indices.iter().all(|&i| (i as usize) < *n));
                if index_list_is_cheaper(*n, indices.len()) {
                    let idx_bits = bits_for(*n);
                    for &i in indices {
                        w.push_bits(i as u64, idx_bits);
                    }
                } else {
                    let mut it = indices.iter().peekable();
                    for pos in 0..*n {
                        let hit = it.peek().is_some_and(|&&p| p as usize == pos);
                        if hit {
                            it.next();
                        }
                        w.push_bit(hit);
                    }
                }
                for &v in values {
                    w.push_f32(v);
                }
            }
            Payload::CaesarSplit(cm) => cm.encode_into(w),
            Payload::Quant { bits, levels, norm, codes } => {
                debug_assert!(*bits >= 1 && *bits <= 32);
                debug_assert!(
                    (*levels as u64) < (1u64 << *bits),
                    "bucket range must fit the charged width"
                );
                w.push_f32(*norm);
                for &c in codes {
                    w.push_bit(c & 1 == 1);
                    w.push_bits((c >> 1) as u64, *bits);
                }
            }
        }
    }

    /// Serialize to bytes. The measured length is debug-asserted against
    /// both `len_bits` and the legacy traffic formulas — the cross-check
    /// that replaced formula-only accounting.
    pub fn encode(&self) -> EncodedPayload {
        let mut w = BitWriter::new();
        self.encode_into(&mut w);
        let bits = w.len_bits();
        debug_assert_eq!(bits, self.len_bits(), "layout drifted from len_bits");
        debug_assert_eq!(bits, legacy_bits(self), "wire drifted from traffic formulas");
        EncodedPayload { spec: self.spec(), bits, bytes: w.into_bytes() }
    }

    /// Inverse of [`Payload::encode_into`] given the out-of-band spec.
    pub fn decode_from(r: &mut BitReader, spec: &PayloadSpec) -> Payload {
        match *spec {
            PayloadSpec::Dense { n } => {
                Payload::Dense((0..n).map(|_| r.read_f32()).collect())
            }
            PayloadSpec::TopK { n, kept } => {
                let indices: Vec<u32> = if index_list_is_cheaper(n, kept) {
                    let idx_bits = bits_for(n);
                    (0..kept).map(|_| r.read_bits(idx_bits) as u32).collect()
                } else {
                    let mut idx = Vec::with_capacity(kept);
                    for pos in 0..n {
                        if r.read_bit() {
                            idx.push(pos as u32);
                        }
                    }
                    idx
                };
                debug_assert_eq!(indices.len(), kept, "bitmap popcount disagrees with spec");
                let values = (0..indices.len()).map(|_| r.read_f32()).collect();
                Payload::TopK { n, indices, values }
            }
            PayloadSpec::CaesarSplit { n } => {
                Payload::CaesarSplit(CompressedModel::decode_from(r, n))
            }
            PayloadSpec::Quant { n, bits, levels } => {
                let norm = r.read_f32();
                let codes = (0..n)
                    .map(|_| {
                        let neg = r.read_bit() as u32;
                        let q = r.read_bits(bits) as u32;
                        (q << 1) | neg
                    })
                    .collect();
                Payload::Quant { bits, levels, norm, codes }
            }
        }
    }

    /// Densify to a flat f32 vector. For `Dense`/`TopK`/`Quant` this is
    /// bit-identical to what the legacy eager codecs produced. For
    /// `CaesarSplit` it is the *prior-free* reconstruction (`sign·avg_abs`
    /// at quantized slots) — receivers with a stale local model should use
    /// `compress::caesar_recover` instead.
    pub fn to_dense(&self) -> Vec<f32> {
        match self {
            Payload::Dense(v) => v.clone(),
            Payload::TopK { n, indices, values } => {
                let mut out = vec![0.0f32; *n];
                for (&i, &v) in indices.iter().zip(values) {
                    out[i as usize] = v;
                }
                out
            }
            Payload::CaesarSplit(cm) => cm.naive_reconstruction(),
            Payload::Quant { levels, norm, codes, .. } => codes
                .iter()
                .map(|&c| quant::dequantize_code(c, *levels, *norm))
                .collect(),
        }
    }

    /// Consuming densify: moves the vector out for `Dense` (no clone on
    /// the uncompressed hot path); other variants fall back to
    /// [`Payload::to_dense`].
    pub fn into_dense(self) -> Vec<f32> {
        match self {
            Payload::Dense(v) => v,
            other => other.to_dense(),
        }
    }
}

/// The legacy closed-form accounting from [`crate::compress::traffic`] —
/// now a cross-check only: `encode` debug-asserts the measured length
/// against it, and `tests/wire_format.rs` pins the equality per codec.
pub fn legacy_bits(p: &Payload) -> usize {
    match p {
        Payload::Dense(v) => traffic::full_model_bits(v.len()),
        Payload::TopK { n, indices, .. } => traffic::topk_grad_bits(*n, indices.len()),
        Payload::CaesarSplit(cm) => traffic::caesar_model_bits(cm.len(), cm.n_quantized()),
        Payload::Quant { bits, codes, .. } => traffic::quantized_bits(codes.len(), *bits),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{caesar_compress, topk};
    use crate::util::prop::{forall, gen_vec_f32, Config};
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn roundtrip(p: &Payload) -> Payload {
        let enc = p.encode();
        assert_eq!(enc.bits, p.len_bits());
        assert_eq!(enc.bits, legacy_bits(p));
        assert_eq!(enc.len_bytes(), enc.bits.div_ceil(8));
        enc.decode()
    }

    #[test]
    fn dense_roundtrip() {
        let p = Payload::Dense(randn(257, 0));
        assert_eq!(roundtrip(&p), p);
        assert_eq!(p.len_bits(), 257 * 32);
    }

    #[test]
    fn topk_roundtrip_both_position_encodings() {
        let g = randn(4096, 1);
        // sparse → index list; dense → bitmap
        for ratio in [0.99, 0.2] {
            let (p, _) = topk::topk_encode(&g, ratio);
            let back = roundtrip(&p);
            assert_eq!(back, p, "ratio={ratio}");
            assert_eq!(back.to_dense(), topk::topk_sparsify(&g, ratio).dense);
        }
    }

    #[test]
    fn topk_empty_and_full() {
        let g = randn(64, 2);
        let (empty, _) = topk::topk_encode(&g, 1.0);
        assert_eq!(empty.len_bits(), 0);
        assert_eq!(roundtrip(&empty), empty);
        let (full, _) = topk::topk_encode(&g, 0.0);
        assert_eq!(roundtrip(&full), full);
        assert_eq!(full.to_dense(), g);
    }

    #[test]
    fn caesar_roundtrip() {
        let w = randn(1000, 3);
        let p = Payload::CaesarSplit(caesar_compress(&w, 0.35));
        assert_eq!(roundtrip(&p), p);
    }

    #[test]
    fn quant_roundtrip_and_dense_parity() {
        let x = randn(2048, 4);
        let noise: Vec<f32> = {
            let mut rng = Rng::new(5);
            (0..2048).map(|_| rng.f32()).collect()
        };
        for bits in [1u32, 4, 12, 28] {
            let levels = quant::levels_for_bits(bits);
            let (norm, codes) = quant::quantize_codes(&x, levels, Some(&noise));
            let p = Payload::Quant { bits, levels, norm, codes };
            let back = roundtrip(&p);
            assert_eq!(back, p, "bits={bits}");
            let want = quant::quantize_stochastic(&x, levels, &noise);
            let got = back.to_dense();
            for i in 0..want.len() {
                assert_eq!(got[i].to_bits(), want[i].to_bits(), "bits={bits} elem {i}");
            }
        }
    }

    #[test]
    fn quant_zero_norm_roundtrip() {
        let x = vec![0.0f32; 33];
        let levels = quant::levels_for_bits(4);
        let (norm, codes) = quant::quantize_codes(&x, levels, None);
        let p = Payload::Quant { bits: 4, levels, norm, codes };
        assert_eq!(roundtrip(&p).to_dense(), x);
    }

    #[test]
    fn prop_payload_roundtrip_fuzz() {
        forall(
            Config { cases: 64, seed: 0x31BE },
            |rng, size| {
                let x = gen_vec_f32(rng, size * 4, 1.0);
                let kind = rng.below(4);
                let ratio = rng.f64();
                let bits = 1 + rng.below(28) as u32;
                (x, kind, ratio, bits)
            },
            |(x, kind, ratio, bits)| {
                let p = match kind {
                    0 => Payload::Dense(x.clone()),
                    1 => topk::topk_encode(x, *ratio).0,
                    2 => Payload::CaesarSplit(caesar_compress(x, *ratio)),
                    _ => {
                        let levels = quant::levels_for_bits(*bits);
                        let (norm, codes) = quant::quantize_codes(x, levels, None);
                        Payload::Quant { bits: *bits, levels, norm, codes }
                    }
                };
                let enc = p.encode();
                if enc.bits != legacy_bits(&p) {
                    return Err(format!("bits {} != legacy {}", enc.bits, legacy_bits(&p)));
                }
                if enc.decode() != p {
                    return Err("decode(encode(p)) != p".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn spec_reports_n() {
        assert_eq!(PayloadSpec::Dense { n: 5 }.n(), 5);
        assert_eq!(PayloadSpec::TopK { n: 7, kept: 2 }.n(), 7);
        assert_eq!(PayloadSpec::CaesarSplit { n: 9 }.n(), 9);
        assert_eq!(PayloadSpec::Quant { n: 3, bits: 4, levels: 15 }.n(), 3);
    }
}

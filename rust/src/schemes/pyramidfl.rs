//! PyramidFL (Li et al., MobiCom'22) — ranks devices by their last
//! observed *gradient norm* and uses the rank to set the gradient
//! compression ratio (high-norm devices compressed less), and fills
//! faster devices' idle time with extra local iterations. The model
//! download stays uncompressed (the paper's Fig. 7 discussion: PyramidFL
//! ignores download time).

use super::{DevicePlan, DownloadCodec, RoundCtx, Scheme, UploadCodec};

pub struct PyramidFl {
    /// Max extra local-iteration multiplier when filling idle time.
    pub max_tau_factor: f64,
    /// Local-iteration granularity (must match the AOT chunk size).
    pub tau_step: usize,
}

impl PyramidFl {
    pub fn new() -> PyramidFl {
        PyramidFl { max_tau_factor: 2.0, tau_step: 5 }
    }
}

impl Default for PyramidFl {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheme for PyramidFl {
    fn name(&self) -> &'static str {
        "pyramidfl"
    }

    fn plan_round(&mut self, ctx: &RoundCtx) -> Vec<DevicePlan> {
        let k = ctx.participants.len();
        // rank participants by last-known gradient norm, descending;
        // unseen devices (norm 0.0 sentinel) are treated as most important
        // so they get probed with low compression.
        let mut order: Vec<usize> = (0..k).collect();
        let key = |i: usize| {
            let n = ctx.grad_norms[ctx.participants[i]];
            if n == 0.0 {
                f64::MAX
            } else {
                n
            }
        };
        order.sort_by(|&a, &b| key(b).partial_cmp(&key(a)).unwrap().then(a.cmp(&b)));
        let mut rank = vec![0usize; k];
        for (pos, &i) in order.iter().enumerate() {
            rank[i] = pos;
        }
        // gradient compression ratio from rank (Eq. 6 shape)
        let span = ctx.cfg.theta_max - ctx.cfg.theta_min;
        let ratios: Vec<f64> = (0..k)
            .map(|i| ctx.cfg.theta_min + span * rank[i] as f64 / k.max(1) as f64)
            .collect();

        // per-device iteration count: the slowest participant (at base τ)
        // sets the pace; faster ones fill idle time with extra iterations.
        let base_tau = ctx.cfg.tau;
        let comm =
            |i: usize| ctx.q_bits / ctx.beta_d[i] + (1.0 - ratios[i]) * ctx.q_bits / ctx.beta_u[i];
        let cost = |i: usize, tau: usize| {
            comm(i) + tau as f64 * ctx.cfg.batch as f64 * ctx.mu[i]
        };
        let pace = (0..k)
            .map(|i| cost(i, base_tau))
            .fold(f64::MIN, f64::max);
        ctx.participants
            .iter()
            .enumerate()
            .map(|(i, &device)| {
                let budget = pace - comm(i);
                let tau_fill =
                    (budget / (ctx.cfg.batch as f64 * ctx.mu[i])).floor() as usize;
                let tau_max = (base_tau as f64 * self.max_tau_factor) as usize;
                let tau = tau_fill.clamp(base_tau, tau_max);
                let tau = (tau / self.tau_step.max(1)) * self.tau_step.max(1);
                DevicePlan {
                    device,
                    download: DownloadCodec::Full,
                    upload: UploadCodec::TopK { ratio: ratios[i] },
                    batch: ctx.cfg.batch,
                    tau: tau.max(self.tau_step),
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schemes::tests_support::ctx_fixture;

    #[test]
    fn high_norm_devices_get_low_ratio() {
        let fx = ctx_fixture(5, 10);
        // fixture grad_norms increase with device id → participant 4 has
        // the biggest norm → rank 0 → θ_min
        let mut s = PyramidFl::new();
        let plans = s.plan_round(&fx.ctx());
        let r = |i: usize| match plans[i].upload {
            UploadCodec::TopK { ratio } => ratio,
            _ => panic!(),
        };
        assert!(r(4) < r(1));
        assert!((r(4) - fx.cfg.theta_min).abs() < 1e-9);
    }

    #[test]
    fn unseen_devices_probed_with_low_compression() {
        let mut fx = ctx_fixture(3, 5);
        fx.grad_norms[0] = 0.0; // device 0 unseen
        fx.grad_norms[1] = 10.0;
        fx.grad_norms[2] = 5.0;
        let mut s = PyramidFl::new();
        let plans = s.plan_round(&fx.ctx());
        let r = |i: usize| match plans[i].upload {
            UploadCodec::TopK { ratio } => ratio,
            _ => panic!(),
        };
        assert!(r(0) < r(1) && r(1) < r(2));
    }

    #[test]
    fn fast_devices_do_more_iterations() {
        let fx = ctx_fixture(5, 10);
        let mut s = PyramidFl::new();
        let plans = s.plan_round(&fx.ctx());
        // fixture: μ increases with i → participant 0 is fastest → most τ
        assert!(plans[0].tau >= plans[4].tau);
        assert!(plans[0].tau >= fx.cfg.tau);
        for p in &plans {
            assert_eq!(p.tau % 5, 0, "tau must align to the AOT chunk");
            assert!(p.tau <= fx.cfg.tau * 2);
            assert_eq!(p.download, DownloadCodec::Full);
        }
    }
}

//! Caesar (this paper) and its Fig. 9 ablations.
//!
//! * download: staleness-aware ratio (Eq. 3) via K-cluster grouping,
//!   threshold-split + 1-bit codec with local-model recovery (§4.1)
//! * upload: importance-ranked Top-K ratio (Eq. 4–6, §4.2)
//! * batch: greedy Eq. 7–9 regulation (§4.3)
//!
//! Ablations: `Caesar-BR` replaces the deviation-aware ratios with
//! capability-aware (CAC) ones and plain Top-K download (keeping batch
//! regulation); `Caesar-DC` keeps the deviation-aware compression but uses
//! the fixed identical batch.

use super::{DevicePlan, DownloadCodec, RoundCtx, Scheme, UploadCodec};
use crate::caesar::batchsize::{optimize_batches, BatchPlanInput};
use crate::caesar::staleness::cluster_download_ratios;

pub struct Caesar {
    /// Deviation-aware compression (staleness Eq. 3 + importance Eq. 6).
    /// When false (Caesar-BR): CAC ratios + plain Top-K download codec.
    pub deviation_aware: bool,
    /// Adaptive batch regulation Eq. 7–9. When false (Caesar-DC): fixed.
    pub batch_regulation: bool,
    name: &'static str,
}

impl Caesar {
    pub fn full() -> Caesar {
        Caesar { deviation_aware: true, batch_regulation: true, name: "caesar" }
    }

    /// Fig. 9's Caesar-BR: batch regulation only.
    pub fn without_deviation_aware() -> Caesar {
        Caesar { deviation_aware: false, batch_regulation: true, name: "caesar-br" }
    }

    /// Fig. 9's Caesar-DC: deviation-aware compression only.
    pub fn without_batch_regulation() -> Caesar {
        Caesar { deviation_aware: true, batch_regulation: false, name: "caesar-dc" }
    }
}

impl Scheme for Caesar {
    fn name(&self) -> &'static str {
        self.name
    }

    fn plan_round(&mut self, ctx: &RoundCtx) -> Vec<DevicePlan> {
        let k = ctx.participants.len();
        let cfg = ctx.cfg;

        // --- download ratios ---
        let theta_d: Vec<f64> = if self.deviation_aware {
            let clusters = if cfg.clusters == 0 { k } else { cfg.clusters };
            let (ratios, _) =
                cluster_download_ratios(ctx.staleness, ctx.t, cfg.theta_max, clusters);
            ratios
        } else {
            (0..k)
                .map(|i| ctx.cac_ratio(ctx.beta_d[i], ctx.beta_d))
                .collect()
        };

        // --- upload ratios ---
        let theta_u: Vec<f64> = if self.deviation_aware {
            ctx.participants
                .iter()
                .map(|&d| ctx.importance.upload_ratio(d, cfg.theta_min, cfg.theta_max))
                .collect()
        } else {
            (0..k)
                .map(|i| ctx.cac_ratio(ctx.beta_u[i], ctx.beta_u))
                .collect()
        };

        // --- batch sizes (Eq. 7–9 with nominal payload estimates) ---
        let batches: Vec<usize> = if self.batch_regulation {
            let inputs: Vec<BatchPlanInput> = (0..k)
                .map(|i| BatchPlanInput {
                    // estimated transfer: (1-θ)·Q plus the 1-bit plane for
                    // the caesar codec, matching Eq. 7's θ·Q/β shape
                    download_s: (1.0 - theta_d[i] * (31.0 / 32.0)) * ctx.q_bits
                        / ctx.beta_d[i],
                    upload_s: (1.0 - theta_u[i]) * ctx.q_bits / ctx.beta_u[i],
                    mu: ctx.mu[i],
                })
                .collect();
            optimize_batches(&inputs, cfg.tau, cfg.batch).0
        } else {
            vec![cfg.batch; k]
        };

        (0..k)
            .map(|i| {
                let device = ctx.participants[i];
                let download = if !self.deviation_aware {
                    DownloadCodec::TopK { ratio: theta_d[i] }
                } else if ctx.never[i] {
                    // never participated → no local model → full precision
                    // (Eq. 3 with δ = t gives θ = 0)
                    DownloadCodec::Full
                } else {
                    DownloadCodec::CaesarSplit { ratio: theta_d[i] }
                };
                DevicePlan {
                    device,
                    download,
                    upload: UploadCodec::TopK { ratio: theta_u[i] },
                    batch: batches[i],
                    tau: cfg.tau,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schemes::tests_support::ctx_fixture;

    fn dl_ratio(p: &DevicePlan) -> f64 {
        match p.download {
            DownloadCodec::CaesarSplit { ratio } | DownloadCodec::TopK { ratio } => ratio,
            DownloadCodec::Full => 0.0,
            _ => panic!(),
        }
    }

    fn ul_ratio(p: &DevicePlan) -> f64 {
        match p.upload {
            UploadCodec::TopK { ratio } => ratio,
            _ => panic!(),
        }
    }

    #[test]
    fn fresher_devices_get_more_download_compression() {
        let fx = ctx_fixture(6, 12);
        let mut s = Caesar::full();
        // exact per-device ratios: clusters = participants
        let mut cfg = fx.cfg.clone();
        cfg.clusters = 0;
        let mut fx2 = fx;
        fx2.cfg = cfg;
        let plans = s.plan_round(&fx2.ctx());
        // fixture staleness increases with i → ratio decreases
        for w in plans.windows(2) {
            assert!(dl_ratio(&w[0]) >= dl_ratio(&w[1]) - 1e-9);
        }
    }

    #[test]
    fn never_participated_gets_full_precision() {
        let mut fx = ctx_fixture(3, 5);
        fx.never[2] = true;
        fx.staleness[2] = 5;
        let mut s = Caesar::full();
        let plans = s.plan_round(&fx.ctx());
        assert_eq!(plans[2].download, DownloadCodec::Full);
        assert!(matches!(plans[0].download, DownloadCodec::CaesarSplit { .. }));
    }

    #[test]
    fn important_devices_get_low_upload_ratio() {
        let fx = ctx_fixture(5, 10);
        let mut s = Caesar::full();
        let plans = s.plan_round(&fx.ctx());
        // fixture: importance score grows with device id (volume up, but KL
        // up too — check against the table's own ranks instead)
        for (i, p) in plans.iter().enumerate() {
            let want = fx
                .importance
                .upload_ratio(fx.participants[i], fx.cfg.theta_min, fx.cfg.theta_max);
            assert!((ul_ratio(p) - want).abs() < 1e-12);
        }
    }

    #[test]
    fn batch_regulation_gives_leader_bmax_and_others_less_or_equal() {
        let fx = ctx_fixture(5, 10);
        let mut s = Caesar::full();
        let plans = s.plan_round(&fx.ctx());
        assert!(plans.iter().any(|p| p.batch == fx.cfg.batch));
        assert!(plans.iter().all(|p| (1..=fx.cfg.batch).contains(&p.batch)));
        // heterogeneous fixture → not all equal
        assert!(!plans.iter().all(|p| p.batch == fx.cfg.batch));
    }

    #[test]
    fn ablation_br_uses_cac_and_topk_download() {
        let fx = ctx_fixture(4, 10);
        let mut s = Caesar::without_deviation_aware();
        assert_eq!(s.name(), "caesar-br");
        let plans = s.plan_round(&fx.ctx());
        for p in &plans {
            assert!(matches!(p.download, DownloadCodec::TopK { .. }));
        }
        // CAC: best downlink (participant 0) → θ_min
        assert!((dl_ratio(&plans[0]) - fx.cfg.theta_min).abs() < 1e-9);
    }

    #[test]
    fn ablation_dc_uses_fixed_batch() {
        let fx = ctx_fixture(4, 10);
        let mut s = Caesar::without_batch_regulation();
        assert_eq!(s.name(), "caesar-dc");
        let plans = s.plan_round(&fx.ctx());
        assert!(plans.iter().all(|p| p.batch == fx.cfg.batch));
        assert!(plans
            .iter()
            .any(|p| matches!(p.download, DownloadCodec::CaesarSplit { .. })));
    }
}

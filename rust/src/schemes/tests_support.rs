//! Shared fixture for scheme unit tests: owns the vectors a RoundCtx
//! borrows, with simple deterministic heterogeneity.

use crate::caesar::ImportanceTable;
use crate::config::ExperimentConfig;
use crate::schemes::RoundCtx;

pub struct CtxFixture {
    pub cfg: ExperimentConfig,
    pub t: usize,
    pub participants: Vec<usize>,
    pub staleness: Vec<usize>,
    pub never: Vec<bool>,
    pub beta_d: Vec<f64>,
    pub beta_u: Vec<f64>,
    pub mu: Vec<f64>,
    pub importance: ImportanceTable,
    pub grad_norms: Vec<f64>,
}

/// `k` participants out of a 10-device pool, round `t`.
/// Participant i: staleness i, bandwidth decreasing with i (device 0 is
/// the best-connected), μ increasing with i (device 0 is the fastest).
pub fn ctx_fixture(k: usize, t: usize) -> CtxFixture {
    let cfg = ExperimentConfig::preset("cifar");
    let n_dev = 10;
    let volumes: Vec<usize> = (0..n_dev).map(|i| 100 + i * 50).collect();
    let kls: Vec<f64> = (0..n_dev).map(|i| 0.1 * i as f64).collect();
    CtxFixture {
        cfg,
        t,
        participants: (0..k).collect(),
        staleness: (0..k).map(|i| i.min(t)).collect(),
        never: vec![false; k],
        beta_d: (0..k).map(|i| 20e6 / (1.0 + i as f64)).collect(),
        beta_u: (0..k).map(|i| 16e6 / (1.0 + i as f64)).collect(),
        mu: (0..k).map(|i| 1e-3 * (1.0 + i as f64)).collect(),
        importance: ImportanceTable::build(&volumes, &kls, 0.5),
        // strictly positive (0.0 is the "unseen" sentinel for PyramidFL)
        grad_norms: (0..n_dev).map(|i| (i as f64 + 1.0) * 0.5).collect(),
    }
}

impl CtxFixture {
    pub fn ctx(&self) -> RoundCtx<'_> {
        RoundCtx {
            t: self.t,
            participants: &self.participants,
            staleness: &self.staleness,
            never: &self.never,
            beta_d: &self.beta_d,
            beta_u: &self.beta_u,
            mu: &self.mu,
            q_bits: self.cfg.n_params_paper as f64 * 32.0,
            importance: &self.importance,
            grad_norms: &self.grad_norms,
            cfg: &self.cfg,
        }
    }
}

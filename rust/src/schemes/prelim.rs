//! The Fig. 1 preliminary schemes: FIC (fixed identical compression) and
//! CAC (capability-aware compression) applied to the global model only
//! (GM-*) or the local gradient only (LG-*), plus the no-compression
//! reference. Top-K is the codec for both directions (§2.2); FIC uses a
//! fixed ratio of 0.35, CAC spans [0.1, 0.6] by capability.

use super::{DevicePlan, DownloadCodec, RoundCtx, Scheme, UploadCodec};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Target {
    None,
    GlobalModel,
    LocalGradient,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Policy {
    Fixed,
    CapabilityAware,
}

pub struct Prelim {
    target: Target,
    policy: Policy,
    name: &'static str,
    /// FIC ratio (paper §2.2: 0.35).
    pub fixed_ratio: f64,
}

impl Prelim {
    pub fn no_compression() -> Prelim {
        Prelim { target: Target::None, policy: Policy::Fixed, name: "nocomp", fixed_ratio: 0.0 }
    }

    pub fn gm_fic() -> Prelim {
        Prelim {
            target: Target::GlobalModel,
            policy: Policy::Fixed,
            name: "gm-fic",
            fixed_ratio: 0.35,
        }
    }

    pub fn gm_cac() -> Prelim {
        Prelim {
            target: Target::GlobalModel,
            policy: Policy::CapabilityAware,
            name: "gm-cac",
            fixed_ratio: 0.35,
        }
    }

    pub fn lg_fic() -> Prelim {
        Prelim {
            target: Target::LocalGradient,
            policy: Policy::Fixed,
            name: "lg-fic",
            fixed_ratio: 0.35,
        }
    }

    pub fn lg_cac() -> Prelim {
        Prelim {
            target: Target::LocalGradient,
            policy: Policy::CapabilityAware,
            name: "lg-cac",
            fixed_ratio: 0.35,
        }
    }
}

impl Scheme for Prelim {
    fn name(&self) -> &'static str {
        self.name
    }

    fn plan_round(&mut self, ctx: &RoundCtx) -> Vec<DevicePlan> {
        ctx.participants
            .iter()
            .enumerate()
            .map(|(i, &device)| {
                let ratio_d = match self.policy {
                    Policy::Fixed => self.fixed_ratio,
                    Policy::CapabilityAware => ctx.cac_ratio(ctx.beta_d[i], ctx.beta_d),
                };
                let ratio_u = match self.policy {
                    Policy::Fixed => self.fixed_ratio,
                    Policy::CapabilityAware => ctx.cac_ratio(ctx.beta_u[i], ctx.beta_u),
                };
                DevicePlan {
                    device,
                    download: if self.target == Target::GlobalModel {
                        DownloadCodec::TopK { ratio: ratio_d }
                    } else {
                        DownloadCodec::Full
                    },
                    upload: if self.target == Target::LocalGradient {
                        UploadCodec::TopK { ratio: ratio_u }
                    } else {
                        UploadCodec::Full
                    },
                    batch: ctx.cfg.batch,
                    tau: ctx.cfg.tau,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schemes::tests_support::ctx_fixture;

    #[test]
    fn nocomp_is_fully_uncompressed() {
        let fx = ctx_fixture(3, 5);
        let mut s = Prelim::no_compression();
        for p in s.plan_round(&fx.ctx()) {
            assert_eq!(p.download, DownloadCodec::Full);
            assert_eq!(p.upload, UploadCodec::Full);
        }
    }

    #[test]
    fn gm_fic_compresses_model_only_at_fixed_ratio() {
        let fx = ctx_fixture(4, 5);
        let mut s = Prelim::gm_fic();
        for p in s.plan_round(&fx.ctx()) {
            assert_eq!(p.download, DownloadCodec::TopK { ratio: 0.35 });
            assert_eq!(p.upload, UploadCodec::Full);
        }
    }

    #[test]
    fn lg_cac_compresses_gradient_by_capability() {
        let fx = ctx_fixture(4, 5);
        let mut s = Prelim::lg_cac();
        let plans = s.plan_round(&fx.ctx());
        let ratios: Vec<f64> = plans
            .iter()
            .map(|p| match p.upload {
                UploadCodec::TopK { ratio } => ratio,
                _ => panic!(),
            })
            .collect();
        for p in &plans {
            assert_eq!(p.download, DownloadCodec::Full);
        }
        // weakest uplink (last participant in fixture) gets θ_max
        assert!((ratios[3] - fx.cfg.theta_max).abs() < 1e-9);
        assert!((ratios[0] - fx.cfg.theta_min).abs() < 1e-9);
    }
}

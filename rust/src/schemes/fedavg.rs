//! FedAvg (McMahan et al.) — the uncompressed baseline: full-precision
//! model and gradient, identical fixed batch size on every device.

use super::{DevicePlan, DownloadCodec, RoundCtx, Scheme, UploadCodec};

#[derive(Default)]
pub struct FedAvg;

impl FedAvg {
    pub fn new() -> FedAvg {
        FedAvg
    }
}

impl Scheme for FedAvg {
    fn name(&self) -> &'static str {
        "fedavg"
    }

    fn plan_round(&mut self, ctx: &RoundCtx) -> Vec<DevicePlan> {
        ctx.participants
            .iter()
            .map(|&device| DevicePlan {
                device,
                download: DownloadCodec::Full,
                upload: UploadCodec::Full,
                batch: ctx.cfg.batch,
                tau: ctx.cfg.tau,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schemes::tests_support::ctx_fixture;

    #[test]
    fn plans_are_uncompressed_and_uniform() {
        let fx = ctx_fixture(4, 3);
        let mut s = FedAvg::new();
        let plans = s.plan_round(&fx.ctx());
        assert_eq!(plans.len(), 4);
        for p in &plans {
            assert_eq!(p.download, DownloadCodec::Full);
            assert_eq!(p.upload, UploadCodec::Full);
            assert_eq!(p.batch, fx.cfg.batch);
            assert_eq!(p.tau, fx.cfg.tau);
        }
    }
}

//! FL schemes: Caesar and the paper's baselines behind one trait.
//!
//! A scheme decides, per round, each participant's download codec, upload
//! codec, batch size and local-iteration count. The coordinator executes
//! the plan; schemes never touch tensors.
//!
//! Paper mapping (§6.1 Baselines):
//! * [`fedavg`]    — FedAvg: no compression, fixed identical batch.
//! * [`flexcom`]   — FlexCom: bandwidth-aware Top-K gradient compression,
//!                   identical gradually-increasing batch.
//! * [`prowd`]     — ProWD: bandwidth-chosen quantization of model AND
//!                   gradient.
//! * [`pyramidfl`] — PyramidFL: gradient-norm-ranked gradient compression,
//!                   per-device local-iteration adjustment.
//! * [`caesar`]    — Caesar (+ the Fig. 9 ablations Caesar-BR/Caesar-DC).
//! * [`prelim`]    — the Fig. 1 preliminary schemes (GM/LG × FIC/CAC).

pub mod caesar;
pub mod fedavg;
pub mod flexcom;
pub mod prelim;
pub mod prowd;
pub mod pyramidfl;

#[cfg(test)]
pub mod tests_support;

use crate::caesar::ImportanceTable;
use crate::compress::{self, quant, topk};
use crate::config::ExperimentConfig;
use crate::util::rng::Rng;
use crate::wire::Payload;

/// How the global model is compressed for download.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DownloadCodec {
    /// Full fp32 model.
    Full,
    /// Caesar §4.1 threshold-split + 1-bit + recovery. `ratio` = quantized
    /// fraction.
    CaesarSplit { ratio: f64 },
    /// Plain Top-K sparsification; dropped positions are filled from the
    /// receiver's stale local model (the GM-FIC/GM-CAC baselines).
    TopK { ratio: f64 },
    /// Stochastic uniform quantization to `bits` value bits (ProWD).
    Quant { bits: u32 },
}

impl DownloadCodec {
    /// Construct the exact wire payload this codec emits for the global
    /// model `w` (native backend; the PJRT path lives in `CodecEngine`).
    /// Quant draws from `rng` per the contract in `compress::quant`.
    pub fn encode_payload(self, w: &[f32], rng: &mut Rng) -> Payload {
        match self {
            DownloadCodec::Full => Payload::Dense(w.to_vec()),
            DownloadCodec::CaesarSplit { ratio } => {
                Payload::CaesarSplit(compress::caesar_compress(w, ratio))
            }
            DownloadCodec::TopK { ratio } => topk::topk_encode(w, ratio).0,
            DownloadCodec::Quant { bits } => quant::quant_payload(w, bits, rng).0,
        }
    }
}

/// How the local gradient is compressed for upload.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum UploadCodec {
    Full,
    /// Top-K: `ratio` = dropped fraction.
    TopK { ratio: f64 },
    Quant { bits: u32 },
}

impl UploadCodec {
    /// Construct the exact wire payload this codec emits for gradient `g`
    /// (native backend; the PJRT path lives in `CodecEngine`).
    pub fn encode_payload(self, g: &[f32], rng: &mut Rng) -> Payload {
        match self {
            UploadCodec::Full => Payload::Dense(g.to_vec()),
            UploadCodec::TopK { ratio } => topk::topk_encode(g, ratio).0,
            UploadCodec::Quant { bits } => quant::quant_payload(g, bits, rng).0,
        }
    }
}

/// The per-participant decision for one round.
#[derive(Clone, Copy, Debug)]
pub struct DevicePlan {
    pub device: usize,
    pub download: DownloadCodec,
    pub upload: UploadCodec,
    pub batch: usize,
    pub tau: usize,
}

/// Everything a scheme may consult when planning a round. Slices are
/// indexed by participant position (not device id) unless noted.
pub struct RoundCtx<'a> {
    /// 1-based round number.
    pub t: usize,
    /// Selected device ids.
    pub participants: &'a [usize],
    /// δ_i^t per participant.
    pub staleness: &'a [usize],
    /// True if the participant has never trained (no local model).
    pub never: &'a [bool],
    /// This round's download/upload bandwidth (bit/s) per participant.
    pub beta_d: &'a [f64],
    pub beta_u: &'a [f64],
    /// Per-sample compute latency (s) per participant.
    pub mu: &'a [f64],
    /// Paper-scale uncompressed payload Q in bits (Eq. 7).
    pub q_bits: f64,
    /// Static data-importance table over ALL devices (indexed by id).
    pub importance: &'a ImportanceTable,
    /// Last observed gradient norm per device id (0.0 = none yet).
    pub grad_norms: &'a [f64],
    pub cfg: &'a ExperimentConfig,
}

impl<'a> RoundCtx<'a> {
    /// Normalized position of `x` within `xs` (0 = min, 1 = max).
    pub fn norm_frac(xs: &[f64], x: f64) -> f64 {
        let (mut lo, mut hi) = (f64::MAX, f64::MIN);
        for &v in xs {
            lo = lo.min(v);
            hi = hi.max(v);
        }
        if hi <= lo {
            return 0.5;
        }
        (x - lo) / (hi - lo)
    }

    /// Capability-aware compression ratio (the CAC policy used by the
    /// preliminary experiments and FlexCom): weakest link → θ_max.
    pub fn cac_ratio(&self, bandwidth: f64, all: &[f64]) -> f64 {
        let frac = Self::norm_frac(all, bandwidth);
        self.cfg.theta_max - (self.cfg.theta_max - self.cfg.theta_min) * frac
    }
}

/// A federated-learning scheme.
pub trait Scheme: Send {
    fn name(&self) -> &'static str;

    /// Plan one round (returns one plan per participant, same order).
    fn plan_round(&mut self, ctx: &RoundCtx) -> Vec<DevicePlan>;
}

/// Construct a scheme by CLI name.
pub fn by_name(name: &str) -> Option<Box<dyn Scheme>> {
    match name {
        "fedavg" => Some(Box::new(fedavg::FedAvg::new())),
        "flexcom" => Some(Box::new(flexcom::FlexCom::new())),
        "prowd" => Some(Box::new(prowd::ProWd::new())),
        "pyramidfl" => Some(Box::new(pyramidfl::PyramidFl::new())),
        "caesar" => Some(Box::new(caesar::Caesar::full())),
        "caesar-br" => Some(Box::new(caesar::Caesar::without_deviation_aware())),
        "caesar-dc" => Some(Box::new(caesar::Caesar::without_batch_regulation())),
        "nocomp" => Some(Box::new(prelim::Prelim::no_compression())),
        "gm-fic" => Some(Box::new(prelim::Prelim::gm_fic())),
        "gm-cac" => Some(Box::new(prelim::Prelim::gm_cac())),
        "lg-fic" => Some(Box::new(prelim::Prelim::lg_fic())),
        "lg-cac" => Some(Box::new(prelim::Prelim::lg_cac())),
        _ => None,
    }
}

/// The five head-to-head schemes of Figures 5–7 / Table 3.
pub const MAIN_SCHEMES: [&str; 5] = ["fedavg", "flexcom", "prowd", "pyramidfl", "caesar"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn by_name_covers_all() {
        for n in [
            "fedavg",
            "flexcom",
            "prowd",
            "pyramidfl",
            "caesar",
            "caesar-br",
            "caesar-dc",
            "nocomp",
            "gm-fic",
            "gm-cac",
            "lg-fic",
            "lg-cac",
        ] {
            assert!(by_name(n).is_some(), "{n}");
        }
        assert!(by_name("sgd").is_none());
    }

    #[test]
    fn norm_frac_bounds() {
        let xs = [1.0, 5.0, 9.0];
        assert_eq!(RoundCtx::norm_frac(&xs, 1.0), 0.0);
        assert_eq!(RoundCtx::norm_frac(&xs, 9.0), 1.0);
        assert_eq!(RoundCtx::norm_frac(&xs, 5.0), 0.5);
        assert_eq!(RoundCtx::norm_frac(&[3.0, 3.0], 3.0), 0.5);
    }
}

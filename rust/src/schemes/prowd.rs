//! ProWD (Yoon et al., ICML'22) — bit-width-heterogeneous FL: both the
//! downloaded model and the uploaded gradient are quantized, with the
//! per-device bit-width chosen from its bandwidth (weak links → fewer
//! bits). Fixed identical batch.

use super::{DevicePlan, DownloadCodec, RoundCtx, Scheme, UploadCodec};
use crate::compress::quant::bits_for_bandwidth;

pub struct ProWd {
    pub min_bits: u32,
    pub max_bits: u32,
}

impl ProWd {
    /// §6.1 bounds every scheme's compression ratio to [0.1, 0.6]; for a
    /// bit-width codec that is a payload of 40%–90% of fp32, i.e. roughly
    /// 12–28 value bits per element (1 sign bit + b bucket bits ≈
    /// 32·(1−θ)). Matches the paper's Table 3, where ProWD saves ~27%
    /// traffic, not the 4× an unbounded 2–8-bit policy would give.
    pub fn new() -> ProWd {
        ProWd { min_bits: 12, max_bits: 28 }
    }
}

impl Default for ProWd {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheme for ProWd {
    fn name(&self) -> &'static str {
        "prowd"
    }

    fn plan_round(&mut self, ctx: &RoundCtx) -> Vec<DevicePlan> {
        ctx.participants
            .iter()
            .enumerate()
            .map(|(i, &device)| {
                let frac_d = RoundCtx::norm_frac(ctx.beta_d, ctx.beta_d[i]);
                let frac_u = RoundCtx::norm_frac(ctx.beta_u, ctx.beta_u[i]);
                DevicePlan {
                    device,
                    download: DownloadCodec::Quant {
                        bits: bits_for_bandwidth(frac_d, self.min_bits, self.max_bits),
                    },
                    upload: UploadCodec::Quant {
                        bits: bits_for_bandwidth(frac_u, self.min_bits, self.max_bits),
                    },
                    batch: ctx.cfg.batch,
                    tau: ctx.cfg.tau,
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schemes::tests_support::ctx_fixture;

    #[test]
    fn weak_links_get_fewer_bits() {
        let fx = ctx_fixture(5, 10);
        let mut s = ProWd::new();
        let plans = s.plan_round(&fx.ctx());
        let bits: Vec<u32> = plans
            .iter()
            .map(|p| match p.download {
                DownloadCodec::Quant { bits } => bits,
                _ => panic!(),
            })
            .collect();
        // beta decreases with i → bits decrease with i
        for w in bits.windows(2) {
            assert!(w[0] >= w[1]);
        }
        assert_eq!(bits[0], 28);
        assert_eq!(bits[4], 12);
    }

    #[test]
    fn both_directions_quantized() {
        let fx = ctx_fixture(3, 2);
        let mut s = ProWd::new();
        for p in s.plan_round(&fx.ctx()) {
            assert!(matches!(p.download, DownloadCodec::Quant { .. }));
            assert!(matches!(p.upload, UploadCodec::Quant { .. }));
            assert_eq!(p.batch, fx.cfg.batch);
        }
    }
}

//! FlexCom (Li et al., INFOCOM'21) — capability-aware Top-K compression of
//! the *local gradients only*: participants with weaker upload bandwidth
//! use larger compression ratios. Devices share an identical, gradually
//! increasing batch size (§6.1).

use super::{DevicePlan, DownloadCodec, RoundCtx, Scheme, UploadCodec};

pub struct FlexCom {
    /// Batch ramp start (grows linearly to cfg.batch over the run).
    start_batch: usize,
}

impl FlexCom {
    pub fn new() -> FlexCom {
        FlexCom { start_batch: 8 }
    }
}

impl Default for FlexCom {
    fn default() -> Self {
        Self::new()
    }
}

impl Scheme for FlexCom {
    fn name(&self) -> &'static str {
        "flexcom"
    }

    fn plan_round(&mut self, ctx: &RoundCtx) -> Vec<DevicePlan> {
        // identical gradually-increasing batch: linear ramp over the run
        let frac = (ctx.t as f64 / ctx.cfg.rounds.max(1) as f64).min(1.0);
        let batch = (self.start_batch as f64
            + frac * (ctx.cfg.batch.saturating_sub(self.start_batch)) as f64)
            .round() as usize;
        let batch = batch.clamp(1, ctx.cfg.batch);
        ctx.participants
            .iter()
            .enumerate()
            .map(|(i, &device)| DevicePlan {
                device,
                download: DownloadCodec::Full,
                upload: UploadCodec::TopK {
                    ratio: ctx.cac_ratio(ctx.beta_u[i], ctx.beta_u),
                },
                batch,
                tau: ctx.cfg.tau,
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schemes::tests_support::ctx_fixture;

    #[test]
    fn weakest_uplink_gets_largest_ratio() {
        let fx = ctx_fixture(5, 10);
        let mut s = FlexCom::new();
        let plans = s.plan_round(&fx.ctx());
        let ratios: Vec<f64> = plans
            .iter()
            .map(|p| match p.upload {
                UploadCodec::TopK { ratio } => ratio,
                _ => panic!("expected topk"),
            })
            .collect();
        // fixture: beta_u decreases with i → ratio increases with i
        for w in ratios.windows(2) {
            assert!(w[0] <= w[1] + 1e-12);
        }
        assert!((ratios[0] - fx.cfg.theta_min).abs() < 1e-9);
        assert!((ratios[4] - fx.cfg.theta_max).abs() < 1e-9);
    }

    #[test]
    fn batch_ramps_up_over_rounds() {
        let fx_early = ctx_fixture(3, 1);
        let fx_late = ctx_fixture(3, 250);
        let mut s = FlexCom::new();
        let b_early = s.plan_round(&fx_early.ctx())[0].batch;
        let b_late = s.plan_round(&fx_late.ctx())[0].batch;
        assert!(b_early < b_late);
        assert_eq!(b_late, fx_late.cfg.batch);
        // identical across participants
        let plans = s.plan_round(&fx_early.ctx());
        assert!(plans.iter().all(|p| p.batch == plans[0].batch));
    }

    #[test]
    fn model_download_uncompressed() {
        let fx = ctx_fixture(3, 5);
        let mut s = FlexCom::new();
        for p in s.plan_round(&fx.ctx()) {
            assert_eq!(p.download, DownloadCodec::Full);
        }
    }
}

//! Experiment configuration: per-dataset presets from the paper's §6.1
//! plus `key=value` CLI overrides.

use crate::fleet::FleetKind;
use crate::util::cli::Args;

/// Which trainer executes the local SGD iterations.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainerBackend {
    /// The AOT HLO artifacts via PJRT (the real three-layer path).
    Xla,
    /// The native rust oracle in `nn/` (artifact-free fallback; used by
    /// unit tests and available via `--trainer native`).
    Native,
}

/// Which implementation performs model/gradient compression.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CompressionBackend {
    /// rust-native codecs (default: any shape, any scale).
    Native,
    /// The AOT-lowered L1 Pallas kernels via PJRT (parity-pinned).
    Xla,
}

/// Round-engine knobs (the event-driven coordinator in `engine/`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EngineConfig {
    /// Worker threads executing device rounds. 1 = sequential execution on
    /// the coordinator thread (the default, and the parity baseline);
    /// values above the host's parallelism are clamped. The persistent
    /// worker pool is sized from this once, at `Server` construction —
    /// changing it afterwards has no effect on an existing server.
    pub workers: usize,
    /// Devices per aggregation group — the fixed fan-in of the canonical
    /// f64 reduction tree. Results are bit-identical across worker counts
    /// precisely because this does NOT depend on `workers`; changing it
    /// changes last-bit rounding (like changing batch order would).
    pub agg_group: usize,
    /// Elements per aggregation *chunk* — partial sums are stored as
    /// runs of this many f64s (rounded up to a power of two) so no
    /// single reduction buffer is model-sized and chunk storage recycles
    /// through the pool. `0` disables chunk-sharding (one flat buffer
    /// per partial sum). Bit-transparent, unlike `agg_group`: chunking
    /// only splits storage, never the element order or arithmetic, so
    /// any value produces identical model bits.
    pub agg_chunk: usize,
    /// Per-device probability of vanishing mid-round (0 disables).
    pub dropout_rate: f64,
    /// Simulated device heartbeat interval in seconds (<= 0 disables
    /// liveness pings).
    pub heartbeat_s: f64,
    /// Rounds the coordinator may hold in flight at once. `1` (the
    /// default) is the classic hard barrier: round t fully closes before
    /// round t+1 opens, bit-identical to the pre-pipelining engine.
    /// Values above 1 open round t+1 (participant selection, download
    /// encodes, device execution) while round t's stragglers drain.
    pub pipeline_depth: usize,
    /// Maximum rounds a straggler's upload may fold late (semi-async
    /// staleness bound). `0` (the default) means every upload folds into
    /// its own round — the barrier semantics. With S >= 1, an upload
    /// whose round cost exceeds twice the round's median folds into a
    /// later round's aggregate (at most S rounds later), so the slowest
    /// devices stop holding the barrier.
    pub staleness_bound: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 1,
            agg_group: 8,
            agg_chunk: detect_agg_chunk(),
            dropout_rate: 0.0,
            heartbeat_s: 10.0,
            pipeline_depth: 1,
            staleness_bound: 0,
        }
    }
}

/// Fallback aggregation chunk length (f64 elements) when the L2 cache
/// size cannot be detected: 64Ki elements = 512 KiB per chunk, the
/// pre-autotune default.
pub const AGG_CHUNK_FALLBACK: usize = 65_536;

/// Default `agg_chunk`, autotuned from the detected L2 cache size so a
/// partial-sum chunk fits the per-core cache: `L2 bytes / 8` f64
/// elements, clamped to [4Ki, 1Mi] and detected once per process.
/// Chunking is bit-transparent (it splits storage, never arithmetic), so
/// the autotuned value only moves performance — an explicit `agg-chunk=`
/// override always wins, and `EngineStats::agg_chunk` records what a run
/// actually used.
pub fn detect_agg_chunk() -> usize {
    static CHUNK: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *CHUNK.get_or_init(|| {
        parse_cache_size(
            &std::fs::read_to_string("/sys/devices/system/cpu/cpu0/cache/index2/size")
                .unwrap_or_default(),
        )
        .map(|bytes| (bytes / 8).clamp(1 << 12, 1 << 20))
        .unwrap_or(AGG_CHUNK_FALLBACK)
    })
}

/// Parse a sysfs cache-size string ("512K", "4M", "1048576") to bytes.
fn parse_cache_size(s: &str) -> Option<usize> {
    let s = s.trim();
    let (digits, mult) = match s.as_bytes().last()? {
        b'K' | b'k' => (&s[..s.len() - 1], 1024),
        b'M' | b'm' => (&s[..s.len() - 1], 1024 * 1024),
        _ => (s, 1),
    };
    let v: usize = digits.trim().parse().ok()?;
    (v > 0).then(|| v.saturating_mul(mult))
}

/// Full configuration of one FL experiment run.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    /// Task/dataset name: cifar | har | speech | oppo.
    pub task: String,
    pub fleet: FleetKind,
    /// Total training samples across the fleet (test set is extra).
    pub n_train: usize,
    pub n_test: usize,
    /// Communication rounds (paper §6.1 defaults).
    pub rounds: usize,
    /// Participation fraction α.
    pub alpha: f64,
    /// Local iterations τ.
    pub tau: usize,
    /// Default/maximum batch size.
    pub batch: usize,
    /// Initial learning rate and per-round decay.
    pub lr: f64,
    pub lr_decay: f64,
    /// Data heterogeneity level p = 1/δ (0 = IID).
    pub het_p: f64,
    /// Compression ratio bounds [θ_min, θ_max] (paper: [0.1, 0.6]).
    pub theta_min: f64,
    pub theta_max: f64,
    /// Importance mix λ (Eq. 5).
    pub lambda: f64,
    /// Staleness clusters K (0 = exact per-device ratios).
    pub clusters: usize,
    /// Paper-scale parameter count for traffic/time simulation
    /// (compress/traffic.rs::PayloadScale).
    pub n_params_paper: usize,
    /// Relative per-sample compute cost vs the cifar stand-in.
    pub model_cost: f64,
    /// Evaluate every this many rounds.
    pub eval_every: usize,
    /// Target accuracy (or AUC for oppo) for *-to-accuracy metrics.
    pub target_acc: f64,
    pub seed: u64,
    pub trainer: TrainerBackend,
    pub compression: CompressionBackend,
    /// Event-driven round-engine knobs.
    pub engine: EngineConfig,
}

impl ExperimentConfig {
    /// Paper §6.1 defaults for each dataset.
    pub fn preset(task: &str) -> ExperimentConfig {
        let base = ExperimentConfig {
            task: task.to_string(),
            fleet: FleetKind::Jetson80,
            n_train: 20_000,
            n_test: 4_000,
            rounds: 250,
            alpha: 0.1,
            tau: 30,
            batch: 32,
            lr: 0.1,
            lr_decay: 0.993,
            het_p: 5.0,
            theta_min: 0.1,
            theta_max: 0.6,
            lambda: 0.5,
            clusters: 4,
            n_params_paper: 11_689_512, // ResNet-18
            model_cost: 1.0,
            eval_every: 1,
            target_acc: 0.80,
            seed: 42,
            trainer: TrainerBackend::Xla,
            compression: CompressionBackend::Native,
            engine: EngineConfig::default(),
        };
        match task {
            "cifar" => base,
            "har" => ExperimentConfig {
                n_train: 7_352,
                n_test: 2_000,
                rounds: 150,
                tau: 10,
                batch: 16,
                // paper's HAR lr is 0.01 on CNN-H; the MLP stand-in needs a
                // proportionally larger step (substitution, DESIGN.md §3)
                lr: 0.06,
                lr_decay: 0.99,
                n_params_paper: 4_600_000, // CNN-H scale
                model_cost: 0.4,
                target_acc: 0.86,
                ..base
            },
            "speech" => ExperimentConfig {
                n_train: 20_000,
                n_test: 4_000,
                n_params_paper: 35_000, // CNN-S (paper traffic is MB-scale)
                model_cost: 0.8,
                target_acc: 0.87,
                ..base
            },
            "oppo" => ExperimentConfig {
                fleet: FleetKind::Phone40,
                n_train: 9_000,
                n_test: 1_000,
                rounds: 50,
                n_params_paper: 129_314, // 129,314-feature LR
                model_cost: 0.15,
                target_acc: 0.65, // AUC target
                ..base
            },
            other => panic!("unknown task preset {other}"),
        }
    }

    /// Apply `key=value` overrides from the CLI.
    pub fn apply_overrides(mut self, args: &Args) -> ExperimentConfig {
        if let Some(v) = args.get_usize("rounds") {
            self.rounds = v;
        }
        if let Some(v) = args.get_f64("alpha") {
            self.alpha = v;
        }
        if let Some(v) = args.get_usize("tau") {
            self.tau = v;
        }
        if let Some(v) = args.get_usize("batch") {
            self.batch = v;
        }
        if let Some(v) = args.get_f64("lr") {
            self.lr = v;
        }
        if let Some(v) = args.get_f64("lr-decay") {
            self.lr_decay = v;
        }
        if let Some(v) = args.get_f64("p") {
            self.het_p = v;
        }
        if let Some(v) = args.get_f64("theta-min") {
            self.theta_min = v;
        }
        if let Some(v) = args.get_f64("theta-max") {
            self.theta_max = v;
        }
        if let Some(v) = args.get_f64("lambda") {
            self.lambda = v;
        }
        if let Some(v) = args.get_usize("clusters") {
            self.clusters = v;
        }
        if let Some(v) = args.get_usize("devices") {
            self.fleet = FleetKind::JetsonScaled(v);
        }
        if let Some(v) = args.get_u64("seed") {
            self.seed = v;
        }
        if let Some(v) = args.get_f64("target") {
            self.target_acc = v;
        }
        if let Some(v) = args.get_usize("eval-every") {
            self.eval_every = v.max(1);
        }
        if let Some(v) = args.get_usize("n-train") {
            self.n_train = v;
        }
        if let Some(v) = args.get("trainer") {
            self.trainer = match v {
                "native" => TrainerBackend::Native,
                "xla" => TrainerBackend::Xla,
                other => panic!("unknown trainer {other}"),
            };
        }
        if let Some(v) = args.get_usize("engine-workers") {
            self.engine.workers = v.max(1);
        }
        if let Some(v) = args.get_usize("agg-group") {
            self.engine.agg_group = v.max(1);
        }
        if let Some(v) = args.get_usize("agg-chunk") {
            self.engine.agg_chunk = v;
        }
        if let Some(v) = args.get_f64("dropout") {
            self.engine.dropout_rate = v.clamp(0.0, 1.0);
        }
        if let Some(v) = args.get_f64("heartbeat") {
            self.engine.heartbeat_s = v;
        }
        if let Some(v) = args.get_usize("pipeline-depth") {
            self.engine.pipeline_depth = v.max(1);
        }
        if let Some(v) = args.get_usize("staleness-bound") {
            self.engine.staleness_bound = v;
        }
        if let Some(v) = args.get("compression-backend") {
            self.compression = match v {
                "native" => CompressionBackend::Native,
                "xla" => CompressionBackend::Xla,
                other => panic!("unknown compression backend {other}"),
            };
        }
        self
    }

    /// Number of devices in the configured fleet.
    pub fn n_devices(&self) -> usize {
        match self.fleet {
            FleetKind::Jetson80 => 80,
            FleetKind::Phone40 => 40,
            FleetKind::JetsonScaled(n) => n,
        }
    }

    /// Participants per round: max(1, round(α·n)).
    pub fn participants_per_round(&self) -> usize {
        ((self.alpha * self.n_devices() as f64).round() as usize).max(1)
    }

    /// Learning rate at round t (exponential decay, paper §6.1).
    pub fn lr_at(&self, t: usize) -> f64 {
        self.lr * self.lr_decay.powi(t as i32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_table() {
        let c = ExperimentConfig::preset("cifar");
        assert_eq!((c.rounds, c.tau, c.batch), (250, 30, 32));
        assert_eq!(c.n_devices(), 80);
        let h = ExperimentConfig::preset("har");
        assert_eq!((h.rounds, h.tau, h.batch), (150, 10, 16));
        // lr is re-tuned for the MLP stand-in (DESIGN.md §Substitutions);
        // rounds/τ/batch keep the paper's Table values.
        assert!((h.lr - 0.06).abs() < 1e-12);
        let o = ExperimentConfig::preset("oppo");
        assert_eq!(o.rounds, 50);
        assert_eq!(o.n_devices(), 40);
        let s = ExperimentConfig::preset("speech");
        assert_eq!(s.rounds, 250);
    }

    #[test]
    fn participants_respect_alpha() {
        let c = ExperimentConfig::preset("cifar");
        assert_eq!(c.participants_per_round(), 8);
        let o = ExperimentConfig::preset("oppo");
        assert_eq!(o.participants_per_round(), 4);
    }

    #[test]
    fn lr_decays() {
        let c = ExperimentConfig::preset("cifar");
        assert!((c.lr_at(0) - 0.1).abs() < 1e-12);
        assert!(c.lr_at(100) < c.lr_at(10));
    }

    #[test]
    fn overrides_apply() {
        let args = Args::parse(
            "x rounds=10 p=2.5 devices=100 trainer=native seed=7"
                .split_whitespace()
                .map(String::from),
        );
        let c = ExperimentConfig::preset("cifar").apply_overrides(&args);
        assert_eq!(c.rounds, 10);
        assert_eq!(c.het_p, 2.5);
        assert_eq!(c.n_devices(), 100);
        assert_eq!(c.trainer, TrainerBackend::Native);
        assert_eq!(c.seed, 7);
        assert_eq!(c.engine, EngineConfig::default());
    }

    #[test]
    fn engine_overrides_apply_and_clamp() {
        let args = Args::parse(
            "x engine-workers=4 agg-group=16 agg-chunk=1024 dropout=1.5 heartbeat=2.5"
                .split_whitespace()
                .map(String::from),
        );
        let c = ExperimentConfig::preset("har").apply_overrides(&args);
        assert_eq!(c.engine.workers, 4);
        assert_eq!(c.engine.agg_group, 16);
        assert_eq!(c.engine.agg_chunk, 1024);
        assert_eq!(c.engine.dropout_rate, 1.0); // clamped to a probability
        assert_eq!(c.engine.heartbeat_s, 2.5);
        // agg-chunk=0 is a valid setting: chunk-sharding off
        let off = Args::parse("x agg-chunk=0".split_whitespace().map(String::from));
        assert_eq!(ExperimentConfig::preset("har").apply_overrides(&off).engine.agg_chunk, 0);
        // zero workers clamps up to 1
        let z = Args::parse("x engine-workers=0".split_whitespace().map(String::from));
        assert_eq!(ExperimentConfig::preset("har").apply_overrides(&z).engine.workers, 1);
    }

    #[test]
    fn pipeline_knobs_default_to_the_barrier_and_apply() {
        let d = EngineConfig::default();
        assert_eq!((d.pipeline_depth, d.staleness_bound), (1, 0));
        let args = Args::parse(
            "x pipeline-depth=2 staleness-bound=3".split_whitespace().map(String::from),
        );
        let c = ExperimentConfig::preset("har").apply_overrides(&args);
        assert_eq!(c.engine.pipeline_depth, 2);
        assert_eq!(c.engine.staleness_bound, 3);
        // depth 0 clamps up to 1 (the barrier)
        let z = Args::parse("x pipeline-depth=0".split_whitespace().map(String::from));
        assert_eq!(
            ExperimentConfig::preset("har").apply_overrides(&z).engine.pipeline_depth,
            1
        );
    }

    #[test]
    fn agg_chunk_autotune_parses_sysfs_sizes_and_falls_back() {
        assert_eq!(parse_cache_size("512K\n"), Some(512 * 1024));
        assert_eq!(parse_cache_size("4M"), Some(4 * 1024 * 1024));
        assert_eq!(parse_cache_size("1048576"), Some(1024 * 1024));
        assert_eq!(parse_cache_size(""), None);
        assert_eq!(parse_cache_size("0K"), None);
        assert_eq!(parse_cache_size("nope"), None);
        // the detected default is clamped and power-of-two-friendly; the
        // fallback is the historical 64Ki elements
        let d = detect_agg_chunk();
        assert!((1 << 12..=1 << 20).contains(&d), "detected {d}");
        assert_eq!(EngineConfig::default().agg_chunk, d);
    }

    #[test]
    #[should_panic(expected = "unknown task")]
    fn unknown_preset_panics() {
        ExperimentConfig::preset("mnist");
    }
}

//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`) emitted by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//!
//! HLO *text* is the interchange format (xla_extension 0.5.1 rejects
//! jax ≥ 0.5 serialized protos; the text parser reassigns instruction ids
//! — see DESIGN.md §2 and /opt/xla-example/README.md). Modules are
//! compiled lazily on first use and cached for the life of the process:
//! python never runs on the request path.

pub mod manifest;

pub use manifest::{Manifest, ModuleSpec, TensorSpec};

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

/// A loaded artifact set + PJRT client with a lazy executable cache.
///
/// NOTE: the underlying PJRT wrappers hold raw pointers; `Runtime` is
/// intentionally not Sync — callers on worker threads create one runtime
/// each or serialize access (the coordinator uses one runtime per worker).
pub struct Runtime {
    dir: PathBuf,
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<String, std::rc::Rc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open an artifact directory (must contain `manifest.json`).
    pub fn open(dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(&dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime {
            dir: dir.to_path_buf(),
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    /// The default artifact directory (`$CAESAR_ARTIFACTS` or `artifacts/`).
    pub fn default_dir() -> PathBuf {
        std::env::var("CAESAR_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (or fetch from cache) a module by manifest name.
    fn executable(&self, name: &str) -> Result<std::rc::Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.borrow().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .manifest
            .module(name)
            .ok_or_else(|| anyhow!("module {name} not in manifest"))?;
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {name}: {e:?}"))?;
        let rc = std::rc::Rc::new(exe);
        self.cache.borrow_mut().insert(name.to_string(), rc.clone());
        Ok(rc)
    }

    /// Pre-compile a list of modules (warm-up; avoids first-call latency).
    pub fn warm(&self, names: &[&str]) -> Result<()> {
        for n in names {
            self.executable(n)?;
        }
        Ok(())
    }

    /// Execute a module. Inputs are validated against the manifest.
    /// All our modules are lowered with `return_tuple=True`, so the result
    /// is always the decomposed tuple of output literals.
    pub fn exec(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let spec = self
            .manifest
            .module(name)
            .ok_or_else(|| anyhow!("module {name} not in manifest"))?;
        if inputs.len() != spec.inputs.len() {
            return Err(anyhow!(
                "{name}: expected {} inputs, got {}",
                spec.inputs.len(),
                inputs.len()
            ));
        }
        for (i, (lit, ts)) in inputs.iter().zip(&spec.inputs).enumerate() {
            let n: usize = ts.shape.iter().product();
            if lit.element_count() != n {
                return Err(anyhow!(
                    "{name}: input {i} has {} elements, manifest says {:?}",
                    lit.element_count(),
                    ts.shape
                ));
            }
        }
        let exe = self.executable(name)?;
        // NOTE: we deliberately avoid `PjRtLoadedExecutable::execute`
        // (literal inputs): its C++ shim `release()`s the uploaded input
        // buffers without ever freeing them, leaking ~the full input
        // payload per call (≈1 GB per 250-round run). Uploading through
        // `buffer_from_host_literal` keeps ownership on our side — the
        // buffers free on drop — and `execute_b` borrows them.
        let mut bufs = Vec::with_capacity(inputs.len());
        for (i, lit) in inputs.iter().enumerate() {
            bufs.push(
                self.client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("uploading input {i} of {name}: {e:?}"))?,
            );
        }
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&bufs)
            .map_err(|e| anyhow!("executing {name}: {e:?}"))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of {name}: {e:?}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untupling {name}: {e:?}"))
    }
}

/// Build a f32 literal with the given dims.
pub fn lit_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("lit_f32: {} elements for dims {dims:?}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Build an i32 literal with the given dims.
pub fn lit_i32(data: &[i32], dims: &[i64]) -> Result<xla::Literal> {
    let n: i64 = dims.iter().product();
    if n as usize != data.len() {
        return Err(anyhow!("lit_i32: {} elements for dims {dims:?}", data.len()));
    }
    xla::Literal::vec1(data)
        .reshape(dims)
        .map_err(|e| anyhow!("reshape: {e:?}"))
}

/// Scalar f32 literal.
pub fn lit_scalar(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Extract a f32 vector from a literal.
pub fn to_vec_f32(lit: &xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec f32: {e:?}"))
}

/// Extract the single f32 from a scalar literal.
pub fn to_scalar_f32(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>()
        .map_err(|e| anyhow!("scalar f32: {e:?}"))
}
